//! Predictor ablation (paper Fig. 16): LSTM vs reactive vs oracle on
//! the bursty workload, measuring SLA violations and cost.
//!
//! The LSTM runs through the real PJRT artifact when `artifacts/`
//! exists (build with `make artifacts`), demonstrating the predictor on
//! the Rust control path with no Python.
//!
//! Run: `cargo run --release --example predictor_ablation`

use ipa::coordinator::adapter::Policy;
use ipa::models::accuracy::AccuracyMetric;
use ipa::reports::figures::{run_cell, EvalOpts, PredKind};
use ipa::util::cli::Args;
use ipa::workload::tracegen::Pattern;

fn main() {
    let args = Args::from_env();
    let seconds = args.get_usize("seconds", 420);
    let artifacts = if std::path::Path::new("artifacts/manifest.json").exists() {
        Some("artifacts".to_string())
    } else {
        eprintln!("warning: artifacts/ missing — LSTM falls back to reactive");
        None
    };
    let mut opts = EvalOpts::new(seconds, artifacts);

    for pipeline in ["video", "audio-qa", "sum-qa"] {
        println!("\n=== {pipeline} (bursty workload, IPA policy) ===");
        println!(
            "{:<10} {:>12} {:>10} {:>12}",
            "predictor", "violations", "cost", "pred-SMAPE"
        );
        for kind in [PredKind::Lstm, PredKind::Reactive, PredKind::Oracle] {
            let m = run_cell(
                pipeline,
                Policy::Ipa(AccuracyMetric::Pas),
                Pattern::Bursty,
                kind,
                &mut opts,
            );
            println!(
                "{:<10} {:>11.2}% {:>10.1} {:>11.1}%",
                kind.name(),
                m.violation_rate() * 100.0,
                m.avg_cost(),
                m.prediction_smape()
            );
        }
    }
    println!(
        "\nExpected shape (paper Fig. 16): the proactive LSTM cuts SLA \
         violations vs the reactive baseline at similar cost; the oracle \
         bounds what better predictors could still gain."
    );
}
