//! Adaptability sweep (paper Fig. 14): vary the α/β objective weights
//! and show IPA navigating the accuracy↔cost frontier on every
//! pipeline.
//!
//! Run: `cargo run --release --example adaptability_sweep [-- --seconds 300]`

use ipa::coordinator::adapter::Policy;
use ipa::models::accuracy::AccuracyMetric;
use ipa::models::pipelines;
use ipa::reports::figures::{run_cell_spec, EvalOpts, PredKind};
use ipa::util::cli::Args;
use ipa::workload::tracegen::Pattern;

fn main() {
    let args = Args::from_env();
    let seconds = args.get_usize("seconds", 300);
    let mut opts = EvalOpts::new(seconds, None);

    // (label, α multiplier, β multiplier) — left to right = cost-first
    // to accuracy-first.
    let points: [(&str, f64, f64); 5] = [
        ("β×20 (cost-first)", 0.2, 20.0),
        ("β×4", 0.5, 4.0),
        ("paper weights", 1.0, 1.0),
        ("α×4", 4.0, 0.5),
        ("α×20 (acc-first)", 20.0, 0.05),
    ];

    for spec0 in pipelines::all() {
        println!("\n=== {} (fluctuating workload) ===", spec0.name);
        println!("{:<20} {:>10} {:>8}", "preference", "cost", "PAS");
        let mut prev_cost = -1.0;
        for (label, am, bm) in points {
            let mut spec = spec0.clone();
            spec.weights.alpha *= am;
            spec.weights.beta *= bm;
            let m = run_cell_spec(
                &spec,
                Policy::Ipa(AccuracyMetric::Pas),
                Pattern::Fluctuating,
                PredKind::Reactive,
                &mut opts,
            );
            let marker = if m.avg_cost() + 1e-9 >= prev_cost { " " } else { "!" };
            prev_cost = m.avg_cost();
            println!(
                "{:<20} {:>10.1} {:>8.2} {marker}",
                label,
                m.avg_cost(),
                m.avg_pas()
            );
        }
    }
    println!(
        "\nEach pipeline traces a monotone frontier: paying more cores buys \
         more accurate variant combinations (paper Fig. 14)."
    );
}
