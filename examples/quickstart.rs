//! Quickstart: one IPA adaptation decision, end to end.
//!
//! Builds the video pipeline's profiles, asks the IP optimizer for the
//! optimal (variant, batch, replicas) per stage at a given load, and
//! shows how the decision shifts as load rises — the Fig. 5 story.
//!
//! Run: `cargo run --release --example quickstart`

use ipa::models::pipelines;
use ipa::optimizer::ip::{solve, Problem};
use ipa::profiler::analytic::pipeline_profiles;

fn main() {
    let spec = pipelines::by_name("video").expect("video pipeline");
    let profiles = pipeline_profiles(&spec);
    println!(
        "pipeline: {} | stages: {:?} | SLA: {:.2}s | weights α={} β={} δ={}",
        spec.name,
        spec.stages.iter().map(|s| s.name()).collect::<Vec<_>>(),
        spec.sla_e2e(),
        spec.weights.alpha,
        spec.weights.beta,
        spec.weights.delta
    );

    for lambda in [2.0, 10.0, 20.0, 35.0] {
        let p = Problem::new(&spec, &profiles, lambda);
        match solve(&p) {
            Some((cfg, stats)) => {
                println!(
                    "\nλ = {lambda:>4} RPS → PAS {:.2}, cost {:.0} cores, \
                     e2e latency {:.2}s (solved in {} nodes)",
                    cfg.pas, cfg.cost, cfg.latency_e2e, stats.nodes
                );
                for (i, sc) in cfg.stages.iter().enumerate() {
                    println!(
                        "  stage {i}: {:<22} batch {:>2}  x{:>2} replicas  \
                         ({:.0} cores, acc {:.2})",
                        sc.variant_key, sc.batch, sc.replicas, sc.cost, sc.accuracy
                    );
                }
            }
            None => println!("\nλ = {lambda:>4} RPS → infeasible"),
        }
    }
    println!(
        "\nLow load buys accurate variants; high load trades accuracy for \
         throughput — IPA's core adaptation (paper Fig. 5)."
    );
}
