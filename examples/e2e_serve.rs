//! END-TO-END VALIDATION DRIVER (the serving-paper e2e required by
//! DESIGN.md): load real AOT-compiled models and serve batched requests
//! through the full three-layer stack —
//!
//!   L1 Pallas matmul kernels → L2 JAX variant graphs (AOT, HLO text) →
//!   L3 Rust: PJRT executor pool + central batching queues +
//!   thread-per-replica serving + LSTM predictor (also via PJRT) +
//!   the IP optimizer reconfiguring variants/batches/replicas live.
//!
//! Python is not running anywhere in this process.  The run reports
//! throughput, latency percentiles, SLA attainment, and the adapter's
//! live reconfiguration log; EXPERIMENTS.md records a reference run.
//!
//! Requires artifacts (`make artifacts`) for the real PJRT path; with
//! `--synthetic` (or when artifacts are absent) the same threaded
//! engine runs on the analytic profiles through a profile-sleeping
//! executor — the wall-clock driver over the shared cluster core, no
//! artifacts needed.
//!
//! Run: `cargo run --release --example e2e_serve [-- --seconds 60 --time-scale 0.5 --synthetic]`

use std::sync::Arc;

use ipa::coordinator::adapter::Policy;
use ipa::models::accuracy::AccuracyMetric;
use ipa::models::pipelines;
use ipa::predictor::ReactivePredictor;
use ipa::serving::engine::{serve, serve_with, ServeConfig, SyntheticExecutor};
use ipa::serving::loadgen::LoadGenConfig;
use ipa::util::cli::Args;
use ipa::workload::trace::Trace;
use ipa::workload::tracegen::Pattern;

fn main() {
    let args = Args::from_env();
    let pipeline = args.get_or("pipeline", "video").to_string();
    let seconds = args.get_usize("seconds", 60);
    let time_scale = args.get_f64("time-scale", 0.5);
    let pattern =
        Pattern::from_name(args.get_or("pattern", "fluctuating")).unwrap_or(Pattern::Fluctuating);

    let Some(spec) = pipelines::by_name(&pipeline) else {
        eprintln!("unknown pipeline {pipeline}");
        std::process::exit(2);
    };
    let have_artifacts = std::path::Path::new("artifacts/manifest.json").exists();
    let synthetic = args.flag("synthetic") || !have_artifacts;
    if synthetic && !args.flag("synthetic") {
        eprintln!("artifacts/ missing — falling back to the synthetic executor");
    }

    let cfg = ServeConfig {
        artifact_dir: "artifacts".into(),
        executors: 2,
        max_workers: 6,
        interval: 4.0,
        apply_delay: 0.5,
        use_lstm: !synthetic,
        profile_batches: vec![1, 4, 16, 64],
        profile_reps: 3,
        sla_floor: if synthetic { 0.0 } else { args.get_f64("sla-floor", 0.25) },
        legacy_lock: false,
    };
    let lg = LoadGenConfig { time_scale, seed: args.get_u64("seed", 11) };
    let trace = Trace::synthetic(pattern, seconds);

    println!(
        "e2e live serve ({}): pipeline={pipeline} workload={} trace={seconds}s \
         at {time_scale}x wall compression",
        if synthetic { "synthetic executor" } else { "real PJRT artifacts" },
        pattern.name()
    );
    // Frozen analytic profiles, uniformly scaled into the wall domain
    // so λ/latency/SLA stay consistent under compression.
    let run_synthetic = |cfg: &ServeConfig| {
        let mut cfg = cfg.clone();
        cfg.sla_floor = 0.0;
        let prof = ipa::profiler::analytic::pipeline_profiles(&spec).scaled(time_scale);
        let executor = Arc::new(SyntheticExecutor::from_profiles(&prof, 1.0));
        serve_with(
            &spec,
            prof,
            Policy::Ipa(AccuracyMetric::Pas),
            &cfg,
            lg,
            &trace,
            executor,
            Box::new(ReactivePredictor::default()),
        )
        .expect("synthetic serve")
    };

    let t0 = std::time::Instant::now();
    let rep = if synthetic {
        run_synthetic(&cfg)
    } else {
        println!("startup: compiling artifacts + measuring live profiles ...");
        match serve(&spec, Policy::Ipa(AccuracyMetric::Pas), &cfg, lg, &trace) {
            Ok(rep) => rep,
            Err(e) => {
                // e.g. built with the offline xla stub — the threaded
                // engine still demonstrates end to end synthetically
                eprintln!("real PJRT serve failed ({e:#}); falling back to synthetic executor");
                run_synthetic(&cfg)
            }
        }
    };
    let wall = t0.elapsed().as_secs_f64();

    let m = &rep.metrics;
    let s = m.latency_summary();
    println!("\n--- measured live profiles (ms, batch-1 under 1 replica) ---");
    for st in &rep.profiles.stages {
        for vp in &st.variants {
            println!(
                "  {:<26} l(1)={:>7.2}ms l(64)={:>8.2}ms tput(64)={:>7.1}/s",
                vp.variant.key(),
                vp.latency.latency(1) * 1e3,
                vp.latency.latency(64) * 1e3,
                vp.latency.throughput(64)
            );
        }
    }
    println!("\n--- run results ---");
    println!("live SLA (Swayam rule over measured profiles): {:.1} ms", rep.sla * 1e3);
    println!(
        "requests {} | completed {} | dropped {:.2}% | SLA attainment {:.1}%",
        m.requests.len(),
        m.latencies().len(),
        m.drop_rate() * 100.0,
        m.sla_attainment() * 100.0
    );
    println!(
        "latency p50 {:.1} ms | p95 {:.1} ms | p99 {:.1} ms | max {:.1} ms",
        s.p50 * 1e3,
        s.p95 * 1e3,
        s.p99 * 1e3,
        s.max * 1e3
    );
    println!(
        "throughput {:.1} req/s over {:.1}s wall",
        m.latencies().len() as f64 / wall.max(1e-9),
        wall
    );
    println!("\n--- adapter reconfiguration log ---");
    for iv in &m.intervals {
        println!(
            "  t={:>6.1}s λ_obs={:>6.1} λ_lstm={:>6.1} pas={:>6.2} cost={:>5.1} [{}]",
            iv.t,
            iv.lambda_observed,
            iv.lambda_predicted,
            iv.pas,
            iv.cost,
            iv.variants.join(", ")
        );
    }
}
