//! Video-monitoring pipeline (paper Fig. 6a / Fig. 8): simulate the
//! four workload archetypes under IPA and the three baselines, printing
//! the temporal PAS/cost series and the averaged comparison — the
//! paper's headline experiment in miniature.
//!
//! Run: `cargo run --release --example video_pipeline [-- --seconds 600]`

use ipa::baselines::rim::RimParams;
use ipa::coordinator::adapter::Policy;
use ipa::models::accuracy::AccuracyMetric;
use ipa::reports::figures::{run_cell, EvalOpts, PredKind};
use ipa::util::cli::Args;
use ipa::workload::tracegen::Pattern;

fn main() {
    let args = Args::from_env();
    let seconds = args.get_usize("seconds", 420);
    let artifacts = if std::path::Path::new("artifacts/manifest.json").exists() {
        Some("artifacts".to_string())
    } else {
        None
    };
    let mut opts = EvalOpts::new(seconds, artifacts);

    let systems: [(&str, Policy); 4] = [
        ("IPA", Policy::Ipa(AccuracyMetric::Pas)),
        ("FA2-low", Policy::Fa2Low),
        ("FA2-high", Policy::Fa2High),
        ("RIM", Policy::Rim(RimParams { fixed_replicas: 8 })),
    ];

    for pattern in Pattern::EVAL {
        println!("\n=== workload: {} ===", pattern.name());
        for (name, policy) in systems {
            let m = run_cell("video", policy, pattern, PredKind::Lstm, &mut opts);
            println!(
                "{:<9} PAS {:>6.2} | cost {:>6.1} cores | SLA {:>5.1}% | \
                 drops {:>5.2}% | p99 {:>5.2}s",
                name,
                m.avg_pas(),
                m.avg_cost(),
                m.sla_attainment() * 100.0,
                m.drop_rate() * 100.0,
                m.latency_summary().p99
            );
            if name == "IPA" && pattern == Pattern::Bursty {
                println!("  temporal (every 60s):");
                for iv in m.intervals.iter().step_by(6) {
                    println!(
                        "    t={:>4.0}s λ̂={:>5.1} pas={:>6.2} cost={:>5.1} [{}]",
                        iv.t,
                        iv.lambda_predicted,
                        iv.pas,
                        iv.cost,
                        iv.variants.join(", ")
                    );
                }
            }
        }
    }
    println!(
        "\nExpected shape (paper §5.2): FA2-low/high bracket PAS; IPA sits \
         between at FA2-low-like cost; RIM matches accuracy but at a high \
         pinned cost."
    );
}
