//! FLEET E2E: N pipelines over ONE shared replica pool, through BOTH
//! clocks.
//!
//! The demo fleet (bursty video feed + fluctuating audio-sentiment +
//! steady NLP, antiphase-correlated so one member surges while another
//! decays) runs end-to-end twice:
//!
//!   1. the fleet DES driver — every member's events interleaved in
//!      one virtual-time queue, the joint cross-pipeline solver
//!      re-splitting the budget each adaptation tick;
//!   2. the live fleet engine — worker threads per (member, stage)
//!      behind one budget-checked core on a compressed wall clock
//!      (synthetic profile-sleeping executors; no artifacts needed).
//!
//! Both run the ELASTIC control plane by default (pass `--static 1` to
//! pin the pool): the autoscaler grows/shrinks the pool against a cost
//! target, the spec's priority classes guard the video feed with
//! mid-interval preemption, and ticks where only one member's λ moved
//! re-solve incrementally.
//!
//! The pool can be HETEROGENEOUS: `--nodes "4x(8c,32g,0a)+2x(16c,64g,1a)"`
//! replaces the fungible slot pool with counted node shapes that
//! replicas bin-pack onto (accel-demanding variants land only on accel
//! nodes; the autoscaler then moves whole nodes).  Members run MIXED
//! SLA classes (the demo fleet's NLP line is `throughput`, the rest
//! `latency_critical`); override per member with
//! `--class nlp-batchline=latency_critical,video-edge=throughput`.
//!
//! Both print the per-pipeline accounting table from `reports::tables`,
//! now including the preempt column, the cost-vector breakdown and the
//! pool size/cost/node lines.
//!
//! Topology flags: `--nodes` terms take `@zone` suffixes
//! (`"2x(8c,32g,0a)@east+2x(8c,32g,0a)@west"`), `--spread member[,..]`
//! flags members whose replicas must survive any single zone loss, and
//! `--migration-delay 0.5` charges every replica a reconfiguration
//! moves between nodes through the apply delay (sticky packing keeps
//! that count low — the migration line in the tables shows it).
//!
//! Both clocks run the SHARDED data plane by default (per-member event
//! wheels in the DES, lock-free per-stage ingress rings in the live
//! engine); pass `--legacy-clock 1` / `--legacy-lock 1` to A/B the
//! pre-sharding single heap / single lock.
//!
//! Flight recorder: `--trace-out spans.jsonl` dumps the DES run's
//! sampled stage-hop spans (1-in-`--sample`, default 64),
//! `--journal-out journal.jsonl` the control-plane decision journal
//! (byte-identical across reruns — CI diffs two runs), and
//! `--metrics-text out.prom` (or `-` for stdout) the Prometheus-style
//! exposition.  `--skip-live 1` stops after the DES clock.
//!
//! Epoch-parallel DES: `--sim-threads N` pins the worker count the
//! fleet DES fans members across between control-plane barriers
//! (mirrors `IPA_SIM_THREADS`; 0 = auto, 1 = sequential — results are
//! byte-identical at any count, which CI verifies by `cmp`ing the
//! journals of a 1-thread and a default run).  `--des-only 1` runs
//! just the DES clock (implies `--skip-live 1`) so CI and scripted
//! sweeps never touch the wall-clock engine.
//!
//! Fleet front door: `--route-policy least_loaded` (or `round_robin`,
//! `zone_local`, `sticky`; default `none` = the classic pre-addressed
//! ingress) sends every arrival through the per-member router over the
//! packing's replica→node→zone placement, and `--admission 1` turns on
//! degrade-then-shed admission control (brownout before the §4.5 drop
//! ledger).  `IPA_ROUTE_*` env knobs supply thresholds; both clocks
//! print the `router_table` accounting when the door is on.  The whole
//! example drives one `fleet::run::FleetRun` builder on both clocks.
//!
//! Scale runs: `--members 50` swaps in the deterministic synthetic
//! 50-member fleet on a heterogeneous pool scaled by `--nodes-scale K`
//! (a 50×-scaled mix ≈ a 500-node pool) — the harness behind the
//! `fleet_scale` bench grid, runnable standalone.
//!
//! Run: `cargo run --release --example fleet_serve
//!       [-- --seconds 240 --budget 24 --time-scale 0.05 --fleet spec.json
//!           --members 50 --nodes-scale 5
//!           --cost-target 30 --static 0
//!           --nodes "2x(8c,32g,0a)@east+2x(8c,32g,0a)@west"
//!           --class nlp-batchline=throughput
//!           --spread video-edge --migration-delay 0.5
//!           --legacy-lock 0 --legacy-clock 0
//!           --route-policy least_loaded --admission 1
//!           --sim-threads 0 --des-only 0
//!           --trace-out spans.jsonl --journal-out journal.jsonl
//!           --metrics-text - --sample 64 --skip-live 0]`

use std::sync::Arc;

use ipa::fleet::autoscaler::AutoscalerConfig;
use ipa::fleet::nodes::NodeInventory;
use ipa::fleet::router::{RoutePolicy, RouterConfig};
use ipa::fleet::run::FleetRun;
use ipa::fleet::solver::{solve_fleet, solve_fleet_placed, FleetTuning, PreemptionConfig};
use ipa::fleet::spec::{FleetSpec, SlaClass};
use ipa::optimizer::ip::Problem;
use ipa::profiler::analytic::pipeline_profiles;
use ipa::profiler::profile::PipelineProfiles;
use ipa::reports::tables;
use ipa::reports::timeline;
use ipa::serving::engine::ServeConfig;
use ipa::serving::loadgen::LoadGenConfig;
use ipa::simulator::sim::SimConfig;
use ipa::telemetry::{export, spans_to_jsonl, Telemetry, TelemetryConfig};
use ipa::util::cli::Args;
use ipa::util::stats::mean;

fn main() {
    let args = Args::from_env();
    let seconds = args.get_usize("seconds", 240);
    let time_scale = args.get_f64("time-scale", 0.05);
    let static_pool = args.get_usize("static", 0) != 0;
    let legacy_lock = args.get_usize("legacy-lock", 0) != 0;
    let legacy_clock = args.get_usize("legacy-clock", 0) != 0;
    // Flight-recorder flags: any output path turns the telemetry plane
    // on for the DES run (spans sampled 1-in---sample; journal always).
    let trace_out = args.get("trace-out");
    let journal_out = args.get("journal-out");
    let metrics_text = args.get("metrics-text");
    let sample = args.get_u64("sample", 64).max(1);
    // Epoch-parallel DES worker count (0 = auto via IPA_SIM_THREADS /
    // cores, 1 = sequential A/B anchor; results identical either way).
    let sim_threads = args.get_usize("sim-threads", 0);
    let des_only = args.get_usize("des-only", 0) != 0;
    let skip_live = des_only || args.get_usize("skip-live", 0) != 0;
    let traced = trace_out.is_some() || journal_out.is_some() || metrics_text.is_some();

    // Fleet front door: `--route-policy round_robin|least_loaded|
    // zone_local|sticky` sends every arrival through the per-member
    // router (default `none` = the classic pre-addressed ingress,
    // byte-identical to before the router existed), and `--admission 1`
    // turns on degrade-then-shed admission control.  `IPA_ROUTE_*`
    // environment knobs supply the remaining thresholds; the CLI flags
    // override the env.
    let route_policy = args.get("route-policy").unwrap_or("none");
    let router_cfg: Option<RouterConfig> = if route_policy == "none"
        && args.get("admission").is_none()
    {
        None
    } else {
        let mut rc = RouterConfig::from_env();
        if route_policy != "none" {
            match RoutePolicy::from_name(route_policy) {
                Some(p) => rc.policy = p,
                None => {
                    eprintln!(
                        "bad --route-policy {route_policy:?}: expected \
                         round_robin|least_loaded|zone_local|sticky|none"
                    );
                    std::process::exit(2);
                }
            }
        }
        if args.get("admission").is_some() {
            rc.admission = args.get_usize("admission", 0) != 0;
        }
        Some(rc)
    };

    // --members N swaps the demo fleet for the deterministic synthetic
    // scale fleet (ignored when --fleet names an explicit spec file).
    let members_n = args.get_usize("members", 0);
    let mut fleet = match args.get("fleet") {
        Some(path) => {
            let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
                eprintln!("cannot read fleet spec {path}: {e}");
                std::process::exit(2);
            });
            FleetSpec::parse(&text).unwrap_or_else(|e| {
                eprintln!("bad fleet spec {path}: {e}");
                std::process::exit(2);
            })
        }
        None if members_n > 0 => FleetSpec::synthetic(members_n),
        None => FleetSpec::demo3(),
    };
    fleet.replica_budget = args.get_usize("budget", fleet.replica_budget as usize) as u32;
    // A synthetic fleet defaults onto a heterogeneous pool scaled K×
    // from a small base mix (--nodes-scale; defaults to one 10-node
    // base block per 10 members so the pool always covers the fleet's
    // stage floor); an explicit --nodes below still wins.
    if members_n > 0 && args.get("nodes").is_none() {
        let k = args.get_usize("nodes-scale", members_n.div_ceil(10)).max(1) as u32;
        let base = NodeInventory::parse("8x(8c,32g,0a)+2x(16c,64g,1a)").expect("static base pool");
        fleet.nodes = Some(base.scaled(k));
    }
    // --nodes overrides the spec's inventory (if any): counted shapes
    // replicas bin-pack onto instead of the fungible slot pool.
    if let Some(spec) = args.get("nodes") {
        match NodeInventory::parse(spec) {
            Ok(inv) => fleet.nodes = Some(inv),
            Err(e) => {
                eprintln!("bad --nodes: {e}");
                std::process::exit(2);
            }
        }
    }
    // --class name=class[,name=class..] overrides member SLA classes.
    if let Some(spec) = args.get("class") {
        for pair in spec.split(',') {
            let Some((name, class)) = pair.split_once('=') else {
                eprintln!("bad --class entry {pair:?}: expected member=class");
                std::process::exit(2);
            };
            let Some(class) = SlaClass::from_name(class.trim()) else {
                eprintln!("bad --class entry {pair:?}: unknown class");
                std::process::exit(2);
            };
            match fleet.members.iter_mut().find(|m| m.name == name.trim()) {
                Some(m) => m.sla_class = class,
                None => {
                    eprintln!("--class names unknown member {name:?}");
                    std::process::exit(2);
                }
            }
        }
    }
    // --spread name[,name..] flags members for zone redundancy.
    if let Some(spec) = args.get("spread") {
        for name in spec.split(',').map(str::trim).filter(|n| !n.is_empty()) {
            match fleet.members.iter_mut().find(|m| m.name == name) {
                Some(m) => m.spread = true,
                None => {
                    eprintln!("--spread names unknown member {name:?}");
                    std::process::exit(2);
                }
            }
        }
    }
    let migration_delay = args.get_f64("migration-delay", 0.0);
    if let Err(e) = fleet.validate() {
        eprintln!("invalid fleet: {e}");
        std::process::exit(2);
    }

    let specs = fleet.specs().expect("validated above");
    let profs: Vec<PipelineProfiles> = specs.iter().map(pipeline_profiles).collect();
    let traces = fleet.traces(seconds);
    let names: Vec<String> = fleet.members.iter().map(|m| m.name.clone()).collect();
    let budget = fleet.nodes.as_ref().map_or(fleet.replica_budget, |i| i.replica_cap());

    match &fleet.nodes {
        Some(inv) => println!(
            "fleet '{}': {} pipelines over {} nodes [{inv}] (≤{budget} replicas), \
             {seconds}s traces",
            fleet.name,
            fleet.members.len(),
            inv.n_nodes(),
        ),
        None => println!(
            "fleet '{}': {} pipelines over one {}-replica pool, {seconds}s traces",
            fleet.name,
            fleet.members.len(),
            budget
        ),
    }
    for (m, t) in fleet.members.iter().zip(&traces) {
        println!(
            "  {:<16} {:<10} pattern={:<12} class={:<16} peak λ={:.1} rps",
            m.name,
            m.pipeline,
            m.pattern.name(),
            m.sla_class.name(),
            t.peak()
        );
    }

    // How the joint solver splits the pool at each member's mean load
    // (a static preview; the drivers re-split every adaptation tick and
    // the tables below report the allocation each run ended on).
    let mean_lambdas: Vec<f64> = traces.iter().map(|t| mean(&t.rates)).collect();
    let problems: Vec<Problem> = specs
        .iter()
        .zip(&profs)
        .zip(&mean_lambdas)
        .map(|((s, p), &l)| Problem::new(s, p, l))
        .collect();
    match &fleet.nodes {
        Some(inv) => {
            let alloc =
                solve_fleet_placed(&problems, inv, &fleet.priorities(), &fleet.spreads(), None)
                    .expect("inventory hosts the stage floor");
            let packing = alloc.packing.as_ref().expect("packed solve carries a packing");
            println!(
                "\njoint packed solve @ mean λ: {} replicas on {} of {} nodes, \
                 total objective {:.2}",
                alloc.replicas_used,
                packing.nodes_used(),
                inv.n_nodes(),
                alloc.total_objective
            );
        }
        None => {
            let alloc = solve_fleet(&problems, budget).expect("budget covers the stage floor");
            println!(
                "\njoint solve @ mean λ: {} of {budget} replicas granted, \
                 total objective {:.2}",
                alloc.replicas_used, alloc.total_objective
            );
        }
    }

    // Elastic control plane: priorities + SLA classes + nodes from the
    // spec, a pool autoscaler capped at ~25% above the starting budget,
    // the preemption fast path, and incremental re-solves for quiet
    // ticks.  --static pins the pool but keeps the node/class policy.
    let cost_target = args.get_f64("cost-target", budget as f64 * 1.25);
    let tuning = if static_pool {
        FleetTuning {
            nodes: fleet.nodes.clone(),
            sla_classes: Some(fleet.classes()),
            spread: Some(fleet.spreads()),
            migration_delay,
            ..Default::default()
        }
    } else {
        FleetTuning {
            priorities: Some(fleet.priorities()),
            autoscaler: Some(AutoscalerConfig {
                cost_per_replica: 1.0,
                cost_target,
                min_pool: 0,
                max_step_up: 4,
                max_step_down: 2,
                headroom: 1.25,
                shrink_after: 3,
            }),
            preemption: Some(PreemptionConfig::default()),
            resolve_threshold: 0.15,
            nodes: fleet.nodes.clone(),
            sla_classes: Some(fleet.classes()),
            spread: Some(fleet.spreads()),
            migration_delay,
        }
    };
    println!(
        "control plane: {} (priorities {:?}, classes {:?}, pool cap {}, \
         spread {:?}, migration delay {migration_delay}s/replica)",
        if static_pool { "static pool" } else { "elastic" },
        fleet.priorities(),
        fleet.classes().iter().map(|c| c.name()).collect::<Vec<_>>(),
        if static_pool { budget as f64 } else { cost_target },
        fleet.spreads(),
    );

    // One FleetRun is the front door to BOTH clocks: it resolves the
    // spec (specs/profiles/SLAs/traces/budget/predictors) once, and the
    // router + telemetry planes attach to each clock identically.
    let mut run = FleetRun::new(fleet.clone(), tuning).seconds(seconds).cadence(10.0, 8.0);
    if let Some(rc) = router_cfg.clone() {
        println!(
            "front door: policy {} | admission {}",
            rc.policy.name(),
            if rc.admission { "degrade-then-shed" } else { "off" },
        );
        run = run.router(rc);
    }
    let tel = Arc::new(if traced {
        Telemetry::new(
            TelemetryConfig { sample_one_in: sample, ..Default::default() },
            specs.len(),
        )
    } else {
        Telemetry::off()
    });
    if traced {
        run = run.telemetry(Arc::clone(&tel));
    }

    // ---- clock 1: the fleet DES driver -------------------------------
    println!("\n=== fleet DES driver (virtual time) ===");
    let t0 = std::time::Instant::now();
    let des = run
        .sim(SimConfig { seed: 5, legacy_clock, sim_threads, ..Default::default() })
        .expect("valid fleet");
    let fm = &des.metrics;
    println!(
        "simulated {} requests in {:.2}s wall | pool peak in use {} / {} (final size; \
         started at {budget}) | {} incremental / {} full solves",
        fm.total_requests(),
        t0.elapsed().as_secs_f64(),
        fm.peak_in_use,
        fm.budget,
        des.adapter.incremental_solves,
        des.adapter.full_solves,
    );
    println!();
    // `repl` column = the allocation the run actually ended on
    print!("{}", tables::fleet_table(&names, &fm.members, &fm.final_replicas, &fm.pool));
    if router_cfg.is_some() {
        print!("{}", tables::router_table(&names, &fm.router));
    }

    // ---- flight recorder output --------------------------------------
    if traced {
        let spans = tel.take_spans();
        let journal = tel.journal();
        let write = |path: &str, what: &str, text: String| {
            std::fs::write(path, text).unwrap_or_else(|e| {
                eprintln!("cannot write {what} to {path}: {e}");
                std::process::exit(2);
            });
        };
        println!(
            "\nflight recorder: {} spans (1-in-{sample} sampling, {} dropped), \
             {} journal entries",
            spans.len(),
            tel.dropped_spans(),
            journal.len(),
        );
        if let Some(path) = trace_out {
            write(path, "span trace", spans_to_jsonl(&spans));
            println!("  spans   -> {path}");
        }
        if let Some(path) = journal_out {
            write(path, "decision journal", journal.to_jsonl());
            println!("  journal -> {path}");
        }
        if let Some(path) = metrics_text {
            let text = export::prometheus_text(&spans, &journal);
            if path == "-" {
                print!("{text}");
            } else {
                write(path, "metrics exposition", text);
                println!("  metrics -> {path}");
            }
        }
        let wf = timeline::waterfalls(&spans, 2);
        if !wf.is_empty() {
            println!("\nsample span waterfalls (first 2 traces):\n{wf}");
        }
    }

    if skip_live {
        println!(
            "\nfleet e2e complete: DES clock only ({})",
            if des_only { "--des-only" } else { "--skip-live" }
        );
        return;
    }

    // ---- clock 2: the live fleet engine ------------------------------
    println!(
        "\n=== live fleet engine (wall clock, {time_scale}x compression, synthetic executors) ==="
    );
    let cfg = ServeConfig {
        artifact_dir: String::new(),
        executors: 0,
        max_workers: 6,
        interval: 4.0,
        apply_delay: 0.5,
        use_lstm: false,
        profile_batches: vec![],
        profile_reps: 0,
        sla_floor: 0.0,
        legacy_lock,
    };
    let t0 = std::time::Instant::now();
    // The same FleetRun finishes on the wall clock: time-scaled
    // profiles + profile-sleeping synthetic executors, and the same
    // router/telemetry planes the DES run drove.
    let rep = run
        .serve(&cfg, LoadGenConfig { time_scale, seed: 5 })
        .expect("live fleet serve");
    let live_metrics: Vec<_> = rep.members.iter().map(|r| r.metrics.clone()).collect();
    println!(
        "served {} requests in {:.2}s wall | pool peak in use {} / {} (final size; \
         started at {budget})\n",
        live_metrics.iter().map(|m| m.requests.len()).sum::<usize>(),
        t0.elapsed().as_secs_f64(),
        rep.peak_in_use,
        rep.budget,
    );
    print!("{}", tables::fleet_table(&names, &live_metrics, &rep.final_replicas, &rep.pool));
    if router_cfg.is_some() {
        print!("{}", tables::router_table(&names, &rep.router));
    }

    println!("\nfleet e2e complete: both clocks drove the same shared-budget machinery");
}
