//! CI bench-trajectory checker: diff two `BENCH_cluster.json` files
//! section by section and fail (exit 1) when the current run regressed
//! more than the threshold against the baseline.
//!
//! Time sections (`solver`, `fleet_solver`, `fleet_autoscaler`,
//! `fleet_binpack`, `fleet_topology`, `fleet_scale`, `sim_parallel`)
//! regress when `mean_s` grows past
//! `baseline × (1 + threshold)`; throughput sections (`simulator`,
//! `fleet_sim`, `fleet_router`, `data_plane`, `telemetry`) regress when
//! `items_per_s` falls below `baseline × (1 − threshold)`.  Rows or sections absent from the
//! baseline are reported as new and never fail; a missing baseline
//! FILE passes outright (the first run seeds the cache).
//!
//! Usage: `bench_diff <baseline.json> <current.json> [threshold]`
//! (threshold defaults to 0.25 — the 25% gate from the CI contract).
//! Exit codes: 0 ok / nothing to compare, 1 regression, 2 bad input.

use ipa::util::json::Json;

/// Sections judged on per-iteration wall time (`mean_s`, lower=better).
const TIME_SECTIONS: &[&str] = &[
    "solver",
    "fleet_solver",
    "fleet_autoscaler",
    "fleet_binpack",
    "fleet_topology",
    "fleet_scale",
    "sim_parallel",
];
/// Sections judged on `items_per_s` (higher=better).
const THROUGHPUT_SECTIONS: &[&str] =
    &["simulator", "fleet_sim", "fleet_router", "data_plane", "telemetry"];

struct Row {
    name: String,
    value: f64,
}

fn rows_of(doc: &Json, section: &str, field: &str) -> Vec<Row> {
    let Some(arr) = doc.get(section).and_then(Json::as_arr) else {
        return Vec::new();
    };
    arr.iter()
        .filter_map(|r| {
            let name = r.get("name").and_then(Json::as_str)?.to_string();
            let value = r.get(field).and_then(Json::as_f64)?;
            Some(Row { name, value })
        })
        .collect()
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    if args.len() < 3 {
        eprintln!("usage: bench_diff <baseline.json> <current.json> [threshold]");
        std::process::exit(2);
    }
    let threshold: f64 = match args.get(3) {
        Some(t) => match t.parse() {
            Ok(v) if (0.0..10.0).contains(&v) => v,
            _ => {
                eprintln!("bench_diff: bad threshold {t:?}");
                std::process::exit(2);
            }
        },
        None => 0.25,
    };

    // No baseline = first run on this branch: nothing to diff, the
    // caller seeds the cache with the current file afterwards.
    let baseline_text = match std::fs::read_to_string(&args[1]) {
        Ok(t) => t,
        Err(_) => {
            println!("bench_diff: no baseline at {} — first run, nothing to compare", args[1]);
            return;
        }
    };
    let current_text = match std::fs::read_to_string(&args[2]) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("bench_diff: cannot read current results {}: {e}", args[2]);
            std::process::exit(2);
        }
    };
    let parse = |label: &str, text: &str| -> Json {
        match Json::parse(text) {
            Ok(j) => j,
            Err(e) => {
                eprintln!("bench_diff: {label} is not valid JSON: {e}");
                std::process::exit(2);
            }
        }
    };
    let baseline = parse("baseline", &baseline_text);
    let current = parse("current", &current_text);

    let mut failures: Vec<String> = Vec::new();
    let mut compared = 0usize;

    // (section, field, true when lower is better)
    let plans = TIME_SECTIONS
        .iter()
        .map(|&s| (s, "mean_s", true))
        .chain(THROUGHPUT_SECTIONS.iter().map(|&s| (s, "items_per_s", false)));
    for (section, field, lower_is_better) in plans {
        let base = rows_of(&baseline, section, field);
        let cur = rows_of(&current, section, field);
        if cur.is_empty() {
            println!("[{section}] no rows in the current run");
            continue;
        }
        if base.is_empty() {
            println!("[{section}] new section (no baseline rows) — skipped");
            continue;
        }
        println!("[{section}] ({field}, {} rows)", cur.len());
        for c in &cur {
            let Some(b) = base.iter().find(|b| b.name == c.name) else {
                println!("  {:<48} new row — skipped", c.name);
                continue;
            };
            if b.value <= 0.0 {
                println!("  {:<48} baseline 0 — skipped", c.name);
                continue;
            }
            compared += 1;
            let change = c.value / b.value - 1.0;
            let regressed = if lower_is_better {
                change > threshold
            } else {
                change < -threshold
            };
            println!(
                "  {:<48} {:>12.6} -> {:>12.6}  ({:+.1}%){}",
                c.name,
                b.value,
                c.value,
                change * 100.0,
                if regressed { "  REGRESSION" } else { "" }
            );
            if regressed {
                failures.push(format!(
                    "{section}/{}: {field} {:.6} -> {:.6} ({:+.1}%, limit ±{:.0}%)",
                    c.name,
                    b.value,
                    c.value,
                    change * 100.0,
                    threshold * 100.0
                ));
            }
        }
    }

    if failures.is_empty() {
        println!(
            "bench_diff: {compared} rows compared, none regressed past {:.0}%",
            threshold * 100.0
        );
    } else {
        eprintln!("bench_diff: {} regression(s) past {:.0}%:", failures.len(), threshold * 100.0);
        for f in &failures {
            eprintln!("  {f}");
        }
        std::process::exit(1);
    }
}
