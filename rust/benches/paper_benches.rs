//! `cargo bench` entry point (benchkit harness, criterion substitute).
//!
//! Two halves:
//!  1. REPRODUCTION — regenerate every paper table and figure
//!     (Tables 2/3/5/6, Figs 2, 7–18) and print them verbatim, so
//!     `bench_output.txt` carries the full evaluation.
//!  2. MICRO — timed benchmarks of the hot paths: IP solver across the
//!     Fig. 13 grid, simulator event loop, option enumeration, trace
//!     generation, quadratic fits, and (when artifacts are present)
//!     real PJRT execution latency per variant/batch.
//!
//! Trace length via IPA_BENCH_SECONDS (default 420).

use ipa::benchkit::{print_section, Bencher};
use ipa::coordinator::adapter::{Adapter, AdapterConfig, Policy};
use ipa::models::accuracy::AccuracyMetric;
use ipa::models::pipelines;
use ipa::optimizer::ip;
use ipa::predictor::ReactivePredictor;
use ipa::profiler::analytic::pipeline_profiles;
use ipa::reports::{figures, figures::EvalOpts, tables};
use ipa::simulator::sim::{SimConfig, Simulation};
use ipa::workload::trace::Trace;
use ipa::workload::tracegen::{self, Pattern};

fn main() {
    let seconds: usize = std::env::var("IPA_BENCH_SECONDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(420);
    let artifacts = std::path::Path::new("artifacts/manifest.json")
        .exists()
        .then(|| "artifacts".to_string());
    println!(
        "ipa paper bench harness | trace length {seconds}s | artifacts: {}",
        artifacts.as_deref().unwrap_or("absent (LSTM -> reactive)")
    );

    // ---------------- 1. paper reproduction -------------------------
    let mut opts = EvalOpts::new(seconds, artifacts.clone());
    println!("\n################ PAPER REPRODUCTION ################");
    print!("{}", tables::fig2());
    print!("{}", tables::table2());
    print!("{}", tables::table3());
    print!("{}", tables::table5());
    print!("{}", tables::table6());
    print!("{}", figures::fig7(&mut opts));
    for p in ["video", "audio-qa", "audio-sent", "sum-qa", "nlp"] {
        print!("{}", figures::fig_e2e(p, &mut opts));
    }
    print!("{}", figures::fig13());
    print!("{}", figures::fig14(&mut opts));
    print!("{}", figures::fig15(&mut opts));
    print!("{}", figures::fig16(&mut opts));
    print!("{}", figures::fig17(&mut opts));

    // ---------------- 2. micro benchmarks ----------------------------
    println!("\n################ MICRO BENCHMARKS ################");
    let b = Bencher::new(2, 10);

    // IP solver across the Fig. 13 grid (solver decision time — the
    // numbers BENCH_cluster.json carries as the perf baseline).
    let mut rows = Vec::new();
    for (s, m) in [(2usize, 5usize), (5, 5), (10, 10)] {
        let (spec, prof) = figures::synthetic_problem(s, m);
        rows.push(b.run(&format!("ip_solve/{s}stages_x_{m}variants"), || {
            let p = ip::Problem::new(&spec, &prof, 12.0);
            ip::solve(&p)
        }));
    }
    // Paper pipelines at representative load.
    for name in ["video", "nlp"] {
        let spec = pipelines::by_name(name).unwrap();
        let prof = pipeline_profiles(&spec);
        rows.push(b.run(&format!("ip_solve/{name}"), || {
            ip::solve(&ip::Problem::new(&spec, &prof, 20.0))
        }));
    }
    print_section("optimizer (paper budget: <2s at 10x10)", &rows);
    let solver_rows = rows.clone();

    // Ablation: §7 future-work heuristic vs the exact IP (optimality
    // gap + speedup).
    let mut rows = Vec::new();
    for (s, m) in [(5usize, 5usize), (10, 10)] {
        let (spec, prof) = figures::synthetic_problem(s, m);
        let p = ip::Problem::new(&spec, &prof, 12.0);
        let exact = ip::solve(&p).map(|(c, _)| c.objective).unwrap_or(f64::NAN);
        let heur = ipa::optimizer::heuristic::solve(&p)
            .map(|h| h.config.objective)
            .unwrap_or(f64::NAN);
        println!(
            "ablation heuristic/{s}x{m}: exact obj {exact:.3} vs heuristic {heur:.3} \
             (gap {:.2}%)",
            (exact - heur) / exact.abs().max(1e-9) * 100.0
        );
        rows.push(b.run(&format!("heuristic_solve/{s}stages_x_{m}variants"), || {
            ipa::optimizer::heuristic::solve(&p)
        }));
    }
    print_section("heuristic solver (future-work ablation)", &rows);

    // Option enumeration.
    let spec = pipelines::by_name("nlp").unwrap();
    let prof = pipeline_profiles(&spec);
    let rows = vec![b.run("options/enumerate_nlp", || {
        ip::Problem::new(&spec, &prof, 18.0).stage_options()
    })];
    print_section("option enumeration", &rows);

    // Simulator throughput: events/sec on a bursty video run.
    let trace = Trace::synthetic(Pattern::Bursty, 300);
    let n_requests = trace.arrivals(7).len() as f64;
    let mk_sim = || {
        let spec = pipelines::by_name("video").unwrap();
        let prof = pipeline_profiles(&spec);
        Simulation::new(
            Adapter::new(
                spec,
                prof,
                Policy::Ipa(AccuracyMetric::Pas),
                AdapterConfig::default(),
                Box::new(ReactivePredictor::default()),
            ),
            SimConfig::default(),
        )
    };
    let rows = vec![b.run_throughput("simulator/video_bursty_300s", n_requests, || {
        mk_sim().run(&trace)
    })];
    print_section("simulator (items/s = simulated requests/s)", &rows);
    let simulator_rows = rows.clone();

    // Fleet: joint cross-pipeline solver decision time + fleet DES
    // throughput over the 3-member demo fleet.
    use ipa::fleet::router::{RoutePolicy, RouterConfig};
    use ipa::fleet::solver::{solve_fleet, FleetAdapter};
    use ipa::fleet::spec::FleetSpec;
    use ipa::optimizer::ip::Problem;
    use ipa::predictor::Predictor;
    use ipa::simulator::sim::{run_fleet, FleetDesParams};

    let fleet = FleetSpec::demo3();
    let fleet_specs = fleet.specs().unwrap();
    let fleet_profs: Vec<_> = fleet_specs.iter().map(pipeline_profiles).collect();
    let fleet_slas: Vec<f64> = fleet_specs.iter().map(|s| s.sla_e2e()).collect();
    let budget = fleet.replica_budget;

    let mut rows = Vec::new();
    for lambdas in [[6.0, 6.0, 6.0], [25.0, 10.0, 4.0]] {
        let problems: Vec<Problem> = fleet_specs
            .iter()
            .zip(&fleet_profs)
            .zip(lambdas)
            .map(|((s, p), l)| Problem::new(s, p, l))
            .collect();
        rows.push(b.run(
            &format!("fleet_solve/3pipes_b{budget}_l{}", lambdas[0] as u32),
            || solve_fleet(&problems, budget),
        ));
    }
    print_section("fleet solver (joint budget split, 3 pipelines)", &rows);
    let fleet_solver_rows = rows.clone();

    let fleet_seconds = (seconds / 2).max(120);
    let fleet_seed = 7u64; // shared by the throughput denominator and the run
    let fleet_traces = fleet.traces(fleet_seconds);
    let fleet_n_requests: f64 = fleet_traces
        .iter()
        .enumerate()
        .map(|(m, t)| {
            t.arrivals(ipa::workload::tracegen::member_seed(fleet_seed, m)).len() as f64
        })
        .sum();
    let rows = vec![b.run_throughput(
        &format!("fleet_sim/demo3_{fleet_seconds}s"),
        fleet_n_requests,
        || {
            let predictors: Vec<Box<dyn Predictor + Send>> = fleet_specs
                .iter()
                .map(|_| Box::new(ReactivePredictor::default()) as Box<dyn Predictor + Send>)
                .collect();
            let mut adapter = FleetAdapter::new(
                fleet_specs.clone(),
                fleet_profs.clone(),
                AccuracyMetric::Pas,
                budget,
                AdapterConfig::default(),
                predictors,
            )
            .unwrap();
            run_fleet(
                FleetDesParams {
                    profiles: &fleet_profs,
                    slas: &fleet_slas,
                    interval: 10.0,
                    apply_delay: 8.0,
                    sim: SimConfig { seed: fleet_seed, ..Default::default() },
                    system: "fleet-bench",
                    budget,
                    faults: &[],
                    router: None,
                    telemetry: None,
                },
                &mut adapter,
                &fleet_traces,
            )
        },
    )];
    print_section("fleet simulator (items/s = simulated requests/s)", &rows);
    let fleet_sim_rows = rows.clone();

    // Elastic control plane: autoscaler resize decision time (demand
    // estimation + the grow/shrink policy), the preemption fast path
    // (incl. re-priming the solve cache each iteration), and the
    // incremental re-solve of a single moved member.
    use ipa::fleet::autoscaler::AutoscalerConfig;
    use ipa::fleet::solver::{FleetTuning, PreemptionConfig};
    let mk_elastic = |threshold: f64| {
        let predictors: Vec<Box<dyn Predictor + Send>> = fleet_specs
            .iter()
            .map(|_| Box::new(ReactivePredictor::default()) as Box<dyn Predictor + Send>)
            .collect();
        FleetAdapter::new(
            fleet_specs.clone(),
            fleet_profs.clone(),
            AccuracyMetric::Pas,
            budget,
            AdapterConfig::default(),
            predictors,
        )
        .and_then(|a| {
            a.with_tuning(FleetTuning {
                priorities: Some(fleet.priorities()),
                autoscaler: Some(AutoscalerConfig {
                    cost_target: budget as f64 * 1.25,
                    ..Default::default()
                }),
                preemption: Some(PreemptionConfig::default()),
                resolve_threshold: threshold,
                ..Default::default()
            })
        })
        .unwrap()
    };
    let mut rows = Vec::new();
    {
        let mut ad = mk_elastic(0.15);
        let histories: Vec<Vec<f64>> = vec![vec![8.0; 60], vec![6.0; 60], vec![5.0; 60]];
        rows.push(b.run("fleet_autoscaler/resize_decision_3pipes", || {
            ad.resize(0.0, &histories)
        }));
    }
    {
        let mut ad = mk_elastic(0.15);
        rows.push(b.run("fleet_autoscaler/preempt_fast_path_incl_reprime", || {
            ad.decide_for_lambdas(&[4.0, 4.0, 4.0]);
            ad.preempt(0.0, &[30.0, 4.0, 4.0])
        }));
    }
    {
        let mut ad = mk_elastic(0.15);
        ad.decide_for_lambdas(&[6.0, 6.0, 6.0]);
        let mut flip = false;
        rows.push(b.run("fleet_autoscaler/incremental_resolve_1of3", || {
            flip = !flip;
            ad.decide_for_lambdas(&[if flip { 12.0 } else { 6.0 }, 6.0, 6.0])
        }));
        println!(
            "fleet incremental telemetry: {} incremental vs {} full solves",
            ad.incremental_solves, ad.full_solves
        );
    }
    print_section("fleet elastic control plane", &rows);
    let fleet_autoscaler_rows = rows.clone();

    // Multi-resource bin-packing: FFD placement decision time at
    // 10/50/200 replicas on a mixed 2-shape inventory, plus the joint
    // solve overhead of a heterogeneous pool vs the fungible
    // (scalar-equivalent) single shape.
    use ipa::fleet::nodes::{NodeInventory, PackItem};
    use ipa::fleet::solver::solve_fleet_packed;
    use ipa::resources::ResourceVec;
    let mut rows = Vec::new();
    {
        let inv = NodeInventory::parse("40x(8c,32g,0a)+40x(16c,64g,2a)").unwrap();
        for n in [10u32, 50, 200] {
            let items: Vec<PackItem> = (0..n)
                .map(|i| PackItem {
                    member: (i % 3) as usize,
                    stage: (i % 2) as usize,
                    unit: match i % 3 {
                        0 => ResourceVec::new(1.0, 2.0, 0.0),
                        1 => ResourceVec::new(2.0, 4.0, 0.0),
                        _ => ResourceVec::new(8.0, 16.0, 1.0),
                    },
                    replicas: 1,
                })
                .collect();
            rows.push(b.run(&format!("fleet_binpack/pack_{n}_replicas"), || {
                inv.pack(&items).expect("inventory sized for the demand mix")
            }));
        }
    }
    {
        let prios = fleet.priorities();
        let lambdas = [14.0, 7.0, 4.0];
        let problems: Vec<Problem> = fleet_specs
            .iter()
            .zip(&fleet_profs)
            .zip(lambdas)
            .map(|((s, p), l)| Problem::new(s, p, l))
            .collect();
        let single = NodeInventory::fungible(budget);
        rows.push(b.run("fleet_binpack/solve_single_shape_fungible", || {
            solve_fleet_packed(&problems, &single, &prios)
        }));
        let hetero = NodeInventory::parse("4x(4c,16g,0a)+2x(16c,64g,2a)").unwrap();
        rows.push(b.run("fleet_binpack/solve_hetero_2shape", || {
            solve_fleet_packed(&problems, &hetero, &prios)
        }));
    }
    print_section("fleet bin-packing (nodes + packed joint solve)", &rows);
    let fleet_binpack_rows = rows.clone();

    // Topology layer: sticky vs plain pack time on a zoned 3-pool
    // inventory (plus the moves each pays after a demand shift), and
    // the zone-kill emergency repack — the packed joint solve on the
    // survivor inventory, which is what a fault costs on the control
    // plane.
    use ipa::fleet::solver::solve_fleet_placed;
    let mut rows = Vec::new();
    {
        let inv = NodeInventory::parse(
            "30x(8c,32g,0a)@east+30x(8c,32g,0a)@west+40x(16c,64g,2a)@east",
        )
        .unwrap();
        let items: Vec<PackItem> = (0..200u32)
            .map(|i| PackItem {
                member: (i % 3) as usize,
                stage: (i % 2) as usize,
                unit: match i % 3 {
                    0 => ResourceVec::new(1.0, 2.0, 0.0),
                    1 => ResourceVec::new(2.0, 4.0, 0.0),
                    _ => ResourceVec::new(8.0, 16.0, 1.0),
                },
                replicas: 1,
            })
            .collect();
        let prev = inv.pack(&items).expect("inventory sized for the demand mix");
        // a demand shift: one member grows, another shrinks
        let mut shifted = items.clone();
        for it in shifted.iter_mut().take(30) {
            it.replicas = if it.member == 0 { 2 } else { it.replicas };
        }
        for it in shifted.iter_mut().rev().take(30) {
            it.replicas = if it.member == 1 { 0 } else { it.replicas };
        }
        rows.push(b.run("fleet_topology/pack_plain_200", || inv.pack(&shifted)));
        rows.push(b.run("fleet_topology/pack_sticky_200", || {
            inv.pack_sticky(&shifted, Some(&prev), &[])
        }));
        let sticky_moves = inv
            .pack_sticky(&shifted, Some(&prev), &[])
            .map_or(0, |p| p.moved_from(&prev).len());
        let plain_moves = inv.pack(&shifted).map_or(0, |p| p.moved_from(&prev).len());
        println!(
            "fleet topology: sticky reconfig moves {sticky_moves} vs plain FFD {plain_moves}"
        );
    }
    {
        // zone-kill repack latency: the east zone (with the accel
        // nodes) dies, the joint solve re-plans on the west survivors
        let mut survivor = NodeInventory::parse(
            "4x(4c,16g,0a)@east+4x(4c,16g,0a)@west+2x(16c,64g,2a)@east",
        )
        .unwrap();
        survivor.drain_zone("east");
        let prios = fleet.priorities();
        let lambdas = [8.0, 5.0, 3.0];
        let problems: Vec<Problem> = fleet_specs
            .iter()
            .zip(&fleet_profs)
            .zip(lambdas)
            .map(|((s, p), l)| Problem::new(s, p, l))
            .collect();
        rows.push(b.run("fleet_topology/zone_kill_repack_solve", || {
            solve_fleet_placed(&problems, &survivor, &prios, &[], None)
        }));
    }
    print_section("fleet topology (sticky packing + zone-kill repack)", &rows);
    let fleet_topology_rows = rows.clone();

    // Sharded data plane: the 64-stage synthetic ring harness (lock-free
    // per-stage rings vs the pre-sharding single lock) and an 8-member
    // fleet DES (per-member event wheels vs the legacy single heap).
    // Both speedups are asserted in-run, so `cargo bench` itself gates
    // the data-plane claim; relax with IPA_RING_SPEEDUP_GATE /
    // IPA_DES_SPEEDUP_GATE on noisy shared hardware.
    use ipa::data_plane::synthetic::{run_legacy_lock, run_sharded, SyntheticCfg};

    let gate = |var: &str, default: f64| -> f64 {
        std::env::var(var).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
    };
    let mut rows = Vec::new();

    let dp_cfg = SyntheticCfg::bench_default();
    let dp_items = dp_cfg.total_items() as f64;
    let ring_sharded = b.run_throughput(
        &format!("data_plane/sharded_rings_{}stages", dp_cfg.stages),
        dp_items,
        || run_sharded(&dp_cfg),
    );
    let ring_legacy = b.run_throughput(
        &format!("data_plane/legacy_single_lock_{}stages", dp_cfg.stages),
        dp_items,
        || run_legacy_lock(&dp_cfg),
    );
    let ring_speedup = ring_legacy.summary.mean / ring_sharded.summary.mean.max(1e-12);
    let ring_gate = gate("IPA_RING_SPEEDUP_GATE", 10.0);
    println!("  data_plane: ring speedup {ring_speedup:.1}x (gate {ring_gate:.1}x)");
    assert!(
        ring_speedup >= ring_gate,
        "sharded rings only {ring_speedup:.1}x the single-lock path (gate {ring_gate:.1}x)"
    );
    rows.push(ring_sharded);
    rows.push(ring_legacy);

    // 8-member fleet (demo3 cycled) at a fixed 120 s horizon: wide
    // enough that the single heap pays log(total events) across every
    // member on every pop, while each wheel stays member-local.
    let wide_n = 8usize;
    let wide_base = fleet.traces(120);
    let wide_specs: Vec<_> = (0..wide_n).map(|i| fleet_specs[i % 3].clone()).collect();
    let wide_profs: Vec<_> = (0..wide_n).map(|i| fleet_profs[i % 3].clone()).collect();
    let wide_slas: Vec<f64> = (0..wide_n).map(|i| fleet_slas[i % 3]).collect();
    let wide_traces: Vec<_> = (0..wide_n).map(|i| wide_base[i % 3].clone()).collect();
    let wide_budget = 64u32;
    let wide_items: f64 = wide_traces
        .iter()
        .enumerate()
        .map(|(m, t)| {
            t.arrivals(ipa::workload::tracegen::member_seed(fleet_seed, m)).len() as f64
        })
        .sum();
    let wide_run_routed = |legacy_clock: bool, router: Option<RouterConfig>| {
        let predictors: Vec<Box<dyn Predictor + Send>> = wide_specs
            .iter()
            .map(|_| Box::new(ReactivePredictor::default()) as Box<dyn Predictor + Send>)
            .collect();
        let mut adapter = FleetAdapter::new(
            wide_specs.clone(),
            wide_profs.clone(),
            AccuracyMetric::Pas,
            wide_budget,
            AdapterConfig::default(),
            predictors,
        )
        .unwrap();
        run_fleet(
            FleetDesParams {
                profiles: &wide_profs,
                slas: &wide_slas,
                interval: 10.0,
                apply_delay: 8.0,
                sim: SimConfig { seed: fleet_seed, legacy_clock, ..Default::default() },
                system: "dp-bench",
                budget: wide_budget,
                faults: &[],
                router,
                telemetry: None,
            },
            &mut adapter,
            &wide_traces,
        )
    };
    let wide_run = |legacy_clock: bool| wide_run_routed(legacy_clock, None);
    // one parity pass before timing: both clocks must produce the very
    // same per-request outcomes on the bench workload
    {
        let sharded_m = wide_run(false);
        let legacy_m = wide_run(true);
        for (m, (s, l)) in sharded_m.members.iter().zip(&legacy_m.members).enumerate() {
            assert_eq!(s.requests, l.requests, "member {m}: sharded clock diverged");
        }
    }
    let des_sharded = b.run_throughput(
        &format!("data_plane/fleet_des_sharded_{wide_n}members"),
        wide_items,
        || wide_run(false),
    );
    let des_legacy = b.run_throughput(
        &format!("data_plane/fleet_des_single_heap_{wide_n}members"),
        wide_items,
        || wide_run(true),
    );
    let des_speedup = des_legacy.summary.mean / des_sharded.summary.mean.max(1e-12);
    let des_gate = gate("IPA_DES_SPEEDUP_GATE", 1.0);
    println!("  data_plane: {wide_n}-member DES speedup {des_speedup:.2}x (gate {des_gate:.2}x)");
    assert!(
        des_speedup >= des_gate,
        "sharded DES clock only {des_speedup:.2}x the single heap (gate {des_gate:.2}x)"
    );
    rows.push(des_sharded);
    rows.push(des_legacy);
    print_section("data plane (sharded rings + sharded DES clock)", &rows);
    let data_plane_rows = rows.clone();

    // Telemetry plane: the flight-recorder overhead gate.  The same
    // 64-stage synthetic dispatch run with default sampling must stay
    // within 10% of the telemetry-off run (IPA_TELEM_OVERHEAD_GATE
    // overrides on noisy hardware); a traced 8-member fleet DES row
    // shows the end-to-end cost with spans + decision journal on.
    use ipa::telemetry::{Telemetry, TelemetryConfig};

    let mut rows = Vec::new();
    let telem_off = b.run_throughput(
        &format!("telemetry/untraced_{}stages", dp_cfg.stages),
        dp_items,
        || ipa::data_plane::synthetic::run_sharded_traced(&dp_cfg, &Telemetry::off()),
    );
    let sample_1_in = TelemetryConfig::default().sample_one_in;
    let telem_on = b.run_throughput(
        &format!("telemetry/sampled_1in{sample_1_in}_{}stages", dp_cfg.stages),
        dp_items,
        || {
            // fresh recorder each iteration so the span sink never
            // grows across iterations (steady-state cost, not drain)
            let tel = Telemetry::new(TelemetryConfig::default(), dp_cfg.stages);
            ipa::data_plane::synthetic::run_sharded_traced(&dp_cfg, &tel)
        },
    );
    let telem_overhead = telem_on.summary.mean / telem_off.summary.mean.max(1e-12) - 1.0;
    let telem_gate = gate("IPA_TELEM_OVERHEAD_GATE", 0.10);
    println!(
        "  telemetry: sampled overhead {:.1}% (gate {:.1}%)",
        telem_overhead * 100.0,
        telem_gate * 100.0
    );
    assert!(
        telem_overhead <= telem_gate,
        "sampled telemetry costs {:.1}% over the untraced dispatch path (gate {:.1}%)",
        telem_overhead * 100.0,
        telem_gate * 100.0
    );
    rows.push(telem_off);
    rows.push(telem_on);

    rows.push(b.run_throughput(
        &format!("telemetry/fleet_des_traced_{wide_n}members"),
        wide_items,
        || {
            let tel = Telemetry::new(TelemetryConfig::default(), wide_n);
            let predictors: Vec<Box<dyn Predictor + Send>> = wide_specs
                .iter()
                .map(|_| Box::new(ReactivePredictor::default()) as Box<dyn Predictor + Send>)
                .collect();
            let mut adapter = FleetAdapter::new(
                wide_specs.clone(),
                wide_profs.clone(),
                AccuracyMetric::Pas,
                wide_budget,
                AdapterConfig::default(),
                predictors,
            )
            .unwrap();
            run_fleet(
                FleetDesParams {
                    profiles: &wide_profs,
                    slas: &wide_slas,
                    interval: 10.0,
                    apply_delay: 8.0,
                    sim: SimConfig { seed: fleet_seed, ..Default::default() },
                    system: "telem-bench",
                    budget: wide_budget,
                    faults: &[],
                    router: None,
                    telemetry: Some(&tel),
                },
                &mut adapter,
                &wide_traces,
            )
        },
    ));
    print_section("telemetry (flight recorder overhead)", &rows);
    let telemetry_rows = rows.clone();

    // Solver scaling wall: synthetic-fleet solve+pack wall time across
    // the member×node grid, new default control plane (parallel
    // per-member solves + hierarchical cells + delta packing) A/B'd
    // in-run against the pre-PR flat sequential path.  Each episode is
    // one full joint decision plus three incremental ticks that perturb
    // ~10% of the fleet's λ by 25% (past the 15% re-solve threshold, so
    // the ticks exercise the incremental re-solve and the delta
    // repack).  The 100×1000 speedup is asserted against
    // IPA_FLEET_SCALE_GATE — default 0.75×cores clamped to [1.5, 5],
    // so the ≥5x target gates on ≥7-core machines and scales down to
    // what parallelism can physically deliver on small CI runners.
    use ipa::fleet::cells::set_cell_threshold;
    use ipa::fleet::nodes::{reset_delta_pack, set_delta_pack};
    use ipa::fleet::solver::set_solver_threads;

    let sb = Bencher::new(1, 3);
    let grid: [(usize, &str); 3] = [
        (10, "40x(8c,32g,0a)+10x(16c,64g,1a)"),
        (50, "200x(8c,32g,0a)+50x(16c,64g,1a)"),
        (100, "800x(8c,32g,0a)+200x(16c,64g,1a)"),
    ];
    let mut rows = Vec::new();
    let mut scale_speedup_100 = f64::NAN;
    for (n, nodes) in grid {
        let inv = NodeInventory::parse(nodes).unwrap();
        let scale_spec = FleetSpec::synthetic(n);
        let scale_specs = scale_spec.specs().unwrap();
        let scale_profs: Vec<_> = scale_specs.iter().map(pipeline_profiles).collect();
        let lambdas: Vec<f64> = (0..n).map(|i| 4.0 + (i % 7) as f64).collect();
        let mut episode = || {
            let predictors: Vec<Box<dyn Predictor + Send>> = scale_specs
                .iter()
                .map(|_| Box::new(ReactivePredictor::default()) as Box<dyn Predictor + Send>)
                .collect();
            let mut ad = FleetAdapter::new(
                scale_specs.clone(),
                scale_profs.clone(),
                AccuracyMetric::Pas,
                inv.replica_cap(),
                AdapterConfig::default(),
                predictors,
            )
            .and_then(|a| {
                a.with_tuning(FleetTuning {
                    resolve_threshold: 0.15,
                    nodes: Some(inv.clone()),
                    ..Default::default()
                })
            })
            .unwrap();
            ad.decide_for_lambdas(&lambdas);
            for tick in 1..=3usize {
                let moved: Vec<f64> = lambdas
                    .iter()
                    .enumerate()
                    .map(|(i, &l)| if i % 10 == tick { l * 1.25 } else { l })
                    .collect();
                ad.decide_for_lambdas(&moved);
            }
            ad.full_solves + ad.incremental_solves
        };
        // the pre-PR path: one thread, no cells, full sticky repacks
        set_solver_threads(1);
        set_cell_threshold(usize::MAX);
        set_delta_pack(false);
        let slow =
            sb.run(&format!("fleet_scale/flat_seq_{n}m_{}n", inv.n_nodes()), &mut episode);
        // the new default control plane
        set_solver_threads(0);
        set_cell_threshold(0);
        set_delta_pack(true);
        let fast =
            sb.run(&format!("fleet_scale/cells_par_{n}m_{}n", inv.n_nodes()), &mut episode);
        let speedup = slow.summary.mean / fast.summary.mean.max(1e-12);
        println!(
            "  fleet_scale: {n} members x {} nodes: {speedup:.2}x vs flat sequential",
            inv.n_nodes()
        );
        if n == 100 {
            scale_speedup_100 = speedup;
        }
        rows.push(fast);
        rows.push(slow);
    }
    set_solver_threads(0);
    set_cell_threshold(0);
    reset_delta_pack();
    let cores = std::thread::available_parallelism().map_or(1, |c| c.get()) as f64;
    let scale_gate = gate("IPA_FLEET_SCALE_GATE", (0.75 * cores).clamp(1.5, 5.0));
    println!("  fleet_scale: 100x1000 speedup {scale_speedup_100:.2}x (gate {scale_gate:.2}x)");
    assert!(
        scale_speedup_100 >= scale_gate,
        "scaled control plane only {scale_speedup_100:.2}x the flat sequential path \
         (gate {scale_gate:.2}x)"
    );
    print_section("fleet scale (solve+pack wall time, new default vs flat)", &rows);
    let fleet_scale_rows = rows.clone();

    // Epoch-parallel fleet DES: members advance concurrently between
    // control-plane barriers on the same epoch driver, so the only
    // variable under test is the worker count (SimConfig::sim_threads,
    // 0 = auto).  Demo3-cycled fleets at 8/32/100 members over the
    // 120 s bench traces; one parity pass pins byte-identical
    // per-request outcomes before any timing.  The 100-member speedup
    // is asserted against IPA_SIM_PAR_GATE — default 0.3×cores clamped
    // to [1.1, 3.0], so the gate engages on ≥4-core machines and stays
    // honest about barrier + fan-out overhead on small CI runners.
    let mut rows = Vec::new();
    let mut par_speedup_100 = f64::NAN;
    for n in [8usize, 32, 100] {
        let par_specs: Vec<_> = (0..n).map(|i| fleet_specs[i % 3].clone()).collect();
        let par_profs: Vec<_> = (0..n).map(|i| fleet_profs[i % 3].clone()).collect();
        let par_slas: Vec<f64> = (0..n).map(|i| fleet_slas[i % 3]).collect();
        let par_traces: Vec<_> = (0..n).map(|i| wide_base[i % 3].clone()).collect();
        let par_budget = 8 * n as u32;
        let mut episode = |threads: usize| {
            let predictors: Vec<Box<dyn Predictor + Send>> = par_specs
                .iter()
                .map(|_| Box::new(ReactivePredictor::default()) as Box<dyn Predictor + Send>)
                .collect();
            let mut adapter = FleetAdapter::new(
                par_specs.clone(),
                par_profs.clone(),
                AccuracyMetric::Pas,
                par_budget,
                AdapterConfig::default(),
                predictors,
            )
            .unwrap();
            run_fleet(
                FleetDesParams {
                    profiles: &par_profs,
                    slas: &par_slas,
                    interval: 10.0,
                    apply_delay: 8.0,
                    sim: SimConfig {
                        seed: fleet_seed,
                        sim_threads: threads,
                        ..Default::default()
                    },
                    system: "par-bench",
                    budget: par_budget,
                    faults: &[],
                    router: None,
                    telemetry: None,
                },
                &mut adapter,
                &par_traces,
            )
        };
        // parity before timing: the worker count may not change the run
        {
            let par = episode(0);
            let seq = episode(1);
            for (m, (p, s)) in par.members.iter().zip(&seq.members).enumerate() {
                assert_eq!(p.requests, s.requests, "member {m}: parallel epochs diverged");
            }
        }
        let seq = sb.run(&format!("sim_parallel/seq1_{n}m"), || episode(1));
        let par = sb.run(&format!("sim_parallel/par_{n}m"), || episode(0));
        let speedup = seq.summary.mean / par.summary.mean.max(1e-12);
        println!("  sim_parallel: {n} members: {speedup:.2}x vs 1 worker");
        if n == 100 {
            par_speedup_100 = speedup;
        }
        rows.push(par);
        rows.push(seq);
    }
    let par_gate = gate("IPA_SIM_PAR_GATE", (0.3 * cores).clamp(1.1, 3.0));
    println!("  sim_parallel: 100-member speedup {par_speedup_100:.2}x (gate {par_gate:.2}x)");
    assert!(
        par_speedup_100 >= par_gate,
        "epoch-parallel DES only {par_speedup_100:.2}x the 1-worker driver (gate {par_gate:.2}x)"
    );
    print_section("sim parallel (epoch-parallel fleet DES vs 1 worker)", &rows);
    let sim_parallel_rows = rows.clone();

    // Fleet front door: the same wide 8-member DES run pre-addressed
    // (router off — the historical ingress), routed through the
    // least-loaded policy, and routed with admission control on.  The
    // rows bound what the per-arrival route/admit decision costs on top
    // of the data plane; a counter check pins that the routed run
    // actually routed every arrival.
    let mut rows = Vec::new();
    {
        let routed = wide_run_routed(
            false,
            Some(RouterConfig { policy: RoutePolicy::LeastLoaded, ..RouterConfig::default() }),
        );
        let total: u64 = routed.router.iter().map(|s| s.total_routed()).sum();
        assert_eq!(
            total as usize,
            routed.members.iter().map(|m| m.requests.len()).sum::<usize>(),
            "routed bench run must route every arrival"
        );
    }
    rows.push(b.run_throughput(
        &format!("fleet_router/pre_addressed_{wide_n}m"),
        wide_items,
        || wide_run_routed(false, None),
    ));
    rows.push(b.run_throughput(
        &format!("fleet_router/routed_least_loaded_{wide_n}m"),
        wide_items,
        || {
            wide_run_routed(
                false,
                Some(RouterConfig {
                    policy: RoutePolicy::LeastLoaded,
                    ..RouterConfig::default()
                }),
            )
        },
    ));
    rows.push(b.run_throughput(
        &format!("fleet_router/routed_admission_{wide_n}m"),
        wide_items,
        || {
            wide_run_routed(
                false,
                Some(RouterConfig {
                    policy: RoutePolicy::LeastLoaded,
                    admission: true,
                    ..RouterConfig::default()
                }),
            )
        },
    ));
    print_section("fleet router (front door cost vs pre-addressed ingress)", &rows);
    let fleet_router_rows = rows.clone();

    // Perf baseline for future PRs: solver decision time + simulator
    // throughput (single-pipeline and fleet) + elastic control-plane
    // latencies, in a stable JSON shape.
    match ipa::benchkit::write_json(
        "BENCH_cluster.json",
        &[
            ("solver", &solver_rows[..]),
            ("simulator", &simulator_rows[..]),
            ("fleet_solver", &fleet_solver_rows[..]),
            ("fleet_sim", &fleet_sim_rows[..]),
            ("fleet_autoscaler", &fleet_autoscaler_rows[..]),
            ("fleet_binpack", &fleet_binpack_rows[..]),
            ("fleet_topology", &fleet_topology_rows[..]),
            ("fleet_scale", &fleet_scale_rows[..]),
            ("sim_parallel", &sim_parallel_rows[..]),
            ("fleet_router", &fleet_router_rows[..]),
            ("data_plane", &data_plane_rows[..]),
            ("telemetry", &telemetry_rows[..]),
        ],
    ) {
        Ok(()) => println!("wrote BENCH_cluster.json"),
        Err(e) => eprintln!("BENCH_cluster.json not written: {e}"),
    }

    // Trace generation + fits.
    let rows = vec![
        b.run_throughput("tracegen/bursty_3600s", 3600.0, || {
            tracegen::generate(Pattern::Bursty, 3600, 1)
        }),
        b.run("profiler/quadratic_fit_x29", || {
            pipeline_profiles(&pipelines::by_name("video").unwrap())
        }),
    ];
    print_section("workload + profiler", &rows);

    // Real PJRT execution latency (L1/L2 through the runtime).
    if let Some(dir) = &artifacts {
        let mut engine = ipa::runtime::engine::Engine::new(dir).expect("engine");
        let mut rows = Vec::new();
        for (key, hidden) in [("detect.yolov5n", 32usize), ("qa.roberta-large", 480)] {
            for batch in [1usize, 64] {
                let x = vec![0.1f32; batch * hidden];
                // warm compile outside the timer
                engine.execute_variant(key, batch, &x).unwrap();
                rows.push(b.run_throughput(
                    &format!("pjrt_exec/{key}/b{batch}"),
                    batch as f64,
                    || engine.execute_variant(key, batch, &x).unwrap(),
                ));
            }
        }
        let window = vec![10.0f32; 120];
        engine.predict(&window).unwrap();
        rows.push(b.run("pjrt_exec/lstm_predict", || engine.predict(&window).unwrap()));
        print_section("PJRT runtime (real artifact execution)", &rows);
    }

    println!("\nbench harness complete");
}
