//! Multi-resource bin-packing acceptance pins (ISSUE 4):
//!
//! (a) packing invariants as properties — no node's capacity is ever
//!     exceeded on any axis, every replica is placed exactly once, and
//!     accel-demanding replicas land only on accel-capable nodes;
//! (b) scalar regression — with a single node shape and zero mem/accel
//!     demand (the fungible embedding), the packed joint solver AND
//!     both fleet drivers produce byte-identical allocations, metrics
//!     and reports to the pre-refactor scalar path;
//! (c) heterogeneity — on a 2-shape pool the accel-requiring variants
//!     are demonstrably placed only on accel nodes, and a CPU-only
//!     pool filters them out of the solve entirely;
//! (d) preemption safety — the fast path never moves a replica onto
//!     nodes that cannot fit it (the candidate preemption is dropped
//!     and the pool stays packed);
//! (e) SLA classes — throughput members get relaxed drop SLAs and
//!     uncapped batch waits, latency-critical members get capped waits,
//!     keyed through `FleetTuning::sla_classes` on both drivers.

// The old fleet entry-point names (run_fleet_des* / serve_fleet_*)
// are exercised on purpose until their deprecation window closes.
#![allow(deprecated)]

use std::sync::Arc;

use ipa::coordinator::adapter::AdapterConfig;
use ipa::fleet::nodes::{NodeInventory, NodePool, NodeShape, PackItem};
use ipa::fleet::solver::{
    solve_fleet_packed, solve_fleet_tiers, FleetAdapter, FleetTuning, PreemptionConfig,
};
use ipa::fleet::spec::{FleetSpec, SlaClass};
use ipa::models::accuracy::AccuracyMetric;
use ipa::models::pipelines::{self, PipelineSpec};
use ipa::optimizer::ip::Problem;
use ipa::predictor::{Predictor, ReactivePredictor};
use ipa::profiler::analytic::pipeline_profiles;
use ipa::profiler::profile::PipelineProfiles;
use ipa::resources::ResourceVec;
use ipa::reports::tables;
use ipa::serving::engine::{serve_fleet_with, BatchExecutor, ServeConfig, SyntheticExecutor};
use ipa::serving::loadgen::LoadGenConfig;
use ipa::simulator::sim::{run_fleet_des, SimConfig};
use ipa::util::quickcheck::{check, prop_assert};

fn predictors(n: usize) -> Vec<Box<dyn Predictor + Send>> {
    (0..n)
        .map(|_| Box::new(ReactivePredictor::default()) as Box<dyn Predictor + Send>)
        .collect()
}

fn demo_parts() -> (Vec<PipelineSpec>, Vec<PipelineProfiles>, Vec<f64>) {
    let fleet = FleetSpec::demo3();
    let specs = fleet.specs().unwrap();
    let profs: Vec<PipelineProfiles> = specs.iter().map(pipeline_profiles).collect();
    let slas: Vec<f64> = specs.iter().map(|s| s.sla_e2e()).collect();
    (specs, profs, slas)
}

// ---------------------------------------------------------------------------
// (a) packing invariants
// ---------------------------------------------------------------------------

/// Property: for random inventories and demand sets, a successful pack
/// never exceeds any node's capacity on any axis, places every replica
/// exactly once, and puts accel demand only on accel-capable nodes.
#[test]
fn prop_packing_respects_every_capacity_axis() {
    check("fleet packing invariants", 150, |g| {
        let pools: Vec<NodePool> = (0..g.usize(1, 4))
            .map(|i| NodePool {
                shape: NodeShape {
                    name: format!("shape{i}"),
                    capacity: ResourceVec::new(
                        g.usize(1, 33) as f64,
                        g.usize(0, 129) as f64,
                        g.usize(0, 5) as f64,
                    ),
                    zone: String::new(),
                },
                count: g.usize(1, 8) as u32,
                bought: 0,
            })
            .collect();
        let inv = NodeInventory::new(pools);
        let items: Vec<PackItem> = (0..g.usize(1, 10))
            .map(|m| PackItem {
                member: m,
                stage: g.usize(0, 3),
                unit: ResourceVec::new(
                    g.usize(1, 17) as f64,
                    g.usize(0, 65) as f64,
                    g.usize(0, 3) as f64,
                ),
                replicas: g.usize(1, 6) as u32,
            })
            .collect();
        let Some(p) = inv.pack(&items) else { return Ok(()) };
        prop_assert(p.valid_for(&inv), "node over capacity on some axis")?;
        let total: u32 = items.iter().map(|it| it.replicas).sum();
        prop_assert(p.placements.len() == total as usize, "replica lost or duplicated")?;
        for pl in &p.placements {
            let it = items.iter().find(|it| it.member == pl.member).unwrap();
            let cap = inv.pools[p.shape_of[pl.node]].shape.capacity;
            prop_assert(it.unit.fits(cap), "replica on a node that cannot host it")?;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// (b) scalar regression: fungible single shape == the pre-refactor path
// ---------------------------------------------------------------------------

/// The packed solver on a fungible single-shape inventory returns
/// byte-identical allocations to the scalar tiered solver, across
/// budgets, λ mixes and priority layouts.
#[test]
fn fungible_packed_solver_matches_scalar_solver_exactly() {
    let (specs, profs, _) = demo_parts();
    for lambdas in [[4.0, 4.0, 4.0], [22.0, 9.0, 3.0], [9.0, 18.0, 12.0]] {
        let problems: Vec<Problem> = specs
            .iter()
            .zip(&profs)
            .zip(lambdas)
            .map(|((s, p), l)| Problem::new(s, p, l))
            .collect();
        for budget in [7u32, 12, 20, 28] {
            for prios in [vec![0u32, 0, 0], vec![2, 1, 0], vec![1, 2, 1]] {
                let scalar = solve_fleet_tiers(&problems, budget, &prios).unwrap();
                let packed =
                    solve_fleet_packed(&problems, &NodeInventory::fungible(budget), &prios)
                        .unwrap();
                assert_eq!(scalar.replicas_used, packed.replicas_used);
                assert_eq!(scalar.total_objective, packed.total_objective);
                for (s, p) in scalar.members.iter().zip(&packed.members) {
                    assert_eq!(s.budget, p.budget, "λ {lambdas:?} budget {budget}");
                    assert_eq!(s.config, p.config, "configs must be byte-identical");
                }
                // the packing itself is the scalar budget check
                let packing = packed.packing.unwrap();
                assert_eq!(packing.placements.len(), packed.replicas_used as usize);
            }
        }
    }
}

/// Both drivers, same seed, fungible single-shape inventory vs the
/// legacy scalar pool: identical per-member requests, intervals and
/// fleet tables — the end-to-end regression pin for the refactor.
#[test]
fn fungible_des_run_is_byte_identical_to_scalar_path() {
    let (_, profs, slas) = demo_parts();
    let traces = FleetSpec::demo3().traces(160);
    let names: Vec<String> =
        FleetSpec::demo3().members.iter().map(|m| m.name.clone()).collect();
    let run = |nodes: Option<NodeInventory>| {
        let (specs, profs2, _) = demo_parts();
        let mut adapter = FleetAdapter::new(
            specs,
            profs2,
            AccuracyMetric::Pas,
            24,
            AdapterConfig::default(),
            predictors(3),
        )
        .and_then(|a| a.with_tuning(FleetTuning { nodes, ..Default::default() }))
        .unwrap();
        run_fleet_des(
            &profs,
            &slas,
            10.0,
            8.0,
            SimConfig { seed: 5, ..Default::default() },
            &mut adapter,
            &traces,
            "fleet-regression",
            24,
        )
    };
    let scalar = run(None);
    let packed = run(Some(NodeInventory::fungible(24)));
    assert_eq!(scalar.budget, packed.budget);
    assert_eq!(scalar.peak_in_use, packed.peak_in_use);
    assert_eq!(scalar.final_replicas, packed.final_replicas);
    assert_eq!(scalar.pool, packed.pool, "pool reports must match field for field");
    for (m, (a, b)) in scalar.members.iter().zip(&packed.members).enumerate() {
        assert_eq!(a.requests, b.requests, "member {m}: request records diverge");
        assert_eq!(a.intervals.len(), b.intervals.len());
        for (ia, ib) in a.intervals.iter().zip(&b.intervals) {
            assert_eq!(ia.cost, ib.cost, "member {m}: interval cost diverges");
            assert_eq!(ia.variants, ib.variants, "member {m}: variants diverge");
        }
    }
    // the rendered reports agree byte for byte
    let ta = tables::fleet_table(&names, &scalar.members, &scalar.final_replicas, &scalar.pool);
    let tb = tables::fleet_table(&names, &packed.members, &packed.final_replicas, &packed.pool);
    assert_eq!(ta, tb, "fleet tables must be byte-identical");
}

// ---------------------------------------------------------------------------
// (c) heterogeneity end-to-end
// ---------------------------------------------------------------------------

/// A 2-shape pool through the DES driver: the run completes, the
/// budget equals the inventory's replica cap, the report carries
/// per-shape node lines, and every accel-demanding replica of the
/// final allocation is hosted by an accel node.
#[test]
fn heterogeneous_pool_runs_and_isolates_accel_variants() {
    // accuracy-hungry video so heavy (accel) variants are attractive
    let fleet = FleetSpec::demo3();
    let mut specs = fleet.specs().unwrap();
    specs[0].weights.alpha *= 40.0;
    let profs: Vec<PipelineProfiles> = specs.iter().map(pipeline_profiles).collect();
    let slas: Vec<f64> = specs.iter().map(|s| s.sla_e2e()).collect();
    let traces = fleet.traces(140);
    let inv = NodeInventory::parse("6x(4c,16g,0a)+2x(16c,64g,2a)").unwrap();
    let cap = inv.replica_cap();
    let mut adapter = FleetAdapter::new(
        specs,
        profs.clone(),
        AccuracyMetric::Pas,
        cap, // with_tuning re-derives this from the inventory anyway
        AdapterConfig::default(),
        predictors(3),
    )
    .and_then(|a| {
        a.with_tuning(FleetTuning {
            priorities: Some(fleet.priorities()),
            nodes: Some(inv.clone()),
            sla_classes: Some(fleet.classes()),
            ..Default::default()
        })
    })
    .unwrap();
    let fm = run_fleet_des(
        &profs,
        &slas,
        10.0,
        8.0,
        SimConfig { seed: 9, ..Default::default() },
        &mut adapter,
        &traces,
        "fleet-hetero",
        0, // ignored: the controller's inventory governs
    );
    assert_eq!(fm.budget, cap, "budget is the inventory replica cap");
    assert!(fm.total_completed() > 0);
    assert_eq!(fm.pool.nodes_final.len(), 2, "per-shape counts surface in the report");
    assert!(fm.pool.node_secs.iter().all(|(_, s)| *s > 0.0), "node-seconds accrued");
    let names: Vec<String> = fleet.members.iter().map(|m| m.name.clone()).collect();
    let table = tables::fleet_table(&names, &fm.members, &fm.final_replicas, &fm.pool);
    assert!(table.contains("pool nodes:"), "{table}");
    assert!(table.contains("cost vector:"), "{table}");
}

/// Failure modes: a CPU-only inventory rejects nothing (it filters the
/// accel variants instead), while an inventory too small for the stage
/// floor is rejected at tuning time.
#[test]
fn inventory_validation_and_filtering() {
    let (specs, profs, _) = demo_parts();
    // too small for the 7-stage floor
    let tiny = NodeInventory::parse("3x(2c,8g,0a)").unwrap();
    assert!(FleetAdapter::new(
        specs.clone(),
        profs.clone(),
        AccuracyMetric::Pas,
        24,
        AdapterConfig::default(),
        predictors(3),
    )
    .and_then(|a| a.with_tuning(FleetTuning { nodes: Some(tiny), ..Default::default() }))
    .is_err());
    // CPU-only pool: the solve simply never picks accel variants
    let plain = NodeInventory::parse("10x(4c,16g,0a)").unwrap();
    let mut ad = FleetAdapter::new(
        specs,
        profs,
        AccuracyMetric::Pas,
        24,
        AdapterConfig::default(),
        predictors(3),
    )
    .and_then(|a| a.with_tuning(FleetTuning { nodes: Some(plain), ..Default::default() }))
    .unwrap();
    let ds = ad.decide_for_lambdas(&[12.0, 6.0, 4.0]);
    for d in &ds {
        for sc in &d.config.stages {
            assert_eq!(sc.resources.accel_slots, 0.0, "accel variant on a CPU-only pool");
            assert!(sc.resources.cpu_cores <= 4.0, "replica wider than every node");
        }
    }
}

// ---------------------------------------------------------------------------
// (d) preemption never strands a replica on an impossible node
// ---------------------------------------------------------------------------

/// On a node-backed pool, every preemption the fast path emits must
/// bin-pack; an emitted decision vector is re-packed here as the
/// external check.
#[test]
fn preemption_on_nodes_stays_packable() {
    let (specs, profs, _) = demo_parts();
    let inv = NodeInventory::parse("8x(2c,8g,0a)+1x(16c,64g,2a)").unwrap();
    let mut fired = 0usize;
    for burst in [20.0, 35.0, 50.0] {
        let mut ad = FleetAdapter::new(
            specs.clone(),
            profs.clone(),
            AccuracyMetric::Pas,
            24,
            AdapterConfig::default(),
            predictors(3),
        )
        .and_then(|a| {
            a.with_tuning(FleetTuning {
                priorities: Some(vec![2, 1, 0]),
                preemption: Some(PreemptionConfig { burst_factor: 1.4, max_reclaim: 4 }),
                nodes: Some(inv.clone()),
                ..Default::default()
            })
        })
        .unwrap();
        ad.decide_for_lambdas(&[4.0, 4.0, 4.0]);
        let Some(p) = ad.preempt(5.0, &[burst, 4.0, 4.0]) else { continue };
        fired += 1;
        let configs: Vec<&ipa::optimizer::ip::PipelineConfig> =
            p.decisions.iter().map(|d| &d.config).collect();
        let packing = inv
            .pack(&ipa::fleet::nodes::config_demands(&configs))
            .expect("preemption emitted an unpackable fleet");
        assert!(packing.valid_for(&inv));
        for &(donor, _) in &p.from {
            assert!(donor != p.to, "no self-donation");
        }
    }
    // the grid is tuned to trigger at least once; if packing vetoes
    // every candidate that is fine too, but a silent no-op across the
    // whole grid would leave the property untested
    assert!(fired >= 1, "no preemption fired on the node pool grid");
}

/// Class policy alone moves replicas: with every priority equal, a
/// latency-critical burster reclaims from the throughput member (and a
/// throughput burster never receives).
#[test]
fn throughput_class_donates_at_equal_priority() {
    let (specs, profs, _) = demo_parts();
    let classes =
        vec![SlaClass::LatencyCritical, SlaClass::LatencyCritical, SlaClass::Throughput];
    let mk = || {
        FleetAdapter::new(
            specs.clone(),
            profs.clone(),
            AccuracyMetric::Pas,
            12,
            AdapterConfig::default(),
            predictors(3),
        )
        .and_then(|a| {
            a.with_tuning(FleetTuning {
                // priorities left at the default (all equal): the SLA
                // classes alone must drive donor eligibility
                preemption: Some(PreemptionConfig { burst_factor: 1.5, max_reclaim: 4 }),
                sla_classes: Some(classes.clone()),
                ..Default::default()
            })
        })
        .unwrap()
    };
    let mut fired = 0usize;
    for burst in [15.0, 25.0, 40.0] {
        let mut ad = mk();
        ad.decide_for_lambdas(&[4.0, 4.0, 4.0]);
        let Some(p) = ad.preempt(5.0, &[burst, 4.0, 4.0]) else { continue };
        fired += 1;
        assert_eq!(p.to, 0);
        assert!(p.reclaimed >= 1);
        for &(donor, _) in &p.from {
            assert_eq!(donor, 2, "only the throughput member is donor-eligible");
        }
    }
    assert!(fired >= 1, "class-driven donation never fired across the burst grid");
    // a throughput burster is never a receiver
    let mut ad2 = mk();
    ad2.decide_for_lambdas(&[4.0, 4.0, 4.0]);
    assert!(ad2.preempt(5.0, &[4.0, 4.0, 60.0]).is_none());
}

// ---------------------------------------------------------------------------
// (e) SLA classes through both drivers
// ---------------------------------------------------------------------------

/// Classes key the per-member drop SLA and batch-timeout ceiling in
/// both drivers without perturbing the calm-load parity between them.
#[test]
fn sla_classes_flow_through_both_drivers() {
    const SCALE: f64 = 0.05;
    const BUDGET: u32 = 16;
    let seed = 23u64;
    let specs: Vec<PipelineSpec> = ["video", "video"]
        .iter()
        .map(|n| {
            let mut s = pipelines::by_name(n).unwrap();
            s.weights.beta *= 50.0;
            s
        })
        .collect();
    let profs: Vec<PipelineProfiles> = specs.iter().map(pipeline_profiles).collect();
    let slas: Vec<f64> = specs.iter().map(|s| s.sla_e2e()).collect();
    let mut rates = vec![1.0; 70];
    rates.extend(vec![0.0; 30]);
    let traces = vec![
        ipa::workload::trace::Trace::new("class-parity-a", rates.clone()),
        ipa::workload::trace::Trace::new("class-parity-b", rates),
    ];
    let classes = vec![SlaClass::LatencyCritical, SlaClass::Throughput];
    let tuning = || FleetTuning {
        sla_classes: Some(classes.clone()),
        ..Default::default()
    };

    let mut sim_adapter = FleetAdapter::new(
        specs.clone(),
        profs.clone(),
        AccuracyMetric::Pas,
        BUDGET,
        AdapterConfig { interval: 10_000.0, apply_delay: 8.0, max_replicas: 4 },
        predictors(2),
    )
    .and_then(|a| a.with_tuning(tuning()))
    .unwrap();
    let fm = run_fleet_des(
        &profs,
        &slas,
        10_000.0,
        8.0,
        SimConfig { seed, service_noise: 0.0, drop_enabled: true, ..Default::default() },
        &mut sim_adapter,
        &traces,
        "class-sim",
        BUDGET,
    );

    let cfg = ServeConfig {
        artifact_dir: String::new(),
        executors: 0,
        max_workers: 4,
        interval: 10_000.0,
        apply_delay: 8.0 * SCALE,
        use_lstm: false,
        profile_batches: vec![],
        profile_reps: 0,
        sla_floor: 0.0,
        legacy_lock: false,
    };
    let scaled: Vec<PipelineProfiles> = profs.iter().map(|p| p.scaled(SCALE)).collect();
    let executors: Vec<Arc<dyn BatchExecutor>> = scaled
        .iter()
        .map(|p| Arc::new(SyntheticExecutor::from_profiles(p, 1.0)) as Arc<dyn BatchExecutor>)
        .collect();
    let rep = serve_fleet_with(
        &specs,
        scaled,
        AccuracyMetric::Pas,
        BUDGET,
        "class-live",
        &cfg,
        LoadGenConfig { time_scale: SCALE, seed },
        &traces,
        executors,
        predictors(2),
        tuning(),
    )
    .expect("live engine with SLA classes");

    for m in 0..2 {
        let s = &fm.members[m];
        let l = &rep.members[m].metrics;
        assert!(s.requests.len() > 30, "member {m}: thin trace");
        assert_eq!(s.requests.len(), l.requests.len(), "member {m}: arrivals diverge");
        assert_eq!(
            s.completed_count(),
            l.completed_count(),
            "member {m}: completions diverge"
        );
        assert_eq!(s.completed_count(), s.requests.len(), "member {m}: all complete");
        assert_eq!(s.dropped_count(), 0, "member {m}: calm load never drops");
    }
}

/// Unit pin of the class policy wiring: latency-critical caps the
/// batch-formation timeout at a quarter of the SLA, throughput relaxes
/// the drop SLA 2× — observable directly on the constructed cores.
#[test]
fn class_policy_caps_timeouts_and_scales_drop_sla() {
    use ipa::cluster::core::ClusterCore;
    use ipa::cluster::drop_policy::DropPolicy;
    use ipa::fleet::core::{FleetCore, MemberInit};
    use ipa::optimizer::ip::{PipelineConfig, StageConfig};
    let config = PipelineConfig {
        stages: vec![StageConfig {
            variant_idx: 0,
            variant_key: "v".into(),
            batch: 64,
            replicas: 1,
            cost: 1.0,
            accuracy: 90.0,
            latency: 0.1,
            resources: ResourceVec::cpu(1.0),
        }],
        pas: 90.0,
        cost: 1.0,
        batch_sum: 64,
        objective: 0.0,
        latency_e2e: 0.1,
        resources: ResourceVec::cpu(1.0),
    };
    let sla = 4.0;
    // λ=2, batch 64 → λ-shaped timeout 47.25 s; LC caps it at SLA/4
    let lc_cap = SlaClass::LatencyCritical.timeout_cap(sla);
    let inits = vec![
        MemberInit {
            config: config.clone(),
            lambda: 2.0,
            drop: DropPolicy::new(sla, true)
                .scaled(SlaClass::LatencyCritical.drop_sla_scale()),
            timeout_cap: lc_cap,
        },
        MemberInit {
            config: config.clone(),
            lambda: 2.0,
            drop: DropPolicy::new(sla, true).scaled(SlaClass::Throughput.drop_sla_scale()),
            timeout_cap: SlaClass::Throughput.timeout_cap(sla),
        },
    ];
    let fleet = FleetCore::with_nodes(4, None, &inits).unwrap();
    assert!((fleet.member(0).stages[0].dispatcher.timeout() - 1.0).abs() < 1e-9);
    assert!((fleet.member(1).stages[0].dispatcher.timeout() - 47.25).abs() < 1e-9);
    // BOTH classes report attainment against the true SLA; only the
    // drop threshold moves for the throughput member
    assert_eq!(fleet.member(0).drop_policy.sla, 4.0);
    assert_eq!(fleet.member(1).drop_policy.sla, 4.0, "metrics keep the true SLA");
    assert!(!fleet.member(1).drop_policy.should_drop(1, 7.9), "sheds only past 2×");
    assert!(fleet.member(1).drop_policy.should_drop(1, 8.1));
    assert!(fleet.member(0).drop_policy.should_drop(1, 4.1), "LC sheds past 1×");
    // sanity: the standalone capped constructor agrees
    let solo = ClusterCore::new_capped(&config, 2.0, DropPolicy::new(sla, true), lc_cap);
    assert!((solo.stages[0].dispatcher.timeout() - 1.0).abs() < 1e-9);
}
