//! Topology acceptance pins (ISSUE 5): sticky placement, failure
//! domains, and migration-budgeted repacking.
//!
//! (a) stickiness — a sticky re-pack of an UNCHANGED configuration
//!     moves zero replicas, and a changed configuration's sticky move
//!     count never exceeds what a plain FFD re-pack would pay;
//! (b) zone spread — a spread-flagged member's packing survives any
//!     single zone loss with ≥ 1 replica per stage, both at the packer
//!     and through a full `run_fleet_des_faults` run with a mid-run
//!     `kill_zone` and emergency repack;
//! (c) migration charging — a migration-charged reconfiguration never
//!     activates earlier than an uncharged one;
//! (d) scalar regression — on a fungible single-zone inventory the
//!     sticky/spread machinery is invisible: `pack_sticky` with no
//!     history reproduces `pack` byte for byte and the placed joint
//!     solve equals the PR-4 packed solve.

// The old fleet entry-point names (run_fleet_des* / serve_fleet_*)
// are exercised on purpose until their deprecation window closes.
#![allow(deprecated)]

use ipa::coordinator::adapter::AdapterConfig;
use ipa::fleet::core::FleetReconfig;
use ipa::fleet::nodes::{NodeInventory, NodePool, NodeShape, PackItem};
use ipa::fleet::solver::{solve_fleet_packed, solve_fleet_placed, FleetAdapter, FleetTuning};
use ipa::fleet::spec::FleetSpec;
use ipa::models::accuracy::AccuracyMetric;
use ipa::models::pipelines;
use ipa::optimizer::ip::Problem;
use ipa::predictor::{Predictor, ReactivePredictor};
use ipa::profiler::analytic::pipeline_profiles;
use ipa::resources::ResourceVec;
use ipa::simulator::sim::{run_fleet_des_faults, SimConfig, ZoneFault};
use ipa::util::quickcheck::{check, prop_assert};
use ipa::workload::tracegen::Pattern;

fn predictors(n: usize) -> Vec<Box<dyn Predictor + Send>> {
    (0..n)
        .map(|_| Box::new(ReactivePredictor::default()) as Box<dyn Predictor + Send>)
        .collect()
}

/// A random 1-3 shape inventory spread over 1-3 zones.
fn gen_inventory(g: &mut ipa::util::quickcheck::Gen) -> NodeInventory {
    let zones = ["east", "west", "north"];
    let n_zones = g.usize(1, 4);
    let pools: Vec<NodePool> = (0..g.usize(1, 4))
        .map(|i| NodePool {
            shape: NodeShape {
                name: format!("s{i}"),
                capacity: ResourceVec::new(
                    g.usize(2, 33) as f64,
                    g.usize(8, 129) as f64,
                    g.usize(0, 3) as f64,
                ),
                zone: zones[i % n_zones].to_string(),
            },
            count: g.usize(1, 5) as u32,
            bought: 0,
        })
        .collect();
    NodeInventory::new(pools)
}

fn gen_items(g: &mut ipa::util::quickcheck::Gen) -> Vec<PackItem> {
    (0..g.usize(1, 6))
        .map(|m| PackItem {
            member: m,
            stage: g.usize(0, 2),
            unit: ResourceVec::new(
                g.usize(1, 9) as f64,
                g.usize(1, 33) as f64,
                g.usize(0, 2) as f64,
            ),
            replicas: g.usize(1, 5) as u32,
        })
        .collect()
}

// ---------------------------------------------------------------------------
// (a) stickiness
// ---------------------------------------------------------------------------

/// Property: re-packing the SAME items against their own packing keeps
/// every replica in place — zero moves — and a shifted demand's sticky
/// pack never moves more replicas than a plain FFD re-pack would.
#[test]
fn prop_sticky_moves_bounded_by_plain_and_zero_when_unchanged() {
    check("sticky pack minimizes moves", 150, |g| {
        let inv = gen_inventory(g);
        let items = gen_items(g);
        let Some(prev) = inv.pack(&items) else { return Ok(()) };

        // unchanged demand: identity re-pack, zero moves
        let same = inv
            .pack_sticky(&items, Some(&prev), &[])
            .expect("a packed demand set must re-pack against itself");
        prop_assert(
            same.moved_from(&prev).is_empty(),
            "unchanged configuration moved a replica",
        )?;

        // shifted demand: one member grows by one replica
        let mut shifted = items.clone();
        let k = g.usize(0, shifted.len());
        shifted[k].replicas += 1;
        let sticky = inv.pack_sticky(&shifted, Some(&prev), &[]);
        let plain = inv.pack(&shifted);
        match (sticky, plain) {
            (Some(s), Some(p)) => prop_assert(
                s.moved_from(&prev).len() <= p.moved_from(&prev).len(),
                "sticky pack moved MORE than plain FFD",
            ),
            // sticky falls back to plain inside the fleet core, so a
            // sticky-only failure is not a correctness loss here
            _ => Ok(()),
        }
    });
}

// ---------------------------------------------------------------------------
// (b) zone spread
// ---------------------------------------------------------------------------

/// Property: whatever the packer accepts for a spread-flagged member
/// survives ANY single zone loss with ≥ 1 replica per stage (when the
/// inventory spans ≥ 2 zones — below that spread is vacuous).
#[test]
fn prop_spread_packing_survives_any_single_zone_loss() {
    check("zone spread survives any kill", 150, |g| {
        let inv = gen_inventory(g);
        let mut items = gen_items(g);
        // spread members need ≥ 2 replicas per stage to spread at all
        for it in items.iter_mut() {
            it.replicas = it.replicas.max(2);
        }
        let spread = vec![true; items.len()];
        let Some(p) = inv.pack_sticky(&items, None, &spread) else { return Ok(()) };
        if inv.distinct_zones() < 2 {
            return Ok(()); // vacuous: nothing to spread across
        }
        let zones: Vec<String> = inv
            .pools
            .iter()
            .filter(|pl| pl.count > 0)
            .map(|pl| pl.shape.zone.clone())
            .collect();
        for zone in &zones {
            let surv = p.survivors_of_zone(&inv, zone);
            for it in &items {
                if it.replicas == 0 {
                    continue;
                }
                prop_assert(
                    surv.get(&(it.member, it.stage)).copied().unwrap_or(0) >= 1,
                    &format!(
                        "member {} stage {} dies with zone {zone}",
                        it.member, it.stage
                    ),
                )?;
            }
        }
        Ok(())
    });
}

/// End to end: a spread-flagged member on a two-zone pool rides through
/// a mid-run `kill_zone` — at the instant of the fault every one of its
/// stages still has a live replica, the emergency repack lands on the
/// survivor zone, and the run keeps completing requests.
#[test]
fn kill_zone_des_spread_member_never_drops_below_stage_floor() {
    let mut fleet = FleetSpec::demo3();
    fleet.members.truncate(2); // video + audio-sent, 2 stages each
    fleet.members[0].spread = true;
    fleet.members[0].pattern = Pattern::SteadyLow;
    fleet.members[1].pattern = Pattern::SteadyLow;
    let inv = NodeInventory::parse("3x(8c,32g,0a)@east+3x(8c,32g,0a)@west").unwrap();
    fleet.nodes = Some(inv.clone());
    fleet.validate().unwrap();

    let specs = fleet.specs().unwrap();
    let profs: Vec<_> = specs.iter().map(pipeline_profiles).collect();
    let slas: Vec<f64> = specs.iter().map(|s| s.sla_e2e()).collect();
    let mut adapter = FleetAdapter::new(
        specs.clone(),
        profs.clone(),
        AccuracyMetric::Pas,
        inv.replica_cap(),
        AdapterConfig::default(),
        predictors(2),
    )
    .and_then(|a| {
        a.with_tuning(FleetTuning {
            nodes: Some(inv.clone()),
            spread: Some(fleet.spreads()),
            migration_delay: 0.5,
            ..Default::default()
        })
    })
    .unwrap();

    let traces = fleet.traces(180);
    let faults = [ZoneFault { at: 75.0, zone: "west".into() }];
    let fm = run_fleet_des_faults(
        &profs,
        &slas,
        10.0,
        8.0,
        SimConfig { seed: 11, ..Default::default() },
        &mut adapter,
        &traces,
        "fleet-topo",
        0,
        &faults,
    );

    assert_eq!(fm.pool.zone_kills, 1, "the scripted fault fired");
    assert_eq!(fm.budget, 24, "west zone (3×8 slots) drained from the pool");
    assert_eq!(
        fm.pool.nodes_by_zone,
        vec![("east".to_string(), 3), ("west".to_string(), 0)]
    );
    // at the instant of the loss, the spread member held ≥ 1 replica
    // per stage OUTSIDE the dead zone — the spread guarantee
    assert_eq!(fm.zone_fault_min_survivors.len(), 1);
    assert!(
        fm.zone_fault_min_survivors[0][0] >= 1,
        "spread member dropped below its stage floor at the fault: {:?}",
        fm.zone_fault_min_survivors
    );
    // the run kept serving: both members completed work, and the final
    // configurations fit the survivor pool
    for m in &fm.members {
        assert!(m.completed_count() > 100, "{}: {}", m.workload, m.completed_count());
    }
    assert!(fm.final_replicas.iter().sum::<u32>() <= fm.budget);
    // a churny elastic run charges migrations; this one at least
    // recorded the ledger without panicking
    assert!(fm.pool.migrations < 10_000);
}

// ---------------------------------------------------------------------------
// (c) migration charging
// ---------------------------------------------------------------------------

/// Property: for any (apply delay, migration delay, move count), the
/// migration-charged stager never activates a decision EARLIER than the
/// uncharged one, is exactly the uncharged one at zero moves, and is
/// monotone in the move count.
#[test]
fn prop_migration_charge_never_applies_earlier() {
    check("migration charge is monotone", 200, |g| {
        let apply = g.f64(0.0, 20.0);
        let per_move = g.f64(0.0, 3.0);
        let moves = g.usize(0, 50) as u32;
        let now = g.f64(0.0, 1000.0);
        let mut plain = FleetReconfig::new(apply);
        let mut charged = FleetReconfig::with_migration(apply, per_move);
        let at_plain = plain.stage(now, Vec::new(), 8, None, moves);
        let at_charged = charged.stage(now, Vec::new(), 8, None, moves);
        prop_assert(at_charged >= at_plain, "charged reconfig applied earlier")?;
        let at_zero = charged.stage(now, Vec::new(), 8, None, 0);
        prop_assert(at_zero <= at_charged, "more moves must never apply sooner")?;
        prop_assert((at_zero - at_plain).abs() < 1e-9, "zero moves must charge nothing")
    });
}

// ---------------------------------------------------------------------------
// (d) scalar / fungible regression
// ---------------------------------------------------------------------------

/// Property: on the fungible single-zone embedding the topology layer
/// is invisible — `pack_sticky` with no history and no flags IS `pack`
/// (byte for byte), spread flags change nothing, and the packing still
/// succeeds iff Σ replicas fits the slot count.
#[test]
fn prop_fungible_single_zone_reproduces_scalar_packing() {
    check("fungible packing unchanged by topology", 150, |g| {
        let n = g.usize(1, 33) as u32;
        let inv = NodeInventory::fungible(n);
        let items = gen_items(g);
        let total: u32 = items.iter().map(|it| it.replicas).sum();
        let plain = inv.pack(&items);
        prop_assert(plain.is_some() == (total <= n), "scalar budget rule broken")?;
        let sticky = inv.pack_sticky(&items, None, &[]);
        prop_assert(sticky == plain, "pack_sticky(None, []) must BE pack")?;
        // spread flags are vacuous on the single unnamed zone
        let flagged = inv.pack_sticky(&items, None, &vec![true; items.len()]);
        prop_assert(flagged == plain, "spread must be vacuous on one zone")?;
        // and the identity re-pack moves nothing
        if let Some(prev) = &plain {
            let again = inv.pack_sticky(&items, Some(prev), &[]).expect("re-pack");
            prop_assert(again.moved_from(prev).is_empty(), "fungible re-pack moved")?;
        }
        Ok(())
    });
}

/// The placed joint solve with no flags and no history equals the PR-4
/// packed solve on both fungible and heterogeneous inventories.
#[test]
fn placed_solve_without_topology_matches_packed_solve() {
    let specs: Vec<_> = ["video", "audio-sent", "nlp"]
        .iter()
        .map(|n| pipelines::by_name(n).unwrap())
        .collect();
    let profs: Vec<_> = specs.iter().map(pipeline_profiles).collect();
    let problems: Vec<Problem> = specs
        .iter()
        .zip(&profs)
        .zip([14.0, 7.0, 4.0])
        .map(|((s, p), l)| Problem::new(s, p, l))
        .collect();
    for inv in [
        NodeInventory::fungible(24),
        NodeInventory::parse("4x(4c,16g,0a)+2x(16c,64g,2a)").unwrap(),
        NodeInventory::parse("3x(4c,16g,0a)@east+3x(4c,16g,0a)@west").unwrap(),
    ] {
        let prios = [2u32, 1, 0];
        let packed = solve_fleet_packed(&problems, &inv, &prios).unwrap();
        let placed = solve_fleet_placed(&problems, &inv, &prios, &[], None).unwrap();
        assert_eq!(packed.budget, placed.budget, "{inv}");
        assert_eq!(packed.replicas_used, placed.replicas_used);
        for (a, b) in packed.members.iter().zip(&placed.members) {
            assert_eq!(a.config, b.config, "{inv}: configs diverge");
            assert_eq!(a.budget, b.budget);
            assert_eq!(a.solved, b.solved);
        }
        assert_eq!(packed.packing, placed.packing, "{inv}: placements diverge");
    }
}

/// Sticky solves through the adapter: two consecutive decisions with
/// identical λ produce identical configurations, a [`FleetCore`]
/// holding the first plans ZERO churn for the second
/// ([`FleetCore::plan_moves`] — what the drivers charge through the
/// migration delay), and re-applying it migrates nothing.
#[test]
fn adapter_sticky_decisions_plan_zero_moves_when_quiet() {
    use ipa::cluster::drop_policy::DropPolicy;
    use ipa::fleet::core::{FleetCore, MemberInit};

    let fleet = FleetSpec::demo3();
    let specs = fleet.specs().unwrap();
    let profs: Vec<_> = specs.iter().map(pipeline_profiles).collect();
    let inv = NodeInventory::parse("4x(4c,16g,0a)@east+4x(4c,16g,0a)@west").unwrap();
    let mut adapter = FleetAdapter::new(
        specs.clone(),
        profs,
        AccuracyMetric::Pas,
        inv.replica_cap(),
        AdapterConfig::default(),
        predictors(3),
    )
    .and_then(|a| {
        a.with_tuning(FleetTuning {
            nodes: Some(inv.clone()),
            migration_delay: 0.25,
            ..Default::default()
        })
    })
    .unwrap();
    assert!((adapter.migration_delay - 0.25).abs() < 1e-12);
    let a = adapter.decide_for_lambdas(&[8.0, 5.0, 3.0]);
    let b = adapter.decide_for_lambdas(&[8.0, 5.0, 3.0]);
    for (da, db) in a.iter().zip(&b) {
        assert_eq!(da.config, db.config, "quiet re-decide changed a configuration");
    }
    // a core holding the first decision prices the second at ZERO
    // churn — the migration-charged stager adds nothing for it
    let inits: Vec<MemberInit> = a
        .iter()
        .zip(&specs)
        .map(|(d, s)| {
            MemberInit::new(d.config.clone(), 10.0, DropPolicy::new(s.sla_e2e(), true))
        })
        .collect();
    let mut core = FleetCore::with_nodes(0, Some(inv), &inits).unwrap();
    let cfgs: Vec<&ipa::optimizer::ip::PipelineConfig> = b.iter().map(|d| &d.config).collect();
    assert_eq!(core.plan_moves(&cfgs), 0, "quiet decision must plan zero churn");
    let pairs: Vec<(ipa::optimizer::ip::PipelineConfig, f64)> =
        b.iter().map(|d| (d.config.clone(), 10.0)).collect();
    core.apply(&pairs).unwrap();
    assert_eq!(core.pool_report().migrations, 0, "quiet apply must migrate nothing");
}
