//! Data-plane regression suite: the lock-free ring contract
//! (FIFO / no-loss / no-duplication, exact multiset delivery under
//! producer contention, drop-on-full accounting), byte-for-byte parity
//! between the sharded DES clock and the legacy single heap, and a
//! live-engine smoke over both hot paths (sharded rings vs the legacy
//! single lock).

// The old fleet entry-point names (run_fleet_des* / serve_fleet_*)
// are exercised on purpose until their deprecation window closes.
#![allow(deprecated)]

use std::collections::VecDeque;
use std::sync::Arc;

use ipa::cluster::core::ClusterCore;
use ipa::cluster::drop_policy::DropPolicy;
use ipa::coordinator::adapter::{AdapterConfig, Policy};
use ipa::data_plane::ingress::{shed, LaneGrid};
use ipa::data_plane::ring::MpscRing;
use ipa::fleet::solver::FleetAdapter;
use ipa::models::accuracy::AccuracyMetric;
use ipa::models::pipelines::{self, PipelineSpec};
use ipa::optimizer::ip::{PipelineConfig, StageConfig};
use ipa::predictor::{Predictor, ReactivePredictor};
use ipa::profiler::analytic::pipeline_profiles;
use ipa::profiler::profile::PipelineProfiles;
use ipa::resources::ResourceVec;
use ipa::serving::engine::{serve_with, ServeConfig, SyntheticExecutor};
use ipa::serving::loadgen::LoadGenConfig;
use ipa::simulator::sim::{run_fleet_des, FleetRunMetrics, SimConfig};
use ipa::util::quickcheck::{check, prop_assert};
use ipa::workload::trace::Trace;

// ---------------------------------------------------------------------------
// Ring contract
// ---------------------------------------------------------------------------

/// Any interleaving of pushes and pops matches a VecDeque reference:
/// FIFO order, nothing lost, nothing duplicated, full-ring pushes
/// rejected with the value intact.
#[test]
fn quickcheck_ring_matches_fifo_reference() {
    check("mpsc ring == VecDeque", 300, |g| {
        let cap_pow = g.usize(1, 6); // capacity 2..=32
        let ring: MpscRing<u64> = MpscRing::with_capacity(1 << cap_pow);
        let mut reference: VecDeque<u64> = VecDeque::new();
        let n_ops = g.usize(1, 120);
        let mut next = 0u64;
        for _ in 0..n_ops {
            if g.bool() {
                match ring.try_push(next) {
                    Ok(()) => reference.push_back(next),
                    Err(v) => {
                        prop_assert(v == next, "rejected push must return the value")?;
                        prop_assert(
                            reference.len() >= ring.capacity(),
                            "ring rejected a push while not full",
                        )?;
                    }
                }
                next += 1;
            } else {
                prop_assert(ring.pop() == reference.pop_front(), "pop diverged")?;
            }
        }
        while let Some(expected) = reference.pop_front() {
            prop_assert(ring.pop() == Some(expected), "drain diverged")?;
        }
        prop_assert(ring.pop().is_none(), "ring not empty after drain")
    });
}

/// Seeded multi-producer stress: every pushed value is delivered exactly
/// once (exact multiset), and each producer's own values arrive in its
/// push order — the MPSC contract under real contention.  A small ring
/// forces constant full-ring backoff.
#[test]
fn multi_producer_stress_delivers_exact_multiset_in_producer_order() {
    const PRODUCERS: u64 = 8;
    const PER_PRODUCER: u64 = 4_000;
    let ring: Arc<MpscRing<u64>> = Arc::new(MpscRing::with_capacity(64));
    let handles: Vec<_> = (0..PRODUCERS)
        .map(|p| {
            let ring = Arc::clone(&ring);
            std::thread::spawn(move || {
                for i in 0..PER_PRODUCER {
                    let mut v = (p << 32) | i;
                    loop {
                        match ring.try_push(v) {
                            Ok(()) => break,
                            Err(back) => {
                                v = back;
                                std::thread::yield_now();
                            }
                        }
                    }
                }
            })
        })
        .collect();
    // single consumer (the engine's dispatch side) drains concurrently
    let total = (PRODUCERS * PER_PRODUCER) as usize;
    let mut got = Vec::with_capacity(total);
    while got.len() < total {
        match ring.pop() {
            Some(v) => got.push(v),
            None => std::thread::yield_now(),
        }
    }
    for h in handles {
        h.join().unwrap();
    }
    assert!(ring.pop().is_none(), "ring must be empty after full drain");
    // exact multiset: every (producer, index) pair exactly once
    let mut sorted = got.clone();
    sorted.sort_unstable();
    let expected: Vec<u64> =
        (0..PRODUCERS).flat_map(|p| (0..PER_PRODUCER).map(move |i| (p << 32) | i)).collect();
    assert_eq!(sorted, expected, "multiset delivery mismatch");
    // per-producer FIFO: indices of one producer arrive monotonically
    let mut last = vec![-1i64; PRODUCERS as usize];
    for v in got {
        let p = (v >> 32) as usize;
        let i = (v & 0xFFFF_FFFF) as i64;
        assert!(i > last[p], "producer {p} reordered: {i} after {}", last[p]);
        last[p] = i;
    }
}

// ---------------------------------------------------------------------------
// Ingress shed accounting
// ---------------------------------------------------------------------------

fn two_stage_core() -> ClusterCore {
    let config = PipelineConfig {
        stages: (0..2)
            .map(|i| StageConfig {
                variant_idx: 0,
                variant_key: format!("v{i}"),
                batch: 4,
                replicas: 1,
                cost: 1.0,
                accuracy: 90.0,
                latency: 0.1,
                resources: ResourceVec::cpu(1.0),
            })
            .collect(),
        pas: 90.0,
        cost: 2.0,
        batch_sum: 8,
        objective: 0.0,
        latency_e2e: 0.2,
        resources: ResourceVec::ZERO,
    };
    ClusterCore::new(&config, f64::INFINITY, DropPolicy::new(10.0, true))
}

/// An arrival shed because its ingress lane was full lands in the SAME
/// drop ledger the §4.5 drop policy feeds: recorded as an arrival (so
/// demand metrics see it) and as a drop.
#[test]
fn full_lane_shed_feeds_drop_policy_counters() {
    let grid = LaneGrid::single(2, 2);
    let mut core = two_stage_core();
    let mut shed_count = 0u64;
    for id in 0..10u64 {
        let t = id as f64 * 0.01;
        if grid.ingest(0, id, t) {
            continue;
        }
        shed(&mut core, id, t);
        shed_count += 1;
    }
    assert_eq!(shed_count, 8, "capacity-2 lane sheds all but the first two");
    assert_eq!(core.accounting.dropped_count(), 8);
    assert!(core.accounting.is_dropped(5));
    // the two queued arrivals drain normally and stay undropped
    assert_eq!(grid.drain_into(0, 0, &mut core, 64), 2);
    assert_eq!(core.accounting.dropped_count(), 8);
    assert!(!core.accounting.is_dropped(0));
}

// ---------------------------------------------------------------------------
// Sharded DES clock: byte-for-byte parity with the single heap
// ---------------------------------------------------------------------------

fn fleet_des_run(legacy_clock: bool, seed: u64) -> FleetRunMetrics {
    const BUDGET: u32 = 20;
    let fleet = ipa::fleet::spec::FleetSpec::demo3();
    let specs: Vec<PipelineSpec> = fleet.specs().unwrap();
    let profs: Vec<PipelineProfiles> = specs.iter().map(pipeline_profiles).collect();
    let slas: Vec<f64> = specs.iter().map(PipelineSpec::sla_e2e).collect();
    let traces: Vec<Trace> = fleet.traces(180);
    let predictors: Vec<Box<dyn Predictor + Send>> = specs
        .iter()
        .map(|_| Box::new(ReactivePredictor::default()) as Box<dyn Predictor + Send>)
        .collect();
    let mut adapter = FleetAdapter::new(
        specs,
        profs.clone(),
        AccuracyMetric::Pas,
        BUDGET,
        AdapterConfig { interval: 30.0, apply_delay: 8.0, max_replicas: 4 },
        predictors,
    )
    .unwrap();
    run_fleet_des(
        &profs,
        &slas,
        30.0,
        8.0,
        SimConfig { seed, legacy_clock, ..Default::default() },
        &mut adapter,
        &traces,
        "clock-parity",
        BUDGET,
    )
}

/// The tentpole's determinism contract: the sharded per-member clock
/// pops the exact event order the single heap would (one global seq,
/// tournament min), so a full fleet DES — adaptation ticks, service
/// noise RNG draws, drops and all — reproduces byte-for-byte.
#[test]
fn fleet_des_sharded_clock_matches_single_heap_byte_for_byte() {
    let sharded = fleet_des_run(false, 11);
    let legacy = fleet_des_run(true, 11);
    assert_eq!(sharded.members.len(), legacy.members.len());
    let total: usize = sharded.members.iter().map(|m| m.requests.len()).sum();
    assert!(total > 300, "thin run ({total} requests) proves nothing");
    for (m, (s, l)) in sharded.members.iter().zip(&legacy.members).enumerate() {
        assert_eq!(s.requests, l.requests, "member {m}: per-request outcomes diverge");
        assert_eq!(s.completed_count(), l.completed_count(), "member {m}");
        assert_eq!(s.dropped_count(), l.dropped_count(), "member {m}");
    }
    assert_eq!(sharded.peak_in_use, legacy.peak_in_use);
    assert_eq!(sharded.final_replicas, legacy.final_replicas);
}

// ---------------------------------------------------------------------------
// Live engine: sharded rings vs the legacy single lock
// ---------------------------------------------------------------------------

fn live_run(legacy_lock: bool) -> (usize, usize, usize) {
    const SCALE: f64 = 0.05;
    let seed = 17u64;
    let spec = pipelines::by_name("video").unwrap();
    let prof = pipeline_profiles(&spec);
    // calm load + quiet tail: the unique correct outcome is "everything
    // completes, nothing drops" on BOTH hot paths
    let mut rates = vec![1.0; 40];
    rates.extend(vec![0.0; 20]);
    let trace = Trace::new("dp-live", rates);
    let n_arrivals = trace.arrivals(seed).len();
    let cfg = ServeConfig {
        artifact_dir: String::new(),
        executors: 0,
        max_workers: 8,
        interval: 10_000.0,
        apply_delay: 8.0 * SCALE,
        use_lstm: false,
        profile_batches: vec![],
        profile_reps: 0,
        sla_floor: 0.0,
        legacy_lock,
    };
    let scaled = prof.scaled(SCALE);
    let executor = Arc::new(SyntheticExecutor::from_profiles(&scaled, 1.0));
    let rep = serve_with(
        &spec,
        scaled,
        Policy::Fa2Low,
        &cfg,
        LoadGenConfig { time_scale: SCALE, seed },
        &trace,
        executor,
        Box::new(ReactivePredictor::default()),
    )
    .expect("live engine");
    (n_arrivals, rep.metrics.completed_count(), rep.metrics.dropped_count())
}

/// Smoke over both live hot paths: the sharded rings (default) and the
/// legacy single lock complete the same calm trace in full.
#[test]
fn live_engine_completes_calm_trace_on_both_hot_paths() {
    for legacy_lock in [false, true] {
        let (arrivals, completed, dropped) = live_run(legacy_lock);
        assert!(arrivals > 25, "thin trace ({arrivals})");
        assert_eq!(
            completed, arrivals,
            "legacy_lock={legacy_lock}: every arrival must complete"
        );
        assert_eq!(dropped, 0, "legacy_lock={legacy_lock}: nothing may drop");
    }
}
