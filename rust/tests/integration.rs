//! Cross-module integration tests: adapter + simulator + baselines
//! reproducing the paper's headline claims in miniature.

use ipa::baselines::rim::RimParams;
use ipa::coordinator::adapter::{Adapter, AdapterConfig, Policy};
use ipa::models::accuracy::AccuracyMetric;
use ipa::models::pipelines;
use ipa::predictor::ReactivePredictor;
use ipa::profiler::analytic::pipeline_profiles;
use ipa::simulator::sim::{SimConfig, Simulation};
use ipa::workload::trace::Trace;
use ipa::workload::tracegen::Pattern;

fn run(pipeline: &str, policy: Policy, pattern: Pattern, seconds: usize) -> ipa::metrics::RunMetrics {
    let spec = pipelines::by_name(pipeline).unwrap();
    let prof = pipeline_profiles(&spec);
    let adapter = Adapter::new(
        spec,
        prof,
        policy,
        AdapterConfig::default(),
        Box::new(ReactivePredictor::default()),
    );
    let mut sim = Simulation::new(adapter, SimConfig { seed: 5, ..Default::default() });
    sim.run(&Trace::synthetic(pattern, seconds))
}

/// Headline claim (§5.2, up to 21%): IPA improves PAS over the
/// cost-comparable baseline (FA2-low) with at most a modest cost
/// increase, on every pipeline.
#[test]
fn ipa_beats_fa2_low_on_accuracy_at_comparable_cost() {
    for pipeline in ["video", "audio-qa", "audio-sent", "sum-qa", "nlp"] {
        let ipa = run(pipeline, Policy::Ipa(AccuracyMetric::Pas), Pattern::Fluctuating, 300);
        let low = run(pipeline, Policy::Fa2Low, Pattern::Fluctuating, 300);
        assert!(
            ipa.avg_pas() >= low.avg_pas() - 1e-9,
            "{pipeline}: IPA PAS {} < FA2-low {}",
            ipa.avg_pas(),
            low.avg_pas()
        );
    }
}

/// §5.2: FA2-high and RIM buy accuracy with heavy over-provisioning;
/// IPA stays much cheaper than RIM.
#[test]
fn ipa_cheaper_than_rim() {
    // RIM's static scale is provisioned for peak; at steady-low load it
    // over-provisions badly (§5.4: ~3x IPA's allocation).
    let ipa = run("video", Policy::Ipa(AccuracyMetric::Pas), Pattern::SteadyLow, 240);
    let rim = run(
        "video",
        Policy::Rim(RimParams { fixed_replicas: 8 }),
        Pattern::SteadyLow,
        240,
    );
    assert!(
        ipa.avg_cost() < rim.avg_cost(),
        "ipa {} vs rim {}",
        ipa.avg_cost(),
        rim.avg_cost()
    );
}

/// §5.2 steady-high behaviour: under sustained high load IPA diverges
/// toward cheaper variants (PAS at or below its steady-low PAS).
#[test]
fn ipa_downgrades_under_steady_high() {
    let lo = run("video", Policy::Ipa(AccuracyMetric::Pas), Pattern::SteadyLow, 240);
    let hi = run("video", Policy::Ipa(AccuracyMetric::Pas), Pattern::SteadyHigh, 240);
    assert!(
        hi.avg_pas() <= lo.avg_pas() + 1e-9,
        "steady-high PAS {} should not exceed steady-low {}",
        hi.avg_pas(),
        lo.avg_pas()
    );
    // The downgrade keeps the system serving: drops stay bounded even at
    // ~4x the load.  (Cost need not rise: lighter variants are cheaper
    // per unit of throughput — that's the point of switching.)
    assert!(hi.drop_rate() < 0.15, "drops {}", hi.drop_rate());
}

/// Fig. 14 adaptability: the (α, β) knobs trace a monotone cost/accuracy
/// frontier.
#[test]
fn weight_knobs_trace_frontier() {
    let spec0 = pipelines::by_name("audio-sent").unwrap();
    let mut results = Vec::new();
    for (am, bm) in [(0.2, 10.0), (1.0, 1.0), (10.0, 0.1)] {
        let mut spec = spec0.clone();
        spec.weights.alpha *= am;
        spec.weights.beta *= bm;
        let prof = pipeline_profiles(&spec);
        let adapter = Adapter::new(
            spec,
            prof,
            Policy::Ipa(AccuracyMetric::Pas),
            AdapterConfig::default(),
            Box::new(ReactivePredictor::default()),
        );
        let mut sim = Simulation::new(adapter, SimConfig { seed: 5, ..Default::default() });
        let m = sim.run(&Trace::synthetic(Pattern::SteadyLow, 200));
        results.push((m.avg_cost(), m.avg_pas()));
    }
    // accuracy-prioritized runs must not have lower PAS than
    // resource-prioritized runs, and vice versa for cost
    assert!(results[2].1 >= results[0].1, "{results:?}");
    assert!(results[0].0 <= results[2].0, "{results:?}");
}

/// Drop policy (§4.5): with dropping disabled, bursty overload inflates
/// tail latency beyond the 2×SLA ceiling that dropping enforces.
#[test]
fn dropping_caps_tail_latency() {
    let spec = pipelines::by_name("video").unwrap();
    let prof = pipeline_profiles(&spec);
    let mk = |drop_enabled| {
        let adapter = Adapter::new(
            spec.clone(),
            prof.clone(),
            Policy::Fa2Low,
            AdapterConfig::default(),
            Box::new(ReactivePredictor { window: 30, headroom: 0.3 }), // underestimates
        );
        Simulation::new(
            adapter,
            SimConfig { seed: 9, drop_enabled, service_noise: 0.0, ..Default::default() },
        )
    };
    let trace = Trace::synthetic(Pattern::Bursty, 240);
    let with_drop = mk(true).run(&trace);
    let without = mk(false).run(&trace);
    let max_with = with_drop.latencies().iter().fold(0.0f64, |a, &b| a.max(b));
    let max_without = without.latencies().iter().fold(0.0f64, |a, &b| a.max(b));
    assert!(max_with <= max_without + 1e-9, "{max_with} vs {max_without}");
}

/// All five pipelines complete a bursty run with sane metrics under IPA.
#[test]
fn all_pipelines_bursty_sanity() {
    for pipeline in ["video", "audio-qa", "audio-sent", "sum-qa", "nlp"] {
        let m = run(pipeline, Policy::Ipa(AccuracyMetric::Pas), Pattern::Bursty, 240);
        assert!(m.requests.len() > 500, "{pipeline}: {}", m.requests.len());
        assert!(m.avg_pas() > 0.0);
        assert!(m.avg_cost() > 0.0);
        assert!(m.sla_attainment() > 0.3, "{pipeline}: {}", m.sla_attainment());
        assert!(m.intervals.len() >= 20);
    }
}

/// The adaptive headline run is exactly replayable from its decision
/// log: simulator and replay driver share the cluster core, so the
/// per-request outcomes and headline aggregates are bit-identical.
#[test]
fn headline_run_is_exactly_replayable() {
    let spec = pipelines::by_name("audio-qa").unwrap();
    let prof = pipeline_profiles(&spec);
    let cfg = AdapterConfig::default();
    let adapter = Adapter::new(
        spec.clone(),
        prof.clone(),
        Policy::Ipa(AccuracyMetric::Pas),
        cfg,
        Box::new(ReactivePredictor::default()),
    );
    let sim_cfg = SimConfig { seed: 5, ..Default::default() };
    let mut sim = Simulation::new(adapter, sim_cfg);
    let trace = Trace::synthetic(Pattern::Fluctuating, 200);
    let (original, log) = sim.run_logged(&trace);
    let replayed = ipa::simulator::replay::replay(
        &prof,
        spec.sla_e2e(),
        cfg.interval,
        cfg.apply_delay,
        sim_cfg,
        &log,
        &trace,
        "replay",
    );
    assert_eq!(original.requests, replayed.requests);
    assert_eq!(original.latencies(), replayed.latencies());
    assert!((original.sla_attainment() - replayed.sla_attainment()).abs() < 1e-12);
}

/// PAS′ (Appendix C): the alternative metric produces the same system
/// ordering as PAS.
#[test]
fn pas_prime_same_ordering() {
    let prime = run("video", Policy::Ipa(AccuracyMetric::PasPrime), Pattern::SteadyLow, 200);
    let low = run("video", Policy::Fa2Low, Pattern::SteadyLow, 200);
    let high = run("video", Policy::Fa2High, Pattern::SteadyLow, 200);
    assert!(prime.avg_pas() >= low.avg_pas() - 1e-9);
    assert!(prime.avg_pas() <= high.avg_pas() + 1e-9);
}
