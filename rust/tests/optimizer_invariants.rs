//! Property-based invariants of the IP solver (the Gurobi-optimality
//! substitute proof obligations) — run via the quickcheck-lite harness.

use ipa::models::accuracy::AccuracyMetric;
use ipa::models::pipelines;
use ipa::optimizer::{brute, ip};
use ipa::profiler::analytic::pipeline_profiles;
use ipa::util::quickcheck::{check, prop_assert, prop_close};

/// B&B equals exhaustive enumeration for random weights/loads/caps on
/// random pipelines — optimality certification.
#[test]
fn prop_bnb_optimal() {
    let specs = pipelines::all();
    check("bnb matches brute oracle", 60, |g| {
        let mut spec = g.choose(&specs).clone();
        spec.weights.alpha = g.f64(0.1, 60.0);
        spec.weights.beta = g.f64(0.01, 8.0);
        spec.weights.delta = g.f64(0.0, 1e-3);
        let prof = pipeline_profiles(&spec);
        let mut p = ip::Problem::new(&spec, &prof, g.f64(0.5, 45.0));
        p.max_replicas = g.usize(1, 48) as u32;
        if g.bool() {
            p.metric = AccuracyMetric::PasPrime;
        }
        match (ip::solve(&p), brute::solve(&p)) {
            (None, None) => Ok(()),
            (Some((a, _)), Some(b)) => {
                prop_close(a.objective, b.objective, 1e-9, "objective")
            }
            (a, b) => prop_assert(
                false,
                &format!("feasibility mismatch: bnb={} brute={}", a.is_some(), b.is_some()),
            ),
        }
    });
}

/// Every solution satisfies the Eq. 10 constraints.
#[test]
fn prop_solutions_feasible() {
    let specs = pipelines::all();
    check("solutions satisfy constraints", 80, |g| {
        let spec = g.choose(&specs).clone();
        let prof = pipeline_profiles(&spec);
        let lambda = g.f64(0.5, 45.0);
        let p = ip::Problem::new(&spec, &prof, lambda);
        let Some((cfg, _)) = ip::solve(&p) else {
            return Ok(());
        };
        // (10b) latency
        prop_assert(cfg.latency_e2e <= spec.sla_e2e() + 1e-9, "latency SLA")?;
        // (10c) throughput per stage
        for (si, sc) in cfg.stages.iter().enumerate() {
            let vp = &prof.stages[si].variants[sc.variant_idx];
            let tput = sc.replicas as f64 * vp.latency.throughput(sc.batch);
            prop_assert(tput >= lambda - 1e-9, "throughput")?;
            // (10d/10e) integrality + one active variant is structural
            prop_assert(sc.replicas >= 1, "positive replicas")?;
            prop_assert(sc.batch.is_power_of_two() && sc.batch <= 64, "batch domain")?;
        }
        Ok(())
    });
}

/// Objective monotonicity: adding load can only keep or worsen the
/// optimal objective (the feasible set shrinks).
#[test]
fn prop_objective_monotone_in_load() {
    let specs = pipelines::all();
    check("objective monotone in lambda", 40, |g| {
        let spec = g.choose(&specs).clone();
        let prof = pipeline_profiles(&spec);
        let l1 = g.f64(0.5, 20.0);
        let l2 = l1 + g.f64(0.5, 20.0);
        let a = ip::solve(&ip::Problem::new(&spec, &prof, l1));
        let b = ip::solve(&ip::Problem::new(&spec, &prof, l2));
        match (a, b) {
            (Some((ca, _)), Some((cb, _))) => prop_assert(
                cb.objective <= ca.objective + 1e-9,
                &format!("obj rose with load: {} -> {}", ca.objective, cb.objective),
            ),
            (None, Some(_)) => prop_assert(false, "feasible at higher load only"),
            _ => Ok(()),
        }
    });
}

/// Raising α (accuracy weight) never lowers the chosen PAS; raising β
/// never raises the chosen cost.
#[test]
fn prop_weight_monotonicity() {
    let specs = pipelines::all();
    check("alpha/beta monotonicity", 40, |g| {
        let spec0 = g.choose(&specs).clone();
        let prof = pipeline_profiles(&spec0);
        let lambda = g.f64(1.0, 30.0);
        let base = ip::solve(&ip::Problem::new(&spec0, &prof, lambda));
        let Some((base_cfg, _)) = base else { return Ok(()) };

        let mut spec_a = spec0.clone();
        spec_a.weights.alpha *= g.f64(2.0, 50.0);
        if let Some((cfg, _)) = ip::solve(&ip::Problem::new(&spec_a, &prof, lambda)) {
            prop_assert(cfg.pas >= base_cfg.pas - 1e-9, "alpha up -> PAS not down")?;
        }

        let mut spec_b = spec0.clone();
        spec_b.weights.beta *= g.f64(2.0, 50.0);
        if let Some((cfg, _)) = ip::solve(&ip::Problem::new(&spec_b, &prof, lambda)) {
            prop_assert(cfg.cost <= base_cfg.cost + 1e-9, "beta up -> cost not up")?;
        }
        Ok(())
    });
}

/// The solver is deterministic.
#[test]
fn prop_deterministic() {
    let specs = pipelines::all();
    check("solver deterministic", 20, |g| {
        let spec = g.choose(&specs).clone();
        let prof = pipeline_profiles(&spec);
        let lambda = g.f64(0.5, 40.0);
        let p = ip::Problem::new(&spec, &prof, lambda);
        let a = ip::solve(&p).map(|(c, _)| c);
        let b = ip::solve(&p).map(|(c, _)| c);
        prop_assert(a == b, "nondeterministic solve")
    });
}

/// Baselines never beat IPA's objective on IPA's own objective function
/// (IPA's search space is a superset).
#[test]
fn prop_ipa_dominates_baselines_on_objective() {
    use ipa::baselines::{fa2, rim};
    let specs = pipelines::all();
    check("ipa objective dominates", 30, |g| {
        let spec = g.choose(&specs).clone();
        let prof = pipeline_profiles(&spec);
        let lambda = g.f64(1.0, 30.0);
        let p = ip::Problem::new(&spec, &prof, lambda);
        let Some((ipa_cfg, _)) = ip::solve(&p) else { return Ok(()) };
        // Baselines may return *infeasible* fallback configs (shed load
        // via dropping) when their restricted space cannot serve λ —
        // only fully feasible configs participate in the dominance check.
        let feasible = |cfg: &ip::PipelineConfig| {
            cfg.latency_e2e <= spec.sla_e2e() + 1e-9
                && cfg.stages.iter().enumerate().all(|(si, sc)| {
                    let vp = &prof.stages[si].variants[sc.variant_idx];
                    sc.replicas as f64 * vp.latency.throughput(sc.batch) >= lambda - 1e-9
                })
        };
        for cfg in [
            fa2::decide(&p, fa2::VariantPin::Lightest),
            fa2::decide(&p, fa2::VariantPin::Heaviest),
            rim::decide(&p, rim::RimParams { fixed_replicas: g.usize(2, 12) as u32 }),
        ] {
            if feasible(&cfg) {
                prop_assert(
                    ipa_cfg.objective >= cfg.objective - 1e-9,
                    &format!("baseline beat IPA: {} > {}", cfg.objective, ipa_cfg.objective),
                )?;
            }
        }
        Ok(())
    });
}
