//! Driver parity over the shared cluster core.
//!
//! The `cluster` refactor's whole point is that the discrete-event
//! simulator, the live wall-clock engine and the replay driver run the
//! SAME stage machinery.  These tests pin that down:
//!
//! 1. replay parity — a recorded decision schedule re-run through the
//!    DES loop reproduces the original per-request outcomes exactly,
//!    including §4.5 drops under bursty overload;
//! 2. sim/live parity — the same trace with frozen analytic profiles
//!    and zero service noise through both the simulator and the
//!    threaded live engine (synthetic executor, compressed wall clock)
//!    produces identical drop/completion counts.

use std::sync::Arc;

use ipa::coordinator::adapter::{Adapter, AdapterConfig, Policy};
use ipa::models::accuracy::AccuracyMetric;
use ipa::models::pipelines;
use ipa::predictor::ReactivePredictor;
use ipa::profiler::analytic::pipeline_profiles;
use ipa::serving::engine::{serve_with, ServeConfig, SyntheticExecutor};
use ipa::serving::loadgen::LoadGenConfig;
use ipa::simulator::replay::replay;
use ipa::simulator::sim::{SimConfig, Simulation};
use ipa::workload::trace::Trace;
use ipa::workload::tracegen::Pattern;

fn adapter(pipeline: &str, policy: Policy, cfg: AdapterConfig) -> Adapter {
    let spec = pipelines::by_name(pipeline).unwrap();
    let prof = pipeline_profiles(&spec);
    Adapter::new(spec, prof, policy, cfg, Box::new(ReactivePredictor::default()))
}

/// Replay parity on a calm trace: every request outcome identical.
#[test]
fn replay_matches_sim_on_steady_load() {
    let cfg = AdapterConfig::default();
    let spec = pipelines::by_name("video").unwrap();
    let prof = pipeline_profiles(&spec);
    let sim_cfg = SimConfig { seed: 21, ..Default::default() };
    let mut sim = Simulation::new(
        adapter("video", Policy::Ipa(AccuracyMetric::Pas), cfg),
        sim_cfg,
    );
    let trace = Trace::synthetic(Pattern::SteadyLow, 200);
    let (original, log) = sim.run_logged(&trace);
    let replayed = replay(
        &prof,
        spec.sla_e2e(),
        cfg.interval,
        cfg.apply_delay,
        sim_cfg,
        &log,
        &trace,
        "replay",
    );
    assert_eq!(original.requests, replayed.requests);
}

/// Replay parity under bursty overload — nonzero drops, reproduced
/// exactly (drop bookkeeping is part of the shared core).
#[test]
fn replay_matches_sim_under_bursty_drops() {
    let cfg = AdapterConfig::default();
    let spec = pipelines::by_name("video").unwrap();
    let prof = pipeline_profiles(&spec);
    let sim_cfg =
        SimConfig { seed: 9, service_noise: 0.05, drop_enabled: true, ..Default::default() };
    let mut sim = Simulation::new(adapter("video", Policy::Fa2Low, cfg), sim_cfg);
    let trace = Trace::synthetic(Pattern::Bursty, 240);
    let (original, log) = sim.run_logged(&trace);
    let replayed = replay(
        &prof,
        spec.sla_e2e(),
        cfg.interval,
        cfg.apply_delay,
        sim_cfg,
        &log,
        &trace,
        "replay",
    );
    assert_eq!(original.requests, replayed.requests);
    assert_eq!(original.dropped_count(), replayed.dropped_count());
    assert_eq!(original.completed_count(), replayed.completed_count());
    assert!(
        original.requests.iter().any(|r| r.completion.is_none()),
        "burst run should exercise drops/incompletions for the parity to be meaningful"
    );
}

/// Sim/live parity: same trace + frozen analytic profiles + zero noise
/// through both drivers → identical drop/completion counts.
///
/// Setup: constant low load with a quiet cooldown tail long enough for
/// both drivers to drain in-run, ample capacity, and no adaptation
/// ticks (interval > horizon) so both drivers hold the initial
/// configuration.  Under these conditions the unique correct outcome is
/// "every arrival completes, nothing drops" — any drift in batching,
/// dropping or accounting between the drivers breaks the equality.
///
/// The live side runs the real threaded engine on a compressed wall
/// clock with latencies scaled to match (`PipelineProfiles::scaled`),
/// so solver inputs (λ, l(b), SLA) scale consistently and the engine
/// picks the equivalent configuration.
#[test]
fn sim_and_live_engine_agree_on_counts() {
    // 20x wall compression: fast enough to keep the test short (~7 s),
    // slow enough that the wall-domain SLA (≈0.35 s) dwarfs scheduler
    // jitter on loaded CI machines.
    const SCALE: f64 = 0.05;
    let seed = 17u64;
    let spec = pipelines::by_name("video").unwrap();
    let prof = pipeline_profiles(&spec);

    // 100 s of λ=1 plus a 30 s quiet tail to drain inside the horizon.
    // At λ=1 FA2-low provisions batch-1 single replicas per stage with
    // ~2.5× throughput headroom: stage utilization stays low, ages stay
    // far under the SLA, and neither formation timeouts nor wall-clock
    // jitter can push any request near a drop boundary.
    let mut rates = vec![1.0; 100];
    rates.extend(vec![0.0; 30]);
    let trace = Trace::new("parity", rates);
    let n_arrivals = trace.arrivals(seed).len();
    assert!(n_arrivals > 60, "trace too thin: {n_arrivals}");

    // --- simulator side (virtual time, paper-scale profiles) ---------
    // FA2-low: min-cost batches under the SLA constraint — the choice
    // is invariant under consistent (λ, latency, SLA) time scaling, so
    // both drivers provision the equivalent configuration.
    let sim_adapter = Adapter::new(
        spec.clone(),
        prof.clone(),
        Policy::Fa2Low,
        AdapterConfig { interval: 10_000.0, apply_delay: 8.0, max_replicas: 8 },
        Box::new(ReactivePredictor::default()),
    );
    let mut sim = Simulation::new(
        sim_adapter,
        SimConfig { seed, service_noise: 0.0, drop_enabled: true, ..Default::default() },
    );
    let m_sim = sim.run(&trace);

    // --- live side (threaded wall clock, scaled profiles) ------------
    let cfg = ServeConfig {
        artifact_dir: String::new(),
        executors: 0,
        max_workers: 8,
        interval: 10_000.0,
        apply_delay: 8.0 * SCALE,
        use_lstm: false,
        profile_batches: vec![],
        profile_reps: 0,
        sla_floor: 0.0,
        legacy_lock: false,
    };
    let scaled = prof.scaled(SCALE);
    let executor = Arc::new(SyntheticExecutor::from_profiles(&scaled, 1.0));
    let rep = serve_with(
        &spec,
        scaled,
        Policy::Fa2Low,
        &cfg,
        LoadGenConfig { time_scale: SCALE, seed },
        &trace,
        executor,
        Box::new(ReactivePredictor::default()),
    )
    .expect("live engine");
    let m_live = rep.metrics;

    assert_eq!(m_sim.requests.len(), n_arrivals, "sim records every arrival");
    assert_eq!(m_live.requests.len(), n_arrivals, "live records every arrival");
    assert_eq!(
        m_sim.completed_count(),
        m_live.completed_count(),
        "completion counts diverge (sim {} vs live {})",
        m_sim.completed_count(),
        m_live.completed_count()
    );
    assert_eq!(
        m_sim.dropped_count(),
        m_live.dropped_count(),
        "drop counts diverge (sim {} vs live {})",
        m_sim.dropped_count(),
        m_live.dropped_count()
    );
    // and the unique correct outcome for this scenario:
    assert_eq!(m_sim.completed_count(), n_arrivals, "sim completed everything");
    assert_eq!(m_sim.dropped_count(), 0, "sim dropped nothing");
}
