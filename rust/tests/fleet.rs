//! Fleet invariants and driver parity.
//!
//! 1. property — the joint allocator never exceeds the shared replica
//!    budget, grants every stage at least one replica, and its total
//!    objective is never worse than the even-split baseline;
//! 2. brute cross-check — on tiny fleets the greedy never reports more
//!    than the exhaustive best split;
//! 3. sim/live fleet parity — the same two-member fleet with frozen
//!    scaled profiles and zero noise through both the fleet DES loop
//!    and the threaded fleet engine produces identical per-member
//!    drop/completion counts (the fleet twin of
//!    `tests/cluster_parity.rs`).

// The old fleet entry-point names (run_fleet_des* / serve_fleet_*)
// are exercised on purpose until their deprecation window closes.
#![allow(deprecated)]

use std::sync::Arc;

use ipa::coordinator::adapter::AdapterConfig;
use ipa::fleet::solver::{
    allocate_at, brute_best_split, even_shares, solve_fleet, FleetAdapter, FleetTuning,
};
use ipa::models::accuracy::AccuracyMetric;
use ipa::models::pipelines::{self, PipelineSpec};
use ipa::optimizer::ip::Problem;
use ipa::optimizer::options::StageOption;
use ipa::predictor::{Predictor, ReactivePredictor};
use ipa::profiler::analytic::pipeline_profiles;
use ipa::profiler::profile::PipelineProfiles;
use ipa::serving::engine::{serve_fleet_with, BatchExecutor, ServeConfig, SyntheticExecutor};
use ipa::serving::loadgen::LoadGenConfig;
use ipa::simulator::sim::{run_fleet_des, SimConfig};
use ipa::util::quickcheck::{check, prop_assert};
use ipa::workload::trace::Trace;

const NAMES: [&str; 5] = ["video", "audio-qa", "audio-sent", "sum-qa", "nlp"];

/// Property: for random member sets, λs and budgets, the joint
/// allocation (a) fits the budget, (b) grants every stage ≥ 1 replica,
/// (c) totals at least the even-split baseline's objective.
#[test]
fn prop_allocator_budget_and_even_split_floor() {
    let all_specs: Vec<PipelineSpec> =
        NAMES.iter().map(|n| pipelines::by_name(n).unwrap()).collect();
    let all_profs: Vec<PipelineProfiles> =
        all_specs.iter().map(pipeline_profiles).collect();
    check("fleet allocator invariants", 25, |g| {
        let n = g.usize(1, 4);
        let idx: Vec<usize> = (0..n).map(|_| g.usize(0, NAMES.len())).collect();
        let lambdas: Vec<f64> = (0..n).map(|_| g.f64(0.5, 30.0)).collect();
        let problems: Vec<Problem> = idx
            .iter()
            .zip(&lambdas)
            .map(|(&i, &l)| Problem::new(&all_specs[i], &all_profs[i], l))
            .collect();
        let floors: Vec<u32> =
            problems.iter().map(|p| p.profiles.stages.len() as u32).collect();
        let floor_total: u32 = floors.iter().sum();
        let budget = floor_total + g.u64(0, 24) as u32;

        let alloc = match solve_fleet(&problems, budget) {
            Some(a) => a,
            None => return prop_assert(false, "budget >= floor but solve_fleet bailed"),
        };
        prop_assert(alloc.replicas_used <= budget, "allocation exceeds budget")?;
        prop_assert(alloc.members.len() == n, "one allocation per member")?;
        for m in &alloc.members {
            prop_assert(
                m.config.stages.iter().all(|s| s.replicas >= 1),
                "stage granted zero replicas",
            )?;
            prop_assert(m.replicas <= m.budget, "member overspends its share")?;
        }
        let options: Vec<Vec<Vec<StageOption>>> =
            problems.iter().map(|p| p.stage_options()).collect();
        let even = allocate_at(&problems, &options, &even_shares(budget, &floors));
        prop_assert(
            alloc.total_objective >= even.total_objective - 1e-9,
            "worse than even split",
        )
    });
}

/// The greedy never reports a better total than the exhaustive best
/// split (it is a lower bound on the optimum by construction).
#[test]
fn greedy_bounded_by_brute_across_budgets() {
    let specs: Vec<PipelineSpec> =
        ["video", "sum-qa"].iter().map(|n| pipelines::by_name(n).unwrap()).collect();
    let profs: Vec<PipelineProfiles> = specs.iter().map(pipeline_profiles).collect();
    for (la, lb) in [(3.0, 3.0), (18.0, 4.0), (30.0, 25.0)] {
        let problems =
            vec![Problem::new(&specs[0], &profs[0], la), Problem::new(&specs[1], &profs[1], lb)];
        for budget in 4..=10u32 {
            let alloc = solve_fleet(&problems, budget).unwrap();
            let brute = brute_best_split(&problems, budget).unwrap();
            assert!(
                alloc.total_objective <= brute + 1e-9,
                "λ=({la},{lb}) budget {budget}: greedy {} above brute {brute}",
                alloc.total_objective
            );
            assert!(alloc.replicas_used <= budget);
        }
    }
}

/// Sim/live fleet parity: a two-member fleet under calm constant load
/// with no adaptation ticks through both fleet drivers → identical
/// per-member completion/drop counts, and the unique correct outcome
/// (everything completes, nothing drops).
///
/// Same construction as the single-pipeline parity test: frozen
/// analytic profiles uniformly scaled into the wall domain, zero
/// service noise, quiet cooldown tail, interval > horizon.  The joint
/// solver's decisions are invariant under consistent (λ, latency, SLA)
/// time scaling, so both drivers provision the same fleet
/// configuration out of the same shared budget.
#[test]
fn fleet_sim_and_live_engine_agree_on_counts() {
    const SCALE: f64 = 0.05;
    const BUDGET: u32 = 16;
    let seed = 23u64;
    // Cost-dominated weights (β × 50) make the joint solver pick the
    // lightest variants at batch 1 with single replicas — ample
    // throughput headroom at λ=1, so no request ever nears a drop
    // boundary and the count equality below is the unique correct
    // outcome (the same construction the single-pipeline parity test
    // gets from FA2-low).
    let specs: Vec<PipelineSpec> = ["video", "video"]
        .iter()
        .map(|n| {
            let mut s = pipelines::by_name(n).unwrap();
            s.weights.beta *= 50.0;
            s
        })
        .collect();
    let profs: Vec<PipelineProfiles> = specs.iter().map(pipeline_profiles).collect();
    let slas: Vec<f64> = specs.iter().map(|s| s.sla_e2e()).collect();

    // 80 s of λ=1 per member plus a 30 s quiet tail to drain in-run.
    let mut rates = vec![1.0; 80];
    rates.extend(vec![0.0; 30]);
    let traces =
        vec![Trace::new("fleet-parity-a", rates.clone()), Trace::new("fleet-parity-b", rates)];

    let predictors = || -> Vec<Box<dyn Predictor + Send>> {
        (0..2)
            .map(|_| Box::new(ReactivePredictor::default()) as Box<dyn Predictor + Send>)
            .collect()
    };

    // --- fleet DES side (virtual time, paper-scale profiles) ----------
    let mut sim_adapter = FleetAdapter::new(
        specs.clone(),
        profs.clone(),
        AccuracyMetric::Pas,
        BUDGET,
        AdapterConfig { interval: 10_000.0, apply_delay: 8.0, max_replicas: 4 },
        predictors(),
    )
    .unwrap();
    let fm_sim = run_fleet_des(
        &profs,
        &slas,
        10_000.0,
        8.0,
        SimConfig { seed, service_noise: 0.0, drop_enabled: true, ..Default::default() },
        &mut sim_adapter,
        &traces,
        "fleet-sim",
        BUDGET,
    );

    // --- live fleet side (threaded wall clock, scaled profiles) -------
    let cfg = ServeConfig {
        artifact_dir: String::new(),
        executors: 0,
        max_workers: 4,
        interval: 10_000.0,
        apply_delay: 8.0 * SCALE,
        use_lstm: false,
        profile_batches: vec![],
        profile_reps: 0,
        sla_floor: 0.0,
        legacy_lock: false,
    };
    let scaled: Vec<PipelineProfiles> = profs.iter().map(|p| p.scaled(SCALE)).collect();
    let executors: Vec<Arc<dyn BatchExecutor>> = scaled
        .iter()
        .map(|p| Arc::new(SyntheticExecutor::from_profiles(p, 1.0)) as Arc<dyn BatchExecutor>)
        .collect();
    let rep = serve_fleet_with(
        &specs,
        scaled,
        AccuracyMetric::Pas,
        BUDGET,
        "fleet-live",
        &cfg,
        LoadGenConfig { time_scale: SCALE, seed },
        &traces,
        executors,
        predictors(),
        FleetTuning::default(),
    )
    .expect("live fleet engine");

    assert_eq!(rep.members.len(), 2);
    assert!(rep.peak_in_use <= BUDGET, "no reconfigs, so no overshoot either");
    assert_eq!(rep.pool.resizes, 0, "default tuning never resizes the pool");
    assert_eq!(rep.pool.preemptions, 0, "default tuning never preempts");
    assert_eq!((rep.pool.pool_min, rep.pool.pool_max), (BUDGET, BUDGET));
    for m in 0..2 {
        let s = &fm_sim.members[m];
        let l = &rep.members[m].metrics;
        assert!(s.requests.len() > 40, "member {m}: thin trace ({})", s.requests.len());
        assert_eq!(
            s.requests.len(),
            l.requests.len(),
            "member {m}: arrival counts diverge"
        );
        assert_eq!(
            s.completed_count(),
            l.completed_count(),
            "member {m}: completion counts diverge (sim {} vs live {})",
            s.completed_count(),
            l.completed_count()
        );
        assert_eq!(
            s.dropped_count(),
            l.dropped_count(),
            "member {m}: drop counts diverge (sim {} vs live {})",
            s.dropped_count(),
            l.dropped_count()
        );
        // the unique correct outcome for this calm scenario
        assert_eq!(s.completed_count(), s.requests.len(), "member {m}: sim completed all");
        assert_eq!(s.dropped_count(), 0, "member {m}: sim dropped nothing");
    }
}
