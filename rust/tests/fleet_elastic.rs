//! Elastic fleet acceptance pins (ISSUE 3):
//!
//! (a) the pool never exceeds the autoscaler's cost-derived cap and
//!     never drops below the fleet's min-feasible floor — both as a
//!     random-walk property on the bare policy and end-to-end through
//!     the DES driver (grow run and shrink run);
//! (b) a burst on a high-priority member triggers preemption only from
//!     strictly lower-priority members, conserves the pool, and the
//!     joint budget safety gate (`FleetCore::apply`) accepts the
//!     post-preemption configuration;
//! (c) incremental re-solves are cache-busting equivalent: when every
//!     member's λ moved past the threshold, the incremental adapter's
//!     decisions are identical to an always-full-solve adapter's; when
//!     only a subset moved, shares stay pinned and only moved members
//!     re-solve;
//! (d) sim/live parity holds with the whole elastic control plane
//!     enabled on both drivers (calm load — the plumbing must not
//!     perturb the counts), plus a live-engine smoke run with real
//!     ticks asserting the pool-bounds invariant on a wall clock.

// The old fleet entry-point names (run_fleet_des* / serve_fleet_*)
// are exercised on purpose until their deprecation window closes.
#![allow(deprecated)]

use std::sync::Arc;

use ipa::cluster::drop_policy::DropPolicy;
use ipa::coordinator::adapter::AdapterConfig;
use ipa::fleet::autoscaler::{Autoscaler, AutoscalerConfig};
use ipa::fleet::core::{FleetCore, PoolReport};
use ipa::fleet::solver::{FleetAdapter, FleetTuning, PreemptionConfig};
use ipa::fleet::spec::FleetSpec;
use ipa::models::accuracy::AccuracyMetric;
use ipa::models::pipelines::{self, PipelineSpec};
use ipa::optimizer::ip::PipelineConfig;
use ipa::predictor::{Predictor, ReactivePredictor};
use ipa::profiler::analytic::pipeline_profiles;
use ipa::profiler::profile::PipelineProfiles;
use ipa::serving::engine::{serve_fleet_with, BatchExecutor, ServeConfig, SyntheticExecutor};
use ipa::serving::loadgen::LoadGenConfig;
use ipa::simulator::sim::{run_fleet_des, FleetRunMetrics, SimConfig};
use ipa::util::quickcheck::{check, prop_assert};
use ipa::workload::trace::Trace;

fn predictors(n: usize) -> Vec<Box<dyn Predictor + Send>> {
    (0..n)
        .map(|_| Box::new(ReactivePredictor::default()) as Box<dyn Predictor + Send>)
        .collect()
}

fn demo_parts() -> (Vec<PipelineSpec>, Vec<PipelineProfiles>, Vec<f64>) {
    let fleet = FleetSpec::demo3();
    let specs = fleet.specs().unwrap();
    let profs: Vec<PipelineProfiles> = specs.iter().map(pipeline_profiles).collect();
    let slas: Vec<f64> = specs.iter().map(|s| s.sla_e2e()).collect();
    (specs, profs, slas)
}

fn adapter_with(budget: u32, tuning: FleetTuning) -> FleetAdapter {
    let (specs, profs, _) = demo_parts();
    let n = specs.len();
    FleetAdapter::new(
        specs,
        profs,
        AccuracyMetric::Pas,
        budget,
        AdapterConfig::default(),
        predictors(n),
    )
    .and_then(|a| a.with_tuning(tuning))
    .unwrap()
}

fn run_elastic_des(budget: u32, tuning: FleetTuning, seconds: usize, seed: u64) -> FleetRunMetrics {
    let (_, profs, slas) = demo_parts();
    let mut adapter = adapter_with(budget, tuning);
    let traces = FleetSpec::demo3().traces(seconds);
    run_fleet_des(
        &profs,
        &slas,
        10.0,
        8.0,
        SimConfig { seed, ..Default::default() },
        &mut adapter,
        &traces,
        "fleet-elastic",
        budget,
    )
}

// ---------------------------------------------------------------------------
// (a) pool bounds
// ---------------------------------------------------------------------------

/// Property: from any start inside [floor, cap], a random demand walk
/// never pushes the autoscaler's target outside [max(floor, min_pool),
/// cost cap].
#[test]
fn prop_autoscaler_walk_stays_within_bounds() {
    check("autoscaler target bounds", 50, |g| {
        let cfg = AutoscalerConfig {
            cost_per_replica: 1.0,
            cost_target: g.f64(8.0, 40.0),
            min_pool: g.usize(0, 4) as u32,
            max_step_up: g.usize(1, 8) as u32,
            max_step_down: g.usize(1, 4) as u32,
            headroom: g.f64(1.0, 1.6),
            shrink_after: g.usize(1, 4) as u32,
        };
        let mut a = Autoscaler::new(cfg);
        let floor = g.usize(2, 10) as u32;
        let lo = floor.max(cfg.min_pool);
        let cap = a.max_pool().max(lo);
        let mut pool = (g.usize(lo as usize, cap as usize + 1)) as u32;
        for _ in 0..40 {
            let demand = g.usize(0, 60) as u32;
            let d = a.decide(pool, demand, floor);
            prop_assert(d.target >= lo, "target below the min-feasible floor")?;
            prop_assert(d.target <= cap, "target above the cost cap")?;
            pool = d.target;
        }
        Ok(())
    });
}

/// DES grow run: start the pool AT the fleet stage floor with a cap
/// well above it — padded demand always exceeds the floor, so the
/// autoscaler must grow, and the whole run must respect floor/cap.
#[test]
fn des_autoscaler_grows_within_cap() {
    let floor = 2 + 2 + 3; // demo3 stage floor
    let tuning = FleetTuning {
        priorities: None,
        autoscaler: Some(AutoscalerConfig {
            cost_per_replica: 1.0,
            cost_target: 28.0,
            min_pool: 0,
            max_step_up: 4,
            max_step_down: 2,
            headroom: 1.25,
            shrink_after: 3,
        }),
        preemption: None,
        resolve_threshold: 0.0,
        ..Default::default()
    };
    let fm = run_elastic_des(floor as u32, tuning, 200, 11);
    assert!(fm.pool.resizes >= 1, "padded demand over the floor must grow the pool");
    assert!(fm.pool.pool_max > floor as u32, "pool never grew: {:?}", fm.pool);
    assert!(fm.pool.pool_max <= 28, "pool exceeded the cost cap: {:?}", fm.pool);
    assert!(fm.pool.pool_min >= floor as u32, "pool fell below the floor: {:?}", fm.pool);
    assert!(fm.budget >= floor as u32 && fm.budget <= 28);
    assert!(
        fm.pool.bought_replica_secs >= fm.pool.used_replica_secs,
        "cannot use more replica-seconds than were bought"
    );
    assert!(fm.total_completed() > 0);
}

/// DES shrink run: start the pool far above a low cost cap under quiet
/// traffic — the autoscaler must walk it down (staged shrinks through
/// the joint apply), never below the floor.
#[test]
fn des_autoscaler_shrinks_toward_cost_target() {
    let floor = 7u32;
    let tuning = FleetTuning {
        priorities: None,
        autoscaler: Some(AutoscalerConfig {
            cost_per_replica: 1.0,
            cost_target: 12.0,
            min_pool: 0,
            max_step_up: 4,
            max_step_down: 4,
            headroom: 1.1,
            shrink_after: 1,
        }),
        preemption: None,
        resolve_threshold: 0.0,
        ..Default::default()
    };
    let fm = run_elastic_des(24, tuning, 200, 13);
    assert!(fm.budget < 24, "pool never shrank: {:?}", fm.pool);
    assert!(fm.pool.pool_min >= floor, "pool fell below the floor: {:?}", fm.pool);
    assert!(fm.pool.pool_max <= 24, "shrink run must never grow past the start");
    assert!(fm.pool.resizes >= 1);
    // the cost ledger reflects the shrink: average bought rate below
    // the starting pool size
    let horizon = 200.0;
    assert!(fm.pool.bought_replica_secs < 24.0 * horizon);
}

/// Regression: apply-delay LONGER than the adaptation interval.  Ticks
/// then stage reconfigurations faster than they activate, so stages
/// come due together (pop_due coalescing) and a shrink staged before a
/// later re-grow can go stale — the drivers must skip it rather than
/// take the pool below the budget later decisions were solved under
/// (the driver's internal `expect`s are the assertion; this run
/// panicked before the stale-shrink guard existed).
#[test]
fn des_survives_apply_delay_longer_than_interval() {
    let floor = 7u32;
    let tuning = FleetTuning {
        priorities: Some(vec![2, 1, 0]),
        autoscaler: Some(AutoscalerConfig {
            cost_per_replica: 1.0,
            cost_target: 20.0,
            min_pool: 0,
            max_step_up: 6,
            max_step_down: 6,
            headroom: 1.25,
            shrink_after: 1,
        }),
        preemption: Some(PreemptionConfig { burst_factor: 1.3, max_reclaim: 4 }),
        resolve_threshold: 0.15,
        ..Default::default()
    };
    let (_, profs, slas) = demo_parts();
    let mut adapter = adapter_with(16, tuning);
    let traces = FleetSpec::demo3().traces(240);
    let fm = run_fleet_des(
        &profs,
        &slas,
        10.0,
        25.0, // apply delay ≫ interval: stages pile up and go stale
        SimConfig { seed: 19, ..Default::default() },
        &mut adapter,
        &traces,
        "fleet-slow-apply",
        16,
    );
    assert!(fm.pool.pool_min >= floor, "pool fell below the floor: {:?}", fm.pool);
    assert!(fm.pool.pool_max <= 20, "pool exceeded the cost cap: {:?}", fm.pool);
    assert!(fm.total_completed() > 0);
}

// ---------------------------------------------------------------------------
// (b) preemption
// ---------------------------------------------------------------------------

/// Unit-level preemption pins across a grid of budgets and burst
/// magnitudes: whenever the fast path fires, the receiver is the
/// high-priority member, every donor is strictly lower priority, the
/// pool is conserved, and `FleetCore::apply` accepts the result.
#[test]
fn preemption_reclaims_only_from_lower_priority_and_stays_budget_safe() {
    let (_, _, slas) = demo_parts();
    let mut fired = 0usize;
    for budget in [9u32, 10, 12, 14] {
        for burst in [15.0, 25.0, 35.0, 45.0] {
            let mut ad = adapter_with(
                budget,
                FleetTuning {
                    priorities: Some(vec![2, 1, 0]),
                    autoscaler: None,
                    preemption: Some(PreemptionConfig { burst_factor: 1.5, max_reclaim: 4 }),
                    resolve_threshold: 0.0,
                    ..Default::default()
                },
            );
            // prime the cache at calm per-member load
            let calm = ad.decide_for_lambdas(&[4.0, 4.0, 4.0]);
            let shares_before: Vec<u32> =
                calm.iter().map(|d| d.config.total_replicas()).collect();
            // build the fleet core on the calm allocation
            let inits: Vec<(PipelineConfig, f64, DropPolicy)> = calm
                .iter()
                .zip(&slas)
                .map(|(d, &sla)| (d.config.clone(), d.lambda_predicted, DropPolicy::new(sla, true)))
                .collect();
            let mut core = FleetCore::new(budget, &inits).unwrap();

            let Some(p) = ad.preempt(5.0, &[burst, 4.0, 4.0]) else { continue };
            fired += 1;
            assert_eq!(p.to, 0, "only the high-priority member bursts here");
            assert!(p.reclaimed >= 1);
            assert!(!p.from.is_empty());
            for &(donor, k) in &p.from {
                assert!(donor != 0, "the burster cannot donate to itself");
                assert!(k >= 1);
            }
            // pool conservation: replicas moved, not created
            let used_after: u32 =
                p.decisions.iter().map(|d| d.config.total_replicas()).sum();
            assert!(used_after <= budget, "preemption violated the budget");
            // the burster gained, donors shrank (weak monotone checks
            // against the pre-preemption configs)
            assert!(
                p.decisions[0].config.total_replicas() >= shares_before[0],
                "burster must not lose replicas"
            );
            // the joint budget gate accepts the fast-path configuration
            let configs: Vec<(PipelineConfig, f64)> = p
                .decisions
                .iter()
                .map(|d| (d.config.clone(), d.lambda_predicted))
                .collect();
            core.apply(&configs).expect("FleetCore::apply must accept the preemption");
        }
    }
    assert!(fired >= 1, "grid never triggered a preemption — pins unexercised");
}

/// No strictly-lower-priority member ⇒ no preemption: a burst on the
/// lowest class (or under all-equal priorities) must return None.
#[test]
fn preemption_never_fires_without_lower_priority_donors() {
    // burst on the lowest-priority member
    let mut ad = adapter_with(
        12,
        FleetTuning {
            priorities: Some(vec![2, 1, 0]),
            autoscaler: None,
            preemption: Some(PreemptionConfig::default()),
            resolve_threshold: 0.0,
            ..Default::default()
        },
    );
    ad.decide_for_lambdas(&[4.0, 4.0, 4.0]);
    assert!(ad.preempt(5.0, &[4.0, 4.0, 60.0]).is_none());
    // all-equal priorities: nobody outranks anybody
    let mut eq = adapter_with(
        12,
        FleetTuning {
            priorities: None,
            autoscaler: None,
            preemption: Some(PreemptionConfig::default()),
            resolve_threshold: 0.0,
            ..Default::default()
        },
    );
    eq.decide_for_lambdas(&[4.0, 4.0, 4.0]);
    assert!(eq.preempt(5.0, &[60.0, 4.0, 4.0]).is_none());
}

/// DES-level: with the demo priorities [2,1,0] the top member can only
/// ever RECEIVE replicas — its preempted counter must stay zero while
/// the run stays budget-safe end to end (the driver's internal
/// `expect`s double as the safety assertion).
#[test]
fn des_preemption_respects_priority_order() {
    let tuning = FleetTuning {
        priorities: Some(vec![2, 1, 0]),
        autoscaler: None,
        preemption: Some(PreemptionConfig { burst_factor: 1.3, max_reclaim: 4 }),
        resolve_threshold: 0.0,
        ..Default::default()
    };
    let fm = run_elastic_des(14, tuning, 240, 17);
    assert_eq!(
        fm.pool.preempted[0], 0,
        "the highest-priority member can never be a donor"
    );
    assert_eq!(fm.pool.preempted.iter().sum::<u32>() > 0, fm.pool.preemptions > 0);
    assert!(fm.total_completed() > 0);
}

// ---------------------------------------------------------------------------
// (c) incremental re-solves
// ---------------------------------------------------------------------------

/// Cache-busting equivalence: when EVERY member's λ moves past the
/// threshold, the incremental adapter must fall back to the full joint
/// solve and its decisions must match an always-full-solve adapter's
/// exactly, tick for tick.
#[test]
fn incremental_equals_full_solve_when_all_lambdas_move() {
    let mk = |threshold: f64| {
        adapter_with(
            16,
            FleetTuning {
                priorities: None,
                autoscaler: None,
                preemption: None,
                resolve_threshold: threshold,
                ..Default::default()
            },
        )
    };
    let mut inc = mk(0.2);
    let mut full = mk(0.0);
    // every step moves every member by far more than 20%
    let steps: [[f64; 3]; 4] =
        [[6.0, 6.0, 6.0], [12.0, 10.0, 3.0], [20.0, 5.0, 14.0], [7.0, 16.0, 6.0]];
    for (t, lambdas) in steps.iter().enumerate() {
        let a = inc.decide_for_lambdas(lambdas);
        let b = full.decide_for_lambdas(lambdas);
        for (m, (da, db)) in a.iter().zip(&b).enumerate() {
            assert_eq!(
                da.config, db.config,
                "tick {t} member {m}: incremental diverged from full solve"
            );
            assert_eq!(da.fallback, db.fallback, "tick {t} member {m}");
        }
    }
    assert_eq!(inc.incremental_solves, 0, "all-moved ticks must run the full solve");
    assert_eq!(inc.full_solves, full.full_solves);
}

/// Subset moves: when one member's λ moves and the others hold, only
/// that member re-solves — shares stay pinned, unmoved members keep
/// their configurations byte for byte, and the budget still holds.
#[test]
fn incremental_resolves_only_moved_members() {
    let mut ad = adapter_with(
        16,
        FleetTuning {
            priorities: None,
            autoscaler: None,
            preemption: None,
            resolve_threshold: 0.2,
            ..Default::default()
        },
    );
    let first = ad.decide_for_lambdas(&[6.0, 6.0, 6.0]);
    assert_eq!(ad.full_solves, 1);
    // member 2 doubles; members 0/1 hold exactly
    let second = ad.decide_for_lambdas(&[6.0, 6.0, 12.0]);
    assert_eq!(ad.incremental_solves, 1, "subset move must take the incremental path");
    assert_eq!(ad.full_solves, 1);
    for m in 0..2 {
        assert_eq!(
            first[m].config, second[m].config,
            "member {m} did not move but its config changed"
        );
    }
    let used: u32 = second.iter().map(|d| d.config.total_replicas()).sum();
    assert!(used <= 16, "incremental path violated the budget");
}

// ---------------------------------------------------------------------------
// (d) sim/live parity with the elastic plane enabled
// ---------------------------------------------------------------------------

/// The fleet parity scenario of `tests/fleet.rs`, with the FULL elastic
/// tuning switched on in both drivers.  Under calm constant load with
/// no adaptation ticks the elastic plumbing must stay quiescent — the
/// per-member counts still match exactly and nothing resizes or
/// preempts on either clock.
#[test]
fn elastic_sim_and_live_engine_agree_on_counts() {
    const SCALE: f64 = 0.05;
    const BUDGET: u32 = 16;
    let seed = 23u64;
    let specs: Vec<PipelineSpec> = ["video", "video"]
        .iter()
        .map(|n| {
            let mut s = pipelines::by_name(n).unwrap();
            s.weights.beta *= 50.0;
            s
        })
        .collect();
    let profs: Vec<PipelineProfiles> = specs.iter().map(pipeline_profiles).collect();
    let slas: Vec<f64> = specs.iter().map(|s| s.sla_e2e()).collect();
    let mut rates = vec![1.0; 80];
    rates.extend(vec![0.0; 30]);
    let traces =
        vec![Trace::new("elastic-parity-a", rates.clone()), Trace::new("elastic-parity-b", rates)];
    let tuning = || FleetTuning {
        priorities: Some(vec![1, 0]),
        autoscaler: Some(AutoscalerConfig {
            cost_per_replica: 1.0,
            cost_target: 20.0,
            ..Default::default()
        }),
        preemption: Some(PreemptionConfig::default()),
        resolve_threshold: 0.15,
        ..Default::default()
    };
    let predictors2 = || predictors(2);

    let mut sim_adapter = FleetAdapter::new(
        specs.clone(),
        profs.clone(),
        AccuracyMetric::Pas,
        BUDGET,
        AdapterConfig { interval: 10_000.0, apply_delay: 8.0, max_replicas: 4 },
        predictors2(),
    )
    .and_then(|a| a.with_tuning(tuning()))
    .unwrap();
    let fm_sim = run_fleet_des(
        &profs,
        &slas,
        10_000.0,
        8.0,
        SimConfig { seed, service_noise: 0.0, drop_enabled: true, ..Default::default() },
        &mut sim_adapter,
        &traces,
        "elastic-sim",
        BUDGET,
    );

    let cfg = ServeConfig {
        artifact_dir: String::new(),
        executors: 0,
        max_workers: 4,
        interval: 10_000.0,
        apply_delay: 8.0 * SCALE,
        use_lstm: false,
        profile_batches: vec![],
        profile_reps: 0,
        sla_floor: 0.0,
        legacy_lock: false,
    };
    let scaled: Vec<PipelineProfiles> = profs.iter().map(|p| p.scaled(SCALE)).collect();
    let executors: Vec<Arc<dyn BatchExecutor>> = scaled
        .iter()
        .map(|p| Arc::new(SyntheticExecutor::from_profiles(p, 1.0)) as Arc<dyn BatchExecutor>)
        .collect();
    let rep = serve_fleet_with(
        &specs,
        scaled,
        AccuracyMetric::Pas,
        BUDGET,
        "elastic-live",
        &cfg,
        LoadGenConfig { time_scale: SCALE, seed },
        &traces,
        executors,
        predictors2(),
        tuning(),
    )
    .expect("live elastic fleet engine");

    for pool in [&fm_sim.pool, &rep.pool] {
        assert_eq!(pool.resizes, 0, "no ticks fired, so nothing may resize");
        assert_eq!(pool.preemptions, 0, "calm load must never preempt");
    }
    for m in 0..2 {
        let s = &fm_sim.members[m];
        let l = &rep.members[m].metrics;
        assert!(s.requests.len() > 40, "member {m}: thin trace");
        assert_eq!(s.requests.len(), l.requests.len(), "member {m}: arrivals diverge");
        assert_eq!(
            s.completed_count(),
            l.completed_count(),
            "member {m}: completions diverge (sim {} vs live {})",
            s.completed_count(),
            l.completed_count()
        );
        assert_eq!(s.dropped_count(), l.dropped_count(), "member {m}: drops diverge");
        assert_eq!(s.completed_count(), s.requests.len(), "member {m}: all complete");
        assert_eq!(s.dropped_count(), 0, "member {m}: nothing drops");
    }
}

/// Live-engine elastic smoke: real wall-clock ticks with the autoscaler
/// and preemption enabled.  Wall-clock decision times are not
/// deterministic, so this pins the invariants, not the counts: the
/// pool stays within [floor, cap] and every request is accounted for.
#[test]
fn live_engine_elastic_pool_stays_within_bounds() {
    const SCALE: f64 = 0.05;
    let (specs, profs, _) = demo_parts();
    let floor = 7u32;
    let tuning = FleetTuning {
        priorities: Some(vec![2, 1, 0]),
        autoscaler: Some(AutoscalerConfig {
            cost_per_replica: 1.0,
            cost_target: 28.0,
            min_pool: 0,
            max_step_up: 4,
            max_step_down: 2,
            headroom: 1.25,
            shrink_after: 2,
        }),
        preemption: Some(PreemptionConfig::default()),
        resolve_threshold: 0.15,
        ..Default::default()
    };
    let cfg = ServeConfig {
        artifact_dir: String::new(),
        executors: 0,
        max_workers: 6,
        interval: 1.0,
        apply_delay: 0.2,
        use_lstm: false,
        profile_batches: vec![],
        profile_reps: 0,
        sla_floor: 0.0,
        legacy_lock: false,
    };
    let traces = FleetSpec::demo3().traces(60);
    let scaled: Vec<PipelineProfiles> = profs.iter().map(|p| p.scaled(SCALE)).collect();
    let executors: Vec<Arc<dyn BatchExecutor>> = scaled
        .iter()
        .map(|p| Arc::new(SyntheticExecutor::from_profiles(p, 1.0)) as Arc<dyn BatchExecutor>)
        .collect();
    let rep = serve_fleet_with(
        &specs,
        scaled,
        AccuracyMetric::Pas,
        floor,
        "elastic-live-smoke",
        &cfg,
        LoadGenConfig { time_scale: SCALE, seed: 7 },
        &traces,
        executors,
        predictors(3),
        tuning,
    )
    .expect("live elastic engine");
    let pool: &PoolReport = &rep.pool;
    assert!(pool.pool_min >= floor, "pool fell below the floor: {pool:?}");
    assert!(pool.pool_max <= 28, "pool exceeded the cost cap: {pool:?}");
    assert!(pool.bought_replica_secs >= pool.used_replica_secs - 1e-9);
    let total: usize = rep.members.iter().map(|r| r.metrics.requests.len()).sum();
    assert!(total > 100, "load generator barely ran ({total} requests)");
}
