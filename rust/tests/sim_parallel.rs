//! Epoch-parallel fleet DES determinism suite: the worker count may
//! only change HOW an epoch is computed, never WHAT it computes.
//!
//! Pinned contract (per ISSUE 9): at 1, 2 and 8 workers — and on the
//! sequential-epochs and legacy-clock paths — a fleet DES run produces
//! byte-identical per-request outcomes and latencies, `FleetRunMetrics`
//! (pool report, peak occupancy, final replicas), merged latency
//! histograms, control-plane journals and span dumps.  Zone faults
//! landing mid-run must barrier identically too.

// The old fleet entry-point names (run_fleet_des* / serve_fleet_*)
// are exercised on purpose until their deprecation window closes.
#![allow(deprecated)]

use ipa::coordinator::adapter::AdapterConfig;
use ipa::fleet::nodes::NodeInventory;
use ipa::fleet::solver::{FleetAdapter, FleetTuning};
use ipa::fleet::spec::FleetSpec;
use ipa::models::accuracy::AccuracyMetric;
use ipa::predictor::{Predictor, ReactivePredictor};
use ipa::profiler::analytic::pipeline_profiles;
use ipa::profiler::profile::PipelineProfiles;
use ipa::simulator::sim::{
    run_fleet_des_faults, run_fleet_des_faults_traced, run_fleet_des_traced, FleetRunMetrics,
    SimConfig, ZoneFault,
};
use ipa::telemetry::{spans_to_jsonl, Telemetry, TelemetryConfig};
use ipa::workload::trace::Trace;
use ipa::workload::tracegen::Pattern;

fn predictors(n: usize) -> Vec<Box<dyn Predictor + Send>> {
    (0..n)
        .map(|_| Box::new(ReactivePredictor::default()) as Box<dyn Predictor + Send>)
        .collect()
}

/// 8-member fleet (demo3 cycled) through the traced fleet DES at an
/// explicit `SimConfig` — the thread-count lever under test.
fn fleet8_run(sim: SimConfig, tel: &Telemetry) -> FleetRunMetrics {
    const BUDGET: u32 = 64;
    let fleet = FleetSpec::demo3();
    let base_specs = fleet.specs().unwrap();
    let base_profs: Vec<PipelineProfiles> = base_specs.iter().map(pipeline_profiles).collect();
    let base_slas: Vec<f64> = base_specs.iter().map(|s| s.sla_e2e()).collect();
    let base_traces: Vec<Trace> = fleet.traces(90);
    let n = 8usize;
    let specs: Vec<_> = (0..n).map(|i| base_specs[i % 3].clone()).collect();
    let profs: Vec<PipelineProfiles> = (0..n).map(|i| base_profs[i % 3].clone()).collect();
    let slas: Vec<f64> = (0..n).map(|i| base_slas[i % 3]).collect();
    let traces: Vec<Trace> = (0..n).map(|i| base_traces[i % 3].clone()).collect();
    let mut adapter = FleetAdapter::new(
        specs,
        profs.clone(),
        AccuracyMetric::Pas,
        BUDGET,
        AdapterConfig { interval: 30.0, apply_delay: 8.0, max_replicas: 4 },
        predictors(n),
    )
    .unwrap();
    run_fleet_des_traced(
        &profs,
        &slas,
        30.0,
        8.0,
        sim,
        &mut adapter,
        &traces,
        "sim-parallel",
        BUDGET,
        tel,
    )
}

/// Every fleet-visible output must match between two runs.
fn assert_runs_identical(a: &FleetRunMetrics, b: &FleetRunMetrics, what: &str) {
    assert_eq!(a.members.len(), b.members.len(), "{what}: member count");
    for (m, (am, bm)) in a.members.iter().zip(&b.members).enumerate() {
        // per-request outcomes carry the latencies — byte-identical
        assert_eq!(am.requests, bm.requests, "{what}: member {m} per-request outcomes");
        assert_eq!(am.completed_count(), bm.completed_count(), "{what}: member {m}");
        assert_eq!(am.dropped_count(), bm.dropped_count(), "{what}: member {m}");
    }
    assert_eq!(a.budget, b.budget, "{what}: final budget");
    assert_eq!(a.peak_in_use, b.peak_in_use, "{what}: peak occupancy");
    assert_eq!(a.final_replicas, b.final_replicas, "{what}: final replicas");
    assert_eq!(a.pool, b.pool, "{what}: pool report");
    assert_eq!(
        a.zone_fault_min_survivors, b.zone_fault_min_survivors,
        "{what}: fault survivors"
    );
    assert_eq!(
        a.merged_latency_histogram(),
        b.merged_latency_histogram(),
        "{what}: merged latency histogram"
    );
}

/// The tentpole contract on the plain driver: 1, 2 and 8 epoch workers,
/// the sequential-epochs lever and the legacy single-heap clock all
/// produce the same run, down to per-request latencies and the merged
/// fleet histogram.
#[test]
fn fleet_des_is_byte_identical_at_any_thread_count() {
    let anchor = fleet8_run(SimConfig { sim_threads: 1, ..Default::default() }, &Telemetry::off());
    let total: usize = anchor.members.iter().map(|m| m.requests.len()).sum();
    assert!(total > 300, "thin run ({total} requests) proves nothing");
    for threads in [2usize, 8] {
        let run = fleet8_run(
            SimConfig { sim_threads: threads, ..Default::default() },
            &Telemetry::off(),
        );
        assert_runs_identical(&anchor, &run, &format!("{threads} threads"));
    }
    let seq = fleet8_run(
        SimConfig { sequential_epochs: true, ..Default::default() },
        &Telemetry::off(),
    );
    assert_runs_identical(&anchor, &seq, "sequential_epochs");
    let legacy =
        fleet8_run(SimConfig { legacy_clock: true, ..Default::default() }, &Telemetry::off());
    assert_runs_identical(&anchor, &legacy, "legacy_clock");
}

/// The traced contract: journals and span dumps — flushed only at
/// sequential barriers — are byte-identical at any worker count, and a
/// deterministic producer never drops spans.
#[test]
fn traced_journals_and_spans_are_byte_identical_across_thread_counts() {
    let runs: Vec<(Telemetry, FleetRunMetrics)> = [1usize, 2, 8]
        .into_iter()
        .map(|threads| {
            let tel = Telemetry::new(TelemetryConfig::full(), 8);
            let fm = fleet8_run(SimConfig { sim_threads: threads, ..Default::default() }, &tel);
            (tel, fm)
        })
        .collect();
    let (tel1, fm1) = &runs[0];
    assert_eq!(tel1.dropped_spans(), 0, "deterministic runs never drop spans");
    let journal1 = tel1.journal().to_jsonl();
    let spans1 = spans_to_jsonl(&tel1.take_spans());
    assert!(!journal1.is_empty() && !spans1.is_empty());
    for (tel, fm) in &runs[1..] {
        assert_eq!(tel.dropped_spans(), 0);
        assert_eq!(journal1, tel.journal().to_jsonl(), "journal not byte-stable");
        assert_eq!(spans1, spans_to_jsonl(&tel.take_spans()), "spans not byte-stable");
        assert_runs_identical(fm1, fm, "traced");
    }
}

/// Zone-fault fixture: a spread member on a two-zone pool with a
/// mid-run `kill_zone` — the fault is a global event, so it lands at a
/// barrier and the emergency repack must be identical at any count.
fn fault_run(sim: SimConfig) -> FleetRunMetrics {
    let mut fleet = FleetSpec::demo3();
    fleet.members.truncate(2);
    fleet.members[0].spread = true;
    fleet.members[0].pattern = Pattern::SteadyLow;
    fleet.members[1].pattern = Pattern::Bursty;
    let inv = NodeInventory::parse("3x(8c,32g,0a)@east+3x(8c,32g,0a)@west").unwrap();
    fleet.nodes = Some(inv.clone());
    fleet.validate().unwrap();
    let specs = fleet.specs().unwrap();
    let profs: Vec<_> = specs.iter().map(pipeline_profiles).collect();
    let slas: Vec<f64> = specs.iter().map(|s| s.sla_e2e()).collect();
    let mut adapter = FleetAdapter::new(
        specs,
        profs.clone(),
        AccuracyMetric::Pas,
        inv.replica_cap(),
        AdapterConfig::default(),
        predictors(2),
    )
    .and_then(|a| {
        a.with_tuning(FleetTuning {
            nodes: Some(inv.clone()),
            spread: Some(fleet.spreads()),
            migration_delay: 0.5,
            ..Default::default()
        })
    })
    .unwrap();
    let traces = fleet.traces(180);
    let faults = [ZoneFault { at: 75.0, zone: "west".into() }];
    run_fleet_des_faults(
        &profs,
        &slas,
        10.0,
        8.0,
        sim,
        &mut adapter,
        &traces,
        "sim-parallel-fault",
        0,
        &faults,
    )
}

/// A mid-run zone kill replays identically at 1/2/8 workers and on the
/// legacy clock: same survivor snapshot, same emergency repack, same
/// per-request outcomes after the loss.
#[test]
fn zone_fault_lands_at_a_barrier_identically_at_any_thread_count() {
    let anchor = fault_run(SimConfig { seed: 11, sim_threads: 1, ..Default::default() });
    assert_eq!(anchor.pool.zone_kills, 1, "the scripted fault fired");
    assert_eq!(anchor.zone_fault_min_survivors.len(), 1);
    for threads in [2usize, 8] {
        let run = fault_run(SimConfig { seed: 11, sim_threads: threads, ..Default::default() });
        assert_runs_identical(&anchor, &run, &format!("fault at {threads} threads"));
    }
    let legacy = fault_run(SimConfig { seed: 11, legacy_clock: true, ..Default::default() });
    assert_runs_identical(&anchor, &legacy, "fault on legacy clock");
}

/// The traced fault path too: journals (which record the emergency
/// decision) byte-stable across worker counts.
#[test]
fn traced_fault_journals_are_byte_identical_across_thread_counts() {
    let mut journals = Vec::new();
    for threads in [1usize, 4] {
        let mut fleet = FleetSpec::demo3();
        fleet.members.truncate(2);
        fleet.members[0].spread = true;
        let inv = NodeInventory::parse("3x(8c,32g,0a)@east+3x(8c,32g,0a)@west").unwrap();
        fleet.nodes = Some(inv.clone());
        fleet.validate().unwrap();
        let specs = fleet.specs().unwrap();
        let profs: Vec<_> = specs.iter().map(pipeline_profiles).collect();
        let slas: Vec<f64> = specs.iter().map(|s| s.sla_e2e()).collect();
        let mut adapter = FleetAdapter::new(
            specs.clone(),
            profs.clone(),
            AccuracyMetric::Pas,
            inv.replica_cap(),
            AdapterConfig::default(),
            predictors(2),
        )
        .and_then(|a| {
            a.with_tuning(FleetTuning {
                nodes: Some(inv.clone()),
                spread: Some(fleet.spreads()),
                ..Default::default()
            })
        })
        .unwrap();
        let traces = fleet.traces(120);
        let faults = [ZoneFault { at: 45.0, zone: "east".into() }];
        let tel = Telemetry::new(TelemetryConfig::full(), 2);
        let _ = run_fleet_des_faults_traced(
            &profs,
            &slas,
            10.0,
            8.0,
            SimConfig { seed: 3, sim_threads: threads, ..Default::default() },
            &mut adapter,
            &traces,
            "sim-parallel-fault-traced",
            0,
            &faults,
            &tel,
        );
        assert_eq!(tel.dropped_spans(), 0);
        journals.push((tel.journal().to_jsonl(), spans_to_jsonl(&tel.take_spans())));
    }
    assert!(!journals[0].0.is_empty());
    assert_eq!(journals[0], journals[1], "traced fault run not byte-stable across workers");
}
