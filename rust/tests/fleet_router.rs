//! Fleet front door: the per-member router + admission gate, end to
//! end on both clocks.
//!
//! (a) property: `LeastLoaded` always lands an arrival on a replica
//!     whose in-flight count was minimal at pick time, across random
//!     route/complete interleavings and topology sizes;
//! (b) zone affinity: a spread member on a two-zone pool routes
//!     zone-local while both zones live, and starts paying cross-zone
//!     hops only after a mid-run `kill_zone` removes its local
//!     replicas;
//! (c) clock parity: the same routed fleet through the DES and the
//!     live engine produces identical per-member routed counts;
//! (d) determinism: a routed + admission-controlled DES run journals
//!     and completes byte-identically at any epoch worker count;
//! (e) admission: a 10× flash crowd is absorbed by degrading
//!     (brownouts in the journal, completions keep flowing), not by
//!     shedding.

use std::sync::Arc;

use ipa::coordinator::adapter::AdapterConfig;
use ipa::fleet::nodes::NodeInventory;
use ipa::fleet::router::{RoutePolicy, Router, RouterConfig};
use ipa::fleet::run::FleetRun;
use ipa::fleet::solver::{FleetAdapter, FleetTuning};
use ipa::fleet::spec::FleetSpec;
use ipa::models::accuracy::AccuracyMetric;
use ipa::predictor::{Predictor, ReactivePredictor};
use ipa::profiler::analytic::pipeline_profiles;
use ipa::profiler::profile::PipelineProfiles;
use ipa::queueing::Request;
use ipa::serving::engine::ServeConfig;
use ipa::serving::loadgen::LoadGenConfig;
use ipa::simulator::sim::{run_fleet, FleetDesParams, SimConfig, ZoneFault};
use ipa::telemetry::{Telemetry, TelemetryConfig};
use ipa::util::quickcheck::{check, prop_assert};
use ipa::workload::trace::Trace;
use ipa::workload::tracegen::Pattern;

fn predictors(n: usize) -> Vec<Box<dyn Predictor + Send>> {
    (0..n)
        .map(|_| Box::new(ReactivePredictor::default()) as Box<dyn Predictor + Send>)
        .collect()
}

fn req(id: u64) -> Request {
    Request { id, arrival: 0.0, stage_arrival: 0.0 }
}

// ---------------------------------------------------------------------------
// (a) LeastLoaded invariant
// ---------------------------------------------------------------------------

/// Property: whatever interleaving of arrivals and batch completions a
/// `LeastLoaded` router sees, every routed arrival lands on a replica
/// whose in-flight count was the minimum across all replicas at pick
/// time.
#[test]
fn prop_least_loaded_always_picks_a_min_inflight_replica() {
    check("least-loaded picks a min-inflight replica", 200, |g| {
        let n = g.usize(1, 6);
        let cfg = RouterConfig { policy: RoutePolicy::LeastLoaded, ..RouterConfig::default() };
        let mut r = Router::new(cfg, 1.0, Vec::new());
        r.set_topology(n, Vec::new(), 0.01);
        let mut outstanding: Vec<u64> = Vec::new();
        let ops = g.usize(1, 60);
        for id in 0..ops as u64 {
            if g.bool() || outstanding.is_empty() {
                let min = *r.inflight().iter().min().unwrap();
                match r.route(id, 0.0) {
                    ipa::fleet::router::RouteOutcome::Route { replica, .. } => {
                        prop_assert(
                            r.inflight()[replica] == min + 1,
                            "routed replica was not least loaded",
                        )?;
                        outstanding.push(id);
                    }
                    o => return Err(format!("admission off, got {o:?}")),
                }
            } else {
                // complete a random prefix of the outstanding requests
                let k = g.usize(1, outstanding.len() + 1);
                let batch: Vec<Request> = outstanding.drain(..k).map(req).collect();
                r.on_batch(&batch);
            }
        }
        prop_assert(
            r.inflight().iter().sum::<u32>() as usize == outstanding.len(),
            "in-flight total drifted from outstanding tags",
        )
    });
}

// ---------------------------------------------------------------------------
// (b) zone affinity through a mid-run zone kill
// ---------------------------------------------------------------------------

/// A spread member keeps ≥ 1 stage-0 replica per zone while both zones
/// live, so `ZoneLocalFirst` almost never crosses; after `kill_zone`
/// drains `west`, every west-origin arrival is forced across.
#[test]
fn zone_local_first_crosses_only_after_the_local_zone_dies() {
    let mut fleet = FleetSpec::demo3();
    fleet.members.truncate(2); // video-edge + audio-social
    fleet.members[0].spread = true;
    fleet.members[0].pattern = Pattern::SteadyLow;
    fleet.members[1].pattern = Pattern::SteadyLow;
    let inv = NodeInventory::parse("3x(8c,32g,0a)@east+3x(8c,32g,0a)@west").unwrap();
    fleet.nodes = Some(inv.clone());
    let tuning = FleetTuning {
        nodes: Some(inv),
        spread: Some(fleet.spreads()),
        ..Default::default()
    };
    let rc = RouterConfig { policy: RoutePolicy::ZoneLocalFirst, ..RouterConfig::default() };
    let run = FleetRun::new(fleet, tuning).seconds(180).router(rc);

    let calm = run.sim(SimConfig { seed: 11, ..Default::default() }).unwrap();
    let faulted = run
        .clone()
        .faults(vec![ZoneFault { at: 75.0, zone: "west".into() }])
        .sim(SimConfig { seed: 11, ..Default::default() })
        .unwrap();

    // both zones alive: the spread member's stage 0 spans east+west, so
    // a local replica (nearly) always exists — transient rolling
    // reconfigurations are the only slack allowed
    let calm_stats = &calm.metrics.router[0];
    assert!(calm_stats.total_routed() > 200, "thin trace: {}", calm_stats.total_routed());
    assert!(
        calm_stats.cross_zone * 20 <= calm_stats.total_routed(),
        "calm run crossed zones for {} of {} arrivals",
        calm_stats.cross_zone,
        calm_stats.total_routed()
    );

    // west dead from t=75: ~half of later arrivals originate in west
    // and MUST cross to the east survivors
    assert_eq!(faulted.metrics.pool.zone_kills, 1, "the scripted fault fired");
    let faulted_stats = &faulted.metrics.router[0];
    assert!(
        faulted_stats.cross_zone > 50,
        "post-kill west-origin arrivals should cross: {} crossings of {}",
        faulted_stats.cross_zone,
        faulted_stats.total_routed()
    );
    assert!(
        faulted_stats.cross_zone > calm_stats.cross_zone,
        "the outage must increase cross-zone traffic"
    );
    // the door stayed open throughout (routing only, no admission)
    assert_eq!(faulted_stats.shed, 0);
    assert!(faulted.metrics.members[0].completed_count() > 100);
}

// ---------------------------------------------------------------------------
// (c) DES ↔ live parity of routed counts
// ---------------------------------------------------------------------------

/// The same routed fleet spec through both clocks: per-member arrival
/// counts are identical by construction (same trace, same seed), and
/// with admission off the router must route every one of them —
/// identical routed totals, zero shed, on both clocks.
#[test]
fn routed_counts_agree_across_des_and_live() {
    let mut spec = FleetSpec::demo3();
    spec.seconds = 40;
    let rc = RouterConfig { policy: RoutePolicy::RoundRobin, ..RouterConfig::default() };
    let run = FleetRun::new(spec, FleetTuning::default()).router(rc);

    let des = run.sim(SimConfig { seed: 5, ..Default::default() }).unwrap();
    let cfg = ServeConfig {
        artifact_dir: String::new(),
        executors: 0,
        max_workers: 4,
        interval: 4.0,
        apply_delay: 0.5,
        use_lstm: false,
        profile_batches: vec![],
        profile_reps: 0,
        sla_floor: 0.0,
        legacy_lock: false,
    };
    let live = run.serve(&cfg, LoadGenConfig { time_scale: 0.02, seed: 5 }).unwrap();

    assert_eq!(des.metrics.members.len(), live.members.len());
    for m in 0..des.metrics.members.len() {
        let d = &des.metrics.router[m];
        let l = &live.router[m];
        let arrivals = des.metrics.members[m].requests.len();
        assert!(arrivals > 40, "member {m}: thin trace ({arrivals})");
        assert_eq!(
            arrivals,
            live.members[m].metrics.requests.len(),
            "member {m}: arrival counts diverge"
        );
        assert_eq!(
            d.total_routed() as usize, arrivals,
            "member {m}: DES router must route every arrival"
        );
        assert_eq!(
            d.total_routed(),
            l.total_routed(),
            "member {m}: routed counts diverge across clocks"
        );
        assert_eq!((d.shed, l.shed), (0, 0), "member {m}: admission is off");
    }
}

// ---------------------------------------------------------------------------
// (d) routed DES determinism at any worker count
// ---------------------------------------------------------------------------

/// A routed + admission-controlled + traced fleet DES run is
/// byte-identical at 1, 2 and 8 epoch workers: same per-request
/// outcomes, same router counters, same journal bytes.
#[test]
fn routed_des_run_is_byte_identical_at_any_worker_count() {
    let mut spec = FleetSpec::demo3();
    spec.seconds = 60;
    let rc = RouterConfig {
        policy: RoutePolicy::LeastLoaded,
        admission: true,
        ..RouterConfig::default()
    };
    let run_at = |threads: usize| {
        let tel = Arc::new(Telemetry::new(TelemetryConfig::default(), 3));
        let run = FleetRun::new(spec.clone(), FleetTuning::default())
            .router(rc.clone())
            .telemetry(Arc::clone(&tel));
        let out = run
            .sim(SimConfig { seed: 7, sim_threads: threads, ..Default::default() })
            .unwrap();
        (out, tel.journal().to_jsonl())
    };

    let (base, base_journal) = run_at(1);
    for threads in [2usize, 8] {
        let (other, journal) = run_at(threads);
        assert_eq!(
            base_journal, journal,
            "journal bytes diverge at {threads} workers"
        );
        for m in 0..3 {
            assert_eq!(
                base.metrics.members[m].requests, other.metrics.members[m].requests,
                "member {m}: per-request outcomes diverge at {threads} workers"
            );
            assert_eq!(
                base.metrics.router[m], other.metrics.router[m],
                "member {m}: router counters diverge at {threads} workers"
            );
        }
    }
    // the run actually exercised the door
    assert!(base.metrics.router.iter().map(|s| s.total_routed()).sum::<u64>() > 0);
}

// ---------------------------------------------------------------------------
// (e) flash crowd: degrade, don't drop
// ---------------------------------------------------------------------------

/// A 10× flash crowd against a brownout-first door (low admit
/// threshold, effectively-unreachable shed threshold): the router
/// degrades under pressure and sheds nothing, completions keep
/// flowing, and the journal records the brownouts.
#[test]
fn flash_crowd_degrades_but_never_sheds() {
    let spec = FleetSpec::demo3().members[0].spec().unwrap(); // video
    let profs: Vec<PipelineProfiles> = vec![pipeline_profiles(&spec)];
    let slas = vec![spec.sla_e2e()];
    let mut rates = vec![4.0; 30];
    rates.extend(vec![40.0; 30]); // 10× flash crowd
    rates.extend(vec![4.0; 20]);
    let traces = vec![Trace::new("video-flash", rates)];
    let mut adapter = FleetAdapter::new(
        vec![spec],
        profs.clone(),
        AccuracyMetric::Pas,
        8,
        AdapterConfig::default(),
        predictors(1),
    )
    .unwrap();
    let tel = Telemetry::new(TelemetryConfig::default(), 1);
    let fm = run_fleet(
        FleetDesParams {
            profiles: &profs,
            slas: &slas,
            interval: 10.0,
            apply_delay: 8.0,
            sim: SimConfig { seed: 9, ..Default::default() },
            system: "flash",
            budget: 8,
            faults: &[],
            router: Some(RouterConfig {
                policy: RoutePolicy::LeastLoaded,
                admission: true,
                admit_threshold: 0.3,
                shed_threshold: 1e6,
                ..RouterConfig::default()
            }),
            telemetry: Some(&tel),
        },
        &mut adapter,
        &traces,
    );

    let stats = &fm.router[0];
    assert!(stats.degraded > 0, "the crowd must trip the brownout stage");
    assert_eq!(stats.shed, 0, "shed threshold is unreachable by construction");
    assert_eq!(
        stats.total_routed() as usize,
        fm.members[0].requests.len(),
        "every arrival was still admitted"
    );
    assert!(fm.members[0].completed_count() > 100, "completions kept flowing");
    let kinds: Vec<String> =
        tel.journal().entries().iter().map(|e| e.kind.clone()).collect();
    assert!(kinds.iter().any(|k| k == "degrade"), "journal records brownouts: {kinds:?}");
    assert!(kinds.iter().any(|k| k == "route"), "journal records routing ticks");
}
