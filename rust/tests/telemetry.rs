//! Flight-recorder regression suite: span waterfalls telescope exactly
//! to end-to-end latency, streaming histogram summaries match the exact
//! Vec-based reference within bucket resolution, the decision journal
//! round-trips through JSONL byte-for-byte and replays to the identical
//! run, traced fleet runs emit byte-identical journals across reruns,
//! and tracing never perturbs the DES (traced == untraced == legacy
//! clock, per-request).

// The old fleet entry-point names (run_fleet_des* / serve_fleet_*)
// are exercised on purpose until their deprecation window closes.
#![allow(deprecated)]

use ipa::coordinator::adapter::{Adapter, AdapterConfig, Policy};
use ipa::fleet::solver::FleetAdapter;
use ipa::metrics::RunMetrics;
use ipa::models::accuracy::AccuracyMetric;
use ipa::models::pipelines;
use ipa::predictor::{Predictor, ReactivePredictor};
use ipa::profiler::analytic::pipeline_profiles;
use ipa::profiler::profile::PipelineProfiles;
use ipa::reports::timeline::{trace_end_to_end, trace_ids, trace_span_sum, waterfalls};
use ipa::simulator::replay::replay;
use ipa::simulator::sim::{
    run_fleet_des_traced, DecisionLog, FleetRunMetrics, SimConfig, Simulation,
};
use ipa::telemetry::journal::{decisions_from_journal, Journal};
use ipa::telemetry::{spans_to_jsonl, stage_histograms, Hop, Span, Telemetry, TelemetryConfig};
use ipa::util::stats::Summary;
use ipa::workload::trace::Trace;
use ipa::workload::tracegen::Pattern;

/// Worst-case multiplicative error of a bucket-midpoint quantile vs a
/// nearest-rank order statistic (the hist.rs resolution bound).
const BUCKET_ERR: f64 = 1.35;

/// One fully-traced single-pipeline DES run (video, every request
/// sampled): metrics + decision log + the span dump + the journal.
fn traced_video_run(seed: u64) -> (RunMetrics, DecisionLog, Vec<Span>, std::sync::Arc<Journal>) {
    let spec = pipelines::by_name("video").unwrap();
    let prof = pipeline_profiles(&spec);
    let adapter = Adapter::new(
        spec,
        prof,
        Policy::Ipa(AccuracyMetric::Pas),
        AdapterConfig::default(),
        Box::new(ReactivePredictor::default()),
    );
    let mut sim = Simulation::new(adapter, SimConfig { seed, ..Default::default() });
    let trace = Trace::synthetic(Pattern::Fluctuating, 150);
    let tel = Telemetry::new(TelemetryConfig::full(), 1);
    let (metrics, log) = sim.run_traced(&trace, &tel);
    let spans = tel.take_spans();
    (metrics, log, spans, tel.journal())
}

// ---------------------------------------------------------------------------
// Span tracing: the telescoping contract
// ---------------------------------------------------------------------------

/// For every completed trace, the timed hops (queue-wait + exec) sum
/// EXACTLY to the end-to-end latency the `Done` span carries — the
/// waterfall never invents or loses time.
#[test]
fn span_waterfalls_telescope_to_end_to_end_latency() {
    let (_, _, spans, _) = traced_video_run(13);
    assert!(!spans.is_empty(), "full sampling must record spans");
    let mut checked = 0usize;
    for id in trace_ids(&spans) {
        let Some(done) = spans.iter().find(|s| s.trace == id && s.hop == Hop::Done) else {
            continue;
        };
        let sum = trace_span_sum(&spans, id);
        assert!(
            (sum - done.dur).abs() < 1e-9,
            "trace {id}: hops sum to {sum} but end-to-end is {}",
            done.dur
        );
        assert_eq!(trace_end_to_end(&spans, id), Some(done.dur));
        checked += 1;
    }
    assert!(checked > 50, "thin run ({checked} completed traces) proves nothing");
    assert!(!waterfalls(&spans, 2).is_empty(), "waterfall rendering must not be blank");
}

// ---------------------------------------------------------------------------
// Streaming histograms vs the exact reference
// ---------------------------------------------------------------------------

/// The per-stage exec histogram folded from the span dump matches the
/// exact `Summary::of` over the same durations: moments exactly,
/// quantiles within bucket resolution of the nearest-rank statistic.
#[test]
fn stage_histogram_summary_matches_exact_reference() {
    let (_, _, spans, _) = traced_video_run(13);
    let series = stage_histograms(&spans);
    assert!(!series.is_empty());
    let first = &series[0];
    assert_eq!((first.member, first.stage), (0, 0));
    let durs: Vec<f64> = spans
        .iter()
        .filter(|s| s.member == 0 && s.stage == 0 && s.hop == Hop::Exec)
        .map(|s| s.dur)
        .collect();
    assert!(durs.len() > 100, "thin series ({})", durs.len());
    let s = first.exec.summary();
    let r = Summary::of(&durs);
    assert_eq!(s.n, r.n);
    assert_eq!(s.min, r.min);
    assert_eq!(s.max, r.max);
    assert!((s.mean - r.mean).abs() < 1e-9 * r.mean.abs().max(1.0));
    let mut sorted = durs.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for (q, got) in [(50.0, s.p50), (95.0, s.p95), (99.0, s.p99)] {
        let rank = (q / 100.0) * (sorted.len() - 1) as f64;
        let x = sorted[rank.round() as usize];
        assert!(
            got <= x * BUCKET_ERR && got >= x / BUCKET_ERR,
            "p{q}: {got} not within bucket error of rank stat {x}"
        );
    }
}

// ---------------------------------------------------------------------------
// Decision journal: JSONL round-trip + replay parity
// ---------------------------------------------------------------------------

/// The journal serializes to JSONL, parses back, and re-serializes to
/// the identical bytes; the decisions it carries drive `replay` to the
/// exact per-request outcomes of the original adaptive run.
#[test]
fn journal_roundtrips_and_replays_to_identical_run() {
    let seed = 13u64;
    let (original, logged, _, journal) = traced_video_run(seed);
    let text = journal.to_jsonl();
    assert!(!text.is_empty(), "a traced run must journal its decisions");
    let parsed = Journal::parse_jsonl(&text).unwrap();
    assert_eq!(parsed.to_jsonl(), text, "JSONL round-trip must be byte-stable");

    let decisions = decisions_from_journal(&journal, Some(0)).unwrap();
    assert_eq!(
        decisions.len(),
        logged.decisions.len(),
        "journal must carry every decision the driver logged"
    );
    let spec = pipelines::by_name("video").unwrap();
    let prof = pipeline_profiles(&spec);
    let cfg = AdapterConfig::default();
    let trace = Trace::synthetic(Pattern::Fluctuating, 150);
    let replayed = replay(
        &prof,
        spec.sla_e2e(),
        cfg.interval,
        cfg.apply_delay,
        SimConfig { seed, ..Default::default() },
        &DecisionLog { decisions },
        &trace,
        "replay-journal",
    );
    assert_eq!(original.requests, replayed.requests, "journal replay diverged");
}

// ---------------------------------------------------------------------------
// Fleet: byte-identical reruns, and tracing never perturbs the DES
// ---------------------------------------------------------------------------

/// 8-member fleet (demo3 cycled) through the traced fleet DES.
fn fleet8_run(legacy_clock: bool, tel: &Telemetry) -> FleetRunMetrics {
    const BUDGET: u32 = 64;
    let fleet = ipa::fleet::spec::FleetSpec::demo3();
    let base_specs = fleet.specs().unwrap();
    let base_profs: Vec<PipelineProfiles> = base_specs.iter().map(pipeline_profiles).collect();
    let base_slas: Vec<f64> = base_specs.iter().map(|s| s.sla_e2e()).collect();
    let base_traces: Vec<Trace> = fleet.traces(90);
    let n = 8usize;
    let specs: Vec<_> = (0..n).map(|i| base_specs[i % 3].clone()).collect();
    let profs: Vec<PipelineProfiles> = (0..n).map(|i| base_profs[i % 3].clone()).collect();
    let slas: Vec<f64> = (0..n).map(|i| base_slas[i % 3]).collect();
    let traces: Vec<Trace> = (0..n).map(|i| base_traces[i % 3].clone()).collect();
    let predictors: Vec<Box<dyn Predictor + Send>> = specs
        .iter()
        .map(|_| Box::new(ReactivePredictor::default()) as Box<dyn Predictor + Send>)
        .collect();
    let mut adapter = FleetAdapter::new(
        specs,
        profs.clone(),
        AccuracyMetric::Pas,
        BUDGET,
        AdapterConfig { interval: 30.0, apply_delay: 8.0, max_replicas: 4 },
        predictors,
    )
    .unwrap();
    run_fleet_des_traced(
        &profs,
        &slas,
        30.0,
        8.0,
        SimConfig { seed: 23, legacy_clock, ..Default::default() },
        &mut adapter,
        &traces,
        "telemetry-parity",
        BUDGET,
        tel,
    )
}

/// Two identical traced fleet runs emit byte-identical span dumps AND
/// byte-identical journals (the CI determinism contract), and the
/// journal speaks the expected event vocabulary.
#[test]
fn traced_fleet_reruns_emit_byte_identical_journals_and_spans() {
    let tel_a = Telemetry::new(TelemetryConfig::full(), 8);
    let tel_b = Telemetry::new(TelemetryConfig::full(), 8);
    let _ = fleet8_run(false, &tel_a);
    let _ = fleet8_run(false, &tel_b);
    assert_eq!(tel_a.dropped_spans(), 0, "deterministic runs never drop spans");

    let journal_a = tel_a.journal().to_jsonl();
    assert!(!journal_a.is_empty());
    assert_eq!(journal_a, tel_b.journal().to_jsonl(), "journal not byte-stable");
    let kinds: std::collections::BTreeSet<String> =
        tel_a.journal().entries().into_iter().map(|e| e.kind).collect();
    assert!(kinds.contains("decision"), "kinds: {kinds:?}");
    assert!(kinds.contains("solve"), "kinds: {kinds:?}");

    let spans_a = spans_to_jsonl(&tel_a.take_spans());
    assert!(!spans_a.is_empty());
    assert_eq!(spans_a, spans_to_jsonl(&tel_b.take_spans()), "spans not byte-stable");
}

/// Tracing is purely observational: a fully-traced sharded run, an
/// untraced sharded run, and a fully-traced LEGACY-clock run all land
/// the exact same per-request outcomes (PR 6's clock parity, now with
/// the recorder on).
#[test]
fn traced_fleet_des_matches_untraced_and_legacy_clock() {
    let traced = fleet8_run(false, &Telemetry::new(TelemetryConfig::full(), 8));
    let untraced = fleet8_run(false, &Telemetry::off());
    let legacy = fleet8_run(true, &Telemetry::new(TelemetryConfig::full(), 8));
    let total: usize = traced.members.iter().map(|m| m.requests.len()).sum();
    assert!(total > 300, "thin run ({total} requests) proves nothing");
    for (m, tm) in traced.members.iter().enumerate() {
        assert_eq!(tm.requests, untraced.members[m].requests, "member {m}: tracing perturbed");
        assert_eq!(tm.requests, legacy.members[m].requests, "member {m}: clock parity broke");
    }
    assert_eq!(traced.final_replicas, untraced.final_replicas);
    assert_eq!(traced.final_replicas, legacy.final_replicas);
}
