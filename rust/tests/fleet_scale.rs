//! Solver-scaling acceptance pins (ISSUE 8):
//!
//! (a) thread transparency — the parallel per-member solves behind
//!     `IPA_SOLVER_THREADS` may change HOW the joint solvers compute,
//!     never WHAT they decide: `solve_fleet` / `solve_fleet_tiers` /
//!     `solve_fleet_placed` results (packing included) and even the
//!     engine's cache hit/miss counters are byte-identical at 1, 2 and
//!     8 threads;
//! (b) hierarchical cells — the cell-partitioned solve stays within a
//!     pinned optimality gap of the flat solve on randomized fleets and
//!     never drops below the global even-split baseline, and the
//!     `cell_threshold` dispatch inside the public solvers preserves
//!     those same floors;
//! (c) delta packing — `pack_delta` keeps every unchanged member's
//!     replicas exactly where the previous packing had them, respects
//!     every capacity axis, and its `moved_from` agrees with a
//!     quadratic reference diff;
//! (d) telemetry — the bounded eval cache surfaces real hit/miss
//!     counts through the `_stats` solver variants.
//!
//! Tests that flip process-global knobs (solver threads, cell
//! threshold, delta packing) serialize on one mutex so the rest of the
//! suite never observes a transient override.

use std::sync::Mutex;

use ipa::fleet::cells::{set_cell_threshold, solve_fleet_cells};
use ipa::fleet::nodes::{
    reset_delta_pack, set_delta_pack, NodeInventory, PackItem, Packing, Placement,
};
use ipa::fleet::solver::{
    even_shares, set_solver_threads, solve_fleet, solve_fleet_placed, solve_fleet_stats,
    solve_fleet_tiers,
};
use ipa::models::pipelines::{self, PipelineSpec};
use ipa::optimizer::ip::Problem;
use ipa::profiler::analytic::pipeline_profiles;
use ipa::profiler::profile::PipelineProfiles;
use ipa::resources::ResourceVec;
use ipa::util::quickcheck::{check, prop_assert};

/// Serializes every test that flips a process-global solver knob.
static KNOBS: Mutex<()> = Mutex::new(());

fn lock_knobs() -> std::sync::MutexGuard<'static, ()> {
    KNOBS.lock().unwrap_or_else(|e| e.into_inner())
}

const PIPES: [&str; 5] = ["video", "audio-sent", "nlp", "sum-qa", "audio-qa"];

/// `n` members cycling the five paper pipelines, λ spread over a
/// deterministic ramp.
fn fleet_parts(n: usize) -> (Vec<PipelineSpec>, Vec<PipelineProfiles>, Vec<f64>) {
    let specs: Vec<PipelineSpec> =
        (0..n).map(|i| pipelines::by_name(PIPES[i % PIPES.len()]).unwrap()).collect();
    let profs: Vec<PipelineProfiles> = specs.iter().map(pipeline_profiles).collect();
    let lambdas: Vec<f64> = (0..n).map(|i| 3.0 + 2.5 * (i % 5) as f64).collect();
    (specs, profs, lambdas)
}

fn problems_of<'a>(
    specs: &'a [PipelineSpec],
    profs: &'a [PipelineProfiles],
    lambdas: &[f64],
) -> Vec<Problem<'a>> {
    specs
        .iter()
        .zip(profs)
        .zip(lambdas)
        .map(|((s, p), &l)| Problem::new(s, p, l))
        .collect()
}

// ---------------------------------------------------------------------------
// (a) thread transparency
// ---------------------------------------------------------------------------

/// All three public solvers (and the engine's cache counters) are
/// byte-identical at 1, 2 and 8 solver threads — the parallel fan-out
/// is placement-transparent.
#[test]
fn thread_count_never_changes_any_solver_decision() {
    let _g = lock_knobs();
    let (specs, profs, lambdas) = fleet_parts(6);
    let problems = problems_of(&specs, &profs, &lambdas);
    let budget = 30u32;
    let priorities = [2u32, 1, 0, 2, 1, 0];
    let inv = NodeInventory::parse("6x(8c,32g,0a)+2x(16c,64g,1a)").unwrap();
    // a previous placement for the sticky/incremental path to hold onto
    set_solver_threads(1);
    let prev = solve_fleet_placed(&problems, &inv, &priorities, &[], None)
        .expect("inventory hosts the fleet")
        .packing
        .expect("placed solve carries a packing");
    let shifted: Vec<f64> = lambdas.iter().map(|l| l * 1.4).collect();
    let problems2 = problems_of(&specs, &profs, &shifted);

    let run = |threads: usize| -> (String, String, String, String) {
        set_solver_threads(threads);
        let (flat, stats) = solve_fleet_stats(&problems, budget).unwrap();
        let tiers = solve_fleet_tiers(&problems, budget, &priorities).unwrap();
        let placed =
            solve_fleet_placed(&problems2, &inv, &priorities, &[], Some(&prev)).unwrap();
        assert!(placed.packing.is_some(), "placed solve must carry a packing");
        (
            format!("{flat:?}"),
            format!("{stats:?}"),
            format!("{tiers:?}"),
            format!("{placed:?}"),
        )
    };
    let base = run(1);
    for threads in [2usize, 8] {
        let got = run(threads);
        assert_eq!(base.0, got.0, "solve_fleet diverged at {threads} threads");
        assert_eq!(base.1, got.1, "cache counters diverged at {threads} threads");
        assert_eq!(base.2, got.2, "solve_fleet_tiers diverged at {threads} threads");
        assert_eq!(base.3, got.3, "solve_fleet_placed diverged at {threads} threads");
    }
    set_solver_threads(0);
}

// ---------------------------------------------------------------------------
// (b) hierarchical cells
// ---------------------------------------------------------------------------

/// The even-split baseline's total objective, computed through
/// singleton flat solves (per-member objective is monotone in budget,
/// so a one-member greedy at budget `b` lands exactly on obj(b)).
fn even_total(problems: &[Problem], budget: u32) -> f64 {
    let floors: Vec<u32> =
        problems.iter().map(|p| p.profiles.stages.len() as u32).collect();
    let even = even_shares(budget, &floors);
    problems
        .iter()
        .zip(even)
        .map(|(p, b)| {
            solve_fleet(std::slice::from_ref(p), b)
                .expect("even share covers the member floor")
                .total_objective
        })
        .sum()
}

/// Randomized fleets: forced 2-member cells stay within a bounded gap
/// of the flat solve, never fall below the even-split baseline, and
/// respect the budget.  Same inputs solve to the same answer.
#[test]
fn cells_quality_within_pinned_gap_of_flat() {
    let _g = lock_knobs();
    set_solver_threads(0);
    set_cell_threshold(0);
    check("hierarchical cells quality gap", 25, |g| {
        let n = g.usize(4, 9);
        let specs: Vec<PipelineSpec> =
            (0..n).map(|i| pipelines::by_name(PIPES[i % PIPES.len()]).unwrap()).collect();
        let profs: Vec<PipelineProfiles> = specs.iter().map(pipeline_profiles).collect();
        let lambdas: Vec<f64> = (0..n).map(|_| g.f64(2.0, 30.0)).collect();
        let problems = problems_of(&specs, &profs, &lambdas);
        let floor: u32 = specs.iter().map(|s| s.n_stages() as u32).sum();
        let budget = floor + g.usize(0, 3 * n + 1) as u32;

        let flat = solve_fleet(&problems, budget).expect("budget covers the floor");
        let (cells, stats) =
            solve_fleet_cells(&problems, budget, 2).expect("same feasibility as flat");
        let (cells2, _) = solve_fleet_cells(&problems, budget, 2).unwrap();
        prop_assert(
            format!("{cells:?}") == format!("{cells2:?}"),
            "cells solve is not deterministic",
        )?;
        prop_assert(cells.replicas_used <= budget, "cells exceeded the budget")?;
        prop_assert(cells.members.len() == problems.len(), "member lost in cells")?;
        prop_assert(stats.cache_misses > 0, "cells solve reported no evaluations")?;
        let gap_floor = flat.total_objective - (0.25 * flat.total_objective.abs() + 2.0);
        prop_assert(
            cells.total_objective >= gap_floor,
            &format!(
                "cells objective {:.3} below the pinned gap floor {gap_floor:.3} \
                 (flat {:.3})",
                cells.total_objective, flat.total_objective
            ),
        )?;
        prop_assert(
            cells.total_objective >= even_total(&problems, budget) - 1e-9,
            "cells fell below the even-split baseline",
        )?;
        Ok(())
    });
}

/// The `cell_threshold` dispatch inside `solve_fleet` itself: forcing a
/// low threshold routes a uniform-priority fleet through cells and the
/// result keeps the flat solver's public guarantees.
#[test]
fn public_solver_dispatches_through_cells_above_threshold() {
    let _g = lock_knobs();
    let (specs, profs, lambdas) = fleet_parts(8);
    let problems = problems_of(&specs, &profs, &lambdas);
    let budget = 40u32;

    set_cell_threshold(usize::MAX);
    let flat = solve_fleet(&problems, budget).unwrap();
    set_cell_threshold(4); // 8 members >= 4: hierarchical path
    let cells = solve_fleet(&problems, budget).unwrap();
    set_cell_threshold(0);

    assert!(cells.replicas_used <= budget);
    assert_eq!(cells.members.len(), 8);
    assert!(
        cells.total_objective >= even_total(&problems, budget) - 1e-9,
        "dispatched cells solve fell below the even baseline"
    );
    assert!(
        cells.total_objective
            >= flat.total_objective - (0.25 * flat.total_objective.abs() + 2.0),
        "dispatched cells solve outside the pinned gap: {} vs flat {}",
        cells.total_objective,
        flat.total_objective
    );
    // tiered fleets must keep the flat path regardless of threshold
    set_cell_threshold(2);
    let prios = [1u32, 0, 1, 0, 1, 0, 1, 0];
    let tiered = solve_fleet_tiers(&problems, budget, &prios).unwrap();
    set_cell_threshold(usize::MAX);
    let tiered_flat = solve_fleet_tiers(&problems, budget, &prios).unwrap();
    set_cell_threshold(0);
    assert_eq!(
        format!("{tiered:?}"),
        format!("{tiered_flat:?}"),
        "tier precedence is global — the threshold must not touch tiered solves"
    );
}

// ---------------------------------------------------------------------------
// (c) delta packing
// ---------------------------------------------------------------------------

/// Quadratic reference for `Packing::moved_from` on an unchanged
/// inventory (flat node ids map to themselves): consume matching
/// (member, stage, node) slots of `prev` one by one, in placement
/// order.
fn reference_moved(cur: &Packing, prev: &Packing) -> Vec<Placement> {
    let mut held: Vec<(usize, usize, usize)> =
        prev.placements.iter().map(|p| (p.member, p.stage, p.node)).collect();
    let mut moved = Vec::new();
    for p in &cur.placements {
        match held.iter().position(|&k| k == (p.member, p.stage, p.node)) {
            Some(i) => {
                held.swap_remove(i);
            }
            None => moved.push(*p),
        }
    }
    moved
}

fn gen_items(g: &mut ipa::util::quickcheck::Gen, members: usize) -> Vec<PackItem> {
    (0..members)
        .map(|m| PackItem {
            member: m,
            stage: g.usize(0, 3),
            unit: match g.usize(0, 3) {
                0 => ResourceVec::new(1.0, 2.0, 0.0),
                1 => ResourceVec::new(2.0, 8.0, 0.0),
                _ => ResourceVec::new(4.0, 16.0, 1.0),
            },
            replicas: g.usize(1, 5) as u32,
        })
        .collect()
}

/// `pack_delta` properties on randomized demand shifts: every capacity
/// axis respected, per-(member, stage) replica counts exactly the new
/// demand, unchanged members' placements preserved verbatim from
/// `prev`, and `moved_from` equal to the quadratic reference diff.
#[test]
fn prop_delta_pack_preserves_unchanged_members() {
    check("delta packing invariants", 120, |g| {
        let inv = NodeInventory::parse("10x(8c,32g,0a)+4x(16c,64g,2a)").unwrap();
        let members = g.usize(2, 8);
        let items = gen_items(g, members);
        let Some(prev) = inv.pack(&items) else { return Ok(()) };

        // shift: each member changes replica count with probability ~1/2
        let mut items2 = items.clone();
        let mut changed = vec![false; members];
        for (m, it) in items2.iter_mut().enumerate() {
            if g.bool() {
                it.replicas = g.usize(0, 6) as u32;
                changed[m] = it.replicas != items[m].replicas;
            }
        }
        let Some(delta) = inv.pack_delta(&items2, &prev, &changed, &[]) else {
            return Ok(()); // declining is always allowed — fallback covers it
        };
        prop_assert(delta.valid_for(&inv), "delta packing over capacity")?;
        let total: u32 = items2.iter().map(|it| it.replicas).sum();
        prop_assert(
            delta.placements.len() == total as usize,
            "delta packing lost or duplicated a replica",
        )?;
        for (m, it) in items2.iter().enumerate() {
            let placed =
                delta.placements.iter().filter(|p| p.member == m && p.stage == it.stage).count();
            prop_assert(
                placed == it.replicas as usize,
                "delta packing wrong replica count for a member",
            )?;
        }
        for (m, &ch) in changed.iter().enumerate() {
            if ch {
                continue;
            }
            let mut prev_nodes: Vec<usize> = prev
                .placements
                .iter()
                .filter(|p| p.member == m)
                .map(|p| p.node)
                .collect();
            let mut delta_nodes: Vec<usize> = delta
                .placements
                .iter()
                .filter(|p| p.member == m)
                .map(|p| p.node)
                .collect();
            prev_nodes.sort_unstable();
            delta_nodes.sort_unstable();
            prop_assert(
                prev_nodes == delta_nodes,
                "an unchanged member's replicas moved under delta packing",
            )?;
        }
        prop_assert(
            delta.moved_from(&prev) == reference_moved(&delta, &prev),
            "moved_from disagrees with the quadratic reference",
        )?;
        Ok(())
    });
}

/// A fully-unchanged repack retains every placement: zero moves.
#[test]
fn delta_pack_all_unchanged_moves_nothing() {
    let inv = NodeInventory::parse("4x(8c,32g,0a)+2x(16c,64g,1a)").unwrap();
    let items: Vec<PackItem> = (0..5)
        .map(|m| PackItem {
            member: m,
            stage: 0,
            unit: ResourceVec::new(2.0, 4.0, 0.0),
            replicas: 2,
        })
        .collect();
    let prev = inv.pack(&items).unwrap();
    let delta = inv
        .pack_delta(&items, &prev, &[false; 5], &[])
        .expect("retaining an intact packing cannot fail");
    assert!(delta.moved_from(&prev).is_empty(), "quiet delta repack must move nothing");
    assert_eq!(delta.placements.len(), prev.placements.len());
}

/// `moved_from` against the reference on plain (non-delta) repacks too
/// — the hash-indexed rewrite is a pure speedup, not a semantic change.
#[test]
fn prop_moved_from_matches_reference_on_plain_packs() {
    check("moved_from reference equivalence", 120, |g| {
        let inv = NodeInventory::parse("8x(8c,32g,0a)+3x(16c,64g,2a)").unwrap();
        let members = g.usize(2, 8);
        let items = gen_items(g, members);
        let Some(prev) = inv.pack(&items) else { return Ok(()) };
        let mut items2 = items.clone();
        for it in items2.iter_mut() {
            if g.bool() {
                it.replicas = g.usize(0, 6) as u32;
            }
        }
        let Some(cur) = inv.pack_sticky(&items2, Some(&prev), &[]) else { return Ok(()) };
        prop_assert(
            cur.moved_from(&prev) == reference_moved(&cur, &prev),
            "moved_from disagrees with the quadratic reference on a sticky repack",
        )?;
        Ok(())
    });
}

/// The delta knob is trade-wall-time-only: with delta packing forced
/// off, the incremental paths fall back to full sticky packs and the
/// fleet still solves (same public contract).
#[test]
fn delta_knob_off_still_solves() {
    let _g = lock_knobs();
    set_delta_pack(false);
    let (specs, profs, lambdas) = fleet_parts(4);
    let problems = problems_of(&specs, &profs, &lambdas);
    let inv = NodeInventory::parse("6x(8c,32g,0a)+2x(16c,64g,1a)").unwrap();
    let alloc = solve_fleet_placed(&problems, &inv, &[0, 0, 0, 0], &[], None).unwrap();
    assert!(alloc.packing.is_some());
    reset_delta_pack();
}

// ---------------------------------------------------------------------------
// (d) cache telemetry
// ---------------------------------------------------------------------------

/// The `_stats` variants surface real cache activity: a joint solve
/// computes at least one evaluation per member and the greedy scans
/// re-read warm entries.
#[test]
fn solver_stats_report_cache_activity() {
    let (specs, profs, lambdas) = fleet_parts(5);
    let problems = problems_of(&specs, &profs, &lambdas);
    let (_, stats) = solve_fleet_stats(&problems, 25).unwrap();
    assert!(
        stats.cache_misses >= problems.len() as u64,
        "fewer evaluations than members: {stats:?}"
    );
    assert!(stats.cache_hits > 0, "greedy scans never re-read the memo: {stats:?}");
}
