//! Runtime integration over the real AOT artifacts: PJRT load/compile/
//! execute, numerics vs the python oracle, the executor pool, and the
//! LSTM predictor serving path.  Requires `make artifacts` (skipped with
//! a message when artifacts are absent).

use ipa::runtime::engine::Engine;
use ipa::runtime::pool::ExecutorPool;
use std::sync::Arc;

/// Locate the AOT artifacts, or print an explicit per-test SKIP line.
/// Every test in this file guards itself with
/// `let Some(dir) = artifacts_dir("<test name>") else { return };`
/// so a run without artifacts is unambiguous in the tier-1 output:
/// each test names itself, states the reason, and passes vacuously —
/// nothing silently depends on absent PJRT artifacts.
fn artifacts_dir(test: &str) -> Option<String> {
    for dir in ["artifacts", "../artifacts"] {
        if std::path::Path::new(dir).join("manifest.json").exists() {
            return Some(dir.to_string());
        }
    }
    eprintln!("SKIP runtime_artifacts::{test}: no artifacts/ (run `make artifacts`)");
    None
}

#[test]
fn manifest_covers_registry() {
    let Some(dir) = artifacts_dir("manifest_covers_registry") else { return };
    let m = ipa::runtime::manifest::Manifest::load(&dir).unwrap();
    // 29 variants x 7 batch sizes
    assert_eq!(m.variants.len(), 29 * 7);
    assert!(m.predictor.is_some());
    for v in &ipa::models::registry::VARIANTS {
        for &b in &ipa::models::registry::BATCH_SIZES {
            let a = m.variant(&v.key(), b).unwrap_or_else(|| panic!("{} b{b}", v.key()));
            assert_eq!(a.hidden, v.hidden(), "{}", v.key());
            assert_eq!(a.accuracy, v.accuracy);
            assert!(m.abs_path(&a.path).exists());
        }
    }
}

#[test]
fn execute_matches_python_oracle() {
    let Some(dir) = artifacts_dir("execute_matches_python_oracle") else { return };
    let mut e = Engine::new(&dir).unwrap();
    // one light + one heavy variant
    for key in ["detect.yolov5n", "qa.roberta-large"] {
        let (got, want) = e.check_variant(key).unwrap();
        let rel = (got - want).abs() / want.abs().max(1e-6);
        assert!(rel < 1e-3, "{key}: got {got} want {want}");
    }
}

#[test]
fn execute_matches_rust_reference_forward() {
    let Some(dir) = artifacts_dir("execute_matches_rust_reference_forward") else { return };
    let mut e = Engine::new(&dir).unwrap();
    let key = "classify.resnet18";
    let art = e.manifest.variant(key, 4).unwrap().clone();
    let w = ipa::runtime::weights::make_params(key, art.hidden, art.layers);
    let x = ipa::runtime::weights::check_input(art.hidden, 4);
    let (got, _) = e.execute_variant(key, 4, &x).unwrap();
    let want = ipa::runtime::weights::reference_forward(&x, 4, art.hidden, &w);
    assert_eq!(got.len(), want.len());
    for (a, b) in got.iter().zip(&want) {
        assert!((a - b).abs() < 1e-3 * b.abs().max(1.0), "{a} vs {b}");
    }
}

#[test]
fn batch_latency_grows_with_batch() {
    let Some(dir) = artifacts_dir("batch_latency_grows_with_batch") else { return };
    let mut e = Engine::new(&dir).unwrap();
    let key = "qa.roberta-large"; // largest hidden -> measurable compute
    let hidden = e.manifest.variant(key, 1).unwrap().hidden;
    let mut times = Vec::new();
    for &b in &[1usize, 64] {
        let x = vec![0.1f32; b * hidden];
        e.execute_variant(key, b, &x).unwrap(); // warm
        let mut best = f64::MAX;
        for _ in 0..3 {
            let (_, dt) = e.execute_variant(key, b, &x).unwrap();
            best = best.min(dt.as_secs_f64());
        }
        times.push(best);
    }
    // Interpret-mode Pallas adds a large fixed per-call overhead, so the
    // growth is strongly sub-linear (that is the batching win the paper
    // exploits) — but batch-64 must still cost measurably more.
    assert!(
        times[1] > times[0] * 1.15,
        "batch-64 {:.6}s vs batch-1 {:.6}s",
        times[1],
        times[0]
    );
}

#[test]
fn lstm_predictor_tracks_load_level() {
    let Some(dir) = artifacts_dir("lstm_predictor_tracks_load_level") else { return };
    let mut e = Engine::new(&dir).unwrap();
    let low = e.predict(&vec![6.0f32; 120]).unwrap();
    let high = e.predict(&vec![30.0f32; 120]).unwrap();
    assert!(high > low, "lstm: high {high} <= low {low}");
    assert!(low > 0.0 && low < 25.0, "low-level prediction {low}");
    assert!(high > 12.0 && high < 60.0, "high-level prediction {high}");
}

#[test]
fn lstm_check_value_matches_manifest() {
    let Some(dir) = artifacts_dir("lstm_check_value_matches_manifest") else { return };
    let mut e = Engine::new(&dir).unwrap();
    let want = e.manifest.predictor.as_ref().unwrap().check_pred;
    let window: Vec<f32> = (0..120).map(|i| 5.0 + 20.0 * i as f32 / 119.0).collect();
    let got = e.predict(&window).unwrap() as f64;
    assert!((got - want).abs() < 1e-2 * want.abs().max(1.0), "{got} vs {want}");
}

#[test]
fn executor_pool_concurrent_use() {
    let Some(dir) = artifacts_dir("executor_pool_concurrent_use") else { return };
    let pool = Arc::new(ExecutorPool::new(&dir, 2).unwrap());
    let mut joins = Vec::new();
    for t in 0..4 {
        let p = Arc::clone(&pool);
        joins.push(std::thread::spawn(move || {
            let key = if t % 2 == 0 { "detect.yolov5n" } else { "classify.resnet18" };
            let hidden = if t % 2 == 0 { 32 } else { 64 };
            for _ in 0..3 {
                let x = vec![0.1f32; hidden];
                let (y, _) = p.execute(key, 1, x).unwrap();
                assert_eq!(y.len(), hidden);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
}

#[test]
fn pool_lstm_closure_plugs_into_predictor() {
    use ipa::predictor::{LstmPredictor, Predictor};
    let Some(dir) = artifacts_dir("pool_lstm_closure_plugs_into_predictor") else { return };
    let pool = Arc::new(ExecutorPool::new(&dir, 1).unwrap());
    let mut pred = LstmPredictor::new(pool.lstm_closure());
    let hist = vec![10.0f64; 150];
    let p = pred.predict(0.0, &hist);
    assert!(p > 2.0 && p < 40.0, "{p}");
}

/// The live serving engine on a real (tiny, compressed) trace — the
/// full three-layer stack end-to-end.
#[test]
fn live_engine_smoke() {
    use ipa::coordinator::adapter::Policy;
    use ipa::models::accuracy::AccuracyMetric;
    use ipa::serving::engine::{serve, ServeConfig};
    use ipa::serving::loadgen::LoadGenConfig;
    let Some(dir) = artifacts_dir("live_engine_smoke") else { return };
    let spec = ipa::models::pipelines::by_name("video").unwrap();
    let cfg = ServeConfig {
        artifact_dir: dir,
        executors: 2,
        max_workers: 4,
        interval: 2.0,
        apply_delay: 0.3,
        use_lstm: true,
        profile_batches: vec![1, 8, 64],
        profile_reps: 2,
        sla_floor: 0.25,
        legacy_lock: false,
    };
    let trace = ipa::workload::trace::Trace::synthetic(
        ipa::workload::tracegen::Pattern::SteadyLow,
        60,
    );
    let lg = LoadGenConfig { time_scale: 0.1, seed: 4 }; // 60s trace in ~6s wall
    let rep = serve(&spec, Policy::Ipa(AccuracyMetric::Pas), &cfg, lg, &trace).unwrap();
    let m = &rep.metrics;
    assert!(m.requests.len() > 150, "{}", m.requests.len());
    assert!(
        m.latencies().len() as f64 > m.requests.len() as f64 * 0.5,
        "completed {} of {}",
        m.latencies().len(),
        m.requests.len()
    );
    assert!(rep.sla > 0.0);
}
