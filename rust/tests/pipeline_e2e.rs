//! End-to-end simulator behaviour: queueing/batching/drop invariants
//! checked over full runs, plus property tests on the serving
//! substrate's conservation laws.

use ipa::coordinator::adapter::{Adapter, AdapterConfig, Policy};
use ipa::models::accuracy::AccuracyMetric;
use ipa::models::pipelines;
use ipa::predictor::{OraclePredictor, ReactivePredictor};
use ipa::profiler::analytic::pipeline_profiles;
use ipa::simulator::sim::{SimConfig, Simulation};
use ipa::util::quickcheck::{check, prop_assert};
use ipa::workload::trace::Trace;
use ipa::workload::tracegen::{self, Pattern};

fn sim_with(
    pipeline: &str,
    policy: Policy,
    seed: u64,
    oracle_trace: Option<Trace>,
) -> Simulation {
    let spec = pipelines::by_name(pipeline).unwrap();
    let prof = pipeline_profiles(&spec);
    let predictor: Box<dyn ipa::predictor::Predictor + Send> = match oracle_trace {
        Some(t) => Box::new(OraclePredictor { trace: t }),
        None => Box::new(ReactivePredictor::default()),
    };
    let adapter = Adapter::new(spec, prof, policy, AdapterConfig::default(), predictor);
    Simulation::new(adapter, SimConfig { seed, ..Default::default() })
}

/// Conservation: every arrival is either completed, dropped, or still
/// in flight at horizon; nothing is duplicated or invented.
#[test]
fn prop_request_conservation() {
    check("request conservation", 8, |g| {
        let pattern = *g.choose(&[Pattern::SteadyLow, Pattern::Bursty, Pattern::Fluctuating]);
        let seed = g.u64(1, 1000);
        let trace = Trace::new(
            pattern.name(),
            tracegen::generate(pattern, 150, seed),
        );
        let mut sim = sim_with("video", Policy::Ipa(AccuracyMetric::Pas), seed, None);
        let m = sim.run(&trace);
        let arrivals = trace.arrivals(seed).len();
        prop_assert(m.requests.len() == arrivals, "record per arrival")?;
        let completed = m.latencies().len();
        let dropped = m.requests.iter().filter(|r| r.completion.is_none()).count();
        prop_assert(completed + dropped == arrivals, "partition")?;
        // the cluster core's accounting must agree with the raw records
        prop_assert(m.completed_count() == completed, "completed_count")?;
        prop_assert(m.dropped_count() == dropped, "dropped_count")?;
        prop_assert(
            m.completed_count() + m.dropped_count() == arrivals,
            "no request both dropped and completed",
        )?;
        // ids unique
        let mut ids: Vec<u64> = m.requests.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        prop_assert(ids.len() == arrivals, "unique ids")
    });
}

/// Latency sanity: completions follow arrivals, and with dropping on,
/// completed latencies stay below 2×SLA + max service time.
#[test]
fn prop_latency_bounds() {
    check("latency bounds", 6, |g| {
        let seed = g.u64(1, 500);
        let trace = Trace::new("bursty", tracegen::generate(Pattern::Bursty, 150, seed));
        let mut sim = sim_with("audio-qa", Policy::Ipa(AccuracyMetric::Pas), seed, None);
        let m = sim.run(&trace);
        for r in &m.requests {
            if let Some(c) = r.completion {
                prop_assert(c >= r.arrival, "causality")?;
                prop_assert(c - r.arrival < 3.0 * m.sla, "2xSLA drop ceiling")?;
            }
        }
        Ok(())
    });
}

/// Oracle-predicted runs never violate more than reactive runs on
/// bursty load (Fig. 16 direction), aggregated over pipelines.
#[test]
fn oracle_no_worse_than_reactive_on_bursts() {
    let mut oracle_v = 0.0;
    let mut reactive_v = 0.0;
    for pipeline in ["video", "sum-qa", "nlp"] {
        let trace = Trace::synthetic(Pattern::Bursty, 300);
        let m1 = sim_with(pipeline, Policy::Ipa(AccuracyMetric::Pas), 5, Some(trace.clone()))
            .run(&trace);
        let m2 = sim_with(pipeline, Policy::Ipa(AccuracyMetric::Pas), 5, None).run(&trace);
        oracle_v += m1.violation_rate();
        reactive_v += m2.violation_rate();
    }
    assert!(
        oracle_v <= reactive_v + 0.05,
        "oracle {oracle_v} vs reactive {reactive_v}"
    );
}

/// Reconfiguration stability: steady workloads should not thrash model
/// variants every interval.
#[test]
fn steady_load_rarely_switches() {
    let trace = Trace::synthetic(Pattern::SteadyLow, 300);
    let m = sim_with("video", Policy::Ipa(AccuracyMetric::Pas), 3, None).run(&trace);
    let switches = m.variant_switches();
    assert!(
        (switches as f64) < m.intervals.len() as f64 * 0.4,
        "{switches} switches in {} intervals",
        m.intervals.len()
    );
}

/// The monitor's observed rates track the trace's ground truth.
#[test]
fn monitoring_tracks_load() {
    let trace = Trace::synthetic(Pattern::SteadyHigh, 240);
    let m = sim_with("video", Policy::Fa2Low, 3, None).run(&trace);
    let observed: Vec<f64> = m.intervals.iter().skip(2).map(|i| i.lambda_observed).collect();
    let mean_obs = ipa::util::stats::mean(&observed);
    assert!((mean_obs - 26.0).abs() < 4.0, "observed mean {mean_obs}");
}

/// FA2-low under bursty load violates more than under steady-low
/// (bursts hurt a reactive fixed-variant system).
#[test]
fn bursts_hurt_attainment() {
    let steady = sim_with("video", Policy::Fa2Low, 7, None)
        .run(&Trace::synthetic(Pattern::SteadyLow, 240));
    let bursty = sim_with("video", Policy::Fa2Low, 7, None)
        .run(&Trace::synthetic(Pattern::Bursty, 240));
    assert!(
        bursty.violation_rate() >= steady.violation_rate() - 0.02,
        "bursty {} vs steady {}",
        bursty.violation_rate(),
        steady.violation_rate()
    );
}
