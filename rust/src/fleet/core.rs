//! The shared-pool fleet core: one [`ClusterCore`] per member pipeline
//! plus the accounting that enforces the global replica budget.
//!
//! [`FleetCore`] is clock-agnostic exactly like the single-pipeline
//! core: the DES fleet driver feeds it virtual time, the live fleet
//! engine wall-clock time.  Its job beyond fan-out is the *budget
//! invariant*:
//!
//! * configured replicas — Σ over every stage of every member of the
//!   active replica count — never exceed the pool
//!   ([`FleetCore::new`] / [`FleetCore::apply`] reject violating
//!   configurations before touching any member);
//! * during a rolling reconfiguration, batches in flight on shrunk
//!   stages keep their old slots busy (`busy > replicas`), so the pool
//!   can transiently hold more work than it is configured for — the
//!   core tracks that overshoot ([`PoolUsage::in_use`],
//!   [`FleetCore::peak_in_use`]) instead of pretending it away, which
//!   is precisely the §5.3 rolling-update semantics at fleet scope.
//!
//! [`FleetReconfig`] is the joint apply-delay stager: one decision
//! *vector* per tick, activated atomically so the budget check always
//! sees the whole fleet's next configuration.  [`FleetReconfig::pop_due`]
//! *coalesces*: it drains every staged fleet whose time has come and
//! returns only the newest, so a slow tick can never leave stale
//! reconfigurations queued behind the current one.
//!
//! The pool itself is elastic: [`FleetCore::resize_pool`] grows or
//! shrinks the budget (never below the currently configured replicas),
//! and the core keeps the cost ledger — replica-seconds *bought*
//! (∫ budget dt) vs *used* (∫ configured dt) via [`FleetCore::accrue`]
//! — plus pool-size extremes and preemption counts, all surfaced
//! through [`FleetCore::pool_report`].
//!
//! With a [`NodeInventory`] attached ([`FleetCore::with_nodes`]) the
//! pool stops being fungible: the budget is the inventory's replica
//! cap, every [`FleetCore::apply`] additionally bin-packs the new
//! configuration's resource vectors onto the nodes,
//! [`FleetCore::resize_pool`] moves WHOLE nodes of the elastic shape
//! (a shrink must re-pack the active replicas or it is rejected), and
//! the ledger gains node-seconds per shape.  Per-member SLA classes
//! plug in as batch-timeout ceilings carried by [`MemberInit`].
//!
//! Placement is *sticky*: every apply re-packs against the previous
//! placement ([`NodeInventory::pack_sticky`] keep-in-place pass, plain
//! FFD as the fallback when stickiness cannot pack), every placement
//! NOT inherited from it — moves and new starts, the container churn —
//! is counted into the migrations ledger
//! ([`PoolReport::migrations`]), and [`FleetCore::plan_moves`] lets the
//! drivers price a candidate decision's churn BEFORE staging it — the
//! per-replica migration delay [`FleetReconfig::with_migration`] then
//! charges on top of the apply delay.  Zone-spread flags
//! ([`FleetCore::with_nodes_spread`]) make the pack reject placements a
//! single zone loss would break, [`FleetCore::kill_zone`] is the fault
//! actuator (drain a zone's nodes mid-run), and
//! [`FleetCore::resize_pool_with`] mirrors the controller's inventory
//! on resizes — with pressure-aware buying the shape CHOICE no longer
//! follows from the replica target alone, so cap-convergence stopped
//! being enough to keep the two views in lockstep.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::cluster::core::ClusterCore;
use crate::cluster::drop_policy::DropPolicy;
use crate::coordinator::adapter::Decision;
use crate::fleet::nodes::{config_demands, NodeInventory, Packing};
use crate::optimizer::ip::PipelineConfig;
use crate::telemetry::journal::Journal;
use crate::util::json::Json;

/// Per-member construction parameters of a fleet core: the initial
/// configuration, the λ shaping its batch timeouts, the drop policy,
/// and the SLA-class batch-timeout ceiling (`f64::INFINITY` =
/// uncapped, the classless behavior).
#[derive(Debug, Clone)]
pub struct MemberInit {
    pub config: PipelineConfig,
    pub lambda: f64,
    pub drop: DropPolicy,
    pub timeout_cap: f64,
}

impl MemberInit {
    /// Classless member (uncapped batch timeouts).
    pub fn new(config: PipelineConfig, lambda: f64, drop: DropPolicy) -> MemberInit {
        MemberInit { config, lambda, drop, timeout_cap: f64::INFINITY }
    }
}

/// Pool occupancy snapshot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolUsage {
    /// The global replica budget.
    pub budget: u32,
    /// Σ configured replicas across every member stage (≤ `budget`).
    pub configured: u32,
    /// Σ busy slots across every member stage.
    pub busy: u32,
    /// Σ per-stage `max(busy, replicas)` — what the pool is physically
    /// holding right now; exceeds `configured` only during a rolling
    /// shrink while old batches drain.
    pub in_use: u32,
}

/// End-of-run pool accounting: size extremes, resize/preemption counts
/// and the replica-second cost ledger.
#[derive(Debug, Clone, PartialEq)]
pub struct PoolReport {
    /// Pool size when the run ended.
    pub budget: u32,
    /// Smallest pool size ever held.
    pub pool_min: u32,
    /// Largest pool size ever held.
    pub pool_max: u32,
    /// Highest occupancy observed (rolling-shrink overshoot included).
    pub peak_in_use: u32,
    /// Number of [`FleetCore::resize_pool`] calls that changed the size.
    pub resizes: u32,
    /// Σ container churn across reconfigurations: replica placements
    /// NOT inherited from the previous packing — node-to-node moves
    /// and newly started replicas alike (both pay a container start;
    /// scale-downs tear down for free).  Sticky packing keeps this
    /// low; always 0 on fungible pools.
    pub migrations: u32,
    /// Zones drained by [`FleetCore::kill_zone`] fault events.
    pub zone_kills: u32,
    /// Number of preemption events applied.
    pub preemptions: u32,
    /// Replicas taken from each member by preemptions (fleet order).
    pub preempted: Vec<u32>,
    /// ∫ budget dt — replica-seconds the pool was *paid for*.
    pub bought_replica_secs: f64,
    /// ∫ configured dt — replica-seconds actually *provisioned*.
    pub used_replica_secs: f64,
    /// Final node counts per shape, `(shape name, count)` — empty for
    /// fungible pools.
    pub nodes_final: Vec<(String, u32)>,
    /// ∫ count dt per shape — node-seconds bought, `(shape name,
    /// seconds)` — empty for fungible pools.
    pub node_secs: Vec<(String, f64)>,
    /// Final node counts per zone, `(zone, nodes)` — empty for
    /// fungible or unzoned pools.
    pub nodes_by_zone: Vec<(String, u32)>,
}

impl PoolReport {
    /// Fraction of bought replica-seconds that were provisioned.
    pub fn utilization(&self) -> f64 {
        if self.bought_replica_secs <= 0.0 {
            return 1.0;
        }
        self.used_replica_secs / self.bought_replica_secs
    }
}

/// N member cluster cores over one replica pool.
#[derive(Debug)]
pub struct FleetCore {
    cores: Vec<ClusterCore>,
    budget: u32,
    /// Heterogeneous node shapes backing the pool (None = fungible).
    /// When present, `budget` always equals its replica cap and every
    /// `apply` must bin-pack onto the nodes.
    inventory: Option<NodeInventory>,
    /// Per-member batch-timeout ceilings (SLA classes).
    timeout_caps: Vec<f64>,
    /// Per-member zone-spread flags (node pools only): flagged members'
    /// placements must survive any single zone loss, enforced by every
    /// pack this core runs.
    spread: Vec<bool>,
    /// The active per-member configurations (what a pool shrink must
    /// re-pack against).
    last_configs: Vec<PipelineConfig>,
    /// Node placement of the active configurations (node pools only).
    last_packing: Option<Packing>,
    /// ∫ count dt per shape (node pools only, shape order).
    node_secs: Vec<f64>,
    /// Highest `in_use` ever observed (rolling-reconfig overshoot
    /// included); updated by [`FleetCore::note`].
    peak_in_use: u32,
    /// Pool-size extremes over the core's lifetime.
    pool_min: u32,
    pool_max: u32,
    /// Size-changing [`FleetCore::resize_pool`] calls.
    resizes: u32,
    /// Σ replicas moved between consecutive packings.
    migrations: u32,
    /// Zones drained by [`FleetCore::kill_zone`].
    zone_kills: u32,
    /// Preemption events recorded via [`FleetCore::note_preemption`].
    preemptions: u32,
    /// Replicas reclaimed from each member by preemptions.
    preempted: Vec<u32>,
    /// Cost ledger (see [`FleetCore::accrue`]).
    last_accrual: f64,
    bought_replica_secs: f64,
    used_replica_secs: f64,
    /// Decision journal attached by the traced drivers (None = silent).
    journal: Option<Arc<Journal>>,
}

impl FleetCore {
    /// Build from per-member initial configurations.  `inits` carries
    /// (config, λ for batch-timeout shaping, drop policy) per member.
    /// Errors when the combined configuration exceeds the budget.
    pub fn new(
        budget: u32,
        inits: &[(PipelineConfig, f64, DropPolicy)],
    ) -> Result<FleetCore, String> {
        let member_inits: Vec<MemberInit> = inits
            .iter()
            .map(|(cfg, lambda, drop)| MemberInit::new(cfg.clone(), *lambda, *drop))
            .collect();
        Self::with_nodes(budget, None, &member_inits)
    }

    /// [`FleetCore::new`] with the full pool description: an optional
    /// heterogeneous node inventory (the budget then becomes its
    /// replica cap and the initial configurations must bin-pack onto
    /// the nodes) and per-member SLA-class timeout ceilings.
    pub fn with_nodes(
        budget: u32,
        inventory: Option<NodeInventory>,
        inits: &[MemberInit],
    ) -> Result<FleetCore, String> {
        Self::with_nodes_spread(budget, inventory, inits, &[])
    }

    /// [`FleetCore::with_nodes`] plus per-member zone-spread flags:
    /// flagged members' placements must span ≥ 2 failure domains per
    /// stage (when the inventory has ≥ 2 zones), at construction and
    /// on every subsequent apply/repack.
    pub fn with_nodes_spread(
        budget: u32,
        inventory: Option<NodeInventory>,
        inits: &[MemberInit],
        spread: &[bool],
    ) -> Result<FleetCore, String> {
        let budget = inventory.as_ref().map_or(budget, |i| i.replica_cap());
        let configured: u32 = inits.iter().map(|mi| mi.config.total_replicas()).sum();
        if configured > budget {
            return Err(format!(
                "fleet initial configuration needs {configured} replicas but the pool \
                 holds {budget}"
            ));
        }
        let last_configs: Vec<PipelineConfig> =
            inits.iter().map(|mi| mi.config.clone()).collect();
        let last_packing = match &inventory {
            Some(inv) => {
                let refs: Vec<&PipelineConfig> = last_configs.iter().collect();
                Some(inv.pack_sticky(&config_demands(&refs), None, spread).ok_or_else(
                    || {
                        "fleet initial configuration does not pack into the node inventory"
                            .to_string()
                    },
                )?)
            }
            None => None,
        };
        let cores: Vec<ClusterCore> = inits
            .iter()
            .map(|mi| ClusterCore::new_capped(&mi.config, mi.lambda, mi.drop, mi.timeout_cap))
            .collect();
        let n = cores.len();
        let n_shapes = inventory.as_ref().map_or(0, |i| i.pools.len());
        Ok(FleetCore {
            cores,
            budget,
            inventory,
            timeout_caps: inits.iter().map(|mi| mi.timeout_cap).collect(),
            spread: spread.to_vec(),
            last_configs,
            last_packing,
            node_secs: vec![0.0; n_shapes],
            peak_in_use: configured,
            pool_min: budget,
            pool_max: budget,
            resizes: 0,
            migrations: 0,
            zone_kills: 0,
            preemptions: 0,
            preempted: vec![0; n],
            last_accrual: 0.0,
            bought_replica_secs: 0.0,
            used_replica_secs: 0.0,
            journal: None,
        })
    }

    pub fn n_members(&self) -> usize {
        self.cores.len()
    }

    pub fn budget(&self) -> u32 {
        self.budget
    }

    /// Attach the decision journal: applies, pool resizes and zone
    /// kills are recorded as structured entries stamped with the
    /// driver's virtual time (applies use the last accrual instant —
    /// drivers accrue to `now` before applying).
    pub fn set_journal(&mut self, journal: Arc<Journal>) {
        self.journal = Some(journal);
    }

    pub fn member(&self, m: usize) -> &ClusterCore {
        &self.cores[m]
    }

    /// Mutable member access for the drivers (ingest / try_form /
    /// finish_service / forward / complete all live on [`ClusterCore`]).
    /// Call [`FleetCore::note`] after a mutation burst so peak pool
    /// usage stays tracked.
    pub fn member_mut(&mut self, m: usize) -> &mut ClusterCore {
        &mut self.cores[m]
    }

    /// All member cores as a mutable slice — the epoch-parallel DES
    /// driver splits this into disjoint per-member `&mut` for its
    /// worker fan-out.  Workers must not touch fleet-level state;
    /// peaks observed in-epoch are folded back through
    /// [`FleetCore::note_peak`] at the barrier.
    pub fn cores_mut(&mut self) -> &mut [ClusterCore] {
        &mut self.cores
    }

    /// Max-merge an externally computed pool occupancy into the peak
    /// tracker (the epoch driver reconstructs the fleet-wide `in_use`
    /// timeline from per-member contribution logs at each barrier).
    pub fn note_peak(&mut self, peak: u32) {
        if peak > self.peak_in_use {
            self.peak_in_use = peak;
        }
    }

    /// Current pool occupancy.
    pub fn pool(&self) -> PoolUsage {
        let mut configured = 0u32;
        let mut busy = 0u32;
        let mut in_use = 0u32;
        for c in &self.cores {
            configured += c.configured_replicas();
            busy += c.busy_replicas();
            for st in &c.stages {
                in_use += st.busy.max(st.replicas);
            }
        }
        PoolUsage { budget: self.budget, configured, busy, in_use }
    }

    /// Record the current occupancy into the peak tracker.
    pub fn note(&mut self) {
        let u = self.pool().in_use;
        if u > self.peak_in_use {
            self.peak_in_use = u;
        }
    }

    /// Highest pool occupancy seen so far (includes rolling-shrink
    /// overshoot — configured replicas never exceed the budget, this
    /// may).
    pub fn peak_in_use(&self) -> u32 {
        self.peak_in_use
    }

    /// Atomically activate one configuration per member (a joint
    /// decision).  Validates Σ replicas ≤ budget across the WHOLE new
    /// fleet configuration — and, on a node-backed pool, that every
    /// replica's resource vector bin-packs onto the nodes — before
    /// touching any member; on error nothing changes.
    pub fn apply(&mut self, configs: &[(PipelineConfig, f64)]) -> Result<(), String> {
        if configs.len() != self.cores.len() {
            return Err(format!(
                "fleet apply: {} configs for {} members",
                configs.len(),
                self.cores.len()
            ));
        }
        let next: u32 = configs.iter().map(|(cfg, _)| cfg.total_replicas()).sum();
        if next > self.budget {
            return Err(format!(
                "fleet apply would configure {next} replicas over a {} budget",
                self.budget
            ));
        }
        let packing = match &self.inventory {
            Some(inv) => {
                let refs: Vec<&PipelineConfig> = configs.iter().map(|(c, _)| c).collect();
                match self.pack_next(&refs) {
                    Some(p) => Some(p),
                    None => {
                        return Err(format!(
                            "fleet apply does not bin-pack into the node inventory {inv}"
                        ))
                    }
                }
            }
            None => None,
        };
        for (i, (core, (cfg, lambda))) in self.cores.iter_mut().zip(configs).enumerate() {
            core.apply_config_capped(cfg, *lambda, self.timeout_caps[i]);
        }
        self.last_configs = configs.iter().map(|(c, _)| c.clone()).collect();
        let mut moved = 0u32;
        if let Some(new) = packing {
            if let Some(prev) = &self.last_packing {
                moved = new.moved_from(prev).len() as u32;
                self.migrations += moved;
            }
            self.last_packing = Some(new);
        }
        if let Some(j) = &self.journal {
            j.record(
                self.last_accrual,
                "fleet_apply",
                Json::obj()
                    .set("configured", next as i64)
                    .set("budget", self.budget as i64)
                    .set("moved", moved as i64),
            );
        }
        self.note();
        Ok(())
    }

    /// The container churn a candidate joint configuration would pay
    /// if applied now: placements the sticky re-pack cannot inherit
    /// from the active one — node-to-node moves AND newly started
    /// replicas (both cost a container start; [`Packing::moved_from`]
    /// counts exactly this).  0 on fungible/scalar pools, on the first
    /// placement, or when the candidate does not pack (the apply will
    /// reject it anyway).  Drivers price this through the
    /// migration-charged reconfiguration delay BEFORE staging the
    /// decision.
    pub fn plan_moves(&self, configs: &[&PipelineConfig]) -> u32 {
        let (Some(inv), Some(prev)) = (&self.inventory, &self.last_packing) else {
            return 0;
        };
        if inv.is_fungible() {
            return 0; // fungible slots are a fiction: nothing moves
        }
        self.pack_next(configs).map_or(0, |p| p.moved_from(prev).len() as u32)
    }

    /// The candidate packing of `configs` against the active placement:
    /// the delta-pack fast path when the per-member config diff against
    /// [`FleetCore::apply`]'s last activation identifies unchanged
    /// members (retained verbatim — a quiet tick on a 1000-node pool
    /// re-places nothing), the full sticky pack (keep replicas where
    /// they are, plain FFD as the fallback) otherwise.  Stickiness and
    /// delta retention are optimizations, never a new way to reject a
    /// packable configuration.
    fn pack_next(&self, configs: &[&PipelineConfig]) -> Option<Packing> {
        let inv = self.inventory.as_ref()?;
        let demands = config_demands(configs);
        if crate::fleet::nodes::delta_pack_enabled() && !inv.is_fungible() {
            if let Some(prev) = &self.last_packing {
                if configs.len() == self.last_configs.len() {
                    let changed: Vec<bool> = configs
                        .iter()
                        .zip(&self.last_configs)
                        .map(|(c, old)| **c != *old)
                        .collect();
                    if changed.iter().any(|&c| !c) {
                        if let Some(p) = inv.pack_delta(&demands, prev, &changed, &self.spread) {
                            return Some(p);
                        }
                    }
                }
            }
        }
        inv.pack_prefer_sticky(&demands, self.last_packing.as_ref(), &self.spread)
    }

    /// Node placement of the active configurations (node pools only).
    pub fn last_packing(&self) -> Option<&Packing> {
        self.last_packing.as_ref()
    }

    /// The node inventory backing the pool, if any.
    pub fn inventory(&self) -> Option<&NodeInventory> {
        self.inventory.as_ref()
    }

    /// Σ configured replicas across the fleet.
    pub fn configured_replicas(&self) -> u32 {
        self.cores.iter().map(ClusterCore::configured_replicas).sum()
    }

    /// Advance the cost ledger to `now`: the elapsed span is charged at
    /// the current pool size (bought) and the current configured
    /// replica count (used).  Drivers call this at every boundary that
    /// changes either quantity — adaptation tick, joint apply,
    /// preemption, resize — and once at the end of the run, so the
    /// integrals are piecewise-exact.  Time never runs backwards: a
    /// stale `now` is a no-op.
    pub fn accrue(&mut self, now: f64) {
        let dt = now - self.last_accrual;
        if dt <= 0.0 {
            return;
        }
        self.bought_replica_secs += dt * self.budget as f64;
        self.used_replica_secs += dt * self.configured_replicas() as f64;
        if let Some(inv) = &self.inventory {
            for (s, pool) in inv.pools.iter().enumerate() {
                self.node_secs[s] += dt * pool.count as f64;
            }
        }
        self.last_accrual = now;
    }

    /// Grow or shrink the pool itself (the autoscaler's actuator).
    /// Accrues cost at the old size first, then changes the budget.
    /// Shrinking below the currently configured replicas is rejected —
    /// callers shrink configurations first (a joint apply under the
    /// smaller budget), then the pool.
    ///
    /// On a node-backed pool, `new_budget` is a replica target the
    /// inventory converges to by adding/removing WHOLE nodes of the
    /// elastic shape ([`NodeInventory::retarget`]); the active
    /// configurations are re-packed onto the changed inventory in both
    /// directions (flat node indices shift when elastic nodes come and
    /// go), and a shrink that cannot re-pack them is rejected.
    pub fn resize_pool(&mut self, now: f64, new_budget: u32) -> Result<(), String> {
        self.resize_pool_with(now, new_budget, None)
    }

    /// [`FleetCore::resize_pool`] with an inventory *mirror*: when the
    /// controller runs pressure-aware buying, the shape (and zone) it
    /// bought no longer follows from the replica target alone, so the
    /// driver passes the controller's inventory and the core adopts its
    /// counts wholesale (the shape list must match — only counts may
    /// differ).  Without a mirror the core retargets by cap exactly as
    /// before, steering shrink eviction by its own active placement.
    pub fn resize_pool_with(
        &mut self,
        now: f64,
        new_budget: u32,
        mirror: Option<&NodeInventory>,
    ) -> Result<(), String> {
        let configured = self.configured_replicas();
        if mirror.is_none() && new_budget < configured {
            return Err(format!(
                "pool resize to {new_budget} below {configured} configured replicas"
            ));
        }
        // Resolve the target to whole nodes when the pool is an
        // inventory (the cap moves in node-sized steps).
        let (target, tentative) = match (&self.inventory, mirror) {
            (Some(cur), Some(m)) => {
                if cur.pools.len() != m.pools.len()
                    || !cur.pools.iter().zip(&m.pools).all(|(a, b)| a.shape == b.shape)
                {
                    return Err("pool mirror has a different shape list".into());
                }
                (m.replica_cap(), Some(m.clone()))
            }
            (Some(inv), None) => {
                let mut t = inv.clone();
                t.retarget_with(new_budget.max(configured), None, self.last_packing.as_ref());
                (t.replica_cap(), Some(t))
            }
            (None, _) => (new_budget, None),
        };
        if target < configured {
            return Err(format!(
                "pool resize to {target} below {configured} configured replicas"
            ));
        }
        if target == self.budget
            && tentative.as_ref().is_none_or(|t| Some(t) == self.inventory.as_ref())
        {
            return Ok(());
        }
        let mut new_packing = None;
        if let Some(t) = &tentative {
            let demands = config_demands(&self.last_configs.iter().collect::<Vec<_>>());
            new_packing =
                t.pack_prefer_sticky(&demands, self.last_packing.as_ref(), &self.spread);
            if new_packing.is_none() && target < self.budget {
                return Err(format!(
                    "pool shrink to {target} strands active replicas: the remaining \
                     nodes cannot host them"
                ));
            }
        }
        self.accrue(now);
        let from = self.budget;
        self.budget = target;
        if let Some(t) = tentative {
            self.inventory = Some(t);
            // the placement is recomputed against the NEW flat node
            // layout (growth can, in pathological cases, fail the FFD
            // re-pack even with more capacity — then no placement is
            // claimed rather than a stale one kept)
            if let (Some(prev), Some(new)) = (&self.last_packing, &new_packing) {
                self.migrations += new.moved_from(prev).len() as u32;
            }
            self.last_packing = new_packing;
        }
        self.pool_min = self.pool_min.min(target);
        self.pool_max = self.pool_max.max(target);
        self.resizes += 1;
        if let Some(j) = &self.journal {
            j.record(
                now,
                "pool_resize",
                Json::obj()
                    .set("from", from as i64)
                    .set("to", target as i64)
                    .set("mirrored", mirror.is_some()),
            );
        }
        Ok(())
    }

    /// Fault actuator: drain every node in `zone` mid-run.  The budget
    /// drops to the survivor inventory's cap — possibly BELOW the
    /// configured replicas (it is an outage, not a negotiation); the
    /// stale placement is discarded and callers follow up with an
    /// emergency apply solved under the survivor pool.  Returns the
    /// number of nodes drained (0 = unknown zone / fungible / no
    /// inventory, and nothing changes).  The zone is drained, not
    /// condemned: a later autoscaler growth may repurchase into it
    /// (modeling recovery) — see [`NodeInventory::drain_zone`].
    pub fn kill_zone(&mut self, now: f64, zone: &str) -> u32 {
        let Some(inv) = &self.inventory else { return 0 };
        if inv.is_fungible()
            || !inv.pools.iter().any(|p| p.count > 0 && p.shape.zone == zone)
        {
            return 0;
        }
        self.accrue(now);
        let inv = self.inventory.as_mut().expect("checked above");
        let drained = inv.drain_zone(zone);
        self.budget = inv.replica_cap();
        self.pool_min = self.pool_min.min(self.budget);
        self.zone_kills += 1;
        self.last_packing = None;
        if let Some(j) = &self.journal {
            j.record(
                now,
                "zone_kill",
                Json::obj()
                    .set("zone", zone)
                    .set("drained_nodes", drained as i64)
                    .set("budget", self.budget as i64),
            );
        }
        drained
    }

    /// Per member, the minimum over its stages of replicas that would
    /// SURVIVE losing `zone` under the active placement — the quantity
    /// zone-spread keeps ≥ 1 for flagged members.  `None` without a
    /// node-backed placement.
    pub fn zone_survivors(&self, zone: &str) -> Option<Vec<u32>> {
        let packing = self.last_packing.as_ref()?;
        let inv = self.inventory.as_ref()?;
        let by_key = packing.survivors_of_zone(inv, zone);
        Some(
            self.cores
                .iter()
                .enumerate()
                .map(|(m, c)| {
                    (0..c.stages.len())
                        .map(|s| by_key.get(&(m, s)).copied().unwrap_or(0))
                        .min()
                        .unwrap_or(0)
                })
                .collect(),
        )
    }

    /// Record one applied preemption event: `from` lists (member,
    /// replicas reclaimed) per donor.
    pub fn note_preemption(&mut self, from: &[(usize, u32)]) {
        self.preemptions += 1;
        for &(m, k) in from {
            if let Some(c) = self.preempted.get_mut(m) {
                *c += k;
            }
        }
    }

    /// The end-of-run pool accounting snapshot (callers usually
    /// [`FleetCore::accrue`] the final instant first).
    pub fn pool_report(&self) -> PoolReport {
        // The fungible embedding must report byte-identically to the
        // classic scalar pool, so its node bookkeeping is suppressed —
        // including migrations: fungible "slots" are a fiction, nothing
        // physically moves (the scalar pool always reports 0).
        let migrations = match &self.inventory {
            Some(inv) if !inv.is_fungible() => self.migrations,
            _ => 0,
        };
        let (nodes_final, node_secs, nodes_by_zone) = match &self.inventory {
            Some(inv) if !inv.is_fungible() => (
                inv.pools.iter().map(|p| (p.shape.name.clone(), p.count)).collect(),
                inv.pools
                    .iter()
                    .zip(&self.node_secs)
                    .map(|(p, &s)| (p.shape.name.clone(), s))
                    .collect(),
                inv.nodes_by_zone(),
            ),
            _ => (Vec::new(), Vec::new(), Vec::new()),
        };
        PoolReport {
            budget: self.budget,
            pool_min: self.pool_min,
            pool_max: self.pool_max,
            peak_in_use: self.peak_in_use,
            resizes: self.resizes,
            migrations,
            zone_kills: self.zone_kills,
            preemptions: self.preemptions,
            preempted: self.preempted.clone(),
            bought_replica_secs: self.bought_replica_secs,
            used_replica_secs: self.used_replica_secs,
            nodes_final,
            node_secs,
            nodes_by_zone,
        }
    }

    /// End of run: per-member accounting, member order preserved.
    pub fn into_accountings(self) -> Vec<crate::cluster::accounting::Accounting> {
        self.cores.into_iter().map(ClusterCore::into_accounting).collect()
    }
}

/// One staged joint decision (a decision per member), its activation
/// time, the pool budget it was solved under, and an optional pool
/// *shrink* to perform after the decisions activate (growth happens
/// immediately at decision time — only the shrink must wait until the
/// smaller configuration is in force, or [`FleetCore::resize_pool`]
/// would reject it).
#[derive(Debug, Clone)]
pub struct StagedFleet {
    pub decisions: Vec<Decision>,
    pub at: f64,
    /// Controller pool budget the decisions were solved under.  A
    /// pending stage with a larger `budget` than a due shrink target
    /// means that shrink is unsafe to execute yet (the larger
    /// configuration is still in flight) — see
    /// [`FleetReconfig::max_pending_budget`].
    pub budget: u32,
    /// Pool size to shrink to once `decisions` are applied.
    pub shrink_to: Option<u32>,
}

/// FIFO apply-delay stager for joint fleet decisions — the fleet twin
/// of [`crate::cluster::reconfig::Reconfig`], kept separate so a
/// decision vector activates atomically (a member-by-member stager
/// could interleave two ticks and transiently violate the budget).
#[derive(Debug)]
pub struct FleetReconfig {
    pub apply_delay: f64,
    /// Extra activation delay charged per unit of container churn the
    /// staged decision pays — replicas moved between nodes AND replicas
    /// newly started (§4 reconfiguration cost made visible): a churny
    /// decision lands later than a stable one.
    pub migration_delay: f64,
    pending: VecDeque<StagedFleet>,
    /// Cached minimum of `pending[..].at` (`None` when empty).  The
    /// common tick — nothing due — answers [`FleetReconfig::pop_due`] /
    /// [`FleetReconfig::due_len`] / [`FleetReconfig::next_due`] in O(1)
    /// off this cache instead of scanning the queue; the scan only runs
    /// when something actually activates.  Kept exact on every mutation
    /// (`stage` min-folds it in, pops and `clear` recompute it).
    next_at: Option<f64>,
    /// Decision journal attached by the traced drivers (None = silent).
    journal: Option<Arc<Journal>>,
}

impl FleetReconfig {
    pub fn new(apply_delay: f64) -> Self {
        Self::with_migration(apply_delay, 0.0)
    }

    /// [`FleetReconfig::new`] with a per-replica migration charge:
    /// staging a decision that moves `moves` replicas activates after
    /// `apply_delay + migration_delay × moves`.
    pub fn with_migration(apply_delay: f64, migration_delay: f64) -> Self {
        FleetReconfig {
            apply_delay: apply_delay.max(0.0),
            migration_delay: migration_delay.max(0.0),
            pending: VecDeque::new(),
            next_at: None,
            journal: None,
        }
    }

    /// Attach the decision journal: every staged decision vector and
    /// every activation (including what coalescing discarded) is
    /// recorded with the driver's virtual time.
    pub fn set_journal(&mut self, journal: Arc<Journal>) {
        self.journal = Some(journal);
    }

    /// Stage a joint decision at `now`, recording the pool `budget` it
    /// was solved under (and optionally a pool shrink to perform after
    /// activation); `moves` is the replica-migration count the decision
    /// pays for ([`FleetCore::plan_moves`]), each charged at
    /// `migration_delay` on top of the apply delay.  Returns the
    /// activation time — never earlier than the uncharged one.
    pub fn stage(
        &mut self,
        now: f64,
        decisions: Vec<Decision>,
        budget: u32,
        shrink_to: Option<u32>,
        moves: u32,
    ) -> f64 {
        let at = now + self.apply_delay + self.migration_delay * moves as f64;
        if let Some(j) = &self.journal {
            let mut data = Json::obj()
                .set("at", at)
                .set("budget", budget as i64)
                .set("moves", moves as i64)
                .set("members", decisions.len() as i64);
            if let Some(s) = shrink_to {
                data = data.set("shrink_to", s as i64);
            }
            j.record(now, "stage", data);
        }
        self.pending.push_back(StagedFleet { decisions, at, budget, shrink_to });
        self.next_at = Some(match self.next_at {
            Some(x) => x.min(at),
            None => at,
        });
        at
    }

    /// Largest solve budget among still-pending stages — a due shrink
    /// below this would strand an in-flight (bigger) configuration,
    /// so drivers skip it.  `None` when nothing is pending.
    pub fn max_pending_budget(&self) -> Option<u32> {
        self.pending.iter().map(|s| s.budget).max()
    }

    /// Drain every staged decision up to the NEWEST-staged one whose
    /// activation time has come, and return that one (coalescing).  A
    /// joint decision fully supersedes any older-staged one — applying
    /// a stale configuration for an instant before the current one
    /// would churn every member core for nothing — so when a slow tick
    /// lets several stages come due together, the older ones (and any
    /// pool shrink they carried, which was computed against a budget
    /// that no longer reflects the controller's view) are discarded,
    /// never left queued.
    ///
    /// Activation times are NOT monotone in staging order: the per-move
    /// migration charge can make an earlier, churnier decision land
    /// LATER than a subsequent stable one.  The scan therefore covers
    /// the whole queue, not just a front prefix — a stable decision is
    /// never stuck behind a stale churny one, and once it applies the
    /// older entry is dropped rather than left to revert it later.
    pub fn pop_due(&mut self, now: f64) -> Option<StagedFleet> {
        // O(1) fast path off the cached minimum: the common tick has
        // nothing due and never touches the queue.
        match self.next_at {
            Some(a) if a <= now + 1e-9 => {}
            _ => return None,
        }
        let last_due = self
            .pending
            .iter()
            .enumerate()
            .filter(|(_, s)| s.at <= now + 1e-9)
            .map(|(i, _)| i)
            .last()?;
        let mut newest = None;
        for _ in 0..=last_due {
            newest = self.pending.pop_front();
        }
        self.next_at = self.pending.iter().map(|s| s.at).reduce(f64::min);
        if let (Some(j), Some(s)) = (&self.journal, &newest) {
            j.record(
                now,
                "activate",
                Json::obj()
                    .set("due_at", s.at)
                    .set("budget", s.budget as i64)
                    .set("coalesced", last_due as i64),
            );
        }
        newest
    }

    /// Staged fleets discarded by coalescing so far would be invisible;
    /// expose how many entries are due at `now` for diagnostics/tests.
    /// (Whole-queue scan only when the cached minimum says something IS
    /// due; migration charges break `at` monotonicity.)
    pub fn due_len(&self, now: f64) -> usize {
        match self.next_at {
            Some(a) if a <= now + 1e-9 => {
                self.pending.iter().filter(|s| s.at <= now + 1e-9).count()
            }
            _ => 0,
        }
    }

    /// Discard everything staged (a preemption superseded it: the fast
    /// path's decision vector is newer than any queued slow-path one,
    /// and letting a stale stage activate later would silently revert
    /// the preemption).  Returns how many stages were discarded.
    pub fn clear(&mut self) -> usize {
        let n = self.pending.len();
        self.pending.clear();
        self.next_at = None;
        n
    }

    /// Earliest pending activation time (NOT the front entry's — see
    /// [`FleetReconfig::pop_due`] on why `at` is not monotone).  O(1)
    /// off the cached minimum.
    pub fn next_due(&self) -> Option<f64> {
        self.next_at
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::core::FormOutcome;
    use crate::optimizer::ip::StageConfig;

    use crate::fleet::nodes::{NodeInventory, PackItem};
    use crate::resources::ResourceVec;

    fn config(stages: &[(usize, u32)]) -> PipelineConfig {
        config_res(stages, ResourceVec::cpu(1.0))
    }

    fn config_res(stages: &[(usize, u32)], resources: ResourceVec) -> PipelineConfig {
        PipelineConfig {
            stages: stages
                .iter()
                .enumerate()
                .map(|(i, &(batch, replicas))| StageConfig {
                    variant_idx: 0,
                    variant_key: format!("v{i}"),
                    batch,
                    replicas,
                    cost: 1.0,
                    accuracy: 90.0,
                    latency: 0.1,
                    resources,
                })
                .collect(),
            pas: 90.0,
            cost: 2.0,
            batch_sum: stages.iter().map(|s| s.0).sum(),
            objective: 0.0,
            latency_e2e: 0.2,
            resources: ResourceVec::ZERO,
        }
    }

    fn two_member_fleet(budget: u32) -> FleetCore {
        FleetCore::new(
            budget,
            &[
                (config(&[(1, 2), (1, 1)]), 10.0, DropPolicy::new(1.0, true)),
                (config(&[(1, 1)]), 10.0, DropPolicy::new(1.0, true)),
            ],
        )
        .unwrap()
    }

    #[test]
    fn new_rejects_over_budget_init() {
        let inits = vec![
            (config(&[(1, 4), (1, 4)]), 10.0, DropPolicy::new(1.0, true)),
            (config(&[(1, 4)]), 10.0, DropPolicy::new(1.0, true)),
        ];
        assert!(FleetCore::new(11, &inits).is_err());
        assert!(FleetCore::new(12, &inits).is_ok());
    }

    #[test]
    fn apply_is_atomic_and_budget_checked() {
        let mut f = two_member_fleet(4);
        // over budget: 3 + 2 = 5 > 4 — rejected, nothing changes
        let err = f.apply(&[(config(&[(1, 2), (1, 1)]), 10.0), (config(&[(1, 2)]), 10.0)]);
        assert!(err.is_err());
        assert_eq!(f.configured_replicas(), 4);
        assert_eq!(f.member(1).stages[0].replicas, 1);
        // wrong arity rejected
        assert!(f.apply(&[(config(&[(1, 1)]), 10.0)]).is_err());
        // within budget: applied to every member
        f.apply(&[(config(&[(2, 1), (1, 1)]), 10.0), (config(&[(4, 2)]), 10.0)]).unwrap();
        assert_eq!(f.configured_replicas(), 4);
        assert_eq!(f.member(1).stages[0].replicas, 2);
        assert_eq!(f.member(1).stages[0].batch, 4);
    }

    #[test]
    fn pool_tracks_rolling_shrink_overshoot() {
        let mut f = two_member_fleet(4);
        // occupy both replicas of member 0 stage 0
        f.member_mut(0).ingest(0, 0.0);
        f.member_mut(0).ingest(1, 0.0);
        assert!(matches!(f.member_mut(0).try_form(0, 0.0), FormOutcome::Formed(_)));
        assert!(matches!(f.member_mut(0).try_form(0, 0.0), FormOutcome::Formed(_)));
        f.note();
        assert_eq!(f.pool().busy, 2);
        // shrink member 0 stage 0 to 1 replica while 2 batches in flight
        f.apply(&[(config(&[(1, 1), (1, 1)]), 10.0), (config(&[(1, 1)]), 10.0)]).unwrap();
        let u = f.pool();
        assert_eq!(u.configured, 3);
        assert!(u.configured <= u.budget);
        assert_eq!(u.in_use, 4, "old batches keep their slots until done");
        assert!(f.peak_in_use() >= 4);
        f.member_mut(0).finish_service(0);
        f.member_mut(0).finish_service(0);
        f.note();
        assert_eq!(f.pool().in_use, 3);
    }

    #[test]
    fn member_accounting_is_isolated() {
        let mut f = two_member_fleet(4);
        f.member_mut(0).ingest(0, 0.0);
        f.member_mut(1).ingest(0, 0.0);
        f.member_mut(1).complete(0, 0.5);
        let accs = f.into_accountings();
        assert_eq!(accs.len(), 2);
        assert_eq!(accs[0].completed_count(), 0);
        assert_eq!(accs[1].completed_count(), 1);
    }

    #[test]
    fn fleet_reconfig_fifo_after_delay() {
        let d = |pas: f64| Decision {
            config: PipelineConfig {
                stages: Vec::new(),
                pas,
                cost: 1.0,
                batch_sum: 0,
                objective: 0.0,
                latency_e2e: 0.0,
                resources: ResourceVec::ZERO,
            },
            lambda_predicted: 10.0,
            decision_time: 0.0,
            fallback: false,
        };
        let mut r = FleetReconfig::new(8.0);
        assert_eq!(r.stage(10.0, vec![d(1.0), d(2.0)], 8, None, 0), 18.0);
        assert_eq!(r.stage(20.0, vec![d(3.0), d(4.0)], 8, None, 0), 28.0);
        assert_eq!(r.pending_len(), 2);
        assert!(r.pop_due(17.9).is_none());
        let first = r.pop_due(18.0).unwrap();
        assert_eq!(first.decisions.len(), 2);
        assert_eq!(first.decisions[0].config.pas, 1.0);
        assert_eq!(r.next_due(), Some(28.0));
        assert!(r.pop_due(20.0).is_none());
        assert_eq!(r.pop_due(30.0).unwrap().decisions[1].config.pas, 4.0);
        assert_eq!(r.pending_len(), 0);
    }

    /// Regression: several stages due together must all drain in ONE
    /// pop — the oldest superseded, the newest returned, nothing left
    /// queued for a later (stale) application.
    #[test]
    fn fleet_reconfig_pop_due_coalesces_all_due_stages() {
        let d = |pas: f64| Decision {
            config: PipelineConfig {
                stages: Vec::new(),
                pas,
                cost: 1.0,
                batch_sum: 0,
                objective: 0.0,
                latency_e2e: 0.0,
                resources: ResourceVec::ZERO,
            },
            lambda_predicted: 10.0,
            decision_time: 0.0,
            fallback: false,
        };
        let mut r = FleetReconfig::new(8.0);
        r.stage(10.0, vec![d(1.0)], 9, Some(9), 0);
        r.stage(20.0, vec![d(2.0)], 12, None, 0);
        r.stage(30.0, vec![d(3.0)], 10, None, 0);
        // a slow tick: all three are due by t=40
        assert_eq!(r.due_len(40.0), 3);
        assert_eq!(r.max_pending_budget(), Some(12));
        let s = r.pop_due(40.0).expect("newest staged fleet");
        assert_eq!(s.decisions[0].config.pas, 3.0, "newest wins");
        assert_eq!(s.shrink_to, None, "stale shrink discarded with its stage");
        assert_eq!(s.budget, 10);
        assert_eq!(r.pending_len(), 0, "nothing stale left queued");
        assert_eq!(r.max_pending_budget(), None);
        assert!(r.pop_due(100.0).is_none());
    }

    /// Regression for the cached-minimum fast path: migration charges
    /// make activation times NON-monotone in staging order (an older,
    /// churnier decision lands later than a newer stable one), so the
    /// cache must track the true minimum across the whole queue — not
    /// the front entry — and be recomputed after pops.
    #[test]
    fn fleet_reconfig_cached_min_survives_non_monotone_staging() {
        let d = |pas: f64| Decision {
            config: PipelineConfig {
                stages: Vec::new(),
                pas,
                cost: 1.0,
                batch_sum: 0,
                objective: 0.0,
                latency_e2e: 0.0,
                resources: ResourceVec::ZERO,
            },
            lambda_predicted: 10.0,
            decision_time: 0.0,
            fallback: false,
        };
        let mut r = FleetReconfig::with_migration(8.0, 0.5);
        // churny decision staged FIRST: at = 10 + 8 + 0.5×20 = 28
        assert_eq!(r.stage(10.0, vec![d(1.0)], 8, None, 20), 28.0);
        // stable decision staged second lands EARLIER: at = 12 + 8 = 20
        assert_eq!(r.stage(12.0, vec![d(2.0)], 8, None, 0), 20.0);
        // the cache is the true minimum, not the front entry's 28
        assert_eq!(r.next_due(), Some(20.0));
        assert_eq!(r.due_len(19.0), 0, "fast path: nothing due yet");
        assert!(r.pop_due(19.0).is_none());
        assert_eq!(r.due_len(20.0), 1);
        // the stable decision applies at 20 and supersedes the churny
        // one queued in front of it
        let s = r.pop_due(20.0).expect("stable decision is due");
        assert_eq!(s.decisions[0].config.pas, 2.0);
        assert_eq!(r.pending_len(), 0, "older churny stage superseded");
        assert_eq!(r.next_due(), None, "cache recomputed after pop");
        // restage + clear resets the cache
        r.stage(30.0, vec![d(3.0)], 8, None, 4);
        assert_eq!(r.next_due(), Some(40.0));
        r.clear();
        assert_eq!(r.next_due(), None);
        assert!(r.pop_due(1e9).is_none());
    }

    #[test]
    fn resize_pool_bounds_and_extremes() {
        let mut f = two_member_fleet(4);
        assert_eq!(f.configured_replicas(), 4);
        // grow is always fine
        f.resize_pool(10.0, 9).unwrap();
        assert_eq!(f.budget(), 9);
        // shrink below configured replicas is rejected
        assert!(f.resize_pool(20.0, 3).is_err());
        assert_eq!(f.budget(), 9);
        // shrink to exactly configured is fine
        f.resize_pool(30.0, 4).unwrap();
        let rep = f.pool_report();
        assert_eq!((rep.pool_min, rep.pool_max), (4, 9));
        assert_eq!(rep.resizes, 2);
        // no-op resize does not count
        f.resize_pool(31.0, 4).unwrap();
        assert_eq!(f.pool_report().resizes, 2);
    }

    #[test]
    fn cost_ledger_integrates_bought_vs_used() {
        let mut f = two_member_fleet(8); // 4 configured of 8 bought
        f.accrue(10.0);
        let r = f.pool_report();
        assert!((r.bought_replica_secs - 80.0).abs() < 1e-9, "{}", r.bought_replica_secs);
        assert!((r.used_replica_secs - 40.0).abs() < 1e-9, "{}", r.used_replica_secs);
        assert!((r.utilization() - 0.5).abs() < 1e-9);
        // time never runs backwards
        f.accrue(5.0);
        assert!((f.pool_report().bought_replica_secs - 80.0).abs() < 1e-9);
        // a resize accrues at the old size first, then charges the new
        f.resize_pool(20.0, 16).unwrap();
        f.accrue(30.0);
        let r = f.pool_report();
        // 10s × 8 + 10s × 16 = 240 bought; 30s × 4 = 120 used
        assert!((r.bought_replica_secs - 240.0).abs() < 1e-9, "{}", r.bought_replica_secs);
        assert!((r.used_replica_secs - 120.0).abs() < 1e-9, "{}", r.used_replica_secs);
    }

    fn node_inits(replicas: &[(u32, ResourceVec)]) -> Vec<MemberInit> {
        replicas
            .iter()
            .map(|&(n, r)| {
                MemberInit::new(config_res(&[(1, n)], r), 10.0, DropPolicy::new(1.0, true))
            })
            .collect()
    }

    #[test]
    fn with_nodes_packs_or_rejects_at_construction() {
        let inv = NodeInventory::parse("2x(8c,32g,0a)+1x(16c,64g,2a)").unwrap();
        // 2 accel replicas + 4 cpu replicas: fits (accel on the big node)
        let inits = node_inits(&[
            (2, ResourceVec::new(8.0, 4.0, 1.0)),
            (4, ResourceVec::new(2.0, 2.0, 0.0)),
        ]);
        let f = FleetCore::with_nodes(0, Some(inv.clone()), &inits).unwrap();
        assert_eq!(f.budget(), inv.replica_cap(), "budget is the inventory cap");
        let packing = f.last_packing().expect("node pools track their placement");
        assert!(packing.valid_for(f.inventory().unwrap()));
        // 3 accel replicas cannot fit 2 accel slots
        let over = node_inits(&[(3, ResourceVec::new(8.0, 4.0, 1.0))]);
        assert!(FleetCore::with_nodes(0, Some(inv), &over).is_err());
    }

    #[test]
    fn apply_packs_on_node_pools() {
        let inv = NodeInventory::parse("2x(8c,32g,0a)+1x(16c,64g,2a)").unwrap();
        let inits = node_inits(&[
            (1, ResourceVec::new(8.0, 4.0, 1.0)),
            (2, ResourceVec::new(2.0, 2.0, 0.0)),
        ]);
        let mut f = FleetCore::with_nodes(0, Some(inv), &inits).unwrap();
        // within pack limits: accepted
        f.apply(&[
            (config_res(&[(1, 2)], ResourceVec::new(8.0, 4.0, 1.0)), 10.0),
            (config_res(&[(1, 4)], ResourceVec::new(2.0, 2.0, 0.0)), 10.0),
        ])
        .unwrap();
        assert_eq!(f.configured_replicas(), 6);
        // 3 accel replicas over 2 accel slots: rejected atomically,
        // nothing changes even though Σ replicas fits the budget
        let err = f.apply(&[
            (config_res(&[(1, 3)], ResourceVec::new(8.0, 4.0, 1.0)), 10.0),
            (config_res(&[(1, 1)], ResourceVec::new(2.0, 2.0, 0.0)), 10.0),
        ]);
        assert!(err.is_err());
        assert_eq!(f.configured_replicas(), 6, "rejected apply must not touch members");
    }

    #[test]
    fn node_resize_moves_whole_nodes_and_guards_shrink() {
        let inv = NodeInventory::parse("2x(4c,16g,0a)+1x(16c,64g,2a)").unwrap();
        // one 8c accel replica on the big node
        let inits = node_inits(&[(1, ResourceVec::new(8.0, 4.0, 1.0))]);
        let mut f = FleetCore::with_nodes(0, Some(inv), &inits).unwrap();
        assert_eq!(f.budget(), 2 * 4 + 16);
        // grow toward 40: whole 4-slot nodes, never past the target
        f.resize_pool(10.0, 40).unwrap();
        assert_eq!(f.budget(), 40, "24 + 4×4 = 40");
        assert_eq!(f.inventory().unwrap().pools[0].count, 6);
        // shrink toward 16: elastic nodes drain (they host nothing)
        f.resize_pool(20.0, 16).unwrap();
        assert_eq!(f.budget(), 16, "all elastic nodes removed, big node fixed");
        assert_eq!(f.inventory().unwrap().pools[0].count, 0);
        let rep = f.pool_report();
        assert_eq!(rep.resizes, 2);
        assert_eq!(rep.nodes_final.len(), 2);
        assert_eq!(rep.nodes_final[0].1, 0);
        assert_eq!(rep.nodes_final[1].1, 1);
        // node-seconds: shape0 held 2 nodes for 10 s then 6 for 10 s;
        // shape1 one node for 20 s (accrual at the resize boundaries)
        f.accrue(20.0);
        let rep = f.pool_report();
        assert!((rep.node_secs[0].1 - (2.0 * 10.0 + 6.0 * 10.0)).abs() < 1e-9);
        assert!((rep.node_secs[1].1 - 20.0).abs() < 1e-9);
    }

    #[test]
    fn node_shrink_rejected_when_replicas_would_strand() {
        // the elastic 8c shape (accel tie-break keeps the accel node
        // special) hosts the replicas; the remaining 16c node cannot
        // take all three 8-core replicas at once
        let inv = NodeInventory::parse("2x(8c,32g,0a)+1x(16c,64g,1a)").unwrap();
        assert_eq!(inv.elastic_idx(), 0, "8c shape is the elastic one");
        let inits = node_inits(&[(3, ResourceVec::new(8.0, 4.0, 0.0))]);
        let mut f = FleetCore::with_nodes(0, Some(inv), &inits).unwrap();
        assert_eq!(f.budget(), 32);
        // shrinking toward 3 would sell BOTH 8c nodes (24 cpu of
        // replica demand cannot re-pack onto the 16c survivor)
        assert!(f.resize_pool(5.0, 3).is_err());
        assert_eq!(f.budget(), 32, "rejected shrink leaves the pool untouched");
    }

    #[test]
    fn fungible_inventory_reports_like_the_scalar_pool() {
        let inits = node_inits(&[(2, ResourceVec::cpu(1.0)), (1, ResourceVec::cpu(1.0))]);
        let mut a = FleetCore::with_nodes(0, Some(NodeInventory::fungible(4)), &inits).unwrap();
        let mut b = FleetCore::with_nodes(4, None, &inits).unwrap();
        a.accrue(10.0);
        b.accrue(10.0);
        assert_eq!(a.pool_report(), b.pool_report(), "fungible embedding is invisible");
        // the packing itself still enforces the slot rule
        let inv = NodeInventory::fungible(4);
        let items =
            [PackItem { member: 0, stage: 0, unit: ResourceVec::cpu(16.0), replicas: 5 }];
        assert!(inv.pack(&items).is_none(), "5 replicas over 4 slots");
    }

    #[test]
    fn timeout_caps_flow_through_apply() {
        let mut inits = node_inits(&[(1, ResourceVec::cpu(1.0))]);
        inits[0].timeout_cap = 0.2;
        // λ=2, batch 8 → uncapped timeout would be 5.25 s
        inits[0].config = config(&[(8, 1)]);
        let mut f = FleetCore::with_nodes(4, None, &inits).unwrap();
        assert!((f.member(0).stages[0].dispatcher.timeout() - 0.2).abs() < 1e-9);
        f.apply(&[(config(&[(8, 1)]), 2.0)]).unwrap();
        assert!(
            (f.member(0).stages[0].dispatcher.timeout() - 0.2).abs() < 1e-9,
            "the class ceiling survives reconfiguration"
        );
    }

    #[test]
    fn sticky_apply_counts_only_real_migrations() {
        let inv = NodeInventory::parse("2x(8c,32g,0a)+1x(16c,64g,2a)").unwrap();
        let inits = node_inits(&[(2, ResourceVec::new(4.0, 4.0, 0.0))]);
        let mut f = FleetCore::with_nodes(0, Some(inv), &inits).unwrap();
        let cfg = |n| (config_res(&[(1, n)], ResourceVec::new(4.0, 4.0, 0.0)), 10.0);
        // re-applying the same configuration moves nothing
        f.apply(&[cfg(2)]).unwrap();
        assert_eq!(f.pool_report().migrations, 0, "unchanged config must not migrate");
        // growth places NEW replicas (each counts as one move) but the
        // existing ones stay put
        f.apply(&[cfg(4)]).unwrap();
        assert_eq!(f.pool_report().migrations, 2, "two new replicas, zero displaced");
        // plan_moves prices the same diff without committing it
        let (next, _) = cfg(6);
        assert_eq!(f.plan_moves(&[&next]), 2);
        assert_eq!(f.pool_report().migrations, 2, "plan_moves is read-only");
    }

    #[test]
    fn kill_zone_drains_nodes_and_lowers_the_budget() {
        let inv =
            NodeInventory::parse("2x(8c,32g,0a)@east+2x(8c,32g,0a)@west").unwrap();
        let inits = node_inits(&[(2, ResourceVec::new(4.0, 4.0, 0.0))]);
        let mut f = FleetCore::with_nodes(0, Some(inv), &inits).unwrap();
        assert_eq!(f.budget(), 32);
        assert!(f.zone_survivors("east").is_some());
        // unknown zone: no-op
        assert_eq!(f.kill_zone(5.0, "nowhere"), 0);
        assert_eq!(f.budget(), 32);
        // draining west halves the pool and discards the placement
        assert_eq!(f.kill_zone(10.0, "west"), 2);
        assert_eq!(f.budget(), 16);
        let rep = f.pool_report();
        assert_eq!(rep.zone_kills, 1);
        assert_eq!(rep.pool_min, 16);
        assert_eq!(rep.nodes_by_zone, vec![("east".to_string(), 2), ("west".to_string(), 0)]);
        assert!(f.last_packing().is_none(), "stale placement discarded");
        // an emergency apply re-packs onto the survivors
        f.apply(&[(config_res(&[(1, 2)], ResourceVec::new(4.0, 4.0, 0.0)), 10.0)]).unwrap();
        assert!(f.last_packing().is_some());
    }

    #[test]
    fn migration_charge_never_activates_earlier_than_uncharged() {
        let d = || Decision {
            config: PipelineConfig {
                stages: Vec::new(),
                pas: 1.0,
                cost: 1.0,
                batch_sum: 0,
                objective: 0.0,
                latency_e2e: 0.0,
                resources: ResourceVec::ZERO,
            },
            lambda_predicted: 10.0,
            decision_time: 0.0,
            fallback: false,
        };
        let mut plain = FleetReconfig::new(8.0);
        let mut charged = FleetReconfig::with_migration(8.0, 0.5);
        assert_eq!(plain.stage(10.0, vec![d()], 8, None, 3), 18.0, "uncharged ignores moves");
        assert_eq!(charged.stage(10.0, vec![d()], 8, None, 3), 19.5, "3 moves × 0.5s");
        assert_eq!(charged.stage(20.0, vec![d()], 8, None, 0), 28.0, "stable decision pays 0");
    }

    /// Regression: migration charges make activation times NON-MONOTONE
    /// in staging order — a stable decision staged after a churny one
    /// must still land at ITS (earlier) time, and the stale churny
    /// entry must be dropped, never applied later to revert it.
    #[test]
    fn fleet_reconfig_stable_decision_not_stuck_behind_churny_one() {
        let d = |pas: f64| Decision {
            config: PipelineConfig {
                stages: Vec::new(),
                pas,
                cost: 1.0,
                batch_sum: 0,
                objective: 0.0,
                latency_e2e: 0.0,
                resources: ResourceVec::ZERO,
            },
            lambda_predicted: 10.0,
            decision_time: 0.0,
            fallback: false,
        };
        let mut r = FleetReconfig::with_migration(8.0, 0.5);
        // churny decision at t=10: 30 moves -> lands at 33
        assert_eq!(r.stage(10.0, vec![d(1.0)], 8, None, 30), 33.0);
        // stable decision at t=20: 0 moves -> lands at 28, BEFORE it
        assert_eq!(r.stage(20.0, vec![d(2.0)], 8, None, 0), 28.0);
        assert_eq!(r.next_due(), Some(28.0), "earliest activation, not front's");
        assert_eq!(r.due_len(28.0), 1);
        let s = r.pop_due(28.0).expect("stable decision lands at its own time");
        assert_eq!(s.decisions[0].config.pas, 2.0, "the NEWER decision applies");
        assert_eq!(r.pending_len(), 0, "stale churny entry dropped, never applied");
        assert!(r.pop_due(100.0).is_none());
    }

    #[test]
    fn resize_pool_with_mirror_adopts_the_controller_inventory() {
        let inv = NodeInventory::parse("2x(4c,16g,0a)@east+2x(4c,16g,0a)@west").unwrap();
        let inits = node_inits(&[(2, ResourceVec::new(4.0, 4.0, 0.0))]);
        let mut f = FleetCore::with_nodes(0, Some(inv.clone()), &inits).unwrap();
        // the controller bought a west node the cap alone cannot express
        let mut mirror = inv.clone();
        mirror.pools[1].count = 3;
        f.resize_pool_with(10.0, mirror.replica_cap(), Some(&mirror)).unwrap();
        assert_eq!(f.inventory().unwrap(), &mirror, "counts adopted wholesale");
        assert_eq!(f.budget(), 20);
        // a mirror with a different shape LIST is rejected
        let alien = NodeInventory::parse("4x(8c,32g,0a)").unwrap();
        assert!(f.resize_pool_with(20.0, 32, Some(&alien)).is_err());
    }

    #[test]
    fn preemption_counters_accumulate_per_member() {
        let mut f = two_member_fleet(4);
        f.note_preemption(&[(1, 2)]);
        f.note_preemption(&[(0, 1), (1, 1)]);
        let r = f.pool_report();
        assert_eq!(r.preemptions, 2);
        assert_eq!(r.preempted, vec![1, 3]);
    }
}
