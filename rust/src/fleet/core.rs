//! The shared-pool fleet core: one [`ClusterCore`] per member pipeline
//! plus the accounting that enforces the global replica budget.
//!
//! [`FleetCore`] is clock-agnostic exactly like the single-pipeline
//! core: the DES fleet driver feeds it virtual time, the live fleet
//! engine wall-clock time.  Its job beyond fan-out is the *budget
//! invariant*:
//!
//! * configured replicas — Σ over every stage of every member of the
//!   active replica count — never exceed the pool
//!   ([`FleetCore::new`] / [`FleetCore::apply`] reject violating
//!   configurations before touching any member);
//! * during a rolling reconfiguration, batches in flight on shrunk
//!   stages keep their old slots busy (`busy > replicas`), so the pool
//!   can transiently hold more work than it is configured for — the
//!   core tracks that overshoot ([`PoolUsage::in_use`],
//!   [`FleetCore::peak_in_use`]) instead of pretending it away, which
//!   is precisely the §5.3 rolling-update semantics at fleet scope.
//!
//! [`FleetReconfig`] is the joint apply-delay stager: one decision
//! *vector* per tick, activated atomically so the budget check always
//! sees the whole fleet's next configuration.

use std::collections::VecDeque;

use crate::cluster::core::ClusterCore;
use crate::cluster::drop_policy::DropPolicy;
use crate::coordinator::adapter::Decision;
use crate::optimizer::ip::PipelineConfig;

/// Pool occupancy snapshot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolUsage {
    /// The global replica budget.
    pub budget: u32,
    /// Σ configured replicas across every member stage (≤ `budget`).
    pub configured: u32,
    /// Σ busy slots across every member stage.
    pub busy: u32,
    /// Σ per-stage `max(busy, replicas)` — what the pool is physically
    /// holding right now; exceeds `configured` only during a rolling
    /// shrink while old batches drain.
    pub in_use: u32,
}

/// N member cluster cores over one replica pool.
#[derive(Debug)]
pub struct FleetCore {
    cores: Vec<ClusterCore>,
    budget: u32,
    /// Highest `in_use` ever observed (rolling-reconfig overshoot
    /// included); updated by [`FleetCore::note`].
    peak_in_use: u32,
}

impl FleetCore {
    /// Build from per-member initial configurations.  `inits` carries
    /// (config, λ for batch-timeout shaping, drop policy) per member.
    /// Errors when the combined configuration exceeds the budget.
    pub fn new(
        budget: u32,
        inits: &[(PipelineConfig, f64, DropPolicy)],
    ) -> Result<FleetCore, String> {
        let configured: u32 = inits.iter().map(|(cfg, _, _)| cfg.total_replicas()).sum();
        if configured > budget {
            return Err(format!(
                "fleet initial configuration needs {configured} replicas but the pool \
                 holds {budget}"
            ));
        }
        let cores = inits
            .iter()
            .map(|(cfg, lambda, drop)| ClusterCore::new(cfg, *lambda, *drop))
            .collect();
        Ok(FleetCore { cores, budget, peak_in_use: configured })
    }

    pub fn n_members(&self) -> usize {
        self.cores.len()
    }

    pub fn budget(&self) -> u32 {
        self.budget
    }

    pub fn member(&self, m: usize) -> &ClusterCore {
        &self.cores[m]
    }

    /// Mutable member access for the drivers (ingest / try_form /
    /// finish_service / forward / complete all live on [`ClusterCore`]).
    /// Call [`FleetCore::note`] after a mutation burst so peak pool
    /// usage stays tracked.
    pub fn member_mut(&mut self, m: usize) -> &mut ClusterCore {
        &mut self.cores[m]
    }

    /// Current pool occupancy.
    pub fn pool(&self) -> PoolUsage {
        let mut configured = 0u32;
        let mut busy = 0u32;
        let mut in_use = 0u32;
        for c in &self.cores {
            configured += c.configured_replicas();
            busy += c.busy_replicas();
            for st in &c.stages {
                in_use += st.busy.max(st.replicas);
            }
        }
        PoolUsage { budget: self.budget, configured, busy, in_use }
    }

    /// Record the current occupancy into the peak tracker.
    pub fn note(&mut self) {
        let u = self.pool().in_use;
        if u > self.peak_in_use {
            self.peak_in_use = u;
        }
    }

    /// Highest pool occupancy seen so far (includes rolling-shrink
    /// overshoot — configured replicas never exceed the budget, this
    /// may).
    pub fn peak_in_use(&self) -> u32 {
        self.peak_in_use
    }

    /// Atomically activate one configuration per member (a joint
    /// decision).  Validates Σ replicas ≤ budget across the WHOLE new
    /// fleet configuration before touching any member; on error nothing
    /// changes.
    pub fn apply(&mut self, configs: &[(PipelineConfig, f64)]) -> Result<(), String> {
        if configs.len() != self.cores.len() {
            return Err(format!(
                "fleet apply: {} configs for {} members",
                configs.len(),
                self.cores.len()
            ));
        }
        let next: u32 = configs.iter().map(|(cfg, _)| cfg.total_replicas()).sum();
        if next > self.budget {
            return Err(format!(
                "fleet apply would configure {next} replicas over a {} budget",
                self.budget
            ));
        }
        for (core, (cfg, lambda)) in self.cores.iter_mut().zip(configs) {
            core.apply_config(cfg, *lambda);
        }
        self.note();
        Ok(())
    }

    /// Σ configured replicas across the fleet.
    pub fn configured_replicas(&self) -> u32 {
        self.cores.iter().map(ClusterCore::configured_replicas).sum()
    }

    /// End of run: per-member accounting, member order preserved.
    pub fn into_accountings(self) -> Vec<crate::cluster::accounting::Accounting> {
        self.cores.into_iter().map(ClusterCore::into_accounting).collect()
    }
}

/// One staged joint decision (a decision per member) and its activation
/// time.
#[derive(Debug, Clone)]
pub struct StagedFleet {
    pub decisions: Vec<Decision>,
    pub at: f64,
}

/// FIFO apply-delay stager for joint fleet decisions — the fleet twin
/// of [`crate::cluster::reconfig::Reconfig`], kept separate so a
/// decision vector activates atomically (a member-by-member stager
/// could interleave two ticks and transiently violate the budget).
#[derive(Debug)]
pub struct FleetReconfig {
    pub apply_delay: f64,
    pending: VecDeque<StagedFleet>,
}

impl FleetReconfig {
    pub fn new(apply_delay: f64) -> Self {
        FleetReconfig { apply_delay: apply_delay.max(0.0), pending: VecDeque::new() }
    }

    /// Stage a joint decision at `now`; returns its activation time.
    pub fn stage(&mut self, now: f64, decisions: Vec<Decision>) -> f64 {
        let at = now + self.apply_delay;
        self.pending.push_back(StagedFleet { decisions, at });
        at
    }

    /// Pop the oldest staged decision whose activation time has come.
    pub fn pop_due(&mut self, now: f64) -> Option<StagedFleet> {
        if self.pending.front().is_some_and(|s| s.at <= now + 1e-9) {
            self.pending.pop_front()
        } else {
            None
        }
    }

    pub fn next_due(&self) -> Option<f64> {
        self.pending.front().map(|s| s.at)
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::core::FormOutcome;
    use crate::optimizer::ip::StageConfig;

    fn config(stages: &[(usize, u32)]) -> PipelineConfig {
        PipelineConfig {
            stages: stages
                .iter()
                .enumerate()
                .map(|(i, &(batch, replicas))| StageConfig {
                    variant_idx: 0,
                    variant_key: format!("v{i}"),
                    batch,
                    replicas,
                    cost: 1.0,
                    accuracy: 90.0,
                    latency: 0.1,
                })
                .collect(),
            pas: 90.0,
            cost: 2.0,
            batch_sum: stages.iter().map(|s| s.0).sum(),
            objective: 0.0,
            latency_e2e: 0.2,
        }
    }

    fn two_member_fleet(budget: u32) -> FleetCore {
        FleetCore::new(
            budget,
            &[
                (config(&[(1, 2), (1, 1)]), 10.0, DropPolicy::new(1.0, true)),
                (config(&[(1, 1)]), 10.0, DropPolicy::new(1.0, true)),
            ],
        )
        .unwrap()
    }

    #[test]
    fn new_rejects_over_budget_init() {
        let inits = vec![
            (config(&[(1, 4), (1, 4)]), 10.0, DropPolicy::new(1.0, true)),
            (config(&[(1, 4)]), 10.0, DropPolicy::new(1.0, true)),
        ];
        assert!(FleetCore::new(11, &inits).is_err());
        assert!(FleetCore::new(12, &inits).is_ok());
    }

    #[test]
    fn apply_is_atomic_and_budget_checked() {
        let mut f = two_member_fleet(4);
        // over budget: 3 + 2 = 5 > 4 — rejected, nothing changes
        let err = f.apply(&[(config(&[(1, 2), (1, 1)]), 10.0), (config(&[(1, 2)]), 10.0)]);
        assert!(err.is_err());
        assert_eq!(f.configured_replicas(), 4);
        assert_eq!(f.member(1).stages[0].replicas, 1);
        // wrong arity rejected
        assert!(f.apply(&[(config(&[(1, 1)]), 10.0)]).is_err());
        // within budget: applied to every member
        f.apply(&[(config(&[(2, 1), (1, 1)]), 10.0), (config(&[(4, 2)]), 10.0)]).unwrap();
        assert_eq!(f.configured_replicas(), 4);
        assert_eq!(f.member(1).stages[0].replicas, 2);
        assert_eq!(f.member(1).stages[0].batch, 4);
    }

    #[test]
    fn pool_tracks_rolling_shrink_overshoot() {
        let mut f = two_member_fleet(4);
        // occupy both replicas of member 0 stage 0
        f.member_mut(0).ingest(0, 0.0);
        f.member_mut(0).ingest(1, 0.0);
        assert!(matches!(f.member_mut(0).try_form(0, 0.0), FormOutcome::Formed(_)));
        assert!(matches!(f.member_mut(0).try_form(0, 0.0), FormOutcome::Formed(_)));
        f.note();
        assert_eq!(f.pool().busy, 2);
        // shrink member 0 stage 0 to 1 replica while 2 batches in flight
        f.apply(&[(config(&[(1, 1), (1, 1)]), 10.0), (config(&[(1, 1)]), 10.0)]).unwrap();
        let u = f.pool();
        assert_eq!(u.configured, 3);
        assert!(u.configured <= u.budget);
        assert_eq!(u.in_use, 4, "old batches keep their slots until done");
        assert!(f.peak_in_use() >= 4);
        f.member_mut(0).finish_service(0);
        f.member_mut(0).finish_service(0);
        f.note();
        assert_eq!(f.pool().in_use, 3);
    }

    #[test]
    fn member_accounting_is_isolated() {
        let mut f = two_member_fleet(4);
        f.member_mut(0).ingest(0, 0.0);
        f.member_mut(1).ingest(0, 0.0);
        f.member_mut(1).complete(0, 0.5);
        let accs = f.into_accountings();
        assert_eq!(accs.len(), 2);
        assert_eq!(accs[0].completed_count(), 0);
        assert_eq!(accs[1].completed_count(), 1);
    }

    #[test]
    fn fleet_reconfig_fifo_after_delay() {
        let d = |pas: f64| Decision {
            config: PipelineConfig {
                stages: Vec::new(),
                pas,
                cost: 1.0,
                batch_sum: 0,
                objective: 0.0,
                latency_e2e: 0.0,
            },
            lambda_predicted: 10.0,
            decision_time: 0.0,
            fallback: false,
        };
        let mut r = FleetReconfig::new(8.0);
        assert_eq!(r.stage(10.0, vec![d(1.0), d(2.0)]), 18.0);
        assert_eq!(r.stage(20.0, vec![d(3.0), d(4.0)]), 28.0);
        assert_eq!(r.pending_len(), 2);
        assert!(r.pop_due(17.9).is_none());
        let first = r.pop_due(18.0).unwrap();
        assert_eq!(first.decisions.len(), 2);
        assert_eq!(first.decisions[0].config.pas, 1.0);
        assert_eq!(r.next_due(), Some(28.0));
        assert!(r.pop_due(20.0).is_none());
        assert_eq!(r.pop_due(30.0).unwrap().decisions[1].config.pas, 4.0);
        assert_eq!(r.pending_len(), 0);
    }
}
