//! The fleet front door: per-member affinity-aware request routing +
//! admission control.
//!
//! Until this module, every request arrived pre-addressed to its member
//! pipeline and replicas were anonymous capacity slots.  A real
//! multi-tenant ingress needs more: spread each arrival across the
//! member's stage-0 replicas (PR 5's [`crate::fleet::nodes::Packing`]
//! knows which node — and therefore which zone — each replica lives
//! on), and decide *before* enqueueing whether the queue can still meet
//! the SLA.  [`Router`] is that layer, shared verbatim by both clocks:
//! the fleet DES owns one router per member lane (virtual time), the
//! live engine one per member behind a mutex (wall time).
//!
//! **Routing policies** ([`RoutePolicy`]):
//!
//! * `RoundRobin` — the classic baseline; position depends only on the
//!   member's arrival count, so DES and live runs of the same trace
//!   route identically (pinned in `tests/fleet_router.rs`).
//! * `LeastLoaded` — per-replica in-flight (queued) counters; always
//!   picks a replica with the minimum count (lowest index on ties).
//! * `ZoneLocalFirst` — each arrival carries an origin zone (derived
//!   deterministically from its id over the inventory's zone universe);
//!   the router prefers the least-loaded replica *in that zone* and
//!   only crosses zones when the origin zone has no live replica —
//!   paying [`RouterConfig::cross_zone_penalty`] extra exec latency on
//!   the DES clock.
//! * `StickySession` — `id / session_stride` is the session key; repeat
//!   sessions hit their previous replica *warm*, modeled as a
//!   [`RouterConfig::warm_scale`] exec-latency discount (the
//!   cache-affinity idea: repeat traffic is cheaper).
//!
//! **Admission** (off unless [`RouterConfig::admission`]): the router
//! predicts the stage-0 queue wait from its own in-flight counters and
//! the active profile (`queued × l(b) / (b × replicas)`).  When the
//! prediction crosses `admit_threshold × SLA` the request is *degraded*
//! — still served, but as a brownout/cheaper response, modeled as a
//! [`RouterConfig::brownout_scale`] exec discount — and only past
//! `shed_threshold × SLA` is it shed into the §4.5 drop ledger
//! (`record_arrival` + `record_drop`, never enqueued).  Degrade-first
//! is the point: under a flash crowd the journal shows `degrade`
//! events while completions keep flowing, not a wall of drops.
//!
//! **Determinism.**  No RNG anywhere: origin zones and session keys
//! derive from request ids, ties break toward the lowest replica
//! index, and all state lives per member (the epoch-parallel DES
//! mutates it only inside that member's lane).  A routed DES run is
//! byte-identical at any `IPA_SIM_THREADS` count.
//!
//! **Live caveat.**  On the wall clock the executor really sleeps the
//! profiled latency, so warm/brownout/cross-zone *latency* adjustments
//! are DES-only; the live engine still routes, admits, degrades and
//! sheds with the same code and reports the same
//! [`RouterStats`](crate::metrics::RouterStats).
//!
//! Tuning defaults come from [`RouterConfig::default`]; every field has
//! an `IPA_ROUTE_*` environment override via
//! [`RouterConfig::from_env`] (see the crate-level "Runtime knobs").

use std::collections::HashMap;

use crate::metrics::RouterStats;
use crate::queueing::Request;

/// How a [`Router`] picks the stage-0 replica for an arrival.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutePolicy {
    /// Cycle replicas in arrival order (DES↔live identical).
    #[default]
    RoundRobin,
    /// Minimum in-flight counter, lowest index on ties.
    LeastLoaded,
    /// Least-loaded within the arrival's origin zone; cross zones only
    /// when that zone has no live replica.
    ZoneLocalFirst,
    /// Session-key hash → warm replica (exec-latency discount on hits).
    StickySession,
}

impl RoutePolicy {
    /// Parse a CLI/env name (`round_robin`, `least_loaded`,
    /// `zone_local`, `sticky`).
    pub fn from_name(s: &str) -> Option<RoutePolicy> {
        match s.trim() {
            "round_robin" | "rr" => Some(RoutePolicy::RoundRobin),
            "least_loaded" | "ll" => Some(RoutePolicy::LeastLoaded),
            "zone_local" | "zone_local_first" => Some(RoutePolicy::ZoneLocalFirst),
            "sticky" | "sticky_session" => Some(RoutePolicy::StickySession),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round_robin",
            RoutePolicy::LeastLoaded => "least_loaded",
            RoutePolicy::ZoneLocalFirst => "zone_local",
            RoutePolicy::StickySession => "sticky",
        }
    }
}

/// Front-door settings (one per fleet run; every member's router shares
/// them).
#[derive(Debug, Clone, PartialEq)]
pub struct RouterConfig {
    pub policy: RoutePolicy,
    /// Enable the degrade-then-shed admission stage.
    pub admission: bool,
    /// Extra exec seconds a batch pays when any of its requests was
    /// routed across zones (DES latency model).
    pub cross_zone_penalty: f64,
    /// Exec-latency multiplier for warm (sticky-hit) requests (< 1).
    pub warm_scale: f64,
    /// Exec-latency multiplier for degraded/brownout responses (< 1 —
    /// a cheaper answer is also a faster one).
    pub brownout_scale: f64,
    /// Degrade when predicted queue wait exceeds this × the member's
    /// class-scaled SLA.
    pub admit_threshold: f64,
    /// Shed (§4.5 drop ledger) past this × the class-scaled SLA.
    pub shed_threshold: f64,
    /// Consecutive request ids sharing one sticky-session key.
    pub session_stride: u64,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            policy: RoutePolicy::RoundRobin,
            admission: false,
            cross_zone_penalty: 0.002,
            warm_scale: 0.7,
            brownout_scale: 0.5,
            admit_threshold: 0.6,
            shed_threshold: 1.5,
            session_stride: 16,
        }
    }
}

impl RouterConfig {
    /// Defaults with every `IPA_ROUTE_*` environment override applied
    /// (read once per call — construction-time, never on the hot
    /// path): `IPA_ROUTE_POLICY`, `IPA_ROUTE_ADMISSION`,
    /// `IPA_ROUTE_CROSS_ZONE_PENALTY`, `IPA_ROUTE_WARM_SCALE`,
    /// `IPA_ROUTE_BROWNOUT_SCALE`, `IPA_ROUTE_ADMIT_THRESHOLD`,
    /// `IPA_ROUTE_SHED_THRESHOLD`, `IPA_ROUTE_SESSION_STRIDE`.
    pub fn from_env() -> RouterConfig {
        fn env_f64(name: &str, default: f64) -> f64 {
            std::env::var(name).ok().and_then(|v| v.trim().parse().ok()).unwrap_or(default)
        }
        let d = RouterConfig::default();
        RouterConfig {
            policy: std::env::var("IPA_ROUTE_POLICY")
                .ok()
                .and_then(|v| RoutePolicy::from_name(&v))
                .unwrap_or(d.policy),
            admission: std::env::var("IPA_ROUTE_ADMISSION")
                .map(|v| v.trim() == "1")
                .unwrap_or(d.admission),
            cross_zone_penalty: env_f64("IPA_ROUTE_CROSS_ZONE_PENALTY", d.cross_zone_penalty),
            warm_scale: env_f64("IPA_ROUTE_WARM_SCALE", d.warm_scale),
            brownout_scale: env_f64("IPA_ROUTE_BROWNOUT_SCALE", d.brownout_scale),
            admit_threshold: env_f64("IPA_ROUTE_ADMIT_THRESHOLD", d.admit_threshold),
            shed_threshold: env_f64("IPA_ROUTE_SHED_THRESHOLD", d.shed_threshold),
            session_stride: std::env::var("IPA_ROUTE_SESSION_STRIDE")
                .ok()
                .and_then(|v| v.trim().parse().ok())
                .filter(|&s: &u64| s > 0)
                .unwrap_or(d.session_stride),
        }
    }
}

/// The router's verdict for one arrival.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RouteOutcome {
    /// Enqueue normally on `replica`.
    Route { replica: usize, cross_zone: bool, warm: bool },
    /// Enqueue on `replica` but serve the brownout/cheaper response.
    Degrade { replica: usize },
    /// Do not enqueue: book into the §4.5 drop ledger
    /// (`record_arrival` + `record_drop`).
    Shed,
}

/// Per-batch exec-latency adjustment from routing decisions:
/// `service' = service × scale + extra`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchAdjust {
    /// Mean per-request factor (warm/brownout discounts; 1.0 neutral).
    pub scale: f64,
    /// Max cross-zone hop penalty in the batch, seconds.
    pub extra: f64,
}

impl BatchAdjust {
    pub const NEUTRAL: BatchAdjust = BatchAdjust { scale: 1.0, extra: 0.0 };
}

/// Routing counters accumulated since the last control-plane tick (the
/// journal's `route`/`admit`/`degrade` events are built from one of
/// these per member per adaptation interval).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RouteTick {
    pub routed: u64,
    pub degraded: u64,
    pub shed: u64,
    pub cross_zone: u64,
    pub warm_hits: u64,
}

/// A routed request's pending bookkeeping: which replica holds it and
/// how its eventual batch should be priced.
#[derive(Debug, Clone, Copy)]
struct RouteTag {
    replica: usize,
    /// Arrival time — lets [`Router::expire`] reclaim tags whose
    /// requests were dropped at batch formation (the router never sees
    /// those ids again).
    t: f64,
    warm: bool,
    degraded: bool,
    cross_zone: bool,
}

/// One member's front door.  All state is per member: the DES keeps a
/// router inside the member's lane (mutated only by that member's
/// epoch worker, so parallel epochs stay byte-deterministic), the live
/// engine behind a per-member mutex.
#[derive(Debug)]
pub struct Router {
    cfg: RouterConfig,
    /// Class-scaled SLA — the admission thresholds' base.
    sla: f64,
    /// Stage-0 replica slots currently routable.
    replicas: usize,
    /// Zone label per replica slot (from the packing; empty when the
    /// pool is fungible/unzoned — zone policy then degenerates to
    /// least-loaded with no cross-zone charges).
    zones: Vec<String>,
    /// Distinct zone labels of the full inventory — the origin-zone
    /// universe arrivals are attributed to (fixed for the run: clients
    /// in a dead zone keep sending).
    zone_names: Vec<String>,
    /// Estimated service seconds per queued request at the active
    /// config (`l(b)/b`), refreshed at every topology sync.
    service_per_item: f64,
    /// Queued-request count per replica slot.
    inflight: Vec<u32>,
    /// Round-robin cursor (RoundRobin picks; StickySession cold picks).
    rr: usize,
    /// id → pending tag, consumed at batch formation.
    assigned: HashMap<u64, RouteTag>,
    /// Sticky session key → replica.
    sessions: HashMap<u64, usize>,
    stats: RouterStats,
    tick: RouteTick,
}

impl Router {
    /// A router for one member.  `sla` is the member's end-to-end SLA
    /// already scaled by its SLA class (the same scaling the §4.5 drop
    /// policy uses); `zone_names` the inventory's distinct zones.
    pub fn new(cfg: RouterConfig, sla: f64, zone_names: Vec<String>) -> Router {
        Router {
            cfg,
            sla: if sla.is_finite() && sla > 0.0 { sla } else { 1.0 },
            replicas: 1,
            zones: Vec::new(),
            zone_names,
            service_per_item: 0.0,
            inflight: vec![0],
            rr: 0,
            assigned: HashMap::new(),
            sessions: HashMap::new(),
            stats: RouterStats { routed: vec![0], ..RouterStats::default() },
            tick: RouteTick::default(),
        }
    }

    /// Sync the routable topology after a reconfiguration, pool resize
    /// or zone kill: stage-0 replica count, per-replica zone labels
    /// (packing order; padded/truncated defensively if a rolling
    /// transition briefly disagrees) and the per-request service
    /// estimate of the active config.  In-flight tags on vanished
    /// replicas are folded back onto the surviving slots.
    pub fn set_topology(&mut self, replicas: usize, mut zones: Vec<String>, service_per_item: f64) {
        let n = replicas.max(1);
        if !zones.is_empty() {
            zones.resize(n, String::new());
        }
        self.zones = zones;
        self.service_per_item = if service_per_item.is_finite() && service_per_item > 0.0 {
            service_per_item
        } else {
            0.0
        };
        if n != self.replicas {
            self.replicas = n;
            for tag in self.assigned.values_mut() {
                if tag.replica >= n {
                    tag.replica %= n;
                }
            }
            let mut counts = vec![0u32; n];
            for tag in self.assigned.values() {
                counts[tag.replica] += 1;
            }
            self.inflight = counts;
            self.sessions.retain(|_, r| *r < n);
            if self.stats.routed.len() < n {
                self.stats.routed.resize(n, 0);
            }
        }
    }

    /// Predicted stage-0 queue wait at the current occupancy, seconds.
    pub fn est_wait(&self) -> f64 {
        let queued: u32 = self.inflight.iter().sum();
        queued as f64 * self.service_per_item / self.replicas.max(1) as f64
    }

    /// The arrival's origin zone (deterministic in its id), if the
    /// inventory is zoned.
    fn origin_zone(&self, id: u64) -> Option<&str> {
        if self.zone_names.is_empty() {
            None
        } else {
            Some(self.zone_names[(id % self.zone_names.len() as u64) as usize].as_str())
        }
    }

    /// Least-loaded replica among `candidates` (lowest index on ties);
    /// falls back over all replicas when the filter matches none.
    fn least_loaded<F: Fn(usize) -> bool>(&self, keep: F) -> Option<usize> {
        let mut best: Option<usize> = None;
        for r in 0..self.replicas {
            if !keep(r) {
                continue;
            }
            match best {
                Some(b) if self.inflight[r] >= self.inflight[b] => {}
                _ => best = Some(r),
            }
        }
        best
    }

    /// Pick a replica for `id`: `(replica, cross_zone, warm)`.
    fn pick(&mut self, id: u64) -> (usize, bool, bool) {
        let n = self.replicas;
        match self.cfg.policy {
            RoutePolicy::RoundRobin => {
                let r = self.rr % n;
                self.rr = (self.rr + 1) % n;
                (r, false, false)
            }
            RoutePolicy::LeastLoaded => {
                (self.least_loaded(|_| true).unwrap_or(0), false, false)
            }
            RoutePolicy::ZoneLocalFirst => {
                let origin = self.origin_zone(id).map(str::to_string);
                match &origin {
                    Some(z) if !self.zones.is_empty() => {
                        match self.least_loaded(|r| self.zones[r] == *z) {
                            Some(r) => (r, false, false),
                            // origin zone has no live replica: the hop
                            // crosses zones and pays the penalty
                            None => (self.least_loaded(|_| true).unwrap_or(0), true, false),
                        }
                    }
                    _ => (self.least_loaded(|_| true).unwrap_or(0), false, false),
                }
            }
            RoutePolicy::StickySession => {
                let key = id / self.cfg.session_stride.max(1);
                match self.sessions.get(&key) {
                    Some(&r) if r < n => (r, false, true),
                    _ => {
                        let r = self.rr % n;
                        self.rr = (self.rr + 1) % n;
                        self.sessions.insert(key, r);
                        (r, false, false)
                    }
                }
            }
        }
    }

    /// Route one arrival at `now`.  The caller actuates the outcome:
    /// `Route`/`Degrade` → ingest into stage 0; `Shed` → book the §4.5
    /// drop (`record_arrival` + `record_drop`) without enqueueing.
    pub fn route(&mut self, id: u64, now: f64) -> RouteOutcome {
        if self.cfg.admission {
            let est = self.est_wait();
            if est > self.cfg.shed_threshold * self.sla {
                self.stats.shed += 1;
                self.tick.shed += 1;
                return RouteOutcome::Shed;
            }
            if est > self.cfg.admit_threshold * self.sla {
                let (replica, cross_zone, _) = self.pick(id);
                self.commit(id, now, replica, cross_zone, false, true);
                return RouteOutcome::Degrade { replica };
            }
        }
        let (replica, cross_zone, warm) = self.pick(id);
        self.commit(id, now, replica, cross_zone, warm, false);
        RouteOutcome::Route { replica, cross_zone, warm }
    }

    fn commit(
        &mut self,
        id: u64,
        now: f64,
        replica: usize,
        cross_zone: bool,
        warm: bool,
        degraded: bool,
    ) {
        self.inflight[replica] += 1;
        self.assigned.insert(id, RouteTag { replica, t: now, warm, degraded, cross_zone });
        if self.stats.routed.len() <= replica {
            self.stats.routed.resize(replica + 1, 0);
        }
        self.stats.routed[replica] += 1;
        self.tick.routed += 1;
        if cross_zone {
            self.stats.cross_zone += 1;
            self.tick.cross_zone += 1;
        }
        if warm {
            self.stats.warm_hits += 1;
            self.tick.warm_hits += 1;
        }
        if degraded {
            self.stats.degraded += 1;
            self.tick.degraded += 1;
        }
    }

    /// A stage-0 batch formed: consume the member requests' tags (they
    /// leave the routed queue), free their in-flight slots and return
    /// the exec-latency adjustment the routing decisions earned
    /// (`service' = service × scale + extra`).  The live engine calls
    /// this for bookkeeping only — its executor really sleeps.
    pub fn on_batch(&mut self, requests: &[Request]) -> BatchAdjust {
        if requests.is_empty() {
            return BatchAdjust::NEUTRAL;
        }
        let mut scale_sum = 0.0;
        let mut extra: f64 = 0.0;
        for req in requests {
            match self.assigned.remove(&req.id) {
                Some(tag) => {
                    if let Some(c) = self.inflight.get_mut(tag.replica) {
                        *c = c.saturating_sub(1);
                    }
                    scale_sum += if tag.degraded {
                        self.cfg.brownout_scale
                    } else if tag.warm {
                        self.cfg.warm_scale
                    } else {
                        1.0
                    };
                    if tag.cross_zone {
                        extra = extra.max(self.cfg.cross_zone_penalty);
                    }
                }
                // expired tag (see `expire`) or pre-router request:
                // neutral pricing
                None => scale_sum += 1.0,
            }
        }
        BatchAdjust { scale: scale_sum / requests.len() as f64, extra }
    }

    /// Reclaim tags of requests the router will never see again —
    /// §4.5 drops happen *inside* batch formation, invisible from
    /// here, so anything older than the drop horizon (4× SLA) has
    /// certainly left the queue.  Called at control-plane sync points;
    /// effects are per-id and commutative, so map iteration order
    /// never leaks into results.
    pub fn expire(&mut self, now: f64) {
        if self.assigned.is_empty() {
            return;
        }
        let horizon = (4.0 * self.sla).max(1.0);
        let stale: Vec<u64> = self
            .assigned
            .iter()
            .filter(|(_, tag)| now - tag.t > horizon)
            .map(|(&id, _)| id)
            .collect();
        for id in stale {
            if let Some(tag) = self.assigned.remove(&id) {
                if let Some(c) = self.inflight.get_mut(tag.replica) {
                    *c = c.saturating_sub(1);
                }
            }
        }
    }

    /// Cumulative per-run counters.
    pub fn stats(&self) -> &RouterStats {
        &self.stats
    }

    /// Drain the since-last-tick counters (journal aggregation).
    pub fn take_tick(&mut self) -> RouteTick {
        std::mem::take(&mut self.tick)
    }

    /// Current per-replica queued counts (tests / diagnostics).
    pub fn inflight(&self) -> &[u32] {
        &self.inflight
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request { id, arrival: 0.0, stage_arrival: 0.0 }
    }

    fn router(policy: RoutePolicy, replicas: usize) -> Router {
        let cfg = RouterConfig { policy, ..RouterConfig::default() };
        let mut r = Router::new(cfg, 1.0, Vec::new());
        r.set_topology(replicas, Vec::new(), 0.01);
        r
    }

    #[test]
    fn round_robin_cycles_evenly() {
        let mut r = router(RoutePolicy::RoundRobin, 3);
        let mut hits = vec![0u32; 3];
        for id in 0..9 {
            match r.route(id, 0.0) {
                RouteOutcome::Route { replica, .. } => hits[replica] += 1,
                o => panic!("unexpected {o:?}"),
            }
        }
        assert_eq!(hits, vec![3, 3, 3]);
    }

    #[test]
    fn least_loaded_prefers_emptiest_slot() {
        let mut r = router(RoutePolicy::LeastLoaded, 3);
        // three arrivals spread 0,1,2; complete replica 1's request and
        // the next arrival must land there
        for id in 0..3 {
            r.route(id, 0.0);
        }
        r.on_batch(&[req(1)]);
        match r.route(3, 0.0) {
            RouteOutcome::Route { replica, .. } => assert_eq!(replica, 1),
            o => panic!("unexpected {o:?}"),
        }
    }

    #[test]
    fn sticky_sessions_rehit_their_replica_warm() {
        let mut r = router(RoutePolicy::StickySession, 4);
        let stride = RouterConfig::default().session_stride;
        let first = match r.route(0, 0.0) {
            RouteOutcome::Route { replica, warm } => {
                assert!(!warm, "cold first hit");
                replica
            }
            o => panic!("unexpected {o:?}"),
        };
        // same session (id within the stride) must rehit warm
        match r.route(stride - 1, 0.0) {
            RouteOutcome::Route { replica, warm, .. } => {
                assert_eq!(replica, first);
                assert!(warm);
            }
            o => panic!("unexpected {o:?}"),
        }
        assert_eq!(r.stats().warm_hits, 1);
    }

    #[test]
    fn zone_local_crosses_only_when_zone_empty() {
        let cfg = RouterConfig { policy: RoutePolicy::ZoneLocalFirst, ..RouterConfig::default() };
        let mut r = Router::new(cfg, 1.0, vec!["east".into(), "west".into()]);
        r.set_topology(3, vec!["east".into(), "east".into(), "west".into()], 0.01);
        // id 0 → origin east (0 % 2), id 1 → west
        match r.route(0, 0.0) {
            RouteOutcome::Route { replica, cross_zone, .. } => {
                assert!(replica < 2, "east-origin stays on an east replica");
                assert!(!cross_zone);
            }
            o => panic!("unexpected {o:?}"),
        }
        match r.route(1, 0.0) {
            RouteOutcome::Route { replica, cross_zone, .. } => {
                assert_eq!(replica, 2);
                assert!(!cross_zone);
            }
            o => panic!("unexpected {o:?}"),
        }
        // west zone dies: west-origin arrivals must cross
        r.set_topology(2, vec!["east".into(), "east".into()], 0.01);
        match r.route(3, 0.0) {
            RouteOutcome::Route { cross_zone, .. } => assert!(cross_zone),
            o => panic!("unexpected {o:?}"),
        }
        assert_eq!(r.stats().cross_zone, 1);
    }

    #[test]
    fn admission_degrades_then_sheds() {
        let cfg = RouterConfig {
            policy: RoutePolicy::RoundRobin,
            admission: true,
            admit_threshold: 0.5,
            shed_threshold: 2.0,
            ..RouterConfig::default()
        };
        let mut r = Router::new(cfg, 1.0, Vec::new());
        // 1 replica, 1s of service per queued item: est_wait = queued
        r.set_topology(1, Vec::new(), 1.0);
        assert!(matches!(r.route(0, 0.0), RouteOutcome::Route { .. }));
        // est 1.0 > 0.5×sla → degrade; est still ≤ 2.0×sla → no shed
        assert!(matches!(r.route(1, 0.0), RouteOutcome::Degrade { .. }));
        assert!(matches!(r.route(2, 0.0), RouteOutcome::Shed));
        assert_eq!(r.stats().degraded, 1);
        assert_eq!(r.stats().shed, 1);
        // batch pricing: the degraded request discounts the mean
        let adj = r.on_batch(&[req(0), req(1)]);
        assert!(adj.scale < 1.0 && adj.scale > 0.5);
    }

    #[test]
    fn batch_adjust_prices_warm_and_cross_zone() {
        let cfg = RouterConfig {
            policy: RoutePolicy::ZoneLocalFirst,
            cross_zone_penalty: 0.01,
            ..RouterConfig::default()
        };
        let mut r = Router::new(cfg, 1.0, vec!["east".into(), "west".into()]);
        r.set_topology(1, vec!["east".into()], 0.01);
        // id 1 → west origin, but only east replicas exist
        r.route(1, 0.0);
        let adj = r.on_batch(&[req(1)]);
        assert_eq!(adj.extra, 0.01);
        assert_eq!(adj.scale, 1.0);
    }

    #[test]
    fn expire_reclaims_dropped_requests() {
        let mut r = router(RoutePolicy::LeastLoaded, 2);
        r.route(0, 0.0);
        r.route(1, 0.0);
        assert_eq!(r.inflight().iter().sum::<u32>(), 2);
        // neither request ever forms a batch (dropped inside §4.5);
        // past the horizon the router reclaims them
        r.expire(100.0);
        assert_eq!(r.inflight().iter().sum::<u32>(), 0);
    }

    #[test]
    fn shrink_folds_inflight_onto_survivors() {
        let mut r = router(RoutePolicy::LeastLoaded, 4);
        for id in 0..4 {
            r.route(id, 0.0);
        }
        r.set_topology(2, Vec::new(), 0.01);
        assert_eq!(r.inflight().len(), 2);
        assert_eq!(r.inflight().iter().sum::<u32>(), 4);
        // consuming the folded tags still balances
        r.on_batch(&[req(0), req(1), req(2), req(3)]);
        assert_eq!(r.inflight().iter().sum::<u32>(), 0);
    }

    #[test]
    fn config_from_env_defaults_without_overrides() {
        // (process env in tests is shared — only assert the defaults
        // path is sane, not specific override values)
        let c = RouterConfig::from_env();
        assert!(c.session_stride > 0);
        assert!(c.shed_threshold >= c.admit_threshold);
    }
}
