//! Fleet description: N heterogeneous pipelines sharing one replica
//! budget.
//!
//! A [`FleetSpec`] names the member pipelines (each a paper pipeline
//! from [`crate::models::pipelines`] with its own workload pattern,
//! trace seed and optional SLA scaling) plus the *global* replica
//! budget every stage of every member draws from.  Specs load from /
//! dump to JSON through [`crate::util::json`] so fleet scenarios are
//! shareable files, and [`FleetSpec::traces`] materializes the member
//! λ traces through the correlated multi-pipeline generator
//! ([`crate::workload::tracegen::generate_fleet`]).

use crate::fleet::nodes::NodeInventory;
use crate::models::pipelines::{self, PipelineSpec};
use crate::util::json::Json;
use crate::workload::trace::Trace;
use crate::workload::tracegen::{generate_fleet_seeded, FleetCorrelation, Pattern};

/// Per-member SLA class: how a member's traffic tolerates waiting.
/// Keys the drop policy, the batch-formation timeout ceiling and
/// preemption donor preference — plugged into the drivers through
/// [`crate::fleet::solver::FleetTuning::sla_classes`] (absent classes =
/// the pre-class behavior: everything latency-critical with uncapped
/// timeouts).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlaClass {
    /// Interactive traffic: verbatim drop SLA, batch-formation waits
    /// capped at a quarter of the e2e SLA, preferred preemption
    /// receiver.
    LatencyCritical,
    /// Batch traffic: tolerates 2× the SLA before shedding, uncapped
    /// batch-formation waits (fill the batch), preferred preemption
    /// donor and never a receiver.
    Throughput,
}

impl SlaClass {
    pub fn name(self) -> &'static str {
        match self {
            SlaClass::LatencyCritical => "latency_critical",
            SlaClass::Throughput => "throughput",
        }
    }

    pub fn from_name(s: &str) -> Option<SlaClass> {
        match s {
            "latency_critical" => Some(SlaClass::LatencyCritical),
            "throughput" => Some(SlaClass::Throughput),
            _ => None,
        }
    }

    /// Multiplier on the member's drop-policy SLA (§4.5 ages are judged
    /// against `scale × SLA`).
    pub fn drop_sla_scale(self) -> f64 {
        match self {
            SlaClass::LatencyCritical => 1.0,
            SlaClass::Throughput => 2.0,
        }
    }

    /// Batch-formation timeout ceiling for a member with e2e SLA `sla`
    /// (same time domain as the driver's clock).  Latency-critical
    /// members never wait longer than a quarter of their SLA for a
    /// batch to fill (floored at the 50 ms dispatch granularity);
    /// throughput members wait as long as the λ-shaped timeout allows.
    pub fn timeout_cap(self, sla: f64) -> f64 {
        match self {
            SlaClass::LatencyCritical => (0.25 * sla).max(0.05),
            SlaClass::Throughput => f64::INFINITY,
        }
    }
}

/// One pipeline instance inside a fleet.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetMember {
    /// Instance label, unique within the fleet (one pipeline type can
    /// appear under several labels with different workloads).
    pub name: String,
    /// Paper pipeline this member runs (`models::pipelines::by_name`).
    pub pipeline: String,
    /// Workload archetype driving this member's λ trace.
    pub pattern: Pattern,
    /// Trace seed (mixed with the correlation envelope).
    pub seed: u64,
    /// Per-member SLA override: multiplies the paper's per-stage SLAs
    /// (1.0 = verbatim Table 6).
    pub sla_scale: f64,
    /// Priority class, HIGHER = more important (a Kubernetes
    /// PriorityClass value).  The tiered joint solver grants the pool
    /// to higher classes first, and the preemption fast path reclaims
    /// replicas only from strictly lower classes.  Default 0.
    pub priority: u32,
    /// SLA class (latency-critical vs throughput/batch) — keys drop
    /// policy, batch timeout ceilings and preemption eligibility when
    /// the caller wires [`FleetSpec::classes`] into the tuned drivers.
    /// Default latency-critical.
    pub sla_class: SlaClass,
    /// Zone-spread flag: when the node inventory spans ≥ 2 failure
    /// domains, this member keeps ≥ 2 replicas per stage across ≥ 2
    /// zones so one zone loss never drops it below its stage floor
    /// (wired through [`crate::fleet::solver::FleetTuning::spread`]).
    /// Vacuous on single-zone or fungible pools.  Default false.
    pub spread: bool,
}

impl FleetMember {
    /// Resolve the member's [`PipelineSpec`] with its SLA scaling
    /// applied.
    pub fn spec(&self) -> Option<PipelineSpec> {
        let mut spec = pipelines::by_name(&self.pipeline)?;
        if self.sla_scale != 1.0 {
            for s in spec.stage_slas.iter_mut() {
                *s *= self.sla_scale;
            }
        }
        Some(spec)
    }
}

/// A fleet: members + the shared replica budget they compete for.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpec {
    pub name: String,
    pub members: Vec<FleetMember>,
    /// Global replica budget: Σ over every stage of every member of the
    /// configured replica count must never exceed this.
    pub replica_budget: u32,
    /// Default trace length, seconds.
    pub seconds: usize,
    /// How the member traces co-move (one bursting while another
    /// decays, a shared surge, or independent streams).
    pub correlation: FleetCorrelation,
    /// Heterogeneous node shapes backing the pool.  `None` = the
    /// classic fungible pool of `replica_budget` slots; `Some` makes
    /// `replica_budget` informational (the inventory's replica cap
    /// governs) and replicas bin-pack onto the nodes.
    pub nodes: Option<NodeInventory>,
}

impl FleetSpec {
    /// Resolved per-member pipeline specs (SLA scaling applied).
    /// Errors on an unknown pipeline name.
    pub fn specs(&self) -> Result<Vec<PipelineSpec>, String> {
        self.members
            .iter()
            .map(|m| {
                m.spec().ok_or_else(|| {
                    format!("fleet member {}: unknown pipeline {}", m.name, m.pipeline)
                })
            })
            .collect()
    }

    /// Total stage count across members — the absolute replica floor
    /// (every stage needs at least one replica).
    pub fn min_replicas(&self) -> Result<u32, String> {
        Ok(self.specs()?.iter().map(|s| s.n_stages() as u32).sum())
    }

    /// Per-member priority classes in fleet order (what
    /// [`crate::fleet::solver::FleetTuning::priorities`] takes).
    pub fn priorities(&self) -> Vec<u32> {
        self.members.iter().map(|m| m.priority).collect()
    }

    /// Per-member SLA classes in fleet order (what
    /// [`crate::fleet::solver::FleetTuning::sla_classes`] takes).
    pub fn classes(&self) -> Vec<SlaClass> {
        self.members.iter().map(|m| m.sla_class).collect()
    }

    /// Per-member zone-spread flags in fleet order (what
    /// [`crate::fleet::solver::FleetTuning::spread`] takes).
    pub fn spreads(&self) -> Vec<bool> {
        self.members.iter().map(|m| m.spread).collect()
    }

    /// Structural validation: nonempty, unique non-blank member names,
    /// known pipelines, budget ≥ one replica per stage.  Names are the
    /// aliasing keys of reports/tables and trace labels, so blank or
    /// whitespace-padded names (visually identical rows) are rejected
    /// alongside exact duplicates.  Delegates to
    /// [`FleetSpec::validate_journaled`] with no journal — advisory
    /// findings go to the log only.
    pub fn validate(&self) -> Result<(), String> {
        self.validate_journaled(None)
    }

    /// [`FleetSpec::validate`] plus advisory diagnostics surfaced at
    /// validation time: structural problems still return `Err`, while
    /// warning-grade findings — a spread-flagged member over a pool
    /// with fewer than two failure-domain zones, where the ≥-2-zones
    /// spread constraint cannot possibly be honored and the packer
    /// silently degrades to single-zone placement — are recorded as
    /// warning-level `"validate"` events on `journal` (and
    /// `log_warn!`-ed) instead of failing the run.
    pub fn validate_journaled(
        &self,
        journal: Option<&crate::telemetry::journal::Journal>,
    ) -> Result<(), String> {
        if self.members.is_empty() {
            return Err("fleet has no members".into());
        }
        for (i, m) in self.members.iter().enumerate() {
            if m.name.trim().is_empty() {
                return Err(format!("fleet member {i}: blank name"));
            }
            if m.name.trim() != m.name {
                return Err(format!(
                    "fleet member name {:?} has surrounding whitespace",
                    m.name
                ));
            }
            if self.members[..i].iter().any(|o| o.name == m.name) {
                return Err(format!("duplicate fleet member name {}", m.name));
            }
            if !m.sla_scale.is_finite() || m.sla_scale <= 0.0 {
                return Err(format!("fleet member {}: sla_scale must be > 0", m.name));
            }
        }
        let floor = self.min_replicas()?;
        match &self.nodes {
            // With an inventory the budget is informational (the
            // replica cap governs) — validate the pool that is
            // actually in force.
            Some(nodes) => {
                nodes.validate()?;
                let cap = nodes.replica_cap();
                if cap < floor {
                    return Err(format!(
                        "node inventory caps {cap} replicas, below the \
                         one-replica-per-stage floor {floor}"
                    ));
                }
            }
            None => {
                if self.replica_budget < floor {
                    return Err(format!(
                        "replica budget {} below the one-replica-per-stage floor {floor}",
                        self.replica_budget
                    ));
                }
            }
        }
        // Advisory: a spread flag is a no-op without ≥ 2 zones to
        // spread across — surface it now, not mid-run.
        let zones =
            self.nodes.as_ref().map(|n| n.distinct_zones()).unwrap_or(1);
        if zones < 2 {
            for m in self.members.iter().filter(|m| m.spread) {
                crate::log_warn!(
                    "fleet::spec",
                    "member {}: spread flag set but the pool has {zones} zone(s); \
                     placement cannot spread",
                    m.name
                );
                if let Some(j) = journal {
                    j.record(
                        0.0,
                        "validate",
                        Json::obj()
                            .set("level", "warn")
                            .set("member", m.name.as_str())
                            .set("warning", "spread_without_zones")
                            .set("zones", zones as i64),
                    );
                }
            }
        }
        Ok(())
    }

    /// Materialize the correlated member traces, each from its member's
    /// own seed (`seconds` overrides the spec default when nonzero).
    pub fn traces(&self, seconds: usize) -> Vec<Trace> {
        let secs = if seconds > 0 { seconds } else { self.seconds };
        let seeded: Vec<(Pattern, u64)> =
            self.members.iter().map(|m| (m.pattern, m.seed)).collect();
        let rates = generate_fleet_seeded(&seeded, secs, self.correlation);
        self.members
            .iter()
            .zip(rates)
            .map(|(m, r)| Trace::new(format!("{}:{}", m.name, m.pattern.name()), r))
            .collect()
    }

    // ---- JSON ------------------------------------------------------------

    /// Parse a fleet spec from JSON text (see [`FleetSpec::to_json`] for
    /// the shape).  Validates structurally before returning.
    pub fn parse(text: &str) -> Result<FleetSpec, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        let spec = FleetSpec::from_json(&j)?;
        spec.validate()?;
        Ok(spec)
    }

    /// Build from a parsed [`Json`] value.
    pub fn from_json(j: &Json) -> Result<FleetSpec, String> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or("fleet: missing string field 'name'")?
            .to_string();
        let replica_budget = j
            .get("replica_budget")
            .and_then(Json::as_i64)
            .ok_or("fleet: missing numeric field 'replica_budget'")?;
        if !(0..=u32::MAX as i64).contains(&replica_budget) {
            return Err(format!("fleet: replica_budget {replica_budget} out of u32 range"));
        }
        let seconds = j.get("seconds").and_then(Json::as_usize).unwrap_or(240);
        let correlation = match j.get("correlation") {
            None => FleetCorrelation::Independent,
            Some(c) => {
                let mode = c
                    .get("mode")
                    .and_then(Json::as_str)
                    .ok_or("fleet: correlation needs a string 'mode'")?;
                let period = c.get("period").and_then(Json::as_usize).unwrap_or(300);
                match mode {
                    "independent" => FleetCorrelation::Independent,
                    "antiphase" => FleetCorrelation::Antiphase { period },
                    "in_phase" => FleetCorrelation::InPhase { period },
                    other => return Err(format!("fleet: unknown correlation mode {other}")),
                }
            }
        };
        let members_json = j
            .get("members")
            .and_then(Json::as_arr)
            .ok_or("fleet: missing array field 'members'")?;
        let mut members = Vec::new();
        for (i, mj) in members_json.iter().enumerate() {
            let name = mj
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("fleet member {i}: missing 'name'"))?
                .to_string();
            let pipeline = mj
                .get("pipeline")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("fleet member {name}: missing 'pipeline'"))?
                .to_string();
            let pattern_name = mj.get("pattern").and_then(Json::as_str).unwrap_or("steady_low");
            let pattern = Pattern::from_name(pattern_name)
                .ok_or_else(|| format!("fleet member {name}: unknown pattern {pattern_name}"))?;
            let seed = match mj.get("seed").and_then(Json::as_i64) {
                Some(s) if s < 0 => {
                    return Err(format!("fleet member {name}: seed must be >= 0"))
                }
                Some(s) => s as u64,
                None => 1 + i as u64,
            };
            let sla_scale = mj.get("sla_scale").and_then(Json::as_f64).unwrap_or(1.0);
            let priority = match mj.get("priority").and_then(Json::as_i64) {
                Some(p) if !(0..=u32::MAX as i64).contains(&p) => {
                    return Err(format!(
                        "fleet member {name}: priority {p} out of u32 range"
                    ))
                }
                Some(p) => p as u32,
                None => 0,
            };
            let sla_class = match mj.get("class").and_then(Json::as_str) {
                Some(c) => SlaClass::from_name(c)
                    .ok_or_else(|| format!("fleet member {name}: unknown SLA class {c:?}"))?,
                None => SlaClass::LatencyCritical,
            };
            let spread = mj.get("spread").and_then(Json::as_bool).unwrap_or(false);
            members.push(FleetMember {
                name,
                pipeline,
                pattern,
                seed,
                sla_scale,
                priority,
                sla_class,
                spread,
            });
        }
        let nodes = match j.get("nodes") {
            Some(nj) => Some(NodeInventory::from_json(nj)?),
            None => None,
        };
        Ok(FleetSpec {
            name,
            members,
            replica_budget: replica_budget as u32,
            seconds,
            correlation,
            nodes,
        })
    }

    /// Serialize to the canonical JSON shape ([`FleetSpec::parse`]
    /// round-trips it).
    pub fn to_json(&self) -> Json {
        let corr = match self.correlation {
            FleetCorrelation::Independent => Json::obj().set("mode", "independent"),
            FleetCorrelation::Antiphase { period } => {
                Json::obj().set("mode", "antiphase").set("period", period)
            }
            FleetCorrelation::InPhase { period } => {
                Json::obj().set("mode", "in_phase").set("period", period)
            }
        };
        let mut j = Json::obj()
            .set("name", self.name.clone())
            .set("replica_budget", self.replica_budget as usize)
            .set("seconds", self.seconds)
            .set("correlation", corr)
            .set(
                "members",
                Json::Arr(
                    self.members
                        .iter()
                        .map(|m| {
                            Json::obj()
                                .set("name", m.name.clone())
                                .set("pipeline", m.pipeline.clone())
                                .set("pattern", m.pattern.name())
                                .set("seed", m.seed as usize)
                                .set("sla_scale", m.sla_scale)
                                .set("priority", m.priority as usize)
                                .set("class", m.sla_class.name())
                                .set("spread", m.spread)
                        })
                        .collect(),
                ),
            );
        if let Some(nodes) = &self.nodes {
            j = j.set("nodes", nodes.to_json());
        }
        j
    }

    /// The canonical 3-pipeline demo fleet: a bursty video feed
    /// (latency-critical, priority 2), a fluctuating audio-sentiment
    /// feed (latency-critical, priority 1) and a steady NLP batch line
    /// (throughput class, priority 0) in antiphase, over one 24-replica
    /// pool.  Priorities and SLA classes only bite when a caller wires
    /// them into the tuned solver — the plain
    /// [`crate::fleet::solver::FleetAdapter::new`] path treats every
    /// member equally.
    pub fn demo3() -> FleetSpec {
        FleetSpec {
            name: "demo3".into(),
            members: vec![
                FleetMember {
                    name: "video-edge".into(),
                    pipeline: "video".into(),
                    pattern: Pattern::Bursty,
                    seed: 11,
                    sla_scale: 1.0,
                    priority: 2,
                    sla_class: SlaClass::LatencyCritical,
                    spread: false,
                },
                FleetMember {
                    name: "audio-social".into(),
                    pipeline: "audio-sent".into(),
                    pattern: Pattern::Fluctuating,
                    seed: 12,
                    sla_scale: 1.0,
                    priority: 1,
                    sla_class: SlaClass::LatencyCritical,
                    spread: false,
                },
                FleetMember {
                    name: "nlp-batchline".into(),
                    pipeline: "nlp".into(),
                    pattern: Pattern::SteadyLow,
                    seed: 13,
                    sla_scale: 1.0,
                    priority: 0,
                    sla_class: SlaClass::Throughput,
                    spread: false,
                },
            ],
            replica_budget: 24,
            seconds: 240,
            correlation: FleetCorrelation::Antiphase { period: 300 },
            nodes: None,
        }
    }

    /// A deterministic `n`-member scale fleet: the five paper pipelines
    /// and the five workload archetypes cycled, every third member in
    /// the throughput class, uniform priority (so the hierarchical cell
    /// solver activates at scale — tiers would force the flat path),
    /// 8 replicas of budget per member.  `examples/fleet_serve
    /// --members N` and the `fleet_scale` bench build their fleets
    /// here; pair with [`NodeInventory::scaled`] for the node pool.
    pub fn synthetic(n: usize) -> FleetSpec {
        const PIPELINES: [&str; 5] = ["video", "audio-sent", "nlp", "sum-qa", "audio-qa"];
        const PATTERNS: [Pattern; 5] = [
            Pattern::SteadyLow,
            Pattern::Bursty,
            Pattern::Fluctuating,
            Pattern::SteadyHigh,
            Pattern::Composite,
        ];
        let members: Vec<FleetMember> = (0..n)
            .map(|i| FleetMember {
                name: format!("syn-{i:03}"),
                pipeline: PIPELINES[i % PIPELINES.len()].into(),
                pattern: PATTERNS[i % PATTERNS.len()],
                seed: 100 + i as u64,
                sla_scale: 1.0,
                priority: 0,
                sla_class: if i % 3 == 2 {
                    SlaClass::Throughput
                } else {
                    SlaClass::LatencyCritical
                },
                spread: false,
            })
            .collect();
        FleetSpec {
            name: format!("synthetic-{n}"),
            members,
            replica_budget: 8 * n as u32,
            seconds: 240,
            correlation: FleetCorrelation::Antiphase { period: 300 },
            nodes: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demo3_is_valid_and_resolves() {
        let f = FleetSpec::demo3();
        f.validate().unwrap();
        let specs = f.specs().unwrap();
        assert_eq!(specs.len(), 3);
        assert_eq!(specs[2].n_stages(), 3); // nlp
        assert_eq!(f.min_replicas().unwrap(), 2 + 2 + 3);
    }

    #[test]
    fn synthetic_fleets_are_valid_and_uniform_priority() {
        for n in [1, 5, 16, 50] {
            let f = FleetSpec::synthetic(n);
            f.validate().unwrap_or_else(|e| panic!("synthetic({n}): {e}"));
            assert_eq!(f.members.len(), n);
            assert!(f.priorities().iter().all(|&p| p == 0), "uniform so cells activate");
            assert_eq!(f, FleetSpec::synthetic(n), "construction is deterministic");
        }
    }

    #[test]
    fn json_roundtrip() {
        let f = FleetSpec::demo3();
        let text = f.to_json().to_string();
        let back = FleetSpec::parse(&text).unwrap();
        assert_eq!(f, back);
    }

    #[test]
    fn parse_rejects_bad_specs() {
        // unknown pipeline
        let mut f = FleetSpec::demo3();
        f.members[0].pipeline = "no-such".into();
        assert!(FleetSpec::parse(&f.to_json().to_string()).is_err());
        // duplicate names (they would silently alias in reports/tables
        // and per-member trace labels)
        let mut f = FleetSpec::demo3();
        f.members[1].name = f.members[0].name.clone();
        assert!(FleetSpec::parse(&f.to_json().to_string()).is_err());
        // blank / whitespace-padded names alias visually — rejected too
        let mut f = FleetSpec::demo3();
        f.members[0].name = "   ".into();
        assert!(f.validate().is_err());
        let mut f = FleetSpec::demo3();
        f.members[0].name = " video-edge".into();
        assert!(f.validate().is_err());
        // budget under the floor
        let mut f = FleetSpec::demo3();
        f.replica_budget = 3;
        assert!(FleetSpec::parse(&f.to_json().to_string()).is_err());
        // garbage
        assert!(FleetSpec::parse("{").is_err());
        assert!(FleetSpec::parse("{\"name\":\"x\"}").is_err());
        // out-of-range numerics are rejected, not silently truncated
        let budget_overflow = r#"{"name":"x","replica_budget":4294967320,"members":
            [{"name":"a","pipeline":"video"}]}"#;
        assert!(FleetSpec::parse(budget_overflow).is_err());
        let negative_seed = r#"{"name":"x","replica_budget":8,"members":
            [{"name":"a","pipeline":"video","seed":-1}]}"#;
        assert!(FleetSpec::parse(negative_seed).is_err());
        let negative_priority = r#"{"name":"x","replica_budget":8,"members":
            [{"name":"a","pipeline":"video","priority":-2}]}"#;
        assert!(FleetSpec::parse(negative_priority).is_err());
    }

    #[test]
    fn sla_scale_validation_rejects_nonfinite_and_nonpositive() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, 0.0, -1.5] {
            let mut f = FleetSpec::demo3();
            f.members[1].sla_scale = bad;
            assert!(f.validate().is_err(), "sla_scale {bad} must be rejected");
        }
        let mut f = FleetSpec::demo3();
        f.members[1].sla_scale = 0.5;
        f.validate().unwrap();
    }

    #[test]
    fn sla_class_parses_defaults_and_roundtrips() {
        let f = FleetSpec::demo3();
        assert_eq!(
            f.classes(),
            vec![SlaClass::LatencyCritical, SlaClass::LatencyCritical, SlaClass::Throughput]
        );
        // omitted class defaults to latency-critical
        let text = r#"{"name":"x","replica_budget":8,"members":
            [{"name":"a","pipeline":"video"},
             {"name":"b","pipeline":"video","class":"throughput"}]}"#;
        let f = FleetSpec::parse(text).unwrap();
        assert_eq!(f.classes(), vec![SlaClass::LatencyCritical, SlaClass::Throughput]);
        // unknown class rejected
        let bad = r#"{"name":"x","replica_budget":8,"members":
            [{"name":"a","pipeline":"video","class":"best-effort"}]}"#;
        assert!(FleetSpec::parse(bad).is_err());
        // class policy knobs
        assert_eq!(SlaClass::LatencyCritical.drop_sla_scale(), 1.0);
        assert_eq!(SlaClass::Throughput.drop_sla_scale(), 2.0);
        assert!((SlaClass::LatencyCritical.timeout_cap(4.0) - 1.0).abs() < 1e-12);
        assert_eq!(SlaClass::LatencyCritical.timeout_cap(0.01), 0.05, "dispatch floor");
        assert_eq!(SlaClass::Throughput.timeout_cap(4.0), f64::INFINITY);
    }

    #[test]
    fn nodes_parse_validate_and_roundtrip() {
        use crate::fleet::nodes::NodeInventory;
        let mut f = FleetSpec::demo3();
        f.nodes = Some(NodeInventory::parse("4x(8c,32g,0a)+2x(16c,64g,1a)").unwrap());
        f.validate().unwrap();
        let back = FleetSpec::parse(&f.to_json().to_string()).unwrap();
        assert_eq!(f, back);
        // an inventory whose replica cap is below the stage floor fails
        let mut tiny = FleetSpec::demo3();
        tiny.nodes = Some(NodeInventory::parse("3x(2c,8g,0a)").unwrap());
        assert!(tiny.validate().is_err(), "6 slots < 7-stage floor");
        // invalid shapes are rejected through the spec too
        let bad = r#"{"name":"x","replica_budget":8,
            "members":[{"name":"a","pipeline":"video"}],
            "nodes":[{"shape":"s","cpu":0,"mem_gb":8,"accel":0,"count":2}]}"#;
        assert!(FleetSpec::parse(bad).is_err());
    }

    #[test]
    fn spread_parses_defaults_and_roundtrips() {
        let f = FleetSpec::demo3();
        assert_eq!(f.spreads(), vec![false, false, false], "demo fleet is unspread");
        // omitted spread defaults to false; explicit true survives the
        // JSON round trip
        let text = r#"{"name":"x","replica_budget":8,"members":
            [{"name":"a","pipeline":"video","spread":true},
             {"name":"b","pipeline":"video"}]}"#;
        let f = FleetSpec::parse(text).unwrap();
        assert_eq!(f.spreads(), vec![true, false]);
        let back = FleetSpec::parse(&f.to_json().to_string()).unwrap();
        assert_eq!(f, back);
    }

    #[test]
    fn spread_without_zones_warns_into_the_journal() {
        use crate::fleet::nodes::NodeInventory;
        use crate::telemetry::journal::Journal;
        // A spread member over a fungible (zoneless) pool: structurally
        // valid, but the spread constraint can never be honored — one
        // warning-level validate event per flagged member.
        let mut f = FleetSpec::demo3();
        f.members[0].spread = true;
        let j = Journal::new();
        f.validate_journaled(Some(&j)).unwrap();
        let es = j.entries();
        assert_eq!(es.len(), 1, "one spread member → one warning");
        assert_eq!(es[0].kind, "validate");
        assert_eq!(es[0].data.get("level").and_then(Json::as_str), Some("warn"));
        assert_eq!(es[0].data.get("member").and_then(Json::as_str), Some("video-edge"));
        assert_eq!(
            es[0].data.get("warning").and_then(Json::as_str),
            Some("spread_without_zones")
        );
        // Single-zone inventory: still cannot spread → still warns.
        f.nodes = Some(NodeInventory::parse("8x(8c,32g,0a)@east").unwrap());
        let j1 = Journal::new();
        f.validate_journaled(Some(&j1)).unwrap();
        assert_eq!(j1.len(), 1, "one named zone is still < 2");
        // Two zones: the flag is honorable → no warning.
        f.nodes =
            Some(NodeInventory::parse("4x(8c,32g,0a)@east+4x(8c,32g,0a)@west").unwrap());
        let j2 = Journal::new();
        f.validate_journaled(Some(&j2)).unwrap();
        assert!(j2.is_empty(), "two zones → nothing to warn about");
        // And the journal-less path stays Ok (warning goes to log only).
        f.nodes = None;
        f.validate().unwrap();
    }

    #[test]
    fn priority_parses_and_defaults() {
        let f = FleetSpec::demo3();
        assert_eq!(f.priorities(), vec![2, 1, 0]);
        // omitted priority defaults to 0 (best effort)
        let text = r#"{"name":"x","replica_budget":8,"members":
            [{"name":"a","pipeline":"video"},
             {"name":"b","pipeline":"video","priority":7}]}"#;
        let f = FleetSpec::parse(text).unwrap();
        assert_eq!(f.priorities(), vec![0, 7]);
    }

    #[test]
    fn sla_scale_applies() {
        let mut f = FleetSpec::demo3();
        f.members[0].sla_scale = 2.0;
        let spec = f.members[0].spec().unwrap();
        let base = pipelines::by_name("video").unwrap();
        assert!((spec.sla_e2e() - 2.0 * base.sla_e2e()).abs() < 1e-9);
    }

    #[test]
    fn traces_materialize_per_member() {
        let f = FleetSpec::demo3();
        let traces = f.traces(60);
        assert_eq!(traces.len(), 3);
        for t in &traces {
            assert_eq!(t.seconds(), 60);
            assert!(t.rates.iter().all(|&r| r >= 0.5));
        }
        assert!(traces[0].name.starts_with("video-edge:"));
    }

    #[test]
    fn member_seed_changes_only_that_members_trace() {
        let f = FleetSpec::demo3();
        let base = f.traces(120);
        let mut f2 = f.clone();
        f2.members[1].seed = 99;
        let alt = f2.traces(120);
        assert_eq!(base[0].rates, alt[0].rates);
        assert_ne!(base[1].rates, alt[1].rates, "member 1 seed must matter");
        assert_eq!(base[2].rates, alt[2].rates);
    }
}
