//! Hierarchical cells: the joint solver's scale-out layer.
//!
//! The flat greedy share allocation re-scans every member each
//! iteration, so one grant costs O(fleet) evaluations and a full solve
//! O(fleet²) — fine at the paper's ≤ 5 pipelines, a wall at 100
//! members on 1000-node pools.  Production schedulers break this by
//! partitioning: here the fleet is split into contiguous cells of
//! [`DEFAULT_CELL_SIZE`] members, each solved *independently* against
//! a sub-budget through its own `ShareEngine` — the same engine the
//! flat policies drive; the policy-vs-engine split is what makes the
//! reuse free — and a cheap top-level rebalancer then moves replicas
//! BETWEEN cells by marginal gain, one at a time, until no transfer
//! strictly improves the fleet objective.
//!
//! * **Activation** — [`cell_threshold`] members or more, uniform
//!   priorities only (tier precedence is global by definition, so
//!   tiered fleets keep the flat path).  `IPA_CELL_THRESHOLD` /
//!   [`set_cell_threshold`] tune it; `usize::MAX` disables cells for
//!   A/B runs.
//! * **Quality** — the result is floored at the global even-split
//!   baseline (the same guarantee the flat solver gives), and
//!   `tests/fleet_scale.rs` pins a bounded optimality gap vs the flat
//!   solve on randomized fleets.
//! * **Determinism** — cells are solved in member order, every scan is
//!   prewarmed through the engine (scan-order cache admission), and
//!   the rebalancer is strict-improvement first-seen-wins: results and
//!   cache counters are byte-identical at any
//!   [`crate::fleet::solver::solver_threads`] count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use crate::fleet::nodes::NodeInventory;
use crate::fleet::solver::{even_shares, FleetAllocation, ShareEngine, SolveStats};
use crate::optimizer::ip::Problem;

/// Members per cell.  Cells are contiguous ranges in member order —
/// the partition is reproducible and maps directly onto spec order.
pub const DEFAULT_CELL_SIZE: usize = 16;

/// Default member count at which uniform-priority solves go
/// hierarchical.
const DEFAULT_CELL_THRESHOLD: usize = 32;

/// Cell-threshold override: 0 = unset (env/default resolution).
static CELL_THRESHOLD: AtomicUsize = AtomicUsize::new(0);

/// Member count at which the uniform-priority joint solvers switch to
/// hierarchical cells.  Resolution order: [`set_cell_threshold`]
/// override, else `IPA_CELL_THRESHOLD`, else 32.  `usize::MAX`
/// disables cells entirely (the flat A/B baseline).
pub fn cell_threshold() -> usize {
    let o = CELL_THRESHOLD.load(Ordering::Relaxed);
    if o != 0 {
        return o;
    }
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("IPA_CELL_THRESHOLD")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(DEFAULT_CELL_THRESHOLD)
    })
}

/// Override [`cell_threshold`] for this process (0 = back to the
/// env/default resolution, `usize::MAX` = never go hierarchical).
pub fn set_cell_threshold(n: usize) {
    CELL_THRESHOLD.store(n, Ordering::Relaxed);
}

/// The hierarchical planner: one `ShareEngine` per contiguous member
/// range, plus the concatenated floors the policy layer needs.  Built
/// by the solver's planner dispatch above [`cell_threshold`] members
/// (or explicitly via [`solve_fleet_cells`]).
pub(crate) struct CellPlanner<'a> {
    cells: Vec<ShareEngine<'a>>,
    /// Member range `[start, end)` of each cell.
    ranges: Vec<(usize, usize)>,
    floors: Vec<u32>,
    min_per: Vec<u32>,
}

impl<'a> CellPlanner<'a> {
    /// `None` when the global `budget` cannot cover the per-member
    /// floors (same contract as the flat engine).
    pub(crate) fn new(
        problems: &'a [Problem<'a>],
        budget: u32,
        inv: Option<&NodeInventory>,
        spread: &[bool],
        cell_size: usize,
    ) -> Option<CellPlanner<'a>> {
        let n = problems.len();
        let cell_size = cell_size.max(1);
        let mut cells = Vec::new();
        let mut ranges = Vec::new();
        let mut start = 0usize;
        while start < n {
            let end = (start + cell_size).min(n);
            let sp: Vec<bool> =
                (start..end).map(|i| spread.get(i).copied().unwrap_or(false)).collect();
            // Each cell engine is built against the GLOBAL budget: the
            // rebalancer may grow a cell past its initial sub-budget,
            // and min-feasible lookahead jumps must stay visible.
            let engine = ShareEngine::new(&problems[start..end], budget, inv, &sp)?;
            cells.push(engine);
            ranges.push((start, end));
            start = end;
        }
        let floors: Vec<u32> =
            cells.iter().flat_map(|c| c.floors().iter().copied()).collect();
        let min_per: Vec<u32> =
            cells.iter().flat_map(|c| c.min_per().iter().copied()).collect();
        if budget < floors.iter().sum::<u32>() {
            return None;
        }
        Some(CellPlanner { cells, ranges, floors, min_per })
    }

    pub(crate) fn floors(&self) -> &[u32] {
        &self.floors
    }

    pub(crate) fn min_per(&self) -> &[u32] {
        &self.min_per
    }

    pub(crate) fn stats(&self) -> SolveStats {
        self.cells.iter().fold(SolveStats::default(), |a, c| a.merged(c.stats()))
    }

    /// Σ objective of a global share vector, through the cell memos.
    fn total_obj(&mut self, shares: &[u32]) -> f64 {
        let mut total = 0.0;
        for (c, &(start, end)) in self.ranges.iter().enumerate() {
            let keys: Vec<(usize, u32)> = (start..end).map(|i| (i - start, shares[i])).collect();
            self.cells[c].ensure(&keys);
            for i in start..end {
                total += self.cells[c].obj(i - start, shares[i]);
            }
        }
        total
    }

    /// The hierarchical share computation (uniform priorities):
    ///
    /// 1. sub-budgets — cell floor sums plus the surplus round-robined
    ///    one replica at a time;
    /// 2. independent per-cell greedy solves (each with its own
    ///    even-split floor, exactly the flat single-class pass);
    /// 3. the top-level rebalancer: grant any replicas the cells left
    ///    unspent to the globally best marginal member, then move one
    ///    replica at a time from the member whose last replica is worth
    ///    least to the member whose next replica is worth most, while
    ///    the transfer strictly gains;
    /// 4. the global even-split floor (never worse than even, like the
    ///    flat solver).
    pub(crate) fn solve_shares(&mut self, budget: u32) -> Vec<u32> {
        let n = self.floors.len();
        let floor_total: u32 = self.floors.iter().sum();
        // ---- 1: sub-budgets ---------------------------------------
        let mut cell_budget: Vec<u32> =
            self.cells.iter().map(|c| c.floors().iter().sum::<u32>()).collect();
        let mut surplus = budget - floor_total;
        if !cell_budget.is_empty() {
            let mut ci = 0usize;
            while surplus > 0 {
                cell_budget[ci] += 1;
                surplus -= 1;
                ci = (ci + 1) % cell_budget.len();
            }
        }
        // ---- 2: independent cell solves ---------------------------
        let mut shares = vec![0u32; n];
        let widest = self.ranges.iter().map(|&(s, e)| e - s).max().unwrap_or(0);
        let zeros: Vec<u32> = vec![0; widest]; // uniform priority 0 within a cell
        for (c, &(start, end)) in self.ranges.iter().enumerate() {
            let local = self.cells[c].solve_shares(cell_budget[c], &zeros[..end - start]);
            shares[start..end].copy_from_slice(&local);
        }
        // ---- 3: top-level marginal-gain rebalancer ----------------
        let mut leftover: u32 = budget - shares.iter().sum::<u32>();
        let max_iters = 4 * n + budget as usize;
        for _ in 0..max_iters {
            // Prewarm exactly the scan's reads, in scan order.
            for (c, &(start, end)) in self.ranges.iter().enumerate() {
                let mut keys = Vec::with_capacity(3 * (end - start));
                for i in start..end {
                    let li = i - start;
                    keys.push((li, shares[i]));
                    keys.push((li, shares[i] + 1));
                    if shares[i] > self.floors[i] {
                        keys.push((li, shares[i] - 1));
                    }
                }
                self.cells[c].ensure(&keys);
            }
            // Best receiver (max gain of one more replica) and best
            // donor (min loss of one fewer, above its floor) — strict
            // comparisons, first seen wins: deterministic.
            let mut best_gain: Option<(usize, f64)> = None;
            let mut best_loss: Option<(usize, f64)> = None;
            for (c, &(start, end)) in self.ranges.iter().enumerate() {
                for i in start..end {
                    let li = i - start;
                    let cur = self.cells[c].obj(li, shares[i]);
                    let gain = self.cells[c].obj(li, shares[i] + 1) - cur;
                    if best_gain.as_ref().is_none_or(|&(_, g)| gain > g) {
                        best_gain = Some((i, gain));
                    }
                    if shares[i] > self.floors[i] {
                        let loss = cur - self.cells[c].obj(li, shares[i] - 1);
                        if best_loss.as_ref().is_none_or(|&(_, l)| loss < l) {
                            best_loss = Some((i, loss));
                        }
                    }
                }
            }
            let Some((gi, gain)) = best_gain else { break };
            if leftover > 0 && gain > 1e-12 {
                shares[gi] += 1;
                leftover -= 1;
                continue;
            }
            match best_loss {
                Some((di, loss)) if di != gi && gain > loss + 1e-9 => {
                    shares[gi] += 1;
                    shares[di] -= 1;
                }
                _ => break, // no strictly-improving transfer left
            }
        }
        // ---- 4: the global even-split floor -----------------------
        let even = even_shares(budget, &self.floors);
        let cells_total = self.total_obj(&shares);
        let even_total = self.total_obj(&even);
        if cells_total + 1e-12 >= even_total {
            shares
        } else {
            even
        }
    }

    /// Materialize a global share vector through the cell memos
    /// (concatenation of the per-cell allocations).
    pub(crate) fn allocate(&mut self, shares: &[u32]) -> FleetAllocation {
        let mut members = Vec::with_capacity(shares.len());
        for (c, &(start, end)) in self.ranges.iter().enumerate() {
            let local = self.cells[c].allocate(&shares[start..end]);
            members.extend(local.members);
        }
        FleetAllocation {
            budget: shares.iter().sum(),
            replicas_used: members.iter().map(|m| m.replicas).sum(),
            total_objective: members.iter().map(|m| m.config.objective).sum(),
            members,
            packing: None,
        }
    }
}

/// Force a hierarchical solve at an explicit `cell_size` regardless of
/// [`cell_threshold`] — the quality-gap tests and the `fleet_scale`
/// bench cross-check cells against the flat solve with it.  Uniform
/// priorities over a fungible budget (no inventory); same `None`
/// contract as [`crate::fleet::solver::solve_fleet`].
pub fn solve_fleet_cells(
    problems: &[Problem],
    budget: u32,
    cell_size: usize,
) -> Option<(FleetAllocation, SolveStats)> {
    if problems.is_empty() {
        return Some((
            FleetAllocation {
                members: Vec::new(),
                budget,
                replicas_used: 0,
                total_objective: 0.0,
                packing: None,
            },
            SolveStats::default(),
        ));
    }
    let mut planner = CellPlanner::new(problems, budget, None, &[], cell_size)?;
    let shares = planner.solve_shares(budget);
    let mut alloc = planner.allocate(&shares);
    alloc.budget = budget;
    debug_assert!(alloc.replicas_used <= budget, "cells allocation exceeds budget");
    Some((alloc, planner.stats()))
}
