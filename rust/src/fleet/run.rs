//! One front door for driving a whole fleet on either clock.
//!
//! Before this module the two fleet drivers had sprawled into eight
//! near-duplicate entry points (`run_fleet_des{,_faults}{,_traced}` on
//! the DES side, `serve_fleet_with`/`serve_fleet_traced` on the wall
//! clock), each threading the same dozen arguments in a slightly
//! different order.  [`FleetRun`] is the single builder both clocks
//! share: construct it from a [`FleetSpec`] + [`FleetTuning`], chain
//! the optional planes, and finish with [`FleetRun::sim`] (virtual
//! time) or [`FleetRun::serve`] (wall clock):
//!
//! ```ignore
//! let run = FleetRun::new(FleetSpec::demo3(), FleetTuning::default())
//!     .seconds(240)
//!     .faults(vec![ZoneFault { at: 60.0, zone: "east".into() }])
//!     .router(RouterConfig::from_env())
//!     .telemetry(tel);
//! let des = run.sim(SimConfig { seed: 5, ..Default::default() })?;
//! let live = run.serve(&serve_cfg, LoadGenConfig { time_scale: 0.05, seed: 5 })?;
//! ```
//!
//! The builder resolves everything the old entry points made every
//! caller assemble by hand: member [`PipelineSpec`]s and analytic
//! profiles, end-to-end SLAs, correlated traces, the replica budget
//! (inventory cap when the spec carries nodes), reactive per-member
//! predictors, and — on the live clock — profile-sleeping
//! [`SyntheticExecutor`]s over time-scaled profiles.  Callers that
//! need real PJRT executors, custom predictors, or externally built
//! traces drop one level down to the option-struct cores these same
//! finishers call: [`run_fleet`] + [`FleetDesParams`] and
//! [`serve_fleet`] + [`FleetServeParams`].
//!
//! `faults` ride the DES clock only (scripted virtual-time zone kills
//! have no wall-clock analogue yet); every other plane — tuning,
//! router, telemetry — drives both clocks identically.

use std::sync::Arc;

use crate::coordinator::adapter::AdapterConfig;
use crate::fleet::router::RouterConfig;
use crate::fleet::solver::{FleetAdapter, FleetTuning};
use crate::fleet::spec::FleetSpec;
use crate::models::accuracy::AccuracyMetric;
use crate::models::pipelines::PipelineSpec;
use crate::predictor::{Predictor, ReactivePredictor};
use crate::profiler::analytic::pipeline_profiles;
use crate::profiler::profile::PipelineProfiles;
use crate::serving::engine::{
    serve_fleet, BatchExecutor, FleetServeParams, FleetServeReport, ServeConfig,
    SyntheticExecutor,
};
use crate::serving::loadgen::LoadGenConfig;
use crate::simulator::sim::{run_fleet, FleetDesParams, FleetRunMetrics, SimConfig, ZoneFault};
use crate::telemetry::Telemetry;
use crate::util::error::{Error, Result};
use crate::workload::trace::Trace;

/// Builder for one fleet run — see the module docs for the shape.
/// Cheap to keep around: one instance can finish on both clocks (the
/// canonical demo runs `.sim(..)` then `.serve(..)`).
#[derive(Clone)]
pub struct FleetRun {
    spec: FleetSpec,
    tuning: FleetTuning,
    metric: AccuracyMetric,
    system: String,
    interval: f64,
    apply_delay: f64,
    /// Trace length override; 0 = the spec's default.
    seconds: usize,
    faults: Vec<ZoneFault>,
    router: Option<RouterConfig>,
    telemetry: Option<Arc<Telemetry>>,
}

/// Everything [`FleetRun::sim`] returns: the run metrics plus the
/// adapter it drove (solve counters, cache stats, node inventory —
/// state the old entry points left in a caller-owned controller).
pub struct FleetSimRun {
    pub metrics: FleetRunMetrics,
    pub adapter: FleetAdapter,
}

/// The spec-derived inputs both finishers resolve identically.
struct Resolved {
    specs: Vec<PipelineSpec>,
    profiles: Vec<PipelineProfiles>,
    slas: Vec<f64>,
    traces: Vec<Trace>,
    budget: u32,
}

impl FleetRun {
    /// A run over `spec`'s members with `tuning`'s control plane
    /// (priorities, autoscaler, preemption, nodes, SLA classes, spread;
    /// `FleetTuning::default()` = the fixed-pool classless plane).
    /// Defaults: PAS metric, `"fleet-ipa"` system label, 10 s
    /// adaptation interval with an 8 s apply delay, the spec's trace
    /// length, no faults, no router, no telemetry.
    pub fn new(spec: FleetSpec, tuning: FleetTuning) -> FleetRun {
        FleetRun {
            spec,
            tuning,
            metric: AccuracyMetric::Pas,
            system: "fleet-ipa".into(),
            interval: 10.0,
            apply_delay: 8.0,
            seconds: 0,
            faults: Vec::new(),
            router: None,
            telemetry: None,
        }
    }

    /// Accuracy metric the joint solver maximizes (default PAS).
    pub fn metric(mut self, metric: AccuracyMetric) -> FleetRun {
        self.metric = metric;
        self
    }

    /// Label stamped on every member's [`crate::metrics::RunMetrics::system`].
    pub fn system(mut self, system: impl Into<String>) -> FleetRun {
        self.system = system.into();
        self
    }

    /// Adaptation-tick period and decision→activation delay (virtual
    /// seconds on the DES clock; the live clock takes its cadence from
    /// [`ServeConfig`] instead).
    pub fn cadence(mut self, interval: f64, apply_delay: f64) -> FleetRun {
        self.interval = interval;
        self.apply_delay = apply_delay;
        self
    }

    /// Trace length, seconds (0 = the spec's own default).
    pub fn seconds(mut self, seconds: usize) -> FleetRun {
        self.seconds = seconds;
        self
    }

    /// Scripted failure-domain outages (DES clock only).
    pub fn faults(mut self, faults: Vec<ZoneFault>) -> FleetRun {
        self.faults = faults;
        self
    }

    /// Attach the fleet front door (routing + admission) to both
    /// clocks; see [`crate::fleet::router`].
    pub fn router(mut self, router: RouterConfig) -> FleetRun {
        self.router = Some(router);
        self
    }

    /// Attach the flight recorder (spans + decision journal) to both
    /// clocks.
    pub fn telemetry(mut self, tel: Arc<Telemetry>) -> FleetRun {
        self.telemetry = Some(tel);
        self
    }

    fn resolve(&self) -> Result<Resolved> {
        // Validation-time advisories (e.g. spread flags over a < 2-zone
        // pool) land in the attached journal, ahead of any run event.
        let journal = self.telemetry.as_ref().map(|t| t.journal());
        self.spec
            .validate_journaled(journal.as_deref())
            .map_err(|e| crate::anyhow!("invalid fleet: {e}"))?;
        let specs = self.spec.specs().map_err(Error::from)?;
        let profiles: Vec<PipelineProfiles> = specs.iter().map(pipeline_profiles).collect();
        let slas: Vec<f64> = specs.iter().map(PipelineSpec::sla_e2e).collect();
        let traces = self.spec.traces(self.seconds);
        let budget = self
            .spec
            .nodes
            .as_ref()
            .map_or(self.spec.replica_budget, |i| i.replica_cap());
        Ok(Resolved { specs, profiles, slas, traces, budget })
    }

    fn predictors(n: usize) -> Vec<Box<dyn Predictor + Send>> {
        (0..n)
            .map(|_| Box::new(ReactivePredictor::default()) as Box<dyn Predictor + Send>)
            .collect()
    }

    /// Finish on the DES clock: build the [`FleetAdapter`] (reactive
    /// predictors, the tuning's control plane) and drive
    /// [`run_fleet`] over the spec's correlated traces.
    pub fn sim(&self, sim: SimConfig) -> Result<FleetSimRun> {
        let r = self.resolve()?;
        let mut adapter = FleetAdapter::new(
            r.specs,
            r.profiles.clone(),
            self.metric,
            r.budget,
            AdapterConfig::default(),
            Self::predictors(r.slas.len()),
        )
        .and_then(|a| a.with_tuning(self.tuning.clone()))
        .map_err(Error::from)?;
        let metrics = run_fleet(
            FleetDesParams {
                profiles: &r.profiles,
                slas: &r.slas,
                interval: self.interval,
                apply_delay: self.apply_delay,
                sim,
                system: &self.system,
                budget: r.budget,
                faults: &self.faults,
                router: self.router.clone(),
                telemetry: self.telemetry.as_deref(),
            },
            &mut adapter,
            &r.traces,
        );
        Ok(FleetSimRun { metrics, adapter })
    }

    /// Finish on the wall clock: time-scale the analytic profiles by
    /// `lg.time_scale`, plug profile-sleeping [`SyntheticExecutor`]s
    /// and reactive predictors into [`serve_fleet`], and replay the
    /// spec's traces compressed onto real threads.  (Real-artifact
    /// callers use [`serve_fleet`] directly with a
    /// [`crate::serving::engine::PoolExecutor`].)
    pub fn serve(&self, cfg: &ServeConfig, lg: LoadGenConfig) -> Result<FleetServeReport> {
        let r = self.resolve()?;
        let scaled: Vec<PipelineProfiles> =
            r.profiles.iter().map(|p| p.scaled(lg.time_scale)).collect();
        let executors: Vec<Arc<dyn BatchExecutor>> = scaled
            .iter()
            .map(|p| Arc::new(SyntheticExecutor::from_profiles(p, 1.0)) as Arc<dyn BatchExecutor>)
            .collect();
        serve_fleet(FleetServeParams {
            specs: &r.specs,
            profiles: scaled,
            metric: self.metric,
            budget: r.budget,
            system: &self.system,
            cfg,
            lg,
            traces: &r.traces,
            executors,
            predictors: Self::predictors(r.slas.len()),
            tuning: self.tuning.clone(),
            router: self.router.clone(),
            telemetry: self.telemetry.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn short_demo() -> FleetSpec {
        let mut f = FleetSpec::demo3();
        f.seconds = 40;
        f
    }

    #[test]
    fn builder_runs_the_demo_fleet_on_the_des_clock() {
        let run = FleetRun::new(short_demo(), FleetTuning::default()).system("builder");
        let out = run.sim(SimConfig { seed: 5, ..Default::default() }).unwrap();
        assert_eq!(out.metrics.members.len(), 3);
        assert!(out.metrics.total_requests() > 0);
        assert_eq!(out.metrics.members[0].system, "builder");
        // no router attached → all-default front-door stats
        assert!(out.metrics.router.iter().all(|s| s.total_routed() == 0));
    }

    #[test]
    fn builder_matches_the_raw_params_path_byte_for_byte() {
        let spec = short_demo();
        let built = FleetRun::new(spec.clone(), FleetTuning::default())
            .sim(SimConfig { seed: 5, ..Default::default() })
            .unwrap();

        // hand-assemble exactly what the builder resolves
        let specs = spec.specs().unwrap();
        let profs: Vec<PipelineProfiles> = specs.iter().map(pipeline_profiles).collect();
        let slas: Vec<f64> = specs.iter().map(PipelineSpec::sla_e2e).collect();
        let traces = spec.traces(0);
        let mut adapter = FleetAdapter::new(
            specs,
            profs.clone(),
            AccuracyMetric::Pas,
            spec.replica_budget,
            AdapterConfig::default(),
            FleetRun::predictors(slas.len()),
        )
        .and_then(|a| a.with_tuning(FleetTuning::default()))
        .unwrap();
        let raw = run_fleet(
            FleetDesParams {
                profiles: &profs,
                slas: &slas,
                interval: 10.0,
                apply_delay: 8.0,
                sim: SimConfig { seed: 5, ..Default::default() },
                system: "fleet-ipa",
                budget: spec.replica_budget,
                faults: &[],
                router: None,
                telemetry: None,
            },
            &mut adapter,
            &traces,
        );
        assert_eq!(built.metrics.total_requests(), raw.total_requests());
        for (b, r) in built.metrics.members.iter().zip(&raw.members) {
            assert_eq!(b.requests, r.requests, "per-request outcomes must be identical");
        }
    }

    #[test]
    fn routed_builder_run_routes_every_arrival() {
        let run = FleetRun::new(short_demo(), FleetTuning::default())
            .router(RouterConfig::default());
        let out = run.sim(SimConfig { seed: 5, ..Default::default() }).unwrap();
        for (m, stats) in out.metrics.router.iter().enumerate() {
            assert_eq!(
                stats.total_routed() as usize,
                out.metrics.members[m].requests.len(),
                "admission off: member {m} routes every arrival"
            );
            assert_eq!(stats.shed, 0);
        }
    }

    #[test]
    fn builder_rejects_invalid_specs() {
        let mut bad = FleetSpec::demo3();
        bad.replica_budget = 1;
        let err = FleetRun::new(bad, FleetTuning::default())
            .sim(SimConfig::default())
            .err()
            .expect("under-floor budget must fail");
        assert!(err.to_string().contains("invalid fleet"));
    }
}
