//! The joint cross-pipeline allocator: split one replica budget across
//! N pipelines so the fleet-total objective (Σ per-pipeline Eq. 9) is
//! maximized.
//!
//! Layering (mirrors the paper's §4.3 multi-objective structure):
//!
//! * [`solve_under_budget`] — one pipeline under a *total*-replica cap.
//!   Fast path: the per-pipeline exact IP ([`ip::solve_with_options`])
//!   over options filtered to the cap; when its optimum already fits
//!   the budget it is optimal for the constrained problem too.  Slow
//!   path: an exact DFS over the (Pareto-pruned, small) option sets
//!   with the Σ-replica constraint.
//! * [`solve_fleet`] — greedy marginal-gain allocation: every member
//!   starts at its one-replica-per-stage floor and each remaining
//!   replica goes to the pipeline whose next grant buys the most
//!   objective per replica (with a lookahead jump to a member's minimum
//!   feasible allocation, so crossing an infeasibility threshold is
//!   visible to the greedy).  The result is floored at the even-split
//!   baseline: the solver computes both and returns the better, so a
//!   fleet allocation is never worse than splitting the pool evenly.
//! * [`brute_best_split`] — exhaustive split enumeration for tiny
//!   fleets; the optimality cross-check the tests pin the greedy
//!   against.
//!
//! * [`solve_fleet_tiers`] — the same greedy run lexicographically over
//!   priority classes (higher class claims the pool first); with one
//!   distinct class it IS [`solve_fleet`].
//!
//! * [`solve_fleet_packed`] — the same share machinery over a
//!   heterogeneous [`NodeInventory`]: options are pre-filtered to
//!   node-placeable variants, the result must first-fit-decreasing
//!   bin-pack onto the nodes, and packing failures walk the budget
//!   down (memoized member evaluations make repair steps cheap).  On a
//!   fungible inventory it is byte-identical to [`solve_fleet_tiers`].
//!
//! * [`solve_fleet_placed`] — the topology-aware packed solve: packs
//!   *stickily* against the previous placement (moves minimized), and
//!   zone-spread members get ≥ 2 replicas per stage across ≥ 2 failure
//!   domains (spread floors, option transform, and the pack check
//!   itself), so one zone loss never drops them below their stage
//!   floor.  With no spread flags and no previous packing it IS
//!   [`solve_fleet_packed`].
//!
//! **Engine vs policy.**  The share machinery itself lives in an engine
//! layer — `ShareEngine`: per-member floors and option sets, a BOUNDED
//! memoized evaluation cache ([`SolveStats`] hit/miss telemetry), and
//! the greedy passes, with every independent per-member evaluation
//! fanned across [`solver_threads`] scoped workers
//! ([`crate::runtime::pool::scoped_map`]).  The three public solvers
//! are thin policies over it, and [`crate::fleet::cells`] reuses the
//! engine unchanged to go *hierarchical* above
//! [`crate::fleet::cells::cell_threshold`] members (uniform priorities
//! only): cells solve independently against sub-budgets, then a cheap
//! top-level marginal-gain rebalancer moves replicas between them.
//! Parallelism is placement-transparent: the fan-out computes exactly
//! the evaluations the sequential scan would read and admits them in
//! scan order, so results — and the journal's cache counters — are
//! byte-identical at any thread count (`IPA_SOLVER_THREADS=1` is the
//! legacy sequential path, kept for A/B).
//!
//! [`FleetAdapter`] packages the allocator as a [`FleetController`]
//! (per-member predictors → joint solve → one [`Decision`] per member)
//! for the fleet drivers in `simulator::sim` and `serving::engine` —
//! and, when tuned via [`FleetTuning`], runs the *elastic* control
//! plane on top: an InferLine-style slow/fast split where the slow path
//! is the joint solve plus a pool-resize proposal
//! ([`FleetAdapter::resize`], backed by
//! [`crate::fleet::autoscaler::Autoscaler`]) and the fast path is
//! mid-interval priority preemption ([`FleetAdapter::preempt`]) plus
//! incremental re-solves that skip members whose predicted λ barely
//! moved.
//!
//! Modeling note: a member whose IP is infeasible even at the full pool
//! gets a budget-clamped survival config ([`fallback_under_budget`] —
//! lightest variants, throughput-greedy replica placement) and sheds
//! the excess through §4.5 dropping, exactly like the single-pipeline
//! fallback.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use crate::coordinator::adapter::{AdapterConfig, Decision};
use crate::fleet::autoscaler::{Autoscaler, AutoscalerConfig};
use crate::fleet::cells::CellPlanner;
use crate::fleet::nodes::{config_demands, NodeInventory, Packing};
use crate::runtime::pool::scoped_map;
use crate::fleet::spec::SlaClass;
use crate::models::accuracy::AccuracyMetric;
use crate::models::pipelines::PipelineSpec;
use crate::optimizer::ip::{self, materialize, PipelineConfig, Problem, StageConfig};
use crate::optimizer::options::StageOption;
use crate::predictor::Predictor;
use crate::profiler::profile::PipelineProfiles;
use crate::resources::ResourceVec;
use crate::telemetry::journal::Journal;
use crate::util::json::Json;

/// Exact single-pipeline solve under a total-replica budget.  `None`
/// when no SLA-feasible configuration fits `budget` replicas.
pub fn solve_under_budget(
    p: &Problem,
    options: &[Vec<StageOption>],
    budget: u32,
) -> Option<PipelineConfig> {
    let s = options.len() as u32;
    if s == 0 || budget < s {
        return None;
    }
    // Every other stage needs at least one replica.
    let cap = budget - (s - 1);
    let filtered: Vec<Vec<StageOption>> = options
        .iter()
        .map(|os| os.iter().filter(|o| o.replicas <= cap).cloned().collect())
        .collect();
    if filtered.iter().any(Vec::is_empty) {
        return None;
    }
    // Fast path: the unconstrained optimum that already fits the pool
    // is optimal for the constrained problem as well.
    if let Some((cfg, _)) = ip::solve_with_options(p, &filtered) {
        if cfg.total_replicas() <= budget {
            return Some(cfg);
        }
    }
    budget_dfs(p, &filtered, budget)
}

/// Exact DFS with the Σ-replica constraint active (slow path of
/// [`solve_under_budget`]; option sets are Pareto-pruned and small).
fn budget_dfs(p: &Problem, options: &[Vec<StageOption>], budget: u32) -> Option<PipelineConfig> {
    let s = options.len();
    let sla = p.spec.sla_e2e();
    let mut suf_min_lat = vec![0.0f64; s + 1];
    let mut suf_min_rep = vec![0u32; s + 1];
    for d in (0..s).rev() {
        let min_lat =
            options[d].iter().map(StageOption::total_latency).fold(f64::MAX, f64::min);
        let min_rep = options[d].iter().map(|o| o.replicas).min().unwrap_or(1);
        suf_min_lat[d] = suf_min_lat[d + 1] + min_lat;
        suf_min_rep[d] = suf_min_rep[d + 1] + min_rep;
    }

    struct Ctx<'a> {
        p: &'a Problem<'a>,
        options: &'a [Vec<StageOption>],
        suf_min_lat: &'a [f64],
        suf_min_rep: &'a [u32],
        sla: f64,
        budget: u32,
    }

    fn rec(
        c: &Ctx,
        depth: usize,
        lat: f64,
        reps: u32,
        picks: &mut Vec<usize>,
        best: &mut Option<(f64, Vec<usize>)>,
    ) {
        if depth == c.options.len() {
            let cfg = materialize(c.p, c.options, picks);
            if best.as_ref().is_none_or(|(obj, _)| cfg.objective > *obj) {
                *best = Some((cfg.objective, picks.clone()));
            }
            return;
        }
        for (oi, o) in c.options[depth].iter().enumerate() {
            let nlat = lat + o.total_latency();
            if nlat + c.suf_min_lat[depth + 1] > c.sla {
                continue;
            }
            let nreps = reps + o.replicas;
            if nreps + c.suf_min_rep[depth + 1] > c.budget {
                continue;
            }
            picks[depth] = oi;
            rec(c, depth + 1, nlat, nreps, picks, best);
        }
    }

    let ctx = Ctx { p, options, suf_min_lat: &suf_min_lat, suf_min_rep: &suf_min_rep, sla, budget };
    let mut picks = vec![0usize; s];
    let mut best: Option<(f64, Vec<usize>)> = None;
    rec(&ctx, 0, 0.0, 0, &mut picks, &mut best);
    best.map(|(_, picks)| materialize(p, options, &picks))
}

/// Smallest total-replica budget at which the pipeline is SLA-feasible
/// (searched in `[n_stages, hi]`); `None` if infeasible even at `hi`.
pub fn min_feasible_replicas(p: &Problem, options: &[Vec<StageOption>], hi: u32) -> Option<u32> {
    min_feasible(p, options, hi).map(|(m, _)| m)
}

/// [`min_feasible_replicas`] plus the configuration solved AT the
/// threshold — the binary search's last successful probe is the
/// threshold itself, so callers that also need the config (the
/// autoscaler's per-axis demand vector) get it without a second solve.
fn min_feasible(
    p: &Problem,
    options: &[Vec<StageOption>],
    hi: u32,
) -> Option<(u32, PipelineConfig)> {
    let mut lo = options.len() as u32;
    if lo == 0 || hi < lo {
        return None;
    }
    // `best` is always the solve at the current `hi` — the search
    // invariant keeps `hi` feasible, and the loop exits with lo == hi.
    let mut best = solve_under_budget(p, options, hi)?;
    let mut hi = hi;
    // feasibility is monotone in the budget: binary search the threshold
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        match solve_under_budget(p, options, mid) {
            Some(cfg) => {
                best = cfg;
                hi = mid;
            }
            None => lo = mid + 1,
        }
    }
    Some((lo, best))
}

/// Budget-clamped survival configuration (the fleet twin of
/// [`ip::fallback_config`]): lightest variant per stage at its
/// throughput-optimal batch, with the granted replicas placed greedily
/// on the most throughput-starved stage.  Always uses ≤ `budget`
/// replicas and ≥ 1 per stage; §4.5 dropping sheds what it cannot
/// serve.
pub fn fallback_under_budget(p: &Problem, budget: u32) -> PipelineConfig {
    fallback_min(p, budget, 1)
}

/// [`fallback_under_budget`] with a per-stage replica floor: zone-spread
/// members survive on ≥ 2 replicas per stage (one zone loss must leave
/// one), classless members on the classic 1.
fn fallback_min(p: &Problem, budget: u32, min_per_stage: u32) -> PipelineConfig {
    let s = p.profiles.stages.len();
    let min_per_stage = min_per_stage.max(1);
    let budget = budget.max(s as u32 * min_per_stage);
    let w = p.spec.weights;

    struct Pick<'a> {
        vi: usize,
        vp: &'a crate::profiler::profile::VariantProfile,
        batch: usize,
        tput1: f64,
    }
    let picks: Vec<Pick> = p
        .profiles
        .stages
        .iter()
        .map(|st| {
            let (vi, vp) = st
                .variants
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| {
                    (a.cost_per_replica(), a.latency.latency(1))
                        .partial_cmp(&(b.cost_per_replica(), b.latency.latency(1)))
                        .unwrap()
                })
                .unwrap();
            let batch = vp.latency.best_batch();
            Pick { vi, vp, batch, tput1: vp.latency.throughput(batch) }
        })
        .collect();

    let mut replicas = vec![min_per_stage; s];
    let mut left = budget - s as u32 * min_per_stage;
    while left > 0 {
        // most starved stage = lowest provisioned throughput, if any is
        // still short of λ
        let (i, headroom) = replicas
            .iter()
            .enumerate()
            .map(|(i, &r)| (i, r as f64 * picks[i].tput1))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        if headroom >= p.lambda {
            break; // every stage keeps up; don't burn pool for nothing
        }
        replicas[i] += 1;
        left -= 1;
    }

    let mut stages = Vec::with_capacity(s);
    let mut cost = 0.0;
    let mut batch_sum = 0usize;
    let mut lat = 0.0;
    let mut pas_frac = 1.0;
    let mut resources = ResourceVec::ZERO;
    for (pk, &n) in picks.iter().zip(&replicas) {
        stages.push(StageConfig {
            variant_idx: pk.vi,
            variant_key: pk.vp.variant.key(),
            batch: pk.batch,
            replicas: n,
            cost: n as f64 * pk.vp.cost_per_replica(),
            accuracy: pk.vp.variant.accuracy,
            latency: pk.vp.latency.latency(pk.batch),
            resources: pk.vp.resources_per_replica(),
        });
        cost += n as f64 * pk.vp.cost_per_replica();
        batch_sum += pk.batch;
        lat += pk.vp.latency.latency(pk.batch)
            + crate::queueing::worst_case_delay(pk.batch, p.lambda);
        pas_frac *= pk.vp.variant.accuracy / 100.0;
        resources = resources.add(pk.vp.resources_per_replica().scale(n as f64));
    }
    PipelineConfig {
        stages,
        pas: 100.0 * pas_frac,
        cost,
        batch_sum,
        objective: w.alpha * 100.0 * pas_frac - w.beta * cost - w.delta * batch_sum as f64,
        latency_e2e: lat,
        resources,
    }
}

/// One member's share of the pool and the configuration it bought.
#[derive(Debug, Clone)]
pub struct MemberAllocation {
    /// Replicas granted from the shared pool.
    pub budget: u32,
    /// Chosen configuration (solved or budget-clamped fallback).
    pub config: PipelineConfig,
    /// Replicas the configuration actually occupies (≤ `budget`).
    pub replicas: u32,
    /// False when the member IP was infeasible within its share and the
    /// clamped fallback was used.
    pub solved: bool,
}

/// The joint allocation across the fleet.
#[derive(Debug, Clone)]
pub struct FleetAllocation {
    pub members: Vec<MemberAllocation>,
    /// Σ granted member shares ([`solve_fleet`] resets this to the pool
    /// size it solved against; greedy may leave part of the pool
    /// ungranted when no member benefits).
    pub budget: u32,
    /// Σ member `replicas` — never exceeds `budget`.
    pub replicas_used: u32,
    /// Σ member objectives (the quantity the greedy maximizes).
    pub total_objective: f64,
    /// Node placement of every replica — `Some` only for
    /// [`solve_fleet_packed`] results (the scalar solvers never pack).
    pub packing: Option<Packing>,
}

/// The even-split baseline shares: every member starts at its stage
/// floor, the rest of the pool is dealt round-robin.
pub fn even_shares(budget: u32, floors: &[u32]) -> Vec<u32> {
    let mut shares = floors.to_vec();
    let floor_total: u32 = floors.iter().sum();
    let mut left = budget.saturating_sub(floor_total);
    let n = floors.len();
    let mut i = 0usize;
    while left > 0 && n > 0 {
        shares[i] += 1;
        left -= 1;
        i = (i + 1) % n;
    }
    shares
}

fn eval_member(p: &Problem, options: &[Vec<StageOption>], b: u32) -> (PipelineConfig, bool) {
    eval_member_at(p, options, b, 1)
}

/// [`eval_member`] with a per-stage replica floor for the fallback path
/// (the solve path enforces the floor through the option transform of
/// [`ShareEngine`] — every spread option carries ≥ `min_per` replicas).
fn eval_member_at(
    p: &Problem,
    options: &[Vec<StageOption>],
    b: u32,
    min_per: u32,
) -> (PipelineConfig, bool) {
    match solve_under_budget(p, options, b) {
        Some(cfg) => (cfg, true),
        None => (fallback_min(p, b, min_per), false),
    }
}

/// Evaluate an explicit share vector (used by the even-split baseline
/// and the property tests).
pub fn allocate_at(
    problems: &[Problem],
    options: &[Vec<Vec<StageOption>>],
    shares: &[u32],
) -> FleetAllocation {
    let members: Vec<MemberAllocation> = problems
        .iter()
        .zip(options)
        .zip(shares)
        .map(|((p, os), &b)| {
            let (config, solved) = eval_member(p, os, b);
            let replicas = config.total_replicas();
            MemberAllocation { budget: b, config, replicas, solved }
        })
        .collect();
    FleetAllocation {
        budget: shares.iter().sum(),
        replicas_used: members.iter().map(|m| m.replicas).sum(),
        total_objective: members.iter().map(|m| m.config.objective).sum(),
        members,
        packing: None,
    }
}

/// Global solver fan-out override (0 = unset → env/auto resolution).
static SOLVER_THREADS: AtomicUsize = AtomicUsize::new(0);

fn env_solver_threads() -> usize {
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("IPA_SOLVER_THREADS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(0)
    })
}

/// Threads the engine fans independent per-member evaluations across.
/// Resolution order: [`set_solver_threads`] override, else the
/// `IPA_SOLVER_THREADS` environment variable, else the machine's
/// available parallelism capped at 8 (member solves are short; more
/// workers only pay spawn cost).  `1` is the legacy sequential path:
/// every evaluation runs inline on the caller's thread.  The knob
/// trades wall time ONLY — the fan-out computes exactly the
/// evaluations the sequential scan would read and admits them in scan
/// order, so decisions and cache counters are byte-identical at any
/// value.
pub fn solver_threads() -> usize {
    let o = SOLVER_THREADS.load(Ordering::Relaxed);
    if o != 0 {
        return o;
    }
    let e = env_solver_threads();
    if e != 0 {
        return e;
    }
    std::thread::available_parallelism().map_or(1, |n| n.get().min(8))
}

/// Override [`solver_threads`] for this process (0 = back to the
/// env/auto resolution).  The benches and the determinism tests A/B
/// the parallel engine against the sequential path with it.
pub fn set_solver_threads(n: usize) {
    SOLVER_THREADS.store(n, Ordering::Relaxed);
}

/// Bound on memoized evaluations kept per member.  The packed solver's
/// budget walk-down revisits nearby shares constantly but only a
/// handful of distinct budgets are ever live at once; unbounded, a
/// 100-member adapter held every (member, share) config it ever
/// touched across ticks.
const EVAL_CACHE_CAP: usize = 128;

/// One member's bounded memo of budget-capped solves: share → (config,
/// solved), FIFO-evicted at [`EVAL_CACHE_CAP`].  Eviction depends only
/// on insertion order, which the engine keeps deterministic (scan-order
/// prewarm), so the hit/miss counters — surfaced in the decision
/// journal — are byte-identical at any thread count.
#[derive(Clone, Default)]
struct EvalCache {
    map: HashMap<u32, (PipelineConfig, bool)>,
    order: VecDeque<u32>,
    hits: u64,
    misses: u64,
}

impl EvalCache {
    fn lookup(&mut self, b: u32) -> Option<(PipelineConfig, bool)> {
        match self.map.get(&b) {
            Some((cfg, solved)) => {
                self.hits += 1;
                Some((cfg.clone(), *solved))
            }
            None => None,
        }
    }

    /// Record a freshly computed evaluation (counted as a miss),
    /// evicting the oldest entry at the cap.
    fn admit(&mut self, b: u32, v: (PipelineConfig, bool)) {
        debug_assert!(!self.map.contains_key(&b), "duplicate admit for share {b}");
        self.misses += 1;
        if self.order.len() >= EVAL_CACHE_CAP {
            if let Some(old) = self.order.pop_front() {
                self.map.remove(&old);
            }
        }
        self.order.push_back(b);
        self.map.insert(b, v);
    }
}

/// Engine cache telemetry for one joint solve, surfaced in the decision
/// journal's full-`solve` events.  Deterministic across thread counts
/// (the prewarm admits in scan order), so journals stay byte-identical
/// under `IPA_SOLVER_THREADS`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SolveStats {
    /// Memo hits across every member cache.
    pub cache_hits: u64,
    /// Evaluations actually computed (= admissions).
    pub cache_misses: u64,
}

impl SolveStats {
    /// Component-wise sum (the cells planner aggregates per-cell stats).
    pub fn merged(self, other: SolveStats) -> SolveStats {
        SolveStats {
            cache_hits: self.cache_hits + other.cache_hits,
            cache_misses: self.cache_misses + other.cache_misses,
        }
    }
}

/// The ENGINE layer of the joint solver: per-member floors (one replica
/// per stage — TWO for zone-spread members on a multi-zone inventory),
/// Pareto-pruned option sets (filtered to node-placeable options when
/// an inventory is given), the bounded memoized evaluations, the
/// min-feasible lookahead targets, and the greedy share machinery.
/// [`solve_fleet`] / [`solve_fleet_tiers`] / [`solve_fleet_placed`]
/// drive it as thin policies, and [`crate::fleet::cells`] reuses it
/// unchanged for hierarchical solves.
///
/// Construction and the greedy passes fan independent per-member work
/// across [`solver_threads`] scoped workers; every scan that READS the
/// memo first prewarms exactly its read set in scan order
/// ([`ShareEngine::ensure`]), so the selection logic itself stays
/// sequential and results are byte-identical at any thread count.
pub(crate) struct ShareEngine<'a> {
    problems: &'a [Problem<'a>],
    floors: Vec<u32>,
    /// Per-stage replica floor of each member (2 when spread is active).
    min_per: Vec<u32>,
    options: Vec<Vec<Vec<StageOption>>>,
    min_b: Vec<Option<u32>>,
    cache: Vec<EvalCache>,
}

impl<'a> ShareEngine<'a> {
    /// `None` when `budget` cannot cover the per-member floors.
    pub(crate) fn new(
        problems: &'a [Problem<'a>],
        budget: u32,
        inv: Option<&NodeInventory>,
        spread: &[bool],
    ) -> Option<ShareEngine<'a>> {
        let n = problems.len();
        let min_per: Vec<u32> =
            (0..n).map(|i| if spread_active(spread, i, inv) { 2 } else { 1 }).collect();
        let floors: Vec<u32> = problems
            .iter()
            .zip(&min_per)
            .map(|(p, &m)| p.profiles.stages.len() as u32 * m)
            .collect();
        let floor_total: u32 = floors.iter().sum();
        if budget < floor_total {
            return None;
        }
        // Option enumeration + the min-feasible lookahead search are
        // the dominant construction cost at fleet scale and independent
        // across members — fanned out, merged in member order.
        let idx: Vec<usize> = (0..n).collect();
        let mp = &min_per;
        let built: Vec<(Vec<Vec<StageOption>>, Option<u32>)> =
            scoped_map(solver_threads(), &idx, |_, &i| {
                let p = &problems[i];
                let mut os = p.stage_options();
                if let Some(inv) = inv {
                    // A variant no node shape can host one replica of
                    // can never be placed — drop it before the solve.
                    filter_options(&mut os, inv, mp[i] > 1, mp[i]);
                }
                let mb = min_feasible_replicas(p, &os, budget);
                (os, mb)
            });
        let mut options = Vec::with_capacity(n);
        let mut min_b = Vec::with_capacity(n);
        for (os, mb) in built {
            options.push(os);
            min_b.push(mb);
        }
        Some(ShareEngine {
            problems,
            floors,
            min_per,
            options,
            min_b,
            cache: vec![EvalCache::default(); n],
        })
    }

    pub(crate) fn floors(&self) -> &[u32] {
        &self.floors
    }

    pub(crate) fn min_per(&self) -> &[u32] {
        &self.min_per
    }

    pub(crate) fn stats(&self) -> SolveStats {
        SolveStats {
            cache_hits: self.cache.iter().map(|c| c.hits).sum(),
            cache_misses: self.cache.iter().map(|c| c.misses).sum(),
        }
    }

    /// Compute (in parallel) every listed evaluation not yet cached and
    /// admit the results in list order — the deterministic prewarm each
    /// greedy scan runs before reading.  List order IS the sequential
    /// scan order, so FIFO eviction and the hit/miss counters match the
    /// threads=1 path exactly.
    pub(crate) fn ensure(&mut self, keys: &[(usize, u32)]) {
        let mut missing: Vec<(usize, u32)> = Vec::new();
        for &(i, b) in keys {
            if !self.cache[i].map.contains_key(&b) && !missing.contains(&(i, b)) {
                missing.push((i, b));
            }
        }
        if missing.is_empty() {
            return;
        }
        let problems = self.problems;
        let options = &self.options;
        let min_per = &self.min_per;
        let computed: Vec<(PipelineConfig, bool)> =
            scoped_map(solver_threads(), &missing, |_, &(i, b)| {
                eval_member_at(&problems[i], &options[i], b, min_per[i])
            });
        for ((i, b), v) in missing.into_iter().zip(computed) {
            self.cache[i].admit(b, v);
        }
    }

    /// Memoized member evaluation; computes inline on a (rare,
    /// eviction-induced) miss.
    pub(crate) fn eval(&mut self, i: usize, b: u32) -> (PipelineConfig, bool) {
        if let Some(v) = self.cache[i].lookup(b) {
            return v;
        }
        let v = eval_member_at(&self.problems[i], &self.options[i], b, self.min_per[i]);
        self.cache[i].admit(b, v.clone());
        v
    }

    pub(crate) fn obj(&mut self, i: usize, b: u32) -> f64 {
        self.eval(i, b).0.objective
    }

    /// The evaluations one greedy iteration's scan reads, in scan
    /// order: each listed member at its current share, at +1, and at
    /// its min-feasible lookahead jump when that jump fits `remaining`.
    fn grant_keys(&self, members: &[usize], shares: &[u32], remaining: u32) -> Vec<(usize, u32)> {
        let mut keys = Vec::with_capacity(members.len() * 3);
        for &i in members {
            keys.push((i, shares[i]));
            keys.push((i, shares[i] + 1)); // remaining >= 1 inside the loop
            if let Some(mb) = self.min_b[i] {
                let k = mb.saturating_sub(shares[i]);
                if k > 1 && k <= remaining {
                    keys.push((i, mb));
                }
            }
        }
        keys
    }

    /// The greedy marginal-gain pass over a *subset* of members: while
    /// `remaining` replicas are left, grant the next one (or a
    /// lookahead jump to a member's minimum feasible allocation) to
    /// whichever listed member buys the most objective per replica.
    /// Mutates `shares` and `remaining` in place; stops when no listed
    /// member benefits.  Each iteration prewarms its read set, then
    /// selects with a strictly sequential scan.
    pub(crate) fn greedy_grant(
        &mut self,
        members: &[usize],
        shares: &mut [u32],
        remaining: &mut u32,
    ) {
        while *remaining > 0 {
            let keys = self.grant_keys(members, shares, *remaining);
            self.ensure(&keys);
            let mut best: Option<(usize, u32, f64)> = None;
            for &i in members {
                let cur = self.obj(i, shares[i]);
                let mut cands = vec![1u32];
                if let Some(mb) = self.min_b[i] {
                    if mb > shares[i] {
                        cands.push(mb - shares[i]);
                    }
                }
                for &k in &cands {
                    if k == 0 || k > *remaining {
                        continue;
                    }
                    let gain = self.obj(i, shares[i] + k) - cur;
                    if gain <= 1e-12 {
                        continue;
                    }
                    let rate = gain / k as f64;
                    if best.as_ref().is_none_or(|&(_, _, r)| rate > r) {
                        best = Some((i, k, rate));
                    }
                }
            }
            match best {
                Some((i, k, _)) => {
                    shares[i] += k;
                    *remaining -= k;
                }
                None => break, // no listed member benefits from another replica
            }
        }
    }

    /// The share computation both joint solvers run: a single priority
    /// class takes the plain greedy with the even-split floor; several
    /// classes take the lexicographic tier loop (no even-split floor —
    /// precedence is the point).  Reusable across budgets on one engine
    /// (the packed solver walks budgets downward keeping the memo warm).
    pub(crate) fn solve_shares(&mut self, budget: u32, priorities: &[u32]) -> Vec<u32> {
        let n = self.problems.len();
        let floor_total: u32 = self.floors.iter().sum();
        let mut shares = self.floors.clone();
        let mut remaining = budget - floor_total;
        if priorities.iter().all(|&p| p == priorities[0]) {
            let all: Vec<usize> = (0..n).collect();
            self.greedy_grant(&all, &mut shares, &mut remaining);
            // Never worse than the even split: compute both, keep the better.
            let even = even_shares(budget, &self.floors);
            let mut keys: Vec<(usize, u32)> = (0..n).map(|i| (i, shares[i])).collect();
            keys.extend((0..n).map(|i| (i, even[i])));
            self.ensure(&keys);
            let greedy_total: f64 = (0..n).map(|i| self.obj(i, shares[i])).sum();
            let even_total: f64 = (0..n).map(|i| self.obj(i, even[i])).sum();
            if greedy_total + 1e-12 >= even_total {
                shares
            } else {
                even
            }
        } else {
            let mut classes: Vec<u32> = priorities.to_vec();
            classes.sort_unstable();
            classes.dedup();
            for &class in classes.iter().rev() {
                let tier: Vec<usize> = (0..n).filter(|&i| priorities[i] == class).collect();
                self.greedy_grant(&tier, &mut shares, &mut remaining);
                if remaining == 0 {
                    break;
                }
            }
            shares
        }
    }

    /// Materialize an allocation for a share vector through the memo
    /// (same outcome as [`allocate_at`], no re-solve when warm).
    pub(crate) fn allocate(&mut self, shares: &[u32]) -> FleetAllocation {
        let keys: Vec<(usize, u32)> = shares.iter().copied().enumerate().collect();
        self.ensure(&keys);
        let members: Vec<MemberAllocation> = shares
            .iter()
            .enumerate()
            .map(|(i, &b)| {
                let (config, solved) = self.eval(i, b);
                let replicas = config.total_replicas();
                MemberAllocation { budget: b, config, replicas, solved }
            })
            .collect();
        FleetAllocation {
            budget: shares.iter().sum(),
            replicas_used: members.iter().map(|m| m.replicas).sum(),
            total_objective: members.iter().map(|m| m.config.objective).sum(),
            members,
            packing: None,
        }
    }
}

/// Flat-vs-cells dispatch: at or above
/// [`crate::fleet::cells::cell_threshold`] members with uniform
/// priorities the fleet is partitioned into cells solved independently
/// and rebalanced (tier precedence is global, so tiered fleets keep
/// the flat path); below it, one flat engine.  All three public
/// solvers go through this, so flat and hierarchical paths see the
/// same activation rule.
enum Planner<'a> {
    Flat(ShareEngine<'a>),
    Cells(CellPlanner<'a>),
}

impl<'a> Planner<'a> {
    fn new(
        problems: &'a [Problem<'a>],
        budget: u32,
        inv: Option<&NodeInventory>,
        spread: &[bool],
        priorities: &[u32],
    ) -> Option<Planner<'a>> {
        let n = problems.len();
        let uniform = priorities.iter().all(|&p| p == priorities[0]);
        if uniform && n >= crate::fleet::cells::cell_threshold() {
            return CellPlanner::new(
                problems,
                budget,
                inv,
                spread,
                crate::fleet::cells::DEFAULT_CELL_SIZE,
            )
            .map(Planner::Cells);
        }
        ShareEngine::new(problems, budget, inv, spread).map(Planner::Flat)
    }

    fn solve_shares(&mut self, budget: u32, priorities: &[u32]) -> Vec<u32> {
        match self {
            Planner::Flat(e) => e.solve_shares(budget, priorities),
            Planner::Cells(c) => c.solve_shares(budget),
        }
    }

    fn allocate(&mut self, shares: &[u32]) -> FleetAllocation {
        match self {
            Planner::Flat(e) => e.allocate(shares),
            Planner::Cells(c) => c.allocate(shares),
        }
    }

    fn floors(&self) -> &[u32] {
        match self {
            Planner::Flat(e) => e.floors(),
            Planner::Cells(c) => c.floors(),
        }
    }

    fn min_per(&self) -> &[u32] {
        match self {
            Planner::Flat(e) => e.min_per(),
            Planner::Cells(c) => c.min_per(),
        }
    }

    fn stats(&self) -> SolveStats {
        match self {
            Planner::Flat(e) => e.stats(),
            Planner::Cells(c) => c.stats(),
        }
    }
}

/// Does member `i`'s zone-spread flag bite?  Only on an inventory with
/// ≥ 2 zones — below that there is nothing to spread across and the
/// constraint is vacuous (the classic behavior).
fn spread_active(spread: &[bool], i: usize, inv: Option<&NodeInventory>) -> bool {
    spread.get(i).copied().unwrap_or(false)
        && inv.is_some_and(|v| v.distinct_zones() >= 2)
}

/// The per-member option transform of the topology-aware solve: keep
/// node-placeable options only, and for spread-active members keep
/// options hostable in ≥ 2 zones with their induced replica count
/// raised to `min_per` (so EVERY solve path — joint, incremental,
/// preemption — emits ≥ min_per replicas per spread stage).
fn filter_options(
    os: &mut [Vec<StageOption>],
    inv: &NodeInventory,
    spread_on: bool,
    min_per: u32,
) {
    for stage in os.iter_mut() {
        stage.retain(|o| inv.fits_any_node(o.resources));
        if spread_on {
            stage.retain(|o| inv.zones_fitting(o.resources) >= 2);
            for o in stage.iter_mut() {
                if o.replicas < min_per {
                    o.cost = o.cost / o.replicas as f64 * min_per as f64;
                    o.replicas = min_per;
                }
            }
        }
    }
}

/// Greedy marginal-gain joint solve.  `None` only when `budget` cannot
/// cover one replica per stage across the fleet; otherwise the returned
/// allocation respects the budget and its total objective is at least
/// the even-split baseline's.
pub fn solve_fleet(problems: &[Problem], budget: u32) -> Option<FleetAllocation> {
    solve_fleet_stats(problems, budget).map(|(a, _)| a)
}

/// [`solve_fleet`] plus the engine's cache telemetry.
pub fn solve_fleet_stats(
    problems: &[Problem],
    budget: u32,
) -> Option<(FleetAllocation, SolveStats)> {
    let n = problems.len();
    if n == 0 {
        return Some((
            FleetAllocation {
                members: Vec::new(),
                budget,
                replicas_used: 0,
                total_objective: 0.0,
                packing: None,
            },
            SolveStats::default(),
        ));
    }
    let zeros = vec![0u32; n];
    let mut planner = Planner::new(problems, budget, None, &[], &zeros)?;
    let shares = planner.solve_shares(budget, &zeros);
    let mut alloc = planner.allocate(&shares);
    alloc.budget = budget;
    debug_assert!(alloc.replicas_used <= budget, "fleet allocation exceeds budget");
    Some((alloc, planner.stats()))
}

/// Priority-tiered joint solve: members are grouped by priority class
/// (HIGHER value = more important, like a Kubernetes PriorityClass) and
/// the pool is granted *lexicographically* — the top tier's greedy pass
/// claims whatever it can benefit from first, then the next tier runs
/// on the remainder, and so on.  Every member still holds its
/// one-replica-per-stage floor regardless of class (a starved tier
/// would be a dead pipeline, not a deprioritized one).
///
/// With a single distinct priority this is exactly [`solve_fleet`]
/// (even-split floor included); with several tiers the even-split floor
/// is intentionally dropped — precedence is the point.
pub fn solve_fleet_tiers(
    problems: &[Problem],
    budget: u32,
    priorities: &[u32],
) -> Option<FleetAllocation> {
    solve_fleet_tiers_stats(problems, budget, priorities).map(|(a, _)| a)
}

/// [`solve_fleet_tiers`] plus the engine's cache telemetry.
pub fn solve_fleet_tiers_stats(
    problems: &[Problem],
    budget: u32,
    priorities: &[u32],
) -> Option<(FleetAllocation, SolveStats)> {
    let n = problems.len();
    assert_eq!(priorities.len(), n, "one priority class per member");
    if n == 0 || priorities.iter().all(|&p| p == priorities[0]) {
        return solve_fleet_stats(problems, budget);
    }
    let mut planner = Planner::new(problems, budget, None, &[], priorities)?;
    let shares = planner.solve_shares(budget, priorities);
    let mut alloc = planner.allocate(&shares);
    alloc.budget = budget;
    debug_assert!(alloc.replicas_used <= budget, "tiered allocation exceeds budget");
    Some((alloc, planner.stats()))
}

/// The bin-packing joint solve over a heterogeneous node inventory.
///
/// Same tiered/greedy share machinery as [`solve_fleet_tiers`], with
/// the pool constraint upgraded from `Σ replicas ≤ budget` to "every
/// replica's resource vector places onto some node" (first-fit-
/// decreasing, [`NodeInventory::pack`]):
///
/// 1. options are pre-filtered to variants at least one node shape can
///    host (accel-demanding variants vanish on CPU-only pools);
/// 2. the share solve runs at the inventory's replica cap and the
///    result is packed; on packing failure the budget steps down one
///    replica and re-solves (the memoized member evaluations carry
///    over, so repair steps are cheap);
/// 3. the last resort — one lightest replica per stage — is what
///    [`FleetAdapter::with_tuning`] validates packable up front, so
///    adapter callers never see `None` here.
///
/// On a [`NodeInventory::fungible`] inventory every step degenerates to
/// the scalar path: no option is filtered, the first pack succeeds, and
/// the allocation is byte-identical to [`solve_fleet_tiers`] at
/// `budget = n` (pinned by `tests/fleet_binpack.rs`).
pub fn solve_fleet_packed(
    problems: &[Problem],
    inv: &NodeInventory,
    priorities: &[u32],
) -> Option<FleetAllocation> {
    solve_fleet_placed(problems, inv, priorities, &[], None)
}

/// The topology-aware [`solve_fleet_packed`]: per-member zone-spread
/// flags (flagged members must place every stage across ≥ 2 failure
/// domains — enforced through the option transform, the spread floors
/// and the pack check itself) and an optional previous [`Packing`] the
/// result is packed *stickily* against, so the placement the allocation
/// reports moves as few replicas as the FFD permits.  With no flags and
/// no previous packing this IS [`solve_fleet_packed`].
pub fn solve_fleet_placed(
    problems: &[Problem],
    inv: &NodeInventory,
    priorities: &[u32],
    spread: &[bool],
    prev: Option<&Packing>,
) -> Option<FleetAllocation> {
    solve_fleet_placed_stats(problems, inv, priorities, spread, prev).map(|(a, _)| a)
}

/// [`solve_fleet_placed`] plus the engine's cache telemetry.
pub fn solve_fleet_placed_stats(
    problems: &[Problem],
    inv: &NodeInventory,
    priorities: &[u32],
    spread: &[bool],
    prev: Option<&Packing>,
) -> Option<(FleetAllocation, SolveStats)> {
    let n = problems.len();
    assert_eq!(priorities.len(), n, "one priority class per member");
    let cap = inv.replica_cap();
    if n == 0 {
        return Some((
            FleetAllocation {
                members: Vec::new(),
                budget: cap,
                replicas_used: 0,
                total_objective: 0.0,
                packing: inv.pack(&[]),
            },
            SolveStats::default(),
        ));
    }
    let pack =
        |demands: &[crate::fleet::nodes::PackItem]| inv.pack_prefer_sticky(demands, prev, spread);
    let mut planner = Planner::new(problems, cap, Some(inv), spread, priorities)?;
    let floor_total: u32 = planner.floors().iter().sum();
    let mut b = cap;
    loop {
        let shares = planner.solve_shares(b, priorities);
        let mut alloc = planner.allocate(&shares);
        let refs: Vec<&PipelineConfig> = alloc.members.iter().map(|m| &m.config).collect();
        if let Some(packing) = pack(&config_demands(&refs)) {
            alloc.budget = b;
            alloc.packing = Some(packing);
            debug_assert!(alloc.replicas_used <= b, "packed allocation exceeds budget");
            return Some((alloc, planner.stats()));
        }
        if b == floor_total {
            break;
        }
        // Step below what the failed allocation actually used: any
        // budget ≥ replicas_used could reproduce the same unpackable
        // shares, and the replica cap is a loose CPU-slot bound for
        // fat replicas — single-replica steps from it would crawl.
        b = alloc.replicas_used.saturating_sub(1).clamp(floor_total, b - 1);
    }
    // Last resort: the per-stage-floor lightest-variant configuration
    // (one replica per stage, two for spread-active members).
    let members: Vec<MemberAllocation> = problems
        .iter()
        .zip(planner.floors())
        .zip(planner.min_per())
        .map(|((p, &f), &m)| {
            let config = fallback_min(p, f, m);
            let replicas = config.total_replicas();
            MemberAllocation { budget: f, config, replicas, solved: false }
        })
        .collect();
    let refs: Vec<&PipelineConfig> = members.iter().map(|m| &m.config).collect();
    let packing = pack(&config_demands(&refs))?;
    Some((
        FleetAllocation {
            budget: floor_total,
            replicas_used: members.iter().map(|m| m.replicas).sum(),
            total_objective: members.iter().map(|m| m.config.objective).sum(),
            members,
            packing: Some(packing),
        },
        planner.stats(),
    ))
}

/// Exhaustive best split for tiny fleets (the greedy's cross-check):
/// best Σ objective over every share vector with `shares[i] ≥
/// n_stages_i` and `Σ shares ≤ budget`.
pub fn brute_best_split(problems: &[Problem], budget: u32) -> Option<f64> {
    let n = problems.len();
    if n == 0 {
        return Some(0.0);
    }
    let floors: Vec<u32> = problems.iter().map(|p| p.profiles.stages.len() as u32).collect();
    let floor_total: u32 = floors.iter().sum();
    if budget < floor_total {
        return None;
    }
    let options: Vec<Vec<Vec<StageOption>>> =
        problems.iter().map(|p| p.stage_options()).collect();
    let mut eval =
        |i: usize, b: u32| -> f64 { eval_member(&problems[i], &options[i], b).0.objective };

    fn rec(
        i: usize,
        left: u32,
        floors: &[u32],
        acc: f64,
        eval: &mut dyn FnMut(usize, u32) -> f64,
        best: &mut f64,
    ) {
        let n = floors.len();
        if i == n - 1 {
            for b in floors[i]..=left {
                let total = acc + eval(i, b);
                if total > *best {
                    *best = total;
                }
            }
            return;
        }
        let rest_floor: u32 = floors[i + 1..].iter().sum();
        for b in floors[i]..=left.saturating_sub(rest_floor) {
            rec(i + 1, left - b, floors, acc + eval(i, b), eval, best);
        }
    }

    let mut best = f64::MIN;
    rec(0, budget, &floors, 0.0, &mut eval, &mut best);
    Some(best)
}

// ---------------------------------------------------------------------------
// Fleet controller: per-member predictors + the joint solve, packaged
// for the drivers.
// ---------------------------------------------------------------------------

/// A joint decision source for the fleet drivers: both the DES fleet
/// loop and the live fleet engine call this once per adaptation tick
/// and receive one [`Decision`] per member.  The two defaulted hooks
/// make the control plane *elastic*: a pool-resize proposal before each
/// joint decision, and a mid-interval preemption fast path between
/// ticks.  Plain controllers ignore both and behave exactly as before.
pub trait FleetController {
    /// Initial configurations, decided on each trace's first-second
    /// rate before any request arrives.
    fn initial(&mut self, first_rates: &[f64]) -> Vec<Decision>;

    /// One adaptation-tick joint decision from the per-member observed
    /// load histories.
    fn decide(&mut self, now: f64, histories: &[Vec<f64>]) -> Vec<Decision>;

    /// Attach a control-plane decision journal
    /// ([`crate::telemetry::journal::Journal`]): controllers that
    /// support it record every solve / resize / preemption / fault
    /// outcome as a structured, virtual-time-stamped entry.  Default:
    /// ignore (plain controllers stay silent).
    fn set_journal(&mut self, _journal: Arc<Journal>) {}

    /// Pool-resize proposal for this tick, called by the driver right
    /// BEFORE [`FleetController::decide`] with the same histories.
    /// `Some(p)` means the controller now budgets against a pool of
    /// `p`: the driver grows the physical pool immediately (so the
    /// joint solve can use it) and defers a shrink until the smaller
    /// configurations activate.  Default: never resize.
    fn resize(&mut self, _now: f64, _histories: &[Vec<f64>]) -> Option<u32> {
        None
    }

    /// Whether this controller can ever preempt.  Drivers skip the
    /// mid-interval check entirely (no monitor scans, no events) when
    /// false, so the fixed-pool path pays nothing.  Default: false.
    fn wants_preemption(&self) -> bool {
        false
    }

    /// Mid-interval preemption fast path: called by the driver BETWEEN
    /// adaptation ticks with the per-member observed rates.  `Some`
    /// carries a full replacement decision vector (reclaimed replicas
    /// moved from strictly lower-priority members to a bursting
    /// higher-priority one) that the driver applies immediately,
    /// bypassing both the joint IP and the apply delay.  Default:
    /// never preempt.
    fn preempt(&mut self, _now: f64, _observed: &[f64]) -> Option<FleetPreemption> {
        None
    }

    /// The heterogeneous node inventory this controller budgets
    /// against, queried once by the drivers to build the fleet core.
    /// `None` (the default) = the classic fungible replica pool.
    fn node_inventory(&self) -> Option<NodeInventory> {
        None
    }

    /// Per-member SLA classes, queried once by the drivers to key the
    /// drop policy and batch-timeout ceilings.  `None` (the default) =
    /// the pre-class behavior (verbatim SLAs, uncapped timeouts).
    fn sla_classes(&self) -> Option<Vec<SlaClass>> {
        None
    }

    /// Per-member zone-spread flags, queried once by the drivers so the
    /// fleet core enforces the same spread constraint the solves do.
    /// `None` (the default) = no spread constraints.
    fn spread(&self) -> Option<Vec<bool>> {
        None
    }

    /// Per-replica migration charge the drivers add to the apply delay
    /// for every replica a staged decision moves.  0 (the default) =
    /// migrations are free, the pre-topology behavior.
    fn migration_delay(&self) -> f64 {
        0.0
    }

    /// Zone-fault hook: the driver drained `zone` from the pool and
    /// hands over the `survivor` inventory plus the per-member observed
    /// rates; a topology-aware controller adopts the survivor pool and
    /// answers an EMERGENCY joint decision solved under it (applied
    /// immediately — an outage does not wait for the apply delay).
    /// `None` (the default) = the controller cannot re-plan, the driver
    /// leaves the pool untouched.
    fn fault(
        &mut self,
        _now: f64,
        _survivor: NodeInventory,
        _observed: &[f64],
    ) -> Option<Vec<Decision>> {
        None
    }
}

/// Preemption knobs (see [`FleetAdapter::preempt`]).
#[derive(Debug, Clone, Copy)]
pub struct PreemptionConfig {
    /// Trigger: a member's observed rate must exceed
    /// `burst_factor ×` its last predicted λ.
    pub burst_factor: f64,
    /// Max replicas reclaimed by one preemption event.
    pub max_reclaim: u32,
}

impl Default for PreemptionConfig {
    fn default() -> Self {
        PreemptionConfig { burst_factor: 1.5, max_reclaim: 4 }
    }
}

/// One preemption fast-path outcome: the full post-preemption decision
/// vector plus who paid for it.
#[derive(Debug, Clone)]
pub struct FleetPreemption {
    /// One decision per member.  Unchanged members carry the
    /// controller's *currently intended* configuration — the last
    /// joint solve, which may still be inside its apply-delay window.
    /// Applying this vector therefore fast-forwards any such pending
    /// reconfiguration along with the preemption (the fast path jumps
    /// the whole queue; drivers clear the stager so the superseded
    /// stage never re-applies).
    pub decisions: Vec<Decision>,
    /// The bursting member that received the reclaimed replicas.
    pub to: usize,
    /// (member, replicas taken) per donor — all strictly lower
    /// priority than `to`.
    pub from: Vec<(usize, u32)>,
    /// Σ replicas moved.
    pub reclaimed: u32,
    /// The pool size the controller budgets against.  The decision
    /// vector fits this, so after applying it the driver syncs the
    /// physical pool down to it (executing any still-pending shrink
    /// early — a preemption clears the reconfiguration queue).
    pub budget: u32,
}

/// Elastic-control-plane options bundled for callers that build the
/// adapter indirectly (the live fleet engine).  `Default` = the PR-2
/// behavior: equal priorities, fixed pool, full joint re-solve every
/// tick, no preemption.
#[derive(Debug, Clone, Default)]
pub struct FleetTuning {
    /// Per-member priority classes (higher = more important); `None` =
    /// all equal.
    pub priorities: Option<Vec<u32>>,
    /// Pool autoscaler; `None` = the pool is fixed at the budget.
    pub autoscaler: Option<AutoscalerConfig>,
    /// Mid-interval preemption fast path; `None` = disabled.
    pub preemption: Option<PreemptionConfig>,
    /// Incremental re-solve threshold: members whose predicted λ moved
    /// relatively less than this keep their cached configuration and
    /// share (0 = always full joint solve).
    pub resolve_threshold: f64,
    /// Heterogeneous node inventory backing the pool; `None` = the
    /// classic fungible replica pool.  When set, the budget becomes the
    /// inventory's replica cap, joint solves bin-pack replicas onto the
    /// nodes ([`solve_fleet_packed`]) and the autoscaler's resizes move
    /// WHOLE nodes of the elastic shape.
    pub nodes: Option<NodeInventory>,
    /// Per-member SLA classes (latency-critical vs throughput); `None`
    /// = classless legacy behavior.  Classes key the drop-threshold
    /// scale, the batch-timeout ceiling and preemption eligibility:
    /// only latency-critical members receive, and throughput members
    /// donate to latency-critical bursters at priorities ≤ the
    /// burster's (first in the donor order), so class policy fires
    /// even when every priority is equal.
    pub sla_classes: Option<Vec<SlaClass>>,
    /// Per-member zone-spread flags: flagged members keep ≥ 2 replicas
    /// per stage across ≥ 2 failure domains (when the node inventory
    /// spans ≥ 2 zones), so one zone loss never drops them below their
    /// stage floor.  `None` = no spread constraints.
    pub spread: Option<Vec<bool>>,
    /// Per-replica migration charge added to the apply delay for every
    /// replica a staged decision moves between nodes (container churn
    /// priced into the reconfiguration).  0 = migrations are free.
    pub migration_delay: f64,
}

/// The last joint solution, kept for incremental re-solves and the
/// preemption fast path.
#[derive(Clone)]
struct SolveCache {
    /// Predicted λ per member the solution was computed for (≥ 0.5).
    lambdas: Vec<f64>,
    /// Granted pool share per member.
    shares: Vec<u32>,
    configs: Vec<PipelineConfig>,
    solved: Vec<bool>,
    /// Pool size the shares were solved against.
    budget: u32,
    /// Node placement of `configs` (node pools only) — the sticky
    /// anchor for the next solve's packing and the occupancy hint for
    /// zone-aware retargets.
    packing: Option<Packing>,
}

/// The fleet adapter: one predictor per member feeding the joint
/// allocator each tick — plus the elastic control plane (priority
/// tiers, pool autoscaling, mid-interval preemption, incremental
/// re-solves) when tuned on.
pub struct FleetAdapter {
    pub specs: Vec<PipelineSpec>,
    pub profiles: Vec<PipelineProfiles>,
    pub metric: AccuracyMetric,
    /// The shared replica pool (moves when an autoscaler is attached).
    pub budget: u32,
    pub config: AdapterConfig,
    pub predictors: Vec<Box<dyn Predictor + Send>>,
    /// Per-member priority class, higher = more important (all equal by
    /// default — plain joint solving, no preemption donors).
    pub priorities: Vec<u32>,
    /// Pool autoscaler (None = fixed pool).
    pub autoscaler: Option<Autoscaler>,
    /// Preemption fast-path knobs (None = disabled).
    pub preemption: Option<PreemptionConfig>,
    /// Relative λ-move threshold for incremental re-solves (0 = always
    /// run the full joint solve).
    pub resolve_threshold: f64,
    /// Heterogeneous node inventory (None = fungible pool).  Tracks the
    /// autoscaler's retargets; `budget` always equals its replica cap.
    pub inventory: Option<NodeInventory>,
    /// Per-member SLA classes (None = classless legacy behavior).
    pub sla_classes: Option<Vec<SlaClass>>,
    /// Per-member zone-spread flags (all false = no spread policy).
    pub spread: Vec<bool>,
    /// Per-replica migration charge the drivers add to the apply delay
    /// (0 = migrations free, the pre-topology behavior).
    pub migration_delay: f64,
    /// Telemetry: how many decisions ran the full joint solve vs the
    /// incremental per-member path.
    pub full_solves: usize,
    pub incremental_solves: usize,
    cache: Option<SolveCache>,
    /// λs predicted by [`FleetAdapter::resize`] this tick, consumed by
    /// the following [`FleetAdapter::decide`] so stateful predictors
    /// are only asked once per tick.
    pending_lambdas: Option<Vec<f64>>,
    /// Last demand estimate (clamped λs it was computed for, Σ min
    /// feasible, the per-axis demand vector) — reused on quiet ticks so
    /// the autoscaler's demand estimation doesn't cost a full
    /// feasibility search when the incremental path is skipping the
    /// joint solve anyway.
    last_demand: Option<(Vec<f64>, u32, ResourceVec)>,
    /// Decision journal attached by the traced drivers (None = silent).
    journal: Option<Arc<Journal>>,
    /// Virtual time of the driver call in flight — journal entries are
    /// stamped with it, never with the wall clock, so two identical
    /// runs journal byte-identically.
    journal_now: f64,
}

impl FleetAdapter {
    /// Errors when member vectors disagree in length or the budget
    /// cannot cover one replica per stage (the only condition under
    /// which [`solve_fleet`] returns `None`).
    pub fn new(
        specs: Vec<PipelineSpec>,
        profiles: Vec<PipelineProfiles>,
        metric: AccuracyMetric,
        budget: u32,
        config: AdapterConfig,
        predictors: Vec<Box<dyn Predictor + Send>>,
    ) -> Result<FleetAdapter, String> {
        if specs.len() != profiles.len() || specs.len() != predictors.len() {
            return Err(format!(
                "fleet adapter: {} specs vs {} profiles vs {} predictors",
                specs.len(),
                profiles.len(),
                predictors.len()
            ));
        }
        let floor: u32 = specs.iter().map(|s| s.n_stages() as u32).sum();
        if budget < floor {
            return Err(format!("fleet budget {budget} below stage floor {floor}"));
        }
        let n = specs.len();
        Ok(FleetAdapter {
            specs,
            profiles,
            metric,
            budget,
            config,
            predictors,
            priorities: vec![0; n],
            autoscaler: None,
            preemption: None,
            resolve_threshold: 0.0,
            inventory: None,
            sla_classes: None,
            spread: vec![false; n],
            migration_delay: 0.0,
            full_solves: 0,
            incremental_solves: 0,
            cache: None,
            pending_lambdas: None,
            last_demand: None,
            journal: None,
            journal_now: 0.0,
        })
    }

    /// Apply an elastic-control-plane tuning bundle.  Errors when a
    /// per-member vector length disagrees with the member count, or
    /// when a node inventory cannot host the fleet at all (replica cap
    /// below the stage floor, or the one-replica-per-stage
    /// lightest-variant floor — the packed solver's last resort — does
    /// not pack).
    pub fn with_tuning(mut self, tuning: FleetTuning) -> Result<FleetAdapter, String> {
        let n = self.specs.len();
        if let Some(prio) = tuning.priorities {
            if prio.len() != n {
                return Err(format!(
                    "fleet tuning: {} priorities for {n} members",
                    prio.len(),
                ));
            }
            self.priorities = prio;
        }
        if let Some(classes) = tuning.sla_classes {
            if classes.len() != n {
                return Err(format!(
                    "fleet tuning: {} SLA classes for {n} members",
                    classes.len(),
                ));
            }
            self.sla_classes = Some(classes);
        }
        if let Some(spread) = tuning.spread {
            if spread.len() != n {
                return Err(format!(
                    "fleet tuning: {} spread flags for {n} members",
                    spread.len(),
                ));
            }
            self.spread = spread;
        }
        if !tuning.migration_delay.is_finite() || tuning.migration_delay < 0.0 {
            return Err(format!(
                "fleet tuning: migration_delay {} must be finite and >= 0",
                tuning.migration_delay
            ));
        }
        self.migration_delay = tuning.migration_delay;
        if let Some(inv) = tuning.nodes {
            inv.validate().map_err(|e| format!("fleet tuning: {e}"))?;
            let cap = inv.replica_cap();
            // The effective floor counts the spread members at two
            // replicas per stage (zone redundancy is part of the floor).
            let floor: u32 = (0..n)
                .map(|i| {
                    let m = if spread_active(&self.spread, i, Some(&inv)) { 2 } else { 1 };
                    self.specs[i].n_stages() as u32 * m
                })
                .sum();
            if cap < floor {
                return Err(format!(
                    "node inventory caps {cap} replicas, below the stage floor {floor}"
                ));
            }
            // The packed solver's last resort — one lightest replica
            // per stage — must pack, so decide() can never come back
            // empty-handed.  Every later inventory change (the
            // autoscaler's retargets) re-checks this before committing.
            if !self.floor_packs(&inv) {
                return Err(
                    "node inventory cannot host the fleet's one-replica-per-stage floor"
                        .into(),
                );
            }
            self.budget = cap;
            self.inventory = Some(inv);
        }
        self.autoscaler = tuning.autoscaler.map(Autoscaler::new);
        self.preemption = tuning.preemption;
        self.resolve_threshold = tuning.resolve_threshold;
        Ok(self)
    }

    pub fn n_members(&self) -> usize {
        self.specs.len()
    }

    /// The fleet's min-feasible replica floor (one replica per stage of
    /// every member) — the pool never shrinks below it.
    pub fn stage_floor(&self) -> u32 {
        self.specs.iter().map(|s| s.n_stages() as u32).sum()
    }

    /// Attach the decision journal: every solve (full and incremental,
    /// with per-member shares and the rejected next-grant candidates),
    /// autoscaler resize (with the pressure axis), preemption and zone
    /// fault is recorded as a structured entry stamped with the
    /// driver's virtual time.
    pub fn set_journal(&mut self, journal: Arc<Journal>) {
        self.journal = Some(journal);
    }

    /// Record a journal entry at the in-flight driver time (no-op
    /// without a journal attached).
    fn jot(&self, kind: &str, data: Json) {
        if let Some(j) = &self.journal {
            j.record(self.journal_now, kind, data);
        }
    }

    /// Member `i`'s solver problem at λ, replica options capped by the
    /// current pool.
    fn member_problem(&self, i: usize, lambda: f64) -> Problem<'_> {
        Problem {
            spec: &self.specs[i],
            profiles: &self.profiles[i],
            lambda: lambda.max(0.5),
            metric: self.metric,
            max_replicas: self.config.max_replicas.min(self.budget),
        }
    }

    /// Member `i`'s problem for *demand estimation*: options capped by
    /// the adapter limit only, NOT the current pool — demand above the
    /// pool is exactly what the autoscaler needs to see.
    fn demand_problem(&self, i: usize, lambda: f64) -> Problem<'_> {
        Problem {
            spec: &self.specs[i],
            profiles: &self.profiles[i],
            lambda: lambda.max(0.5),
            metric: self.metric,
            max_replicas: self.config.max_replicas,
        }
    }

    /// Is member `i`'s zone-spread flag in force on the current
    /// inventory (≥ 2 zones to spread across)?
    fn spread_on(&self, i: usize) -> bool {
        spread_active(&self.spread, i, self.inventory.as_ref())
    }

    /// Member `i`'s per-stage replica floor (2 under active spread).
    fn member_min(&self, i: usize) -> u32 {
        if self.spread_on(i) {
            2
        } else {
            1
        }
    }

    /// The option sets member `i` may choose from — node-placeability
    /// filtered when an inventory is attached, plus the zone-spread
    /// transform for flagged members (the packed solver's pre-filter,
    /// applied identically on the incremental and preemption paths so a
    /// fast-path re-solve can never pick a variant the nodes cannot
    /// host or a replica count one zone loss would break).
    fn member_options(&self, p: &Problem, member: usize) -> Vec<Vec<StageOption>> {
        let mut os = p.stage_options();
        if let Some(inv) = &self.inventory {
            filter_options(&mut os, inv, self.spread_on(member), self.member_min(member));
        }
        os
    }

    /// Does the per-stage-floor lightest-variant configuration — the
    /// packed solver's last resort (one replica per stage, two for
    /// spread members) — bin-pack into `inv` with the spread constraint
    /// honored?  Checked before EVERY inventory the adapter adopts
    /// ([`FleetAdapter::with_tuning`], each autoscaler retarget and
    /// each zone fault), which is what makes the
    /// `solve_fleet_placed(..).expect(..)` in the decide path sound.
    fn floor_packs(&self, inv: &NodeInventory) -> bool {
        let floor_configs: Vec<PipelineConfig> = (0..self.specs.len())
            .map(|i| {
                let p = self.demand_problem(i, 0.5);
                let m = if spread_active(&self.spread, i, Some(inv)) { 2 } else { 1 };
                fallback_min(&p, self.specs[i].n_stages() as u32 * m, m)
            })
            .collect();
        let refs: Vec<&PipelineConfig> = floor_configs.iter().collect();
        inv.pack_sticky(&config_demands(&refs), None, &self.spread).is_some()
    }

    /// Pack these per-member configurations onto the pool, stickily
    /// against `prev`.  Fungible / legacy pools never re-check here
    /// (shares already enforce the scalar budget) and answer
    /// `Ok(None)`; node pools run the bin-packer (sticky first, plain
    /// FFD fallback) and answer `Err(())` when the fleet does not fit.
    ///
    /// When the caller knows WHICH members' configurations changed
    /// (`changed[i]`, incremental re-solves and preemption), the
    /// delta-pack fast path re-places only those members against the
    /// retained occupancy of the rest — O(changed) instead of
    /// O(fleet × nodes) — and any precondition miss falls through to
    /// the full sticky pack.
    fn repack(
        &self,
        configs: &[PipelineConfig],
        prev: Option<&Packing>,
        changed: Option<&[bool]>,
    ) -> Result<Option<Packing>, ()> {
        match &self.inventory {
            Some(inv) => {
                let refs: Vec<&PipelineConfig> = configs.iter().collect();
                let demands = config_demands(&refs);
                if crate::fleet::nodes::delta_pack_enabled() {
                    if let (Some(prev), Some(changed)) = (prev, changed) {
                        if changed.iter().any(|&c| !c) {
                            if let Some(p) =
                                inv.pack_delta(&demands, prev, changed, &self.spread)
                            {
                                return Ok(Some(p));
                            }
                        }
                    }
                }
                inv.pack_prefer_sticky(&demands, prev, &self.spread).map(Some).ok_or(())
            }
            None => Ok(None),
        }
    }

    /// Incremental path: when only a strict subset of members moved
    /// (relative λ change ≤ `resolve_threshold` for the rest), keep
    /// everyone's share fixed and re-run the budget-capped solve for
    /// the moved members alone.  Shares are unchanged, so the joint
    /// budget invariant holds trivially; on a node pool the re-solved
    /// configurations are additionally re-packed, and a packing failure
    /// falls back to the full joint solve.  Returns `None` when the
    /// full joint solve is required (feature off, no/stale cache, pool
    /// resized, every member moved, or repack failed).
    fn try_incremental(&mut self, lambdas: &[f64], t0: Instant) -> Option<Vec<Decision>> {
        if self.resolve_threshold <= 0.0 {
            return None;
        }
        {
            let cache = self.cache.as_ref()?;
            if cache.budget != self.budget || cache.lambdas.len() != lambdas.len() {
                return None;
            }
            let moved = lambdas
                .iter()
                .zip(&cache.lambdas)
                .filter(|&(&l, &old)| {
                    (l.max(0.5) - old).abs() / old.max(0.5) > self.resolve_threshold
                })
                .count();
            if moved >= lambdas.len() {
                return None; // cache-busting: everyone moved, solve jointly
            }
        }
        let mut cache = self.cache.take().expect("checked above");
        // Only node pools can reject the result (repack failure), so
        // only they pay for the restore snapshot.
        let original = self.inventory.is_some().then(|| cache.clone());
        let moved: Vec<bool> = lambdas
            .iter()
            .zip(&cache.lambdas)
            .map(|(&l, &old)| (l.max(0.5) - old).abs() / old.max(0.5) > self.resolve_threshold)
            .collect();
        let moved_idx: Vec<usize> = (0..lambdas.len()).filter(|&i| moved[i]).collect();
        // The moved members' budget-capped re-solves are independent —
        // fan them out like the joint engine does, merged in member
        // order.  (`&self` is not Sync — `predictors` holds `Box<dyn
        // Predictor + Send>` — so the closure captures the Sync fields
        // it needs instead.)
        let specs = &self.specs;
        let profiles = &self.profiles;
        let metric = self.metric;
        let max_replicas = self.config.max_replicas.min(self.budget);
        let inv = self.inventory.as_ref();
        let spread = &self.spread;
        let shares = &cache.shares;
        let resolved = scoped_map(solver_threads(), &moved_idx, |_, &i| {
            let p = Problem {
                spec: &specs[i],
                profiles: &profiles[i],
                lambda: lambdas[i].max(0.5),
                metric,
                max_replicas,
            };
            let spread_on = spread_active(spread, i, inv);
            let min_per = if spread_on { 2 } else { 1 };
            let mut opts = p.stage_options();
            if let Some(inv) = inv {
                filter_options(&mut opts, inv, spread_on, min_per);
            }
            eval_member_at(&p, &opts, shares[i], min_per)
        });
        for (&i, (cfg, solved)) in moved_idx.iter().zip(resolved) {
            cache.configs[i] = cfg;
            cache.solved[i] = solved;
            cache.lambdas[i] = lambdas[i].max(0.5);
        }
        match self.repack(&cache.configs, cache.packing.as_ref(), Some(&moved)) {
            Ok(p) => cache.packing = p,
            Err(()) => {
                // moved members picked shapes the nodes cannot host at
                // the pinned shares — the full joint solve must re-split
                self.cache = Some(original.expect("repack() only fails on node pools"));
                return None;
            }
        }
        self.incremental_solves += 1;
        if self.journal.is_some() {
            self.jot(
                "solve",
                Json::obj()
                    .set("mode", "incremental")
                    .set("budget", cache.budget as i64)
                    .set("lambdas", cache.lambdas.clone())
                    .set(
                        "shares",
                        cache.shares.iter().map(|&s| s as i64).collect::<Vec<i64>>(),
                    ),
            );
        }
        let decision_time = t0.elapsed().as_secs_f64();
        let ds = cache_decisions(&cache, decision_time);
        self.cache = Some(cache);
        Some(ds)
    }

    /// Joint decision for explicit per-member λ (sweeps / tests / the
    /// initial tick).  Runs the incremental path when possible,
    /// otherwise the full (priority-tiered) joint solve.
    pub fn decide_for_lambdas(&mut self, lambdas: &[f64]) -> Vec<Decision> {
        assert_eq!(lambdas.len(), self.specs.len());
        let t0 = Instant::now();
        if let Some(ds) = self.try_incremental(lambdas, t0) {
            return ds;
        }
        let problems: Vec<Problem> = (0..self.specs.len())
            .map(|i| self.member_problem(i, lambdas[i]))
            .collect();
        let (alloc, stats) = match &self.inventory {
            Some(inv) => {
                let prev = self.cache.as_ref().and_then(|c| c.packing.as_ref());
                solve_fleet_placed_stats(&problems, inv, &self.priorities, &self.spread, prev)
                    .expect("floor packability was checked by with_tuning")
            }
            None => solve_fleet_tiers_stats(&problems, self.budget, &self.priorities)
                .expect("budget >= stage floor was checked at construction"),
        };
        self.full_solves += 1;
        let decision_time = t0.elapsed().as_secs_f64();
        let cache = SolveCache {
            lambdas: lambdas.iter().map(|l| l.max(0.5)).collect(),
            shares: alloc.members.iter().map(|m| m.budget).collect(),
            configs: alloc.members.iter().map(|m| m.config.clone()).collect(),
            solved: alloc.members.iter().map(|m| m.solved).collect(),
            budget: self.budget,
            packing: alloc.packing,
        };
        if self.journal.is_some() {
            // Rejected candidates: what one more replica would have
            // bought each member — the marginal grant the greedy
            // declined.  Pure budget-capped re-solves, run only with a
            // journal attached; they touch no adapter state, and — like
            // the incremental path — fan out over the Sync fields
            // (`&self` is not Sync).
            let specs = &self.specs;
            let profiles = &self.profiles;
            let metric = self.metric;
            let max_replicas = self.config.max_replicas.min(self.budget);
            let inv = self.inventory.as_ref();
            let spread = &self.spread;
            let idx: Vec<usize> = (0..self.specs.len()).collect();
            let rejected: Vec<Json> = scoped_map(solver_threads(), &idx, |_, &i| {
                let p = Problem {
                    spec: &specs[i],
                    profiles: &profiles[i],
                    lambda: cache.lambdas[i],
                    metric,
                    max_replicas,
                };
                let spread_on = spread_active(spread, i, inv);
                let min_per = if spread_on { 2 } else { 1 };
                let mut opts = p.stage_options();
                if let Some(inv) = inv {
                    filter_options(&mut opts, inv, spread_on, min_per);
                }
                let (cfg, solved) = eval_member_at(&p, &opts, cache.shares[i] + 1, min_per);
                Json::obj()
                    .set("member", i as i64)
                    .set("next_share", (cache.shares[i] + 1) as i64)
                    .set("cost", cfg.cost)
                    .set("objective", cfg.objective)
                    .set("solved", solved)
            });
            self.jot(
                "solve",
                Json::obj()
                    .set("mode", "full")
                    .set("budget", cache.budget as i64)
                    .set("lambdas", cache.lambdas.clone())
                    .set(
                        "shares",
                        cache.shares.iter().map(|&s| s as i64).collect::<Vec<i64>>(),
                    )
                    .set("cache_hits", stats.cache_hits as i64)
                    .set("cache_misses", stats.cache_misses as i64)
                    .set("rejected", rejected),
            );
        }
        let ds = cache_decisions(&cache, decision_time);
        self.cache = Some(cache);
        ds
    }

    /// Autoscaler tick (the slow path's outer loop): predict this
    /// tick's λs (stashed for the following [`FleetAdapter::decide`] so
    /// stateful predictors run once per tick), estimate fleet-wide
    /// demand as Σ per-member minimum feasible replicas at those λs,
    /// and ask the autoscaler for a bounded pool step.  Returns the new
    /// pool size when it changed; the adapter immediately budgets
    /// against it.
    pub fn resize(&mut self, now: f64, histories: &[Vec<f64>]) -> Option<u32> {
        let lambdas: Vec<f64> = self
            .predictors
            .iter_mut()
            .zip(histories)
            .map(|(p, h)| p.predict(now, h).max(0.5))
            .collect();
        self.pending_lambdas = Some(lambdas.clone());
        self.autoscaler.as_ref()?;
        let floor = self.stage_floor();
        let cap = self.autoscaler.as_ref().expect("checked").max_pool().max(floor);
        let clamped: Vec<f64> = lambdas.iter().map(|l| l.max(0.5)).collect();
        // Quiet ticks reuse the last estimate: re-running the
        // per-member feasibility search when no λ moved past the
        // incremental threshold would cost about what the skipped
        // joint solve saves.
        let cached = self.last_demand.as_ref().and_then(|(ls, d, pr)| {
            let quiet = self.resolve_threshold > 0.0
                && ls.len() == clamped.len()
                && clamped
                    .iter()
                    .zip(ls)
                    .all(|(&l, &old)| (l - old).abs() / old.max(0.5) <= self.resolve_threshold);
            quiet.then_some((*d, *pr))
        });
        let (demand, pressure) = match cached {
            Some(dp) => dp,
            None => {
                let mut demand = 0u32;
                let mut pressure = ResourceVec::ZERO;
                for (i, &l) in clamped.iter().enumerate() {
                    let p = self.demand_problem(i, l);
                    // node-placeability filtered like every solve path:
                    // an unplaceable accel variant must not make demand
                    // look cheaper than the packed solve can deliver
                    let opts = self.member_options(&p, i);
                    let member_floor = self.specs[i].n_stages() as u32 * self.member_min(i);
                    match min_feasible(&p, &opts, cap) {
                        // the min-feasible configuration's total demand
                        // vector is the per-axis pressure the pool must
                        // be able to absorb
                        Some((m, cfg)) => {
                            demand += m;
                            pressure = pressure.add(cfg.resources);
                        }
                        None => {
                            demand += member_floor;
                            pressure = pressure
                                .add(fallback_min(&p, member_floor, self.member_min(i)).resources);
                        }
                    }
                }
                self.last_demand = Some((clamped, demand, pressure));
                (demand, pressure)
            }
        };
        let decision =
            self.autoscaler.as_mut().expect("checked").decide(self.budget, demand, floor);
        if self.inventory.is_some() {
            // Whole-node granularity: retarget toward the proposed
            // replica target (growth never overshoots it — the cost cap
            // holds — so the actuated budget is the resulting replica
            // cap, not the raw target), buying the shape the per-axis
            // PRESSURE selects (accel-bound demand buys accel nodes)
            // and selling from the most-spare zone under the active
            // placement.  An inventory that can no longer host the
            // per-stage floor is never adopted: the replica cap counts
            // CPU slots only, so a shrink could otherwise strand the
            // floor on a memory/accel axis and leave the packed solve
            // without its last resort.
            let mut tentative = self.inventory.clone().expect("checked");
            tentative.retarget_with(
                decision.target.max(floor),
                Some(pressure),
                self.cache.as_ref().and_then(|c| c.packing.as_ref()),
            );
            let node_cap = tentative.replica_cap();
            // an unchanged cap means an unchanged inventory (growth and
            // shrink are direction-exclusive), so there is nothing to
            // adopt or announce
            if node_cap == self.budget || !self.floor_packs(&tentative) {
                return None;
            }
            self.inventory = Some(tentative);
            self.budget = node_cap;
            self.jot("resize", resize_entry(demand, node_cap, pressure));
            Some(node_cap)
        } else if decision.target != self.budget {
            self.budget = decision.target;
            self.jot("resize", resize_entry(demand, decision.target, pressure));
            Some(decision.target)
        } else {
            None
        }
    }

    /// The preemption fast path: find the highest-priority member whose
    /// observed rate burst past `burst_factor ×` its last predicted λ
    /// *and* whose current share leaves it SLA-infeasible, then reclaim
    /// up to `max_reclaim` replicas from strictly lower-priority
    /// members (throughput-class donors first, then lowest priority,
    /// then fattest share, never below a donor's stage floor).  Only
    /// the burster and the donors are re-solved — single-member
    /// budget-capped solves, no joint IP — so this is cheap enough to
    /// run between adaptation ticks.
    ///
    /// With SLA classes attached, only latency-critical members are
    /// preemption receivers (a bursting batch line waits for the next
    /// tick instead) and throughput members additionally donate to
    /// latency-critical bursters at priorities ≤ the burster's — class
    /// policy fires even when every priority is equal.  With a node
    /// inventory attached, the post-preemption configuration must
    /// bin-pack — a replica is never moved onto nodes that cannot host
    /// it, the candidate is dropped instead.
    pub fn preempt(&mut self, _now: f64, observed: &[f64]) -> Option<FleetPreemption> {
        let pc = self.preemption?;
        let n = self.specs.len();
        assert_eq!(observed.len(), n);
        {
            let cache = self.cache.as_ref()?;
            if cache.budget != self.budget || cache.shares.len() != n {
                return None;
            }
        }
        let floors: Vec<u32> = (0..n)
            .map(|i| self.specs[i].n_stages() as u32 * self.member_min(i))
            .collect();
        let t0 = Instant::now();

        // Bursting receiver-eligible members, most important (then
        // hottest) first.
        let mut bursters: Vec<(usize, f64)> = {
            let cache = self.cache.as_ref().expect("checked");
            (0..n)
                .filter(|&i| match &self.sla_classes {
                    Some(c) => c[i] == SlaClass::LatencyCritical,
                    None => true,
                })
                .filter_map(|i| {
                    let ratio = observed[i].max(0.5) / cache.lambdas[i].max(0.5);
                    (ratio > pc.burst_factor).then_some((i, ratio))
                })
                .collect()
        };
        bursters.sort_by(|a, b| {
            self.priorities[b.0]
                .cmp(&self.priorities[a.0])
                .then(b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal))
        });

        for (bi, _) in bursters {
            let mut cache = self.cache.take().expect("checked");
            let lam_new = observed[bi].max(0.5);
            let p = self.member_problem(bi, lam_new);
            let opts = self.member_options(&p, bi);
            // How many more replicas feasibility at the burst λ needs.
            let need = match min_feasible_replicas(&p, &opts, self.budget) {
                Some(m) if m > cache.shares[bi] => m - cache.shares[bi],
                _ => {
                    self.cache = Some(cache);
                    continue; // share already suffices, or hopeless at any size
                }
            };
            let want = need.min(pc.max_reclaim.max(1));
            let mut shares = cache.shares.clone();
            let mut from: Vec<(usize, u32)> = Vec::new();
            let mut got = 0u32;
            // Donor eligibility: strictly lower priority class — OR,
            // with SLA classes attached, a throughput member at a
            // priority ≤ the latency-critical burster's (batch traffic
            // donates to interactive traffic even without a priority
            // gap; without classes nothing changes).
            let donor_ok = |j: usize| {
                if self.priorities[j] < self.priorities[bi] {
                    return true;
                }
                match &self.sla_classes {
                    Some(c) => {
                        c[j] == SlaClass::Throughput
                            && c[bi] == SlaClass::LatencyCritical
                            && self.priorities[j] <= self.priorities[bi]
                    }
                    None => false,
                }
            };
            while got < want {
                // throughput-class donors first, then lowest priority
                // class; within those, fattest share
                let donor = (0..n)
                    .filter(|&j| donor_ok(j) && shares[j] > floors[j])
                    .min_by_key(|&j| {
                        let class_rank = match &self.sla_classes {
                            Some(c) => (c[j] != SlaClass::Throughput) as u32,
                            None => 0,
                        };
                        (class_rank, self.priorities[j], u32::MAX - shares[j], j)
                    });
                let Some(j) = donor else { break };
                shares[j] -= 1;
                got += 1;
                match from.iter_mut().find(|(m, _)| *m == j) {
                    Some((_, k)) => *k += 1,
                    None => from.push((j, 1)),
                }
            }
            if got == 0 {
                self.cache = Some(cache);
                continue; // no strictly-lower-priority replica to reclaim
            }
            shares[bi] += got;
            // Only node pools can reject the result (repack failure),
            // so only they pay for the restore snapshot.
            let original = self.inventory.is_some().then(|| cache.clone());
            // Re-solve only the members whose share changed.
            let (cfg, solved) = eval_member_at(&p, &opts, shares[bi], self.member_min(bi));
            cache.configs[bi] = cfg;
            cache.solved[bi] = solved;
            cache.lambdas[bi] = lam_new;
            for &(j, _) in &from {
                let pj = self.member_problem(j, cache.lambdas[j]);
                let oj = self.member_options(&pj, j);
                let (cfg, solved) = eval_member_at(&pj, &oj, shares[j], self.member_min(j));
                cache.configs[j] = cfg;
                cache.solved[j] = solved;
            }
            cache.shares = shares;
            // Node safety: the post-preemption fleet must still pack —
            // otherwise this burster's preemption is abandoned (the
            // slow path will re-split at the next tick).  Only the
            // burster and its donors changed configuration, so the
            // delta-pack fast path applies.
            let mut changed = vec![false; n];
            changed[bi] = true;
            for &(j, _) in &from {
                changed[j] = true;
            }
            match self.repack(&cache.configs, cache.packing.as_ref(), Some(&changed)) {
                Ok(pk) => cache.packing = pk,
                Err(()) => {
                    self.cache = Some(original.expect("repack() only fails on node pools"));
                    continue;
                }
            }
            let decisions = cache_decisions(&cache, t0.elapsed().as_secs_f64());
            let budget = cache.budget;
            self.cache = Some(cache);
            let reclaimed = got;
            self.jot(
                "preempt",
                Json::obj()
                    .set("to", bi as i64)
                    .set(
                        "from",
                        from.iter()
                            .map(|&(m, k)| {
                                Json::obj()
                                    .set("member", m as i64)
                                    .set("replicas", k as i64)
                            })
                            .collect::<Vec<Json>>(),
                    )
                    .set("reclaimed", reclaimed as i64)
                    .set("budget", budget as i64),
            );
            return Some(FleetPreemption { decisions, to: bi, from, reclaimed, budget });
        }
        None
    }

    /// Zone-fault handler: adopt the `survivor` inventory the driver
    /// drained and answer an emergency joint decision solved under it
    /// ([`solve_fleet_placed`] from a cold cache — the old shares and
    /// placement died with the zone).  `None` when the adapter runs no
    /// node inventory, or when even the per-stage floor no longer packs
    /// on the survivors (the fleet cannot be saved by re-planning; the
    /// driver leaves the pool untouched).  Note spread constraints
    /// deactivate on their own when only one zone remains.
    pub fn fault(
        &mut self,
        _now: f64,
        survivor: NodeInventory,
        observed: &[f64],
    ) -> Option<Vec<Decision>> {
        self.inventory.as_ref()?;
        if !self.floor_packs(&survivor) {
            return None;
        }
        self.budget = survivor.replica_cap();
        self.inventory = Some(survivor);
        self.cache = None;
        self.last_demand = None;
        self.pending_lambdas = None;
        self.jot("fault", Json::obj().set("survivor_budget", self.budget as i64));
        Some(self.decide_for_lambdas(observed))
    }
}

/// Journal payload for an autoscaler resize: the demand estimate, the
/// adopted replica target, and the per-axis pressure vector the node
/// retarget shopped with.  `axis` names the axis that steers node
/// shape — accel pressure is what makes `retarget_with` buy accel
/// nodes; everything else buys the CPU shape.
fn resize_entry(demand: u32, target: u32, pressure: ResourceVec) -> Json {
    let axis = if pressure.accel_slots > 0.0 { "accel" } else { "cpu" };
    Json::obj()
        .set("demand", demand as i64)
        .set("target", target as i64)
        .set("axis", axis)
        .set("pressure_cpu", pressure.cpu_cores)
        .set("pressure_mem", pressure.memory_gb)
        .set("pressure_accel", pressure.accel_slots)
}

/// Decisions straight from the solve cache (shared by the full,
/// incremental and preemption paths).
fn cache_decisions(cache: &SolveCache, decision_time: f64) -> Vec<Decision> {
    cache
        .configs
        .iter()
        .zip(&cache.lambdas)
        .zip(&cache.solved)
        .map(|((cfg, &l), &solved)| Decision {
            config: cfg.clone(),
            lambda_predicted: l,
            decision_time,
            fallback: !solved,
        })
        .collect()
}

impl FleetController for FleetAdapter {
    fn initial(&mut self, first_rates: &[f64]) -> Vec<Decision> {
        self.journal_now = 0.0;
        self.decide_for_lambdas(first_rates)
    }

    fn set_journal(&mut self, journal: Arc<Journal>) {
        FleetAdapter::set_journal(self, journal)
    }

    fn decide(&mut self, now: f64, histories: &[Vec<f64>]) -> Vec<Decision> {
        self.journal_now = now;
        // resize() may already have predicted this tick's λs.
        let lambdas: Vec<f64> = match self.pending_lambdas.take() {
            Some(l) => l,
            None => self
                .predictors
                .iter_mut()
                .zip(histories)
                .map(|(p, h)| p.predict(now, h).max(0.5))
                .collect(),
        };
        self.decide_for_lambdas(&lambdas)
    }

    fn resize(&mut self, now: f64, histories: &[Vec<f64>]) -> Option<u32> {
        self.journal_now = now;
        FleetAdapter::resize(self, now, histories)
    }

    fn wants_preemption(&self) -> bool {
        self.preemption.is_some()
    }

    fn preempt(&mut self, now: f64, observed: &[f64]) -> Option<FleetPreemption> {
        self.journal_now = now;
        FleetAdapter::preempt(self, now, observed)
    }

    fn node_inventory(&self) -> Option<NodeInventory> {
        self.inventory.clone()
    }

    fn sla_classes(&self) -> Option<Vec<SlaClass>> {
        self.sla_classes.clone()
    }

    fn spread(&self) -> Option<Vec<bool>> {
        self.spread.iter().any(|&s| s).then(|| self.spread.clone())
    }

    fn migration_delay(&self) -> f64 {
        self.migration_delay
    }

    fn fault(
        &mut self,
        now: f64,
        survivor: NodeInventory,
        observed: &[f64],
    ) -> Option<Vec<Decision>> {
        self.journal_now = now;
        FleetAdapter::fault(self, now, survivor, observed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::pipelines;
    use crate::profiler::analytic::pipeline_profiles;

    fn problem<'a>(
        spec: &'a PipelineSpec,
        prof: &'a PipelineProfiles,
        lambda: f64,
    ) -> Problem<'a> {
        Problem::new(spec, prof, lambda)
    }

    #[test]
    fn budget_inactive_matches_unconstrained() {
        let spec = pipelines::by_name("video").unwrap();
        let prof = pipeline_profiles(&spec);
        let p = problem(&spec, &prof, 12.0);
        let options = p.stage_options();
        let free = ip::solve_with_options(&p, &options).unwrap().0;
        let capped = solve_under_budget(&p, &options, 1000).unwrap();
        assert!((free.objective - capped.objective).abs() < 1e-9);
    }

    #[test]
    fn budget_constrained_solve_respects_budget_and_sla() {
        let spec = pipelines::by_name("video").unwrap();
        let prof = pipeline_profiles(&spec);
        let p = problem(&spec, &prof, 25.0);
        let options = p.stage_options();
        let free = ip::solve_with_options(&p, &options).unwrap().0;
        // squeeze below the unconstrained usage
        for b in (2..=free.total_replicas()).rev() {
            if let Some(cfg) = solve_under_budget(&p, &options, b) {
                assert!(cfg.total_replicas() <= b);
                assert!(cfg.latency_e2e <= spec.sla_e2e() + 1e-9);
                assert!(cfg.objective <= free.objective + 1e-9);
            }
        }
        assert!(solve_under_budget(&p, &options, 1).is_none(), "below stage floor");
    }

    #[test]
    fn min_feasible_is_threshold() {
        let spec = pipelines::by_name("video").unwrap();
        let prof = pipeline_profiles(&spec);
        let p = problem(&spec, &prof, 20.0);
        let options = p.stage_options();
        let mb = min_feasible_replicas(&p, &options, 64).unwrap();
        assert!(solve_under_budget(&p, &options, mb).is_some());
        if mb > 2 {
            assert!(solve_under_budget(&p, &options, mb - 1).is_none());
        }
    }

    #[test]
    fn fallback_clamped_to_budget() {
        let spec = pipelines::by_name("nlp").unwrap();
        let prof = pipeline_profiles(&spec);
        let p = problem(&spec, &prof, 5_000.0);
        for budget in [3u32, 5, 9] {
            let fb = fallback_under_budget(&p, budget);
            assert_eq!(fb.stages.len(), 3);
            assert!(fb.total_replicas() <= budget, "budget {budget}");
            assert!(fb.stages.iter().all(|s| s.replicas >= 1));
        }
    }

    #[test]
    fn greedy_respects_budget_and_beats_even_split() {
        let specs: Vec<PipelineSpec> = ["video", "audio-sent", "nlp"]
            .iter()
            .map(|n| pipelines::by_name(n).unwrap())
            .collect();
        let profs: Vec<PipelineProfiles> = specs.iter().map(pipeline_profiles).collect();
        let problems: Vec<Problem> = specs
            .iter()
            .zip(&profs)
            .zip([22.0, 9.0, 6.0])
            .map(|((s, pf), l)| problem(s, pf, l))
            .collect();
        for budget in [7u32, 10, 16, 24] {
            let alloc = solve_fleet(&problems, budget).unwrap();
            assert!(alloc.replicas_used <= budget, "budget {budget}");
            let floors: Vec<u32> =
                problems.iter().map(|p| p.profiles.stages.len() as u32).collect();
            let options: Vec<Vec<Vec<StageOption>>> =
                problems.iter().map(|p| p.stage_options()).collect();
            let even = allocate_at(&problems, &options, &even_shares(budget, &floors));
            assert!(
                alloc.total_objective >= even.total_objective - 1e-9,
                "budget {budget}: greedy {} < even {}",
                alloc.total_objective,
                even.total_objective
            );
        }
        assert!(solve_fleet(&problems, 6).is_none(), "floor is 7");
    }

    #[test]
    fn greedy_bounded_by_brute_on_tiny_fleet() {
        let specs: Vec<PipelineSpec> =
            ["video", "sum-qa"].iter().map(|n| pipelines::by_name(n).unwrap()).collect();
        let profs: Vec<PipelineProfiles> = specs.iter().map(pipeline_profiles).collect();
        let problems: Vec<Problem> = specs
            .iter()
            .zip(&profs)
            .zip([15.0, 8.0])
            .map(|((s, pf), l)| problem(s, pf, l))
            .collect();
        for budget in [4u32, 6, 9] {
            let alloc = solve_fleet(&problems, budget).unwrap();
            let brute = brute_best_split(&problems, budget).unwrap();
            assert!(
                alloc.total_objective <= brute + 1e-9,
                "budget {budget}: greedy {} above brute optimum {brute}",
                alloc.total_objective
            );
        }
    }

    #[test]
    fn tiers_with_one_class_match_plain_solve() {
        let specs: Vec<PipelineSpec> = ["video", "audio-sent", "nlp"]
            .iter()
            .map(|n| pipelines::by_name(n).unwrap())
            .collect();
        let profs: Vec<PipelineProfiles> = specs.iter().map(pipeline_profiles).collect();
        let problems: Vec<Problem> = specs
            .iter()
            .zip(&profs)
            .zip([20.0, 8.0, 5.0])
            .map(|((s, pf), l)| problem(s, pf, l))
            .collect();
        for budget in [8u32, 14, 24] {
            let plain = solve_fleet(&problems, budget).unwrap();
            let tiered = solve_fleet_tiers(&problems, budget, &[3, 3, 3]).unwrap();
            assert_eq!(
                plain.members.iter().map(|m| m.budget).collect::<Vec<_>>(),
                tiered.members.iter().map(|m| m.budget).collect::<Vec<_>>(),
                "budget {budget}"
            );
            assert!((plain.total_objective - tiered.total_objective).abs() < 1e-9);
        }
    }

    #[test]
    fn tiers_grant_high_priority_first_under_contention() {
        let specs: Vec<PipelineSpec> =
            ["video", "video"].iter().map(|n| pipelines::by_name(n).unwrap()).collect();
        let profs: Vec<PipelineProfiles> = specs.iter().map(pipeline_profiles).collect();
        // both members want replicas badly at this λ
        let problems =
            vec![problem(&specs[0], &profs[0], 25.0), problem(&specs[1], &profs[1], 25.0)];
        for budget in [6u32, 8, 10] {
            let hi_first = solve_fleet_tiers(&problems, budget, &[9, 1]).unwrap();
            let lo_first = solve_fleet_tiers(&problems, budget, &[1, 9]).unwrap();
            assert!(hi_first.replicas_used <= budget);
            // identical members: precedence is the only asymmetry, so
            // member 0's share under [9,1] equals member 1's under [1,9]
            assert_eq!(hi_first.members[0].budget, lo_first.members[1].budget);
            assert!(
                hi_first.members[0].budget >= hi_first.members[1].budget,
                "budget {budget}: high-priority member got {} vs {}",
                hi_first.members[0].budget,
                hi_first.members[1].budget
            );
            // the top tier is never worse off than under plain joint solving
            let plain = solve_fleet(&problems, budget).unwrap();
            assert!(
                hi_first.members[0].config.objective >= plain.members[0].config.objective - 1e-9
            );
        }
    }

    #[test]
    fn packed_on_fungible_inventory_matches_scalar_solver() {
        let specs: Vec<PipelineSpec> = ["video", "audio-sent", "nlp"]
            .iter()
            .map(|n| pipelines::by_name(n).unwrap())
            .collect();
        let profs: Vec<PipelineProfiles> = specs.iter().map(pipeline_profiles).collect();
        let problems: Vec<Problem> = specs
            .iter()
            .zip(&profs)
            .zip([18.0, 7.0, 4.0])
            .map(|((s, pf), l)| problem(s, pf, l))
            .collect();
        for budget in [8u32, 14, 24] {
            for prios in [vec![0u32, 0, 0], vec![2, 1, 0]] {
                let scalar = solve_fleet_tiers(&problems, budget, &prios).unwrap();
                let packed =
                    solve_fleet_packed(&problems, &NodeInventory::fungible(budget), &prios)
                        .unwrap();
                assert_eq!(
                    scalar.members.iter().map(|m| m.budget).collect::<Vec<_>>(),
                    packed.members.iter().map(|m| m.budget).collect::<Vec<_>>(),
                    "budget {budget} prios {prios:?}: shares diverge"
                );
                for (s, p) in scalar.members.iter().zip(&packed.members) {
                    assert_eq!(s.config, p.config, "budget {budget}: configs diverge");
                    assert_eq!(s.solved, p.solved);
                }
                assert!((scalar.total_objective - packed.total_objective).abs() < 1e-12);
                let packing = packed.packing.expect("packed solve carries a packing");
                assert_eq!(packing.placements.len(), packed.replicas_used as usize);
            }
        }
    }

    #[test]
    fn packed_places_accel_variants_only_on_accel_nodes() {
        // Accuracy-hungry weights push the video pipeline toward
        // yolov5x (8c + one accel slot).
        let mut spec = pipelines::by_name("video").unwrap();
        spec.weights.alpha *= 50.0;
        let prof = pipeline_profiles(&spec);
        let problems = vec![problem(&spec, &prof, 4.0)];
        let hetero =
            crate::fleet::nodes::NodeInventory::parse("4x(8c,32g,0a)+2x(16c,64g,2a)").unwrap();
        let alloc = solve_fleet_packed(&problems, &hetero, &[0]).unwrap();
        let packing = alloc.packing.as_ref().unwrap();
        assert!(packing.valid_for(&hetero), "no node over capacity on any axis");
        for pl in &packing.placements {
            let sc = &alloc.members[pl.member].config.stages[pl.stage];
            if sc.resources.accel_slots > 0.0 {
                let shape = &hetero.pools[packing.shape_of[pl.node]].shape;
                assert!(
                    shape.capacity.accel_slots >= sc.resources.accel_slots,
                    "accel replica placed on an accel-less node"
                );
            }
        }
        // a CPU-only pool filters the accel variants out entirely
        let plain = crate::fleet::nodes::NodeInventory::parse("8x(4c,16g,0a)").unwrap();
        let cpu_alloc = solve_fleet_packed(&problems, &plain, &[0]).unwrap();
        for m in &cpu_alloc.members {
            for sc in &m.config.stages {
                assert_eq!(
                    sc.resources.accel_slots, 0.0,
                    "accel variant chosen on a CPU-only pool"
                );
            }
        }
    }

    #[test]
    fn placed_solve_spreads_flagged_members_across_zones() {
        use crate::fleet::nodes::NodeInventory;
        let specs: Vec<PipelineSpec> =
            ["video", "audio-sent"].iter().map(|n| pipelines::by_name(n).unwrap()).collect();
        let profs: Vec<PipelineProfiles> = specs.iter().map(pipeline_profiles).collect();
        let problems =
            vec![problem(&specs[0], &profs[0], 6.0), problem(&specs[1], &profs[1], 4.0)];
        let inv =
            NodeInventory::parse("3x(8c,32g,0a)@east+3x(8c,32g,0a)@west").unwrap();
        let spread = [true, false];
        let alloc = solve_fleet_placed(&problems, &inv, &[0, 0], &spread, None).unwrap();
        // the flagged member runs ≥ 2 replicas per stage
        for sc in &alloc.members[0].config.stages {
            assert!(sc.replicas >= 2, "spread stage below redundancy floor: {sc:?}");
        }
        // and every one of its stages survives any single zone loss
        let packing = alloc.packing.as_ref().unwrap();
        for zone in ["east", "west"] {
            let surv = packing.survivors_of_zone(&inv, zone);
            for s in 0..alloc.members[0].config.stages.len() {
                assert!(
                    surv.get(&(0, s)).copied().unwrap_or(0) >= 1,
                    "member 0 stage {s} dies with zone {zone}"
                );
            }
        }
        // no flags + no prev = the plain packed solve, byte for byte
        let plain = solve_fleet_packed(&problems, &inv, &[0, 0]).unwrap();
        let placed = solve_fleet_placed(&problems, &inv, &[0, 0], &[], None).unwrap();
        assert_eq!(plain.members.len(), placed.members.len());
        for (a, b) in plain.members.iter().zip(&placed.members) {
            assert_eq!(a.config, b.config);
            assert_eq!(a.budget, b.budget);
        }
        assert_eq!(plain.packing, placed.packing);
    }

    #[test]
    fn fleet_adapter_decides_per_member() {
        let specs: Vec<PipelineSpec> = ["video", "audio-sent"]
            .iter()
            .map(|n| pipelines::by_name(n).unwrap())
            .collect();
        let profs: Vec<PipelineProfiles> = specs.iter().map(pipeline_profiles).collect();
        let predictors: Vec<Box<dyn Predictor + Send>> = (0..2)
            .map(|_| {
                Box::new(crate::predictor::ReactivePredictor::default())
                    as Box<dyn Predictor + Send>
            })
            .collect();
        let mut fa = FleetAdapter::new(
            specs,
            profs,
            AccuracyMetric::Pas,
            20,
            AdapterConfig::default(),
            predictors,
        )
        .unwrap();
        let ds = fa.decide_for_lambdas(&[10.0, 5.0]);
        assert_eq!(ds.len(), 2);
        let used: u32 = ds.iter().map(|d| d.config.total_replicas()).sum();
        assert!(used <= 20);
        assert!(ds.iter().all(|d| !d.config.stages.is_empty()));
        // controller path with histories
        let ds2 = fa.decide(60.0, &[vec![8.0; 40], vec![4.0; 40]]);
        assert_eq!(ds2.len(), 2);
        // budget below the fleet stage floor is rejected at construction
        let specs2: Vec<PipelineSpec> =
            vec![pipelines::by_name("nlp").unwrap(), pipelines::by_name("video").unwrap()];
        let profs2: Vec<PipelineProfiles> = specs2.iter().map(pipeline_profiles).collect();
        let preds2: Vec<Box<dyn Predictor + Send>> = (0..2)
            .map(|_| {
                Box::new(crate::predictor::ReactivePredictor::default())
                    as Box<dyn Predictor + Send>
            })
            .collect();
        assert!(FleetAdapter::new(
            specs2,
            profs2,
            AccuracyMetric::Pas,
            4,
            AdapterConfig::default(),
            preds2
        )
        .is_err());
    }
}
