//! The joint cross-pipeline allocator: split one replica budget across
//! N pipelines so the fleet-total objective (Σ per-pipeline Eq. 9) is
//! maximized.
//!
//! Layering (mirrors the paper's §4.3 multi-objective structure):
//!
//! * [`solve_under_budget`] — one pipeline under a *total*-replica cap.
//!   Fast path: the per-pipeline exact IP ([`ip::solve_with_options`])
//!   over options filtered to the cap; when its optimum already fits
//!   the budget it is optimal for the constrained problem too.  Slow
//!   path: an exact DFS over the (Pareto-pruned, small) option sets
//!   with the Σ-replica constraint.
//! * [`solve_fleet`] — greedy marginal-gain allocation: every member
//!   starts at its one-replica-per-stage floor and each remaining
//!   replica goes to the pipeline whose next grant buys the most
//!   objective per replica (with a lookahead jump to a member's minimum
//!   feasible allocation, so crossing an infeasibility threshold is
//!   visible to the greedy).  The result is floored at the even-split
//!   baseline: the solver computes both and returns the better, so a
//!   fleet allocation is never worse than splitting the pool evenly.
//! * [`brute_best_split`] — exhaustive split enumeration for tiny
//!   fleets; the optimality cross-check the tests pin the greedy
//!   against.
//!
//! [`FleetAdapter`] packages the allocator as a [`FleetController`]
//! (per-member predictors → joint solve → one [`Decision`] per member)
//! for the fleet drivers in `simulator::sim` and `serving::engine`.
//!
//! Modeling note: a member whose IP is infeasible even at the full pool
//! gets a budget-clamped survival config ([`fallback_under_budget`] —
//! lightest variants, throughput-greedy replica placement) and sheds
//! the excess through §4.5 dropping, exactly like the single-pipeline
//! fallback.

use std::collections::HashMap;
use std::time::Instant;

use crate::coordinator::adapter::{AdapterConfig, Decision};
use crate::models::accuracy::AccuracyMetric;
use crate::models::pipelines::PipelineSpec;
use crate::optimizer::ip::{self, materialize, PipelineConfig, Problem, StageConfig};
use crate::optimizer::options::StageOption;
use crate::predictor::Predictor;
use crate::profiler::profile::PipelineProfiles;

/// Exact single-pipeline solve under a total-replica budget.  `None`
/// when no SLA-feasible configuration fits `budget` replicas.
pub fn solve_under_budget(
    p: &Problem,
    options: &[Vec<StageOption>],
    budget: u32,
) -> Option<PipelineConfig> {
    let s = options.len() as u32;
    if s == 0 || budget < s {
        return None;
    }
    // Every other stage needs at least one replica.
    let cap = budget - (s - 1);
    let filtered: Vec<Vec<StageOption>> = options
        .iter()
        .map(|os| os.iter().filter(|o| o.replicas <= cap).cloned().collect())
        .collect();
    if filtered.iter().any(Vec::is_empty) {
        return None;
    }
    // Fast path: the unconstrained optimum that already fits the pool
    // is optimal for the constrained problem as well.
    if let Some((cfg, _)) = ip::solve_with_options(p, &filtered) {
        if cfg.total_replicas() <= budget {
            return Some(cfg);
        }
    }
    budget_dfs(p, &filtered, budget)
}

/// Exact DFS with the Σ-replica constraint active (slow path of
/// [`solve_under_budget`]; option sets are Pareto-pruned and small).
fn budget_dfs(p: &Problem, options: &[Vec<StageOption>], budget: u32) -> Option<PipelineConfig> {
    let s = options.len();
    let sla = p.spec.sla_e2e();
    let mut suf_min_lat = vec![0.0f64; s + 1];
    let mut suf_min_rep = vec![0u32; s + 1];
    for d in (0..s).rev() {
        let min_lat =
            options[d].iter().map(StageOption::total_latency).fold(f64::MAX, f64::min);
        let min_rep = options[d].iter().map(|o| o.replicas).min().unwrap_or(1);
        suf_min_lat[d] = suf_min_lat[d + 1] + min_lat;
        suf_min_rep[d] = suf_min_rep[d + 1] + min_rep;
    }

    struct Ctx<'a> {
        p: &'a Problem<'a>,
        options: &'a [Vec<StageOption>],
        suf_min_lat: &'a [f64],
        suf_min_rep: &'a [u32],
        sla: f64,
        budget: u32,
    }

    fn rec(
        c: &Ctx,
        depth: usize,
        lat: f64,
        reps: u32,
        picks: &mut Vec<usize>,
        best: &mut Option<(f64, Vec<usize>)>,
    ) {
        if depth == c.options.len() {
            let cfg = materialize(c.p, c.options, picks);
            if best.as_ref().is_none_or(|(obj, _)| cfg.objective > *obj) {
                *best = Some((cfg.objective, picks.clone()));
            }
            return;
        }
        for (oi, o) in c.options[depth].iter().enumerate() {
            let nlat = lat + o.total_latency();
            if nlat + c.suf_min_lat[depth + 1] > c.sla {
                continue;
            }
            let nreps = reps + o.replicas;
            if nreps + c.suf_min_rep[depth + 1] > c.budget {
                continue;
            }
            picks[depth] = oi;
            rec(c, depth + 1, nlat, nreps, picks, best);
        }
    }

    let ctx = Ctx { p, options, suf_min_lat: &suf_min_lat, suf_min_rep: &suf_min_rep, sla, budget };
    let mut picks = vec![0usize; s];
    let mut best: Option<(f64, Vec<usize>)> = None;
    rec(&ctx, 0, 0.0, 0, &mut picks, &mut best);
    best.map(|(_, picks)| materialize(p, options, &picks))
}

/// Smallest total-replica budget at which the pipeline is SLA-feasible
/// (searched in `[n_stages, hi]`); `None` if infeasible even at `hi`.
pub fn min_feasible_replicas(p: &Problem, options: &[Vec<StageOption>], hi: u32) -> Option<u32> {
    let mut lo = options.len() as u32;
    if lo == 0 || hi < lo {
        return None;
    }
    solve_under_budget(p, options, hi)?;
    // feasibility is monotone in the budget: binary search the threshold
    let mut hi = hi;
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if solve_under_budget(p, options, mid).is_some() {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    Some(lo)
}

/// Budget-clamped survival configuration (the fleet twin of
/// [`ip::fallback_config`]): lightest variant per stage at its
/// throughput-optimal batch, with the granted replicas placed greedily
/// on the most throughput-starved stage.  Always uses ≤ `budget`
/// replicas and ≥ 1 per stage; §4.5 dropping sheds what it cannot
/// serve.
pub fn fallback_under_budget(p: &Problem, budget: u32) -> PipelineConfig {
    let s = p.profiles.stages.len();
    let budget = budget.max(s as u32);
    let w = p.spec.weights;

    struct Pick<'a> {
        vi: usize,
        vp: &'a crate::profiler::profile::VariantProfile,
        batch: usize,
        tput1: f64,
    }
    let picks: Vec<Pick> = p
        .profiles
        .stages
        .iter()
        .map(|st| {
            let (vi, vp) = st
                .variants
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| {
                    (a.cost_per_replica(), a.latency.latency(1))
                        .partial_cmp(&(b.cost_per_replica(), b.latency.latency(1)))
                        .unwrap()
                })
                .unwrap();
            let batch = vp.latency.best_batch();
            Pick { vi, vp, batch, tput1: vp.latency.throughput(batch) }
        })
        .collect();

    let mut replicas = vec![1u32; s];
    let mut left = budget - s as u32;
    while left > 0 {
        // most starved stage = lowest provisioned throughput, if any is
        // still short of λ
        let (i, headroom) = replicas
            .iter()
            .enumerate()
            .map(|(i, &r)| (i, r as f64 * picks[i].tput1))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        if headroom >= p.lambda {
            break; // every stage keeps up; don't burn pool for nothing
        }
        replicas[i] += 1;
        left -= 1;
    }

    let mut stages = Vec::with_capacity(s);
    let mut cost = 0.0;
    let mut batch_sum = 0usize;
    let mut lat = 0.0;
    let mut pas_frac = 1.0;
    for (pk, &n) in picks.iter().zip(&replicas) {
        stages.push(StageConfig {
            variant_idx: pk.vi,
            variant_key: pk.vp.variant.key(),
            batch: pk.batch,
            replicas: n,
            cost: n as f64 * pk.vp.cost_per_replica(),
            accuracy: pk.vp.variant.accuracy,
            latency: pk.vp.latency.latency(pk.batch),
        });
        cost += n as f64 * pk.vp.cost_per_replica();
        batch_sum += pk.batch;
        lat += pk.vp.latency.latency(pk.batch)
            + crate::queueing::worst_case_delay(pk.batch, p.lambda);
        pas_frac *= pk.vp.variant.accuracy / 100.0;
    }
    PipelineConfig {
        stages,
        pas: 100.0 * pas_frac,
        cost,
        batch_sum,
        objective: w.alpha * 100.0 * pas_frac - w.beta * cost - w.delta * batch_sum as f64,
        latency_e2e: lat,
    }
}

/// One member's share of the pool and the configuration it bought.
#[derive(Debug, Clone)]
pub struct MemberAllocation {
    /// Replicas granted from the shared pool.
    pub budget: u32,
    /// Chosen configuration (solved or budget-clamped fallback).
    pub config: PipelineConfig,
    /// Replicas the configuration actually occupies (≤ `budget`).
    pub replicas: u32,
    /// False when the member IP was infeasible within its share and the
    /// clamped fallback was used.
    pub solved: bool,
}

/// The joint allocation across the fleet.
#[derive(Debug, Clone)]
pub struct FleetAllocation {
    pub members: Vec<MemberAllocation>,
    /// Σ granted member shares ([`solve_fleet`] resets this to the pool
    /// size it solved against; greedy may leave part of the pool
    /// ungranted when no member benefits).
    pub budget: u32,
    /// Σ member `replicas` — never exceeds `budget`.
    pub replicas_used: u32,
    /// Σ member objectives (the quantity the greedy maximizes).
    pub total_objective: f64,
}

/// The even-split baseline shares: every member starts at its stage
/// floor, the rest of the pool is dealt round-robin.
pub fn even_shares(budget: u32, floors: &[u32]) -> Vec<u32> {
    let mut shares = floors.to_vec();
    let floor_total: u32 = floors.iter().sum();
    let mut left = budget.saturating_sub(floor_total);
    let n = floors.len();
    let mut i = 0usize;
    while left > 0 && n > 0 {
        shares[i] += 1;
        left -= 1;
        i = (i + 1) % n;
    }
    shares
}

fn eval_member(p: &Problem, options: &[Vec<StageOption>], b: u32) -> (PipelineConfig, bool) {
    match solve_under_budget(p, options, b) {
        Some(cfg) => (cfg, true),
        None => (fallback_under_budget(p, b), false),
    }
}

/// Evaluate an explicit share vector (used by the even-split baseline
/// and the property tests).
pub fn allocate_at(
    problems: &[Problem],
    options: &[Vec<Vec<StageOption>>],
    shares: &[u32],
) -> FleetAllocation {
    let members: Vec<MemberAllocation> = problems
        .iter()
        .zip(options)
        .zip(shares)
        .map(|((p, os), &b)| {
            let (config, solved) = eval_member(p, os, b);
            let replicas = config.total_replicas();
            MemberAllocation { budget: b, config, replicas, solved }
        })
        .collect();
    FleetAllocation {
        budget: shares.iter().sum(),
        replicas_used: members.iter().map(|m| m.replicas).sum(),
        total_objective: members.iter().map(|m| m.config.objective).sum(),
        members,
    }
}

/// Greedy marginal-gain joint solve.  `None` only when `budget` cannot
/// cover one replica per stage across the fleet; otherwise the returned
/// allocation respects the budget and its total objective is at least
/// the even-split baseline's.
pub fn solve_fleet(problems: &[Problem], budget: u32) -> Option<FleetAllocation> {
    let n = problems.len();
    if n == 0 {
        return Some(FleetAllocation {
            members: Vec::new(),
            budget,
            replicas_used: 0,
            total_objective: 0.0,
        });
    }
    let floors: Vec<u32> = problems.iter().map(|p| p.profiles.stages.len() as u32).collect();
    let floor_total: u32 = floors.iter().sum();
    if budget < floor_total {
        return None;
    }
    let options: Vec<Vec<Vec<StageOption>>> =
        problems.iter().map(|p| p.stage_options()).collect();

    // Memoized member evaluation: (member, share) → (objective, solved).
    let mut cache: Vec<HashMap<u32, (f64, bool)>> = vec![HashMap::new(); n];
    let obj_at = |cache: &mut [HashMap<u32, (f64, bool)>], i: usize, b: u32| -> f64 {
        if let Some(&(o, _)) = cache[i].get(&b) {
            return o;
        }
        let (cfg, solved) = eval_member(&problems[i], &options[i], b);
        let o = cfg.objective;
        cache[i].insert(b, (o, solved));
        o
    };

    // Lookahead targets: each member's minimum feasible allocation, so
    // the greedy can see across an infeasibility threshold.
    let min_b: Vec<Option<u32>> =
        (0..n).map(|i| min_feasible_replicas(&problems[i], &options[i], budget)).collect();

    let mut shares = floors.clone();
    let mut remaining = budget - floor_total;
    while remaining > 0 {
        let mut best: Option<(usize, u32, f64)> = None;
        for i in 0..n {
            let cur = obj_at(&mut cache, i, shares[i]);
            let mut cands = vec![1u32];
            if let Some(mb) = min_b[i] {
                if mb > shares[i] {
                    cands.push(mb - shares[i]);
                }
            }
            for &k in &cands {
                if k == 0 || k > remaining {
                    continue;
                }
                let gain = obj_at(&mut cache, i, shares[i] + k) - cur;
                if gain <= 1e-12 {
                    continue;
                }
                let rate = gain / k as f64;
                if best.as_ref().is_none_or(|&(_, _, r)| rate > r) {
                    best = Some((i, k, rate));
                }
            }
        }
        match best {
            Some((i, k, _)) => {
                shares[i] += k;
                remaining -= k;
            }
            None => break, // no member benefits from another replica
        }
    }

    // Never worse than the even split: compute both, keep the better.
    let even = even_shares(budget, &floors);
    let greedy_total: f64 = (0..n).map(|i| obj_at(&mut cache, i, shares[i])).sum();
    let even_total: f64 = (0..n).map(|i| obj_at(&mut cache, i, even[i])).sum();
    let final_shares = if greedy_total + 1e-12 >= even_total { shares } else { even };

    let mut alloc = allocate_at(problems, &options, &final_shares);
    alloc.budget = budget;
    debug_assert!(alloc.replicas_used <= budget, "fleet allocation exceeds budget");
    Some(alloc)
}

/// Exhaustive best split for tiny fleets (the greedy's cross-check):
/// best Σ objective over every share vector with `shares[i] ≥
/// n_stages_i` and `Σ shares ≤ budget`.
pub fn brute_best_split(problems: &[Problem], budget: u32) -> Option<f64> {
    let n = problems.len();
    if n == 0 {
        return Some(0.0);
    }
    let floors: Vec<u32> = problems.iter().map(|p| p.profiles.stages.len() as u32).collect();
    let floor_total: u32 = floors.iter().sum();
    if budget < floor_total {
        return None;
    }
    let options: Vec<Vec<Vec<StageOption>>> =
        problems.iter().map(|p| p.stage_options()).collect();
    let mut eval =
        |i: usize, b: u32| -> f64 { eval_member(&problems[i], &options[i], b).0.objective };

    fn rec(
        i: usize,
        left: u32,
        floors: &[u32],
        acc: f64,
        eval: &mut dyn FnMut(usize, u32) -> f64,
        best: &mut f64,
    ) {
        let n = floors.len();
        if i == n - 1 {
            for b in floors[i]..=left {
                let total = acc + eval(i, b);
                if total > *best {
                    *best = total;
                }
            }
            return;
        }
        let rest_floor: u32 = floors[i + 1..].iter().sum();
        for b in floors[i]..=left.saturating_sub(rest_floor) {
            rec(i + 1, left - b, floors, acc + eval(i, b), eval, best);
        }
    }

    let mut best = f64::MIN;
    rec(0, budget, &floors, 0.0, &mut eval, &mut best);
    Some(best)
}

// ---------------------------------------------------------------------------
// Fleet controller: per-member predictors + the joint solve, packaged
// for the drivers.
// ---------------------------------------------------------------------------

/// A joint decision source for the fleet drivers: both the DES fleet
/// loop and the live fleet engine call this once per adaptation tick
/// and receive one [`Decision`] per member.
pub trait FleetController {
    /// Initial configurations, decided on each trace's first-second
    /// rate before any request arrives.
    fn initial(&mut self, first_rates: &[f64]) -> Vec<Decision>;

    /// One adaptation-tick joint decision from the per-member observed
    /// load histories.
    fn decide(&mut self, now: f64, histories: &[Vec<f64>]) -> Vec<Decision>;
}

/// The fleet adapter: one predictor per member feeding the joint
/// allocator each tick.
pub struct FleetAdapter {
    pub specs: Vec<PipelineSpec>,
    pub profiles: Vec<PipelineProfiles>,
    pub metric: AccuracyMetric,
    /// The shared replica pool.
    pub budget: u32,
    pub config: AdapterConfig,
    pub predictors: Vec<Box<dyn Predictor + Send>>,
}

impl FleetAdapter {
    /// Errors when member vectors disagree in length or the budget
    /// cannot cover one replica per stage (the only condition under
    /// which [`solve_fleet`] returns `None`).
    pub fn new(
        specs: Vec<PipelineSpec>,
        profiles: Vec<PipelineProfiles>,
        metric: AccuracyMetric,
        budget: u32,
        config: AdapterConfig,
        predictors: Vec<Box<dyn Predictor + Send>>,
    ) -> Result<FleetAdapter, String> {
        if specs.len() != profiles.len() || specs.len() != predictors.len() {
            return Err(format!(
                "fleet adapter: {} specs vs {} profiles vs {} predictors",
                specs.len(),
                profiles.len(),
                predictors.len()
            ));
        }
        let floor: u32 = specs.iter().map(|s| s.n_stages() as u32).sum();
        if budget < floor {
            return Err(format!("fleet budget {budget} below stage floor {floor}"));
        }
        Ok(FleetAdapter { specs, profiles, metric, budget, config, predictors })
    }

    pub fn n_members(&self) -> usize {
        self.specs.len()
    }

    /// Joint decision for explicit per-member λ (sweeps / tests / the
    /// initial tick).
    pub fn decide_for_lambdas(&mut self, lambdas: &[f64]) -> Vec<Decision> {
        assert_eq!(lambdas.len(), self.specs.len());
        let t0 = Instant::now();
        let problems: Vec<Problem> = self
            .specs
            .iter()
            .zip(&self.profiles)
            .zip(lambdas)
            .map(|((spec, prof), &l)| Problem {
                spec,
                profiles: prof,
                lambda: l.max(0.5),
                metric: self.metric,
                max_replicas: self.config.max_replicas.min(self.budget),
            })
            .collect();
        let alloc = solve_fleet(&problems, self.budget)
            .expect("budget >= stage floor was checked at construction");
        let decision_time = t0.elapsed().as_secs_f64();
        alloc
            .members
            .into_iter()
            .zip(lambdas)
            .map(|(m, &l)| Decision {
                config: m.config,
                lambda_predicted: l.max(0.5),
                decision_time,
                fallback: !m.solved,
            })
            .collect()
    }
}

impl FleetController for FleetAdapter {
    fn initial(&mut self, first_rates: &[f64]) -> Vec<Decision> {
        self.decide_for_lambdas(first_rates)
    }

    fn decide(&mut self, now: f64, histories: &[Vec<f64>]) -> Vec<Decision> {
        let lambdas: Vec<f64> = self
            .predictors
            .iter_mut()
            .zip(histories)
            .map(|(p, h)| p.predict(now, h).max(0.5))
            .collect();
        self.decide_for_lambdas(&lambdas)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::pipelines;
    use crate::profiler::analytic::pipeline_profiles;

    fn problem<'a>(
        spec: &'a PipelineSpec,
        prof: &'a PipelineProfiles,
        lambda: f64,
    ) -> Problem<'a> {
        Problem::new(spec, prof, lambda)
    }

    #[test]
    fn budget_inactive_matches_unconstrained() {
        let spec = pipelines::by_name("video").unwrap();
        let prof = pipeline_profiles(&spec);
        let p = problem(&spec, &prof, 12.0);
        let options = p.stage_options();
        let free = ip::solve_with_options(&p, &options).unwrap().0;
        let capped = solve_under_budget(&p, &options, 1000).unwrap();
        assert!((free.objective - capped.objective).abs() < 1e-9);
    }

    #[test]
    fn budget_constrained_solve_respects_budget_and_sla() {
        let spec = pipelines::by_name("video").unwrap();
        let prof = pipeline_profiles(&spec);
        let p = problem(&spec, &prof, 25.0);
        let options = p.stage_options();
        let free = ip::solve_with_options(&p, &options).unwrap().0;
        // squeeze below the unconstrained usage
        for b in (2..=free.total_replicas()).rev() {
            if let Some(cfg) = solve_under_budget(&p, &options, b) {
                assert!(cfg.total_replicas() <= b);
                assert!(cfg.latency_e2e <= spec.sla_e2e() + 1e-9);
                assert!(cfg.objective <= free.objective + 1e-9);
            }
        }
        assert!(solve_under_budget(&p, &options, 1).is_none(), "below stage floor");
    }

    #[test]
    fn min_feasible_is_threshold() {
        let spec = pipelines::by_name("video").unwrap();
        let prof = pipeline_profiles(&spec);
        let p = problem(&spec, &prof, 20.0);
        let options = p.stage_options();
        let mb = min_feasible_replicas(&p, &options, 64).unwrap();
        assert!(solve_under_budget(&p, &options, mb).is_some());
        if mb > 2 {
            assert!(solve_under_budget(&p, &options, mb - 1).is_none());
        }
    }

    #[test]
    fn fallback_clamped_to_budget() {
        let spec = pipelines::by_name("nlp").unwrap();
        let prof = pipeline_profiles(&spec);
        let p = problem(&spec, &prof, 5_000.0);
        for budget in [3u32, 5, 9] {
            let fb = fallback_under_budget(&p, budget);
            assert_eq!(fb.stages.len(), 3);
            assert!(fb.total_replicas() <= budget, "budget {budget}");
            assert!(fb.stages.iter().all(|s| s.replicas >= 1));
        }
    }

    #[test]
    fn greedy_respects_budget_and_beats_even_split() {
        let specs: Vec<PipelineSpec> = ["video", "audio-sent", "nlp"]
            .iter()
            .map(|n| pipelines::by_name(n).unwrap())
            .collect();
        let profs: Vec<PipelineProfiles> = specs.iter().map(pipeline_profiles).collect();
        let problems: Vec<Problem> = specs
            .iter()
            .zip(&profs)
            .zip([22.0, 9.0, 6.0])
            .map(|((s, pf), l)| problem(s, pf, l))
            .collect();
        for budget in [7u32, 10, 16, 24] {
            let alloc = solve_fleet(&problems, budget).unwrap();
            assert!(alloc.replicas_used <= budget, "budget {budget}");
            let floors: Vec<u32> =
                problems.iter().map(|p| p.profiles.stages.len() as u32).collect();
            let options: Vec<Vec<Vec<StageOption>>> =
                problems.iter().map(|p| p.stage_options()).collect();
            let even = allocate_at(&problems, &options, &even_shares(budget, &floors));
            assert!(
                alloc.total_objective >= even.total_objective - 1e-9,
                "budget {budget}: greedy {} < even {}",
                alloc.total_objective,
                even.total_objective
            );
        }
        assert!(solve_fleet(&problems, 6).is_none(), "floor is 7");
    }

    #[test]
    fn greedy_bounded_by_brute_on_tiny_fleet() {
        let specs: Vec<PipelineSpec> =
            ["video", "sum-qa"].iter().map(|n| pipelines::by_name(n).unwrap()).collect();
        let profs: Vec<PipelineProfiles> = specs.iter().map(pipeline_profiles).collect();
        let problems: Vec<Problem> = specs
            .iter()
            .zip(&profs)
            .zip([15.0, 8.0])
            .map(|((s, pf), l)| problem(s, pf, l))
            .collect();
        for budget in [4u32, 6, 9] {
            let alloc = solve_fleet(&problems, budget).unwrap();
            let brute = brute_best_split(&problems, budget).unwrap();
            assert!(
                alloc.total_objective <= brute + 1e-9,
                "budget {budget}: greedy {} above brute optimum {brute}",
                alloc.total_objective
            );
        }
    }

    #[test]
    fn fleet_adapter_decides_per_member() {
        let specs: Vec<PipelineSpec> = ["video", "audio-sent"]
            .iter()
            .map(|n| pipelines::by_name(n).unwrap())
            .collect();
        let profs: Vec<PipelineProfiles> = specs.iter().map(pipeline_profiles).collect();
        let predictors: Vec<Box<dyn Predictor + Send>> = (0..2)
            .map(|_| {
                Box::new(crate::predictor::ReactivePredictor::default())
                    as Box<dyn Predictor + Send>
            })
            .collect();
        let mut fa = FleetAdapter::new(
            specs,
            profs,
            AccuracyMetric::Pas,
            20,
            AdapterConfig::default(),
            predictors,
        )
        .unwrap();
        let ds = fa.decide_for_lambdas(&[10.0, 5.0]);
        assert_eq!(ds.len(), 2);
        let used: u32 = ds.iter().map(|d| d.config.total_replicas()).sum();
        assert!(used <= 20);
        assert!(ds.iter().all(|d| !d.config.stages.is_empty()));
        // controller path with histories
        let ds2 = fa.decide(60.0, &[vec![8.0; 40], vec![4.0; 40]]);
        assert_eq!(ds2.len(), 2);
        // budget below the fleet stage floor is rejected at construction
        let specs2: Vec<PipelineSpec> =
            vec![pipelines::by_name("nlp").unwrap(), pipelines::by_name("video").unwrap()];
        let profs2: Vec<PipelineProfiles> = specs2.iter().map(pipeline_profiles).collect();
        let preds2: Vec<Box<dyn Predictor + Send>> = (0..2)
            .map(|_| {
                Box::new(crate::predictor::ReactivePredictor::default())
                    as Box<dyn Predictor + Send>
            })
            .collect();
        assert!(FleetAdapter::new(
            specs2,
            profs2,
            AccuracyMetric::Pas,
            4,
            AdapterConfig::default(),
            preds2
        )
        .is_err());
    }
}
