//! The pool autoscaler: grow/shrink the *fleet's replica budget itself*
//! against a cost target.
//!
//! IPA (§4) adapts within a fixed cluster; this module is the missing
//! outer loop — the cluster-autoscaler twin that decides how many
//! replicas the pool should even hold.  It is pure policy: callers
//! (normally [`crate::fleet::solver::FleetAdapter::resize`]) feed it
//! the current pool size, a *demand* estimate (the replicas the joint
//! solver would need to keep every member SLA-feasible at the predicted
//! λs — Σ per-member `min_feasible_replicas`) and the fleet's
//! min-feasible floor; it answers with a bounded step toward the
//! demand, clamped to the cost-derived cap.
//!
//! Asymmetric response, like real cluster autoscalers:
//!
//! * **scale-up eagerness** — demand is padded by a headroom factor and
//!   growth happens on the first tick that needs it (an under-provisioned
//!   pool drops requests *now*);
//! * **scale-down hysteresis** — the pool shrinks only after
//!   `shrink_after` consecutive low-demand ticks, and then by at most
//!   `max_step_down` replicas (rolling shrinks strand in-flight work;
//!   flapping wastes the apply delay twice).
//!
//! Invariants (pinned by `tests/fleet_elastic.rs`): a proposed target is
//! never above [`Autoscaler::max_pool`] (the cost cap) unless the
//! fleet's feasibility floor itself exceeds the cap — feasibility wins
//! over cost — and never below that floor.
//!
//! On a heterogeneous node pool the replica target alone does not say
//! WHICH shape to buy; [`pressure_axis`] is the policy half of that
//! choice: given the fleet's per-axis demand vector and the pool's
//! total capacity it names the binding axis (cpu/memory/accel), and
//! [`crate::fleet::nodes::NodeInventory::retarget_with`] buys the shape
//! that is cheapest per unit of that axis — so accel-bound demand buys
//! accelerator nodes instead of piling on the cheapest CPU shape.

use crate::resources::ResourceVec;

/// The binding axis of a demand vector against a capacity vector:
/// index of the largest demand/capacity ratio (0 = cpu, 1 = memory,
/// 2 = accel).  An axis with demand but zero capacity is maximally
/// bound; ties prefer the lower index (CPU first), and zero demand
/// everywhere answers CPU — the classic scalar behavior.
pub fn pressure_axis(demand: ResourceVec, capacity: ResourceVec) -> usize {
    let ratio = |d: f64, c: f64| {
        if d <= 0.0 {
            0.0
        } else if c <= 0.0 {
            f64::INFINITY
        } else {
            d / c
        }
    };
    let rs = [
        ratio(demand.cpu_cores, capacity.cpu_cores),
        ratio(demand.memory_gb, capacity.memory_gb),
        ratio(demand.accel_slots, capacity.accel_slots),
    ];
    let mut best = 0usize;
    for (i, &r) in rs.iter().enumerate().skip(1) {
        if r > rs[best] {
            best = i;
        }
    }
    best
}

/// Autoscaler knobs.
#[derive(Debug, Clone, Copy)]
pub struct AutoscalerConfig {
    /// Cost of holding one replica for one second (abstract $ — the
    /// same unit `cost_target` is expressed in).
    pub cost_per_replica: f64,
    /// Maximum spend rate the operator accepts ($ per second).  The
    /// pool cap is `floor(cost_target / cost_per_replica)` replicas.
    pub cost_target: f64,
    /// Never shrink below this many replicas (the fleet's stage floor
    /// is enforced on top of it — the effective floor is the max).
    pub min_pool: u32,
    /// Max replicas added in one decision (scale-up slew rate).
    pub max_step_up: u32,
    /// Max replicas removed in one decision (scale-down slew rate).
    pub max_step_down: u32,
    /// Scale-up eagerness: demand is padded to `demand × headroom`
    /// before comparing against the pool (≥ 1.0).
    pub headroom: f64,
    /// Scale-down hysteresis: consecutive low-demand ticks required
    /// before a shrink step is proposed.
    pub shrink_after: u32,
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        AutoscalerConfig {
            cost_per_replica: 1.0,
            cost_target: 32.0,
            min_pool: 0,
            max_step_up: 8,
            max_step_down: 2,
            headroom: 1.25,
            shrink_after: 3,
        }
    }
}

/// What the autoscaler decided this tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolAction {
    /// Grow the pool by this many replicas.
    Grow(u32),
    /// Shrink the pool by this many replicas.
    Shrink(u32),
    /// Keep the current size (includes "low demand but hysteresis not
    /// yet expired").
    Hold,
}

/// One autoscaling decision: the proposed pool size and how it differs
/// from the current one.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolDecision {
    /// Proposed pool size (equals the input pool on [`PoolAction::Hold`]).
    pub target: u32,
    pub action: PoolAction,
}

/// The stateful autoscaler (state = the scale-down hysteresis counter
/// plus decision telemetry).
#[derive(Debug, Clone)]
pub struct Autoscaler {
    pub cfg: AutoscalerConfig,
    /// Consecutive ticks with padded demand below the pool.
    low_ticks: u32,
    /// Telemetry: decisions taken, by kind.
    pub grows: u32,
    pub shrinks: u32,
    pub holds: u32,
}

impl Autoscaler {
    pub fn new(cfg: AutoscalerConfig) -> Autoscaler {
        Autoscaler { cfg, low_ticks: 0, grows: 0, shrinks: 0, holds: 0 }
    }

    /// The cost-derived pool cap: the largest pool whose spend rate
    /// stays within `cost_target`.
    pub fn max_pool(&self) -> u32 {
        if self.cfg.cost_per_replica <= 0.0 {
            return u32::MAX;
        }
        let cap = (self.cfg.cost_target / self.cfg.cost_per_replica).floor();
        if cap <= 0.0 {
            0
        } else if cap >= u32::MAX as f64 {
            u32::MAX
        } else {
            cap as u32
        }
    }

    /// One decision: compare padded `demand` against `pool` and propose
    /// a bounded step.  `floor` is the fleet's min-feasible replica
    /// floor (one replica per stage of every member); the target never
    /// drops below `max(floor, min_pool)` and never rises above the
    /// cost cap — except that a floor above the cap wins (an infeasible
    /// cost target cannot be honored without breaking the fleet).
    pub fn decide(&mut self, pool: u32, demand: u32, floor: u32) -> PoolDecision {
        let lo = floor.max(self.cfg.min_pool);
        let cap = self.max_pool().max(lo);
        let padded = (demand as f64 * self.cfg.headroom.max(1.0)).ceil();
        let want = if padded >= cap as f64 { cap } else { (padded as u32).max(lo) };

        if want > pool {
            self.low_ticks = 0;
            let step = (want - pool).min(self.cfg.max_step_up.max(1));
            self.grows += 1;
            PoolDecision { target: pool + step, action: PoolAction::Grow(step) }
        } else if want < pool {
            self.low_ticks += 1;
            if self.low_ticks >= self.cfg.shrink_after.max(1) {
                self.low_ticks = 0;
                let step = (pool - want).min(self.cfg.max_step_down.max(1));
                self.shrinks += 1;
                PoolDecision { target: pool - step, action: PoolAction::Shrink(step) }
            } else {
                self.holds += 1;
                PoolDecision { target: pool, action: PoolAction::Hold }
            }
        } else {
            self.low_ticks = 0;
            self.holds += 1;
            PoolDecision { target: pool, action: PoolAction::Hold }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scaler(cost_target: f64, shrink_after: u32) -> Autoscaler {
        Autoscaler::new(AutoscalerConfig {
            cost_per_replica: 1.0,
            cost_target,
            min_pool: 0,
            max_step_up: 4,
            max_step_down: 2,
            headroom: 1.0,
            shrink_after,
        })
    }

    #[test]
    fn cost_cap_derivation() {
        assert_eq!(scaler(32.0, 1).max_pool(), 32);
        assert_eq!(scaler(31.5, 1).max_pool(), 31);
        assert_eq!(scaler(0.0, 1).max_pool(), 0);
        let free = Autoscaler::new(AutoscalerConfig {
            cost_per_replica: 0.0,
            ..Default::default()
        });
        assert_eq!(free.max_pool(), u32::MAX);
    }

    #[test]
    fn grows_eagerly_with_bounded_step() {
        let mut a = scaler(64.0, 3);
        let d = a.decide(10, 30, 5);
        assert_eq!(d.action, PoolAction::Grow(4), "step capped at max_step_up");
        assert_eq!(d.target, 14);
        // headroom pads the demand before comparing
        let mut h = Autoscaler::new(AutoscalerConfig {
            headroom: 1.5,
            max_step_up: 32,
            cost_target: 64.0,
            ..Default::default()
        });
        assert_eq!(h.decide(10, 10, 2).target, 15, "10 × 1.5 = 15");
    }

    #[test]
    fn shrink_waits_for_hysteresis() {
        let mut a = scaler(64.0, 3);
        assert_eq!(a.decide(10, 4, 2).action, PoolAction::Hold);
        assert_eq!(a.decide(10, 4, 2).action, PoolAction::Hold);
        let d = a.decide(10, 4, 2);
        assert_eq!(d.action, PoolAction::Shrink(2), "third low tick shrinks, step capped");
        assert_eq!(d.target, 8);
        // a demand spike resets the counter
        let mut b = scaler(64.0, 3);
        assert_eq!(b.decide(10, 4, 2).action, PoolAction::Hold);
        assert_eq!(b.decide(10, 30, 2).action, PoolAction::Grow(4));
        assert_eq!(b.decide(14, 4, 2).action, PoolAction::Hold, "counter was reset");
    }

    #[test]
    fn target_clamped_to_cap_and_floor() {
        let mut a = scaler(12.0, 1);
        // demand far over the cap: grow toward the cap, never past it
        let mut pool = 6u32;
        for _ in 0..10 {
            let d = a.decide(pool, 1000, 6);
            assert!(d.target <= 12, "target {} over cost cap", d.target);
            pool = d.target;
        }
        assert_eq!(pool, 12);
        // demand far under the floor: shrink toward the floor, never below
        let mut pool = 12u32;
        for _ in 0..20 {
            let d = a.decide(pool, 0, 6);
            assert!(d.target >= 6, "target {} below floor", d.target);
            pool = d.target;
        }
        assert_eq!(pool, 6);
    }

    #[test]
    fn pressure_axis_names_the_binding_axis() {
        let cap = ResourceVec::new(32.0, 128.0, 2.0);
        // cpu-bound: 24/32 dominates 32/128 and 1/2
        assert_eq!(pressure_axis(ResourceVec::new(24.0, 32.0, 1.0), cap), 0);
        // accel-bound: 2/2 dominates
        assert_eq!(pressure_axis(ResourceVec::new(8.0, 16.0, 2.0), cap), 2);
        // memory-bound
        assert_eq!(pressure_axis(ResourceVec::new(4.0, 120.0, 0.0), cap), 1);
        // demand on a zero-capacity axis binds maximally
        let no_accel = ResourceVec::new(32.0, 128.0, 0.0);
        assert_eq!(pressure_axis(ResourceVec::new(30.0, 8.0, 1.0), no_accel), 2);
        // zero demand everywhere answers cpu (scalar behavior)
        assert_eq!(pressure_axis(ResourceVec::ZERO, cap), 0);
        // cpu wins exact ties (lower index preferred)
        assert_eq!(pressure_axis(ResourceVec::new(16.0, 64.0, 1.0), cap), 0);
    }

    #[test]
    fn floor_above_cap_wins() {
        // cost target allows 4 replicas but the fleet needs 7 to exist
        let mut a = scaler(4.0, 1);
        let d = a.decide(7, 3, 7);
        assert_eq!(d.action, PoolAction::Hold, "feasibility wins over cost");
        let d = a.decide(5, 3, 7);
        assert!(matches!(d.action, PoolAction::Grow(_)), "grow back to the floor");
        assert_eq!(d.target, 7);
    }
}
