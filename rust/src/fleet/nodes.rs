//! Heterogeneous node shapes and the replica bin-packer.
//!
//! The fleet pool stops being a fungible replica count and becomes a
//! [`NodeInventory`]: counts of [`NodeShape`]s, each offering a
//! capacity [`ResourceVec`].  Feasibility of a fleet configuration is
//! then a bin-packing question — every replica's demand vector must be
//! placed on some node without exceeding that node's capacity on ANY
//! axis — answered by [`NodeInventory::pack`] with a first-fit-
//! decreasing heuristic (items sorted scarcest-resource-first, nodes
//! visited accel-poorest-first so CPU-only replicas never squat
//! accelerator slots).
//!
//! **Sticky packing.**  [`NodeInventory::pack_sticky`] additionally
//! takes the *previous* [`Packing`] and minimizes replica moves between
//! consecutive packings: a keep-in-place first pass pins every replica
//! whose old node (same shape, same ordinal — node identity survives
//! count changes) still has room for its new demand, and only the
//! displaced/new replicas fall through to the FFD pass.
//! [`Packing::moved_from`] diffs two packings into the replicas that
//! changed nodes (hash-indexed, linear in replicas) — the migration
//! count the fleet core charges through the reconfiguration delay.
//!
//! **Delta packing.**  [`NodeInventory::pack_delta`] is the incremental
//! fast path for callers that know WHICH members' configurations
//! changed (the adapter's incremental re-solve, preemption,
//! `FleetCore::apply`): unchanged members are retained verbatim on
//! their previous nodes — the occupancy index is rebuilt instead of
//! re-searched — and only changed members run the sticky keep + FFD
//! machinery, so a 2-member wiggle on a 1000-node pool re-places 2
//! members' replicas, not 100.  It declines (`None`) whenever the
//! retained occupancy cannot be reconstructed exactly and every caller
//! then falls back to the full sticky pack; `IPA_DELTA_PACK=0` /
//! [`set_delta_pack`] keeps the legacy path for A/B.
//!
//! **Failure domains.**  Every [`NodeShape`] carries a `zone` label
//! (`""` = the single unnamed zone; parse syntax
//! `"4x(8c,32g,0a)@east"`).  Members flagged for *zone spread* have
//! their replicas placed across ≥ 2 distinct zones per stage (the FFD
//! pass prefers zones the stage does not occupy yet, and the packing is
//! rejected when a spread stage ends up single-zoned), so losing any
//! one zone never takes a spread member below its one-replica-per-stage
//! floor.  [`NodeInventory::drain_zone`] is the fault actuator: it
//! zeroes every pool in a zone (shape list preserved, so node-seconds
//! ledgers keep their indices).
//!
//! **Scalar embedding.**  [`NodeInventory::fungible`] reproduces the
//! pre-refactor pool exactly: `n` unit nodes of one `1c/0g/0a` shape,
//! with every replica's demand coerced to one CPU slot
//! ([`NodeInventory::demand_of`]).  Packing then succeeds iff the
//! replica count fits the pool — byte-identical to the old scalar
//! budget check — which is how the regression tests pin the refactor
//! (with no previous packing and no spread flags, `pack_sticky` IS the
//! PR-4 `pack`).
//!
//! **Elasticity.**  [`NodeInventory::retarget`] adds/removes WHOLE
//! nodes of the elastic (cheapest-per-slot) shape toward a replica
//! target: growth never overshoots the target (the autoscaler's cost
//! cap holds), shrink never undershoots it.  For a target that is
//! itself a REACHABLE cap of the inventory (some whole-node count
//! yields exactly that replica cap), `retarget` converges to that cap
//! from any starting count.  [`NodeInventory::retarget_with`] is the
//! topology-aware variant: growth under a per-axis *pressure* vector
//! buys the shape that is cheapest per unit of the binding axis
//! (accel-bound demand buys accelerator nodes instead of the cheapest
//! CPU shape), and shrink sells cheapest-tier nodes first, then
//! specialer shapes but only down to what growth elastically BOUGHT
//! ([`NodePool::bought`] — a pressure burst's accel purchases are
//! reclaimable, an operator's provisioned accel nodes never leave),
//! draining the zone with the most spare capacity first (fighting
//! stickiness least).  Because shape
//! CHOICE now depends on more than the replica target, the control
//! plane no longer relies on cap-convergence alone: the fleet core
//! mirrors the controller's inventory on every resize
//! (`FleetCore::resize_pool_with`).

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use crate::optimizer::ip::PipelineConfig;
use crate::resources::{CostWeights, ResourceVec};
use crate::util::json::Json;

/// Delta-pack override: 0 = unset (env/default), 1 = on, 2 = off.
static DELTA_PACK: AtomicUsize = AtomicUsize::new(0);

/// Is the [`NodeInventory::pack_delta`] fast path enabled?  Default ON;
/// `IPA_DELTA_PACK=0` or [`set_delta_pack`]`(false)` disables it (the
/// A/B baseline).  Delta packing is placement-preserving and callers
/// fall back to the full sticky pack whenever it declines, so the knob
/// trades wall time only — it never changes what is packable.
pub fn delta_pack_enabled() -> bool {
    match DELTA_PACK.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            static ENV: OnceLock<bool> = OnceLock::new();
            *ENV.get_or_init(|| {
                !matches!(std::env::var("IPA_DELTA_PACK").as_deref().map(str::trim), Ok("0"))
            })
        }
    }
}

/// Force the delta-pack fast path on/off for this process (benches and
/// A/B tests; [`reset_delta_pack`] returns to the env/default
/// resolution).
pub fn set_delta_pack(on: bool) {
    DELTA_PACK.store(if on { 1 } else { 2 }, Ordering::Relaxed);
}

/// Back to the `IPA_DELTA_PACK` / default resolution.
pub fn reset_delta_pack() {
    DELTA_PACK.store(0, Ordering::Relaxed);
}

/// One node hardware variant: a name, its capacity vector and the
/// failure domain (zone/rack) it lives in (`""` = unzoned).
#[derive(Debug, Clone, PartialEq)]
pub struct NodeShape {
    pub name: String,
    pub capacity: ResourceVec,
    pub zone: String,
}

/// `count` nodes of one shape.
#[derive(Debug, Clone, PartialEq)]
pub struct NodePool {
    pub shape: NodeShape,
    pub count: u32,
    /// Nodes of this shape acquired ELASTICALLY (by
    /// [`NodeInventory::retarget_with`] growth) beyond the provisioned
    /// baseline.  Shrink may always sell the elastic (cheapest) tier,
    /// but sells specialer shapes only down to what was bought — an
    /// operator's fixed accelerator nodes never leave the pool.
    /// Transient control-plane state: not serialized, reset by parsing.
    pub bought: u32,
}

/// The whole cluster: counts of heterogeneous node shapes.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeInventory {
    pub pools: Vec<NodePool>,
    /// Scalar-embedding mode: demands are coerced to one CPU slot each
    /// (see [`NodeInventory::demand_of`]).
    fungible: bool,
}

/// One replica group to place: `replicas` copies of a `unit` demand,
/// tagged with the (member, stage) they belong to.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PackItem {
    pub member: usize,
    pub stage: usize,
    /// Per-replica demand vector.
    pub unit: ResourceVec,
    pub replicas: u32,
}

/// Where one replica landed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Placement {
    pub member: usize,
    pub stage: usize,
    /// Flat node index (see [`Packing::shape_of`]).
    pub node: usize,
}

/// A successful packing: per-node occupancy plus one placement per
/// replica.
#[derive(Debug, Clone, PartialEq)]
pub struct Packing {
    /// Pool (shape) index of each flat node.
    pub shape_of: Vec<usize>,
    /// Resources in use on each flat node (≤ that node's capacity on
    /// every axis — `valid_for` re-checks it).
    pub used: Vec<ResourceVec>,
    /// One entry per placed replica.
    pub placements: Vec<Placement>,
}

/// Node-identity mapping between two flat layouts: a node keeps its
/// identity when its shape index AND its ordinal within that shape
/// survive (counts change at the tail of each shape pool).
fn map_nodes(from: &[usize], to: &[usize]) -> Vec<Option<usize>> {
    let n_shapes = from.iter().chain(to.iter()).copied().max().map_or(0, |m| m + 1);
    let mut to_by_shape: Vec<Vec<usize>> = vec![Vec::new(); n_shapes];
    for (ni, &s) in to.iter().enumerate() {
        to_by_shape[s].push(ni);
    }
    let mut ord = vec![0usize; n_shapes];
    from.iter()
        .map(|&s| {
            let o = ord[s];
            ord[s] += 1;
            to_by_shape[s].get(o).copied()
        })
        .collect()
}

impl Packing {
    /// Nodes hosting at least one replica.
    pub fn nodes_used(&self) -> usize {
        let mut used = vec![false; self.shape_of.len()];
        for p in &self.placements {
            used[p.node] = true;
        }
        used.iter().filter(|&&b| b).count()
    }

    /// Nodes hosting at least one replica, per shape index.
    pub fn nodes_used_per_shape(&self, n_shapes: usize) -> Vec<u32> {
        let mut used = vec![false; self.shape_of.len()];
        for p in &self.placements {
            used[p.node] = true;
        }
        let mut out = vec![0u32; n_shapes];
        for (ni, &u) in used.iter().enumerate() {
            if u {
                out[self.shape_of[ni]] += 1;
            }
        }
        out
    }

    /// Every node's occupancy fits its shape's capacity on every axis.
    pub fn valid_for(&self, inv: &NodeInventory) -> bool {
        self.shape_of.len() == self.used.len()
            && self
                .used
                .iter()
                .zip(&self.shape_of)
                .all(|(u, &si)| u.fits(inv.pools[si].shape.capacity))
    }

    /// The replicas of `self` that do NOT sit on a node their
    /// (member, stage) occupied in `prev` — the container churn a
    /// reconfiguration from `prev` to `self` pays: node-to-node moves
    /// and NEW replicas alike (a grown stage starts containers it did
    /// not inherit; only teardowns are free).  Node identity across
    /// the two layouts is (shape, ordinal within shape), so the diff
    /// stays meaningful when elastic nodes came or went in between; a
    /// replica whose old node no longer exists counts as moved.
    pub fn moved_from(&self, prev: &Packing) -> Vec<Placement> {
        let map = map_nodes(&prev.shape_of, &self.shape_of);
        // Multiset of surviving prev slots, hash-indexed by
        // (member, stage, node): each placement of `self` consumes one
        // matching slot in O(1), so the diff is linear in replicas.
        // (The old diff scanned a per-(member, stage) Vec for every
        // placement — quadratic on fat stages at 1000-node scale.)
        let mut held: HashMap<(usize, usize, usize), u32> = HashMap::new();
        for p in &prev.placements {
            if let Some(ni) = map[p.node] {
                *held.entry((p.member, p.stage, ni)).or_insert(0) += 1;
            }
        }
        let mut moved = Vec::new();
        for p in &self.placements {
            match held.get_mut(&(p.member, p.stage, p.node)) {
                Some(k) if *k > 0 => *k -= 1,
                _ => moved.push(*p),
            }
        }
        moved
    }

    /// Distinct zones hosting each (member, stage), for spread checks.
    fn zones_by_key<'a>(&self, inv: &'a NodeInventory) -> HashMap<(usize, usize), Vec<&'a str>> {
        let mut zones: HashMap<(usize, usize), Vec<&str>> = HashMap::new();
        for p in &self.placements {
            let z = inv.pools[self.shape_of[p.node]].shape.zone.as_str();
            let e = zones.entry((p.member, p.stage)).or_default();
            if !e.contains(&z) {
                e.push(z);
            }
        }
        zones
    }

    /// Replicas of each (member, stage) that survive losing `zone`.
    pub fn survivors_of_zone(&self, inv: &NodeInventory, zone: &str) -> HashMap<(usize, usize), u32> {
        let mut out: HashMap<(usize, usize), u32> = HashMap::new();
        for p in &self.placements {
            let z = &inv.pools[self.shape_of[p.node]].shape.zone;
            if z != zone {
                *out.entry((p.member, p.stage)).or_insert(0) += 1;
            }
        }
        out
    }
}

impl NodeInventory {
    /// A heterogeneous inventory.  Call [`NodeInventory::validate`]
    /// before trusting externally-supplied shapes.
    pub fn new(pools: Vec<NodePool>) -> NodeInventory {
        NodeInventory { pools, fungible: false }
    }

    /// The scalar embedding: `n` unit nodes ("slot" shape, one CPU
    /// core), every replica demand coerced to one slot.  Packing is
    /// then exactly the pre-refactor `Σ replicas ≤ n` budget check.
    pub fn fungible(n: u32) -> NodeInventory {
        NodeInventory {
            pools: vec![NodePool {
                shape: NodeShape {
                    name: "slot".into(),
                    capacity: ResourceVec::cpu(1.0),
                    zone: String::new(),
                },
                count: n,
                bought: 0,
            }],
            fungible: true,
        }
    }

    pub fn is_fungible(&self) -> bool {
        self.fungible
    }

    /// This inventory with every pool's node count multiplied by `k`
    /// (elastic `bought` markers cleared — a scaled inventory is a
    /// fresh provisioning, not an autoscaler trajectory).  Scale-up
    /// helper for `fleet_serve --nodes-scale` and the `fleet_scale`
    /// bench grid.
    pub fn scaled(&self, k: u32) -> NodeInventory {
        NodeInventory {
            pools: self
                .pools
                .iter()
                .map(|p| NodePool { shape: p.shape.clone(), count: p.count * k, bought: 0 })
                .collect(),
            fungible: self.fungible,
        }
    }

    /// The demand a replica presents to this inventory: its full vector
    /// on real node pools, one CPU slot in the fungible embedding.
    pub fn demand_of(&self, unit: ResourceVec) -> ResourceVec {
        if self.fungible {
            ResourceVec::cpu(1.0)
        } else {
            unit
        }
    }

    /// Max unit (1-core) replicas one node of `shape` can host — every
    /// replica demands at least one CPU core, so the CPU axis caps the
    /// slot count.
    fn slots_of(shape: &NodeShape) -> u32 {
        ((shape.capacity.cpu_cores + 1e-9).floor() as u32).max(1)
    }

    /// Upper bound on the replicas this inventory can hold — the
    /// replica-denominated pool size (`budget`) the solvers and the
    /// autoscaler reason in.  Exact for the fungible embedding.
    pub fn replica_cap(&self) -> u32 {
        self.pools.iter().map(|p| p.count * Self::slots_of(&p.shape)).sum()
    }

    pub fn n_nodes(&self) -> u32 {
        self.pools.iter().map(|p| p.count).sum()
    }

    /// Σ `count × capacity` across shapes.
    pub fn total_capacity(&self) -> ResourceVec {
        self.pools
            .iter()
            .fold(ResourceVec::ZERO, |a, p| a.add(p.shape.capacity.scale(p.count as f64)))
    }

    /// Distinct zone labels among pools that still hold nodes.  Spread
    /// constraints are vacuous below 2 (nothing to spread across).
    pub fn distinct_zones(&self) -> usize {
        let mut zones: Vec<&str> = Vec::new();
        for p in &self.pools {
            if p.count > 0 && !zones.contains(&p.shape.zone.as_str()) {
                zones.push(p.shape.zone.as_str());
            }
        }
        zones.len()
    }

    /// Distinct zones with at least one node shape that can host one
    /// replica of this demand (spread pre-filter: a variant needing
    /// ≥ 2 zones must find capacity in ≥ 2).
    pub fn zones_fitting(&self, unit: ResourceVec) -> usize {
        let d = self.demand_of(unit);
        let mut zones: Vec<&str> = Vec::new();
        for p in &self.pools {
            if p.count > 0
                && d.fits(p.shape.capacity)
                && !zones.contains(&p.shape.zone.as_str())
            {
                zones.push(p.shape.zone.as_str());
            }
        }
        zones.len()
    }

    /// Zero every pool in `zone` (the fault actuator — shape list and
    /// indices preserved so per-shape ledgers stay aligned).  Returns
    /// the number of nodes drained; fungible pools are never drained.
    ///
    /// Note the zone is drained, not condemned: a later
    /// [`NodeInventory::retarget_with`] growth may buy nodes back into
    /// it (the zone "recovered" — inventories carry no liveness state).
    /// Fault experiments that must keep a zone dead should not run the
    /// autoscaler across the outage window.
    pub fn drain_zone(&mut self, zone: &str) -> u32 {
        if self.fungible {
            return 0;
        }
        let mut drained = 0;
        for p in &mut self.pools {
            if p.shape.zone == zone {
                drained += p.count;
                p.count = 0;
                p.bought = 0;
            }
        }
        drained
    }

    /// Node counts grouped by zone, first-appearance order — empty when
    /// every pool is unzoned (so unzoned reports stay unchanged).
    pub fn nodes_by_zone(&self) -> Vec<(String, u32)> {
        if self.pools.iter().all(|p| p.shape.zone.is_empty()) {
            return Vec::new();
        }
        let mut out: Vec<(String, u32)> = Vec::new();
        for p in &self.pools {
            match out.iter_mut().find(|(z, _)| *z == p.shape.zone) {
                Some((_, c)) => *c += p.count,
                None => out.push((p.shape.zone.clone(), p.count)),
            }
        }
        out
    }

    /// Can SOME node shape host one replica of this demand?  (Option
    /// pre-filter: variants failing this can never be placed.)
    pub fn fits_any_node(&self, unit: ResourceVec) -> bool {
        let d = self.demand_of(unit);
        self.pools.iter().any(|p| d.fits(p.shape.capacity))
    }

    /// The elastic ordering key of pool `i`: price per replica slot
    /// under the default cost weights, ties broken toward the LEAST
    /// special shape (fewest accel slots, then least memory).
    fn elastic_key(&self, i: usize) -> (f64, f64, f64) {
        let c = self.pools[i].shape.capacity;
        (
            c.weighted(CostWeights::default()) / Self::slots_of(&self.pools[i].shape) as f64,
            c.accel_slots,
            c.memory_gb,
        )
    }

    /// Index of the elastic shape — the cheapest per replica slot under
    /// the default cost weights, with price ties broken toward the
    /// LEAST special shape (fewest accel slots, then least memory, then
    /// listing order): under the CPU-only default weights every
    /// integer-core shape prices its slots at 1.0, and the autoscaler
    /// must never buy/sell accelerator nodes as the elastic shape just
    /// because they were listed first.  [`NodeInventory::retarget`]
    /// grows and shrinks this shape only.
    pub fn elastic_idx(&self) -> usize {
        let mut best = 0usize;
        let mut best_key = (f64::MAX, f64::MAX, f64::MAX);
        for i in 0..self.pools.len() {
            let key = self.elastic_key(i);
            if key < best_key {
                best_key = key;
                best = i;
            }
        }
        best
    }

    /// The shape pressure-aware growth buys: cheapest (default-weighted
    /// price) per unit of the BINDING axis of `pressure` vs the current
    /// total capacity — accel-bound demand buys accelerator nodes, not
    /// the cheapest CPU shape.  Falls back to the elastic shape when no
    /// pool offers the binding axis at all.
    fn buy_shape_for(&self, pressure: ResourceVec) -> usize {
        let axis = crate::fleet::autoscaler::pressure_axis(pressure, self.total_capacity());
        let axis_cap = |c: ResourceVec| match axis {
            0 => c.cpu_cores,
            1 => c.memory_gb,
            _ => c.accel_slots,
        };
        let w = CostWeights::default();
        let mut best: Option<((f64, f64, f64), usize)> = None;
        for (i, p) in self.pools.iter().enumerate() {
            let c = p.shape.capacity;
            let a = axis_cap(c);
            if a <= 0.0 {
                continue;
            }
            let key = (c.weighted(w) / a, c.accel_slots, c.memory_gb);
            if best.as_ref().is_none_or(|(bk, _)| key < *bk) {
                best = Some((key, i));
            }
        }
        best.map_or_else(|| self.elastic_idx(), |(_, i)| i)
    }

    /// Free replica slots in `zone`: capacity slots minus the replicas
    /// `occupancy` (if any) placed there.  Shape indices — not flat
    /// node indices — resolve the zone, so an occupancy recorded before
    /// a count change still reads correctly.
    fn zone_spare(&self, zone: &str, occupancy: Option<&Packing>) -> f64 {
        let cap: u32 = self
            .pools
            .iter()
            .filter(|p| p.shape.zone == zone)
            .map(|p| p.count * Self::slots_of(&p.shape))
            .sum();
        let used = occupancy.map_or(0, |pk| {
            pk.placements
                .iter()
                .filter(|pl| {
                    pk.shape_of
                        .get(pl.node)
                        .and_then(|&si| self.pools.get(si))
                        .is_some_and(|p| p.shape.zone == zone)
                })
                .count()
        });
        cap as f64 - used as f64
    }

    /// Add/remove WHOLE nodes of the elastic shape toward a replica
    /// target: growth stops at the last whole node that keeps
    /// `replica_cap ≤ target` (the cost cap is never overshot), shrink
    /// stops before `replica_cap` would fall below `target`.  A target
    /// that is a reachable cap of this inventory is converged to
    /// exactly, from any starting count; other targets land within one
    /// elastic node of it, direction-dependent.  Returns true when a
    /// count changed.
    pub fn retarget(&mut self, target: u32) -> bool {
        self.retarget_with(target, None, None)
    }

    /// [`NodeInventory::retarget`] with topology awareness: `pressure`
    /// (the fleet's per-axis demand vector) selects WHICH shape growth
    /// buys (cheapest per unit of the binding axis — see
    /// [`crate::fleet::autoscaler::pressure_axis`]), and `occupancy`
    /// (the active packing) steers shrink toward the zone with the
    /// most spare capacity — eviction lands where the fewest replicas
    /// live, which fights stickiness least.  Shrink may sell ANY shape
    /// (cheapest tier first), so special nodes a pressure burst bought
    /// are reclaimable once demand subsides.  With both `None` this is
    /// exactly the classic elastic-shape retarget.
    pub fn retarget_with(
        &mut self,
        target: u32,
        pressure: Option<ResourceVec>,
        occupancy: Option<&Packing>,
    ) -> bool {
        if self.pools.is_empty() {
            return false;
        }
        let mut changed = false;

        // ---- grow: the pressure-selected (default: elastic) shape ----
        let buy = match pressure {
            Some(pr) => self.buy_shape_for(pr),
            None => self.elastic_idx(),
        };
        let slots = Self::slots_of(&self.pools[buy].shape);
        while self.replica_cap() + slots <= target {
            self.pools[buy].count += 1;
            self.pools[buy].bought += 1;
            changed = true;
        }

        // ---- shrink: cheapest tier first, most-spare zone first ----
        // The elastic (cheapest, least special) tier is always
        // sellable, exactly as before; specialer shapes sell only what
        // pressure-aware growth BOUGHT (`NodePool::bought`) — so an
        // accel burst's purchases are reclaimed once demand subsides
        // (no permanent cost ratchet) while an operator's fixed
        // accelerator nodes never leave the pool.  Within the order,
        // the zone with the most spare capacity drains first; the
        // ranking is frozen at entry (pre-sale occupancy and capacity
        // — precomputed once): re-ranking after every sale would
        // alternate zones and evict occupied nodes for no reason,
        // exactly the churn stickiness exists to avoid.
        let ekey = self.elastic_key(self.elastic_idx());
        let spare: Vec<f64> = self
            .pools
            .iter()
            .map(|p| self.zone_spare(&p.shape.zone, occupancy))
            .collect();
        let mut sellable: Vec<usize> = (0..self.pools.len())
            .filter(|&i| {
                self.pools[i].count > 0
                    && (self.elastic_key(i) == ekey || self.pools[i].bought > 0)
            })
            .collect();
        sellable.sort_by(|&a, &b| {
            let (ka, kb) = (self.elastic_key(a), self.elastic_key(b));
            ka.partial_cmp(&kb)
                .unwrap()
                .then(spare[b].partial_cmp(&spare[a]).unwrap())
                .then(a.cmp(&b)) // ties: listing order
        });
        for i in sellable {
            let elastic_tier = self.elastic_key(i) == ekey;
            let sl = Self::slots_of(&self.pools[i].shape);
            while self.pools[i].count > 0
                && (elastic_tier || self.pools[i].bought > 0)
                && self.replica_cap() >= target + sl
            {
                self.pools[i].count -= 1;
                self.pools[i].bought = self.pools[i].bought.saturating_sub(1);
                changed = true;
            }
        }
        changed
    }

    /// Structural validation: at least one shape, nonzero counts,
    /// finite non-negative capacities with ≥ 1 CPU core (a node that
    /// cannot host a single 1-core replica is dead weight), non-blank
    /// names, zone labels without surrounding whitespace.
    pub fn validate(&self) -> Result<(), String> {
        if self.pools.is_empty() {
            return Err("node inventory has no shapes".into());
        }
        for p in &self.pools {
            let name = &p.shape.name;
            if name.trim().is_empty() {
                return Err("node shape with a blank name".into());
            }
            if p.count == 0 {
                return Err(format!("node shape {name}: zero count"));
            }
            let c = p.shape.capacity;
            if !c.is_finite() {
                return Err(format!("node shape {name}: non-finite capacity"));
            }
            if !c.non_negative() {
                return Err(format!("node shape {name}: negative capacity"));
            }
            if c.cpu_cores < 1.0 {
                return Err(format!("node shape {name}: needs >= 1 cpu core"));
            }
            if p.shape.zone.trim() != p.shape.zone {
                return Err(format!("node shape {name}: zone has surrounding whitespace"));
            }
        }
        Ok(())
    }

    /// First-fit-decreasing placement of every replica onto the nodes.
    ///
    /// Items expand to one unit per replica and are placed largest
    /// first (accel, then cpu, then memory — scarcest axis first);
    /// nodes are visited accel-poorest-first so CPU-only replicas fill
    /// plain nodes before touching accelerator ones.  `None` when some
    /// replica fits no remaining capacity.  Deterministic.
    pub fn pack(&self, items: &[PackItem]) -> Option<Packing> {
        self.pack_sticky(items, None, &[])
    }

    /// [`NodeInventory::pack`] with topology awareness.
    ///
    /// * `prev` — the previous packing: a keep-in-place first pass pins
    ///   every replica whose old node (same shape, same ordinal) still
    ///   has room for its new demand; only displaced/new replicas run
    ///   the FFD pass, which minimizes moves between consecutive
    ///   packings ([`Packing::moved_from`] counts what did move).
    /// * `spread` — per-member zone-spread flags (indexed by
    ///   `PackItem::member`; missing entries mean false).  When the
    ///   inventory spans ≥ 2 zones, a flagged member's replicas are
    ///   placed across zones (the FFD pass prefers zones the stage does
    ///   not occupy yet; keep-in-place retains a zone-diverse subset
    ///   first) and the packing is REJECTED if any flagged stage with
    ///   replicas ends up single-zoned — losing one zone must never
    ///   take a spread member below its one-replica-per-stage floor.
    ///
    /// The fallback policy every consumer of sticky packing shares:
    /// sticky first (keep replicas where they are), plain FFD when
    /// stickiness cannot pack — stickiness is an optimization, never a
    /// new way to reject a packable configuration.  Spread flags apply
    /// to both attempts.
    pub fn pack_prefer_sticky(
        &self,
        items: &[PackItem],
        prev: Option<&Packing>,
        spread: &[bool],
    ) -> Option<Packing> {
        match self.pack_sticky(items, prev, spread) {
            Some(p) => Some(p),
            // a fresh retry only differs when there was a prev to stick to
            None if prev.is_some() => self.pack_sticky(items, None, spread),
            None => None,
        }
    }

    /// With `prev = None` and no spread flags this is byte-identical to
    /// the plain [`NodeInventory::pack`].
    pub fn pack_sticky(
        &self,
        items: &[PackItem],
        prev: Option<&Packing>,
        spread: &[bool],
    ) -> Option<Packing> {
        let mut shape_of = Vec::new();
        for (si, pool) in self.pools.iter().enumerate() {
            for _ in 0..pool.count {
                shape_of.push(si);
            }
        }
        // Node visit order: scarce (accel-rich, then big) nodes last.
        let mut order: Vec<usize> = (0..shape_of.len()).collect();
        order.sort_by(|&a, &b| {
            let ca = self.pools[shape_of[a]].shape.capacity;
            let cb = self.pools[shape_of[b]].shape.capacity;
            ca.accel_slots
                .partial_cmp(&cb.accel_slots)
                .unwrap()
                .then(ca.cpu_cores.partial_cmp(&cb.cpu_cores).unwrap())
                .then(ca.memory_gb.partial_cmp(&cb.memory_gb).unwrap())
                .then(a.cmp(&b))
        });

        let spread_zones = self.distinct_zones() >= 2;
        let is_spread = |m: usize| spread_zones && spread.get(m).copied().unwrap_or(false);

        let mut used = vec![ResourceVec::ZERO; shape_of.len()];
        let mut placements: Vec<Placement> = Vec::new();
        let mut remaining: Vec<u32> = items.iter().map(|it| it.replicas).collect();
        // Zones already hosting each spread (member, stage).
        let mut key_zones: HashMap<(usize, usize), Vec<String>> = HashMap::new();

        // ---- keep-in-place pass -------------------------------------
        if let Some(prev) = prev {
            let map = map_nodes(&prev.shape_of, &shape_of);
            let mut held: HashMap<(usize, usize), Vec<usize>> = HashMap::new();
            for p in &prev.placements {
                if let Some(ni) = map[p.node] {
                    held.entry((p.member, p.stage)).or_default().push(ni);
                }
            }
            for (ii, it) in items.iter().enumerate() {
                let Some(cands) = held.get_mut(&(it.member, it.stage)) else { continue };
                if is_spread(it.member) {
                    // zone-diverse subset first: when a shrink keeps
                    // only some old replicas, keep one per zone before
                    // any repeat, so the spread survives the shrink
                    let mut seen: Vec<&str> = Vec::new();
                    let mut firsts = Vec::new();
                    let mut rest = Vec::new();
                    for &ni in cands.iter() {
                        let z = self.pools[shape_of[ni]].shape.zone.as_str();
                        if seen.contains(&z) {
                            rest.push(ni);
                        } else {
                            seen.push(z);
                            firsts.push(ni);
                        }
                    }
                    firsts.extend(rest);
                    *cands = firsts;
                }
                let d = self.demand_of(it.unit);
                let mut kept = 0u32;
                for &ni in cands.iter() {
                    if kept >= remaining[ii] {
                        break;
                    }
                    if used[ni].add(d).fits(self.pools[shape_of[ni]].shape.capacity) {
                        used[ni] = used[ni].add(d);
                        placements.push(Placement {
                            member: it.member,
                            stage: it.stage,
                            node: ni,
                        });
                        if is_spread(it.member) {
                            let z = self.pools[shape_of[ni]].shape.zone.clone();
                            let e = key_zones.entry((it.member, it.stage)).or_default();
                            if !e.contains(&z) {
                                e.push(z);
                            }
                        }
                        kept += 1;
                    }
                }
                remaining[ii] -= kept;
            }
        }

        // ---- FFD pass for displaced/new replicas --------------------
        let mut units: Vec<(usize, ResourceVec)> = Vec::new();
        for (ii, it) in items.iter().enumerate() {
            let d = self.demand_of(it.unit);
            for _ in 0..remaining[ii] {
                units.push((ii, d));
            }
        }
        units.sort_by(|a, b| {
            b.1.accel_slots
                .partial_cmp(&a.1.accel_slots)
                .unwrap()
                .then(b.1.cpu_cores.partial_cmp(&a.1.cpu_cores).unwrap())
                .then(b.1.memory_gb.partial_cmp(&a.1.memory_gb).unwrap())
                .then(a.0.cmp(&b.0))
        });
        for (ii, d) in units {
            let it = &items[ii];
            let fits = |ni: usize| used[ni].add(d).fits(self.pools[shape_of[ni]].shape.capacity);
            let node = if is_spread(it.member) {
                // prefer a zone this stage does not occupy yet
                let zones = key_zones.entry((it.member, it.stage)).or_default();
                order
                    .iter()
                    .copied()
                    .find(|&ni| {
                        !zones.contains(&self.pools[shape_of[ni]].shape.zone) && fits(ni)
                    })
                    .or_else(|| order.iter().copied().find(|&ni| fits(ni)))?
            } else {
                order.iter().copied().find(|&ni| fits(ni))?
            };
            used[node] = used[node].add(d);
            placements.push(Placement { member: it.member, stage: it.stage, node });
            if is_spread(it.member) {
                let z = self.pools[shape_of[node]].shape.zone.clone();
                let e = key_zones.entry((it.member, it.stage)).or_default();
                if !e.contains(&z) {
                    e.push(z);
                }
            }
        }

        let packing = Packing { shape_of, used, placements };

        // ---- spread validation --------------------------------------
        if spread_zones {
            let zones = packing.zones_by_key(self);
            for it in items {
                if it.replicas > 0 && is_spread(it.member) {
                    let n = zones.get(&(it.member, it.stage)).map_or(0, Vec::len);
                    if n < 2 {
                        return None; // single-zoned spread stage: rejected
                    }
                }
            }
        }
        Some(packing)
    }

    /// The incremental repack: when the caller knows WHICH members'
    /// configurations changed since `prev` was packed (`changed[i]`;
    /// missing entries mean CHANGED — only an explicit `false`
    /// retains), unchanged members' replicas are retained VERBATIM on
    /// their previous nodes — a retained occupancy index rebuilt in
    /// O(retained replicas), no candidate search, no FFD over the
    /// ~1000-node pool — and only the changed members run the sticky
    /// keep-in-place + FFD machinery against it.
    ///
    /// Answers `None` — callers fall back to
    /// [`NodeInventory::pack_prefer_sticky`], so declining is never a
    /// new way to reject a packable configuration — whenever the
    /// retained occupancy cannot be reconstructed exactly: a retained
    /// replica's previous node vanished, an "unchanged" member's
    /// replica counts disagree with `prev` (the caller's diff was
    /// wrong), a changed member's replicas no longer fit, or a spread
    /// floor would be violated.  When it answers `Some`, the packing is
    /// valid for this inventory and every unchanged member has moved
    /// nothing.  Retention needs no capacity re-check: retained
    /// placements are a subset of `prev`'s per-node load with identical
    /// demands, and `prev` was valid.  Deterministic, but NOT
    /// guaranteed placement-identical to [`NodeInventory::pack_sticky`]
    /// — sticky processes members in item order and may displace an
    /// unchanged member to make room, which is exactly the O(fleet)
    /// work this path exists to skip.
    pub fn pack_delta(
        &self,
        items: &[PackItem],
        prev: &Packing,
        changed: &[bool],
        spread: &[bool],
    ) -> Option<Packing> {
        let unchanged = |m: usize| changed.get(m).is_some_and(|&c| !c);
        let mut shape_of = Vec::new();
        for (si, pool) in self.pools.iter().enumerate() {
            for _ in 0..pool.count {
                shape_of.push(si);
            }
        }
        let map = map_nodes(&prev.shape_of, &shape_of);
        // Surviving prev slots per (member, stage), in prev order —
        // the retained occupancy index.
        let mut held: HashMap<(usize, usize), Vec<usize>> = HashMap::new();
        for p in &prev.placements {
            match map[p.node] {
                Some(ni) => held.entry((p.member, p.stage)).or_default().push(ni),
                // A retained member's node vanished: the occupancy
                // cannot be reconstructed verbatim — decline.
                None if unchanged(p.member) => return None,
                None => {}
            }
        }

        let spread_zones = self.distinct_zones() >= 2;
        let is_spread = |m: usize| spread_zones && spread.get(m).copied().unwrap_or(false);

        let mut used = vec![ResourceVec::ZERO; shape_of.len()];
        let mut placements: Vec<Placement> = Vec::new();
        let mut remaining: Vec<u32> = items.iter().map(|it| it.replicas).collect();
        let mut key_zones: HashMap<(usize, usize), Vec<String>> = HashMap::new();
        let track_zone = |m: usize, s: usize, ni: usize, kz: &mut HashMap<_, Vec<String>>| {
            let z = self.pools[shape_of[ni]].shape.zone.clone();
            let e = kz.entry((m, s)).or_default();
            if !e.contains(&z) {
                e.push(z);
            }
        };

        // ---- pass 0: retain unchanged members verbatim --------------
        // (Before any changed-member placement, so a changed member's
        // fits-checks always see the full retained load.)
        for (ii, it) in items.iter().enumerate() {
            if !unchanged(it.member) || it.replicas == 0 {
                continue;
            }
            let Some(cands) = held.get(&(it.member, it.stage)) else { return None };
            if cands.len() as u32 != it.replicas {
                return None; // caller's "unchanged" diff was wrong
            }
            let d = self.demand_of(it.unit);
            for &ni in cands {
                used[ni] = used[ni].add(d);
                placements.push(Placement { member: it.member, stage: it.stage, node: ni });
                if is_spread(it.member) {
                    track_zone(it.member, it.stage, ni, &mut key_zones);
                }
            }
            remaining[ii] = 0;
        }

        // ---- pass 1: sticky keep-in-place for changed members -------
        for (ii, it) in items.iter().enumerate() {
            if unchanged(it.member) {
                continue;
            }
            let Some(cands) = held.get_mut(&(it.member, it.stage)) else { continue };
            if is_spread(it.member) {
                // zone-diverse subset first (as in pack_sticky)
                let mut seen: Vec<&str> = Vec::new();
                let mut firsts = Vec::new();
                let mut rest = Vec::new();
                for &ni in cands.iter() {
                    let z = self.pools[shape_of[ni]].shape.zone.as_str();
                    if seen.contains(&z) {
                        rest.push(ni);
                    } else {
                        seen.push(z);
                        firsts.push(ni);
                    }
                }
                firsts.extend(rest);
                *cands = firsts;
            }
            let d = self.demand_of(it.unit);
            let mut kept = 0u32;
            for &ni in cands.iter() {
                if kept >= remaining[ii] {
                    break;
                }
                if used[ni].add(d).fits(self.pools[shape_of[ni]].shape.capacity) {
                    used[ni] = used[ni].add(d);
                    placements.push(Placement { member: it.member, stage: it.stage, node: ni });
                    if is_spread(it.member) {
                        track_zone(it.member, it.stage, ni, &mut key_zones);
                    }
                    kept += 1;
                }
            }
            remaining[ii] -= kept;
        }

        // ---- pass 2: FFD for the changed remainder ------------------
        let mut units: Vec<(usize, ResourceVec)> = Vec::new();
        for (ii, it) in items.iter().enumerate() {
            let d = self.demand_of(it.unit);
            for _ in 0..remaining[ii] {
                units.push((ii, d));
            }
        }
        if !units.is_empty() {
            let mut order: Vec<usize> = (0..shape_of.len()).collect();
            order.sort_by(|&a, &b| {
                let ca = self.pools[shape_of[a]].shape.capacity;
                let cb = self.pools[shape_of[b]].shape.capacity;
                ca.accel_slots
                    .partial_cmp(&cb.accel_slots)
                    .unwrap()
                    .then(ca.cpu_cores.partial_cmp(&cb.cpu_cores).unwrap())
                    .then(ca.memory_gb.partial_cmp(&cb.memory_gb).unwrap())
                    .then(a.cmp(&b))
            });
            units.sort_by(|a, b| {
                b.1.accel_slots
                    .partial_cmp(&a.1.accel_slots)
                    .unwrap()
                    .then(b.1.cpu_cores.partial_cmp(&a.1.cpu_cores).unwrap())
                    .then(b.1.memory_gb.partial_cmp(&a.1.memory_gb).unwrap())
                    .then(a.0.cmp(&b.0))
            });
            for (ii, d) in units {
                let it = &items[ii];
                let fits =
                    |ni: usize| used[ni].add(d).fits(self.pools[shape_of[ni]].shape.capacity);
                let node = if is_spread(it.member) {
                    let zones = key_zones.entry((it.member, it.stage)).or_default();
                    order
                        .iter()
                        .copied()
                        .find(|&ni| {
                            !zones.contains(&self.pools[shape_of[ni]].shape.zone) && fits(ni)
                        })
                        .or_else(|| order.iter().copied().find(|&ni| fits(ni)))?
                } else {
                    order.iter().copied().find(|&ni| fits(ni))?
                };
                used[node] = used[node].add(d);
                placements.push(Placement { member: it.member, stage: it.stage, node });
                if is_spread(it.member) {
                    track_zone(it.member, it.stage, node, &mut key_zones);
                }
            }
        }

        let packing = Packing { shape_of, used, placements };

        // ---- spread validation (as in pack_sticky) ------------------
        if spread_zones {
            let zones = packing.zones_by_key(self);
            for it in items {
                if it.replicas > 0 && is_spread(it.member) {
                    let n = zones.get(&(it.member, it.stage)).map_or(0, Vec::len);
                    if n < 2 {
                        return None;
                    }
                }
            }
        }
        Some(packing)
    }

    // ---- text / JSON IO ---------------------------------------------------

    /// Parse `"4x(8c,32g,0a)+2x(16c,64g,1a)@east"`: `+`-separated
    /// `COUNTx(CPUc,MEMg,ACCa)[@ZONE]` terms.  `/` is accepted as the
    /// component separator too, so the [`fmt::Display`] form
    /// (`4x(8c/32g/0a)@east`) round-trips through the parser.  Shape
    /// names default to the canonical capacity string; the zone
    /// defaults to the single unnamed zone.
    pub fn parse(s: &str) -> Result<NodeInventory, String> {
        let mut pools = Vec::new();
        for term in s.split('+') {
            let term = term.trim();
            let (count, rest) = term
                .split_once('x')
                .ok_or_else(|| format!("node term {term:?}: expected COUNTx(CPUc,MEMg,ACCa)"))?;
            let count: u32 = count
                .trim()
                .parse()
                .map_err(|_| format!("node term {term:?}: bad count {count:?}"))?;
            let (rest, zone) = match rest.split_once('@') {
                Some((r, z)) => {
                    let z = z.trim();
                    if z.is_empty() {
                        return Err(format!("node term {term:?}: empty zone after '@'"));
                    }
                    (r, z.to_string())
                }
                None => (rest, String::new()),
            };
            let inner = rest
                .trim()
                .strip_prefix('(')
                .and_then(|r| r.strip_suffix(')'))
                .ok_or_else(|| format!("node term {term:?}: expected (CPUc,MEMg,ACCa)"))?;
            let parts: Vec<&str> =
                inner.split(|ch| ch == ',' || ch == '/').map(str::trim).collect();
            if parts.len() != 3 {
                return Err(format!("node term {term:?}: expected three components"));
            }
            let num = |p: &str, suffix: char| -> Result<f64, String> {
                p.strip_suffix(suffix)
                    .unwrap_or(p)
                    .trim()
                    .parse()
                    .map_err(|_| format!("node term {term:?}: bad component {p:?}"))
            };
            let capacity =
                ResourceVec::new(num(parts[0], 'c')?, num(parts[1], 'g')?, num(parts[2], 'a')?);
            pools.push(NodePool {
                shape: NodeShape { name: format!("({capacity})"), capacity, zone },
                count,
                bought: 0,
            });
        }
        let inv = NodeInventory::new(pools);
        inv.validate()?;
        Ok(inv)
    }

    /// JSON shape: `[{"shape": .., "cpu": .., "mem_gb": .., "accel": ..,
    /// "count": .., "zone": ..}, ..]` (embedded as the fleet spec's
    /// `nodes` field; `zone` is optional and omitted when unzoned).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.pools
                .iter()
                .map(|p| {
                    let mut j = Json::obj()
                        .set("shape", p.shape.name.clone())
                        .set("cpu", p.shape.capacity.cpu_cores)
                        .set("mem_gb", p.shape.capacity.memory_gb)
                        .set("accel", p.shape.capacity.accel_slots)
                        .set("count", p.count as usize);
                    if !p.shape.zone.is_empty() {
                        j = j.set("zone", p.shape.zone.clone());
                    }
                    j
                })
                .collect(),
        )
    }

    pub fn from_json(j: &Json) -> Result<NodeInventory, String> {
        let arr = j.as_arr().ok_or("nodes: expected an array of shapes")?;
        let mut pools = Vec::new();
        for (i, pj) in arr.iter().enumerate() {
            let name = pj
                .get("shape")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("nodes[{i}]: missing string field 'shape'"))?
                .to_string();
            let num = |field: &str| -> Result<f64, String> {
                pj.get(field)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("nodes[{i}] ({name}): missing numeric '{field}'"))
            };
            let capacity = ResourceVec::new(num("cpu")?, num("mem_gb")?, num("accel")?);
            let count = pj
                .get("count")
                .and_then(Json::as_i64)
                .ok_or_else(|| format!("nodes[{i}] ({name}): missing numeric 'count'"))?;
            if !(0..=u32::MAX as i64).contains(&count) {
                return Err(format!("nodes[{i}] ({name}): count {count} out of u32 range"));
            }
            let zone = pj
                .get("zone")
                .and_then(Json::as_str)
                .map(str::to_string)
                .unwrap_or_default();
            pools.push(NodePool {
                shape: NodeShape { name, capacity, zone },
                count: count as u32,
                bought: 0,
            });
        }
        let inv = NodeInventory::new(pools);
        inv.validate()?;
        Ok(inv)
    }
}

impl fmt::Display for NodeInventory {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let terms: Vec<String> = self
            .pools
            .iter()
            .map(|p| {
                if p.shape.zone.is_empty() {
                    format!("{}x({})", p.count, p.shape.capacity)
                } else {
                    format!("{}x({})@{}", p.count, p.shape.capacity, p.shape.zone)
                }
            })
            .collect();
        write!(f, "{}", terms.join("+"))
    }
}

/// The pack items of a joint fleet configuration: one per (member,
/// stage), `replicas` copies of the stage's per-replica demand.
pub fn config_demands(configs: &[&PipelineConfig]) -> Vec<PackItem> {
    let mut items = Vec::new();
    for (m, cfg) in configs.iter().enumerate() {
        for (s, sc) in cfg.stages.iter().enumerate() {
            items.push(PackItem {
                member: m,
                stage: s,
                unit: sc.resources,
                replicas: sc.replicas,
            });
        }
    }
    items
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{check, prop_assert};

    fn item(member: usize, unit: ResourceVec, replicas: u32) -> PackItem {
        PackItem { member, stage: 0, unit, replicas }
    }

    #[test]
    fn parse_roundtrips_and_validates() {
        let inv = NodeInventory::parse("4x(8c,32g,0a)+2x(16c,64g,1a)").unwrap();
        assert_eq!(inv.pools.len(), 2);
        assert_eq!(inv.n_nodes(), 6);
        assert_eq!(inv.replica_cap(), 4 * 8 + 2 * 16);
        assert_eq!(inv.to_string(), "4x(8c/32g/0a)+2x(16c/64g/1a)");
        assert!(!inv.is_fungible());
        // the Display form round-trips through the parser ('/' accepted
        // alongside ','), and so does the JSON form
        assert_eq!(NodeInventory::parse(&inv.to_string()).unwrap(), inv);
        let back = NodeInventory::from_json(&inv.to_json()).unwrap();
        assert_eq!(inv, back);
        // rejects garbage
        assert!(NodeInventory::parse("").is_err());
        assert!(NodeInventory::parse("4x8c,32g,0a").is_err());
        assert!(NodeInventory::parse("x(8c,32g,0a)").is_err());
        assert!(NodeInventory::parse("0x(8c,32g,0a)").is_err(), "zero count");
        assert!(NodeInventory::parse("2x(0c,32g,0a)").is_err(), "sub-1-core node");
        assert!(NodeInventory::parse("2x(8c,-1g,0a)").is_err(), "negative capacity");
    }

    #[test]
    fn zones_parse_display_and_roundtrip() {
        let inv = NodeInventory::parse("2x(8c,32g,0a)@east+2x(8c,32g,0a)@west").unwrap();
        assert_eq!(inv.pools[0].shape.zone, "east");
        assert_eq!(inv.pools[1].shape.zone, "west");
        assert_eq!(inv.distinct_zones(), 2);
        assert_eq!(inv.to_string(), "2x(8c/32g/0a)@east+2x(8c/32g/0a)@west");
        assert_eq!(NodeInventory::parse(&inv.to_string()).unwrap(), inv);
        assert_eq!(NodeInventory::from_json(&inv.to_json()).unwrap(), inv);
        assert_eq!(
            inv.nodes_by_zone(),
            vec![("east".to_string(), 2), ("west".to_string(), 2)]
        );
        // unzoned inventories report no zone breakdown and one zone
        let plain = NodeInventory::parse("4x(8c,32g,0a)").unwrap();
        assert_eq!(plain.distinct_zones(), 1);
        assert!(plain.nodes_by_zone().is_empty());
        // empty zone after '@' rejected
        assert!(NodeInventory::parse("2x(8c,32g,0a)@").is_err());
    }

    #[test]
    fn drain_zone_zeroes_pools_and_preserves_indices() {
        let mut inv = NodeInventory::parse("2x(8c,32g,0a)@east+3x(8c,32g,0a)@west").unwrap();
        assert_eq!(inv.drain_zone("east"), 2);
        assert_eq!(inv.pools.len(), 2, "shape list preserved");
        assert_eq!(inv.pools[0].count, 0);
        assert_eq!(inv.pools[1].count, 3);
        assert_eq!(inv.replica_cap(), 24);
        assert_eq!(inv.distinct_zones(), 1, "dead zone no longer counted");
        assert_eq!(inv.drain_zone("nowhere"), 0);
        // fungible pools are never drained
        let mut f = NodeInventory::fungible(4);
        assert_eq!(f.drain_zone(""), 0);
        assert_eq!(f.replica_cap(), 4);
    }

    #[test]
    fn zones_fitting_counts_capable_zones() {
        let inv =
            NodeInventory::parse("2x(8c,32g,0a)@east+2x(8c,32g,0a)@west+1x(16c,64g,2a)@east")
                .unwrap();
        assert_eq!(inv.zones_fitting(ResourceVec::cpu(1.0)), 2);
        assert_eq!(inv.zones_fitting(ResourceVec::new(8.0, 4.0, 1.0)), 1, "accel only east");
        assert_eq!(inv.zones_fitting(ResourceVec::new(32.0, 4.0, 0.0)), 0);
    }

    #[test]
    fn fungible_embedding_is_the_scalar_budget_check() {
        let inv = NodeInventory::fungible(4);
        assert!(inv.is_fungible());
        assert_eq!(inv.replica_cap(), 4);
        // demands are coerced to one slot regardless of their vector
        let heavy = ResourceVec::new(16.0, 64.0, 2.0);
        assert_eq!(inv.demand_of(heavy), ResourceVec::cpu(1.0));
        assert!(inv.fits_any_node(heavy));
        // packs iff Σ replicas ≤ n, exactly the old budget rule
        assert!(inv.pack(&[item(0, heavy, 2), item(1, ResourceVec::cpu(1.0), 2)]).is_some());
        assert!(inv.pack(&[item(0, heavy, 3), item(1, ResourceVec::cpu(1.0), 2)]).is_none());
    }

    #[test]
    fn accel_replicas_land_only_on_accel_nodes() {
        let inv = NodeInventory::parse("2x(8c,32g,0a)+1x(16c,64g,2a)").unwrap();
        let items = [
            item(0, ResourceVec::new(8.0, 2.0, 1.0), 2), // accel-demanding
            item(1, ResourceVec::new(1.0, 1.0, 0.0), 6), // cpu-only
        ];
        let p = inv.pack(&items).unwrap();
        assert!(p.valid_for(&inv));
        for pl in &p.placements {
            if pl.member == 0 {
                assert_eq!(inv.pools[p.shape_of[pl.node]].shape.capacity.accel_slots, 2.0);
            }
        }
        // cpu-only replicas prefer the plain nodes (accel-poorest first)
        let per_shape = p.nodes_used_per_shape(2);
        assert!(per_shape[0] >= 1, "plain nodes host the cpu-only replicas: {per_shape:?}");
        // a cpu-only pool cannot host the accel demand at all
        let plain = NodeInventory::parse("8x(8c,32g,0a)").unwrap();
        assert!(!plain.fits_any_node(ResourceVec::new(8.0, 2.0, 1.0)));
        assert!(plain.pack(&items).is_none());
    }

    #[test]
    fn elastic_shape_never_ties_onto_special_hardware() {
        // both shapes price slots at 1.0 under the CPU-only default
        // weights — the accel shape must lose the tie even when listed
        // first, and listing order must not matter
        let accel_first = NodeInventory::parse("2x(16c,64g,2a)+4x(8c,32g,0a)").unwrap();
        assert_eq!(accel_first.elastic_idx(), 1, "plain shape wins the price tie");
        let plain_first = NodeInventory::parse("4x(8c,32g,0a)+2x(16c,64g,2a)").unwrap();
        assert_eq!(plain_first.elastic_idx(), 0);
    }

    #[test]
    fn retarget_moves_whole_elastic_nodes_convergently() {
        let base = NodeInventory::parse("2x(4c,16g,0a)+1x(16c,64g,2a)").unwrap();
        assert_eq!(base.elastic_idx(), 0, "4c shape is cheapest per slot");
        assert_eq!(base.replica_cap(), 24);
        // grow toward 35: adds whole 4-slot nodes, never past the target
        let mut grown = base.clone();
        assert!(grown.retarget(35));
        assert_eq!(grown.replica_cap(), 32, "8 - 4k ≤ 35 < next whole node");
        assert_eq!(grown.pools[0].count, 4);
        // shrink back toward 10: removes whole elastic nodes while the
        // cap stays ≥ the target (the fixed big node keeps 16 slots)
        let mut shrunk = grown.clone();
        assert!(shrunk.retarget(10));
        assert_eq!(shrunk.replica_cap(), 16, "every elastic node removed, big node fixed");
        assert_eq!(shrunk.pools[0].count, 0);
        // convergence on a REACHABLE cap (16 = zero elastic nodes):
        // any path ending at the same reachable target agrees
        let mut direct = base.clone();
        direct.retarget(10);
        assert_eq!(direct, shrunk);
        let mut via_cap = grown.clone();
        via_cap.retarget(16);
        assert_eq!(via_cap, shrunk, "reachable caps converge exactly");
        // no-op when the target is already within one node
        let mut hold = base.clone();
        assert!(!hold.retarget(24));
        assert_eq!(hold, base);
    }

    #[test]
    fn retarget_shrink_evicts_from_the_most_spare_zone() {
        // same elastic shape in two zones; replicas occupy east, so a
        // shrink must sell west (most spare) first instead of the
        // arbitrary listing-order pick that fights stickiness
        let inv = NodeInventory::parse("2x(4c,16g,0a)@east+2x(4c,16g,0a)@west").unwrap();
        let items = [item(0, ResourceVec::new(4.0, 4.0, 0.0), 2)];
        let occupancy = inv.pack(&items).unwrap();
        // sanity: both replicas landed in east (first nodes in order)
        for pl in &occupancy.placements {
            assert_eq!(inv.pools[occupancy.shape_of[pl.node]].shape.zone, "east");
        }
        let mut shrunk = inv.clone();
        assert!(shrunk.retarget_with(8, None, Some(&occupancy)));
        assert_eq!(shrunk.replica_cap(), 8);
        assert_eq!(shrunk.pools[0].count, 2, "occupied east zone untouched");
        assert_eq!(shrunk.pools[1].count, 0, "spare west zone evicted");
        // without occupancy the tie goes to the lowest index (east)
        let mut blind = inv.clone();
        assert!(blind.retarget_with(8, None, None));
        assert_eq!(blind.pools[0].count, 0);
        assert_eq!(blind.pools[1].count, 2);
    }

    #[test]
    fn retarget_pressure_buys_the_binding_axis_shape() {
        // accel-bound demand must buy accelerator nodes, not the
        // cheapest CPU shape
        let base = NodeInventory::parse("2x(4c,16g,0a)+1x(16c,64g,2a)").unwrap();
        let mut accel_bound = base.clone();
        let pressure = ResourceVec::new(4.0, 8.0, 4.0); // accel 4 vs capacity 2: binds
        assert!(accel_bound.retarget_with(60, Some(pressure), None));
        assert!(accel_bound.pools[1].count > 1, "accel shape bought: {accel_bound}");
        assert_eq!(accel_bound.pools[0].count, 2, "cpu shape untouched");
        // cpu-bound demand reproduces the classic elastic buy
        let mut cpu_bound = base.clone();
        let mut classic = base.clone();
        assert!(cpu_bound.retarget_with(40, Some(ResourceVec::cpu(40.0)), None));
        assert!(classic.retarget(40));
        assert_eq!(cpu_bound, classic, "cpu pressure = classic elastic growth");
        // later plain shrinks RECLAIM the pressure-bought accel nodes
        // (no permanent cost ratchet) but never the operator's
        // provisioned one — bought-node accounting draws the line
        assert_eq!(accel_bound.pools[1].bought, accel_bound.pools[1].count - 1);
        assert!(accel_bound.retarget_with(24, None, None));
        assert_eq!(accel_bound.pools[0].count, 0, "elastic tier drains first");
        assert_eq!(accel_bound.pools[1].count, 2, "one bought node sold: {accel_bound}");
        assert!(accel_bound.retarget_with(16, None, None));
        assert_eq!(accel_bound.pools[1].count, 1, "second bought node sold");
        assert_eq!(accel_bound.pools[1].bought, 0);
        assert!(!accel_bound.retarget_with(0, None, None));
        assert_eq!(accel_bound.pools[1].count, 1, "provisioned accel node never sold");
        // ...and without any bought nodes, a fixed special shape is
        // never sold no matter how deep the shrink goes
        let mut fixed = base.clone();
        assert!(fixed.retarget_with(4, None, None));
        assert_eq!(fixed.pools[0].count, 0, "elastic tier fully drained");
        assert_eq!(fixed.pools[1].count, 1, "fixed accel node survives");
    }

    #[test]
    fn prop_pack_never_exceeds_capacity_on_any_axis() {
        check("pack respects node capacity", 120, |g| {
            // random 1-3 shape inventory
            let n_shapes = g.usize(1, 4);
            let pools: Vec<NodePool> = (0..n_shapes)
                .map(|i| NodePool {
                    shape: NodeShape {
                        name: format!("s{i}"),
                        capacity: ResourceVec::new(
                            g.usize(1, 33) as f64,
                            g.usize(0, 129) as f64,
                            g.usize(0, 5) as f64,
                        ),
                        zone: String::new(),
                    },
                    count: g.usize(1, 6) as u32,
                    bought: 0,
                })
                .collect();
            let inv = NodeInventory::new(pools);
            let items: Vec<PackItem> = (0..g.usize(1, 8))
                .map(|m| {
                    item(
                        m,
                        ResourceVec::new(
                            g.usize(1, 17) as f64,
                            g.usize(0, 65) as f64,
                            g.usize(0, 3) as f64,
                        ),
                        g.usize(1, 5) as u32,
                    )
                })
                .collect();
            let total_replicas: u32 = items.iter().map(|i| i.replicas).sum();
            match inv.pack(&items) {
                None => Ok(()), // infeasible is a legal answer
                Some(p) => {
                    prop_assert(p.valid_for(&inv), "a node exceeded capacity on some axis")?;
                    prop_assert(
                        p.placements.len() == total_replicas as usize,
                        "every replica must be placed exactly once",
                    )?;
                    // accel-demanding replicas sit on accel-capable nodes
                    for pl in &p.placements {
                        let it = items.iter().find(|i| i.member == pl.member).unwrap();
                        if it.unit.accel_slots > 0.0 {
                            prop_assert(
                                inv.pools[p.shape_of[pl.node]].shape.capacity.accel_slots
                                    >= it.unit.accel_slots,
                                "accel replica on an accel-less node",
                            )?;
                        }
                    }
                    Ok(())
                }
            }
        });
    }

    #[test]
    fn pack_is_deterministic() {
        let inv = NodeInventory::parse("3x(8c,32g,0a)+2x(16c,64g,2a)").unwrap();
        let items = [
            item(0, ResourceVec::new(2.0, 4.0, 0.0), 5),
            item(1, ResourceVec::new(8.0, 16.0, 1.0), 2),
            item(2, ResourceVec::new(1.0, 2.0, 0.0), 7),
        ];
        assert_eq!(inv.pack(&items), inv.pack(&items));
    }

    #[test]
    fn sticky_pack_keeps_unchanged_items_in_place() {
        let inv = NodeInventory::parse("3x(8c,32g,0a)+2x(16c,64g,2a)").unwrap();
        let items = [
            item(0, ResourceVec::new(2.0, 4.0, 0.0), 5),
            item(1, ResourceVec::new(8.0, 16.0, 1.0), 2),
        ];
        let prev = inv.pack(&items).unwrap();
        // identical demand: every replica keeps its node — zero moves
        let again = inv.pack_sticky(&items, Some(&prev), &[]).unwrap();
        assert!(again.moved_from(&prev).is_empty(), "unchanged config must not move");
        // one member grows; the others stay put
        let grown = [
            item(0, ResourceVec::new(2.0, 4.0, 0.0), 7),
            item(1, ResourceVec::new(8.0, 16.0, 1.0), 2),
        ];
        let sticky = inv.pack_sticky(&grown, Some(&prev), &[]).unwrap();
        let moves = sticky.moved_from(&prev);
        assert_eq!(moves.len(), 2, "only the two NEW replicas count as moves: {moves:?}");
        assert!(moves.iter().all(|m| m.member == 0));
        assert!(sticky.valid_for(&inv));
    }

    #[test]
    fn moved_from_maps_node_identity_across_count_changes() {
        let inv = NodeInventory::parse("2x(4c,16g,0a)+1x(16c,64g,2a)").unwrap();
        let items = [item(0, ResourceVec::new(4.0, 4.0, 0.0), 2)];
        let prev = inv.pack(&items).unwrap();
        // grow the elastic shape: old nodes keep (shape, ordinal)
        // identity, so a sticky re-pack still reports zero moves
        let mut bigger = inv.clone();
        bigger.retarget(32);
        let sticky = bigger.pack_sticky(&items, Some(&prev), &[]).unwrap();
        assert!(sticky.moved_from(&prev).is_empty(), "growth must not displace replicas");
        // shrinking away the occupied nodes forces moves
        let smaller = NodeInventory::parse("1x(16c,64g,2a)").unwrap();
        let repacked = smaller.pack(&items).unwrap();
        assert_eq!(repacked.moved_from(&prev).len(), 2, "stranded replicas moved");
    }

    #[test]
    fn spread_pack_spans_zones_and_rejects_single_zone_stages() {
        let inv = NodeInventory::parse("2x(8c,32g,0a)@east+2x(8c,32g,0a)@west").unwrap();
        let items = [item(0, ResourceVec::new(2.0, 2.0, 0.0), 4)];
        // unflagged: FFD fills east first — single zone is fine
        let plain = inv.pack_sticky(&items, None, &[]).unwrap();
        assert!(plain.valid_for(&inv));
        // flagged: replicas must span ≥ 2 zones, and survive any kill
        let spread = inv.pack_sticky(&items, None, &[true]).unwrap();
        for zone in ["east", "west"] {
            let surv = spread.survivors_of_zone(&inv, zone);
            assert!(
                surv.get(&(0, 0)).copied().unwrap_or(0) >= 1,
                "losing {zone} must leave a replica"
            );
        }
        // a single replica cannot spread: rejected for flagged members
        let single = [item(0, ResourceVec::new(2.0, 2.0, 0.0), 1)];
        assert!(inv.pack_sticky(&single, None, &[true]).is_none());
        assert!(inv.pack_sticky(&single, None, &[]).is_some(), "unflagged unaffected");
        // spread is vacuous on a single-zone inventory
        let one_zone = NodeInventory::parse("4x(8c,32g,0a)@east").unwrap();
        assert!(one_zone.pack_sticky(&single, None, &[true]).is_some());
    }

    #[test]
    fn config_demands_expand_stages() {
        use crate::optimizer::ip::{PipelineConfig, StageConfig};
        let cfg = PipelineConfig {
            stages: vec![
                StageConfig {
                    variant_idx: 0,
                    variant_key: "a".into(),
                    batch: 1,
                    replicas: 2,
                    cost: 2.0,
                    accuracy: 50.0,
                    latency: 0.1,
                    resources: ResourceVec::cpu(1.0),
                },
                StageConfig {
                    variant_idx: 1,
                    variant_key: "b".into(),
                    batch: 2,
                    replicas: 1,
                    cost: 8.0,
                    accuracy: 60.0,
                    latency: 0.2,
                    resources: ResourceVec::new(8.0, 2.0, 1.0),
                },
            ],
            pas: 30.0,
            cost: 10.0,
            batch_sum: 3,
            objective: 0.0,
            latency_e2e: 0.3,
            resources: ResourceVec::new(10.0, 4.0, 1.0),
        };
        let items = config_demands(&[&cfg]);
        assert_eq!(items.len(), 2);
        assert_eq!(items[0].replicas, 2);
        assert_eq!(items[1].unit.accel_slots, 1.0);
        assert_eq!(items[1].stage, 1);
    }
}
