//! Heterogeneous node shapes and the replica bin-packer.
//!
//! The fleet pool stops being a fungible replica count and becomes a
//! [`NodeInventory`]: counts of [`NodeShape`]s, each offering a
//! capacity [`ResourceVec`].  Feasibility of a fleet configuration is
//! then a bin-packing question — every replica's demand vector must be
//! placed on some node without exceeding that node's capacity on ANY
//! axis — answered by [`NodeInventory::pack`] with a first-fit-
//! decreasing heuristic (items sorted scarcest-resource-first, nodes
//! visited accel-poorest-first so CPU-only replicas never squat
//! accelerator slots).
//!
//! **Scalar embedding.**  [`NodeInventory::fungible`] reproduces the
//! pre-refactor pool exactly: `n` unit nodes of one `1c/0g/0a` shape,
//! with every replica's demand coerced to one CPU slot
//! ([`NodeInventory::demand_of`]).  Packing then succeeds iff the
//! replica count fits the pool — byte-identical to the old scalar
//! budget check — which is how the regression tests pin the refactor.
//!
//! **Elasticity.**  [`NodeInventory::retarget`] adds/removes WHOLE
//! nodes of the elastic (cheapest-per-slot) shape toward a replica
//! target: growth never overshoots the target (the autoscaler's cost
//! cap holds), shrink never undershoots it.  For a target that is
//! itself a REACHABLE cap of the inventory (some whole-node count
//! yields exactly that replica cap), `retarget` converges to that cap
//! from any starting count — and reachable caps are the only targets
//! the control plane ships: the adapter resolves the autoscaler's raw
//! proposal first and the drivers forward the adapter's resolved cap,
//! which keeps the controller's inventory view and the fleet core's
//! actuated one in lockstep without shipping node lists.  (Arbitrary
//! raw targets are direction-dependent: grow parks in `(t−slots, t]`,
//! shrink in `[t, t+slots)`.)

use std::fmt;

use crate::optimizer::ip::PipelineConfig;
use crate::resources::{CostWeights, ResourceVec};
use crate::util::json::Json;

/// One node hardware variant: a name and its capacity vector.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeShape {
    pub name: String,
    pub capacity: ResourceVec,
}

/// `count` nodes of one shape.
#[derive(Debug, Clone, PartialEq)]
pub struct NodePool {
    pub shape: NodeShape,
    pub count: u32,
}

/// The whole cluster: counts of heterogeneous node shapes.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeInventory {
    pub pools: Vec<NodePool>,
    /// Scalar-embedding mode: demands are coerced to one CPU slot each
    /// (see [`NodeInventory::demand_of`]).
    fungible: bool,
}

/// One replica group to place: `replicas` copies of a `unit` demand,
/// tagged with the (member, stage) they belong to.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PackItem {
    pub member: usize,
    pub stage: usize,
    /// Per-replica demand vector.
    pub unit: ResourceVec,
    pub replicas: u32,
}

/// Where one replica landed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Placement {
    pub member: usize,
    pub stage: usize,
    /// Flat node index (see [`Packing::shape_of`]).
    pub node: usize,
}

/// A successful packing: per-node occupancy plus one placement per
/// replica.
#[derive(Debug, Clone, PartialEq)]
pub struct Packing {
    /// Pool (shape) index of each flat node.
    pub shape_of: Vec<usize>,
    /// Resources in use on each flat node (≤ that node's capacity on
    /// every axis — `valid_for` re-checks it).
    pub used: Vec<ResourceVec>,
    /// One entry per placed replica.
    pub placements: Vec<Placement>,
}

impl Packing {
    /// Nodes hosting at least one replica.
    pub fn nodes_used(&self) -> usize {
        let mut used = vec![false; self.shape_of.len()];
        for p in &self.placements {
            used[p.node] = true;
        }
        used.iter().filter(|&&b| b).count()
    }

    /// Nodes hosting at least one replica, per shape index.
    pub fn nodes_used_per_shape(&self, n_shapes: usize) -> Vec<u32> {
        let mut used = vec![false; self.shape_of.len()];
        for p in &self.placements {
            used[p.node] = true;
        }
        let mut out = vec![0u32; n_shapes];
        for (ni, &u) in used.iter().enumerate() {
            if u {
                out[self.shape_of[ni]] += 1;
            }
        }
        out
    }

    /// Every node's occupancy fits its shape's capacity on every axis.
    pub fn valid_for(&self, inv: &NodeInventory) -> bool {
        self.shape_of.len() == self.used.len()
            && self
                .used
                .iter()
                .zip(&self.shape_of)
                .all(|(u, &si)| u.fits(inv.pools[si].shape.capacity))
    }
}

impl NodeInventory {
    /// A heterogeneous inventory.  Call [`NodeInventory::validate`]
    /// before trusting externally-supplied shapes.
    pub fn new(pools: Vec<NodePool>) -> NodeInventory {
        NodeInventory { pools, fungible: false }
    }

    /// The scalar embedding: `n` unit nodes ("slot" shape, one CPU
    /// core), every replica demand coerced to one slot.  Packing is
    /// then exactly the pre-refactor `Σ replicas ≤ n` budget check.
    pub fn fungible(n: u32) -> NodeInventory {
        NodeInventory {
            pools: vec![NodePool {
                shape: NodeShape { name: "slot".into(), capacity: ResourceVec::cpu(1.0) },
                count: n,
            }],
            fungible: true,
        }
    }

    pub fn is_fungible(&self) -> bool {
        self.fungible
    }

    /// The demand a replica presents to this inventory: its full vector
    /// on real node pools, one CPU slot in the fungible embedding.
    pub fn demand_of(&self, unit: ResourceVec) -> ResourceVec {
        if self.fungible {
            ResourceVec::cpu(1.0)
        } else {
            unit
        }
    }

    /// Max unit (1-core) replicas one node of `shape` can host — every
    /// replica demands at least one CPU core, so the CPU axis caps the
    /// slot count.
    fn slots_of(shape: &NodeShape) -> u32 {
        ((shape.capacity.cpu_cores + 1e-9).floor() as u32).max(1)
    }

    /// Upper bound on the replicas this inventory can hold — the
    /// replica-denominated pool size (`budget`) the solvers and the
    /// autoscaler reason in.  Exact for the fungible embedding.
    pub fn replica_cap(&self) -> u32 {
        self.pools.iter().map(|p| p.count * Self::slots_of(&p.shape)).sum()
    }

    pub fn n_nodes(&self) -> u32 {
        self.pools.iter().map(|p| p.count).sum()
    }

    /// Σ `count × capacity` across shapes.
    pub fn total_capacity(&self) -> ResourceVec {
        self.pools
            .iter()
            .fold(ResourceVec::ZERO, |a, p| a.add(p.shape.capacity.scale(p.count as f64)))
    }

    /// Can SOME node shape host one replica of this demand?  (Option
    /// pre-filter: variants failing this can never be placed.)
    pub fn fits_any_node(&self, unit: ResourceVec) -> bool {
        let d = self.demand_of(unit);
        self.pools.iter().any(|p| d.fits(p.shape.capacity))
    }

    /// Index of the elastic shape — the cheapest per replica slot under
    /// the default cost weights, with price ties broken toward the
    /// LEAST special shape (fewest accel slots, then least memory, then
    /// listing order): under the CPU-only default weights every
    /// integer-core shape prices its slots at 1.0, and the autoscaler
    /// must never buy/sell accelerator nodes as the elastic shape just
    /// because they were listed first.  [`NodeInventory::retarget`]
    /// grows and shrinks this shape only.
    pub fn elastic_idx(&self) -> usize {
        let w = CostWeights::default();
        let mut best = 0usize;
        let mut best_key = (f64::MAX, f64::MAX, f64::MAX);
        for (i, p) in self.pools.iter().enumerate() {
            let c = p.shape.capacity;
            let rate = c.weighted(w) / Self::slots_of(&p.shape) as f64;
            let key = (rate, c.accel_slots, c.memory_gb);
            if key < best_key {
                best_key = key;
                best = i;
            }
        }
        best
    }

    /// Add/remove WHOLE nodes of the elastic shape toward a replica
    /// target: growth stops at the last whole node that keeps
    /// `replica_cap ≤ target` (the cost cap is never overshot), shrink
    /// stops before `replica_cap` would fall below `target`.  A target
    /// that is a reachable cap of this inventory is converged to
    /// exactly, from any starting count (what the control plane relies
    /// on — see the module docs); other targets land within one
    /// elastic node of it, direction-dependent.  Returns true when a
    /// count changed.
    pub fn retarget(&mut self, target: u32) -> bool {
        if self.pools.is_empty() {
            return false;
        }
        let e = self.elastic_idx();
        let slots = Self::slots_of(&self.pools[e].shape);
        let mut changed = false;
        while self.replica_cap() + slots <= target {
            self.pools[e].count += 1;
            changed = true;
        }
        while self.pools[e].count > 0 && self.replica_cap() >= target + slots {
            self.pools[e].count -= 1;
            changed = true;
        }
        changed
    }

    /// Structural validation: at least one shape, nonzero counts,
    /// finite non-negative capacities with ≥ 1 CPU core (a node that
    /// cannot host a single 1-core replica is dead weight), non-blank
    /// names.
    pub fn validate(&self) -> Result<(), String> {
        if self.pools.is_empty() {
            return Err("node inventory has no shapes".into());
        }
        for p in &self.pools {
            let name = &p.shape.name;
            if name.trim().is_empty() {
                return Err("node shape with a blank name".into());
            }
            if p.count == 0 {
                return Err(format!("node shape {name}: zero count"));
            }
            let c = p.shape.capacity;
            if !c.is_finite() {
                return Err(format!("node shape {name}: non-finite capacity"));
            }
            if !c.non_negative() {
                return Err(format!("node shape {name}: negative capacity"));
            }
            if c.cpu_cores < 1.0 {
                return Err(format!("node shape {name}: needs >= 1 cpu core"));
            }
        }
        Ok(())
    }

    /// First-fit-decreasing placement of every replica onto the nodes.
    ///
    /// Items expand to one unit per replica and are placed largest
    /// first (accel, then cpu, then memory — scarcest axis first);
    /// nodes are visited accel-poorest-first so CPU-only replicas fill
    /// plain nodes before touching accelerator ones.  `None` when some
    /// replica fits no remaining capacity.  Deterministic.
    pub fn pack(&self, items: &[PackItem]) -> Option<Packing> {
        let mut shape_of = Vec::new();
        for (si, pool) in self.pools.iter().enumerate() {
            for _ in 0..pool.count {
                shape_of.push(si);
            }
        }
        // Node visit order: scarce (accel-rich, then big) nodes last.
        let mut order: Vec<usize> = (0..shape_of.len()).collect();
        order.sort_by(|&a, &b| {
            let ca = self.pools[shape_of[a]].shape.capacity;
            let cb = self.pools[shape_of[b]].shape.capacity;
            ca.accel_slots
                .partial_cmp(&cb.accel_slots)
                .unwrap()
                .then(ca.cpu_cores.partial_cmp(&cb.cpu_cores).unwrap())
                .then(ca.memory_gb.partial_cmp(&cb.memory_gb).unwrap())
                .then(a.cmp(&b))
        });
        // Expand replicas into units, decreasing demand (FFD).
        let mut units: Vec<(usize, ResourceVec)> = Vec::new();
        for (ii, it) in items.iter().enumerate() {
            let d = self.demand_of(it.unit);
            for _ in 0..it.replicas {
                units.push((ii, d));
            }
        }
        units.sort_by(|a, b| {
            b.1.accel_slots
                .partial_cmp(&a.1.accel_slots)
                .unwrap()
                .then(b.1.cpu_cores.partial_cmp(&a.1.cpu_cores).unwrap())
                .then(b.1.memory_gb.partial_cmp(&a.1.memory_gb).unwrap())
                .then(a.0.cmp(&b.0))
        });
        let mut used = vec![ResourceVec::ZERO; shape_of.len()];
        let mut placements = Vec::with_capacity(units.len());
        for (ii, d) in units {
            let node = order.iter().copied().find(|&ni| {
                used[ni].add(d).fits(self.pools[shape_of[ni]].shape.capacity)
            })?;
            used[node] = used[node].add(d);
            placements.push(Placement { member: items[ii].member, stage: items[ii].stage, node });
        }
        Some(Packing { shape_of, used, placements })
    }

    // ---- text / JSON IO ---------------------------------------------------

    /// Parse `"4x(8c,32g,0a)+2x(16c,64g,1a)"`: `+`-separated
    /// `COUNTx(CPUc,MEMg,ACCa)` terms.  `/` is accepted as the
    /// component separator too, so the [`fmt::Display`] form
    /// (`4x(8c/32g/0a)`) round-trips through the parser.  Shape names
    /// default to the canonical capacity string.
    pub fn parse(s: &str) -> Result<NodeInventory, String> {
        let mut pools = Vec::new();
        for term in s.split('+') {
            let term = term.trim();
            let (count, rest) = term
                .split_once('x')
                .ok_or_else(|| format!("node term {term:?}: expected COUNTx(CPUc,MEMg,ACCa)"))?;
            let count: u32 = count
                .trim()
                .parse()
                .map_err(|_| format!("node term {term:?}: bad count {count:?}"))?;
            let inner = rest
                .trim()
                .strip_prefix('(')
                .and_then(|r| r.strip_suffix(')'))
                .ok_or_else(|| format!("node term {term:?}: expected (CPUc,MEMg,ACCa)"))?;
            let parts: Vec<&str> =
                inner.split(|ch| ch == ',' || ch == '/').map(str::trim).collect();
            if parts.len() != 3 {
                return Err(format!("node term {term:?}: expected three components"));
            }
            let num = |p: &str, suffix: char| -> Result<f64, String> {
                p.strip_suffix(suffix)
                    .unwrap_or(p)
                    .trim()
                    .parse()
                    .map_err(|_| format!("node term {term:?}: bad component {p:?}"))
            };
            let capacity =
                ResourceVec::new(num(parts[0], 'c')?, num(parts[1], 'g')?, num(parts[2], 'a')?);
            pools.push(NodePool {
                shape: NodeShape { name: format!("({capacity})"), capacity },
                count,
            });
        }
        let inv = NodeInventory::new(pools);
        inv.validate()?;
        Ok(inv)
    }

    /// JSON shape: `[{"shape": .., "cpu": .., "mem_gb": .., "accel": ..,
    /// "count": ..}, ..]` (embedded as the fleet spec's `nodes` field).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.pools
                .iter()
                .map(|p| {
                    Json::obj()
                        .set("shape", p.shape.name.clone())
                        .set("cpu", p.shape.capacity.cpu_cores)
                        .set("mem_gb", p.shape.capacity.memory_gb)
                        .set("accel", p.shape.capacity.accel_slots)
                        .set("count", p.count as usize)
                })
                .collect(),
        )
    }

    pub fn from_json(j: &Json) -> Result<NodeInventory, String> {
        let arr = j.as_arr().ok_or("nodes: expected an array of shapes")?;
        let mut pools = Vec::new();
        for (i, pj) in arr.iter().enumerate() {
            let name = pj
                .get("shape")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("nodes[{i}]: missing string field 'shape'"))?
                .to_string();
            let num = |field: &str| -> Result<f64, String> {
                pj.get(field)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("nodes[{i}] ({name}): missing numeric '{field}'"))
            };
            let capacity = ResourceVec::new(num("cpu")?, num("mem_gb")?, num("accel")?);
            let count = pj
                .get("count")
                .and_then(Json::as_i64)
                .ok_or_else(|| format!("nodes[{i}] ({name}): missing numeric 'count'"))?;
            if !(0..=u32::MAX as i64).contains(&count) {
                return Err(format!("nodes[{i}] ({name}): count {count} out of u32 range"));
            }
            pools.push(NodePool {
                shape: NodeShape { name, capacity },
                count: count as u32,
            });
        }
        let inv = NodeInventory::new(pools);
        inv.validate()?;
        Ok(inv)
    }
}

impl fmt::Display for NodeInventory {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let terms: Vec<String> =
            self.pools.iter().map(|p| format!("{}x({})", p.count, p.shape.capacity)).collect();
        write!(f, "{}", terms.join("+"))
    }
}

/// The pack items of a joint fleet configuration: one per (member,
/// stage), `replicas` copies of the stage's per-replica demand.
pub fn config_demands(configs: &[&PipelineConfig]) -> Vec<PackItem> {
    let mut items = Vec::new();
    for (m, cfg) in configs.iter().enumerate() {
        for (s, sc) in cfg.stages.iter().enumerate() {
            items.push(PackItem {
                member: m,
                stage: s,
                unit: sc.resources,
                replicas: sc.replicas,
            });
        }
    }
    items
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{check, prop_assert};

    fn item(member: usize, unit: ResourceVec, replicas: u32) -> PackItem {
        PackItem { member, stage: 0, unit, replicas }
    }

    #[test]
    fn parse_roundtrips_and_validates() {
        let inv = NodeInventory::parse("4x(8c,32g,0a)+2x(16c,64g,1a)").unwrap();
        assert_eq!(inv.pools.len(), 2);
        assert_eq!(inv.n_nodes(), 6);
        assert_eq!(inv.replica_cap(), 4 * 8 + 2 * 16);
        assert_eq!(inv.to_string(), "4x(8c/32g/0a)+2x(16c/64g/1a)");
        assert!(!inv.is_fungible());
        // the Display form round-trips through the parser ('/' accepted
        // alongside ','), and so does the JSON form
        assert_eq!(NodeInventory::parse(&inv.to_string()).unwrap(), inv);
        let back = NodeInventory::from_json(&inv.to_json()).unwrap();
        assert_eq!(inv, back);
        // rejects garbage
        assert!(NodeInventory::parse("").is_err());
        assert!(NodeInventory::parse("4x8c,32g,0a").is_err());
        assert!(NodeInventory::parse("x(8c,32g,0a)").is_err());
        assert!(NodeInventory::parse("0x(8c,32g,0a)").is_err(), "zero count");
        assert!(NodeInventory::parse("2x(0c,32g,0a)").is_err(), "sub-1-core node");
        assert!(NodeInventory::parse("2x(8c,-1g,0a)").is_err(), "negative capacity");
    }

    #[test]
    fn fungible_embedding_is_the_scalar_budget_check() {
        let inv = NodeInventory::fungible(4);
        assert!(inv.is_fungible());
        assert_eq!(inv.replica_cap(), 4);
        // demands are coerced to one slot regardless of their vector
        let heavy = ResourceVec::new(16.0, 64.0, 2.0);
        assert_eq!(inv.demand_of(heavy), ResourceVec::cpu(1.0));
        assert!(inv.fits_any_node(heavy));
        // packs iff Σ replicas ≤ n, exactly the old budget rule
        assert!(inv.pack(&[item(0, heavy, 2), item(1, ResourceVec::cpu(1.0), 2)]).is_some());
        assert!(inv.pack(&[item(0, heavy, 3), item(1, ResourceVec::cpu(1.0), 2)]).is_none());
    }

    #[test]
    fn accel_replicas_land_only_on_accel_nodes() {
        let inv = NodeInventory::parse("2x(8c,32g,0a)+1x(16c,64g,2a)").unwrap();
        let items = [
            item(0, ResourceVec::new(8.0, 2.0, 1.0), 2), // accel-demanding
            item(1, ResourceVec::new(1.0, 1.0, 0.0), 6), // cpu-only
        ];
        let p = inv.pack(&items).unwrap();
        assert!(p.valid_for(&inv));
        for pl in &p.placements {
            if pl.member == 0 {
                assert_eq!(inv.pools[p.shape_of[pl.node]].shape.capacity.accel_slots, 2.0);
            }
        }
        // cpu-only replicas prefer the plain nodes (accel-poorest first)
        let per_shape = p.nodes_used_per_shape(2);
        assert!(per_shape[0] >= 1, "plain nodes host the cpu-only replicas: {per_shape:?}");
        // a cpu-only pool cannot host the accel demand at all
        let plain = NodeInventory::parse("8x(8c,32g,0a)").unwrap();
        assert!(!plain.fits_any_node(ResourceVec::new(8.0, 2.0, 1.0)));
        assert!(plain.pack(&items).is_none());
    }

    #[test]
    fn elastic_shape_never_ties_onto_special_hardware() {
        // both shapes price slots at 1.0 under the CPU-only default
        // weights — the accel shape must lose the tie even when listed
        // first, and listing order must not matter
        let accel_first = NodeInventory::parse("2x(16c,64g,2a)+4x(8c,32g,0a)").unwrap();
        assert_eq!(accel_first.elastic_idx(), 1, "plain shape wins the price tie");
        let plain_first = NodeInventory::parse("4x(8c,32g,0a)+2x(16c,64g,2a)").unwrap();
        assert_eq!(plain_first.elastic_idx(), 0);
    }

    #[test]
    fn retarget_moves_whole_elastic_nodes_convergently() {
        let base = NodeInventory::parse("2x(4c,16g,0a)+1x(16c,64g,2a)").unwrap();
        assert_eq!(base.elastic_idx(), 0, "4c shape is cheapest per slot");
        assert_eq!(base.replica_cap(), 24);
        // grow toward 35: adds whole 4-slot nodes, never past the target
        let mut grown = base.clone();
        assert!(grown.retarget(35));
        assert_eq!(grown.replica_cap(), 32, "8 - 4k ≤ 35 < next whole node");
        assert_eq!(grown.pools[0].count, 4);
        // shrink back toward 10: removes whole elastic nodes while the
        // cap stays ≥ the target (the fixed big node keeps 16 slots)
        let mut shrunk = grown.clone();
        assert!(shrunk.retarget(10));
        assert_eq!(shrunk.replica_cap(), 16, "every elastic node removed, big node fixed");
        assert_eq!(shrunk.pools[0].count, 0);
        // convergence on a REACHABLE cap (16 = zero elastic nodes):
        // any path ending at the same reachable target agrees
        let mut direct = base.clone();
        direct.retarget(10);
        assert_eq!(direct, shrunk);
        let mut via_cap = grown.clone();
        via_cap.retarget(16);
        assert_eq!(via_cap, shrunk, "reachable caps converge exactly");
        // no-op when the target is already within one node
        let mut hold = base.clone();
        assert!(!hold.retarget(24));
        assert_eq!(hold, base);
    }

    #[test]
    fn prop_pack_never_exceeds_capacity_on_any_axis() {
        check("pack respects node capacity", 120, |g| {
            // random 1-3 shape inventory
            let n_shapes = g.usize(1, 4);
            let pools: Vec<NodePool> = (0..n_shapes)
                .map(|i| NodePool {
                    shape: NodeShape {
                        name: format!("s{i}"),
                        capacity: ResourceVec::new(
                            g.usize(1, 33) as f64,
                            g.usize(0, 129) as f64,
                            g.usize(0, 5) as f64,
                        ),
                    },
                    count: g.usize(1, 6) as u32,
                })
                .collect();
            let inv = NodeInventory::new(pools);
            let items: Vec<PackItem> = (0..g.usize(1, 8))
                .map(|m| {
                    item(
                        m,
                        ResourceVec::new(
                            g.usize(1, 17) as f64,
                            g.usize(0, 65) as f64,
                            g.usize(0, 3) as f64,
                        ),
                        g.usize(1, 5) as u32,
                    )
                })
                .collect();
            let total_replicas: u32 = items.iter().map(|i| i.replicas).sum();
            match inv.pack(&items) {
                None => Ok(()), // infeasible is a legal answer
                Some(p) => {
                    prop_assert(p.valid_for(&inv), "a node exceeded capacity on some axis")?;
                    prop_assert(
                        p.placements.len() == total_replicas as usize,
                        "every replica must be placed exactly once",
                    )?;
                    // accel-demanding replicas sit on accel-capable nodes
                    for pl in &p.placements {
                        let it = items.iter().find(|i| i.member == pl.member).unwrap();
                        if it.unit.accel_slots > 0.0 {
                            prop_assert(
                                inv.pools[p.shape_of[pl.node]].shape.capacity.accel_slots
                                    >= it.unit.accel_slots,
                                "accel replica on an accel-less node",
                            )?;
                        }
                    }
                    Ok(())
                }
            }
        });
    }

    #[test]
    fn pack_is_deterministic() {
        let inv = NodeInventory::parse("3x(8c,32g,0a)+2x(16c,64g,2a)").unwrap();
        let items = [
            item(0, ResourceVec::new(2.0, 4.0, 0.0), 5),
            item(1, ResourceVec::new(8.0, 16.0, 1.0), 2),
            item(2, ResourceVec::new(1.0, 2.0, 0.0), 7),
        ];
        assert_eq!(inv.pack(&items), inv.pack(&items));
    }

    #[test]
    fn config_demands_expand_stages() {
        use crate::optimizer::ip::{PipelineConfig, StageConfig};
        let cfg = PipelineConfig {
            stages: vec![
                StageConfig {
                    variant_idx: 0,
                    variant_key: "a".into(),
                    batch: 1,
                    replicas: 2,
                    cost: 2.0,
                    accuracy: 50.0,
                    latency: 0.1,
                    resources: ResourceVec::cpu(1.0),
                },
                StageConfig {
                    variant_idx: 1,
                    variant_key: "b".into(),
                    batch: 2,
                    replicas: 1,
                    cost: 8.0,
                    accuracy: 60.0,
                    latency: 0.2,
                    resources: ResourceVec::new(8.0, 2.0, 1.0),
                },
            ],
            pas: 30.0,
            cost: 10.0,
            batch_sum: 3,
            objective: 0.0,
            latency_e2e: 0.3,
            resources: ResourceVec::new(10.0, 4.0, 1.0),
        };
        let items = config_demands(&[&cfg]);
        assert_eq!(items.len(), 2);
        assert_eq!(items[0].replicas, 2);
        assert_eq!(items[1].unit.accel_slots, 1.0);
        assert_eq!(items[1].stage, 1);
    }
}
