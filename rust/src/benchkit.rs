//! Micro/macro benchmark harness (criterion substitute).
//!
//! `cargo bench` runs `rust/benches/paper_benches.rs` with
//! `harness = false`; that binary uses this module to time closures with
//! warmup, report mean/p50/p99, and print rows in a stable format that
//! `bench_output.txt` captures.

use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::Summary;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time, seconds.
    pub summary: Summary,
    pub iters: usize,
    /// Optional throughput denominator (items per iteration).
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    /// items/second if `items_per_iter` was set.
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / self.summary.mean.max(1e-12))
    }
}

/// Benchmark runner with warmup + fixed iteration count.
pub struct Bencher {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup_iters: 3, iters: 12 }
    }
}

impl Bencher {
    pub fn new(warmup_iters: usize, iters: usize) -> Self {
        Bencher { warmup_iters, iters }
    }

    /// Time `f` (result is returned to prevent dead-code elimination of
    /// the workload; callers usually `let _ =` it).
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut times = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        BenchResult {
            name: name.to_string(),
            summary: Summary::of(&times),
            iters: self.iters,
            items_per_iter: None,
        }
    }

    /// Like [`run`](Self::run) but records items/iteration for
    /// throughput reporting.
    pub fn run_throughput<T, F: FnMut() -> T>(
        &self,
        name: &str,
        items_per_iter: f64,
        f: F,
    ) -> BenchResult {
        let mut r = self.run(name, f);
        r.items_per_iter = Some(items_per_iter);
        r
    }
}

/// Opaque value sink (std::hint::black_box wrapper, kept here so bench
/// code has a single import).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Render one result as the canonical bench row.
pub fn format_row(r: &BenchResult) -> String {
    let s = &r.summary;
    let tput = r
        .throughput()
        .map(|t| format!("  {:>12.1} items/s", t))
        .unwrap_or_default();
    format!(
        "bench {:<44} mean {:>10.4} ms  p50 {:>10.4} ms  p99 {:>10.4} ms  (n={}){}",
        r.name,
        s.mean * 1e3,
        s.p50 * 1e3,
        s.p99 * 1e3,
        r.iters,
        tput
    )
}

/// Print a section header followed by rows.
pub fn print_section(title: &str, rows: &[BenchResult]) {
    println!("\n=== {title} ===");
    for r in rows {
        println!("{}", format_row(r));
    }
}

/// One result as a JSON object (seconds; throughput in items/s when
/// measured).
pub fn result_json(r: &BenchResult) -> Json {
    let mut j = Json::obj()
        .set("name", r.name.clone())
        .set("mean_s", r.summary.mean)
        .set("p50_s", r.summary.p50)
        .set("p99_s", r.summary.p99)
        .set("iters", r.iters);
    if let Some(t) = r.throughput() {
        j = j.set("items_per_s", t);
    }
    j
}

/// Serialize named sections of results as the canonical `BENCH_*.json`
/// shape — a stable perf baseline future PRs diff against.
pub fn sections_json(sections: &[(&str, &[BenchResult])]) -> Json {
    let mut root = Json::obj();
    for (title, rows) in sections {
        root = root.set(
            title,
            Json::Arr(rows.iter().map(result_json).collect()),
        );
    }
    root
}

/// Write sections to `path` as JSON.
pub fn write_json(path: &str, sections: &[(&str, &[BenchResult])]) -> std::io::Result<()> {
    std::fs::write(path, sections_json(sections).to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher::new(1, 5);
        let r = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert_eq!(r.iters, 5);
        assert!(r.summary.mean > 0.0);
        assert!(r.summary.p50 <= r.summary.p99 + 1e-12);
    }

    #[test]
    fn throughput_row() {
        let b = Bencher::new(0, 3);
        let r = b.run_throughput("noop", 100.0, || 1 + 1);
        assert!(r.throughput().unwrap() > 0.0);
        let row = format_row(&r);
        assert!(row.contains("items/s"), "{row}");
    }

    #[test]
    fn json_shape_roundtrips() {
        let b = Bencher::new(0, 3);
        let plain = [b.run("plain", || 1 + 1)];
        let tput = [b.run_throughput("tput", 10.0, || 1 + 1)];
        let j = sections_json(&[("solver", &plain[..]), ("simulator", &tput[..])]);
        let parsed = Json::parse(&j.to_string()).unwrap();
        let solver = parsed.get("solver").unwrap().as_arr().unwrap();
        assert_eq!(solver[0].get("name").unwrap().as_str(), Some("plain"));
        assert!(solver[0].get("mean_s").unwrap().as_f64().unwrap() >= 0.0);
        assert!(solver[0].get("items_per_s").is_none());
        let sim = parsed.get("simulator").unwrap().as_arr().unwrap();
        assert!(sim[0].get("items_per_s").unwrap().as_f64().unwrap() > 0.0);
    }
}
