//! Monitoring daemon (Prometheus substitute, §3): per-second arrival
//! counters in a ring buffer, queried by the adapter for the LSTM's
//! 2-minute history window.

use std::collections::VecDeque;

/// Per-second arrival counter ring.
#[derive(Debug, Clone)]
pub struct Monitor {
    /// counts[i] = arrivals in second (base + i).  A `VecDeque` so
    /// capacity eviction pops the front in O(evicted) — the old `Vec`
    /// `drain(..k)` shifted the whole buffer on every arrival at the
    /// ring edge, O(capacity) per request.
    counts: VecDeque<f64>,
    base: usize,
    capacity: usize,
}

impl Monitor {
    /// `capacity`: how many seconds of history to retain (≥ the LSTM's
    /// 120-second window).
    pub fn new(capacity: usize) -> Self {
        Monitor { counts: VecDeque::new(), base: 0, capacity: capacity.max(1) }
    }

    /// Record one request arrival at time `t` (seconds).
    pub fn record_arrival(&mut self, t: f64) {
        self.record_n(t, 1.0);
    }

    /// Record `n` arrivals at time `t`.
    pub fn record_n(&mut self, t: f64, n: f64) {
        let sec = t.max(0.0) as usize;
        if sec < self.base {
            return; // too old, outside the ring
        }
        while self.base + self.counts.len() <= sec {
            self.counts.push_back(0.0);
        }
        self.counts[sec - self.base] += n;
        // trim to capacity: O(1) amortized front pops, no shifting
        while self.counts.len() > self.capacity {
            self.counts.pop_front();
            self.base += 1;
        }
    }

    /// Per-second history up to and including second `floor(now)-1`
    /// (the current, incomplete second is excluded), most recent last,
    /// at most `window` entries.
    pub fn history(&self, now: f64, window: usize) -> Vec<f64> {
        let end_sec = now.max(0.0) as usize; // exclusive
        let mut out = Vec::new();
        let start = end_sec.saturating_sub(window).max(self.base);
        for s in start..end_sec {
            if s < self.base {
                continue;
            }
            let i = s - self.base;
            out.push(self.counts.get(i).copied().unwrap_or(0.0));
        }
        out
    }

    /// Observed rate over the last `window` seconds (mean RPS).
    pub fn recent_rate(&self, now: f64, window: usize) -> f64 {
        let h = self.history(now, window);
        if h.is_empty() {
            0.0
        } else {
            h.iter().sum::<f64>() / h.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_bucketed_per_second() {
        let mut m = Monitor::new(300);
        m.record_arrival(0.1);
        m.record_arrival(0.9);
        m.record_arrival(1.5);
        assert_eq!(m.history(2.0, 10), vec![2.0, 1.0]);
    }

    #[test]
    fn current_second_excluded() {
        let mut m = Monitor::new(300);
        m.record_arrival(0.5);
        m.record_arrival(1.2);
        // at t=1.5 only second 0 is complete
        assert_eq!(m.history(1.5, 10), vec![1.0]);
    }

    #[test]
    fn window_limits_history() {
        let mut m = Monitor::new(300);
        for s in 0..50 {
            m.record_n(s as f64 + 0.5, s as f64);
        }
        let h = m.history(50.0, 10);
        assert_eq!(h.len(), 10);
        assert_eq!(*h.last().unwrap(), 49.0);
        assert_eq!(h[0], 40.0);
    }

    #[test]
    fn capacity_trims_old() {
        let mut m = Monitor::new(5);
        for s in 0..20 {
            m.record_n(s as f64, 1.0);
        }
        let h = m.history(20.0, 100);
        assert_eq!(h.len(), 5);
    }

    #[test]
    fn gaps_read_as_zero() {
        let mut m = Monitor::new(100);
        m.record_arrival(0.5);
        m.record_arrival(3.5);
        assert_eq!(m.history(4.0, 10), vec![1.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn full_capacity_rate_matches_unbounded_reference() {
        // Regression for the ring-edge eviction rewrite: a monitor that
        // is evicting on every arrival must report the same windows and
        // rates as one that never evicts.
        let mut ring = Monitor::new(120);
        let mut unbounded = Monitor::new(usize::MAX);
        for s in 0..2000 {
            let n = ((s * 7) % 13 + 1) as f64;
            ring.record_n(s as f64 + 0.25, n);
            unbounded.record_n(s as f64 + 0.25, n);
        }
        let now = 2000.0;
        for w in [1, 10, 60, 120] {
            assert_eq!(ring.history(now, w), unbounded.history(now, w), "window {w}");
            let (a, b) = (ring.recent_rate(now, w), unbounded.recent_rate(now, w));
            assert!((a - b).abs() < 1e-12, "window {w}: {a} vs {b}");
        }
    }

    #[test]
    fn recent_rate() {
        let mut m = Monitor::new(100);
        for s in 0..10 {
            m.record_n(s as f64, 4.0);
        }
        assert!((m.recent_rate(10.0, 5) - 4.0).abs() < 1e-9);
    }
}
