//! The IPA adapter (§3): every adaptation interval it
//! (1) fetches the monitored load history, (2) predicts the
//! next-interval peak with the configured predictor, (3) solves for the
//! optimal configuration under the active policy, and (4) emits the new
//! configuration (the simulator / live engine applies it after the
//! reconfiguration delay).
//!
//! Baselines (FA2-low/high, RIM) are expressed as alternative policies
//! behind the same adapter so all four systems share the monitoring,
//! prediction and application machinery — exactly the paper's setup
//! ("the three systems compared benefit from the LSTM predictor").

use crate::baselines::{fa2, rim};
use crate::cluster::reconfig::Reconfig;
use crate::models::accuracy::AccuracyMetric;
use crate::models::pipelines::PipelineSpec;
use crate::optimizer::ip::{self, PipelineConfig, Problem};
use crate::predictor::Predictor;
use crate::profiler::profile::PipelineProfiles;
use std::time::Instant;

/// Which decision policy the adapter runs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// The paper's system: joint variant/batch/replica IP.
    Ipa(AccuracyMetric),
    /// FA2 pinned to the lightest variants.
    Fa2Low,
    /// FA2 pinned to the heaviest variants.
    Fa2High,
    /// RIM: model switching at a fixed high scale.
    Rim(rim::RimParams),
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Ipa(AccuracyMetric::Pas) => "ipa",
            Policy::Ipa(AccuracyMetric::PasPrime) => "ipa-pas-prime",
            Policy::Fa2Low => "fa2-low",
            Policy::Fa2High => "fa2-high",
            Policy::Rim(_) => "rim",
        }
    }
}

/// Adapter settings (§5.3: decision + application ≈ 2 s + 8 s, summed to
/// the 10 s monitoring interval).
#[derive(Debug, Clone, Copy)]
pub struct AdapterConfig {
    /// Seconds between adaptation decisions.
    pub interval: f64,
    /// Delay before a new configuration takes effect (rolling update).
    pub apply_delay: f64,
    /// Horizontal scaling cap per stage.
    pub max_replicas: u32,
}

impl Default for AdapterConfig {
    fn default() -> Self {
        AdapterConfig { interval: 10.0, apply_delay: 8.0, max_replicas: 32 }
    }
}

/// One adaptation decision with bookkeeping.
#[derive(Debug, Clone)]
pub struct Decision {
    pub config: PipelineConfig,
    pub lambda_predicted: f64,
    /// Solver wall time, seconds.
    pub decision_time: f64,
    /// True when the IP was infeasible and the fallback was used.
    pub fallback: bool,
}

/// The adapter: owns the pipeline model, the profiles, the predictor and
/// the policy.  Both the simulator and the live engine call
/// [`Adapter::decide`] at each interval.
pub struct Adapter {
    pub spec: PipelineSpec,
    pub profiles: PipelineProfiles,
    pub policy: Policy,
    pub config: AdapterConfig,
    pub predictor: Box<dyn Predictor + Send>,
}

impl Adapter {
    pub fn new(
        spec: PipelineSpec,
        profiles: PipelineProfiles,
        policy: Policy,
        config: AdapterConfig,
        predictor: Box<dyn Predictor + Send>,
    ) -> Self {
        Adapter { spec, profiles, policy, config, predictor }
    }

    /// The reconfiguration stager matching this adapter's apply delay.
    /// Drivers activate decisions only through the returned
    /// [`Reconfig`], so apply-delay semantics live in one place.
    pub fn reconfig(&self) -> Reconfig {
        Reconfig::new(self.config.apply_delay)
    }

    /// Produce the next configuration from the observed load history.
    pub fn decide(&mut self, now: f64, history: &[f64]) -> Decision {
        let lambda = self.predictor.predict(now, history).max(0.5);
        self.decide_for_lambda(lambda)
    }

    /// Decision for an explicit λ (used by sweeps and tests).
    pub fn decide_for_lambda(&mut self, lambda: f64) -> Decision {
        let t0 = Instant::now();
        let problem = Problem {
            spec: &self.spec,
            profiles: &self.profiles,
            lambda,
            metric: match self.policy {
                Policy::Ipa(m) => m,
                _ => AccuracyMetric::Pas,
            },
            max_replicas: self.config.max_replicas,
        };
        let (config, fallback) = match self.policy {
            Policy::Ipa(_) => match ip::solve(&problem) {
                Some((cfg, _)) => (cfg, false),
                None => (ip::fallback_config(&problem), true),
            },
            Policy::Fa2Low => (fa2::decide(&problem, fa2::VariantPin::Lightest), false),
            Policy::Fa2High => (fa2::decide(&problem, fa2::VariantPin::Heaviest), false),
            Policy::Rim(rp) => (rim::decide(&problem, rp), false),
        };
        Decision {
            config,
            lambda_predicted: lambda,
            decision_time: t0.elapsed().as_secs_f64(),
            fallback,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::pipelines;
    use crate::predictor::ReactivePredictor;
    use crate::profiler::analytic::pipeline_profiles;

    fn adapter(policy: Policy) -> Adapter {
        let spec = pipelines::by_name("video").unwrap();
        let prof = pipeline_profiles(&spec);
        Adapter::new(
            spec,
            prof,
            policy,
            AdapterConfig::default(),
            Box::new(ReactivePredictor::default()),
        )
    }

    #[test]
    fn ipa_decides_within_sla() {
        let mut a = adapter(Policy::Ipa(AccuracyMetric::Pas));
        let d = a.decide(100.0, &[10.0; 120]);
        assert!(!d.fallback);
        assert!(d.config.latency_e2e <= a.spec.sla_e2e() + 1e-9);
        assert!(d.decision_time < 2.0, "Fig 13 budget: {}", d.decision_time);
    }

    #[test]
    fn all_policies_produce_configs() {
        for policy in [
            Policy::Ipa(AccuracyMetric::Pas),
            Policy::Fa2Low,
            Policy::Fa2High,
            Policy::Rim(rim::RimParams::default()),
        ] {
            let mut a = adapter(policy);
            let d = a.decide(50.0, &[8.0; 60]);
            assert_eq!(d.config.stages.len(), 2, "{}", policy.name());
            assert!(d.config.cost > 0.0);
        }
    }

    #[test]
    fn ipa_falls_back_when_infeasible() {
        let mut a = adapter(Policy::Ipa(AccuracyMetric::Pas));
        a.config.max_replicas = 1;
        let d = a.decide_for_lambda(10_000.0);
        assert!(d.fallback);
        assert!(!d.config.stages.is_empty());
    }

    #[test]
    fn ipa_adapts_variants_to_load() {
        // Fig. 5: low load -> accurate models; high load -> light models.
        let mut a = adapter(Policy::Ipa(AccuracyMetric::Pas));
        let low = a.decide_for_lambda(1.0).config;
        let high = a.decide_for_lambda(35.0).config;
        assert!(low.pas >= high.pas, "low {} vs high {}", low.pas, high.pas);
    }

    #[test]
    fn policy_names() {
        assert_eq!(Policy::Fa2Low.name(), "fa2-low");
        assert_eq!(Policy::Ipa(AccuracyMetric::Pas).name(), "ipa");
    }
}
