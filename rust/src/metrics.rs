//! Run metrics: per-request records, per-interval configuration series,
//! and the aggregates the paper reports (SLA attainment, average PAS,
//! average cost, latency CDFs).

use crate::resources::ResourceVec;
use crate::telemetry::hist::Histogram;
use crate::util::stats::{self, Summary};

/// Outcome of one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestRecord {
    pub id: u64,
    pub arrival: f64,
    /// Completion time; `None` if dropped (§4.5).
    pub completion: Option<f64>,
}

impl RequestRecord {
    pub fn latency(&self) -> Option<f64> {
        self.completion.map(|c| c - self.arrival)
    }

    pub fn dropped(&self) -> bool {
        self.completion.is_none()
    }
}

/// Configuration state sampled at each adaptation interval.
#[derive(Debug, Clone)]
pub struct IntervalRecord {
    pub t: f64,
    /// PAS of the active configuration.
    pub pas: f64,
    /// Σ n·R of the active configuration, CPU cores (the default-
    /// weighted norm of `resources`).
    pub cost: f64,
    /// Multi-axis demand of the active configuration (cpu/mem/accel).
    pub resources: ResourceVec,
    /// Observed arrival rate over the last interval.
    pub lambda_observed: f64,
    /// Predictor output used for the decision.
    pub lambda_predicted: f64,
    /// Solver wall time, seconds.
    pub decision_time: f64,
    /// Active variant keys per stage (for temporal plots).
    pub variants: Vec<String>,
}

/// Front-door routing counters for one fleet member (cumulative over a
/// run; produced by [`crate::fleet::router::Router`] on both clocks).
/// `routed[r]` counts requests addressed to stage-0 replica slot `r` —
/// [`RouterStats::utilization_skew`] is the per-replica imbalance the
/// solver (and the report tables) can read.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RouterStats {
    /// Requests routed per stage-0 replica slot.
    pub routed: Vec<u64>,
    /// Admitted but browned out (served the cheaper/degraded response).
    pub degraded: u64,
    /// Refused at the door into the §4.5 drop ledger.
    pub shed: u64,
    /// Routed outside the arrival's origin zone.
    pub cross_zone: u64,
    /// Sticky-session warm hits.
    pub warm_hits: u64,
}

impl RouterStats {
    pub fn total_routed(&self) -> u64 {
        self.routed.iter().sum()
    }

    /// Hottest-replica overload relative to the mean: `max/mean − 1`
    /// (0 = perfectly even; 0 for empty/unrouted runs).
    pub fn utilization_skew(&self) -> f64 {
        let n = self.routed.len();
        let total = self.total_routed();
        if n == 0 || total == 0 {
            return 0.0;
        }
        let mean = total as f64 / n as f64;
        let max = self.routed.iter().copied().max().unwrap_or(0) as f64;
        max / mean - 1.0
    }
}

/// Full result of one run (simulated or live).
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub system: String,
    pub pipeline: String,
    pub workload: String,
    pub requests: Vec<RequestRecord>,
    pub intervals: Vec<IntervalRecord>,
    /// SLA the run was evaluated against (seconds).
    pub sla: f64,
}

impl RunMetrics {
    /// Completed-request latencies.
    pub fn latencies(&self) -> Vec<f64> {
        self.requests.iter().filter_map(|r| r.latency()).collect()
    }

    /// Fraction of *completed* requests within SLA (the paper's SLA
    /// attainment; drops are reported separately).
    pub fn sla_attainment(&self) -> f64 {
        let lats = self.latencies();
        if lats.is_empty() {
            return 0.0;
        }
        lats.iter().filter(|&&l| l <= self.sla).count() as f64 / lats.len() as f64
    }

    /// Fraction of all requests that violated SLA or were dropped.
    pub fn violation_rate(&self) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        let bad = self
            .requests
            .iter()
            .filter(|r| r.latency().map(|l| l > self.sla).unwrap_or(true))
            .count();
        bad as f64 / self.requests.len() as f64
    }

    pub fn drop_rate(&self) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        self.requests.iter().filter(|r| r.dropped()).count() as f64 / self.requests.len() as f64
    }

    /// Requests that completed (the parity tests compare these counts
    /// across drivers).
    pub fn completed_count(&self) -> usize {
        self.requests.iter().filter(|r| r.completion.is_some()).count()
    }

    /// Requests that never completed (§4.5 drops + in-flight at horizon).
    pub fn dropped_count(&self) -> usize {
        self.requests.iter().filter(|r| r.dropped()).count()
    }

    /// Time-average PAS across intervals.
    pub fn avg_pas(&self) -> f64 {
        stats::mean(&self.intervals.iter().map(|i| i.pas).collect::<Vec<_>>())
    }

    /// Time-average cost (CPU cores).
    pub fn avg_cost(&self) -> f64 {
        stats::mean(&self.intervals.iter().map(|i| i.cost).collect::<Vec<_>>())
    }

    /// Time-average resource vector across intervals (the multi-axis
    /// twin of [`RunMetrics::avg_cost`]).
    pub fn avg_resources(&self) -> ResourceVec {
        if self.intervals.is_empty() {
            return ResourceVec::ZERO;
        }
        self.intervals
            .iter()
            .fold(ResourceVec::ZERO, |a, i| a.add(i.resources))
            .scale(1.0 / self.intervals.len() as f64)
    }

    pub fn peak_cost(&self) -> f64 {
        self.intervals.iter().map(|i| i.cost).fold(0.0, f64::max)
    }

    pub fn latency_summary(&self) -> Summary {
        Summary::of(&self.latencies())
    }

    /// Completed-request latencies as a streaming [`Histogram`] —
    /// mergeable across members/shards (the exact Vec-backed
    /// [`RunMetrics::latency_summary`] is unchanged; this is the O(1)-
    /// memory view the fleet aggregates).
    pub fn latency_histogram(&self) -> Histogram {
        let mut h = Histogram::new();
        for r in &self.requests {
            if let Some(l) = r.latency() {
                h.record(l);
            }
        }
        h
    }

    /// Latency CDF for Fig. 15.
    pub fn latency_cdf(&self, points: usize) -> Vec<(f64, f64)> {
        stats::cdf(&self.latencies(), points)
    }

    /// Prediction SMAPE across intervals (predictor quality).
    pub fn prediction_smape(&self) -> f64 {
        let pred: Vec<f64> = self.intervals.iter().map(|i| i.lambda_predicted).collect();
        let obs: Vec<f64> = self.intervals.iter().map(|i| i.lambda_observed).collect();
        stats::smape(&pred, &obs)
    }

    /// Count of model switches across the run (stability metric).
    pub fn variant_switches(&self) -> usize {
        self.intervals
            .windows(2)
            .filter(|w| w[0].variants != w[1].variants)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: f64, completion: Option<f64>) -> RequestRecord {
        RequestRecord { id, arrival, completion }
    }

    fn interval(t: f64, pas: f64, cost: f64) -> IntervalRecord {
        IntervalRecord {
            t,
            pas,
            cost,
            resources: ResourceVec::new(cost, 2.0 * cost, 0.0),
            lambda_observed: 10.0,
            lambda_predicted: 11.0,
            decision_time: 0.001,
            variants: vec!["a".into()],
        }
    }

    #[test]
    fn attainment_and_violations() {
        let m = RunMetrics {
            sla: 1.0,
            requests: vec![
                req(0, 0.0, Some(0.5)),  // ok
                req(1, 0.0, Some(2.0)),  // violate
                req(2, 0.0, None),       // drop
                req(3, 0.0, Some(0.9)),  // ok
            ],
            ..Default::default()
        };
        assert!((m.sla_attainment() - 2.0 / 3.0).abs() < 1e-9);
        assert!((m.violation_rate() - 0.5).abs() < 1e-9);
        assert!((m.drop_rate() - 0.25).abs() < 1e-9);
        assert_eq!(m.completed_count(), 3);
        assert_eq!(m.dropped_count(), 1);
    }

    #[test]
    fn averages() {
        let m = RunMetrics {
            intervals: vec![interval(0.0, 50.0, 4.0), interval(10.0, 60.0, 8.0)],
            ..Default::default()
        };
        assert!((m.avg_pas() - 55.0).abs() < 1e-9);
        assert!((m.avg_cost() - 6.0).abs() < 1e-9);
        assert_eq!(m.peak_cost(), 8.0);
        let r = m.avg_resources();
        assert!((r.cpu_cores - 6.0).abs() < 1e-9);
        assert!((r.memory_gb - 12.0).abs() < 1e-9);
        assert_eq!(r.accel_slots, 0.0);
        assert_eq!(RunMetrics::default().avg_resources(), ResourceVec::ZERO);
    }

    #[test]
    fn switches_counted() {
        let mut a = interval(0.0, 1.0, 1.0);
        let mut b = interval(1.0, 1.0, 1.0);
        let c = interval(2.0, 1.0, 1.0);
        a.variants = vec!["x".into()];
        b.variants = vec!["y".into()];
        let m = RunMetrics {
            intervals: vec![a, b.clone(), c.clone()],
            ..Default::default()
        };
        // x->y is a switch; y->"a" (c) is another
        assert_eq!(m.variant_switches(), 2);
    }

    #[test]
    fn latency_histogram_matches_exact_summary_moments() {
        let m = RunMetrics {
            sla: 1.0,
            requests: (0..200)
                .map(|i| req(i, 0.0, if i % 5 == 0 { None } else { Some(0.01 * i as f64) }))
                .collect(),
            ..Default::default()
        };
        let h = m.latency_histogram().summary();
        let s = m.latency_summary();
        assert_eq!(h.n, s.n);
        assert_eq!(h.min, s.min);
        assert_eq!(h.max, s.max);
        assert!((h.mean - s.mean).abs() < 1e-9);
    }

    #[test]
    fn router_stats_skew() {
        let s = RouterStats { routed: vec![10, 10, 10, 10], ..Default::default() };
        assert_eq!(s.total_routed(), 40);
        assert!(s.utilization_skew().abs() < 1e-9);
        let hot = RouterStats { routed: vec![30, 10, 10, 10], ..Default::default() };
        // mean 15, max 30 → skew 1.0
        assert!((hot.utilization_skew() - 1.0).abs() < 1e-9);
        assert_eq!(RouterStats::default().utilization_skew(), 0.0);
    }

    #[test]
    fn empty_run_safe() {
        let m = RunMetrics::default();
        assert_eq!(m.sla_attainment(), 0.0);
        assert_eq!(m.avg_pas(), 0.0);
        assert_eq!(m.latency_cdf(10).len(), 0);
    }
}
