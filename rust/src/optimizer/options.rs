//! Per-stage option enumeration for the IP (§4.3).
//!
//! For each (variant, batch) pair we derive the *induced* decision:
//! the minimum replica count that satisfies the throughput constraint
//! (Eq. 10c) — cost is strictly increasing in replicas and no other
//! constraint involves them, so `n = ⌈λ / h(b)⌉` is optimal given
//! (m, b).  This collapses the per-stage space from |M|·|B|·n_max to
//! |M|·|B| and makes the branch-and-bound exact and fast.
//!
//! Options that cannot fit the end-to-end SLA even alone, or that need
//! more than `max_replicas`, are dropped; the survivors are then
//! Pareto-pruned (an option dominated on accuracy, latency+queue, cost
//! AND batch simultaneously can never appear in an optimal solution).

use crate::models::registry::BATCH_SIZES;
use crate::profiler::profile::StageProfile;
use crate::queueing::worst_case_delay;
use crate::resources::ResourceVec;

/// One feasible (variant, batch) choice for a stage, with the induced
/// replica count and derived quantities.
#[derive(Debug, Clone, PartialEq)]
pub struct StageOption {
    pub variant_idx: usize,
    pub batch: usize,
    /// Model latency `l_{s,m}(b)`, seconds.
    pub latency: f64,
    /// Worst-case queueing delay `q_s(b) = (b-1)/λ`, seconds.
    pub queue_delay: f64,
    /// Induced replica count `⌈λ / h(b)⌉`.
    pub replicas: u32,
    /// `n · R_m` in CPU cores (the default-weighted norm of
    /// `replicas × resources`).
    pub cost: f64,
    /// The variant's accuracy metric (percent scale).
    pub accuracy: f64,
    /// PER-REPLICA resource demand (what a node must host for each of
    /// the `replicas` copies).
    pub resources: ResourceVec,
}

impl StageOption {
    /// Stage contribution to the Eq. 10b latency sum.
    pub fn total_latency(&self) -> f64 {
        self.latency + self.queue_delay
    }

    /// Aggregate demand of the whole option (`replicas × resources`).
    pub fn total_resources(&self) -> ResourceVec {
        self.resources.scale(self.replicas as f64)
    }
}

/// Enumeration parameters.
#[derive(Debug, Clone, Copy)]
pub struct EnumParams {
    /// Predicted arrival rate λ (RPS).
    pub lambda: f64,
    /// End-to-end SLA (Eq. 10b right-hand side).
    pub sla_e2e: f64,
    /// Horizontal-scaling cap per stage.
    pub max_replicas: u32,
}

/// Enumerate the feasible, Pareto-pruned options of one stage.
pub fn enumerate(stage: &StageProfile, p: EnumParams) -> Vec<StageOption> {
    let mut opts = Vec::new();
    for (vi, vp) in stage.variants.iter().enumerate() {
        for &b in &BATCH_SIZES {
            let latency = vp.latency.latency(b);
            let queue_delay = worst_case_delay(b, p.lambda);
            if latency + queue_delay > p.sla_e2e {
                continue; // cannot fit even with zero-latency other stages
            }
            let tput = vp.latency.throughput(b);
            if tput <= 0.0 {
                continue;
            }
            let replicas = (p.lambda / tput).ceil().max(1.0) as u32;
            if replicas > p.max_replicas {
                continue;
            }
            opts.push(StageOption {
                variant_idx: vi,
                batch: b,
                latency,
                queue_delay,
                replicas,
                cost: replicas as f64 * vp.cost_per_replica(),
                accuracy: vp.variant.accuracy,
                resources: vp.resources_per_replica(),
            });
        }
    }
    pareto_prune(opts)
}

/// Remove options dominated on (accuracy↑, total latency↓, cost↓, batch↓).
pub fn pareto_prune(mut opts: Vec<StageOption>) -> Vec<StageOption> {
    let mut keep = vec![true; opts.len()];
    for i in 0..opts.len() {
        if !keep[i] {
            continue;
        }
        for j in 0..opts.len() {
            if i == j || !keep[i] || !keep[j] {
                continue;
            }
            if dominates(&opts[j], &opts[i]) {
                keep[i] = false;
            }
        }
    }
    let mut it = keep.iter();
    opts.retain(|_| *it.next().unwrap());
    opts
}

/// True if `a` dominates `b`: no worse on all four scalar axes,
/// strictly better on at least one — AND no worse on the resource
/// vector (`a.replicas ≤ b.replicas` with per-replica demand fitting
/// inside `b`'s, so `a`'s replica set bin-packs wherever `b`'s did).
///
/// The vector condition only ever KEEPS more options than the scalar
/// rule did (same-variant batch dominance is untouched — equal
/// per-replica vectors fit reflexively — while some cross-variant
/// prunes are blocked, e.g. one 8-core replica no longer shadows nine
/// 1-core ones).  Extra options cannot change the exact solver's
/// optimum, only enlarge its search; they are exactly the options a
/// heterogeneous node pool may need.
fn dominates(a: &StageOption, b: &StageOption) -> bool {
    let no_worse = a.accuracy >= b.accuracy
        && a.total_latency() <= b.total_latency()
        && a.cost <= b.cost
        && a.batch <= b.batch
        && a.replicas <= b.replicas
        && a.resources.fits(b.resources);
    let strictly = a.accuracy > b.accuracy
        || a.total_latency() < b.total_latency()
        || a.cost < b.cost
        || a.batch < b.batch;
    no_worse && strictly
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::pipelines;
    use crate::profiler::analytic::pipeline_profiles;

    fn video_stage0() -> StageProfile {
        let spec = pipelines::by_name("video").unwrap();
        pipeline_profiles(&spec).stages.remove(0)
    }

    fn params(lambda: f64) -> EnumParams {
        EnumParams { lambda, sla_e2e: 6.89, max_replicas: 32 }
    }

    #[test]
    fn options_nonempty_and_feasible() {
        let st = video_stage0();
        let p = params(10.0);
        let opts = enumerate(&st, p);
        assert!(!opts.is_empty());
        for o in &opts {
            assert!(o.total_latency() <= p.sla_e2e);
            assert!(o.replicas >= 1 && o.replicas <= p.max_replicas);
            // throughput constraint holds by construction
            let vp = &st.variants[o.variant_idx];
            assert!(o.replicas as f64 * vp.latency.throughput(o.batch) >= p.lambda - 1e-9);
        }
    }

    #[test]
    fn replicas_grow_with_lambda() {
        let st = video_stage0();
        let lo = enumerate(&st, params(5.0));
        let hi = enumerate(&st, params(25.0));
        // compare the same (variant,batch) choice present in both
        for o in &lo {
            if let Some(h) =
                hi.iter().find(|h| h.variant_idx == o.variant_idx && h.batch == o.batch)
            {
                assert!(h.replicas >= o.replicas);
            }
        }
    }

    #[test]
    fn pareto_removes_dominated() {
        let mk = |acc, lat, cost, batch| StageOption {
            variant_idx: 0,
            batch,
            latency: lat,
            queue_delay: 0.0,
            replicas: 1,
            cost,
            accuracy: acc,
            resources: ResourceVec::cpu(cost),
        };
        let opts = vec![
            mk(50.0, 0.1, 1.0, 1),  // kept
            mk(50.0, 0.2, 2.0, 1),  // dominated by [0]
            mk(60.0, 0.3, 3.0, 1),  // kept (best accuracy)
        ];
        let pruned = pareto_prune(opts);
        assert_eq!(pruned.len(), 2);
        assert!(pruned.iter().any(|o| o.accuracy == 60.0));
        assert!(pruned.iter().all(|o| !(o.accuracy == 50.0 && o.cost == 2.0)));
    }

    #[test]
    fn pareto_keeps_tradeoff_frontier() {
        let mk = |acc, cost| StageOption {
            variant_idx: 0,
            batch: 1,
            latency: 0.1,
            queue_delay: 0.0,
            replicas: 1,
            cost,
            accuracy: acc,
            resources: ResourceVec::cpu(cost),
        };
        // strictly increasing accuracy and cost: nothing dominated
        let opts = vec![mk(10.0, 1.0), mk(20.0, 2.0), mk(30.0, 3.0)];
        assert_eq!(pareto_prune(opts).len(), 3);
    }

    #[test]
    fn identical_options_collapse() {
        let mk = || StageOption {
            variant_idx: 0,
            batch: 1,
            latency: 0.1,
            queue_delay: 0.0,
            replicas: 1,
            cost: 1.0,
            accuracy: 10.0,
            resources: ResourceVec::cpu(1.0),
        };
        // identical options do not dominate each other (no strict axis) —
        // both are kept; the solver tolerates ties.
        assert_eq!(pareto_prune(vec![mk(), mk()]).len(), 2);
    }

    #[test]
    fn resource_axis_blocks_cross_variant_pruning() {
        // An accel-demanding option that is better on every scalar axis
        // must NOT prune a CPU-only option: on a CPU-only node pool the
        // latter is the only placeable choice.
        let accel = StageOption {
            variant_idx: 1,
            batch: 1,
            latency: 0.05,
            queue_delay: 0.0,
            replicas: 1,
            cost: 0.5,
            accuracy: 90.0,
            resources: ResourceVec::new(8.0, 2.0, 1.0),
        };
        let cpu_only = StageOption {
            variant_idx: 0,
            batch: 1,
            latency: 0.1,
            queue_delay: 0.0,
            replicas: 1,
            cost: 1.0,
            accuracy: 50.0,
            resources: ResourceVec::cpu(1.0),
        };
        assert_eq!(pareto_prune(vec![accel, cpu_only]).len(), 2);
    }

    #[test]
    fn tight_sla_filters_everything() {
        let st = video_stage0();
        let opts = enumerate(
            &st,
            EnumParams { lambda: 10.0, sla_e2e: 1e-6, max_replicas: 32 },
        );
        assert!(opts.is_empty());
    }
}
