//! Brute-force oracle for the IP — exhaustive Cartesian enumeration of
//! the per-stage options, used to certify that the branch-and-bound in
//! [`super::ip`] is exact (the Gurobi-optimality substitute proof
//! obligation).  Only usable on small spaces; the tests keep |options|
//! per stage in the tens.

use super::ip::{materialize, PipelineConfig, Problem};
use super::options::StageOption;

/// Exhaustively find the optimal configuration, or `None` if infeasible.
pub fn solve(p: &Problem) -> Option<PipelineConfig> {
    let options = p.stage_options();
    solve_with_options(p, &options)
}

/// Exhaustive solve over pre-enumerated options.
pub fn solve_with_options(
    p: &Problem,
    options: &[Vec<StageOption>],
) -> Option<PipelineConfig> {
    if options.iter().any(Vec::is_empty) {
        return None;
    }
    let sla = p.spec.sla_e2e();
    let s = options.len();
    let mut idx = vec![0usize; s];
    let mut best: Option<PipelineConfig> = None;
    loop {
        // evaluate current combination
        let lat: f64 = idx
            .iter()
            .zip(options)
            .map(|(&i, o)| o[i].total_latency())
            .sum();
        if lat <= sla {
            let cfg = materialize(p, options, &idx);
            if best.as_ref().is_none_or(|b| cfg.objective > b.objective) {
                best = Some(cfg);
            }
        }
        // odometer increment
        let mut d = 0;
        loop {
            idx[d] += 1;
            if idx[d] < options[d].len() {
                break;
            }
            idx[d] = 0;
            d += 1;
            if d == s {
                return best;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::accuracy::AccuracyMetric;
    use crate::models::pipelines;
    use crate::profiler::analytic::pipeline_profiles;
    use crate::util::quickcheck::{check, prop_assert, prop_close};

    #[test]
    fn bnb_matches_brute_on_all_pipelines() {
        for spec in pipelines::all() {
            let prof = pipeline_profiles(&spec);
            for &lambda in &[2.0, 8.0, 20.0, 35.0] {
                let p = Problem::new(&spec, &prof, lambda);
                let bnb = super::super::ip::solve(&p).map(|(c, _)| c);
                let brute = solve(&p);
                match (bnb, brute) {
                    (None, None) => {}
                    (Some(a), Some(b)) => {
                        assert!(
                            (a.objective - b.objective).abs() < 1e-9,
                            "{} λ={lambda}: bnb {} vs brute {}",
                            spec.name,
                            a.objective,
                            b.objective
                        );
                    }
                    (a, b) => panic!(
                        "{} λ={lambda}: feasibility disagreement bnb={} brute={}",
                        spec.name,
                        a.is_some(),
                        b.is_some()
                    ),
                }
            }
        }
    }

    #[test]
    fn bnb_matches_brute_pas_prime() {
        let spec = pipelines::by_name("sum-qa").unwrap();
        let prof = pipeline_profiles(&spec);
        for &lambda in &[3.0, 12.0] {
            let mut p = Problem::new(&spec, &prof, lambda);
            p.metric = AccuracyMetric::PasPrime;
            let a = super::super::ip::solve(&p).unwrap().0;
            let b = solve(&p).unwrap();
            assert!((a.objective - b.objective).abs() < 1e-9);
        }
    }

    #[test]
    fn bnb_matches_brute_randomized() {
        // Property: for random λ, weights and replica caps, B&B == brute.
        let specs = pipelines::all();
        check("bnb == brute", 40, |g| {
            let spec0 = g.choose(&specs);
            let mut spec = spec0.clone();
            spec.weights.alpha = g.f64(0.5, 50.0);
            spec.weights.beta = g.f64(0.05, 5.0);
            let prof = pipeline_profiles(&spec);
            let mut p = Problem::new(&spec, &prof, g.f64(0.5, 40.0));
            p.max_replicas = g.usize(2, 40) as u32;
            let a = super::super::ip::solve(&p).map(|(c, _)| c);
            let b = solve(&p);
            match (a, b) {
                (None, None) => Ok(()),
                (Some(a), Some(b)) => {
                    prop_close(a.objective, b.objective, 1e-9, "objective mismatch")
                }
                _ => prop_assert(false, "feasibility mismatch"),
            }
        });
    }
}
