//! The IPA Integer Program (Eq. 9/10) and its exact solver.
//!
//! Gurobi is not available offline (repro gate), so we implement an
//! exact branch-and-bound over the per-stage option sets produced by
//! [`super::options`]:
//!
//! * **Branching**: one level per pipeline stage; each node picks one
//!   (variant, batch, induced-replicas) option.
//! * **Infeasibility pruning**: partial latency + Σ remaining minimum
//!   latencies > SLA_P.
//! * **Bound pruning**: an admissible upper bound on the objective —
//!   `α · (best achievable accuracy completion) − β · (cost so far +
//!   Σ remaining minimum costs) − δ · (batch so far + Σ remaining
//!   minimum batches)` — is compared against the incumbent.
//!
//! Optimality is certified against brute-force enumeration in
//! `optimizer::brute` tests and `rust/tests/optimizer_invariants.rs`.

use super::options::{enumerate, EnumParams, StageOption};
use crate::models::accuracy::{normalized_rank, AccuracyMetric};
use crate::models::pipelines::PipelineSpec;
use crate::profiler::profile::PipelineProfiles;
use crate::resources::ResourceVec;

/// Chosen configuration for one stage.
#[derive(Debug, Clone, PartialEq)]
pub struct StageConfig {
    pub variant_idx: usize,
    pub variant_key: String,
    pub batch: usize,
    pub replicas: u32,
    /// `n·R`, CPU cores (default-weighted norm of the vector demand).
    pub cost: f64,
    pub accuracy: f64,
    /// Model latency at the chosen batch, seconds.
    pub latency: f64,
    /// PER-REPLICA resource demand of the chosen variant.
    pub resources: ResourceVec,
}

impl StageConfig {
    /// Aggregate demand of the stage (`replicas × resources`).
    pub fn total_resources(&self) -> ResourceVec {
        self.resources.scale(self.replicas as f64)
    }
}

/// Full pipeline configuration + objective breakdown.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineConfig {
    pub stages: Vec<StageConfig>,
    /// PAS (Eq. 8) of the chosen variants (always the product metric,
    /// for reporting comparability even in PAS′ mode).
    pub pas: f64,
    /// Σ n·R, CPU cores.
    pub cost: f64,
    /// Σ batch sizes (the δ term).
    pub batch_sum: usize,
    /// Objective value f(n, s, I) (Eq. 9) under the requested metric.
    pub objective: f64,
    /// Σ (l + q), seconds — must be ≤ SLA_P.
    pub latency_e2e: f64,
    /// Σ per-stage `replicas × resources` — the configuration's total
    /// multi-axis demand (`cost` is its default-weighted norm).
    pub resources: ResourceVec,
}

impl PipelineConfig {
    /// Total replica slots the configuration occupies — what a shared
    /// fleet pool charges for it (Σ per-stage replicas).
    pub fn total_replicas(&self) -> u32 {
        self.stages.iter().map(|s| s.replicas).sum()
    }
}

/// Solver instrumentation (Fig. 13 reports decision time).
#[derive(Debug, Clone, Copy, Default)]
pub struct SolveStats {
    pub nodes: u64,
    pub pruned_bound: u64,
    pub pruned_infeasible: u64,
    pub options_total: usize,
}

/// Solver inputs.
#[derive(Debug, Clone)]
pub struct Problem<'a> {
    pub spec: &'a PipelineSpec,
    pub profiles: &'a PipelineProfiles,
    /// Predicted arrival rate λ_P (RPS).
    pub lambda: f64,
    pub metric: AccuracyMetric,
    pub max_replicas: u32,
}

impl<'a> Problem<'a> {
    pub fn new(spec: &'a PipelineSpec, profiles: &'a PipelineProfiles, lambda: f64) -> Self {
        Problem { spec, profiles, lambda, metric: AccuracyMetric::Pas, max_replicas: 32 }
    }

    /// Per-stage option sets (enumerated + Pareto-pruned).
    pub fn stage_options(&self) -> Vec<Vec<StageOption>> {
        let p = EnumParams {
            lambda: self.lambda,
            sla_e2e: self.spec.sla_e2e(),
            max_replicas: self.max_replicas,
        };
        self.profiles.stages.iter().map(|s| enumerate(s, p)).collect()
    }

    /// Accuracy contribution of an option under the active metric,
    /// in the *additive* domain the solver accumulates:
    /// PAS — log of the fraction (product → sum);
    /// PAS′ — the normalized rank itself.
    fn acc_term(&self, stage_idx: usize, o: &StageOption) -> f64 {
        match self.metric {
            AccuracyMetric::Pas => (o.accuracy / 100.0).ln(),
            AccuracyMetric::PasPrime => {
                normalized_rank(self.spec.stages[stage_idx], o.accuracy)
            }
        }
    }

    /// Map the accumulated additive accuracy back to the metric value.
    fn acc_value(&self, additive: f64) -> f64 {
        match self.metric {
            AccuracyMetric::Pas => 100.0 * additive.exp(),
            AccuracyMetric::PasPrime => additive,
        }
    }
}

/// Exact solve.  Returns `None` when no configuration satisfies the SLA
/// and throughput constraints (the adapter then falls back to
/// [`fallback_config`]).
pub fn solve(p: &Problem) -> Option<(PipelineConfig, SolveStats)> {
    let options = p.stage_options();
    solve_with_options(p, &options)
}

/// Solve over pre-enumerated options (reused by Fig. 13 sweeps).
///
/// Search strategy (perf-tuned — see EXPERIMENTS.md §Perf):
/// 1. stages are visited most-constrained-first (fewest options);
/// 2. within a stage, options are visited in descending local-utility
///    order (`α·accterm − β·cost − δ·b`) so strong incumbents appear
///    early;
/// 3. a greedy feasible solution seeds the incumbent before the DFS,
///    so the admissible bound prunes from node one.
pub fn solve_with_options(
    p: &Problem,
    options: &[Vec<StageOption>],
) -> Option<(PipelineConfig, SolveStats)> {
    let s = options.len();
    if options.iter().any(|o| o.is_empty()) {
        return None;
    }
    let mut stats = SolveStats {
        options_total: options.iter().map(Vec::len).sum(),
        ..Default::default()
    };
    let w = p.spec.weights;

    // Stage visit order: most constrained first, with *identical*
    // stages grouped adjacently so the symmetry break below applies
    // (Fig. 13 grids have s identical stages → s! symmetric solutions).
    let mut perm: Vec<usize> = (0..s).collect();
    perm.sort_by_key(|&i| options[i].len());
    {
        let mut grouped: Vec<usize> = Vec::with_capacity(s);
        let mut used = vec![false; s];
        for k in 0..s {
            if used[k] {
                continue;
            }
            grouped.push(perm[k]);
            used[k] = true;
            for j in k + 1..s {
                if !used[j] && options[perm[j]] == options[perm[k]] {
                    grouped.push(perm[j]);
                    used[j] = true;
                }
            }
        }
        perm = grouped;
    }
    // same_group[d] = true if permuted stage d has identical options to
    // stage d-1 → restrict its pick position to ≥ the previous pick
    // (canonical sorted representative; exact, any solution has one).
    let same_group: Vec<bool> = (0..s)
        .map(|d| d > 0 && options[perm[d]] == options[perm[d - 1]])
        .collect();

    // Per-stage option visit order: descending local utility.
    let order: Vec<Vec<usize>> = perm
        .iter()
        .map(|&si| {
            let mut idx: Vec<usize> = (0..options[si].len()).collect();
            idx.sort_by(|&a, &b| {
                let u = |o: &StageOption| {
                    w.alpha * p.acc_term(si, o) - w.beta * o.cost - w.delta * o.batch as f64
                };
                u(&options[si][b]).partial_cmp(&u(&options[si][a])).unwrap()
            });
            idx
        })
        .collect();

    // Suffix minima/maxima over the PERMUTED stage order.
    let mut suf_min_lat = vec![0.0; s + 1];
    let mut suf_min_cost = vec![0.0; s + 1];
    let mut suf_min_batch = vec![0.0; s + 1];
    let mut suf_max_acc = vec![0.0; s + 1];
    for d in (0..s).rev() {
        let si = perm[d];
        let min_lat =
            options[si].iter().map(StageOption::total_latency).fold(f64::MAX, f64::min);
        let min_cost = options[si].iter().map(|o| o.cost).fold(f64::MAX, f64::min);
        let min_batch = options[si].iter().map(|o| o.batch as f64).fold(f64::MAX, f64::min);
        let max_acc =
            options[si].iter().map(|o| p.acc_term(si, o)).fold(f64::MIN, f64::max);
        suf_min_lat[d] = suf_min_lat[d + 1] + min_lat;
        suf_min_cost[d] = suf_min_cost[d + 1] + min_cost;
        suf_min_batch[d] = suf_min_batch[d + 1] + min_batch;
        suf_max_acc[d] = suf_max_acc[d + 1] + max_acc;
    }

    let sla = p.spec.sla_e2e();
    let mut best_obj = f64::MIN;
    let mut best: Option<Vec<usize>> = None;

    // Greedy incumbent: best-utility option per stage that keeps the
    // remaining minimum latency feasible.
    {
        let mut picks = vec![usize::MAX; s];
        let mut lat = 0.0;
        let mut ok = true;
        for d in 0..s {
            let si = perm[d];
            let mut found = false;
            for &oi in &order[d] {
                let o = &options[si][oi];
                if lat + o.total_latency() + suf_min_lat[d + 1] <= sla {
                    picks[si] = oi;
                    lat += o.total_latency();
                    found = true;
                    break;
                }
            }
            if !found {
                ok = false;
                break;
            }
        }
        if ok {
            let cfg = materialize(p, options, &picks);
            best_obj = cfg.objective;
            best = Some(picks);
        }
    }

    // DFS over the permuted stages.
    struct Ctx<'a> {
        p: &'a Problem<'a>,
        options: &'a [Vec<StageOption>],
        perm: &'a [usize],
        order: &'a [Vec<usize>],
        same_group: &'a [bool],
        suf_min_lat: &'a [f64],
        suf_min_cost: &'a [f64],
        suf_min_batch: &'a [f64],
        suf_max_acc: &'a [f64],
        sla: f64,
    }

    #[allow(clippy::too_many_arguments)]
    fn dfs(
        c: &Ctx,
        depth: usize,
        start_pos: usize,
        lat: f64,
        cost: f64,
        batch: f64,
        acc: f64,
        chosen: &mut Vec<usize>,
        best_obj: &mut f64,
        best: &mut Option<Vec<usize>>,
        stats: &mut SolveStats,
    ) {
        let w = c.p.spec.weights;
        if depth == c.options.len() {
            let obj = w.alpha * c.p.acc_value(acc) - w.beta * cost - w.delta * batch;
            if obj > *best_obj {
                *best_obj = obj;
                *best = Some(chosen.clone());
            }
            return;
        }
        let si = c.perm[depth];
        let from = if c.same_group[depth] { start_pos } else { 0 };
        for pos in from..c.order[depth].len() {
            let oi = c.order[depth][pos];
            let o = &c.options[si][oi];
            stats.nodes += 1;
            let nlat = lat + o.total_latency();
            if nlat + c.suf_min_lat[depth + 1] > c.sla {
                stats.pruned_infeasible += 1;
                continue;
            }
            let ncost = cost + o.cost;
            let nbatch = batch + o.batch as f64;
            let nacc = acc + c.p.acc_term(si, o);
            // Admissible bound: best accuracy completion, cheapest
            // cost/batch completion.
            let ub = w.alpha * c.p.acc_value(nacc + c.suf_max_acc[depth + 1])
                - w.beta * (ncost + c.suf_min_cost[depth + 1])
                - w.delta * (nbatch + c.suf_min_batch[depth + 1]);
            if ub <= *best_obj {
                stats.pruned_bound += 1;
                continue;
            }
            chosen[si] = oi;
            dfs(c, depth + 1, pos, nlat, ncost, nbatch, nacc, chosen, best_obj, best, stats);
        }
    }

    let ctx = Ctx {
        p,
        options,
        perm: &perm,
        order: &order,
        same_group: &same_group,
        suf_min_lat: &suf_min_lat,
        suf_min_cost: &suf_min_cost,
        suf_min_batch: &suf_min_batch,
        suf_max_acc: &suf_max_acc,
        sla,
    };
    let mut chosen = vec![0usize; s];
    dfs(&ctx, 0, 0, 0.0, 0.0, 0.0, 0.0, &mut chosen, &mut best_obj, &mut best, &mut stats);

    let picks = best?;
    Some((materialize(p, options, &picks), stats))
}

/// Build the [`PipelineConfig`] for a vector of per-stage option picks.
pub fn materialize(
    p: &Problem,
    options: &[Vec<StageOption>],
    picks: &[usize],
) -> PipelineConfig {
    let w = p.spec.weights;
    let mut stages = Vec::new();
    let mut cost = 0.0;
    let mut batch_sum = 0usize;
    let mut lat = 0.0;
    let mut pas_frac = 1.0;
    let mut acc_additive = 0.0;
    let mut resources = ResourceVec::ZERO;
    for (si, (&oi, opts)) in picks.iter().zip(options).enumerate() {
        let o = &opts[oi];
        let vp = &p.profiles.stages[si].variants[o.variant_idx];
        stages.push(StageConfig {
            variant_idx: o.variant_idx,
            variant_key: vp.variant.key(),
            batch: o.batch,
            replicas: o.replicas,
            cost: o.cost,
            accuracy: o.accuracy,
            latency: o.latency,
            resources: o.resources,
        });
        cost += o.cost;
        batch_sum += o.batch;
        lat += o.total_latency();
        pas_frac *= o.accuracy / 100.0;
        acc_additive += p.acc_term(si, o);
        resources = resources.add(o.total_resources());
    }
    let objective =
        w.alpha * p.acc_value(acc_additive) - w.beta * cost - w.delta * batch_sum as f64;
    PipelineConfig {
        stages,
        pas: 100.0 * pas_frac,
        cost,
        batch_sum,
        objective,
        latency_e2e: lat,
        resources,
    }
}

/// Fallback when the IP is infeasible under the predicted load: the
/// lightest variant per stage at its throughput-optimal batch with the
/// replica cap — maximize survivability, accept SLA violations (§4.5
/// dropping sheds the excess).
pub fn fallback_config(p: &Problem) -> PipelineConfig {
    let mut stages = Vec::new();
    let mut cost = 0.0;
    let mut batch_sum = 0usize;
    let mut lat = 0.0;
    let mut pas_frac = 1.0;
    let mut resources = ResourceVec::ZERO;
    for st in &p.profiles.stages {
        // lightest = lowest cost-per-replica, then lowest batch-1 latency
        let (vi, vp) = st
            .variants
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                (a.cost_per_replica(), a.latency.latency(1))
                    .partial_cmp(&(b.cost_per_replica(), b.latency.latency(1)))
                    .unwrap()
            })
            .unwrap();
        let batch = vp.latency.best_batch();
        let tput = vp.latency.throughput(batch);
        let replicas = ((p.lambda / tput).ceil().max(1.0) as u32).min(p.max_replicas);
        stages.push(StageConfig {
            variant_idx: vi,
            variant_key: vp.variant.key(),
            batch,
            replicas,
            cost: replicas as f64 * vp.cost_per_replica(),
            accuracy: vp.variant.accuracy,
            latency: vp.latency.latency(batch),
            resources: vp.resources_per_replica(),
        });
        cost += replicas as f64 * vp.cost_per_replica();
        batch_sum += batch;
        lat += vp.latency.latency(batch) + crate::queueing::worst_case_delay(batch, p.lambda);
        pas_frac *= vp.variant.accuracy / 100.0;
        resources = resources.add(vp.resources_per_replica().scale(replicas as f64));
    }
    let w = p.spec.weights;
    PipelineConfig {
        stages,
        pas: 100.0 * pas_frac,
        cost,
        batch_sum,
        objective: w.alpha * 100.0 * pas_frac - w.beta * cost - w.delta * batch_sum as f64,
        latency_e2e: lat,
        resources,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::pipelines;
    use crate::profiler::analytic::pipeline_profiles;

    fn problem_for(name: &str, lambda: f64) -> (PipelineConfig, SolveStats) {
        let spec = pipelines::by_name(name).unwrap();
        let prof = pipeline_profiles(&spec);
        let p = Problem::new(&spec, &prof, lambda);
        solve(&p).expect("feasible")
    }

    #[test]
    fn video_feasible_and_within_sla() {
        let (cfg, _) = problem_for("video", 10.0);
        assert!(cfg.latency_e2e <= 6.89 + 1e-9);
        assert_eq!(cfg.stages.len(), 2);
        assert!(cfg.pas > 0.0 && cfg.cost > 0.0);
    }

    #[test]
    fn all_pipelines_feasible_at_moderate_load() {
        for spec in pipelines::all() {
            let prof = pipeline_profiles(&spec);
            let p = Problem::new(&spec, &prof, 12.0);
            let (cfg, _) = solve(&p).unwrap_or_else(|| panic!("{} infeasible", spec.name));
            assert!(cfg.latency_e2e <= spec.sla_e2e() + 1e-9, "{}", spec.name);
        }
    }

    #[test]
    fn higher_load_not_cheaper() {
        let (lo, _) = problem_for("video", 5.0);
        let (hi, _) = problem_for("video", 30.0);
        assert!(hi.cost >= lo.cost, "cost {} -> {}", lo.cost, hi.cost);
    }

    #[test]
    fn accuracy_priority_raises_pas() {
        // Fig. 14 mechanism: raising α (or lowering β) must not lower PAS.
        let spec = pipelines::by_name("audio-sent").unwrap();
        let prof = pipeline_profiles(&spec);
        let mut spec_hi = spec.clone();
        spec_hi.weights.alpha *= 20.0;
        let base = solve(&Problem::new(&spec, &prof, 10.0)).unwrap().0;
        let hi = solve(&Problem::new(&spec_hi, &prof, 10.0)).unwrap().0;
        assert!(hi.pas >= base.pas, "{} -> {}", base.pas, hi.pas);
    }

    #[test]
    fn cost_priority_lowers_cost() {
        let spec = pipelines::by_name("audio-sent").unwrap();
        let prof = pipeline_profiles(&spec);
        let mut spec_cheap = spec.clone();
        spec_cheap.weights.beta *= 50.0;
        let base = solve(&Problem::new(&spec, &prof, 10.0)).unwrap().0;
        let cheap = solve(&Problem::new(&spec_cheap, &prof, 10.0)).unwrap().0;
        assert!(cheap.cost <= base.cost, "{} -> {}", base.cost, cheap.cost);
    }

    #[test]
    fn throughput_constraint_satisfied() {
        let spec = pipelines::by_name("video").unwrap();
        let prof = pipeline_profiles(&spec);
        let lambda = 22.0;
        let p = Problem::new(&spec, &prof, lambda);
        let (cfg, _) = solve(&p).unwrap();
        for (si, sc) in cfg.stages.iter().enumerate() {
            let vp = &prof.stages[si].variants[sc.variant_idx];
            let tput = sc.replicas as f64 * vp.latency.throughput(sc.batch);
            assert!(tput >= lambda - 1e-9, "stage {si}: {tput} < {lambda}");
        }
    }

    #[test]
    fn pas_prime_mode_solves() {
        let spec = pipelines::by_name("video").unwrap();
        let prof = pipeline_profiles(&spec);
        let mut p = Problem::new(&spec, &prof, 10.0);
        p.metric = AccuracyMetric::PasPrime;
        let (cfg, _) = solve(&p).unwrap();
        assert!(cfg.latency_e2e <= spec.sla_e2e() + 1e-9);
    }

    #[test]
    fn infeasible_returns_none_and_fallback_works() {
        let spec = pipelines::by_name("video").unwrap();
        let prof = pipeline_profiles(&spec);
        let mut p = Problem::new(&spec, &prof, 100_000.0);
        p.max_replicas = 2;
        assert!(solve(&p).is_none());
        let fb = fallback_config(&p);
        assert_eq!(fb.stages.len(), 2);
        assert!(fb.cost > 0.0);
    }

    #[test]
    fn resource_vector_consistent_with_scalar_cost() {
        use crate::resources::CostWeights;
        let (cfg, _) = problem_for("video", 10.0);
        let total =
            cfg.stages.iter().fold(ResourceVec::ZERO, |a, s| a.add(s.total_resources()));
        assert_eq!(cfg.resources, total, "pipeline vector is the stage sum");
        assert!(
            (cfg.cost - cfg.resources.weighted(CostWeights::default())).abs() < 1e-9,
            "scalar cost is the default-weighted norm of the vector"
        );
        assert!(cfg.resources.memory_gb > 0.0, "registry variants carry memory demand");
    }

    #[test]
    fn stats_populated() {
        let spec = pipelines::by_name("nlp").unwrap();
        let prof = pipeline_profiles(&spec);
        let p = Problem::new(&spec, &prof, 15.0);
        let (_, stats) = solve(&p).unwrap();
        assert!(stats.nodes > 0);
        assert!(stats.options_total > 0);
    }
}
