//! Heuristic solver — the paper's §7 future-work direction ("designing
//! new heuristic methods that can find a good enough but not
//! necessarily optimal solution" for sub-second adaptation on very
//! large graphs).  Implemented as an ablation against the exact B&B:
//!
//! 1. **Greedy construction**: stages most-constrained-first, pick the
//!    best local-utility option that keeps the remaining minimum
//!    latency feasible.
//! 2. **Local search**: hill-climb single-stage swaps until no swap
//!    improves the objective (first-improvement, bounded passes).
//!
//! `reports::figures::fig13`-style sweeps and the bench harness report
//! the optimality gap and speedup vs `optimizer::ip`.

use super::ip::{materialize, PipelineConfig, Problem};
use super::options::StageOption;

/// Result with gap bookkeeping.
#[derive(Debug, Clone)]
pub struct HeuristicResult {
    pub config: PipelineConfig,
    /// Local-search passes executed.
    pub passes: usize,
    /// Options evaluated.
    pub evals: u64,
}

/// Greedy + local-search solve.  Returns `None` iff no feasible
/// configuration exists (same feasibility as the exact solver).
pub fn solve(p: &Problem) -> Option<HeuristicResult> {
    let options = p.stage_options();
    solve_with_options(p, &options)
}

/// Solve over pre-enumerated options.
pub fn solve_with_options(
    p: &Problem,
    options: &[Vec<StageOption>],
) -> Option<HeuristicResult> {
    let s = options.len();
    if options.iter().any(Vec::is_empty) {
        return None;
    }
    let w = p.spec.weights;
    let sla = p.spec.sla_e2e();
    let mut evals = 0u64;

    // Suffix minimum latencies in most-constrained-first order.
    let mut perm: Vec<usize> = (0..s).collect();
    perm.sort_by_key(|&i| options[i].len());
    let mut suf_min_lat = vec![0.0; s + 1];
    for d in (0..s).rev() {
        let si = perm[d];
        let min_lat =
            options[si].iter().map(StageOption::total_latency).fold(f64::MAX, f64::min);
        suf_min_lat[d] = suf_min_lat[d + 1] + min_lat;
    }

    // Greedy construction.
    let utility = |si: usize, o: &StageOption| {
        // local surrogate: treat the accuracy term linearly (exact for
        // PAS', log-approximation for PAS)
        w.alpha * acc_term(p, si, o) - w.beta * o.cost - w.delta * o.batch as f64
    };
    let mut picks = vec![usize::MAX; s];
    let mut lat = 0.0;
    for d in 0..s {
        let si = perm[d];
        let mut best: Option<(f64, usize)> = None;
        for (oi, o) in options[si].iter().enumerate() {
            evals += 1;
            if lat + o.total_latency() + suf_min_lat[d + 1] > sla {
                continue;
            }
            let u = utility(si, o);
            if best.is_none_or(|(bu, _)| u > bu) {
                best = Some((u, oi));
            }
        }
        let (_, oi) = best?;
        picks[si] = oi;
        lat += options[si][oi].total_latency();
    }

    // Local search: single-stage swaps, first-improvement.
    let mut cur = materialize(p, options, &picks);
    let mut passes = 0usize;
    loop {
        passes += 1;
        let mut improved = false;
        for si in 0..s {
            let cur_lat: f64 = picks
                .iter()
                .zip(options)
                .map(|(&oi, os)| os[oi].total_latency())
                .sum();
            let slack = sla - (cur_lat - options[si][picks[si]].total_latency());
            let old = picks[si];
            for oi in 0..options[si].len() {
                if oi == old {
                    continue;
                }
                evals += 1;
                if options[si][oi].total_latency() > slack {
                    continue;
                }
                picks[si] = oi;
                let cand = materialize(p, options, &picks);
                if cand.objective > cur.objective + 1e-12 {
                    cur = cand;
                    improved = true;
                    break; // first improvement; re-scan from this state
                }
                picks[si] = old;
            }
        }
        if !improved || passes >= 8 {
            break;
        }
    }
    Some(HeuristicResult { config: cur, passes, evals })
}

fn acc_term(p: &Problem, si: usize, o: &StageOption) -> f64 {
    use crate::models::accuracy::{normalized_rank, AccuracyMetric};
    match p.metric {
        AccuracyMetric::Pas => (o.accuracy / 100.0).ln(),
        AccuracyMetric::PasPrime => normalized_rank(p.spec.stages[si], o.accuracy),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::pipelines;
    use crate::optimizer::ip;
    use crate::profiler::analytic::pipeline_profiles;
    use crate::util::quickcheck::{check, prop_assert};

    #[test]
    fn feasible_and_near_optimal_on_paper_pipelines() {
        for spec in pipelines::all() {
            let prof = pipeline_profiles(&spec);
            for &lambda in &[3.0, 12.0, 28.0] {
                let p = Problem::new(&spec, &prof, lambda);
                let exact = ip::solve(&p);
                let heur = solve(&p);
                match (exact, heur) {
                    (Some((e, _)), Some(h)) => {
                        assert!(h.config.latency_e2e <= spec.sla_e2e() + 1e-9);
                        // optimality gap bounded on the real pipelines
                        let gap = (e.objective - h.config.objective)
                            / e.objective.abs().max(1e-9);
                        assert!(gap < 0.15, "{} λ={lambda}: gap {gap}", spec.name);
                    }
                    (None, None) => {}
                    (e, h) => {
                        panic!("feasibility mismatch: exact={} heur={}", e.is_some(), h.is_some())
                    }
                }
            }
        }
    }

    #[test]
    fn prop_never_beats_exact_and_always_feasible() {
        let specs = pipelines::all();
        check("heuristic bounded by exact", 40, |g| {
            let mut spec = g.choose(&specs).clone();
            spec.weights.alpha = g.f64(0.1, 50.0);
            spec.weights.beta = g.f64(0.05, 5.0);
            let prof = pipeline_profiles(&spec);
            let p = Problem::new(&spec, &prof, g.f64(0.5, 40.0));
            match (ip::solve(&p), solve(&p)) {
                (Some((e, _)), Some(h)) => {
                    prop_assert(
                        h.config.objective <= e.objective + 1e-9,
                        "heuristic exceeded exact optimum",
                    )?;
                    prop_assert(
                        h.config.latency_e2e <= spec.sla_e2e() + 1e-9,
                        "heuristic infeasible",
                    )
                }
                (None, None) => Ok(()),
                _ => prop_assert(false, "feasibility mismatch"),
            }
        });
    }

    #[test]
    fn fast_on_large_grids() {
        let (spec, prof) = crate::reports::figures::synthetic_problem(10, 10);
        let p = Problem::new(&spec, &prof, 12.0);
        let t0 = std::time::Instant::now();
        let h = solve(&p).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt < 0.1, "heuristic at 10x10 took {dt}s");
        assert!(h.config.cost > 0.0);
    }
}
