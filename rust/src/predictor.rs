//! Load predictors (§3 "Predictor", §5.5 ablation).
//!
//! The adapter asks a predictor for the *maximum* arrival rate over the
//! next `HORIZON` seconds given the last `HISTORY` seconds of observed
//! per-second load.  Three implementations (Fig. 16):
//!
//! * [`LstmPredictor`] — the paper's LSTM, trained at build time in JAX
//!   and served via a PJRT-compiled artifact (the inference closure is
//!   injected by `runtime::engine` so this module stays runtime-free).
//! * [`ReactivePredictor`] — no prediction: the recent observed max
//!   (what reactive autoscalers like InferLine/FA2 use).
//! * [`OraclePredictor`] — ground-truth future max from the trace (the
//!   paper's "baseline predictor with complete knowledge").

use crate::workload::trace::Trace;

/// Window the LSTM consumes (seconds) — matches
/// `python/compile/predictor.HISTORY`.
pub const HISTORY: usize = 120;
/// Prediction horizon (seconds) — matches python `HORIZON`.
pub const HORIZON: usize = 20;

/// A load predictor.
pub trait Predictor {
    /// Predicted max RPS over `[now, now+HORIZON)`.
    ///
    /// `history` holds per-second observed loads, oldest first, with the
    /// most recent second last; it may be shorter than [`HISTORY`] during
    /// warm-up.
    fn predict(&mut self, now: f64, history: &[f64]) -> f64;

    /// Human-readable name for reports.
    fn name(&self) -> &'static str;
}

/// Reactive baseline: max over the trailing `window` seconds (plus a
/// small safety headroom, as reactive autoscalers typically configure).
pub struct ReactivePredictor {
    pub window: usize,
    pub headroom: f64,
}

impl Default for ReactivePredictor {
    fn default() -> Self {
        ReactivePredictor { window: 30, headroom: 1.0 }
    }
}

impl Predictor for ReactivePredictor {
    fn predict(&mut self, _now: f64, history: &[f64]) -> f64 {
        let n = history.len();
        let lo = n.saturating_sub(self.window);
        let m = history[lo..].iter().fold(0.0f64, |a, &b| a.max(b));
        (m * self.headroom).max(0.5)
    }

    fn name(&self) -> &'static str {
        "reactive"
    }
}

/// Oracle: reads the future from the trace.
pub struct OraclePredictor {
    pub trace: Trace,
}

impl Predictor for OraclePredictor {
    fn predict(&mut self, now: f64, _history: &[f64]) -> f64 {
        self.trace.max_in_window(now, HORIZON as f64)
    }

    fn name(&self) -> &'static str {
        "oracle"
    }
}

/// The trained LSTM, behind an injected inference function
/// (`runtime::engine::Engine::lstm_closure` produces one that executes
/// the PJRT artifact).  Histories shorter than [`HISTORY`] are
/// left-padded with their first value.
pub struct LstmPredictor {
    infer: Box<dyn FnMut(&[f32]) -> f32 + Send>,
}

impl LstmPredictor {
    pub fn new(infer: Box<dyn FnMut(&[f32]) -> f32 + Send>) -> Self {
        LstmPredictor { infer }
    }

    /// Build the fixed-size input window from a history slice.
    pub fn window(history: &[f64]) -> [f32; HISTORY] {
        let mut w = [0f32; HISTORY];
        if history.is_empty() {
            return w;
        }
        let pad = history.first().copied().unwrap_or(0.0) as f32;
        let n = history.len();
        for (i, slot) in w.iter_mut().enumerate() {
            *slot = if n >= HISTORY {
                history[n - HISTORY + i] as f32
            } else if i < HISTORY - n {
                pad
            } else {
                history[i - (HISTORY - n)] as f32
            };
        }
        w
    }
}

impl Predictor for LstmPredictor {
    fn predict(&mut self, _now: f64, history: &[f64]) -> f64 {
        let w = Self::window(history);
        let raw = (self.infer)(&w) as f64;
        // Floor at the recently observed max: the solver treats λ as a
        // hard throughput requirement, and provisioning below load that
        // is *currently arriving* is never sound.  This also gives the
        // LSTM the post-burst hysteresis a trailing-max baseline gets
        // for free (without it, fast post-burst downscaling re-enters
        // heavy variants right before the next burst).
        let recent = history.iter().rev().take(15).fold(0.0f64, |a, &b| a.max(b));
        raw.max(recent).max(0.5)
    }

    fn name(&self) -> &'static str {
        "lstm"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::tracegen::Pattern;

    #[test]
    fn reactive_takes_recent_max() {
        let mut p = ReactivePredictor { window: 3, headroom: 1.0 };
        let h = vec![10.0, 50.0, 1.0, 2.0, 3.0];
        assert_eq!(p.predict(0.0, &h), 3.0);
        let h2 = vec![1.0, 9.0, 2.0];
        assert_eq!(p.predict(0.0, &h2), 9.0);
    }

    #[test]
    fn oracle_sees_future() {
        let trace = Trace::new("t", vec![1.0; 100].into_iter().chain(vec![40.0; 10]).collect());
        let mut p = OraclePredictor { trace };
        // standing at t=95, the burst at t=100 is inside the horizon
        assert_eq!(p.predict(95.0, &[]), 40.0);
        assert_eq!(p.predict(10.0, &[]), 1.0);
    }

    #[test]
    fn lstm_window_padding() {
        let w = LstmPredictor::window(&[5.0, 6.0]);
        assert_eq!(w[0], 5.0);
        assert_eq!(w[HISTORY - 2], 5.0);
        assert_eq!(w[HISTORY - 1], 6.0);
        let full: Vec<f64> = (0..200).map(|i| i as f64).collect();
        let w2 = LstmPredictor::window(&full);
        assert_eq!(w2[0], 80.0);
        assert_eq!(w2[HISTORY - 1], 199.0);
    }

    #[test]
    fn lstm_wrapper_floors() {
        // An LSTM stub predicting 0 is floored by recent load.
        let mut p = LstmPredictor::new(Box::new(|_| 0.0));
        let h = vec![20.0; 130];
        assert!(p.predict(0.0, &h) >= 10.0);
    }

    #[test]
    fn reactive_tracks_synthetic_trace_roughly() {
        let tr = Trace::synthetic(Pattern::SteadyLow, 300);
        let mut p = ReactivePredictor::default();
        let pred = p.predict(150.0, &tr.rates[..150]);
        assert!((4.0..10.0).contains(&pred), "{pred}");
    }
}
