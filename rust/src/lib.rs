//! # IPA — Inference Pipeline Adaptation
//!
//! A reproduction of *"IPA: Inference Pipeline Adaptation to Achieve High
//! Accuracy and Cost-Efficiency"* (Ghafouri et al., 2023) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's contribution: an online adapter
//!   that jointly picks a *model variant*, *batch size*, and *replica
//!   count* per pipeline stage by solving an Integer Program
//!   (maximize `α·PAS − β·Σ nR − δ·Σ b` under latency/throughput
//!   constraints), plus every substrate it needs: profiler, queueing,
//!   discrete-event cluster simulator, live serving engine, workload
//!   generation, predictors, baselines (FA2, RIM), metrics and report
//!   harnesses for every table/figure in the paper.
//! * **L2 (python/compile, build-time only)** — JAX compute graphs for
//!   29 synthetic model variants and the LSTM load predictor, lowered
//!   once to HLO text by `make artifacts`.
//! * **L1 (python/compile/kernels)** — Pallas kernels (tiled matmul,
//!   fused LSTM cell) that every L2 graph bottoms out in.
//!
//! Python is never on the request path: the [`runtime`] module loads the
//! HLO artifacts through the PJRT C API (`xla` crate) and serves them
//! from Rust threads.
//!
//! Start with [`coordinator::adapter::Adapter`] (the control loop),
//! [`optimizer::ip::solve`] (the IP), and [`simulator::sim::Simulation`]
//! (the evaluation substrate), or run `cargo run --release -- help`.

pub mod util {
    //! Self-contained substrates (the offline build has no serde / clap /
    //! criterion / proptest / rand — we implement what we need).
    pub mod cli;
    pub mod json;
    pub mod log;
    pub mod quickcheck;
    pub mod rng;
    pub mod stats;
}

pub mod models {
    //! Model-variant registry (paper Tables 7–14), the five paper
    //! pipelines (Fig. 6) and the pipeline accuracy metrics (PAS, PAS′).
    pub mod accuracy;
    pub mod pipelines;
    pub mod registry;
}

pub mod profiler {
    //! §4.2: offline latency profiles — quadratic fits `l(b)=ab²+βb+γ`,
    //! the Eq. 1 base-allocation solver, paper-scale analytic profiles
    //! and measured (runtime) profiles.
    pub mod analytic;
    pub mod base_alloc;
    pub mod fit;
    pub mod profile;
}

pub mod queueing;

pub mod optimizer {
    //! §4.3/4.4: the IP formulation and the exact branch-and-bound
    //! solver (Gurobi substitute), plus a brute-force oracle.
    pub mod brute;
    pub mod heuristic;
    pub mod ip;
    pub mod options;
}

pub mod baselines {
    //! §5.1: FA2 (batch+scale, fixed variant) and RIM (+batching,
    //! variant switching with fixed high scale).
    pub mod fa2;
    pub mod rim;
}

pub mod workload {
    //! Synthetic Twitter-shaped traces (deterministic twin of
    //! python/compile/tracegen.py) and arrival generation.
    pub mod trace;
    pub mod tracegen;
}

pub mod predictor;

pub mod simulator {
    //! Discrete-event cluster simulator: central per-stage queues,
    //! batch dispatch, replica service, §4.5 dropping, reconfiguration
    //! transitions — the Kubernetes-cluster substitute.
    pub mod events;
    pub mod sim;
}

pub mod coordinator {
    //! §3: the adapter loop — monitor → predict → optimize → apply.
    pub mod adapter;
    pub mod monitoring;
}

pub mod runtime {
    //! PJRT runtime: manifest, artifact loading, executor pool, and the
    //! deterministic weight generator (twin of python model.make_params).
    pub mod engine;
    pub mod manifest;
    pub mod pool;
    pub mod weights;
}

pub mod serving {
    //! Live serving engine: thread-per-replica execution of the real
    //! HLO artifacts behind central batching queues, with the adapter
    //! reconfiguring it on a live clock.
    pub mod engine;
    pub mod loadgen;
}

pub mod metrics;

pub mod reports {
    //! Regeneration harness for every paper table and figure.
    pub mod figures;
    pub mod tables;
}

pub mod benchkit;

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
