//! # IPA — Inference Pipeline Adaptation
//!
//! A reproduction of *"IPA: Inference Pipeline Adaptation to Achieve High
//! Accuracy and Cost-Efficiency"* (Ghafouri et al., 2023) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's contribution: an online adapter
//!   that jointly picks a *model variant*, *batch size*, and *replica
//!   count* per pipeline stage by solving an Integer Program
//!   (maximize `α·PAS − β·Σ nR − δ·Σ b` under latency/throughput
//!   constraints), plus every substrate it needs: profiler, queueing,
//!   the shared cluster core with its simulator / live / replay
//!   drivers, workload generation, predictors, baselines (FA2, RIM),
//!   metrics and report harnesses for every table/figure in the paper.
//! * **L2 (python/compile, build-time only)** — JAX compute graphs for
//!   29 synthetic model variants and the LSTM load predictor, lowered
//!   once to HLO text by `make artifacts`.
//! * **L1 (python/compile/kernels)** — Pallas kernels (tiled matmul,
//!   fused LSTM cell) that every L2 graph bottoms out in.
//!
//! Python is never on the request path: the [`runtime`] module loads the
//! HLO artifacts through the PJRT C API (stubbed offline — see
//! `runtime::xla_stub`) and serves them from Rust threads.
//!
//! ## The driver/core split
//!
//! IPA's evaluation method only works if the simulator is a faithful
//! twin of the serving cluster, so the serving machinery is factored
//! into one clock-agnostic core with thin drivers on top:
//!
//! * [`cluster`] — **the core**: per-stage state ([`cluster::core`]),
//!   central batching + round-robin release ([`cluster::dispatch`]),
//!   §4.5 dropping ([`cluster::drop_policy`]), apply-delay
//!   reconfiguration ([`cluster::reconfig`]) and request/interval
//!   accounting ([`cluster::accounting`]).  No clocks, no threads.
//! * **drivers** — [`simulator::sim`] feeds the core virtual time from
//!   a discrete-event queue; [`serving::engine`] feeds it wall-clock
//!   time from worker threads (real PJRT execution or a synthetic
//!   profile-sleeper); [`simulator::replay`] re-runs a recorded
//!   decision schedule through the identical loop.
//!
//! Every behavioral rule — batch release, drop, rolling reconfig,
//! bookkeeping — exists exactly once, and `tests/cluster_parity.rs`
//! pins the drivers to each other.
//!
//! ## The fleet layer
//!
//! [`fleet`] lifts the same split one level up: N heterogeneous
//! pipelines share ONE replica pool.  A [`fleet::spec::FleetSpec`]
//! names the members (each with a priority class) and the global
//! budget, the joint allocator ([`fleet::solver::solve_fleet`] /
//! [`fleet::solver::solve_fleet_tiers`]) splits the pool by greedy
//! marginal gain over per-pipeline IP solves (floored at the
//! even-split baseline; lexicographic over priority tiers), and
//! [`fleet::core::FleetCore`] owns one cluster core per member while
//! enforcing the budget invariant across rolling reconfigurations.
//! Both clocks drive whole fleets: [`simulator::sim::run_fleet`]
//! interleaves every member's events in one virtual-time queue, and
//! [`serving::engine::serve_fleet`] runs one wall-clock loop
//! with per-member adapters — `tests/fleet.rs` pins them to each
//! other and the allocator to its budget/even-split invariants.
//! [`fleet::run::FleetRun`] is the one front door over both: a builder
//! that resolves a [`fleet::spec::FleetSpec`] into specs, profiles,
//! SLAs, traces and a budget once, then runs it on either clock
//! (`.sim(SimConfig)` / `.serve(&ServeConfig, LoadGenConfig)`).
//!
//! The pool itself is *elastic* (InferLine-style slow/fast split,
//! `tests/fleet_elastic.rs`): each tick the slow path may resize the
//! pool against a cost target ([`fleet::autoscaler`], actuated by
//! [`fleet::core::FleetCore::resize_pool`] with a replica-seconds
//! bought/used ledger) before the joint solve — which re-solves only
//! the members whose predicted λ actually moved — while between ticks
//! a preemption fast path ([`fleet::solver::FleetAdapter::preempt`])
//! moves replicas from strictly lower-priority members to a bursting
//! high-priority one without touching the joint IP.
//!
//! Allocation is *multi-resource* (`tests/fleet_binpack.rs`): every
//! replica demands a [`resources::ResourceVec`] (CPU cores, memory GB,
//! accelerator slots — [`models::registry::Variant::resources`]), the
//! scalar `cost()` everywhere is its default-weighted norm (CPU cores
//! only, so every paper number is unchanged), and the fleet pool can be
//! a heterogeneous [`fleet::nodes::NodeInventory`] that replicas
//! first-fit-decreasing bin-pack onto
//! ([`fleet::solver::solve_fleet_packed`]; resizes move whole nodes of
//! the elastic shape).  Members additionally carry an
//! [`fleet::spec::SlaClass`] — latency-critical traffic gets verbatim
//! drop SLAs, capped batch-formation waits and preemption priority,
//! throughput/batch traffic gets relaxed shedding, uncapped batching
//! and donates replicas first.  The fungible single-shape pool with
//! zero memory/accel demand reproduces the scalar path byte for byte.
//!
//! Placement is *topology-aware* (`tests/fleet_topology.rs`):
//! consecutive packings are **sticky**
//! ([`fleet::nodes::NodeInventory::pack_sticky`] keeps every replica on
//! its old node when capacity allows; [`fleet::nodes::Packing::moved_from`]
//! diffs the rest) and every replica a reconfiguration does move is
//! charged through the apply delay
//! ([`fleet::core::FleetReconfig::with_migration`]) and the migrations
//! ledger, so a churny decision is visibly worse than a stable one.
//! Node shapes carry **failure-domain** zone labels; spread-flagged
//! members keep ≥ 2 replicas per stage across ≥ 2 zones
//! ([`fleet::solver::solve_fleet_placed`]), a mid-run zone outage
//! ([`simulator::sim::run_fleet_des_faults`],
//! [`fleet::core::FleetCore::kill_zone`]) drains the zone and forces an
//! emergency repack on the survivors, and the autoscaler buys WHICH
//! shape the per-axis demand pressure selects
//! ([`fleet::autoscaler::pressure_axis`],
//! [`fleet::nodes::NodeInventory::retarget_with`]) instead of always
//! the cheapest — with the fleet core mirroring the controller's
//! inventory on every resize.
//!
//! ## The fleet front door
//!
//! Arrivals enter the fleet through a per-member router + admission
//! gate ([`fleet::router`], `tests/fleet_router.rs`): each request is
//! spread across the member's stage-0 replica slots by a pluggable
//! [`fleet::router::RoutePolicy`] — round-robin, least-loaded,
//! zone-local-first (origin zones derive deterministically from the
//! request id against the inventory's zone universe; crossing zones
//! costs a latency penalty) or sticky-session (warm cache hits run a
//! discounted service time) — reading replica→node→zone placement
//! from the *same* [`fleet::nodes::Packing`] the solver produced.
//! Admission degrades before it drops: past
//! [`fleet::router::RouterConfig::admit_threshold`] of the SLA the
//! request is *browned out* (served cheaper), and only past
//! `shed_threshold` is it refused into the §4.5 drop ledger.  The
//! router is observational on top of the clocks — `router: None`
//! reproduces the pre-addressed ingress byte for byte, and routed DES
//! runs stay byte-identical at any `IPA_SIM_THREADS` because router
//! state lives in the member's lane and journals only at barriers.
//! [`metrics::RouterStats`] (per-replica counts, skew, degrade/shed/
//! cross-zone/warm counters) lands in both clocks' reports and
//! [`reports::tables::router_table`].
//!
//! ## The sharded data plane
//!
//! Both drivers' request hot paths are sharded through [`data_plane`]
//! (`rust/src/data_plane/`), keeping the clock-agnostic [`cluster`]
//! core untouched.  **Lock-free:** arrivals and inter-stage forwards
//! ride one bounded MPSC ring per (member, stage)
//! ([`data_plane::ring::MpscRing`] behind
//! [`data_plane::ingress::LaneGrid`]), and workers read the active
//! configuration through an epoch-gated snapshot
//! ([`data_plane::snapshot::ConfigCell`] — one `Acquire` load on the
//! common path), so the load generator and the adapter's
//! decide/preempt never contend with batch formation.  **Still
//! locked:** the short core lock around each batch attempt (ring drain
//! + `try_form` + hand-off) and around completion bookkeeping — batch
//! formation and accounting stay exactly-once in the shared core.
//! The memory-ordering contract of every atomic is documented at its
//! definition: ring slot stamps are `Acquire`/`Release` pairs with
//! `Relaxed` cursor CASes ([`data_plane::ring`]), the config epoch is
//! a `Release` bump / `Acquire` probe ([`data_plane::snapshot`]), and
//! shutdown is an `Acquire`/`Release` flag paired with a condvar so
//! sleepers wake without polling ([`data_plane::stop::StopGate`]).
//! On the virtual clock, [`simulator::sim::run_fleet_des`] replaces
//! the single global `BinaryHeap` with per-member event wheels merged
//! by a `next_due` tournament ([`data_plane::wheel::ShardedClock`]) —
//! order-identical to the one-heap clock by construction, so seeded
//! runs stay byte-for-byte reproducible
//! (`SimConfig::legacy_clock` / `ServeConfig::legacy_lock` switch the
//! old paths back on for A/B benches).
//!
//! ## The epoch-parallel fleet DES
//!
//! On top of the sharded clock, the fleet DES advances members
//! *concurrently*: members interact only through global control
//! events (adapt ticks, preemption checks, staged applies, zone
//! faults, end-of-run), which ride a dedicated global wheel.  The
//! driver reads that wheel's `next_due` as a barrier, fans the
//! members across scoped threads ([`runtime::pool::scoped_map_mut`] —
//! each worker owns a disjoint `&mut` member core + wheel + lane),
//! drains every member's events strictly before the barrier
//! ([`data_plane::wheel::EventWheel::pop_until`]), then executes the
//! global event sequentially and repeats.  In-epoch event pushes are
//! seq-stamped from disjoint per-member sub-ranges
//! ([`data_plane::wheel::EPOCH_SEQ_STRIDE`]), and per-member spans
//! and occupancy deltas buffer in the member's lane until the
//! barrier, where they fold in member order — so per-member event
//! order, per-request outcomes, merged fleet metrics/histograms and
//! the control-plane journal (written only at barriers) are
//! byte-identical at ANY worker count.  Parallel epochs are the
//! default; `SimConfig::sim_threads = 1` / `IPA_SIM_THREADS=1` or
//! `SimConfig::sequential_epochs` pin one worker for A/B runs, and
//! `SimConfig::legacy_clock` forces the fully sequential single-heap
//! driver (`tests/sim_parallel.rs` pins all of them to each other).
//!
//! ## The telemetry plane
//!
//! [`telemetry`] is the flight recorder riding the data plane: sampled
//! per-request span traces across every stage hop
//! (arrival → enqueue → queue-wait → batch-form → exec →
//! forward/done/drop) collected through per-member lock-free span rings
//! ([`telemetry::Telemetry`], allocation-free when disabled), streaming
//! log-bucketed histograms with exact moments
//! ([`telemetry::hist::Histogram`] — mergeable across members, feeding
//! [`metrics::RunMetrics::latency_histogram`]), and the control-plane
//! decision journal ([`telemetry::journal::Journal`] — every solve,
//! resize, preemption, stage/activate, zone kill as a seq-stamped
//! virtual-time JSON entry; [`telemetry::journal::decisions_from_journal`]
//! rebuilds a [`simulator::replay`] schedule from it).  Recording is
//! purely observational: the traced DES reproduces the untraced run
//! byte for byte, and two traced runs journal byte-identically.
//! Exposition: [`reports::timeline`] waterfalls and
//! [`telemetry::export::prometheus_text`].
//!
//! ## Runtime knobs
//!
//! Every `IPA_*` environment variable, in one place.  Each one A/Bs a
//! default-on mechanism against its legacy path (or relaxes a bench
//! gate on unusual hardware) — none change WHAT is computed, only HOW
//! (or how fast it must be):
//!
//! * `IPA_SIM_THREADS` — fleet-DES epoch workers
//!   ([`simulator::sim::sim_threads`]; default: available cores capped
//!   at 8).  `1` pins the sequential-epochs driver the parallel path
//!   is byte-identical to; programmatic override:
//!   [`simulator::sim::set_sim_threads`] / `SimConfig::sim_threads`.
//! * `IPA_SOLVER_THREADS` — fleet-solver evaluation workers
//!   ([`fleet::solver::solver_threads`]; default: available cores
//!   capped at 8).  `1` pins the sequential scan the parallel merge is
//!   byte-identical to.
//! * `IPA_CELL_THRESHOLD` — member count at which the joint solve goes
//!   hierarchical ([`fleet::cells::cell_threshold`]; default 24).  A
//!   huge value forces the flat solver.
//! * `IPA_DELTA_PACK` — incremental re-packing of changed members
//!   against the retained occupancy index
//!   ([`fleet::nodes::delta_pack_enabled`]; default on).  `0` forces
//!   full sticky first-fit-decreasing packs.
//! * `IPA_ROUTE_*` — front-door defaults read by
//!   [`fleet::router::RouterConfig::from_env`] (CLI flags and
//!   programmatic configs override them): `IPA_ROUTE_POLICY`
//!   (`round_robin|least_loaded|zone_local|sticky`),
//!   `IPA_ROUTE_ADMISSION` (`1` enables degrade-then-shed),
//!   `IPA_ROUTE_CROSS_ZONE_PENALTY` / `IPA_ROUTE_WARM_SCALE` /
//!   `IPA_ROUTE_BROWNOUT_SCALE` (service-time adjustments),
//!   `IPA_ROUTE_ADMIT_THRESHOLD` / `IPA_ROUTE_SHED_THRESHOLD`
//!   (est-wait per SLA fractions) and `IPA_ROUTE_SESSION_STRIDE`
//!   (ids per sticky session).  Unset = no router: both clocks run
//!   the pre-addressed ingress unchanged.
//! * `IPA_LOG` — diagnostic log level (`error|warn|info|debug|trace`;
//!   default off).  Levels print to stderr, never to report files.
//! * `IPA_BENCH_SECONDS` — trace length for `cargo bench` (default
//!   420).
//! * Bench speedup/overhead gates, asserted in-run by `cargo bench`
//!   and overridable on noisy or small hosts: `IPA_RING_SPEEDUP_GATE`
//!   (sharded rings vs single lock, default 10×),
//!   `IPA_DES_SPEEDUP_GATE` (sharded DES clock vs single heap,
//!   default 1×), `IPA_TELEM_OVERHEAD_GATE` (traced vs untraced
//!   dispatch, default 1.10), `IPA_FLEET_SCALE_GATE` (scaled control
//!   plane vs flat sequential, default 0.75×cores clamped to
//!   [1.5, 5]), `IPA_SIM_PAR_GATE` (epoch-parallel DES vs 1 worker,
//!   default 0.3×cores clamped to [1.1, 3]).
//!
//! Start with [`coordinator::adapter::Adapter`] (the control loop),
//! [`optimizer::ip::solve`] (the IP), and [`simulator::sim::Simulation`]
//! (the evaluation substrate), or run `cargo run --release -- help`.

pub mod util {
    //! Self-contained substrates (the offline build has no serde / clap /
    //! criterion / proptest / rand / anyhow — we implement what we need).
    pub mod cli;
    pub mod error;
    pub mod json;
    pub mod log;
    pub mod quickcheck;
    pub mod rng;
    pub mod stats;
}

pub mod models {
    //! Model-variant registry (paper Tables 7–14), the five paper
    //! pipelines (Fig. 6) and the pipeline accuracy metrics (PAS, PAS′).
    pub mod accuracy;
    pub mod pipelines;
    pub mod registry;
}

pub mod profiler {
    //! §4.2: offline latency profiles — quadratic fits `l(b)=ab²+βb+γ`,
    //! the Eq. 1 base-allocation solver, paper-scale analytic profiles
    //! and measured (runtime) profiles.
    pub mod analytic;
    pub mod base_alloc;
    pub mod fit;
    pub mod profile;
}

pub mod queueing;

pub mod resources;

pub mod cluster {
    //! The clock-agnostic cluster core shared by every driver (see the
    //! crate-level "driver/core split"): stage state, batch formation,
    //! §4.5 dropping, rolling reconfiguration, and accounting.  The
    //! simulator, the live engine and the replay driver are thin clocks
    //! over this module — a new driver (deterministic replay landed
    //! this way; multi-pipeline sharding is next) is one file, not a
    //! fork of the stack.
    pub mod accounting;
    pub mod core;
    pub mod dispatch;
    pub mod drop_policy;
    pub mod reconfig;
}

pub mod optimizer {
    //! §4.3/4.4: the IP formulation and the exact branch-and-bound
    //! solver (Gurobi substitute), plus a brute-force oracle.
    pub mod brute;
    pub mod heuristic;
    pub mod ip;
    pub mod options;
}

pub mod fleet {
    //! Multi-pipeline sharding over one *elastic* replica pool (see the
    //! crate-level "fleet layer"): the fleet description + JSON IO
    //! ([`spec`] — members carry priority classes, SLA classes and
    //! zone-spread flags), the heterogeneous node shapes and the
    //! replica bin-packer ([`nodes`] — [`nodes::NodeInventory`] with
    //! first-fit-decreasing [`nodes::NodeInventory::pack`], *sticky*
    //! move-minimizing [`nodes::NodeInventory::pack_sticky`] with
    //! failure-domain zone labels and spread enforcement, whole-node
    //! [`nodes::NodeInventory::retarget`] /
    //! pressure-aware [`nodes::NodeInventory::retarget_with`]
    //! elasticity, and the fungible scalar embedding), the joint
    //! cross-pipeline budget allocator ([`solver`] — greedy
    //! marginal-gain over per-pipeline IP solves, priority tiers,
    //! even-split floor, brute-force cross-check, bin-packed/sticky
    //! solves over node inventories, incremental re-solves, the
    //! mid-interval preemption fast path and the zone-fault emergency
    //! repack), the pool autoscaler ([`autoscaler`] — grow/shrink
    //! steps against a cost target with scale-up eagerness, scale-down
    //! hysteresis and the per-axis [`autoscaler::pressure_axis`] shape
    //! selector) and the shared-pool core ([`core`] — one
    //! [`crate::cluster::core::ClusterCore`] per member behind one
    //! budget/inventory, with rolling-reconfig overshoot accounting,
    //! mirrored pool resizing, zone kills, and the replica-seconds +
    //! node-seconds + migration cost ledgers).
    //!
    //! The solver is split into ENGINE vs POLICY layers for scale:
    //! the engine (`solver::ShareEngine`) owns the bounded memoized
    //! per-member budget-capped solves and fans independent member
    //! evaluations across [`solver::solver_threads`] scoped workers
    //! with a deterministic scan-order merge; the public solvers are
    //! thin policies over it, and [`cells`] reuses the engine
    //! unchanged to go hierarchical at [`cells::cell_threshold`]+
    //! members (independent per-cell solves + a top-level
    //! marginal-gain budget rebalancer).  On the packing side,
    //! [`nodes::NodeInventory::pack_delta`] re-places only the members
    //! whose configuration changed against a retained occupancy index
    //! (full sticky FFD as the universal fallback).  All three paths
    //! are byte-deterministic at any thread count and keep legacy
    //! sequential/flat A/B switches (`IPA_SOLVER_THREADS=1`,
    //! `IPA_CELL_THRESHOLD`, `IPA_DELTA_PACK=0`).
    //!
    //! Arrivals pass through the per-member front door ([`router`] —
    //! pluggable routing policies over the packing's replica→node→zone
    //! placement plus degrade-then-shed admission; see the crate-level
    //! "fleet front door"), and [`run::FleetRun`] is the one builder
    //! entry point that resolves a spec and drives it on either clock.
    //!
    //! The fleet drivers live
    //! with their clocks: [`crate::simulator::sim::run_fleet`] (the
    //! `FleetDesParams` option struct covers faults, tracing and the
    //! router) and [`crate::serving::engine::serve_fleet`] (ditto via
    //! `FleetServeParams`).
    pub mod autoscaler;
    pub mod cells;
    pub mod core;
    pub mod nodes;
    pub mod router;
    pub mod run;
    pub mod solver;
    pub mod spec;
}

pub mod data_plane {
    //! The sharded request hot path (see the crate-level "sharded data
    //! plane"): bounded lock-free MPSC rings ([`ring`]), the
    //! per-(member, stage) ingress lanes the live engine enqueues
    //! through ([`ingress`]), epoch-gated configuration snapshots
    //! ([`snapshot`]), the condvar-backed shutdown gate ([`stop`]),
    //! per-member event wheels + the tournament-merged DES clock
    //! ([`wheel`]), and the synthetic 64-stage executor the
    //! `data_plane` bench section drives in sharded vs single-lock
    //! mode ([`synthetic`]).  Each module documents the memory-ordering
    //! contract of its atomics.
    pub mod ingress;
    pub mod ring;
    pub mod snapshot;
    pub mod stop;
    pub mod synthetic;
    pub mod wheel;
}

pub mod baselines {
    //! §5.1: FA2 (batch+scale, fixed variant) and RIM (+batching,
    //! variant switching with fixed high scale).
    pub mod fa2;
    pub mod rim;
}

pub mod workload {
    //! Synthetic Twitter-shaped traces (deterministic twin of
    //! python/compile/tracegen.py) and arrival generation.
    pub mod trace;
    pub mod tracegen;
}

pub mod predictor;

pub mod simulator {
    //! Virtual-time drivers over the [`crate::cluster`] core: the
    //! deterministic event queue ([`events`]), the adapter-driven
    //! discrete-event simulator ([`sim`] — the Kubernetes-cluster
    //! substitute, whose fleet driver advances members in parallel
    //! between control-plane barriers; see the crate-level
    //! "epoch-parallel fleet DES") and the decision-log replay driver
    //! ([`replay`]).
    pub mod events;
    pub mod replay;
    pub mod sim;
}

pub mod coordinator {
    //! §3: the adapter loop — monitor → predict → optimize → apply
    //! (application is staged through [`crate::cluster::reconfig`]).
    pub mod adapter;
    pub mod monitoring;
}

pub mod runtime {
    //! PJRT runtime: manifest, artifact loading, executor pool, and the
    //! deterministic weight generator (twin of python model.make_params).
    //! `xla_stub` stands in for the real PJRT bindings offline.
    pub mod engine;
    pub mod manifest;
    pub mod pool;
    pub mod weights;
    pub mod xla_stub;
}

pub mod serving {
    //! The wall-clock driver over the [`crate::cluster`] core:
    //! thread-per-replica-slot workers behind the shared core, a
    //! pluggable [`engine::BatchExecutor`] (real PJRT artifacts or a
    //! synthetic profile-sleeper), and the adapter reconfiguring it on
    //! a live clock.  [`engine::serve_fleet`] runs the same loop over a
    //! whole fleet behind one replica budget (optionally through the
    //! [`crate::fleet::router`] front door).
    pub mod engine;
    pub mod loadgen;
}

pub mod metrics;

pub mod telemetry;

pub mod reports {
    //! Regeneration harness for every paper table and figure, plus the
    //! span-trace waterfall renderer ([`timeline`]).
    pub mod figures;
    pub mod tables;
    pub mod timeline;
}

pub mod benchkit;

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
