//! Sharded DES clock: per-member event wheels merged lazily through a
//! tournament of `next_due` heads.
//!
//! The fleet DES used to serialize every member's events through one
//! `BinaryHeap` whose size is dominated by the pre-materialized arrival
//! stream (tens of thousands of entries → every push/pop pays
//! `O(log total_arrivals)` with cold cache lines).  [`ShardedClock`]
//! splits the stream: each member owns an [`EventWheel`] whose *sorted
//! lane* holds its arrival trace (already time-sorted — `O(1)` push and
//! pop from a `VecDeque`) and whose *heap lane* holds the handful of
//! dynamic events in flight (service completions, queue checks), while
//! global control events (Adapt/Apply/Preempt/Fault/End) ride a
//! dedicated wheel.  Popping is a tournament over the `members + 1`
//! `next_due` heads — a linear scan of a few cached keys instead of a
//! log-depth walk of one giant heap — so the cost per event stays flat
//! as members are added.
//!
//! # Byte-for-byte parity with the single heap
//!
//! Determinism is load-bearing (seeded runs must reproduce exactly), so
//! the sharded clock is *order-identical* to
//! [`crate::simulator::events::TimedQueue`] by construction:
//!
//! * ONE global sequence counter stamps every push, whichever wheel it
//!   lands in — the same stamps a single queue would have assigned.
//! * [`ShardedClock::pop`] returns the globally minimal `(time, seq)`
//!   entry: each wheel's `next_due` is its own minimum, and the
//!   tournament takes the minimum of those, which is the global
//!   minimum — exactly the entry a single heap would pop.
//!
//! With `sharded = false` every push routes into the single global
//! wheel's heap lane, which IS the legacy one-heap clock (useful as an
//! A/B lever; both modes pop identically anyway).

use std::cmp::Ordering as CmpOrdering;
use std::collections::{BinaryHeap, VecDeque};

/// One timestamped entry; `seq` breaks ties FIFO (same contract as
/// [`crate::simulator::events::TimedQueue`]).
#[derive(Debug, Clone)]
struct Timed<E> {
    time: f64,
    seq: u64,
    event: E,
}

impl<E> Timed<E> {
    fn key(&self) -> (f64, u64) {
        (self.time, self.seq)
    }
}

/// `(time, seq)` min-order: earlier time first, then lower seq.
fn key_lt(a: (f64, u64), b: (f64, u64)) -> bool {
    match a.0.partial_cmp(&b.0).unwrap_or(CmpOrdering::Equal) {
        CmpOrdering::Less => true,
        CmpOrdering::Greater => false,
        CmpOrdering::Equal => a.1 < b.1,
    }
}

impl<E> PartialEq for Timed<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Timed<E> {}

impl<E> Ord for Timed<E> {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        // reversed for min-heap semantics on BinaryHeap (max-heap)
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(CmpOrdering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}
impl<E> PartialOrd for Timed<E> {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}

/// One shard's event store: a sorted FIFO lane for pre-sorted streams
/// (arrival traces) and a heap lane for everything dynamic.
#[derive(Debug)]
pub struct EventWheel<E> {
    sorted: VecDeque<Timed<E>>,
    heap: BinaryHeap<Timed<E>>,
}

impl<E> Default for EventWheel<E> {
    fn default() -> Self {
        EventWheel { sorted: VecDeque::new(), heap: BinaryHeap::new() }
    }
}

impl<E> EventWheel<E> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Heap-lane push (any time order).
    pub fn push(&mut self, time: f64, seq: u64, event: E) {
        self.heap.push(Timed { time, seq, event });
    }

    /// Sorted-lane push for streams already in `(time, seq)` order —
    /// `O(1)`.  An out-of-order push (strictly earlier than the lane's
    /// tail) falls back to the heap lane, preserving correctness if a
    /// caller's "sorted" stream ever regresses.
    pub fn push_sorted(&mut self, time: f64, seq: u64, event: E) {
        match self.sorted.back() {
            Some(back) if key_lt((time, seq), back.key()) => self.push(time, seq, event),
            _ => self.sorted.push_back(Timed { time, seq, event }),
        }
    }

    /// Key of this wheel's earliest entry (its tournament head).
    pub fn next_due(&self) -> Option<(f64, u64)> {
        match (self.sorted.front(), self.heap.peek()) {
            (Some(s), Some(h)) => {
                Some(if key_lt(s.key(), h.key()) { s.key() } else { h.key() })
            }
            (Some(s), None) => Some(s.key()),
            (None, Some(h)) => Some(h.key()),
            (None, None) => None,
        }
    }

    /// Pop this wheel's earliest entry.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        let take_sorted = match (self.sorted.front(), self.heap.peek()) {
            (Some(s), Some(h)) => key_lt(s.key(), h.key()),
            (Some(_), None) => true,
            (None, _) => false,
        };
        if take_sorted {
            self.sorted.pop_front().map(|t| (t.time, t.event))
        } else {
            self.heap.pop().map(|t| (t.time, t.event))
        }
    }

    pub fn len(&self) -> usize {
        self.sorted.len() + self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The fleet DES clock: one [`EventWheel`] per member plus a global
/// wheel, all stamped from one sequence counter (see module docs for
/// the parity argument).
#[derive(Debug)]
pub struct ShardedClock<E> {
    members: Vec<EventWheel<E>>,
    global: EventWheel<E>,
    seq: u64,
    sharded: bool,
}

impl<E> ShardedClock<E> {
    /// A clock over `n_members` shards; `sharded = false` routes every
    /// push into the single global heap (the legacy clock).
    pub fn new(n_members: usize, sharded: bool) -> Self {
        ShardedClock {
            members: (0..n_members).map(|_| EventWheel::new()).collect(),
            global: EventWheel::new(),
            seq: 0,
            sharded,
        }
    }

    fn next_seq(&mut self) -> u64 {
        self.seq += 1;
        self.seq
    }

    /// Push a member-scoped event (heap lane of the member's wheel).
    pub fn push_member(&mut self, member: usize, time: f64, event: E) {
        let seq = self.next_seq();
        if self.sharded {
            self.members[member].push(time, seq, event);
        } else {
            self.global.push(time, seq, event);
        }
    }

    /// Push a member-scoped event whose stream arrives in time order
    /// (arrival traces): `O(1)` on the member's sorted lane.
    pub fn push_member_sorted(&mut self, member: usize, time: f64, event: E) {
        let seq = self.next_seq();
        if self.sharded {
            self.members[member].push_sorted(time, seq, event);
        } else {
            self.global.push(time, seq, event);
        }
    }

    /// Push a global control event (Adapt/Apply/Preempt/Fault/End).
    pub fn push_global(&mut self, time: f64, event: E) {
        let seq = self.next_seq();
        self.global.push(time, seq, event);
    }

    /// Pop the globally earliest `(time, seq)` event — the tournament
    /// over every wheel's `next_due` head.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        let mut best: Option<(usize, (f64, u64))> = self.global.next_due().map(|k| (0, k));
        for (m, wheel) in self.members.iter().enumerate() {
            if let Some(k) = wheel.next_due() {
                let better = match best {
                    None => true,
                    Some((_, bk)) => key_lt(k, bk),
                };
                if better {
                    best = Some((m + 1, k));
                }
            }
        }
        match best {
            Some((0, _)) => self.global.pop(),
            Some((i, _)) => self.members[i - 1].pop(),
            None => None,
        }
    }

    pub fn len(&self) -> usize {
        self.global.len() + self.members.iter().map(EventWheel::len).sum::<usize>()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::events::TimedQueue;
    use crate::util::quickcheck::{check, prop_assert};

    #[test]
    fn wheel_merges_sorted_and_heap_lanes() {
        let mut w: EventWheel<&str> = EventWheel::new();
        w.push_sorted(1.0, 1, "a1");
        w.push_sorted(3.0, 2, "a3");
        w.push(2.0, 3, "h2");
        w.push(0.5, 4, "h0");
        let order: Vec<&str> = std::iter::from_fn(|| w.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["h0", "a1", "h2", "a3"]);
    }

    #[test]
    fn sorted_lane_regression_falls_back_to_heap() {
        let mut w: EventWheel<u32> = EventWheel::new();
        w.push_sorted(5.0, 1, 5);
        w.push_sorted(2.0, 2, 2); // regresses: lands on the heap lane
        w.push_sorted(6.0, 3, 6);
        let order: Vec<u32> = std::iter::from_fn(|| w.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![2, 5, 6]);
    }

    #[test]
    fn ties_pop_fifo_across_shards() {
        let mut c: ShardedClock<u32> = ShardedClock::new(2, true);
        c.push_member(0, 1.0, 10);
        c.push_member(1, 1.0, 20);
        c.push_global(1.0, 30);
        assert_eq!(c.pop(), Some((1.0, 10)));
        assert_eq!(c.pop(), Some((1.0, 20)));
        assert_eq!(c.pop(), Some((1.0, 30)));
        assert_eq!(c.pop(), None);
    }

    /// The parity contract: any interleaving of member-sorted pushes,
    /// member heap pushes, global pushes and pops produces exactly the
    /// single-queue pop order — in BOTH modes.
    #[test]
    fn quickcheck_pop_order_matches_single_timed_queue() {
        for sharded in [false, true] {
            check("sharded clock == single queue", 200, |g| {
                let members = g.usize(1, 5);
                let mut clock: ShardedClock<u64> = ShardedClock::new(members, sharded);
                let mut reference: TimedQueue<u64> = TimedQueue::new();
                // per-member monotone time cursors feed the sorted lane
                let mut cursors = vec![0.0f64; members];
                let n_ops = g.usize(1, 60);
                let mut payload = 0u64;
                for _ in 0..n_ops {
                    match g.usize(0, 4) {
                        0 => {
                            let m = g.usize(0, members);
                            cursors[m] += g.f64(0.0, 3.0);
                            clock.push_member_sorted(m, cursors[m], payload);
                            reference.push(cursors[m], payload);
                            payload += 1;
                        }
                        1 => {
                            let m = g.usize(0, members);
                            let t = g.f64(0.0, 50.0);
                            clock.push_member(m, t, payload);
                            reference.push(t, payload);
                            payload += 1;
                        }
                        2 => {
                            let t = g.f64(0.0, 50.0);
                            clock.push_global(t, payload);
                            reference.push(t, payload);
                            payload += 1;
                        }
                        _ => {
                            prop_assert(clock.pop() == reference.pop(), "pop diverged")?;
                        }
                    }
                }
                while let Some(expected) = reference.pop() {
                    prop_assert(clock.pop() == Some(expected), "drain diverged")?;
                }
                prop_assert(clock.pop().is_none(), "clock not empty after drain")
            });
        }
    }

    #[test]
    fn len_counts_every_lane() {
        let mut c: ShardedClock<u8> = ShardedClock::new(2, true);
        assert!(c.is_empty());
        c.push_member_sorted(0, 1.0, 0);
        c.push_member(1, 2.0, 1);
        c.push_global(3.0, 2);
        assert_eq!(c.len(), 3);
        c.pop();
        assert_eq!(c.len(), 2);
    }
}
