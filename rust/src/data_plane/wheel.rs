//! Sharded DES clock: per-member event wheels merged lazily through a
//! tournament of `next_due` heads.
//!
//! The fleet DES used to serialize every member's events through one
//! `BinaryHeap` whose size is dominated by the pre-materialized arrival
//! stream (tens of thousands of entries → every push/pop pays
//! `O(log total_arrivals)` with cold cache lines).  [`ShardedClock`]
//! splits the stream: each member owns an [`EventWheel`] whose *sorted
//! lane* holds its arrival trace (already time-sorted — `O(1)` push and
//! pop from a `VecDeque`) and whose *heap lane* holds the handful of
//! dynamic events in flight (service completions, queue checks), while
//! global control events (Adapt/Apply/Preempt/Fault/End) ride a
//! dedicated wheel.  Popping is a tournament over the `members + 1`
//! `next_due` heads — a linear scan of a few cached keys instead of a
//! log-depth walk of one giant heap — so the cost per event stays flat
//! as members are added.
//!
//! # Byte-for-byte parity with the single heap
//!
//! Determinism is load-bearing (seeded runs must reproduce exactly), so
//! the sharded clock is *order-identical* to
//! [`crate::simulator::events::TimedQueue`] by construction:
//!
//! * ONE global sequence counter stamps every push, whichever wheel it
//!   lands in — the same stamps a single queue would have assigned.
//! * [`ShardedClock::pop`] returns the globally minimal `(time, seq)`
//!   entry: each wheel's `next_due` is its own minimum, and the
//!   tournament takes the minimum of those, which is the global
//!   minimum — exactly the entry a single heap would pop.
//!
//! With `sharded = false` every push routes into the single global
//! wheel's heap lane, which IS the legacy one-heap clock (useful as an
//! A/B lever; both modes pop identically anyway).
//!
//! # Epoch-parallel draining
//!
//! The epoch-parallel fleet driver (see [`crate::simulator::sim`])
//! advances every member concurrently between two global control
//! events.  The clock supports that with three pieces:
//!
//! * [`EventWheel::pop_until`] — a bounded drain that pops only entries
//!   whose `(time, seq)` key orders strictly before the barrier event's
//!   key, so each worker can exhaust its member's wheel up to (never
//!   past) the next global event, with exact tie parity: an entry AT
//!   the barrier instant drains before or after the barrier according
//!   to its sequence stamp, just as the sequential pop order would.
//! * [`ShardedClock::lanes_mut`] — hands the member wheels out as a
//!   mutable slice so `scoped_map_mut` can give each worker a disjoint
//!   `&mut EventWheel`.
//! * Per-epoch sequence sub-ranges — workers cannot share the global
//!   `seq` counter without racing, so [`ShardedClock::begin_epoch`]
//!   snapshots it and each member `m` stamps its in-epoch pushes
//!   `base + 1 + m * EPOCH_SEQ_STRIDE + k` (`k` = push count so far).
//!   [`ShardedClock::end_epoch`] then jumps the shared counter past
//!   every sub-range.  Stamps stay strictly increasing per member and
//!   globally unique, so `(time, seq)` ordering — and therefore replay
//!   — is identical at any worker count.

use std::cmp::Ordering as CmpOrdering;
use std::collections::{BinaryHeap, VecDeque};

/// One timestamped entry; `seq` breaks ties FIFO (same contract as
/// [`crate::simulator::events::TimedQueue`]).
#[derive(Debug, Clone)]
struct Timed<E> {
    time: f64,
    seq: u64,
    event: E,
}

impl<E> Timed<E> {
    fn key(&self) -> (f64, u64) {
        (self.time, self.seq)
    }
}

/// `(time, seq)` min-order: earlier time first, then lower seq.
fn key_lt(a: (f64, u64), b: (f64, u64)) -> bool {
    match a.0.partial_cmp(&b.0).unwrap_or(CmpOrdering::Equal) {
        CmpOrdering::Less => true,
        CmpOrdering::Greater => false,
        CmpOrdering::Equal => a.1 < b.1,
    }
}

impl<E> PartialEq for Timed<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Timed<E> {}

impl<E> Ord for Timed<E> {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        // reversed for min-heap semantics on BinaryHeap (max-heap)
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(CmpOrdering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}
impl<E> PartialOrd for Timed<E> {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}

/// One shard's event store: a sorted FIFO lane for pre-sorted streams
/// (arrival traces) and a heap lane for everything dynamic.
#[derive(Debug)]
pub struct EventWheel<E> {
    sorted: VecDeque<Timed<E>>,
    heap: BinaryHeap<Timed<E>>,
}

impl<E> Default for EventWheel<E> {
    fn default() -> Self {
        EventWheel { sorted: VecDeque::new(), heap: BinaryHeap::new() }
    }
}

impl<E> EventWheel<E> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Heap-lane push (any time order).
    pub fn push(&mut self, time: f64, seq: u64, event: E) {
        self.heap.push(Timed { time, seq, event });
    }

    /// Sorted-lane push for streams already in `(time, seq)` order —
    /// `O(1)`.  An out-of-order push (strictly earlier than the lane's
    /// tail) falls back to the heap lane, preserving correctness if a
    /// caller's "sorted" stream ever regresses.
    pub fn push_sorted(&mut self, time: f64, seq: u64, event: E) {
        match self.sorted.back() {
            Some(back) if key_lt((time, seq), back.key()) => self.push(time, seq, event),
            _ => self.sorted.push_back(Timed { time, seq, event }),
        }
    }

    /// Key of this wheel's earliest entry (its tournament head).
    pub fn next_due(&self) -> Option<(f64, u64)> {
        match (self.sorted.front(), self.heap.peek()) {
            (Some(s), Some(h)) => {
                Some(if key_lt(s.key(), h.key()) { s.key() } else { h.key() })
            }
            (Some(s), None) => Some(s.key()),
            (None, Some(h)) => Some(h.key()),
            (None, None) => None,
        }
    }

    /// Pop this wheel's earliest entry if its `(time, seq)` key orders
    /// strictly before `barrier` — the bounded drain the epoch-parallel
    /// driver uses to advance one member up to (never past) the next
    /// global control event.  Comparing full keys (not just times)
    /// keeps exact parity with the sequential pop order even when an
    /// entry is timestamped at the barrier instant: a lower sequence
    /// stamp drains before the barrier, a higher one after, exactly as
    /// a single global pop loop would have interleaved them.
    pub fn pop_until(&mut self, barrier: (f64, u64)) -> Option<(f64, E)> {
        match self.next_due() {
            Some(k) if key_lt(k, barrier) => self.pop(),
            _ => None,
        }
    }

    /// Pop this wheel's earliest entry.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        let take_sorted = match (self.sorted.front(), self.heap.peek()) {
            (Some(s), Some(h)) => key_lt(s.key(), h.key()),
            (Some(_), None) => true,
            (None, _) => false,
        };
        if take_sorted {
            self.sorted.pop_front().map(|t| (t.time, t.event))
        } else {
            self.heap.pop().map(|t| (t.time, t.event))
        }
    }

    pub fn len(&self) -> usize {
        self.sorted.len() + self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Width of each member's per-epoch sequence sub-range (~1M dynamic
/// pushes per member per epoch — far above anything a real epoch
/// generates; the worker asserts it never overflows).  Wide enough
/// that even a 100k-member fleet over millions of epochs stays below
/// `u64::MAX`.
pub const EPOCH_SEQ_STRIDE: u64 = 1 << 20;

/// Cached tournament state: the wheel holding the global minimum and
/// the runner-up head among the OTHER wheels.  Wheel index 0 is the
/// global wheel, `i + 1` is member `i`.
#[derive(Debug, Clone, Copy)]
struct PopCache {
    best: (usize, (f64, u64)),
    second: Option<(usize, (f64, u64))>,
}

/// The fleet DES clock: one [`EventWheel`] per member plus a global
/// wheel, all stamped from one sequence counter (see module docs for
/// the parity argument).
#[derive(Debug)]
pub struct ShardedClock<E> {
    members: Vec<EventWheel<E>>,
    global: EventWheel<E>,
    seq: u64,
    sharded: bool,
    /// Best + runner-up tournament cache so [`ShardedClock::pop`] is
    /// `O(1)` amortized instead of re-scanning `members + 1` heads on
    /// every pop.  `None` = stale (rebuilt lazily on the next pop).
    cache: Option<PopCache>,
}

impl<E> ShardedClock<E> {
    /// A clock over `n_members` shards; `sharded = false` routes every
    /// push into the single global heap (the legacy clock).
    pub fn new(n_members: usize, sharded: bool) -> Self {
        ShardedClock {
            members: (0..n_members).map(|_| EventWheel::new()).collect(),
            global: EventWheel::new(),
            seq: 0,
            sharded,
            cache: None,
        }
    }

    fn next_seq(&mut self) -> u64 {
        self.seq += 1;
        self.seq
    }

    fn wheel(&self, w: usize) -> &EventWheel<E> {
        if w == 0 {
            &self.global
        } else {
            &self.members[w - 1]
        }
    }

    fn wheel_mut(&mut self, w: usize) -> &mut EventWheel<E> {
        if w == 0 {
            &mut self.global
        } else {
            &mut self.members[w - 1]
        }
    }

    /// Full `members + 1` tournament: the overall minimum head plus
    /// the runner-up among the remaining wheels.
    fn rescan(&self) -> Option<PopCache> {
        let mut best: Option<(usize, (f64, u64))> = None;
        let mut second: Option<(usize, (f64, u64))> = None;
        let heads = std::iter::once(self.global.next_due())
            .chain(self.members.iter().map(EventWheel::next_due));
        for (w, head) in heads.enumerate() {
            let Some(k) = head else { continue };
            match best {
                None => best = Some((w, k)),
                Some((_, bk)) if key_lt(k, bk) => {
                    second = best;
                    best = Some((w, k));
                }
                Some(_) => {
                    if second.is_none_or(|(_, sk)| key_lt(k, sk)) {
                        second = Some((w, k));
                    }
                }
            }
        }
        best.map(|b| PopCache { best: b, second })
    }

    /// Incrementally fold a push into wheel `w` into the cache.  A
    /// push can only move `w`'s head EARLIER, so each case is a local
    /// update — the invariant (`best` = overall min head, `second` =
    /// min head among the other wheels) is preserved without a rescan.
    fn pushed(&mut self, w: usize) {
        let Some(mut c) = self.cache else { return };
        let head = match self.wheel(w).next_due() {
            Some(h) => h,
            None => return, // unreachable: the wheel was just pushed to
        };
        if w == c.best.0 {
            // the leader's min only moved earlier; still the leader
            c.best.1 = head;
        } else if key_lt(head, c.best.1) {
            // lead change: the old leader becomes the runner-up (it
            // was the minimum among all other wheels)
            c.second = Some(c.best);
            c.best = (w, head);
        } else if c.second.is_none_or(|(sw, sk)| w == sw || key_lt(head, sk)) {
            c.second = Some((w, head));
        }
        self.cache = Some(c);
    }

    /// Push a member-scoped event (heap lane of the member's wheel).
    pub fn push_member(&mut self, member: usize, time: f64, event: E) {
        let seq = self.next_seq();
        let w = if self.sharded { member + 1 } else { 0 };
        self.wheel_mut(w).push(time, seq, event);
        self.pushed(w);
    }

    /// Push a member-scoped event whose stream arrives in time order
    /// (arrival traces): `O(1)` on the member's sorted lane.
    pub fn push_member_sorted(&mut self, member: usize, time: f64, event: E) {
        let seq = self.next_seq();
        if self.sharded {
            self.members[member].push_sorted(time, seq, event);
            self.pushed(member + 1);
        } else {
            self.global.push(time, seq, event);
            self.pushed(0);
        }
    }

    /// Push a global control event (Adapt/Apply/Preempt/Fault/End).
    pub fn push_global(&mut self, time: f64, event: E) {
        let seq = self.next_seq();
        self.global.push(time, seq, event);
        self.pushed(0);
    }

    /// Pop the globally earliest `(time, seq)` event — the tournament
    /// over every wheel's `next_due` head, served from the best +
    /// runner-up cache.  After the pop, the winning wheel's new head
    /// either keeps the lead (compare against the cached runner-up,
    /// `O(1)`) or the lead changes and the tournament re-runs; bursts
    /// of same-member activity therefore pop in `O(1)` amortized.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        let c = match self.cache {
            Some(c) => c,
            None => match self.rescan() {
                Some(c) => {
                    self.cache = Some(c);
                    c
                }
                None => return None,
            },
        };
        let out = self.wheel_mut(c.best.0).pop();
        self.cache = match (self.wheel(c.best.0).next_due(), c.second) {
            // the popped wheel still leads: only its head moved
            (Some(h), Some((_, sk))) if key_lt(h, sk) => {
                Some(PopCache { best: (c.best.0, h), second: c.second })
            }
            (Some(h), None) => Some(PopCache { best: (c.best.0, h), second: None }),
            // lead change (or the leader drained): full tournament
            _ => self.rescan(),
        };
        out
    }

    /// Key of the earliest pending GLOBAL control event — the next
    /// barrier time for the epoch-parallel driver.
    pub fn global_next_due(&self) -> Option<(f64, u64)> {
        self.global.next_due()
    }

    /// Pop the earliest GLOBAL control event, ignoring member wheels
    /// (the epoch driver has already drained them up to the barrier).
    pub fn pop_global(&mut self) -> Option<(f64, E)> {
        self.cache = None;
        self.global.pop()
    }

    /// The member wheels as a mutable slice, for the epoch-parallel
    /// driver to hand each worker a disjoint `&mut`.  Invalidates the
    /// tournament cache (heads may change out from under it).
    pub fn lanes_mut(&mut self) -> &mut [EventWheel<E>] {
        self.cache = None;
        &mut self.members
    }

    /// Snapshot the sequence counter at an epoch boundary.  Worker `m`
    /// stamps its in-epoch pushes `base + 1 + m * EPOCH_SEQ_STRIDE + k`
    /// (`k` = 0, 1, …) directly into its wheel via [`Self::lanes_mut`].
    pub fn begin_epoch(&self) -> u64 {
        self.seq
    }

    /// Close an epoch opened at `base`: jump the shared counter past
    /// every member's sub-range so post-epoch stamps stay above all
    /// in-epoch stamps.
    pub fn end_epoch(&mut self, base: u64, n_members: usize) {
        self.seq = base + (n_members as u64) * EPOCH_SEQ_STRIDE;
    }

    pub fn len(&self) -> usize {
        self.global.len() + self.members.iter().map(EventWheel::len).sum::<usize>()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::events::TimedQueue;
    use crate::util::quickcheck::{check, prop_assert};

    #[test]
    fn wheel_merges_sorted_and_heap_lanes() {
        let mut w: EventWheel<&str> = EventWheel::new();
        w.push_sorted(1.0, 1, "a1");
        w.push_sorted(3.0, 2, "a3");
        w.push(2.0, 3, "h2");
        w.push(0.5, 4, "h0");
        let order: Vec<&str> = std::iter::from_fn(|| w.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["h0", "a1", "h2", "a3"]);
    }

    #[test]
    fn sorted_lane_regression_falls_back_to_heap() {
        let mut w: EventWheel<u32> = EventWheel::new();
        w.push_sorted(5.0, 1, 5);
        w.push_sorted(2.0, 2, 2); // regresses: lands on the heap lane
        w.push_sorted(6.0, 3, 6);
        let order: Vec<u32> = std::iter::from_fn(|| w.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![2, 5, 6]);
    }

    #[test]
    fn ties_pop_fifo_across_shards() {
        let mut c: ShardedClock<u32> = ShardedClock::new(2, true);
        c.push_member(0, 1.0, 10);
        c.push_member(1, 1.0, 20);
        c.push_global(1.0, 30);
        assert_eq!(c.pop(), Some((1.0, 10)));
        assert_eq!(c.pop(), Some((1.0, 20)));
        assert_eq!(c.pop(), Some((1.0, 30)));
        assert_eq!(c.pop(), None);
    }

    /// The parity contract: any interleaving of member-sorted pushes,
    /// member heap pushes, global pushes and pops produces exactly the
    /// single-queue pop order — in BOTH modes.
    #[test]
    fn quickcheck_pop_order_matches_single_timed_queue() {
        for sharded in [false, true] {
            check("sharded clock == single queue", 200, |g| {
                let members = g.usize(1, 5);
                let mut clock: ShardedClock<u64> = ShardedClock::new(members, sharded);
                let mut reference: TimedQueue<u64> = TimedQueue::new();
                // per-member monotone time cursors feed the sorted lane
                let mut cursors = vec![0.0f64; members];
                let n_ops = g.usize(1, 60);
                let mut payload = 0u64;
                for _ in 0..n_ops {
                    match g.usize(0, 4) {
                        0 => {
                            let m = g.usize(0, members);
                            cursors[m] += g.f64(0.0, 3.0);
                            clock.push_member_sorted(m, cursors[m], payload);
                            reference.push(cursors[m], payload);
                            payload += 1;
                        }
                        1 => {
                            let m = g.usize(0, members);
                            let t = g.f64(0.0, 50.0);
                            clock.push_member(m, t, payload);
                            reference.push(t, payload);
                            payload += 1;
                        }
                        2 => {
                            let t = g.f64(0.0, 50.0);
                            clock.push_global(t, payload);
                            reference.push(t, payload);
                            payload += 1;
                        }
                        _ => {
                            prop_assert(clock.pop() == reference.pop(), "pop diverged")?;
                        }
                    }
                }
                while let Some(expected) = reference.pop() {
                    prop_assert(clock.pop() == Some(expected), "drain diverged")?;
                }
                prop_assert(clock.pop().is_none(), "clock not empty after drain")
            });
        }
    }

    #[test]
    fn pop_until_stops_strictly_before_the_barrier_key() {
        let mut w: EventWheel<u32> = EventWheel::new();
        w.push_sorted(1.0, 1, 1);
        w.push(2.0, 2, 2);
        w.push_sorted(3.0, 3, 3); // tied with the barrier TIME, lower seq
        w.push(3.0, 5, 5); // tied with the barrier time, higher seq
        let mut drained = Vec::new();
        while let Some((_, e)) = w.pop_until((3.0, 4)) {
            drained.push(e);
        }
        // the lower-seq tie drains pre-barrier (it would pop before the
        // barrier event in sequential order); the higher-seq tie defers
        assert_eq!(drained, vec![1, 2, 3]);
        assert_eq!(w.pop(), Some((3.0, 5)));
    }

    /// `pop_until(barrier)` drains exactly the prefix `pop` would.
    #[test]
    fn quickcheck_pop_until_drains_the_pop_prefix() {
        check("pop_until == pop prefix", 200, |g| {
            let mut a: EventWheel<u64> = EventWheel::new();
            let mut b: EventWheel<u64> = EventWheel::new();
            let mut cursor = 0.0f64;
            for seq in 0..g.usize(1, 40) as u64 {
                if g.usize(0, 2) == 0 {
                    cursor += g.f64(0.0, 3.0);
                    a.push_sorted(cursor, seq, seq);
                    b.push_sorted(cursor, seq, seq);
                } else {
                    let t = g.f64(0.0, 30.0);
                    a.push(t, seq, seq);
                    b.push(t, seq, seq);
                }
            }
            let barrier = (g.f64(0.0, 30.0), g.usize(0, 40) as u64);
            while let Some(got) = a.pop_until(barrier) {
                prop_assert(b.pop() == Some(got), "pop_until diverged from pop")?;
            }
            // everything left orders at/after the barrier key
            match a.next_due() {
                Some(k) => prop_assert(!key_lt(k, barrier), "undrained event before barrier"),
                None => prop_assert(b.pop().is_none(), "pop_until stopped early"),
            }
        });
    }

    #[test]
    fn epoch_seq_ranges_stay_ordered_and_unique() {
        let mut c: ShardedClock<u32> = ShardedClock::new(2, true);
        c.push_member_sorted(0, 1.0, 0);
        let base = c.begin_epoch();
        assert_eq!(base, 1);
        // workers stamp into their own sub-ranges via lanes_mut
        let lanes = c.lanes_mut();
        lanes[0].push(2.0, base + 1, 10);
        lanes[1].push(2.5, base + 1 + EPOCH_SEQ_STRIDE, 20);
        c.end_epoch(base, 2);
        // the next shared stamp lands above every in-epoch stamp
        c.push_global(2.75, 30);
        assert_eq!(c.pop(), Some((1.0, 0)));
        assert_eq!(c.pop(), Some((2.0, 10)));
        assert_eq!(c.pop(), Some((2.5, 20)));
        assert_eq!(c.pop(), Some((2.75, 30)));
        assert_eq!(c.pop(), None);
    }

    #[test]
    fn pop_global_skips_member_wheels() {
        let mut c: ShardedClock<u32> = ShardedClock::new(1, true);
        c.push_member(0, 1.0, 1);
        c.push_global(5.0, 2);
        assert_eq!(c.global_next_due().map(|(t, _)| t), Some(5.0));
        assert_eq!(c.pop_global(), Some((5.0, 2)));
        // the member event is still there and the cache recovered
        assert_eq!(c.pop(), Some((1.0, 1)));
        assert_eq!(c.pop(), None);
    }

    #[test]
    fn len_counts_every_lane() {
        let mut c: ShardedClock<u8> = ShardedClock::new(2, true);
        assert!(c.is_empty());
        c.push_member_sorted(0, 1.0, 0);
        c.push_member(1, 2.0, 1);
        c.push_global(3.0, 2);
        assert_eq!(c.len(), 3);
        c.pop();
        assert_eq!(c.len(), 2);
    }
}
