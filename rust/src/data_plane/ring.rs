//! Bounded lock-free MPSC ring queue — the per-(member, stage) arrival
//! lane of the sharded live engine.
//!
//! Classic sequence-stamped bounded queue (Vyukov's bounded MPMC shape)
//! used under a multi-producer / serialized-consumer discipline: any
//! thread may [`MpscRing::try_push`]; [`MpscRing::pop`] is CAS-guarded,
//! so the occasional concurrent drain (several workers racing to empty
//! the same lane) is still safe, but throughput assumes pops are mostly
//! serialized (in the engine they happen under the short core lock).
//!
//! # Memory-ordering contract
//!
//! Each slot carries an [`AtomicUsize`] sequence stamp `seq` next to an
//! [`UnsafeCell`] payload.  For slot index `i` of a ring with capacity
//! `cap` (power of two), the stamp cycles through:
//!
//! * `pos`        — slot empty, writable by the producer claiming `pos`
//! * `pos + 1`    — slot full, readable by the consumer claiming `pos`
//! * `pos + cap`  — slot empty again for the NEXT lap (`pos + cap`)
//!
//! Orderings, and why each suffices:
//!
//! * **`seq` load: `Acquire`** (both sides) — pairs with the `Release`
//!   stores below so that observing "full" (`seq == pos + 1`) makes the
//!   producer's payload write visible, and observing "empty for my lap"
//!   (`seq == pos`) makes the previous consumer's read retirement
//!   visible (the slot really is dead before we overwrite it).
//! * **`tail`/`head` CAS: `Relaxed`** — the cursors only *claim* a
//!   position; they publish no data.  All payload visibility is
//!   mediated by the slot stamp, so the claim itself needs no ordering
//!   (failure ordering likewise `Relaxed`; the loop re-reads).
//! * **`seq` store after a payload write: `Release`** (`pos + 1`) —
//!   publishes the value to the consumer's `Acquire` load.
//! * **`seq` store after a payload read: `Release`** (`pos + cap`) —
//!   publishes the slot's emptiness to the producer that will reuse it
//!   one lap later, ordering the read before the overwrite.
//!
//! Fullness is detected without any cross-cursor read: a producer that
//! finds `seq < pos` is a whole lap ahead of the consumer and fails
//! with `Err(value)` — the caller decides whether to shed (see
//! [`crate::data_plane::ingress::shed`]) or fall back to the locked
//! path.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicUsize, Ordering};

struct Slot<T> {
    seq: AtomicUsize,
    val: UnsafeCell<MaybeUninit<T>>,
}

/// Bounded lock-free ring queue (multi-producer push, CAS-guarded pop).
pub struct MpscRing<T> {
    buf: Box<[Slot<T>]>,
    mask: usize,
    /// Producer cursor: next position to claim for a push.
    tail: AtomicUsize,
    /// Consumer cursor: next position to claim for a pop.
    head: AtomicUsize,
}

// SAFETY: values move across threads whole (a slot is written by
// exactly one claiming producer and read by exactly one claiming
// consumer, handshaked through `seq`), so `T: Send` is the only
// requirement; the ring itself holds no `&T` aliases.
unsafe impl<T: Send> Send for MpscRing<T> {}
unsafe impl<T: Send> Sync for MpscRing<T> {}

impl<T> MpscRing<T> {
    /// A ring holding at least `capacity` items (rounded up to a power
    /// of two, minimum 2).
    pub fn with_capacity(capacity: usize) -> Self {
        let cap = capacity.max(2).next_power_of_two();
        let buf: Vec<Slot<T>> = (0..cap)
            .map(|i| Slot {
                seq: AtomicUsize::new(i),
                val: UnsafeCell::new(MaybeUninit::uninit()),
            })
            .collect();
        MpscRing {
            buf: buf.into_boxed_slice(),
            mask: cap - 1,
            tail: AtomicUsize::new(0),
            head: AtomicUsize::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// Push from any thread; `Err(value)` when the ring is full (the
    /// value comes back so the caller can shed or take the slow path).
    pub fn try_push(&self, value: T) -> Result<(), T> {
        let mut pos = self.tail.load(Ordering::Relaxed);
        loop {
            let slot = &self.buf[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - pos as isize;
            if diff == 0 {
                // Slot empty for this lap: claim `pos` (Relaxed — the
                // stamp, not the cursor, publishes the payload).
                match self.tail.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: winning the CAS makes this thread the
                        // unique writer of slot `pos` until the Release
                        // store below hands it to the consumer.
                        unsafe { (*slot.val.get()).write(value) };
                        slot.seq.store(pos + 1, Ordering::Release);
                        return Ok(());
                    }
                    Err(cur) => pos = cur,
                }
            } else if diff < 0 {
                // The consumer hasn't freed this slot from the previous
                // lap: the ring is full.
                return Err(value);
            } else {
                // Another producer claimed `pos`; chase the cursor.
                pos = self.tail.load(Ordering::Relaxed);
            }
        }
    }

    /// Pop the oldest item, `None` when empty.  CAS-guarded so racing
    /// consumers are safe; the engine serializes drains under the core
    /// lock anyway.
    pub fn pop(&self) -> Option<T> {
        let mut pos = self.head.load(Ordering::Relaxed);
        loop {
            let slot = &self.buf[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - (pos + 1) as isize;
            if diff == 0 {
                match self.head.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: winning the CAS makes this thread the
                        // unique reader of slot `pos`; the producer's
                        // Release store (observed Acquire above) made
                        // the payload visible.
                        let value = unsafe { (*slot.val.get()).assume_init_read() };
                        slot.seq.store(pos + self.mask + 1, Ordering::Release);
                        return Some(value);
                    }
                    Err(cur) => pos = cur,
                }
            } else if diff < 0 {
                return None;
            } else {
                pos = self.head.load(Ordering::Relaxed);
            }
        }
    }

    /// Snapshot emptiness (exact only while producers/consumers are
    /// quiescent — good enough for drain loops and tests).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot occupancy (same caveat as [`MpscRing::is_empty`]).
    pub fn len(&self) -> usize {
        let tail = self.tail.load(Ordering::Acquire);
        let head = self.head.load(Ordering::Acquire);
        tail.saturating_sub(head)
    }
}

impl<T> Drop for MpscRing<T> {
    fn drop(&mut self) {
        // Retire whatever is still queued so payload destructors run.
        while self.pop().is_some() {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_single_producer() {
        let r: MpscRing<u64> = MpscRing::with_capacity(8);
        for i in 0..8 {
            r.try_push(i).unwrap();
        }
        for i in 0..8 {
            assert_eq!(r.pop(), Some(i));
        }
        assert_eq!(r.pop(), None);
    }

    #[test]
    fn full_returns_value_and_frees_after_pop() {
        let r: MpscRing<u64> = MpscRing::with_capacity(2);
        r.try_push(1).unwrap();
        r.try_push(2).unwrap();
        assert_eq!(r.try_push(3), Err(3));
        assert_eq!(r.pop(), Some(1));
        r.try_push(3).unwrap();
        assert_eq!(r.pop(), Some(2));
        assert_eq!(r.pop(), Some(3));
    }

    #[test]
    fn capacity_rounds_to_power_of_two() {
        let r: MpscRing<u8> = MpscRing::with_capacity(5);
        assert_eq!(r.capacity(), 8);
        let r: MpscRing<u8> = MpscRing::with_capacity(0);
        assert_eq!(r.capacity(), 2);
    }

    #[test]
    fn wraps_many_laps() {
        let r: MpscRing<usize> = MpscRing::with_capacity(4);
        for i in 0..1000 {
            r.try_push(i).unwrap();
            assert_eq!(r.pop(), Some(i));
        }
        assert!(r.is_empty());
    }

    #[test]
    fn drop_releases_queued_values() {
        let r: MpscRing<Arc<u32>> = MpscRing::with_capacity(4);
        let v = Arc::new(7u32);
        r.try_push(Arc::clone(&v)).unwrap();
        r.try_push(Arc::clone(&v)).unwrap();
        assert_eq!(Arc::strong_count(&v), 3);
        drop(r);
        assert_eq!(Arc::strong_count(&v), 1);
    }

    #[test]
    fn concurrent_producers_deliver_exactly_once() {
        let r = Arc::new(MpscRing::<u64>::with_capacity(1024));
        let producers = 4u64;
        let per = 5_000u64;
        let handles: Vec<_> = (0..producers)
            .map(|p| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    for i in 0..per {
                        let mut v = (p << 32) | i;
                        loop {
                            match r.try_push(v) {
                                Ok(()) => break,
                                Err(back) => {
                                    v = back;
                                    std::thread::yield_now();
                                }
                            }
                        }
                    }
                })
            })
            .collect();
        let mut got = Vec::new();
        while got.len() < (producers * per) as usize {
            match r.pop() {
                Some(v) => got.push(v),
                None => std::thread::yield_now(),
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.pop(), None);
        // exactly-once delivery + per-producer FIFO
        let mut next = vec![0u64; producers as usize];
        for v in got {
            let (p, i) = ((v >> 32) as usize, v & 0xFFFF_FFFF);
            assert_eq!(i, next[p], "producer {p} out of order");
            next[p] += 1;
        }
        assert!(next.iter().all(|&n| n == per));
    }
}
