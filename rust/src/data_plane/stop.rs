//! Shutdown gate: a stop flag whose sleepers wake *immediately* on
//! [`StopGate::stop`] instead of polling (the live engine's adapter
//! thread used to spin a 50 ms check loop; now it parks on the gate's
//! condvar for the full interval and shutdown interrupts it).
//!
//! No-lost-wakeup protocol:
//!
//! 1. The sleeper takes the gate mutex, re-checks the flag, and only
//!    then waits on the condvar — so a concurrent `stop` either lands
//!    before the check (sleeper returns without waiting) or after the
//!    sleeper is parked (the notify wakes it): there is no window where
//!    the flag is set but the sleeper still commits to a full wait.
//! 2. `stop` sets the flag (`Release`) BEFORE acquiring the mutex and
//!    notifying, so a woken sleeper's flag load (`Acquire`) observes it.
//!
//! The flag doubles as a cheap lock-free poll ([`StopGate::is_stopped`])
//! for hot loops that only need an eventual exit.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One-way stop flag with condvar-interruptible sleeps.
#[derive(Default)]
pub struct StopGate {
    stopped: AtomicBool,
    lock: Mutex<()>,
    cv: Condvar,
}

impl StopGate {
    pub fn new() -> Self {
        Self::default()
    }

    /// Lock-free check for hot loops.
    pub fn is_stopped(&self) -> bool {
        self.stopped.load(Ordering::Acquire)
    }

    /// Trip the gate and wake every sleeper immediately.
    pub fn stop(&self) {
        self.stopped.store(true, Ordering::Release);
        // Acquiring the mutex orders this notify after any in-progress
        // check-then-wait (see module docs, step 1).
        let _guard = self.lock.lock().unwrap();
        self.cv.notify_all();
    }

    /// Sleep `secs`, returning early (with `false`) if stopped; `true`
    /// when the full duration elapsed.
    pub fn sleep_interruptible(&self, secs: f64) -> bool {
        let deadline = Instant::now() + Duration::from_secs_f64(secs.max(0.0));
        let mut guard = self.lock.lock().unwrap();
        loop {
            if self.is_stopped() {
                return false;
            }
            let now = Instant::now();
            if now >= deadline {
                return true;
            }
            let (g, _timeout) = self.cv.wait_timeout(guard, deadline - now).unwrap();
            guard = g;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn full_sleep_without_stop() {
        let gate = StopGate::new();
        let t0 = Instant::now();
        assert!(gate.sleep_interruptible(0.05));
        assert!(t0.elapsed() >= Duration::from_millis(45));
    }

    #[test]
    fn stop_wakes_sleeper_immediately() {
        let gate = Arc::new(StopGate::new());
        let g = Arc::clone(&gate);
        let t0 = Instant::now();
        let h = std::thread::spawn(move || g.sleep_interruptible(10.0));
        std::thread::sleep(Duration::from_millis(30));
        gate.stop();
        assert!(!h.join().unwrap(), "stopped sleep must report false");
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "stop took {:?} — sleeper did not wake promptly",
            t0.elapsed()
        );
    }

    #[test]
    fn stopped_gate_never_sleeps() {
        let gate = StopGate::new();
        gate.stop();
        let t0 = Instant::now();
        assert!(!gate.sleep_interruptible(5.0));
        assert!(t0.elapsed() < Duration::from_millis(500));
        assert!(gate.is_stopped());
    }
}
