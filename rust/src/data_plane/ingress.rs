//! Lock-free ingress lanes for the live engine: one bounded
//! [`MpscRing`] per (member, stage), so arrival and forwarding threads
//! enqueue requests without touching the core mutex.
//!
//! The flow (see [`crate::serving::engine`]):
//!
//! * the load generator stamps a [`Request`] with its arrival time and
//!   pushes it onto lane (member, 0) — [`LaneGrid::ingest`] — without
//!   taking any lock; a full lane reports `false` and the caller sheds
//!   the request with accounting ([`shed`]);
//! * a worker finishing stage `s` pre-stamps the survivors'
//!   `stage_arrival` and pushes them onto lane (member, `s+1`) —
//!   [`LaneGrid::forward`] — returning any leftovers (ring full) for
//!   the caller's locked fallback, so forwards are never lost;
//! * each worker, already holding the short core lock for a batch
//!   attempt, drains its own lane into the core —
//!   [`LaneGrid::drain_into`] — replaying the ORIGINAL timestamps
//!   (`Request::arrival` / `Request::stage_arrival`), so ages, drop
//!   decisions and batch timeouts are computed exactly as if the
//!   request had entered the core at its true arrival instant.
//!
//! The grid itself is immutable after construction (rings are interior
//! mutability), so it shares freely across threads.

use crate::cluster::core::ClusterCore;
use crate::data_plane::ring::MpscRing;
use crate::queueing::Request;

/// Default per-lane capacity: generous enough that a healthy run never
/// sheds (drains happen at batch cadence), small enough to bound a
/// stalled stage's memory.
pub const DEFAULT_LANE_CAPACITY: usize = 4096;

/// One ring per (member, stage), member-major.
pub struct LaneGrid {
    lanes: Vec<MpscRing<Request>>,
    /// Lane-index offset per member (prefix sums of stage counts).
    offsets: Vec<usize>,
}

impl LaneGrid {
    /// A grid over `stages_per_member` (one entry per member), each
    /// lane holding `capacity` requests.
    pub fn new(stages_per_member: &[usize], capacity: usize) -> Self {
        let mut offsets = Vec::with_capacity(stages_per_member.len());
        let mut total = 0usize;
        for &s in stages_per_member {
            offsets.push(total);
            total += s;
        }
        LaneGrid {
            lanes: (0..total).map(|_| MpscRing::with_capacity(capacity)).collect(),
            offsets,
        }
    }

    /// Single-pipeline convenience: one member with `n_stages` lanes.
    pub fn single(n_stages: usize, capacity: usize) -> Self {
        Self::new(&[n_stages], capacity)
    }

    fn lane(&self, member: usize, stage: usize) -> &MpscRing<Request> {
        &self.lanes[self.offsets[member] + stage]
    }

    /// Enqueue a fresh arrival on (member, stage 0) — lock-free.
    /// `false` when the lane is full (caller sheds, see [`shed`]).
    pub fn ingest(&self, member: usize, id: u64, t: f64) -> bool {
        self.lane(member, 0)
            .try_push(Request { id, arrival: t, stage_arrival: t })
            .is_ok()
    }

    /// Enqueue batch survivors on (member, stage) — lock-free.  The
    /// caller pre-stamps `stage_arrival` with the service-done instant.
    /// Returns the requests that did NOT fit (ring full), in order, for
    /// the caller's locked fallback.
    pub fn forward(&self, member: usize, stage: usize, requests: Vec<Request>) -> Vec<Request> {
        let lane = self.lane(member, stage);
        let mut leftovers = Vec::new();
        for r in requests {
            if let Err(r) = lane.try_push(r) {
                leftovers.push(r);
            }
        }
        leftovers
    }

    /// Drain up to `limit` queued requests from (member, stage) into
    /// the core, replaying original timestamps.  The caller holds the
    /// core lock.  Returns how many were drained.
    pub fn drain_into(
        &self,
        member: usize,
        stage: usize,
        core: &mut ClusterCore,
        limit: usize,
    ) -> usize {
        let lane = self.lane(member, stage);
        let mut drained = 0;
        while drained < limit {
            let Some(r) = lane.pop() else { break };
            if stage == 0 {
                core.ingest(r.id, r.arrival);
            } else {
                let at = r.stage_arrival;
                core.forward(stage, r, at);
            }
            drained += 1;
        }
        drained
    }

    /// Queued requests on (member, stage) (snapshot — see
    /// [`MpscRing::len`]).
    pub fn queued(&self, member: usize, stage: usize) -> usize {
        self.lane(member, stage).len()
    }
}

/// Account a request shed at ingress because its lane was full: it
/// arrived (so demand metrics see it) and was dropped (so the §4.5 drop
/// counters — the same ledger the [`crate::cluster::drop_policy`] path
/// feeds — own it).  The caller holds the core lock.
pub fn shed(core: &mut ClusterCore, id: u64, t: f64) {
    core.accounting.record_arrival(id, t);
    core.accounting.record_drop(id);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::drop_policy::DropPolicy;
    use crate::optimizer::ip::{PipelineConfig, StageConfig};
    use crate::resources::ResourceVec;

    fn two_stage_core() -> ClusterCore {
        let config = PipelineConfig {
            stages: (0..2)
                .map(|i| StageConfig {
                    variant_idx: 0,
                    variant_key: format!("v{i}"),
                    batch: 4,
                    replicas: 1,
                    cost: 1.0,
                    accuracy: 90.0,
                    latency: 0.1,
                    resources: ResourceVec::cpu(1.0),
                })
                .collect(),
            pas: 90.0,
            cost: 2.0,
            batch_sum: 8,
            objective: 0.0,
            latency_e2e: 0.2,
            resources: ResourceVec::ZERO,
        };
        ClusterCore::new(&config, f64::INFINITY, DropPolicy::new(10.0, true))
    }

    #[test]
    fn drain_replays_original_arrival_times() {
        let grid = LaneGrid::single(2, 16);
        let mut core = two_stage_core();
        assert!(grid.ingest(0, 1, 0.25));
        assert!(grid.ingest(0, 2, 0.75));
        assert_eq!(grid.queued(0, 0), 2);
        // drained much later, the core still sees the true arrivals
        assert_eq!(grid.drain_into(0, 0, &mut core, 64), 2);
        assert_eq!(grid.queued(0, 0), 0);
        core.complete(1, 1.0);
        core.complete(2, 1.0);
        let m = core.into_accounting().into_metrics("t".into(), "p".into(), "w".into());
        let mut latencies = m.latencies();
        latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(latencies, vec![0.25, 0.75]);
    }

    #[test]
    fn forward_returns_leftovers_when_full() {
        let grid = LaneGrid::single(2, 2);
        let reqs: Vec<Request> =
            (0..3).map(|i| Request { id: i, arrival: 0.0, stage_arrival: 1.0 }).collect();
        let leftovers = grid.forward(0, 1, reqs);
        assert_eq!(leftovers.len(), 1);
        assert_eq!(leftovers[0].id, 2);
        assert_eq!(grid.queued(0, 1), 2);
    }

    #[test]
    fn shed_on_full_lane_feeds_drop_counters() {
        let grid = LaneGrid::single(1, 2);
        // a 1-stage grid over a 2-stage core is fine here — shedding
        // touches only the accounting ledger
        let mut core = two_stage_core();
        assert!(grid.ingest(0, 10, 0.1));
        assert!(grid.ingest(0, 11, 0.2));
        // third arrival finds the lane full → shed with accounting
        assert!(!grid.ingest(0, 12, 0.3));
        shed(&mut core, 12, 0.3);
        assert!(core.accounting.is_dropped(12));
        assert_eq!(core.accounting.dropped_count(), 1);
        // the queued two are unaffected
        assert_eq!(grid.drain_into(0, 0, &mut core, 64), 2);
        assert_eq!(core.accounting.dropped_count(), 1);
    }

    #[test]
    fn member_major_lanes_are_independent() {
        let grid = LaneGrid::new(&[2, 3], 8);
        assert!(grid.ingest(0, 1, 0.0));
        assert!(grid.ingest(1, 1, 0.0));
        grid.forward(1, 2, vec![Request { id: 9, arrival: 0.0, stage_arrival: 0.5 }]);
        assert_eq!(grid.queued(0, 0), 1);
        assert_eq!(grid.queued(1, 0), 1);
        assert_eq!(grid.queued(1, 2), 1);
        assert_eq!(grid.queued(0, 1), 0);
    }
}
