//! Synthetic data-plane executor for the `data_plane` bench section:
//! the same producer → per-stage queue → batch-forming dispatcher
//! topology as the live engine, with the actual work stripped out, so
//! the benchmark isolates the dispatch path itself.
//!
//! Two interchangeable builds of the hot path:
//!
//! * [`run_sharded`] — one lock-free [`MpscRing`] per stage; producers
//!   enqueue round-robin without any lock, dispatchers own disjoint
//!   stage ranges and claim batches straight off their rings, reading
//!   the per-stage batch hint through a [`ConfigCell`] snapshot (one
//!   atomic load per visit) — the sharded engine's shape.
//! * [`run_legacy_lock`] — every queue AND the config behind ONE
//!   global mutex: producers lock per item (the legacy engine's
//!   arrival path locked the core per request), dispatchers lock per
//!   batch attempt and scan their stages under the lock (the legacy
//!   `try_form` shape) — the single-lock engine's shape.
//!
//! Both run the identical workload (`producers × items_per_producer`
//! items spread over `stages` queues) to completion and return the
//! count consumed, so `items / wall_time` is directly comparable.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::data_plane::ring::MpscRing;
use crate::data_plane::snapshot::ConfigCell;
use crate::telemetry::{Hop, Span, Telemetry};

/// Workload shape shared by both paths.
#[derive(Debug, Clone)]
pub struct SyntheticCfg {
    /// Queues (the bench contract pins 64 — the tentpole's stage count).
    pub stages: usize,
    /// Arrival threads (each locks per item on the legacy path).
    pub producers: usize,
    /// Batch-forming threads (disjoint stage ranges on the sharded
    /// path; all contending for the one lock on the legacy path).
    pub dispatchers: usize,
    pub items_per_producer: usize,
    /// Items claimed per batch attempt (the short-lock hand-off unit).
    pub batch: usize,
    pub ring_capacity: usize,
}

impl SyntheticCfg {
    /// The bench shape: 64 stages, thread counts clamped to the host.
    pub fn bench_default() -> Self {
        let cores = std::thread::available_parallelism().map_or(2, usize::from);
        let half = (cores / 2).clamp(2, 4);
        SyntheticCfg {
            stages: 64,
            producers: half,
            dispatchers: half,
            items_per_producer: 40_000,
            batch: 32,
            ring_capacity: 1024,
        }
    }

    pub fn total_items(&self) -> usize {
        self.producers * self.items_per_producer
    }
}

/// Contiguous stage range owned by dispatcher `d` of `n`.
fn stage_range(d: usize, n: usize, stages: usize) -> (usize, usize) {
    let per = stages.div_ceil(n);
    let lo = (d * per).min(stages);
    let hi = ((d + 1) * per).min(stages);
    (lo, hi)
}

/// Sharded path: per-stage rings + epoch-gated config snapshots.
/// Returns the items consumed (always `cfg.total_items()`).
pub fn run_sharded(cfg: &SyntheticCfg) -> usize {
    run_sharded_traced(cfg, &Telemetry::off())
}

/// [`run_sharded`] with span recording on the consume side: every
/// sampled item pops an [`Hop::Exec`] span into `tel`'s rings.  This is
/// what the `telemetry` bench section times against the untraced run —
/// the overhead gate measures exactly the per-item sample-check +
/// ring-push cost on the dispatch hot path.  `Telemetry::off()` is the
/// untraced run.
pub fn run_sharded_traced(cfg: &SyntheticCfg, tel: &Telemetry) -> usize {
    let rings: Vec<MpscRing<u64>> =
        (0..cfg.stages).map(|_| MpscRing::with_capacity(cfg.ring_capacity)).collect();
    let config: ConfigCell<Vec<usize>> = ConfigCell::new(vec![cfg.batch; cfg.stages]);
    let consumed = AtomicUsize::new(0);
    let total = cfg.total_items();

    std::thread::scope(|s| {
        for p in 0..cfg.producers {
            let rings = &rings;
            let n = cfg.items_per_producer;
            let stages = cfg.stages;
            s.spawn(move || {
                for i in 0..n {
                    let stage = (p + i) % stages;
                    let mut v = (p * n + i) as u64;
                    // lock-free enqueue; a full ring backs off briefly
                    loop {
                        match rings[stage].try_push(v) {
                            Ok(()) => break,
                            Err(back) => {
                                v = back;
                                std::thread::yield_now();
                            }
                        }
                    }
                }
            });
        }

        for d in 0..cfg.dispatchers {
            let (rings, config, consumed) = (&rings, &config, &consumed);
            let (lo, hi) = stage_range(d, cfg.dispatchers, cfg.stages);
            s.spawn(move || {
                let mut reader = config.reader();
                while consumed.load(Ordering::Relaxed) < total {
                    let mut got = 0usize;
                    for stage in lo..hi {
                        // the per-stage batch hint: one Acquire load
                        let batch = reader.get(config)[stage];
                        for _ in 0..batch {
                            let Some(item) = rings[stage].pop() else { break };
                            if tel.enabled() && tel.sampled(item) {
                                tel.record(Span {
                                    trace: item,
                                    member: stage as u32,
                                    stage: stage as u32,
                                    hop: Hop::Exec,
                                    t: 0.0,
                                    dur: 0.0,
                                    value: batch as f64,
                                });
                            }
                            got += 1;
                        }
                    }
                    if got > 0 {
                        consumed.fetch_add(got, Ordering::Relaxed);
                    } else {
                        std::thread::yield_now();
                    }
                }
            });
        }
    });
    consumed.load(Ordering::Relaxed)
}

/// Everything the legacy engine kept behind its one mutex: per-stage
/// queues plus the active configuration.
struct LegacyState {
    queues: Vec<VecDeque<u64>>,
    batch_hint: Vec<usize>,
}

/// Single-lock path: one global mutex over every queue and the config.
/// Returns the items consumed (always `cfg.total_items()`).
pub fn run_legacy_lock(cfg: &SyntheticCfg) -> usize {
    let state = Arc::new(Mutex::new(LegacyState {
        queues: (0..cfg.stages).map(|_| VecDeque::new()).collect(),
        batch_hint: vec![cfg.batch; cfg.stages],
    }));
    let consumed = Arc::new(AtomicUsize::new(0));
    let total = cfg.total_items();

    let producers: Vec<_> = (0..cfg.producers)
        .map(|p| {
            let state = Arc::clone(&state);
            let n = cfg.items_per_producer;
            let stages = cfg.stages;
            std::thread::spawn(move || {
                for i in 0..n {
                    let stage = (p + i) % stages;
                    // the legacy arrival path: one lock per request
                    state.lock().unwrap().queues[stage].push_back((p * n + i) as u64);
                }
            })
        })
        .collect();

    let dispatchers: Vec<_> = (0..cfg.dispatchers)
        .map(|d| {
            let state = Arc::clone(&state);
            let consumed = Arc::clone(&consumed);
            let (lo, hi) = stage_range(d, cfg.dispatchers, cfg.stages);
            std::thread::spawn(move || {
                while consumed.load(Ordering::Relaxed) < total {
                    let mut got = 0usize;
                    {
                        // the legacy try_form shape: scan the owned
                        // stages and claim batches under the one lock
                        let mut st = state.lock().unwrap();
                        for stage in lo..hi {
                            let batch = st.batch_hint[stage];
                            for _ in 0..batch {
                                if st.queues[stage].pop_front().is_none() {
                                    break;
                                }
                                got += 1;
                            }
                        }
                    }
                    if got > 0 {
                        consumed.fetch_add(got, Ordering::Relaxed);
                    } else {
                        std::thread::yield_now();
                    }
                }
            })
        })
        .collect();

    for h in producers {
        h.join().unwrap();
    }
    for h in dispatchers {
        h.join().unwrap();
    }
    consumed.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> SyntheticCfg {
        SyntheticCfg {
            stages: 8,
            producers: 2,
            dispatchers: 2,
            items_per_producer: 2_000,
            batch: 8,
            ring_capacity: 64,
        }
    }

    #[test]
    fn sharded_consumes_every_item() {
        let cfg = tiny();
        assert_eq!(run_sharded(&cfg), cfg.total_items());
    }

    #[test]
    fn traced_consumes_every_item_and_records_sampled_spans() {
        use crate::telemetry::TelemetryConfig;
        let cfg = tiny();
        let tel = Telemetry::new(
            TelemetryConfig { sample_one_in: 4, span_buffer: 1 << 14 },
            cfg.stages,
        );
        assert_eq!(run_sharded_traced(&cfg, &tel), cfg.total_items());
        let spans = tel.take_spans();
        assert!(!spans.is_empty());
        assert!(spans.iter().all(|s| s.trace % 4 == 0 && s.hop == Hop::Exec));
    }

    #[test]
    fn legacy_consumes_every_item() {
        let cfg = tiny();
        assert_eq!(run_legacy_lock(&cfg), cfg.total_items());
    }

    #[test]
    fn stage_ranges_cover_and_do_not_overlap() {
        for (n, stages) in [(2usize, 64usize), (3, 64), (4, 10), (5, 3)] {
            let mut seen = vec![false; stages];
            for d in 0..n {
                let (lo, hi) = stage_range(d, n, stages);
                for s in lo..hi {
                    assert!(!seen[s], "stage {s} owned twice");
                    seen[s] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "uncovered stage ({n} dispatchers)");
        }
    }
}
