//! Epoch-gated configuration snapshots: workers read the current
//! `StageConfig` vector without blocking `decide`/`preempt`.
//!
//! A seqlock in spirit, sound in safe Rust: instead of letting readers
//! race the writer over raw bytes (UB without atomics over the whole
//! payload), the cell publishes an immutable `Arc<T>` snapshot behind a
//! tiny mutex and bumps an atomic epoch.  Readers keep a cached
//! `(epoch, Arc<T>)`; the hot path is ONE `Acquire` load comparing
//! epochs — the mutex is touched only on the (rare) tick where the
//! adapter actually published a new configuration, so a worker's
//! config read never contends with another worker, and contends with
//! the adapter only for the duration of an `Arc` clone.
//!
//! # Memory-ordering contract
//!
//! * **epoch `fetch_add`: `Release`** (writer, inside the slot lock) —
//!   pairs with the reader's `Acquire` load: a reader that observes the
//!   new epoch will also observe the new `Arc` once it takes the lock
//!   (the lock itself orders the slot write, the epoch is the cheap
//!   "something changed" signal).
//! * **epoch load: `Acquire`** (reader fast path) — an equal epoch
//!   proves the cached snapshot is still current, because the writer
//!   bumps the epoch on every publish.  A *stale-by-one-instant* read
//!   (publish between our load and use) is acceptable by design: the
//!   engine tolerates a worker forming one more batch under the
//!   previous configuration, exactly like the locked path did between
//!   `apply_config` and the next wakeup.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Writer-published, epoch-versioned snapshot cell.
pub struct ConfigCell<T> {
    epoch: AtomicU64,
    slot: Mutex<Arc<T>>,
}

impl<T> ConfigCell<T> {
    pub fn new(value: T) -> Self {
        ConfigCell { epoch: AtomicU64::new(0), slot: Mutex::new(Arc::new(value)) }
    }

    /// Publish a new snapshot (adapter side).  Holds the slot lock only
    /// for the `Arc` swap; the epoch bump is the readers' signal.
    pub fn publish(&self, value: T) {
        let mut slot = self.slot.lock().unwrap();
        *slot = Arc::new(value);
        // Release pairs with readers' Acquire epoch loads; bumped while
        // the lock is held so epoch N always means "slot holds the Nth
        // published value (or newer)".
        self.epoch.fetch_add(1, Ordering::Release);
    }

    /// Current epoch (Acquire — see module docs).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Clone the current snapshot (slow path; readers go through
    /// [`ConfigReader`] instead).
    pub fn snapshot(&self) -> Arc<T> {
        Arc::clone(&self.slot.lock().unwrap())
    }

    /// A per-thread cached reader primed with the current snapshot.
    pub fn reader(&self) -> ConfigReader<T> {
        ConfigReader { seen: self.epoch(), cached: self.snapshot() }
    }
}

/// Per-reader cache over a [`ConfigCell`]: the common read is one
/// atomic load; the lock is taken only when the epoch moved.
pub struct ConfigReader<T> {
    seen: u64,
    cached: Arc<T>,
}

impl<T> ConfigReader<T> {
    /// The current snapshot (refreshing the cache if the writer
    /// published since the last call).
    pub fn get(&mut self, cell: &ConfigCell<T>) -> &T {
        let epoch = cell.epoch();
        if epoch != self.seen {
            self.cached = cell.snapshot();
            self.seen = epoch;
        }
        &self.cached
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reader_sees_published_updates() {
        let cell = ConfigCell::new(1u32);
        let mut r = cell.reader();
        assert_eq!(*r.get(&cell), 1);
        cell.publish(2);
        assert_eq!(*r.get(&cell), 2);
        // unchanged epoch keeps the cache
        assert_eq!(*r.get(&cell), 2);
    }

    #[test]
    fn epoch_advances_per_publish() {
        let cell = ConfigCell::new(0u8);
        let e0 = cell.epoch();
        cell.publish(1);
        cell.publish(2);
        assert_eq!(cell.epoch(), e0 + 2);
        assert_eq!(*cell.snapshot(), 2);
    }

    #[test]
    fn concurrent_readers_never_tear() {
        // Snapshots are immutable Arcs: a reader can never observe a
        // half-written pair even while the writer spins.
        let cell = std::sync::Arc::new(ConfigCell::new((0u64, 0u64)));
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let readers: Vec<_> = (0..3)
            .map(|_| {
                let cell = std::sync::Arc::clone(&cell);
                let stop = std::sync::Arc::clone(&stop);
                std::thread::spawn(move || {
                    let mut r = cell.reader();
                    while !stop.load(Ordering::Relaxed) {
                        let (a, b) = *r.get(&cell);
                        assert_eq!(a, b, "torn snapshot");
                    }
                })
            })
            .collect();
        for i in 1..2_000u64 {
            cell.publish((i, i));
        }
        stop.store(true, Ordering::Relaxed);
        for h in readers {
            h.join().unwrap();
        }
    }
}
