//! `ipa` — CLI for the IPA reproduction.
//!
//! Subcommands:
//!   report <id>     regenerate a paper table/figure
//!                   (fig2|table2|table3|table5|table6|fig7|fig8..fig12|
//!                    fig13|fig14|fig15|fig16|fig17|all)
//!   simulate        one simulator run (--pipeline --pattern --policy)
//!   serve           live engine over real PJRT artifacts
//!   solve           one IP decision (--pipeline --lambda)
//!   tracegen        dump a synthetic trace
//!   check           verify artifact numerics vs the manifest oracle
//!   version         print version

use ipa::coordinator::adapter::Policy;
use ipa::models::accuracy::AccuracyMetric;
use ipa::models::pipelines;
use ipa::reports::{figures, figures::EvalOpts, tables};
use ipa::util::cli::Args;
use ipa::workload::trace::Trace;
use ipa::workload::tracegen::Pattern;

fn main() {
    let args = Args::from_env();
    let code = match args.command.as_deref() {
        Some("report") => cmd_report(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("serve") => cmd_serve(&args),
        Some("solve") => cmd_solve(&args),
        Some("tracegen") => cmd_tracegen(&args),
        Some("check") => cmd_check(&args),
        Some("version") => {
            println!("ipa {}", ipa::version());
            0
        }
        _ => {
            print_help();
            if args.command.is_none() { 0 } else { 2 }
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "ipa {} — Inference Pipeline Adaptation (paper reproduction)\n\n\
         usage: ipa <command> [--options]\n\n\
         commands:\n\
           report <id>   regenerate a paper table/figure: fig2 table2 table3\n\
                         table5 table6 fig7 fig8 fig9 fig10 fig11 fig12 fig13\n\
                         fig14 fig15 fig16 fig17 all   [--seconds N] [--artifacts DIR]\n\
           simulate      --pipeline video --pattern bursty --policy ipa --seconds 600\n\
           serve         live engine: --pipeline video --seconds 30 [--artifacts DIR]\n\
           solve         --pipeline video --lambda 12 [--pas-prime]\n\
           tracegen      --pattern bursty --seconds 300 [--seed N]\n\
           check         --artifacts DIR [--key detect.yolov5n]\n\
           version",
        ipa::version()
    );
}

fn opts_from(args: &Args) -> EvalOpts {
    let seconds = args.get_usize("seconds", 600);
    let dir = args.get_or("artifacts", "artifacts").to_string();
    let art = if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("note: no artifacts at {dir}; LSTM predictor falls back to reactive");
        None
    };
    EvalOpts::new(seconds, art)
}

fn cmd_report(args: &Args) -> i32 {
    let id = args.positional.first().map(String::as_str).unwrap_or("all");
    let mut opts = opts_from(args);
    let emit = |s: String| print!("{s}");
    match id {
        "fig2" => emit(tables::fig2()),
        "table2" => emit(tables::table2()),
        "table3" => emit(tables::table3()),
        "table5" => emit(tables::table5()),
        "table6" => emit(tables::table6()),
        "fig7" => emit(figures::fig7(&mut opts)),
        "fig8" => emit(figures::fig_e2e("video", &mut opts)),
        "fig9" => emit(figures::fig_e2e("audio-qa", &mut opts)),
        "fig10" => emit(figures::fig_e2e("audio-sent", &mut opts)),
        "fig11" => emit(figures::fig_e2e("sum-qa", &mut opts)),
        "fig12" => emit(figures::fig_e2e("nlp", &mut opts)),
        "fig13" => emit(figures::fig13()),
        "fig14" => emit(figures::fig14(&mut opts)),
        "fig15" => emit(figures::fig15(&mut opts)),
        "fig16" => emit(figures::fig16(&mut opts)),
        "fig17" | "fig18" => emit(figures::fig17(&mut opts)),
        "all" => {
            emit(tables::fig2());
            emit(tables::table2());
            emit(tables::table3());
            emit(tables::table5());
            emit(tables::table6());
            emit(figures::fig7(&mut opts));
            for p in ["video", "audio-qa", "audio-sent", "sum-qa", "nlp"] {
                emit(figures::fig_e2e(p, &mut opts));
            }
            emit(figures::fig13());
            emit(figures::fig14(&mut opts));
            emit(figures::fig15(&mut opts));
            emit(figures::fig16(&mut opts));
            emit(figures::fig17(&mut opts));
        }
        other => {
            eprintln!("unknown report id: {other}");
            return 2;
        }
    }
    0
}

fn parse_policy(name: &str) -> Option<Policy> {
    match name {
        "ipa" => Some(Policy::Ipa(AccuracyMetric::Pas)),
        "ipa-pas-prime" => Some(Policy::Ipa(AccuracyMetric::PasPrime)),
        "fa2-low" => Some(Policy::Fa2Low),
        "fa2-high" => Some(Policy::Fa2High),
        "rim" => Some(Policy::Rim(Default::default())),
        _ => None,
    }
}

fn cmd_simulate(args: &Args) -> i32 {
    let pipeline = args.get_or("pipeline", "video").to_string();
    let pattern = match Pattern::from_name(args.get_or("pattern", "bursty")) {
        Some(p) => p,
        None => {
            eprintln!("unknown pattern");
            return 2;
        }
    };
    let Some(policy) = parse_policy(args.get_or("policy", "ipa")) else {
        eprintln!("unknown policy (ipa|ipa-pas-prime|fa2-low|fa2-high|rim)");
        return 2;
    };
    let mut opts = opts_from(args);
    let pred = match args.get_or("predictor", "lstm") {
        "lstm" => figures::PredKind::Lstm,
        "reactive" => figures::PredKind::Reactive,
        "oracle" => figures::PredKind::Oracle,
        _ => {
            eprintln!("unknown predictor");
            return 2;
        }
    };
    let m = figures::run_cell(&pipeline, policy, pattern, pred, &mut opts);
    let s = m.latency_summary();
    println!(
        "system={} pipeline={} workload={} requests={}",
        m.system,
        m.pipeline,
        m.workload,
        m.requests.len()
    );
    println!(
        "avg PAS {:.2} | avg cost {:.1} cores | SLA attainment {:.1}% | drops {:.2}% | \
         latency p50 {:.2}s p99 {:.2}s | switches {}",
        m.avg_pas(),
        m.avg_cost(),
        m.sla_attainment() * 100.0,
        m.drop_rate() * 100.0,
        s.p50,
        s.p99,
        m.variant_switches()
    );
    0
}

fn cmd_serve(args: &Args) -> i32 {
    use ipa::serving::engine::{serve, ServeConfig};
    use ipa::serving::loadgen::LoadGenConfig;
    let pipeline = args.get_or("pipeline", "video").to_string();
    let Some(spec) = pipelines::by_name(&pipeline) else {
        eprintln!("unknown pipeline");
        return 2;
    };
    let seconds = args.get_usize("seconds", 30);
    let pattern =
        Pattern::from_name(args.get_or("pattern", "fluctuating")).unwrap_or(Pattern::Fluctuating);
    let Some(policy) = parse_policy(args.get_or("policy", "ipa")) else {
        eprintln!("unknown policy");
        return 2;
    };
    let cfg = ServeConfig {
        artifact_dir: args.get_or("artifacts", "artifacts").to_string(),
        use_lstm: !args.flag("no-lstm"),
        interval: args.get_f64("interval", 5.0),
        ..Default::default()
    };
    let lg = LoadGenConfig {
        time_scale: args.get_f64("time-scale", 1.0),
        seed: args.get_u64("seed", 11),
    };
    let trace = Trace::synthetic(pattern, seconds);
    match serve(&spec, policy, &cfg, lg, &trace) {
        Ok(rep) => {
            let m = &rep.metrics;
            let s = m.latency_summary();
            println!(
                "LIVE serve: pipeline={} policy={} workload={} | measured SLA {:.1} ms",
                pipeline,
                m.system,
                m.workload,
                rep.sla * 1e3
            );
            println!(
                "requests {} | completed {} | drops {:.2}% | SLA attainment {:.1}% | \
                 latency p50 {:.1} ms p99 {:.1} ms | throughput {:.1} rps",
                m.requests.len(),
                m.latencies().len(),
                m.drop_rate() * 100.0,
                m.sla_attainment() * 100.0,
                s.p50 * 1e3,
                s.p99 * 1e3,
                m.latencies().len() as f64 / (seconds as f64 * lg.time_scale)
            );
            for i in &m.intervals {
                println!(
                    "  t={:>6.1}s pas={:>6.2} cost={:>5.1} λ_obs={:>6.1} λ_pred={:>6.1} [{}]",
                    i.t,
                    i.pas,
                    i.cost,
                    i.lambda_observed,
                    i.lambda_predicted,
                    i.variants.join(",")
                );
            }
            0
        }
        Err(e) => {
            eprintln!("serve failed: {e:#}");
            1
        }
    }
}

fn cmd_solve(args: &Args) -> i32 {
    let pipeline = args.get_or("pipeline", "video").to_string();
    let Some(spec) = pipelines::by_name(&pipeline) else {
        eprintln!("unknown pipeline");
        return 2;
    };
    let lambda = args.get_f64("lambda", 10.0);
    let prof = ipa::profiler::analytic::pipeline_profiles(&spec);
    let mut p = ipa::optimizer::ip::Problem::new(&spec, &prof, lambda);
    if args.flag("pas-prime") {
        p.metric = AccuracyMetric::PasPrime;
    }
    match ipa::optimizer::ip::solve(&p) {
        Some((cfg, stats)) => {
            println!(
                "λ={lambda} PAS={:.2} cost={:.1} cores latency={:.2}s/{:.2}s objective={:.3}",
                cfg.pas,
                cfg.cost,
                cfg.latency_e2e,
                spec.sla_e2e(),
                cfg.objective
            );
            for (i, sc) in cfg.stages.iter().enumerate() {
                println!(
                    "  stage {i}: {} batch={} replicas={} (n·R={:.0} cores, acc={:.2})",
                    sc.variant_key, sc.batch, sc.replicas, sc.cost, sc.accuracy
                );
            }
            println!(
                "  solver: {} nodes, {} bound-pruned, {} infeasible-pruned",
                stats.nodes, stats.pruned_bound, stats.pruned_infeasible
            );
            0
        }
        None => {
            println!("infeasible at λ={lambda}");
            1
        }
    }
}

fn cmd_tracegen(args: &Args) -> i32 {
    let Some(pattern) = Pattern::from_name(args.get_or("pattern", "bursty")) else {
        eprintln!("unknown pattern");
        return 2;
    };
    let seconds = args.get_usize("seconds", 300);
    let seed = args.get_u64("seed", ipa::workload::tracegen::eval_seed(pattern));
    let rates = ipa::workload::tracegen::generate(pattern, seconds, seed);
    for (t, r) in rates.iter().enumerate() {
        println!("{t},{r:.3}");
    }
    0
}

fn cmd_check(args: &Args) -> i32 {
    use ipa::runtime::engine::Engine;
    let dir = args.get_or("artifacts", "artifacts");
    let mut engine = match Engine::new(dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("engine init failed: {e:#}");
            return 1;
        }
    };
    let keys: Vec<String> = match args.get("key") {
        Some(k) => vec![k.to_string()],
        None => engine.manifest.keys(),
    };
    let mut failures = 0;
    for key in keys {
        match engine.check_variant(&key) {
            Ok((got, want)) => {
                let rel = (got - want).abs() / want.abs().max(1e-6);
                let ok = rel < 1e-3;
                println!(
                    "{key:<28} got {got:>12.5} want {want:>12.5} rel {rel:.2e} {}",
                    if ok { "OK" } else { "FAIL" }
                );
                if !ok {
                    failures += 1;
                }
            }
            Err(e) => {
                println!("{key:<28} ERROR {e:#}");
                failures += 1;
            }
        }
    }
    // LSTM check
    if engine.manifest.predictor.is_some() {
        let window: Vec<f32> = (0..120)
            .map(|i| 5.0 + 20.0 * i as f32 / 119.0)
            .collect();
        match engine.predict(&window) {
            Ok(p) => {
                let want = engine.manifest.predictor.as_ref().unwrap().check_pred;
                let ok = ((p as f64) - want).abs() < 1e-2 * want.abs().max(1.0);
                println!(
                    "predictor/lstm               got {p:>12.5} want {want:>12.5} {}",
                    if ok { "OK" } else { "FAIL" }
                );
                if !ok {
                    failures += 1;
                }
            }
            Err(e) => {
                println!("predictor/lstm               ERROR {e:#}");
                failures += 1;
            }
        }
    }
    if failures > 0 {
        eprintln!("{failures} numerics check(s) failed");
        1
    } else {
        0
    }
}
