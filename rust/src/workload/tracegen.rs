//! Synthetic Twitter-shaped workload generator.
//!
//! DETERMINISM CONTRACT: this is a line-for-line algorithmic twin of
//! `python/compile/tracegen.py` — SplitMix64 plus only +,-,*,/ on f64
//! (no libm transcendentals), so both languages produce bit-identical
//! rate sequences for the same (pattern, seed).  The LSTM predictor is
//! trained (python side) on `composite` traces from this algorithm and
//! serves predictions (rust side, via PJRT) on traces from this twin.

use crate::util::rng::SplitMix64;

/// The four paper workload archetypes (Fig. 7) plus the LSTM-training
/// composite.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Pattern {
    SteadyLow,
    SteadyHigh,
    Fluctuating,
    Bursty,
    Composite,
}

impl Pattern {
    pub const ALL: [Pattern; 5] = [
        Pattern::SteadyLow,
        Pattern::SteadyHigh,
        Pattern::Fluctuating,
        Pattern::Bursty,
        Pattern::Composite,
    ];

    /// The four evaluation patterns of Figs. 8–12.
    pub const EVAL: [Pattern; 4] = [
        Pattern::Bursty,
        Pattern::SteadyHigh,
        Pattern::SteadyLow,
        Pattern::Fluctuating,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Pattern::SteadyLow => "steady_low",
            Pattern::SteadyHigh => "steady_high",
            Pattern::Fluctuating => "fluctuating",
            Pattern::Bursty => "bursty",
            Pattern::Composite => "composite",
        }
    }

    pub fn from_name(s: &str) -> Option<Pattern> {
        Pattern::ALL.iter().copied().find(|p| p.name() == s)
    }
}

/// Length of one synthetic "day" in the composite trace (python twin:
/// `DAY_SECONDS`).
pub const DAY_SECONDS: usize = 2400;

/// Smooth periodic bump in [0,1]: parabola `1-(2p-1)²` per period —
/// a deterministic sin() substitute (libm differs across languages,
/// polynomials do not).
pub fn bump(phase: f64) -> f64 {
    let mut p = phase - phase.trunc();
    if p < 0.0 {
        p += 1.0;
    }
    let d = 2.0 * p - 1.0;
    1.0 - d * d
}

#[derive(Debug, Clone, Copy)]
struct Burst {
    start: f64,
    ramp: f64,
    hold: f64,
    decay: f64,
    amp: f64,
}

impl Burst {
    fn value(&self, t: f64) -> f64 {
        let mut dt = t - self.start;
        if dt < 0.0 {
            return 0.0;
        }
        if dt < self.ramp {
            return self.amp * dt / self.ramp;
        }
        dt -= self.ramp;
        if dt < self.hold {
            return self.amp;
        }
        dt -= self.hold;
        if dt < self.decay {
            return self.amp * (1.0 - dt / self.decay);
        }
        0.0
    }
}

fn gen_bursts(
    rng: &mut SplitMix64,
    seconds: usize,
    mean_gap: f64,
    amp_lo: f64,
    amp_hi: f64,
) -> Vec<Burst> {
    let mut bursts = Vec::new();
    let mut t = rng.range_f64(5.0, mean_gap);
    while t < seconds as f64 {
        let ramp = rng.range_f64(3.0, 8.0);
        let hold = rng.range_f64(10.0, 30.0);
        let decay = rng.range_f64(5.0, 15.0);
        let amp = rng.range_f64(amp_lo, amp_hi);
        bursts.push(Burst { start: t, ramp, hold, decay, amp });
        t += ramp + hold + decay + rng.range_f64(0.5 * mean_gap, 1.5 * mean_gap);
    }
    bursts
}

/// Generate per-second arrival rates (RPS).  Twin of python `generate`.
pub fn generate(pattern: Pattern, seconds: usize, seed: u64) -> Vec<f64> {
    let mut rng = SplitMix64::new(seed);
    let mut rates = vec![0.0f64; seconds];

    match pattern {
        Pattern::SteadyLow => {
            for r in rates.iter_mut() {
                *r = 6.0 + rng.range_f64(-0.8, 0.8);
            }
        }
        Pattern::SteadyHigh => {
            for r in rates.iter_mut() {
                *r = 26.0 + rng.range_f64(-2.0, 2.0);
            }
        }
        Pattern::Fluctuating => {
            for (t, r) in rates.iter_mut().enumerate() {
                let wave = 20.0 * bump(t as f64 / 300.0);
                *r = 6.0 + wave + rng.range_f64(-1.5, 1.5);
            }
        }
        Pattern::Bursty => {
            let bursts = gen_bursts(&mut rng, seconds, 120.0, 18.0, 30.0);
            for (t, r) in rates.iter_mut().enumerate() {
                let mut v = 8.0 + rng.range_f64(-1.0, 1.0);
                for b in &bursts {
                    v += b.value(t as f64);
                }
                *r = v;
            }
        }
        Pattern::Composite => {
            // burst distribution matches the bursty eval archetype (amp
            // 18-30) so the LSTM learns to anticipate real burst onsets
            let bursts = gen_bursts(&mut rng, seconds, 150.0, 16.0, 30.0);
            for (t, r) in rates.iter_mut().enumerate() {
                let day_phase = t as f64 / DAY_SECONDS as f64;
                let diurnal = 16.0 * bump(day_phase);
                let weekly = 4.0 * bump(day_phase / 5.3);
                let mut v = 5.0 + diurnal + weekly + rng.range_f64(-1.2, 1.2);
                for b in &bursts {
                    v += b.value(t as f64);
                }
                *r = v;
            }
        }
    }

    for r in rates.iter_mut() {
        if *r < 0.5 {
            *r = 0.5;
        }
    }
    rates
}

/// How the member traces of a fleet co-move (see [`generate_fleet`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetCorrelation {
    /// Every member is an independent stream (distinct derived seeds).
    Independent,
    /// Members share one periodic envelope, phase-shifted by `i/N`:
    /// one pipeline peaks while another decays — the competing-bursts
    /// scenario the shared replica budget exists for.
    Antiphase {
        /// Envelope period, seconds.
        period: usize,
    },
    /// All members ride the same envelope (a correlated global surge —
    /// the worst case for a shared pool).
    InPhase {
        /// Envelope period, seconds.
        period: usize,
    },
}

/// Derive member `i`'s stream seed from a fleet seed (also used by the
/// drivers to sample per-member arrivals consistently).
pub fn member_seed(seed: u64, member: usize) -> u64 {
    seed ^ (member as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Generate correlated per-second rates for a fleet: one rate vector
/// per member pattern, each member's base stream from a single fleet
/// seed (derived per member via [`member_seed`]).  Deterministic in
/// (patterns, seconds, seed, corr); same +,-,*,/-only arithmetic
/// discipline as [`generate`].
pub fn generate_fleet(
    patterns: &[Pattern],
    seconds: usize,
    seed: u64,
    corr: FleetCorrelation,
) -> Vec<Vec<f64>> {
    let members: Vec<(Pattern, u64)> = patterns
        .iter()
        .enumerate()
        .map(|(i, &p)| (p, member_seed(seed, i)))
        .collect();
    generate_fleet_seeded(&members, seconds, corr)
}

/// [`generate_fleet`] with an explicit (pattern, seed) per member —
/// the fleet-spec path, where every member carries its own trace seed.
pub fn generate_fleet_seeded(
    members: &[(Pattern, u64)],
    seconds: usize,
    corr: FleetCorrelation,
) -> Vec<Vec<f64>> {
    let n = members.len().max(1);
    members
        .iter()
        .enumerate()
        .map(|(i, &(pat, seed))| {
            let mut rates = generate(pat, seconds, seed);
            let (period, phase_off) = match corr {
                FleetCorrelation::Independent => (0usize, 0.0),
                FleetCorrelation::Antiphase { period } => (period, i as f64 / n as f64),
                FleetCorrelation::InPhase { period } => (period, 0.0),
            };
            if period > 0 {
                // mean-1 envelope (bump averages 2/3): 0.25 + 1.125·bump
                // swings each member between 0.25× and 1.375× its base
                // rate without inflating the fleet-average load.
                for (t, r) in rates.iter_mut().enumerate() {
                    let env = 0.25 + 1.125 * bump(t as f64 / period as f64 + phase_off);
                    *r *= env;
                }
            }
            for r in rates.iter_mut() {
                if *r < 0.5 {
                    *r = 0.5;
                }
            }
            rates
        })
        .collect()
}

/// Seed the python LSTM trainer used for the composite trace — MUST
/// match `python/compile/predictor.TRACE_SEED`.
pub const TRAIN_SEED: u64 = 0x7717_7E2A;

/// Default seeds for the four evaluation excerpts (Fig. 7) — distinct
/// from [`TRAIN_SEED`] so the excerpts are "unseen" by the LSTM.
pub fn eval_seed(pattern: Pattern) -> u64 {
    match pattern {
        Pattern::SteadyLow => 0x0051_EAD1,
        Pattern::SteadyHigh => 0x0051_EAD2,
        Pattern::Fluctuating => 0x00F1_0C70,
        Pattern::Bursty => 0x00B0_B570,
        Pattern::Composite => TRAIN_SEED,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mean;

    #[test]
    fn deterministic() {
        let a = generate(Pattern::Bursty, 500, 42);
        let b = generate(Pattern::Bursty, 500, 42);
        assert_eq!(a, b);
        let c = generate(Pattern::Bursty, 500, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn steady_low_mean_and_spread() {
        let r = generate(Pattern::SteadyLow, 2000, 1);
        let m = mean(&r);
        assert!((m - 6.0).abs() < 0.2, "mean {m}");
        assert!(r.iter().all(|&x| (4.0..8.5).contains(&x)));
    }

    #[test]
    fn steady_high_above_low() {
        let hi = mean(&generate(Pattern::SteadyHigh, 2000, 2));
        let lo = mean(&generate(Pattern::SteadyLow, 2000, 2));
        assert!(hi > lo + 15.0);
    }

    #[test]
    fn fluctuating_has_waves() {
        let r = generate(Pattern::Fluctuating, 600, 3);
        let max = r.iter().fold(0.0f64, |a, &b| a.max(b));
        let min = r.iter().fold(f64::MAX, |a, &b| a.min(b));
        assert!(max > 22.0, "max {max}");
        assert!(min < 9.0, "min {min}");
    }

    #[test]
    fn bursty_has_bursts_and_base() {
        let r = generate(Pattern::Bursty, 1200, 4);
        let max = r.iter().fold(0.0f64, |a, &b| a.max(b));
        assert!(max > 24.0, "burst peak {max}");
        // most of the time we are near base
        let near_base = r.iter().filter(|&&x| x < 12.0).count();
        assert!(near_base > r.len() / 3, "{near_base}");
    }

    #[test]
    fn composite_diurnal_structure() {
        let r = generate(Pattern::Composite, 2 * DAY_SECONDS, 5);
        // mid-day (phase 0.5) should exceed midnight (phase ~0)
        let midnight = mean(&r[0..100]);
        let midday = mean(&r[DAY_SECONDS / 2 - 50..DAY_SECONDS / 2 + 50]);
        assert!(midday > midnight + 5.0, "{midnight} vs {midday}");
    }

    #[test]
    fn rates_floored() {
        for p in Pattern::ALL {
            let r = generate(p, 300, 9);
            assert!(r.iter().all(|&x| x >= 0.5));
        }
    }

    #[test]
    fn bump_properties() {
        assert!(bump(0.0).abs() < 1e-12);
        assert!((bump(0.5) - 1.0).abs() < 1e-12);
        assert!((bump(1.25) - bump(0.25)).abs() < 1e-12, "periodic");
        assert!((bump(-0.25) - bump(0.75)).abs() < 1e-12, "negative phase");
    }

    #[test]
    fn bit_exact_with_python_twin() {
        // Values produced by python/compile/tracegen.py (printed with
        // %.17g) — the determinism contract between the two languages.
        let r = generate(Pattern::Bursty, 50, 42);
        let expect = [
            7.3198207857538407,
            7.5572022605102775,
            7.6883814330472751,
            7.0760603370804924,
            8.736456153093064,
            7.4368103874243685,
            8.6012637534270073,
            7.6798620778340414,
            8.23696413271227,
            7.4098036635975513,
        ];
        for (a, b) in r[..10].iter().zip(expect) {
            assert_eq!(*a, b, "bursty stream diverged from python");
        }
        let c = generate(Pattern::Composite, 30, TRAIN_SEED);
        let expect_c = [
            4.0840338748544189,
            5.9074476338245239,
            4.6472281555517601,
            5.4241581155432517,
            4.3530485527439486,
        ];
        for (a, b) in c[..5].iter().zip(expect_c) {
            assert_eq!(*a, b, "composite stream diverged from python");
        }
    }

    #[test]
    fn eval_seeds_distinct_from_training() {
        for p in Pattern::EVAL {
            assert_ne!(eval_seed(p), TRAIN_SEED);
        }
    }

    #[test]
    fn fleet_deterministic_and_member_streams_distinct() {
        let pats = [Pattern::SteadyLow, Pattern::SteadyLow, Pattern::Bursty];
        let corr = FleetCorrelation::Antiphase { period: 200 };
        let a = generate_fleet(&pats, 400, 7, corr);
        let b = generate_fleet(&pats, 400, 7, corr);
        assert_eq!(a, b);
        assert_eq!(a.len(), 3);
        // same pattern, different member → different stream
        assert_ne!(a[0], a[1]);
        assert!(a.iter().all(|r| r.iter().all(|&x| x >= 0.5)));
    }

    #[test]
    fn antiphase_members_move_oppositely() {
        // Two steady members under an antiphase envelope: when one is
        // scaled up the other is scaled down — negative correlation of
        // the deviations from the mean.
        let pats = [Pattern::SteadyLow, Pattern::SteadyLow];
        let r = generate_fleet(&pats, 1200, 3, FleetCorrelation::Antiphase { period: 300 });
        let m0 = mean(&r[0]);
        let m1 = mean(&r[1]);
        let cov: f64 = r[0]
            .iter()
            .zip(&r[1])
            .map(|(&a, &b)| (a - m0) * (b - m1))
            .sum::<f64>()
            / r[0].len() as f64;
        assert!(cov < -1.0, "antiphase covariance {cov}");
        // and the mean-1 envelope keeps the average near the base rate
        assert!((m0 - 6.0).abs() < 1.5, "mean {m0}");
    }

    #[test]
    fn in_phase_members_move_together() {
        let pats = [Pattern::SteadyLow, Pattern::SteadyLow];
        let r = generate_fleet(&pats, 1200, 3, FleetCorrelation::InPhase { period: 300 });
        let m0 = mean(&r[0]);
        let m1 = mean(&r[1]);
        let cov: f64 = r[0]
            .iter()
            .zip(&r[1])
            .map(|(&a, &b)| (a - m0) * (b - m1))
            .sum::<f64>()
            / r[0].len() as f64;
        assert!(cov > 1.0, "in-phase covariance {cov}");
    }

    #[test]
    fn independent_matches_plain_generate() {
        let pats = [Pattern::Fluctuating];
        let r = generate_fleet(&pats, 300, 9, FleetCorrelation::Independent);
        assert_eq!(r[0], generate(Pattern::Fluctuating, 300, member_seed(9, 0)));
    }

    /// Property: across random fleet sizes, seeds and correlation
    /// modes, every member's envelope-scaled trace keeps its base
    /// stream's mean (the 0.25 + 1.125·bump envelope integrates to 1
    /// over whole periods), and generation is deterministic — the same
    /// (pattern, fleet seed, member index) always reproduces the same
    /// stream, because each member's stream is exactly the plain
    /// generator at its [`member_seed`].
    #[test]
    fn prop_fleet_envelopes_mean_one_and_reproducible() {
        use crate::util::quickcheck::{check, prop_assert, prop_close};
        check("fleet envelope mean-1 + member_seed reproducible", 30, |g| {
            let n = g.usize(1, 5);
            let period = *g.choose(&[120usize, 200, 300]);
            let seconds = period * g.usize(2, 5);
            let seed = g.u64(1, 1 << 40);
            // steady patterns: base mean is tight, so the envelope's
            // effect on the mean is cleanly measurable
            let pat = *g.choose(&[Pattern::SteadyLow, Pattern::SteadyHigh]);
            let pats = vec![pat; n];
            for corr in [
                FleetCorrelation::Independent,
                FleetCorrelation::Antiphase { period },
                FleetCorrelation::InPhase { period },
            ] {
                let r = generate_fleet(&pats, seconds, seed, corr);
                prop_assert(
                    r == generate_fleet(&pats, seconds, seed, corr),
                    "fleet generation must be deterministic",
                )?;
                for (i, rates) in r.iter().enumerate() {
                    let base = generate(pat, seconds, member_seed(seed, i));
                    prop_close(
                        mean(rates) / mean(&base),
                        1.0,
                        0.1,
                        "envelope must stay mean-1 over whole periods",
                    )?;
                }
            }
            Ok(())
        });
    }

    /// Property: in the explicitly-seeded path, a member's own seed
    /// fully determines its stream — change one member's seed and only
    /// that member's trace moves.
    #[test]
    fn prop_member_seed_isolated_in_seeded_fleet() {
        use crate::util::quickcheck::{check, prop_assert};
        check("member seed isolation", 30, |g| {
            let n = g.usize(2, 5);
            let seconds = g.usize(50, 300);
            let corr = FleetCorrelation::Antiphase { period: 100 };
            let members: Vec<(Pattern, u64)> =
                (0..n).map(|_| (*g.choose(&Pattern::ALL), g.u64(1, 1 << 40))).collect();
            let base = generate_fleet_seeded(&members, seconds, corr);
            let j = g.usize(0, n);
            let mut changed = members.clone();
            changed[j].1 ^= 0x5EED_u64 << 16;
            let alt = generate_fleet_seeded(&changed, seconds, corr);
            for i in 0..n {
                if i == j {
                    prop_assert(base[i] != alt[i], "changed seed must change the stream")?;
                } else {
                    prop_assert(base[i] == alt[i], "other members' streams must not move")?;
                }
            }
            Ok(())
        });
    }
}
