//! Trace handling: a [`Trace`] wraps per-second rates and produces the
//! concrete request arrival times the simulator / load generator
//! replays (Poisson arrivals within each second, seeded).

use super::tracegen::{self, Pattern};
use crate::util::rng::SplitMix64;

/// A workload trace: per-second arrival rates (RPS).
#[derive(Debug, Clone)]
pub struct Trace {
    pub name: String,
    pub rates: Vec<f64>,
}

impl Trace {
    pub fn new(name: impl Into<String>, rates: Vec<f64>) -> Self {
        Trace { name: name.into(), rates }
    }

    /// Generate one of the synthetic patterns at its default eval seed.
    pub fn synthetic(pattern: Pattern, seconds: usize) -> Self {
        Trace::new(
            pattern.name(),
            tracegen::generate(pattern, seconds, tracegen::eval_seed(pattern)),
        )
    }

    pub fn seconds(&self) -> usize {
        self.rates.len()
    }

    /// Rate at time `t` (clamped to the last second).
    pub fn rate_at(&self, t: f64) -> f64 {
        let i = (t.max(0.0) as usize).min(self.rates.len().saturating_sub(1));
        self.rates[i]
    }

    /// Ground-truth maximum rate in `[t, t+horizon)` — the oracle
    /// predictor's answer and the LSTM's training target.
    pub fn max_in_window(&self, t: f64, horizon: f64) -> f64 {
        let lo = (t.max(0.0) as usize).min(self.rates.len().saturating_sub(1));
        let hi = ((t + horizon).ceil() as usize).min(self.rates.len());
        self.rates[lo..hi.max(lo + 1)]
            .iter()
            .fold(0.0f64, |a, &b| a.max(b))
    }

    /// Materialize request arrival timestamps: Poisson(rate) arrivals
    /// per second, uniformly spread within the second (seeded,
    /// deterministic).
    pub fn arrivals(&self, seed: u64) -> Vec<f64> {
        let mut rng = SplitMix64::new(seed ^ 0xA11C_E5);
        let mut out = Vec::new();
        for (sec, &rate) in self.rates.iter().enumerate() {
            let n = rng.next_poisson(rate);
            let mut ts: Vec<f64> =
                (0..n).map(|_| sec as f64 + rng.next_f64()).collect();
            ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
            out.extend(ts);
        }
        out
    }

    /// Peak rate over the whole trace.
    pub fn peak(&self) -> f64 {
        self.rates.iter().fold(0.0f64, |a, &b| a.max(b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_match_rates_in_aggregate() {
        let tr = Trace::synthetic(Pattern::SteadyLow, 500);
        let arr = tr.arrivals(1);
        let expected: f64 = tr.rates.iter().sum();
        let got = arr.len() as f64;
        assert!(
            (got - expected).abs() / expected < 0.1,
            "{got} vs {expected}"
        );
    }

    #[test]
    fn arrivals_sorted_and_in_range() {
        let tr = Trace::synthetic(Pattern::Bursty, 200);
        let arr = tr.arrivals(2);
        for w in arr.windows(2) {
            assert!(w[0] <= w[1]);
        }
        assert!(arr.iter().all(|&t| t >= 0.0 && t < 200.0));
    }

    #[test]
    fn arrivals_deterministic() {
        let tr = Trace::synthetic(Pattern::Fluctuating, 100);
        assert_eq!(tr.arrivals(7), tr.arrivals(7));
        assert_ne!(tr.arrivals(7), tr.arrivals(8));
    }

    #[test]
    fn max_in_window() {
        let tr = Trace::new("t", vec![1.0, 5.0, 2.0, 9.0, 3.0]);
        assert_eq!(tr.max_in_window(0.0, 2.0), 5.0);
        assert_eq!(tr.max_in_window(2.0, 2.0), 9.0);
        assert_eq!(tr.max_in_window(4.0, 10.0), 3.0);
    }

    #[test]
    fn rate_at_clamps() {
        let tr = Trace::new("t", vec![1.0, 2.0]);
        assert_eq!(tr.rate_at(-1.0), 1.0);
        assert_eq!(tr.rate_at(0.5), 1.0);
        assert_eq!(tr.rate_at(100.0), 2.0);
    }
}
