//! Live serving engine: the paper's Kubernetes deployment, in-process.
//!
//! Real HLO artifacts execute on the PJRT executor pool behind central
//! per-stage batching queues; replica slots are worker threads gated by
//! an atomic replica gauge; the adapter thread reconfigures variants /
//! batch sizes / replica counts on a live clock with the LSTM predictor
//! running through PJRT as well.  Python is nowhere on this path.
//!
//! Latency profiles are *measured at startup* by profiling the actual
//! artifacts (batch ∈ {1,4,16,64}, quadratic fit — the §4.2 method),
//! and the per-stage SLAs follow the Swayam rule `SLA_s = 5 × avg(b=1)`
//! — so the live system derives its own millisecond-scale SLA domain
//! from real measurements (DESIGN.md "scaled-time convention").

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::adapter::{Adapter, AdapterConfig, Policy};
use crate::coordinator::monitoring::Monitor;
use crate::metrics::{IntervalRecord, RequestRecord, RunMetrics};
use crate::models::pipelines::PipelineSpec;
use crate::predictor::{LstmPredictor, Predictor, ReactivePredictor};
use crate::profiler::fit::ProfileSamples;
use crate::profiler::profile::{PipelineProfiles, StageProfile, VariantProfile};
use crate::queueing::{CentralQueue, Request};
use crate::runtime::pool::ExecutorPool;
use crate::serving::loadgen::{self, LoadGenConfig};
use crate::workload::trace::Trace;

/// Live-engine settings.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub artifact_dir: String,
    /// Executor threads (PJRT engines).
    pub executors: usize,
    /// Worker (replica-slot) threads per stage.
    pub max_workers: usize,
    /// Adaptation interval, wall seconds.
    pub interval: f64,
    /// Reconfiguration delay, wall seconds.
    pub apply_delay: f64,
    /// Use the LSTM predictor artifact (false → reactive).
    pub use_lstm: bool,
    /// Batch sizes profiled at startup.
    pub profile_batches: Vec<usize>,
    /// Profile repetitions per point.
    pub profile_reps: usize,
    /// Per-stage SLA floor, seconds.  The Swayam rule (5× batch-1
    /// latency) is defined over model service time; our scaled-down
    /// models execute in microseconds, far below the batching/dispatch
    /// granularity of the in-process cluster substrate (queue timeouts,
    /// worker wakeups, channel hops).  The floor keeps the live SLA
    /// meaningful: SLA_s = max(5 × avg l(1), sla_floor).
    pub sla_floor: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifact_dir: "artifacts".into(),
            executors: 2,
            max_workers: 8,
            interval: 5.0,
            apply_delay: 1.0,
            use_lstm: true,
            profile_batches: vec![1, 4, 16, 64],
            profile_reps: 3,
            sla_floor: 0.25,
        }
    }
}

/// Measure real artifact latencies and build millisecond-scale profiles
/// for one pipeline (the live profiler).
pub fn measure_profiles(
    pool: &ExecutorPool,
    spec: &PipelineSpec,
    cfg: &ServeConfig,
) -> Result<PipelineProfiles> {
    let mut stages = Vec::new();
    for &stage_type in &spec.stages {
        let mut variants = Vec::new();
        for v in crate::models::registry::variants_of(stage_type) {
            let key = v.key();
            let mut samples = ProfileSamples::default();
            for &b in &cfg.profile_batches {
                let x = crate::runtime::weights::check_input(v.hidden(), b);
                pool.execute(&key, b, x.clone())?; // warmup/compile
                let mut best = f64::MAX;
                for _ in 0..cfg.profile_reps {
                    let (_, dt) = pool.execute(&key, b, x.clone())?;
                    best = best.min(dt.as_secs_f64());
                }
                samples.push(b, best);
            }
            let latency = samples
                .fit()
                .ok_or_else(|| anyhow::anyhow!("profile fit failed for {key}"))?;
            variants.push(VariantProfile { variant: v, latency });
        }
        stages.push(StageProfile { stage_type, variants });
    }
    Ok(PipelineProfiles { pipeline: spec.name.to_string(), stages })
}

struct StageShared {
    queue: Mutex<CentralQueue>,
    cv: Condvar,
    /// Active variant key (guarded for reads by workers).
    variant: Mutex<String>,
    batch: AtomicUsize,
    replicas: AtomicUsize,
    hidden: AtomicUsize,
}

struct Shared {
    stages: Vec<StageShared>,
    monitor: Mutex<Monitor>,
    completed: Mutex<Vec<RequestRecord>>,
    dropped: Mutex<Vec<u64>>,
    sla: f64,
    stop: AtomicBool,
    start: Instant,
}

impl Shared {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Outcome of a live run.
pub struct ServeReport {
    pub metrics: RunMetrics,
    /// Measured profiles used for decisions.
    pub profiles: PipelineProfiles,
    /// Live-domain end-to-end SLA, seconds.
    pub sla: f64,
}

/// Serve `trace` through the live engine under `policy`; returns the
/// collected metrics.  `lg.time_scale` compresses trace time.
pub fn serve(
    spec: &PipelineSpec,
    policy: Policy,
    cfg: &ServeConfig,
    lg: LoadGenConfig,
    trace: &Trace,
) -> Result<ServeReport> {
    let pool = Arc::new(ExecutorPool::new(&cfg.artifact_dir, cfg.executors)?);
    let profiles = measure_profiles(&pool, spec, cfg)?;

    // Live spec: same stages/weights, SLAs from measured profiles.
    let mut live_spec = spec.clone();
    live_spec.stage_slas = profiles
        .stages
        .iter()
        .map(|s| s.stage_sla().max(cfg.sla_floor))
        .collect();
    let sla = live_spec.sla_e2e();

    // Time compression multiplies observed rates by 1/time_scale; the
    // monitor sees wall time, so decisions see the compressed domain.
    let predictor: Box<dyn Predictor + Send> = if cfg.use_lstm {
        Box::new(LstmPredictor::new(pool.lstm_closure()))
    } else {
        Box::new(ReactivePredictor::default())
    };
    let mut adapter = Adapter::new(
        live_spec.clone(),
        profiles.clone(),
        policy,
        AdapterConfig {
            interval: cfg.interval,
            apply_delay: cfg.apply_delay,
            max_replicas: cfg.max_workers as u32,
        },
        predictor,
    );

    // Initial decision at the trace's first-second (compressed) rate.
    let init = adapter.decide_for_lambda(trace.rate_at(0.0) / lg.time_scale.max(1e-9));

    let shared = Arc::new(Shared {
        stages: (0..live_spec.n_stages())
            .map(|si| {
                let sc = &init.config.stages[si];
                StageShared {
                    queue: Mutex::new(CentralQueue::new(sc.batch, 0.05)),
                    cv: Condvar::new(),
                    variant: Mutex::new(sc.variant_key.clone()),
                    batch: AtomicUsize::new(sc.batch),
                    replicas: AtomicUsize::new(sc.replicas as usize),
                    hidden: AtomicUsize::new(
                        profiles.stages[si].variants[sc.variant_idx].variant.hidden(),
                    ),
                }
            })
            .collect(),
        monitor: Mutex::new(Monitor::new(600)),
        completed: Mutex::new(Vec::new()),
        dropped: Mutex::new(Vec::new()),
        sla,
        stop: AtomicBool::new(false),
        start: Instant::now(),
    });

    // Warm the initial configuration.
    for sc in &init.config.stages {
        let _ = pool.warm(&sc.variant_key, sc.batch);
    }

    // ---- worker threads (replica slots) ------------------------------
    let mut workers = Vec::new();
    for si in 0..live_spec.n_stages() {
        for wi in 0..cfg.max_workers {
            let sh = Arc::clone(&shared);
            let pl = Arc::clone(&pool);
            let n_stages = live_spec.n_stages();
            workers.push(std::thread::spawn(move || {
                worker_loop(sh, pl, si, wi, n_stages);
            }));
        }
    }

    // ---- adapter thread ----------------------------------------------
    let intervals = Arc::new(Mutex::new(Vec::<IntervalRecord>::new()));
    let adapter_handle = {
        let sh = Arc::clone(&shared);
        let pl = Arc::clone(&pool);
        let iv = Arc::clone(&intervals);
        let mut active_cfg = init.config.clone();
        std::thread::spawn(move || {
            loop {
                std::thread::sleep(Duration::from_secs_f64(adapter.config.interval));
                if sh.stop.load(Ordering::Relaxed) {
                    break;
                }
                let now = sh.now();
                let history = {
                    let m = sh.monitor.lock().unwrap();
                    m.history(now, crate::predictor::HISTORY)
                };
                let observed = {
                    let m = sh.monitor.lock().unwrap();
                    m.recent_rate(now, adapter.config.interval.max(1.0) as usize)
                };
                let d = adapter.decide(now, &history);
                iv.lock().unwrap().push(IntervalRecord {
                    t: now,
                    pas: active_cfg.pas,
                    cost: active_cfg.cost,
                    lambda_observed: observed,
                    lambda_predicted: d.lambda_predicted,
                    decision_time: d.decision_time,
                    variants: active_cfg.stages.iter().map(|s| s.variant_key.clone()).collect(),
                });
                // warm targets before the switch, then apply after delay
                for sc in &d.config.stages {
                    let _ = pl.warm(&sc.variant_key, sc.batch);
                }
                std::thread::sleep(Duration::from_secs_f64(adapter.config.apply_delay));
                if sh.stop.load(Ordering::Relaxed) {
                    break;
                }
                for (si, sc) in d.config.stages.iter().enumerate() {
                    let st = &sh.stages[si];
                    *st.variant.lock().unwrap() = sc.variant_key.clone();
                    st.batch.store(sc.batch, Ordering::Relaxed);
                    st.replicas.store(sc.replicas as usize, Ordering::Relaxed);
                    st.hidden.store(
                        adapter.profiles.stages[si].variants[sc.variant_idx].variant.hidden(),
                        Ordering::Relaxed,
                    );
                    let mut q = st.queue.lock().unwrap();
                    q.set_batch(sc.batch, 0.05);
                    st.cv.notify_all();
                }
                active_cfg = d.config.clone();
            }
        })
    };

    // ---- load generation (blocking) ----------------------------------
    let submitted = loadgen::replay(trace, lg, |id, t| {
        {
            let mut m = shared.monitor.lock().unwrap();
            m.record_arrival(t);
        }
        let st = &shared.stages[0];
        let mut q = st.queue.lock().unwrap();
        q.push(Request { id, arrival: t, stage_arrival: t });
        drop(q);
        st.cv.notify_one();
    });

    // ---- drain & stop --------------------------------------------------
    let drain_deadline = Instant::now() + Duration::from_secs_f64(3.0 + 4.0 * sla);
    loop {
        let done = shared.completed.lock().unwrap().len() + shared.dropped.lock().unwrap().len();
        if done >= submitted || Instant::now() > drain_deadline {
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    shared.stop.store(true, Ordering::Relaxed);
    for st in &shared.stages {
        st.cv.notify_all();
    }
    for w in workers {
        let _ = w.join();
    }
    let _ = adapter_handle.join();

    // ---- assemble metrics ----------------------------------------------
    let completed = shared.completed.lock().unwrap().clone();
    let dropped = shared.dropped.lock().unwrap().clone();
    let mut requests = completed;
    for id in dropped {
        requests.push(RequestRecord { id, arrival: 0.0, completion: None });
    }
    let metrics = RunMetrics {
        system: policy.name().to_string(),
        pipeline: spec.name.to_string(),
        workload: trace.name.clone(),
        requests,
        intervals: intervals.lock().unwrap().clone(),
        sla,
    };
    Ok(ServeReport { metrics, profiles, sla })
}

/// One replica-slot worker.
fn worker_loop(
    sh: Arc<Shared>,
    pool: Arc<ExecutorPool>,
    stage: usize,
    worker_idx: usize,
    n_stages: usize,
) {
    loop {
        if sh.stop.load(Ordering::Relaxed) {
            return;
        }
        let st = &sh.stages[stage];
        // replica gauge: workers above the active count idle
        if worker_idx >= st.replicas.load(Ordering::Relaxed) {
            std::thread::sleep(Duration::from_millis(5));
            continue;
        }
        // wait for a batch
        let batch = {
            let mut q = st.queue.lock().unwrap();
            loop {
                if sh.stop.load(Ordering::Relaxed) {
                    return;
                }
                if let Some(b) = q.pop_batch(sh.now()) {
                    break b;
                }
                let (qq, _) = st
                    .cv
                    .wait_timeout(q, Duration::from_millis(20))
                    .unwrap();
                q = qq;
            }
        };
        let now = sh.now();
        // §4.5 dropping
        let mut live: Vec<Request> = Vec::with_capacity(batch.len());
        for r in batch {
            let age = now - r.arrival;
            if (stage > 0 && age > sh.sla) || age > 2.0 * sh.sla {
                sh.dropped.lock().unwrap().push(r.id);
            } else {
                live.push(r);
            }
        }
        if live.is_empty() {
            continue;
        }
        let key = st.variant.lock().unwrap().clone();
        let b_cfg = st.batch.load(Ordering::Relaxed).max(1);
        let hidden = st.hidden.load(Ordering::Relaxed);
        // pad to the configured batch (artifacts have static shapes)
        let input = vec![0.1f32; b_cfg * hidden];
        match pool.execute(&key, b_cfg, input) {
            Ok(_) => {
                let done = sh.now();
                if stage + 1 < n_stages {
                    let nst = &sh.stages[stage + 1];
                    let mut q = nst.queue.lock().unwrap();
                    for mut r in live {
                        r.stage_arrival = done;
                        q.push(r);
                    }
                    drop(q);
                    nst.cv.notify_one();
                } else {
                    let mut c = sh.completed.lock().unwrap();
                    for r in live {
                        c.push(RequestRecord {
                            id: r.id,
                            arrival: r.arrival,
                            completion: Some(done),
                        });
                    }
                }
            }
            Err(e) => {
                crate::log_warn!("serving", "execute failed: {e:#}");
                for r in live {
                    sh.dropped.lock().unwrap().push(r.id);
                }
            }
        }
    }
}
