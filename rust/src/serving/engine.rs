//! Live serving engine: the paper's Kubernetes deployment, in-process —
//! a *wall-clock driver* over the shared [`crate::cluster`] core.
//!
//! This file owns only the clock and the threads: worker threads claim
//! replica slots and batches from a [`ClusterCore`] behind a mutex, an
//! adapter thread stages decisions through [`Reconfig`], and a
//! [`BatchExecutor`] runs each formed batch.  Batch formation, §4.5
//! dropping, rolling reconfiguration and accounting are the exact same
//! machinery the discrete-event simulator drives with virtual time.
//!
//! The hot path is SHARDED by default (see [`crate::data_plane`]):
//! arrivals and inter-stage forwards ride lock-free per-(member, stage)
//! rings ([`crate::data_plane::ingress::LaneGrid`]) instead of taking
//! the core lock per request, workers read batch hints through an
//! epoch-gated config snapshot
//! ([`crate::data_plane::snapshot::ConfigCell`]), and shutdown wakes
//! sleepers through a [`crate::data_plane::stop::StopGate`] condvar.
//! The core lock is still taken — but only for the short batch hand-off
//! (drain lane + `try_form`) and at adapter reconfig epochs.
//! [`ServeConfig::legacy_lock`] restores the pre-sharding
//! lock-per-arrival path as the bench A/B lever.
//!
//! Two executors plug in:
//! * [`PoolExecutor`] — real HLO artifacts on the PJRT executor pool
//!   (the production path; latency profiles are *measured at startup*
//!   by profiling the actual artifacts, batch ∈ {1,4,16,64}, quadratic
//!   fit — the §4.2 method, with per-stage SLAs from the Swayam rule
//!   `SLA_s = 5 × avg(b=1)`).
//! * [`SyntheticExecutor`] — sleeps the profiled latency instead of
//!   executing; lets the full threaded engine run without artifacts and
//!   anchors the sim/live parity test.
//!
//! [`serve_fleet`] scales the same loop to a whole fleet: worker
//! threads per (member, stage) claim batches from one budget-checked
//! [`FleetCore`], and a single adapter thread runs the joint
//! cross-pipeline solver each interval — splitting every interval in
//! two so the elastic fast path (mid-interval priority preemption) and
//! the slow path (autoscaler pool resize + joint solve) mirror the DES
//! driver's Preempt/Adapt events on a wall clock.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::cluster::accounting::Accounting;
use crate::cluster::core::{ClusterCore, FormOutcome, FormedBatch};
use crate::cluster::drop_policy::DropPolicy;
use crate::coordinator::adapter::{Adapter, AdapterConfig, Policy};
use crate::coordinator::monitoring::Monitor;
use crate::data_plane::ingress::{self, LaneGrid, DEFAULT_LANE_CAPACITY};
use crate::data_plane::snapshot::ConfigCell;
use crate::data_plane::stop::StopGate;
use crate::fleet::core::{FleetCore, FleetReconfig, MemberInit, PoolReport};
use crate::fleet::router::{RouteOutcome, Router, RouterConfig};
use crate::fleet::solver::{FleetAdapter, FleetController, FleetTuning};
use crate::metrics::{RouterStats, RunMetrics};
use crate::models::accuracy::AccuracyMetric;
use crate::models::pipelines::PipelineSpec;
use crate::optimizer::ip::PipelineConfig;
use crate::predictor::{LstmPredictor, Predictor, ReactivePredictor};
use crate::profiler::fit::ProfileSamples;
use crate::profiler::profile::{LatencyProfile, PipelineProfiles, StageProfile, VariantProfile};
use crate::runtime::pool::ExecutorPool;
use crate::serving::loadgen::{self, LoadGenConfig};
use crate::telemetry::{Hop, Span, Telemetry};
use crate::util::error::{Error, Result};
use crate::util::json::Json;
use crate::workload::trace::Trace;

/// Live-engine settings.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub artifact_dir: String,
    /// Executor threads (PJRT engines).
    pub executors: usize,
    /// Worker (replica-slot) threads per stage; also the adapter's
    /// horizontal scaling cap.
    pub max_workers: usize,
    /// Adaptation interval, wall seconds.
    pub interval: f64,
    /// Reconfiguration delay, wall seconds.
    pub apply_delay: f64,
    /// Use the LSTM predictor artifact (false → reactive).
    pub use_lstm: bool,
    /// Batch sizes profiled at startup.
    pub profile_batches: Vec<usize>,
    /// Profile repetitions per point.
    pub profile_reps: usize,
    /// Per-stage SLA floor, seconds.  The Swayam rule (5× batch-1
    /// latency) is defined over model service time; our scaled-down
    /// models execute in microseconds, far below the batching/dispatch
    /// granularity of the in-process cluster substrate (queue timeouts,
    /// worker wakeups, channel hops).  The floor keeps the live SLA
    /// meaningful: SLA_s = max(5 × avg l(1), sla_floor).
    pub sla_floor: f64,
    /// Run the pre-sharding hot path: every arrival and forward takes
    /// the core lock directly instead of riding the per-(member, stage)
    /// ingress rings ([`crate::data_plane::ingress::LaneGrid`]).  Kept
    /// as the A/B lever for the `data_plane` bench section
    /// (`--legacy-lock` in `examples/fleet_serve.rs`); default off.
    pub legacy_lock: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifact_dir: "artifacts".into(),
            executors: 2,
            max_workers: 8,
            interval: 5.0,
            apply_delay: 1.0,
            use_lstm: true,
            profile_batches: vec![1, 4, 16, 64],
            profile_reps: 3,
            sla_floor: 0.25,
            legacy_lock: false,
        }
    }
}

/// Measure real artifact latencies and build millisecond-scale profiles
/// for one pipeline (the live profiler).
pub fn measure_profiles(
    pool: &ExecutorPool,
    spec: &PipelineSpec,
    cfg: &ServeConfig,
) -> Result<PipelineProfiles> {
    let mut stages = Vec::new();
    for &stage_type in &spec.stages {
        let mut variants = Vec::new();
        for v in crate::models::registry::variants_of(stage_type) {
            let key = v.key();
            let mut samples = ProfileSamples::default();
            for &b in &cfg.profile_batches {
                let x = crate::runtime::weights::check_input(v.hidden(), b);
                pool.execute(&key, b, x.clone())?; // warmup/compile
                let mut best = f64::MAX;
                for _ in 0..cfg.profile_reps {
                    let (_, dt) = pool.execute(&key, b, x.clone())?;
                    best = best.min(dt.as_secs_f64());
                }
                samples.push(b, best);
            }
            let latency = samples
                .fit()
                .ok_or_else(|| crate::anyhow!("profile fit failed for {key}"))?;
            variants.push(VariantProfile { variant: v, latency });
        }
        stages.push(StageProfile { stage_type, variants });
    }
    Ok(PipelineProfiles { pipeline: spec.name.to_string(), stages })
}

/// What actually runs a formed batch — the only live-engine seam that
/// differs between production and test drivers.
///
/// Everything execution needs (input width included) derives from the
/// `variant_key` pinned in the [`FormedBatch`] at formation time, so a
/// reconfiguration landing between formation and execution can never
/// pair one variant's artifact with another's input shape.
pub trait BatchExecutor: Send + Sync {
    /// Execute one padded batch of size `batch` on `variant_key`.
    fn execute(&self, variant_key: &str, batch: usize) -> Result<()>;

    /// Pre-compile / warm (key, batch); best-effort.
    fn warm(&self, _variant_key: &str, _batch: usize) {}
}

/// Real PJRT execution through the executor pool.
pub struct PoolExecutor(pub Arc<ExecutorPool>);

impl BatchExecutor for PoolExecutor {
    fn execute(&self, variant_key: &str, batch: usize) -> Result<()> {
        let hidden = crate::models::registry::by_key(variant_key)
            .ok_or_else(|| crate::anyhow!("unknown variant {variant_key}"))?
            .hidden();
        // pad to the configured batch (artifacts have static shapes)
        let input = vec![0.1f32; batch * hidden];
        self.0.execute(variant_key, batch, input).map(|_| ())
    }

    fn warm(&self, variant_key: &str, batch: usize) {
        let _ = self.0.warm(variant_key, batch);
    }
}

/// Profile-driven executor: sleeps `l(batch) × time_scale` instead of
/// executing — deterministic service times for parity tests and
/// artifact-free demos.
pub struct SyntheticExecutor {
    latency: HashMap<String, LatencyProfile>,
    pub time_scale: f64,
}

impl SyntheticExecutor {
    pub fn from_profiles(profiles: &PipelineProfiles, time_scale: f64) -> Self {
        let mut latency = HashMap::new();
        for st in &profiles.stages {
            for vp in &st.variants {
                latency.insert(vp.variant.key(), vp.latency);
            }
        }
        SyntheticExecutor { latency, time_scale }
    }
}

impl BatchExecutor for SyntheticExecutor {
    fn execute(&self, variant_key: &str, batch: usize) -> Result<()> {
        let lp = self
            .latency
            .get(variant_key)
            .ok_or_else(|| crate::anyhow!("no profile for {variant_key}"))?;
        let dt = (lp.latency(batch) * self.time_scale).max(0.0);
        if dt > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(dt));
        }
        Ok(())
    }
}

/// Shared state between the load generator, workers and the adapter
/// thread: the cluster core behind one lock, plus the lock-free ingress
/// lanes, the epoch-gated config snapshot, the monitor and the clock —
/// live-runtime details that stay out of the clock-agnostic core.
struct Shared {
    core: Mutex<ClusterCore>,
    cv: Condvar,
    monitor: Mutex<Monitor>,
    /// Lock-free per-stage arrival/forward lanes (sharded hot path).
    grid: LaneGrid,
    /// Snapshot of the active config; workers read batch/replica hints
    /// through it without touching the core lock (see
    /// [`crate::data_plane::snapshot`]).
    config: ConfigCell<PipelineConfig>,
    stop: StopGate,
    start: Instant,
}

impl Shared {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// How many queued requests a worker drains from its lane per lock
/// acquisition: enough to feed every replica's next batch, floored so
/// tiny configs still drain promptly.
fn drain_limit(cfg: &PipelineConfig, stage: usize) -> usize {
    cfg.stages.get(stage).map_or(32, |sc| (sc.batch * sc.replicas as usize).max(32))
}

/// Outcome of a live run.
pub struct ServeReport {
    pub metrics: RunMetrics,
    /// Profiles used for decisions.
    pub profiles: PipelineProfiles,
    /// Live-domain end-to-end SLA, seconds.
    pub sla: f64,
}

/// Serve `trace` through the live engine under `policy`; returns the
/// collected metrics.  `lg.time_scale` compresses trace time.  Requires
/// artifacts: profiles are measured and batches execute on PJRT.
pub fn serve(
    spec: &PipelineSpec,
    policy: Policy,
    cfg: &ServeConfig,
    lg: LoadGenConfig,
    trace: &Trace,
) -> Result<ServeReport> {
    let pool = Arc::new(ExecutorPool::new(&cfg.artifact_dir, cfg.executors)?);
    let profiles = measure_profiles(&pool, spec, cfg)?;
    let predictor: Box<dyn Predictor + Send> = if cfg.use_lstm {
        Box::new(LstmPredictor::new(pool.lstm_closure()))
    } else {
        Box::new(ReactivePredictor::default())
    };
    let executor: Arc<dyn BatchExecutor> = Arc::new(PoolExecutor(Arc::clone(&pool)));
    serve_with(spec, profiles, policy, cfg, lg, trace, executor, predictor)
}

/// Drive the wall-clock engine over explicit `profiles`, a pluggable
/// `executor` and `predictor` — no artifacts required.  This is the
/// whole live driver; [`serve`] is just PJRT measurement + execution
/// plugged into it.
#[allow(clippy::too_many_arguments)]
pub fn serve_with(
    spec: &PipelineSpec,
    profiles: PipelineProfiles,
    policy: Policy,
    cfg: &ServeConfig,
    lg: LoadGenConfig,
    trace: &Trace,
    executor: Arc<dyn BatchExecutor>,
    predictor: Box<dyn Predictor + Send>,
) -> Result<ServeReport> {
    // Live spec: same stages/weights, SLAs derived from the profiles
    // (Swayam rule, floored — see ServeConfig::sla_floor).
    let mut live_spec = spec.clone();
    live_spec.stage_slas = profiles
        .stages
        .iter()
        .map(|s| s.stage_sla().max(cfg.sla_floor))
        .collect();
    let sla = live_spec.sla_e2e();

    // Time compression multiplies observed rates by 1/time_scale; the
    // monitor sees wall time, so decisions see the compressed domain.
    let mut adapter = Adapter::new(
        live_spec.clone(),
        profiles.clone(),
        policy,
        AdapterConfig {
            interval: cfg.interval,
            apply_delay: cfg.apply_delay,
            max_replicas: cfg.max_workers as u32,
        },
        predictor,
    );

    // Initial decision at the trace's first-second (compressed) rate.
    let init = adapter.decide_for_lambda(trace.rate_at(0.0) / lg.time_scale.max(1e-9));

    // Wall-clock drivers use the bare 50 ms batch-timeout floor (λ=∞):
    // their λ lives in compressed wall time, not the profile domain.
    let core = ClusterCore::new(&init.config, f64::INFINITY, DropPolicy::new(sla, true));
    let n_stages = core.n_stages();

    // Warm the initial configuration BEFORE the run clock starts —
    // compile time must not count against request ages.
    for sc in &init.config.stages {
        executor.warm(&sc.variant_key, sc.batch);
    }

    let shared = Arc::new(Shared {
        core: Mutex::new(core),
        cv: Condvar::new(),
        monitor: Mutex::new(Monitor::new(600)),
        grid: LaneGrid::single(n_stages, DEFAULT_LANE_CAPACITY),
        config: ConfigCell::new(init.config.clone()),
        stop: StopGate::default(),
        start: Instant::now(),
    });

    // ---- worker threads (replica slots) ------------------------------
    let legacy_lock = cfg.legacy_lock;
    let mut workers = Vec::new();
    for si in 0..n_stages {
        for _ in 0..cfg.max_workers {
            let sh = Arc::clone(&shared);
            let ex = Arc::clone(&executor);
            workers.push(std::thread::spawn(move || {
                if legacy_lock {
                    worker_loop(sh, ex, si, n_stages);
                } else {
                    worker_loop_sharded(sh, ex, si, n_stages);
                }
            }));
        }
    }

    // ---- adapter thread ----------------------------------------------
    let adapter_handle = {
        let sh = Arc::clone(&shared);
        let ex = Arc::clone(&executor);
        let mut active_cfg = init.config.clone();
        let mut reconfig = adapter.reconfig();
        std::thread::spawn(move || {
            loop {
                if !sh.stop.sleep_interruptible(adapter.config.interval) {
                    break;
                }
                let now = sh.now();
                let (history, observed) = {
                    let m = sh.monitor.lock().unwrap();
                    (
                        m.history(now, crate::predictor::HISTORY),
                        m.recent_rate(now, adapter.config.interval.max(1.0) as usize),
                    )
                };
                let d = adapter.decide(now, &history);
                sh.core
                    .lock()
                    .unwrap()
                    .accounting
                    .record_interval(now, &active_cfg, observed, &d);
                // warm targets before the switch, then apply after delay
                for sc in &d.config.stages {
                    ex.warm(&sc.variant_key, sc.batch);
                }
                let at = reconfig.stage(now, d);
                if !sh.stop.sleep_interruptible(at - sh.now()) {
                    break;
                }
                while let Some(staged) = reconfig.pop_due(sh.now()) {
                    let d = staged.decision;
                    sh.core.lock().unwrap().apply_config(&d.config, f64::INFINITY);
                    // publish AFTER dropping the core lock (lock order:
                    // core lock may never be held while waiting on the
                    // snapshot slot, and vice versa)
                    sh.config.publish(d.config.clone());
                    sh.cv.notify_all();
                    active_cfg = d.config;
                }
            }
        })
    };

    // ---- load generation (blocking) ----------------------------------
    // Timestamps come from the shared run clock (not loadgen's own
    // epoch) so arrival times, drop ages and completions are measured
    // against the same zero.
    let submitted = loadgen::replay(trace, lg, |id, _t| {
        let t = shared.now();
        shared.monitor.lock().unwrap().record_arrival(t);
        if legacy_lock {
            shared.core.lock().unwrap().ingest(id, t);
        } else if !shared.grid.ingest(0, id, t) {
            // lane full → shed with accounting (the lock-free fast path
            // only ever takes the core lock on this overload edge)
            ingress::shed(&mut shared.core.lock().unwrap(), id, t);
        }
        shared.cv.notify_all();
    });

    // ---- drain & stop --------------------------------------------------
    let drain_deadline = Instant::now() + Duration::from_secs_f64(3.0 + 4.0 * sla);
    loop {
        let done = shared.core.lock().unwrap().accounting.done();
        if done >= submitted || Instant::now() > drain_deadline {
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    shared.stop.stop();
    shared.cv.notify_all();
    for w in workers {
        let _ = w.join();
    }
    let _ = adapter_handle.join();

    // ---- assemble metrics ----------------------------------------------
    let metrics = {
        let mut core = shared.core.lock().unwrap();
        let accounting = std::mem::replace(&mut core.accounting, Accounting::new(sla));
        accounting.into_metrics(
            policy.name().to_string(),
            spec.name.to_string(),
            trace.name.clone(),
        )
    };
    Ok(ServeReport { metrics, profiles, sla })
}

/// One replica-slot worker, legacy single-lock path: claim a batch from
/// the shared core, execute it, then route survivors forward (or
/// complete them).  Arrivals were ingested under the core lock by the
/// load generator; forwards take the lock per batch.
fn worker_loop(sh: Arc<Shared>, exec: Arc<dyn BatchExecutor>, stage: usize, n_stages: usize) {
    loop {
        if sh.stop.is_stopped() {
            return;
        }
        // Claim a batch: formation + §4.5 dropping + busy-slot gating all
        // happen inside the shared core.
        let fb: FormedBatch = {
            let mut core = sh.core.lock().unwrap();
            loop {
                if sh.stop.is_stopped() {
                    return;
                }
                match core.try_form(stage, sh.now()) {
                    FormOutcome::Formed(fb) => break fb,
                    FormOutcome::Busy | FormOutcome::Idle { .. } => {
                        let (guard, _) = sh
                            .cv
                            .wait_timeout(core, Duration::from_millis(20))
                            .unwrap();
                        core = guard;
                    }
                }
            }
        };
        match exec.execute(&fb.variant_key, fb.batch.max(1)) {
            Ok(()) => {
                let done = sh.now();
                let mut core = sh.core.lock().unwrap();
                core.finish_service(stage);
                if stage + 1 < n_stages {
                    for r in fb.requests {
                        core.forward(stage + 1, r, done);
                    }
                } else {
                    for r in &fb.requests {
                        core.complete(r.id, done);
                    }
                }
                drop(core);
                sh.cv.notify_all();
            }
            Err(e) => {
                crate::log_warn!("serving", "execute failed: {e:#}");
                let mut core = sh.core.lock().unwrap();
                core.finish_service(stage);
                for r in &fb.requests {
                    core.accounting.record_drop(r.id);
                }
                drop(core);
                sh.cv.notify_all();
            }
        }
    }
}

/// One replica-slot worker, sharded path (the default): drain this
/// stage's lock-free ingress lane into the core and claim a batch under
/// ONE short lock acquisition; after execution, hand survivors to the
/// next stage's lane without locking (locked fallback only for ring-full
/// leftovers).  Timestamps ride the [`crate::queueing::Request`] through
/// the lane, so formation, §4.5 dropping and batch timeouts see the same
/// instants the legacy path would have.
fn worker_loop_sharded(
    sh: Arc<Shared>,
    exec: Arc<dyn BatchExecutor>,
    stage: usize,
    n_stages: usize,
) {
    let mut reader = sh.config.reader();
    loop {
        if sh.stop.is_stopped() {
            return;
        }
        // one Acquire load unless the adapter published a new config
        let limit = drain_limit(reader.get(&sh.config), stage);
        let fb: FormedBatch = {
            let mut core = sh.core.lock().unwrap();
            loop {
                if sh.stop.is_stopped() {
                    return;
                }
                sh.grid.drain_into(0, stage, &mut core, limit);
                match core.try_form(stage, sh.now()) {
                    FormOutcome::Formed(fb) => break fb,
                    FormOutcome::Busy | FormOutcome::Idle { .. } => {
                        // the 20 ms cap bounds a missed notify: a push
                        // racing past an empty-lane check is picked up
                        // at the next drain
                        let (guard, _) = sh
                            .cv
                            .wait_timeout(core, Duration::from_millis(20))
                            .unwrap();
                        core = guard;
                    }
                }
            }
        };
        match exec.execute(&fb.variant_key, fb.batch.max(1)) {
            Ok(()) => {
                let done = sh.now();
                if stage + 1 < n_stages {
                    // pre-stamp the stage-arrival instant, then forward
                    // lock-free; only ring-full leftovers touch the lock
                    let mut survivors = fb.requests;
                    for r in &mut survivors {
                        r.stage_arrival = done;
                    }
                    let leftovers = sh.grid.forward(0, stage + 1, survivors);
                    let mut core = sh.core.lock().unwrap();
                    core.finish_service(stage);
                    for r in leftovers {
                        core.forward(stage + 1, r, done);
                    }
                    drop(core);
                } else {
                    let mut core = sh.core.lock().unwrap();
                    core.finish_service(stage);
                    for r in &fb.requests {
                        core.complete(r.id, done);
                    }
                    drop(core);
                }
                sh.cv.notify_all();
            }
            Err(e) => {
                crate::log_warn!("serving", "execute failed: {e:#}");
                let mut core = sh.core.lock().unwrap();
                core.finish_service(stage);
                for r in &fb.requests {
                    core.accounting.record_drop(r.id);
                }
                drop(core);
                sh.cv.notify_all();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The fleet engine: one wall-clock loop over N member pipelines behind
// one budget-checked FleetCore.
// ---------------------------------------------------------------------------

/// Shared state of the fleet engine: every member core behind ONE lock
/// (the joint budget check must see the whole fleet atomically), one
/// independently-locked monitor per member (arrival threads for
/// different members never contend), plus the lock-free per-(member,
/// stage) ingress lanes and the epoch-gated config snapshot.
struct FleetShared {
    fleet: Mutex<FleetCore>,
    cv: Condvar,
    monitors: Vec<Mutex<Monitor>>,
    /// Per-member front door (one short lock per arrival; `None` runs
    /// the classic pre-addressed ingress byte-for-byte).
    routers: Option<Vec<Mutex<Router>>>,
    /// Lock-free per-(member, stage) arrival/forward lanes.
    grid: LaneGrid,
    /// Snapshot of every member's active config (workers read batch
    /// hints without the fleet lock).
    configs: ConfigCell<Vec<PipelineConfig>>,
    /// Span recorder (disabled — zero shards, allocation-free — unless
    /// the caller attached one via [`FleetServeParams::telemetry`]).
    tel: Arc<Telemetry>,
    stop: StopGate,
    start: Instant,
}

impl FleetShared {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Outcome of a live fleet run: one [`ServeReport`] per member (input
/// order) plus the shared-pool accounting.
pub struct FleetServeReport {
    pub members: Vec<ServeReport>,
    /// The replica budget the run ENDED under (the autoscaler may have
    /// moved it from the initial value).  Convenience mirror of
    /// `pool.budget`, kept for the common fixed-pool callers.
    pub budget: u32,
    /// Highest pool occupancy observed (rolling-shrink overshoot
    /// included).
    pub peak_in_use: u32,
    /// Per-member configured replicas when the run ended (the last
    /// allocation actually applied).
    pub final_replicas: Vec<u32>,
    /// Pool-size extremes, resize/preemption counts and the
    /// replica-seconds bought/used cost ledger.
    pub pool: PoolReport,
    /// Per-member front-door counters (all-default when the run had no
    /// router), index-aligned with `members`.
    pub router: Vec<RouterStats>,
}

/// Everything one live fleet run needs — the wall-clock twin of
/// [`crate::simulator::sim::FleetDesParams`], consumed by
/// [`serve_fleet`].  `executors` and `predictors` are per member (same
/// order as `specs` / `profiles` / `traces`); `system` labels the
/// per-member [`RunMetrics`] so sim/live pairs group under one name.
///
/// Most callers should go through the [`crate::fleet::run::FleetRun`]
/// builder, which assembles this struct (and its DES twin) from a
/// [`crate::fleet::spec::FleetSpec`].
pub struct FleetServeParams<'a> {
    pub specs: &'a [PipelineSpec],
    pub profiles: Vec<PipelineProfiles>,
    pub metric: AccuracyMetric,
    pub budget: u32,
    pub system: &'a str,
    pub cfg: &'a ServeConfig,
    pub lg: LoadGenConfig,
    pub traces: &'a [Trace],
    pub executors: Vec<Arc<dyn BatchExecutor>>,
    pub predictors: Vec<Box<dyn Predictor + Send>>,
    /// Elastic control plane + pool description ([`FleetTuning::nodes`]
    /// turns the budget into a node inventory replicas bin-pack onto;
    /// [`FleetTuning::sla_classes`] keys drop policy and timeout caps);
    /// `FleetTuning::default()` reproduces fixed-pool classless runs.
    pub tuning: FleetTuning,
    /// Front-door routing + admission (`None` = classic pre-addressed
    /// ingress, byte-for-byte).
    pub router: Option<RouterConfig>,
    /// Span/journal plane; `None` (== `Telemetry::off()`) runs
    /// allocation-free and byte-identical to untraced.
    pub telemetry: Option<Arc<Telemetry>>,
}

/// Drive the wall-clock engine over a whole fleet: per-member worker
/// threads claim batches from one budget-checked [`FleetCore`], the
/// merged load generator replays every member trace on one clock
/// (through the per-member [`Router`] front door when
/// [`FleetServeParams::router`] is set), and a single adapter thread
/// runs the joint cross-pipeline solver ([`FleetAdapter`]) each
/// interval — the live twin of [`crate::simulator::sim::run_fleet`].
pub fn serve_fleet(p: FleetServeParams<'_>) -> Result<FleetServeReport> {
    let FleetServeParams {
        specs,
        profiles,
        metric,
        budget,
        system,
        cfg,
        lg,
        traces,
        executors,
        predictors,
        tuning,
        router,
        telemetry,
    } = p;
    let tel = telemetry.unwrap_or_else(|| Arc::new(Telemetry::off()));
    let n = specs.len();
    if profiles.len() != n || traces.len() != n || executors.len() != n || predictors.len() != n {
        return Err(crate::anyhow!(
            "fleet serve: member vectors disagree ({n} specs, {} profiles, {} traces, \
             {} executors, {} predictors)",
            profiles.len(),
            traces.len(),
            executors.len(),
            predictors.len()
        ));
    }

    // Live specs: profile-derived SLAs (Swayam rule, floored), like the
    // single-pipeline serve_with.
    let mut live_specs = Vec::with_capacity(n);
    let mut slas = Vec::with_capacity(n);
    for (spec, prof) in specs.iter().zip(&profiles) {
        let mut ls = spec.clone();
        ls.stage_slas =
            prof.stages.iter().map(|s| s.stage_sla().max(cfg.sla_floor)).collect();
        slas.push(ls.sla_e2e());
        live_specs.push(ls);
    }

    // Pool description from the tuning: a node inventory makes the
    // budget its replica cap, SLA classes key each member's drop
    // policy and batch-timeout ceiling (None = classic behavior).
    let inventory = tuning.nodes.clone();
    let classes = tuning.sla_classes.clone();
    if let Some(c) = &classes {
        if c.len() != n {
            return Err(crate::anyhow!("fleet serve: {} SLA classes for {n} members", c.len()));
        }
    }
    let spread = tuning.spread.clone().unwrap_or_default();
    let migration_delay = tuning.migration_delay;
    let budget = inventory.as_ref().map_or(budget, |i| i.replica_cap());

    let mut adapter = FleetAdapter::new(
        live_specs.clone(),
        profiles.clone(),
        metric,
        budget,
        AdapterConfig {
            interval: cfg.interval,
            apply_delay: cfg.apply_delay,
            max_replicas: cfg.max_workers as u32,
        },
        predictors,
    )
    .and_then(|a| a.with_tuning(tuning))
    .map_err(Error::from)?;
    adapter.set_journal(tel.journal());

    // Joint initial decision at the traces' first-second (compressed)
    // rates.
    let ts = lg.time_scale.max(1e-9);
    let first: Vec<f64> = traces.iter().map(|t| t.rate_at(0.0) / ts).collect();
    let inits = adapter.initial(&first);
    let fleet_inits: Vec<MemberInit> = inits
        .iter()
        .zip(&slas)
        .enumerate()
        .map(|(m, (d, &sla))| MemberInit {
            config: d.config.clone(),
            lambda: f64::INFINITY,
            // the class scales the drop threshold only — attainment
            // metrics keep judging against the true SLA.  `sla` here is
            // already in the live (wall-clock) domain — it derives from
            // the profiles the caller passed, which define that domain
            // (callers compress them by time_scale) — so the timeout
            // cap lands in the same domain as the 50 ms dispatch floor.
            drop: DropPolicy::new(sla, true)
                .scaled(classes.as_ref().map_or(1.0, |c| c[m].drop_sla_scale())),
            timeout_cap: classes.as_ref().map_or(f64::INFINITY, |c| c[m].timeout_cap(sla)),
        })
        .collect();
    let mut fleet = FleetCore::with_nodes_spread(budget, inventory, &fleet_inits, &spread)
        .map_err(Error::from)?;
    fleet.set_journal(tel.journal());
    let n_stages: Vec<usize> = live_specs.iter().map(PipelineSpec::n_stages).collect();

    // Warm every member's initial configuration before the clock starts.
    for (m, d) in inits.iter().enumerate() {
        for sc in &d.config.stages {
            executors[m].warm(&sc.variant_key, sc.batch);
        }
    }

    // Front door: one router per member (class-scaled SLA, shared zone
    // universe), synced to the initial placement before the clock runs.
    let routers: Option<Vec<Mutex<Router>>> = router.as_ref().map(|rc| {
        let zone_names: Vec<String> = fleet
            .inventory()
            .map(|i| i.nodes_by_zone().into_iter().map(|(z, _)| z).collect())
            .unwrap_or_default();
        (0..n)
            .map(|m| {
                let scale = classes.as_ref().map_or(1.0, |c| c[m].drop_sla_scale());
                Mutex::new(Router::new(rc.clone(), slas[m] * scale, zone_names.clone()))
            })
            .collect()
    });
    if let Some(rs) = &routers {
        let init_cfgs: Vec<PipelineConfig> = inits.iter().map(|d| d.config.clone()).collect();
        sync_live_routers(rs, &fleet, &init_cfgs, 0.0);
    }

    let shared = Arc::new(FleetShared {
        fleet: Mutex::new(fleet),
        cv: Condvar::new(),
        monitors: (0..n).map(|_| Mutex::new(Monitor::new(600))).collect(),
        routers,
        grid: LaneGrid::new(&n_stages, DEFAULT_LANE_CAPACITY),
        configs: ConfigCell::new(inits.iter().map(|d| d.config.clone()).collect()),
        tel: Arc::clone(&tel),
        stop: StopGate::default(),
        start: Instant::now(),
    });

    // ---- worker threads: replica slots per (member, stage) -----------
    let legacy_lock = cfg.legacy_lock;
    let mut workers = Vec::new();
    for (m, &stages) in n_stages.iter().enumerate() {
        for si in 0..stages {
            for _ in 0..cfg.max_workers {
                let sh = Arc::clone(&shared);
                let ex = Arc::clone(&executors[m]);
                workers.push(std::thread::spawn(move || {
                    if legacy_lock {
                        fleet_worker_loop(sh, ex, m, si, stages);
                    } else {
                        fleet_worker_loop_sharded(sh, ex, m, si, stages);
                    }
                }));
            }
        }
    }

    // ---- adapter thread: the joint solver on a wall clock ------------
    // Each interval splits in two: a mid-interval preemption check (the
    // fast path — no joint IP, applied immediately), then the slow path
    // at the full interval (autoscaler resize proposal → joint decide →
    // staged apply), mirroring run_fleet_des' Adapt/Preempt events.
    let adapter_handle = {
        let sh = Arc::clone(&shared);
        let exs: Vec<Arc<dyn BatchExecutor>> = executors.clone();
        let mut active: Vec<PipelineConfig> = inits.iter().map(|d| d.config.clone()).collect();
        let mut reconfig =
            FleetReconfig::with_migration(adapter.config.apply_delay, migration_delay);
        reconfig.set_journal(tel.journal());
        // The controller's current pool view; staged shrinks below it
        // are stale (a later tick re-grew the budget) and are skipped.
        let mut ctl_budget = budget;
        std::thread::spawn(move || {
            loop {
                let half = adapter.config.interval * 0.5;
                if !sh.stop.sleep_interruptible(half) {
                    break;
                }
                // ---- fast path: mid-interval preemption check -------
                // Skipped entirely when the tuning has no preemption:
                // the fixed-pool path must not even touch the monitors
                // here.
                if adapter.wants_preemption() {
                    let nowp = sh.now();
                    let pwindow = half.max(1.0) as usize;
                    let observed_p: Vec<f64> = sh
                        .monitors
                        .iter()
                        .map(|mo| mo.lock().unwrap().recent_rate(nowp, pwindow))
                        .collect();
                    if let Some(p) = adapter.preempt(nowp, &observed_p) {
                        for (m, d) in p.decisions.iter().enumerate() {
                            for sc in &d.config.stages {
                                exs[m].warm(&sc.variant_key, sc.batch);
                            }
                        }
                        let configs: Vec<(PipelineConfig, f64)> = p
                            .decisions
                            .iter()
                            .map(|d| (d.config.clone(), f64::INFINITY))
                            .collect();
                        let mut fleet = sh.fleet.lock().unwrap();
                        fleet.accrue(nowp);
                        match fleet.apply(&configs) {
                            Ok(()) => {
                                // Only a preemption that actually took
                                // effect supersedes the staged slow-path
                                // decision (clearing on a rejected one
                                // would strand the fleet on its stale
                                // configuration for a full interval).
                                reconfig.clear();
                                let floor = fleet.configured_replicas();
                                let _ = fleet.resize_pool_with(
                                    nowp,
                                    p.budget.max(floor),
                                    adapter.node_inventory().as_ref(),
                                );
                                fleet.note_preemption(&p.from);
                                active = p.decisions.into_iter().map(|d| d.config).collect();
                                drop(fleet);
                                // publish after dropping the fleet lock
                                sh.configs.publish(active.clone());
                            }
                            Err(e) => {
                                drop(fleet);
                                crate::log_warn!("fleet", "preemption apply rejected: {e}");
                            }
                        }
                        sh.cv.notify_all();
                    }
                }
                if !sh.stop.sleep_interruptible(half) {
                    break;
                }
                // ---- slow path: autoscale + joint decide ------------
                let now = sh.now();
                let window = adapter.config.interval.max(1.0) as usize;
                let (histories, observed): (Vec<Vec<f64>>, Vec<f64>) = (
                    sh.monitors
                        .iter()
                        .map(|mo| mo.lock().unwrap().history(now, crate::predictor::HISTORY))
                        .collect(),
                    sh.monitors
                        .iter()
                        .map(|mo| mo.lock().unwrap().recent_rate(now, window))
                        .collect(),
                );
                let mut phys_budget = sh.fleet.lock().unwrap().budget();
                // Drift correction: a staged shrink dropped on the way
                // (coalescing, or a preemption clearing the stager)
                // would otherwise strand the physical pool above the
                // controller's view forever — re-sync once nothing is
                // pending (best-effort: never below configured).
                if reconfig.pending_len() == 0 && phys_budget > ctl_budget {
                    let mirror = adapter.node_inventory();
                    let mut fleet = sh.fleet.lock().unwrap();
                    fleet.accrue(now);
                    let floor = fleet.configured_replicas();
                    let _ = fleet.resize_pool_with(now, ctl_budget.max(floor), mirror.as_ref());
                    phys_budget = fleet.budget();
                }
                let pool_to = adapter.resize(now, &histories);
                if let Some(pnew) = pool_to {
                    if pnew > phys_budget {
                        // mirror the controller's inventory: with
                        // pressure-aware buying the bought shape no
                        // longer follows from the target alone
                        let mirror = adapter.node_inventory();
                        let mut fleet = sh.fleet.lock().unwrap();
                        fleet.accrue(now);
                        if let Err(e) = fleet.resize_pool_with(now, pnew, mirror.as_ref()) {
                            crate::log_warn!("fleet", "pool grow rejected: {e}");
                        }
                    }
                    ctl_budget = pnew;
                }
                let ds = adapter.decide(now, &histories);
                {
                    let mut fleet = sh.fleet.lock().unwrap();
                    for (m, d) in ds.iter().enumerate() {
                        fleet
                            .member_mut(m)
                            .accounting
                            .record_interval(now, &active[m], observed[m], d);
                    }
                }
                // warm targets before the switch, then apply after delay
                for (m, d) in ds.iter().enumerate() {
                    for sc in &d.config.stages {
                        exs[m].warm(&sc.variant_key, sc.batch);
                    }
                }
                let shrink_to = pool_to.filter(|&p| p < phys_budget);
                // price the decision's churn into the activation time
                let moves = if reconfig.migration_delay > 0.0 {
                    let cfgs: Vec<&PipelineConfig> = ds.iter().map(|d| &d.config).collect();
                    sh.fleet.lock().unwrap().plan_moves(&cfgs)
                } else {
                    0
                };
                let at = reconfig.stage(now, ds, ctl_budget, shrink_to, moves);
                if !sh.stop.sleep_interruptible(at - sh.now()) {
                    break;
                }
                // pop_due coalesces: every due stage drains, only the
                // newest applies.
                while let Some(staged) = reconfig.pop_due(sh.now()) {
                    let configs: Vec<(PipelineConfig, f64)> = staged
                        .decisions
                        .iter()
                        .map(|d| (d.config.clone(), f64::INFINITY))
                        .collect();
                    let mut fleet = sh.fleet.lock().unwrap();
                    fleet.accrue(sh.now());
                    match fleet.apply(&configs) {
                        Ok(()) => {
                            // a shrink is only safe when it covers the
                            // controller's current budget AND every
                            // pending stage's solve budget (nothing
                            // bigger still in flight)
                            if let Some(pb) = staged.shrink_to {
                                let in_flight = ctl_budget
                                    .max(reconfig.max_pending_budget().unwrap_or(0));
                                if pb >= in_flight {
                                    let mirror = adapter.node_inventory();
                                    if let Err(e) =
                                        fleet.resize_pool_with(sh.now(), pb, mirror.as_ref())
                                    {
                                        crate::log_warn!("fleet", "pool shrink rejected: {e}");
                                    }
                                }
                            }
                            active = staged.decisions.into_iter().map(|d| d.config).collect();
                            drop(fleet);
                            // publish after dropping the fleet lock
                            sh.configs.publish(active.clone());
                        }
                        Err(e) => {
                            // unreachable for solver-built decisions;
                            // keep serving on the old configuration
                            drop(fleet);
                            crate::log_warn!("fleet", "joint apply rejected: {e}");
                        }
                    }
                    sh.cv.notify_all();
                }
                // Front door: sync the routable topology to whatever
                // this interval applied (replica counts, packing zones,
                // active service estimate) and flush the per-member
                // route/degrade/admit journal counters on the wall
                // clock — the live mirror of the DES Adapt arm.
                if let Some(rs) = &sh.routers {
                    let tnow = sh.now();
                    {
                        let fleet = sh.fleet.lock().unwrap();
                        sync_live_routers(rs, &fleet, &active, tnow);
                    }
                    journal_live_route_ticks(&sh.tel, tnow, rs);
                }
            }
        })
    };

    // ---- merged load generation (blocking) ---------------------------
    let submitted = loadgen::replay_fleet(traces, lg, |m, id, _t| {
        let t = shared.now();
        shared.monitors[m].lock().unwrap().record_arrival(t);
        if shared.tel.enabled() && shared.tel.sampled(id) {
            shared.tel.record(Span {
                trace: id,
                member: m as u32,
                stage: 0,
                hop: Hop::Arrival,
                t,
                dur: 0.0,
                value: 0.0,
            });
        }
        // Front door first: a Shed verdict books the §4.5 drop without
        // ever enqueueing; Route/Degrade fall through to normal ingress
        // (the router's on_batch prices them later).
        let shed = shared
            .routers
            .as_ref()
            .map(|rs| matches!(rs[m].lock().unwrap().route(id, t), RouteOutcome::Shed))
            .unwrap_or(false);
        if shed {
            ingress::shed(shared.fleet.lock().unwrap().member_mut(m), id, t);
            if shared.tel.enabled() && shared.tel.sampled(id) {
                shared.tel.record(Span {
                    trace: id,
                    member: m as u32,
                    stage: 0,
                    hop: Hop::Drop,
                    t,
                    dur: 0.0,
                    value: 0.0,
                });
            }
        } else if legacy_lock {
            shared.fleet.lock().unwrap().member_mut(m).ingest(id, t);
        } else if !shared.grid.ingest(m, id, t) {
            ingress::shed(shared.fleet.lock().unwrap().member_mut(m), id, t);
        }
        shared.cv.notify_all();
    });
    let total_submitted: usize = submitted.iter().sum();

    // ---- drain & stop -------------------------------------------------
    let max_sla = slas.iter().fold(0.0f64, |a, &b| a.max(b));
    let drain_deadline = Instant::now() + Duration::from_secs_f64(3.0 + 4.0 * max_sla);
    loop {
        let done: usize = {
            let f = shared.fleet.lock().unwrap();
            (0..n).map(|m| f.member(m).accounting.done()).sum()
        };
        if done >= total_submitted || Instant::now() > drain_deadline {
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    shared.stop.stop();
    shared.cv.notify_all();
    for w in workers {
        let _ = w.join();
    }
    let _ = adapter_handle.join();

    // ---- assemble per-member metrics + pool accounting ----------------
    let (metrics_vec, peak_in_use, final_replicas, pool) = {
        let mut f = shared.fleet.lock().unwrap();
        f.accrue(shared.now());
        f.note();
        let peak = f.peak_in_use();
        let finals: Vec<u32> = (0..n).map(|m| f.member(m).configured_replicas()).collect();
        let pool = f.pool_report();
        let mut out = Vec::with_capacity(n);
        for m in 0..n {
            let acc =
                std::mem::replace(&mut f.member_mut(m).accounting, Accounting::new(slas[m]));
            out.push(acc.into_metrics(
                system.to_string(),
                specs[m].name.to_string(),
                traces[m].name.clone(),
            ));
        }
        (out, peak, finals, pool)
    };
    let members = metrics_vec
        .into_iter()
        .zip(profiles)
        .zip(&slas)
        .map(|((metrics, profiles), &sla)| ServeReport { metrics, profiles, sla })
        .collect();
    let router_stats: Vec<RouterStats> = shared
        .routers
        .as_ref()
        .map(|rs| rs.iter().map(|r| r.lock().unwrap().stats().clone()).collect())
        .unwrap_or_else(|| vec![RouterStats::default(); n]);
    Ok(FleetServeReport {
        members,
        budget: pool.budget,
        peak_in_use,
        final_replicas,
        pool,
        router: router_stats,
    })
}

/// Compatibility shim for the pre-builder 11-argument entry point.
#[deprecated(note = "use `serve_fleet` with `FleetServeParams`, or the \
                     `fleet::run::FleetRun` builder")]
#[allow(clippy::too_many_arguments)]
pub fn serve_fleet_with(
    specs: &[PipelineSpec],
    profiles: Vec<PipelineProfiles>,
    metric: AccuracyMetric,
    budget: u32,
    system: &str,
    cfg: &ServeConfig,
    lg: LoadGenConfig,
    traces: &[Trace],
    executors: Vec<Arc<dyn BatchExecutor>>,
    predictors: Vec<Box<dyn Predictor + Send>>,
    tuning: FleetTuning,
) -> Result<FleetServeReport> {
    serve_fleet(FleetServeParams {
        specs,
        profiles,
        metric,
        budget,
        system,
        cfg,
        lg,
        traces,
        executors,
        predictors,
        tuning,
        router: None,
        telemetry: None,
    })
}

/// Compatibility shim: [`serve_fleet`] with the telemetry plane as a
/// trailing argument.
#[deprecated(note = "use `serve_fleet` with `FleetServeParams`, or the \
                     `fleet::run::FleetRun` builder")]
#[allow(clippy::too_many_arguments)]
pub fn serve_fleet_traced(
    specs: &[PipelineSpec],
    profiles: Vec<PipelineProfiles>,
    metric: AccuracyMetric,
    budget: u32,
    system: &str,
    cfg: &ServeConfig,
    lg: LoadGenConfig,
    traces: &[Trace],
    executors: Vec<Arc<dyn BatchExecutor>>,
    predictors: Vec<Box<dyn Predictor + Send>>,
    tuning: FleetTuning,
    tel: Arc<Telemetry>,
) -> Result<FleetServeReport> {
    serve_fleet(FleetServeParams {
        specs,
        profiles,
        metric,
        budget,
        system,
        cfg,
        lg,
        traces,
        executors,
        predictors,
        tuning,
        router: None,
        telemetry: Some(tel),
    })
}

/// Sync every member router's routable topology from the live fleet:
/// stage-0 replica count, per-replica zone labels from the last
/// packing, and the active config's per-request service estimate
/// (`l(b)/b`) — then reclaim tags past the drop horizon.  The live
/// mirror of the DES `resync_router`.
fn sync_live_routers(
    routers: &[Mutex<Router>],
    fleet: &FleetCore,
    active: &[PipelineConfig],
    now: f64,
) {
    for (m, slot) in routers.iter().enumerate() {
        let core = fleet.member(m);
        let replicas = core.stages[0].replicas.max(1) as usize;
        let zones: Vec<String> = match (fleet.last_packing(), fleet.inventory()) {
            (Some(p), Some(inv)) => p
                .placements
                .iter()
                .filter(|pl| pl.member == m && pl.stage == 0)
                .map(|pl| inv.pools[p.shape_of[pl.node]].shape.zone.clone())
                .collect(),
            _ => Vec::new(),
        };
        let sc = &active[m].stages[0];
        let spi = sc.latency / sc.batch.max(1) as f64;
        let mut router = slot.lock().unwrap();
        router.set_topology(replicas, zones, spi);
        router.expire(now);
    }
}

/// Flush each member router's since-last-tick counters into the
/// journal (`route`/`degrade`/`admit` events on the wall clock) — the
/// live mirror of the DES `journal_route_ticks`.
fn journal_live_route_ticks(tel: &Telemetry, now: f64, routers: &[Mutex<Router>]) {
    for (m, slot) in routers.iter().enumerate() {
        let mut router = slot.lock().unwrap();
        let tick = router.take_tick();
        if tick.routed == 0 && tick.shed == 0 {
            continue;
        }
        tel.journal().record(
            now,
            "route",
            Json::obj()
                .set("member", m as i64)
                .set("routed", tick.routed as i64)
                .set("cross_zone", tick.cross_zone as i64)
                .set("warm", tick.warm_hits as i64)
                .set("skew", router.stats().utilization_skew()),
        );
        if tick.degraded > 0 {
            tel.journal().record(
                now,
                "degrade",
                Json::obj()
                    .set("member", m as i64)
                    .set("count", tick.degraded as i64),
            );
        }
        if tick.shed > 0 {
            tel.journal().record(
                now,
                "admit",
                Json::obj()
                    .set("member", m as i64)
                    .set("shed", tick.shed as i64),
            );
        }
    }
}

/// One fleet replica-slot worker, legacy single-lock path: claim a
/// batch for (member, stage) from the shared fleet core, execute it,
/// route survivors forward.
fn fleet_worker_loop(
    sh: Arc<FleetShared>,
    exec: Arc<dyn BatchExecutor>,
    member: usize,
    stage: usize,
    n_stages: usize,
) {
    loop {
        if sh.stop.is_stopped() {
            return;
        }
        let fb: FormedBatch = {
            let mut fleet = sh.fleet.lock().unwrap();
            loop {
                if sh.stop.is_stopped() {
                    return;
                }
                match fleet.member_mut(member).try_form(stage, sh.now()) {
                    FormOutcome::Formed(fb) => {
                        fleet.note();
                        break fb;
                    }
                    FormOutcome::Busy | FormOutcome::Idle { .. } => {
                        let (guard, _) = sh
                            .cv
                            .wait_timeout(fleet, Duration::from_millis(20))
                            .unwrap();
                        fleet = guard;
                    }
                }
            }
        };
        // Front-door bookkeeping: a formed stage-0 batch frees its
        // requests' in-flight slots (the wall clock ignores the
        // returned latency adjustment — the executor really sleeps).
        if stage == 0 {
            if let Some(rs) = &sh.routers {
                let _ = rs[member].lock().unwrap().on_batch(&fb.requests);
            }
        }
        match exec.execute(&fb.variant_key, fb.batch.max(1)) {
            Ok(()) => {
                let done = sh.now();
                let mut fleet = sh.fleet.lock().unwrap();
                let core = fleet.member_mut(member);
                core.finish_service(stage);
                if stage + 1 < n_stages {
                    for r in fb.requests {
                        core.forward(stage + 1, r, done);
                    }
                } else {
                    for r in &fb.requests {
                        core.complete(r.id, done);
                    }
                }
                drop(fleet);
                sh.cv.notify_all();
            }
            Err(e) => {
                crate::log_warn!("serving", "fleet execute failed: {e:#}");
                let mut fleet = sh.fleet.lock().unwrap();
                let core = fleet.member_mut(member);
                core.finish_service(stage);
                for r in &fb.requests {
                    core.accounting.record_drop(r.id);
                }
                drop(fleet);
                sh.cv.notify_all();
            }
        }
    }
}

/// One fleet replica-slot worker, sharded path (the default): drain the
/// (member, stage) ingress lane into the member core and claim a batch
/// under one short fleet-lock acquisition; survivors ride the next
/// stage's lane lock-free (locked fallback for ring-full leftovers).
fn fleet_worker_loop_sharded(
    sh: Arc<FleetShared>,
    exec: Arc<dyn BatchExecutor>,
    member: usize,
    stage: usize,
    n_stages: usize,
) {
    let mut reader = sh.configs.reader();
    loop {
        if sh.stop.is_stopped() {
            return;
        }
        let limit = drain_limit(&reader.get(&sh.configs)[member], stage);
        let fb: FormedBatch = {
            let mut fleet = sh.fleet.lock().unwrap();
            loop {
                if sh.stop.is_stopped() {
                    return;
                }
                let now = sh.now();
                sh.grid.drain_into(member, stage, fleet.member_mut(member), limit);
                match fleet.member_mut(member).try_form(stage, now) {
                    FormOutcome::Formed(fb) => {
                        fleet.note();
                        break fb;
                    }
                    FormOutcome::Busy | FormOutcome::Idle { .. } => {
                        let (guard, _) = sh
                            .cv
                            .wait_timeout(fleet, Duration::from_millis(20))
                            .unwrap();
                        fleet = guard;
                    }
                }
            }
        };
        let formed_at = sh.now();
        // Front-door bookkeeping (see fleet_worker_loop): stage-0
        // batches release their routed in-flight slots.
        if stage == 0 {
            if let Some(rs) = &sh.routers {
                let _ = rs[member].lock().unwrap().on_batch(&fb.requests);
            }
        }
        if sh.tel.enabled() {
            for r in &fb.requests {
                if sh.tel.sampled(r.id) {
                    let base = Span {
                        trace: r.id,
                        member: member as u32,
                        stage: stage as u32,
                        hop: Hop::QueueWait,
                        t: r.stage_arrival,
                        dur: formed_at - r.stage_arrival,
                        value: fb.requests.len() as f64,
                    };
                    sh.tel.record(base);
                    sh.tel.record(Span {
                        hop: Hop::BatchForm,
                        t: formed_at,
                        dur: 0.0,
                        value: fb.batch as f64,
                        ..base
                    });
                }
            }
        }
        match exec.execute(&fb.variant_key, fb.batch.max(1)) {
            Ok(()) => {
                let done = sh.now();
                if sh.tel.enabled() {
                    for r in &fb.requests {
                        if sh.tel.sampled(r.id) {
                            sh.tel.record(Span {
                                trace: r.id,
                                member: member as u32,
                                stage: stage as u32,
                                hop: Hop::Exec,
                                t: formed_at,
                                dur: done - formed_at,
                                value: fb.requests.len() as f64,
                            });
                            let (hop, dur, value) = if stage + 1 < n_stages {
                                (Hop::Forward, 0.0, (stage + 1) as f64)
                            } else {
                                (Hop::Done, done - r.arrival, 0.0)
                            };
                            sh.tel.record(Span {
                                trace: r.id,
                                member: member as u32,
                                stage: stage as u32,
                                hop,
                                t: done,
                                dur,
                                value,
                            });
                        }
                    }
                }
                if stage + 1 < n_stages {
                    let mut survivors = fb.requests;
                    for r in &mut survivors {
                        r.stage_arrival = done;
                    }
                    let leftovers = sh.grid.forward(member, stage + 1, survivors);
                    let mut fleet = sh.fleet.lock().unwrap();
                    let core = fleet.member_mut(member);
                    core.finish_service(stage);
                    for r in leftovers {
                        core.forward(stage + 1, r, done);
                    }
                    drop(fleet);
                } else {
                    let mut fleet = sh.fleet.lock().unwrap();
                    let core = fleet.member_mut(member);
                    core.finish_service(stage);
                    for r in &fb.requests {
                        core.complete(r.id, done);
                    }
                    drop(fleet);
                }
                sh.cv.notify_all();
            }
            Err(e) => {
                crate::log_warn!("serving", "fleet execute failed: {e:#}");
                let dropped_at = sh.now();
                let mut fleet = sh.fleet.lock().unwrap();
                let core = fleet.member_mut(member);
                core.finish_service(stage);
                for r in &fb.requests {
                    core.accounting.record_drop(r.id);
                    if sh.tel.enabled() && sh.tel.sampled(r.id) {
                        sh.tel.record(Span {
                            trace: r.id,
                            member: member as u32,
                            stage: stage as u32,
                            hop: Hop::Drop,
                            t: dropped_at,
                            dur: dropped_at - r.arrival,
                            value: 0.0,
                        });
                    }
                }
                drop(fleet);
                sh.cv.notify_all();
            }
        }
    }
}
