//! Open-loop load tester (§5.1: "an asynchronous load tester was
//! implemented to emulate the behavior of users in real-world data
//! centers").
//!
//! Replays a [`Trace`]'s Poisson arrivals against a submit callback on
//! a real clock, optionally time-compressed (`time_scale < 1` runs the
//! trace faster; rates scale up accordingly — used by short live runs).

use std::time::{Duration, Instant};

use crate::workload::trace::Trace;

/// Load generation settings.
#[derive(Debug, Clone, Copy)]
pub struct LoadGenConfig {
    /// Wall-seconds per trace-second (1.0 = real time).
    pub time_scale: f64,
    /// Arrival-sampling seed.
    pub seed: u64,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        LoadGenConfig { time_scale: 1.0, seed: 11 }
    }
}

/// Replay `trace`, invoking `submit(id, wall_arrival_s)` for each
/// request.  Blocks until the trace is fully replayed; returns the
/// number of requests submitted.
pub fn replay<F: FnMut(u64, f64)>(
    trace: &Trace,
    cfg: LoadGenConfig,
    mut submit: F,
) -> usize {
    let arrivals = trace.arrivals(cfg.seed);
    let start = Instant::now();
    for (id, &t) in arrivals.iter().enumerate() {
        let due = t * cfg.time_scale;
        loop {
            let now = start.elapsed().as_secs_f64();
            if now >= due {
                break;
            }
            std::thread::sleep(Duration::from_secs_f64((due - now).min(0.02)));
        }
        submit(id as u64, start.elapsed().as_secs_f64());
    }
    arrivals.len()
}

/// Replay N member traces interleaved on ONE clock, invoking
/// `submit(member, id, wall_arrival_s)` per request (ids are
/// per-member, matching the fleet DES driver's id spaces — member `m`
/// samples its arrivals with [`member_seed`]`(cfg.seed, m)`).  Blocks
/// until every trace is fully replayed; returns the per-member
/// submission counts.
pub fn replay_fleet<F: FnMut(usize, u64, f64)>(
    traces: &[Trace],
    cfg: LoadGenConfig,
    mut submit: F,
) -> Vec<usize> {
    use crate::workload::tracegen::member_seed;
    let mut merged: Vec<(f64, usize, u64)> = Vec::new();
    let mut counts = vec![0usize; traces.len()];
    for (m, trace) in traces.iter().enumerate() {
        let arrivals = trace.arrivals(member_seed(cfg.seed, m));
        counts[m] = arrivals.len();
        merged.extend(arrivals.into_iter().enumerate().map(|(id, t)| (t, m, id as u64)));
    }
    // stable order: trace time, then member, then id — deterministic
    // even for simultaneous cross-member arrivals
    merged.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let start = Instant::now();
    for (t, m, id) in merged {
        let due = t * cfg.time_scale;
        loop {
            let now = start.elapsed().as_secs_f64();
            if now >= due {
                break;
            }
            std::thread::sleep(Duration::from_secs_f64((due - now).min(0.02)));
        }
        submit(m, id, start.elapsed().as_secs_f64());
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::tracegen::Pattern;

    #[test]
    fn replays_all_requests_in_order() {
        let trace = Trace::synthetic(Pattern::SteadyLow, 2);
        let mut seen = Vec::new();
        let n = replay(
            &trace,
            LoadGenConfig { time_scale: 0.01, seed: 1 },
            |id, t| seen.push((id, t)),
        );
        assert_eq!(seen.len(), n);
        for w in seen.windows(2) {
            assert!(w[0].0 < w[1].0);
            assert!(w[0].1 <= w[1].1 + 1e-6);
        }
    }

    #[test]
    fn time_scale_compresses() {
        let trace = Trace::synthetic(Pattern::SteadyLow, 3);
        let t0 = Instant::now();
        replay(&trace, LoadGenConfig { time_scale: 0.01, seed: 2 }, |_, _| {});
        // 3 trace-seconds at 100x compression ≈ 30ms wall
        assert!(t0.elapsed().as_secs_f64() < 1.0);
    }

    #[test]
    fn fleet_replay_interleaves_members_in_time_order() {
        let traces =
            vec![Trace::synthetic(Pattern::SteadyLow, 2), Trace::synthetic(Pattern::SteadyHigh, 2)];
        let mut seen: Vec<(usize, u64, f64)> = Vec::new();
        let counts = replay_fleet(
            &traces,
            LoadGenConfig { time_scale: 0.01, seed: 3 },
            |m, id, t| seen.push((m, id, t)),
        );
        assert_eq!(counts.len(), 2);
        assert_eq!(seen.len(), counts.iter().sum::<usize>());
        assert!(counts.iter().all(|&c| c > 0));
        // wall timestamps are non-decreasing across the merged stream
        for w in seen.windows(2) {
            assert!(w[0].2 <= w[1].2 + 1e-6);
        }
        // per-member ids are each a strictly increasing sequence
        for m in 0..2 {
            let ids: Vec<u64> = seen.iter().filter(|e| e.0 == m).map(|e| e.1).collect();
            assert_eq!(ids.len(), counts[m]);
            for w in ids.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
    }
}
