//! Open-loop load tester (§5.1: "an asynchronous load tester was
//! implemented to emulate the behavior of users in real-world data
//! centers").
//!
//! Replays a [`Trace`]'s Poisson arrivals against a submit callback on
//! a real clock, optionally time-compressed (`time_scale < 1` runs the
//! trace faster; rates scale up accordingly — used by short live runs).

use std::time::{Duration, Instant};

use crate::workload::trace::Trace;

/// Load generation settings.
#[derive(Debug, Clone, Copy)]
pub struct LoadGenConfig {
    /// Wall-seconds per trace-second (1.0 = real time).
    pub time_scale: f64,
    /// Arrival-sampling seed.
    pub seed: u64,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        LoadGenConfig { time_scale: 1.0, seed: 11 }
    }
}

/// Replay `trace`, invoking `submit(id, wall_arrival_s)` for each
/// request.  Blocks until the trace is fully replayed; returns the
/// number of requests submitted.
pub fn replay<F: FnMut(u64, f64)>(
    trace: &Trace,
    cfg: LoadGenConfig,
    mut submit: F,
) -> usize {
    let arrivals = trace.arrivals(cfg.seed);
    let start = Instant::now();
    for (id, &t) in arrivals.iter().enumerate() {
        let due = t * cfg.time_scale;
        loop {
            let now = start.elapsed().as_secs_f64();
            if now >= due {
                break;
            }
            std::thread::sleep(Duration::from_secs_f64((due - now).min(0.02)));
        }
        submit(id as u64, start.elapsed().as_secs_f64());
    }
    arrivals.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::tracegen::Pattern;

    #[test]
    fn replays_all_requests_in_order() {
        let trace = Trace::synthetic(Pattern::SteadyLow, 2);
        let mut seen = Vec::new();
        let n = replay(
            &trace,
            LoadGenConfig { time_scale: 0.01, seed: 1 },
            |id, t| seen.push((id, t)),
        );
        assert_eq!(seen.len(), n);
        for w in seen.windows(2) {
            assert!(w[0].0 < w[1].0);
            assert!(w[0].1 <= w[1].1 + 1e-6);
        }
    }

    #[test]
    fn time_scale_compresses() {
        let trace = Trace::synthetic(Pattern::SteadyLow, 3);
        let t0 = Instant::now();
        replay(&trace, LoadGenConfig { time_scale: 0.01, seed: 2 }, |_, _| {});
        // 3 trace-seconds at 100x compression ≈ 30ms wall
        assert!(t0.elapsed().as_secs_f64() < 1.0);
    }
}
