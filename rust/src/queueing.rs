//! Queueing: the Eq. 7 worst-case delay model and the central per-stage
//! batcher used by both the simulator and the live engine.
//!
//! §3: each pipeline stage has ONE centralized queue (deterministic
//! queueing behaviour, analytically modelable); the queue forms batches
//! of the configured size and round-robins them across the stage's
//! replicas.

/// Eq. 7: worst-case queueing delay at batch size `b` under arrival rate
/// `λ` — the first request of a batch waits for `b-1` more arrivals.
pub fn worst_case_delay(batch: usize, lambda: f64) -> f64 {
    if batch <= 1 {
        return 0.0;
    }
    (batch as f64 - 1.0) / lambda.max(1e-9)
}

/// A request flowing through the pipeline (simulator + live engine).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time at the pipeline entrance, seconds.
    pub arrival: f64,
    /// Arrival time at the current stage's queue, seconds.
    pub stage_arrival: f64,
}

/// Central FIFO queue + batcher for one stage.
///
/// A batch is released when `batch_size` requests are waiting, or when
/// the oldest waiting request has been queued for `timeout` seconds
/// (prevents starvation under low load; the paper's formulation assumes
/// full batches — the timeout is the engineering escape hatch).
#[derive(Debug)]
pub struct CentralQueue {
    pub batch_size: usize,
    pub timeout: f64,
    waiting: std::collections::VecDeque<Request>,
}

impl CentralQueue {
    pub fn new(batch_size: usize, timeout: f64) -> Self {
        Self { batch_size, timeout, waiting: Default::default() }
    }

    pub fn len(&self) -> usize {
        self.waiting.len()
    }

    pub fn is_empty(&self) -> bool {
        self.waiting.is_empty()
    }

    /// Reconfigure (model switch / batch change) — queued requests stay.
    pub fn set_batch(&mut self, batch_size: usize, timeout: f64) {
        self.batch_size = batch_size.max(1);
        self.timeout = timeout;
    }

    pub fn push(&mut self, req: Request) {
        self.waiting.push_back(req);
    }

    /// True if a full batch is ready.
    pub fn full_batch_ready(&self) -> bool {
        self.waiting.len() >= self.batch_size
    }

    /// True if the timeout has expired for the oldest request at `now`.
    pub fn timed_out(&self, now: f64) -> bool {
        self.waiting
            .front()
            .is_some_and(|r| now - r.stage_arrival >= self.timeout)
    }

    /// Absolute time at which the oldest waiting request times out.
    pub fn next_timeout_at(&self) -> Option<f64> {
        self.waiting.front().map(|r| r.stage_arrival + self.timeout)
    }

    /// Pop a batch if one is ready (full, or timed out at `now`).
    /// Timed-out batches may be partial.
    pub fn pop_batch(&mut self, now: f64) -> Option<Vec<Request>> {
        if self.full_batch_ready() {
            return Some(self.drain(self.batch_size));
        }
        if !self.waiting.is_empty() && self.timed_out(now) {
            let n = self.waiting.len().min(self.batch_size);
            return Some(self.drain(n));
        }
        None
    }

    /// Drain everything (used on reconfiguration drains / shutdown).
    pub fn drain_all(&mut self) -> Vec<Request> {
        self.waiting.drain(..).collect()
    }

    fn drain(&mut self, n: usize) -> Vec<Request> {
        self.waiting.drain(..n).collect()
    }
}

/// Round-robin replica dispatcher (§3: queues distribute batched
/// requests across model replicas round-robin).
#[derive(Debug, Clone)]
pub struct RoundRobin {
    n: usize,
    next: usize,
}

impl RoundRobin {
    pub fn new(n: usize) -> Self {
        Self { n: n.max(1), next: 0 }
    }

    pub fn resize(&mut self, n: usize) {
        self.n = n.max(1);
        self.next %= self.n;
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    pub fn pick(&mut self) -> usize {
        let i = self.next;
        self.next = (self.next + 1) % self.n;
        i
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, t: f64) -> Request {
        Request { id, arrival: t, stage_arrival: t }
    }

    #[test]
    fn eq7_worst_case() {
        assert_eq!(worst_case_delay(1, 10.0), 0.0);
        assert!((worst_case_delay(8, 20.0) - 0.35).abs() < 1e-12);
        assert!((worst_case_delay(4, 2.0) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn full_batch_release() {
        let mut q = CentralQueue::new(4, 10.0);
        for i in 0..3 {
            q.push(req(i, 0.0));
            assert!(q.pop_batch(0.0).is_none());
        }
        q.push(req(3, 0.1));
        let b = q.pop_batch(0.1).unwrap();
        assert_eq!(b.len(), 4);
        assert_eq!(b[0].id, 0, "FIFO order");
        assert!(q.is_empty());
    }

    #[test]
    fn timeout_releases_partial_batch() {
        let mut q = CentralQueue::new(8, 0.5);
        q.push(req(0, 1.0));
        q.push(req(1, 1.1));
        assert!(q.pop_batch(1.4).is_none());
        let b = q.pop_batch(1.6).unwrap();
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn next_timeout_at_tracks_oldest() {
        let mut q = CentralQueue::new(8, 0.5);
        assert_eq!(q.next_timeout_at(), None);
        q.push(req(0, 2.0));
        q.push(req(1, 2.3));
        assert_eq!(q.next_timeout_at(), Some(2.5));
    }

    #[test]
    fn reconfigure_keeps_queued() {
        let mut q = CentralQueue::new(8, 1.0);
        q.push(req(0, 0.0));
        q.push(req(1, 0.0));
        q.set_batch(2, 1.0);
        let b = q.pop_batch(0.0).unwrap();
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn excess_stays_queued() {
        let mut q = CentralQueue::new(2, 1.0);
        for i in 0..5 {
            q.push(req(i, 0.0));
        }
        assert_eq!(q.pop_batch(0.0).unwrap().len(), 2);
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn round_robin_cycles() {
        let mut rr = RoundRobin::new(3);
        assert_eq!(
            (0..7).map(|_| rr.pick()).collect::<Vec<_>>(),
            vec![0, 1, 2, 0, 1, 2, 0]
        );
        rr.resize(2);
        let picks: Vec<usize> = (0..4).map(|_| rr.pick()).collect();
        assert!(picks.iter().all(|&p| p < 2));
    }
}
