//! Queueing: the Eq. 7 worst-case delay model and the [`Request`] type
//! flowing through the pipeline.
//!
//! §3: each pipeline stage has ONE centralized queue (deterministic
//! queueing behaviour, analytically modelable).  This module holds the
//! *analytic* side the optimizer plans with; the *executable* batcher
//! that used to live here ([`crate::cluster::dispatch::CentralQueue`])
//! moved into the shared cluster core so the simulator, the live engine
//! and the replay driver run the exact same machinery.

/// Eq. 7: worst-case queueing delay at batch size `b` under arrival rate
/// `λ` — the first request of a batch waits for `b-1` more arrivals.
pub fn worst_case_delay(batch: usize, lambda: f64) -> f64 {
    if batch <= 1 {
        return 0.0;
    }
    (batch as f64 - 1.0) / lambda.max(1e-9)
}

/// A request flowing through the pipeline (all drivers).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time at the pipeline entrance, seconds.
    pub arrival: f64,
    /// Arrival time at the current stage's queue, seconds.
    pub stage_arrival: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq7_worst_case() {
        assert_eq!(worst_case_delay(1, 10.0), 0.0);
        assert!((worst_case_delay(8, 20.0) - 0.35).abs() < 1e-12);
        assert!((worst_case_delay(4, 2.0) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn batch_one_never_waits() {
        for lambda in [0.1, 1.0, 100.0] {
            assert_eq!(worst_case_delay(1, lambda), 0.0);
        }
    }
}
