//! Exposition formats: a Prometheus text-format snapshot of a span dump
//! plus journal counters.  Pure functions over drained data — nothing
//! here touches the hot path.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use super::hist::{bucket_upper_edge, Histogram};
use super::journal::Journal;
use super::{stage_histograms, Hop, Span};

/// Emit every 8th bucket edge (16 cumulative buckets + `+Inf`) — enough
/// resolution for dashboards without drowning the exposition.
const EDGE_STRIDE: usize = 8;

fn write_histogram(out: &mut String, name: &str, labels: &str, h: &Histogram) {
    let counts = h.bucket_counts();
    let mut cum = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        cum += c;
        if (i + 1) % EDGE_STRIDE == 0 {
            let le = bucket_upper_edge(i);
            let _ = writeln!(out, "{name}_bucket{{{labels},le=\"{le:e}\"}} {cum}");
        }
    }
    let _ = writeln!(out, "{name}_bucket{{{labels},le=\"+Inf\"}} {}", h.count());
    let _ = writeln!(out, "{name}_sum{{{labels}}} {}", h.sum());
    let _ = writeln!(out, "{name}_count{{{labels}}} {}", h.count());
}

/// Render a drained span set + journal as Prometheus text format:
/// per-hop span counters, per-kind journal counters, and per
/// member×stage queue-wait / exec / batch histograms.
pub fn prometheus_text(spans: &[Span], journal: &Journal) -> String {
    let mut out = String::new();

    let _ = writeln!(out, "# TYPE ipa_spans_total counter");
    let mut by_hop: BTreeMap<&'static str, u64> = BTreeMap::new();
    for s in spans {
        *by_hop.entry(s.hop.name()).or_insert(0) += 1;
    }
    for h in Hop::ALL {
        let n = by_hop.get(h.name()).copied().unwrap_or(0);
        let _ = writeln!(out, "ipa_spans_total{{hop=\"{}\"}} {n}", h.name());
    }

    let _ = writeln!(out, "# TYPE ipa_journal_entries_total counter");
    let mut by_kind: BTreeMap<String, u64> = BTreeMap::new();
    for e in journal.entries() {
        *by_kind.entry(e.kind).or_insert(0) += 1;
    }
    for (kind, n) in &by_kind {
        let _ = writeln!(out, "ipa_journal_entries_total{{kind=\"{kind}\"}} {n}");
    }

    let _ = writeln!(out, "# TYPE ipa_stage_queue_wait_seconds histogram");
    let _ = writeln!(out, "# TYPE ipa_stage_exec_seconds histogram");
    let _ = writeln!(out, "# TYPE ipa_stage_batch_size histogram");
    for series in stage_histograms(spans) {
        let labels = format!("member=\"{}\",stage=\"{}\"", series.member, series.stage);
        write_histogram(&mut out, "ipa_stage_queue_wait_seconds", &labels, &series.queue_wait);
        write_histogram(&mut out, "ipa_stage_exec_seconds", &labels, &series.exec);
        write_histogram(&mut out, "ipa_stage_batch_size", &labels, &series.batch);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn snapshot_contains_counters_and_histograms() {
        let span = |hop, t, dur, value| Span { trace: 0, member: 0, stage: 0, hop, t, dur, value };
        let spans = vec![
            span(Hop::QueueWait, 0.0, 0.1, 1.0),
            span(Hop::Exec, 0.1, 0.2, 4.0),
            span(Hop::Done, 0.3, 0.3, 0.0),
        ];
        let j = Journal::new();
        j.record(1.0, "solve", Json::obj());
        j.record(2.0, "solve", Json::obj());
        let text = prometheus_text(&spans, &j);
        assert!(text.contains("ipa_spans_total{hop=\"done\"} 1"));
        assert!(text.contains("ipa_spans_total{hop=\"drop\"} 0"));
        assert!(text.contains("ipa_journal_entries_total{kind=\"solve\"} 2"));
        assert!(text.contains("ipa_stage_exec_seconds_count{member=\"0\",stage=\"0\"} 1"));
        assert!(text.contains("le=\"+Inf\"} 1"));
    }

    #[test]
    fn deterministic_output() {
        let spans = vec![Span {
            trace: 5,
            member: 1,
            stage: 0,
            hop: Hop::Exec,
            t: 0.0,
            dur: 0.05,
            value: 2.0,
        }];
        let j = Journal::new();
        let a = prometheus_text(&spans, &j);
        let b = prometheus_text(&spans, &j);
        assert_eq!(a, b);
    }
}
