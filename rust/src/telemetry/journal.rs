//! Control-plane decision journal: a structured, seq-stamped event log.
//!
//! Every control-plane actor (solver adapter, autoscaler, preemption
//! fast path, fleet core, staged reconfig, both clocks) records *why*
//! the fleet changed — solver decisions with per-member shares and the
//! rejected next-share costs, pool resizes with the pressure axis,
//! preemptions, migrations, zone kills, reconfig stage/activate — as
//! [`JournalEntry`] rows.  The journal serializes to JSONL via
//! [`crate::util::json`] and parses back; `decision` entries replay
//! against [`crate::simulator::replay`] to reproduce the exact fleet
//! configs of the recorded run.
//!
//! Determinism contract: entries carry the *virtual* clock (`t`) and a
//! per-journal sequence counter — never wall-clock readings — so two
//! identical seeded runs produce byte-identical JSONL (`Json::Obj` is a
//! `BTreeMap`, so key order is stable too).

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::coordinator::adapter::Decision;
use crate::optimizer::ip::{PipelineConfig, StageConfig};
use crate::resources::ResourceVec;
use crate::util::json::Json;

/// One journal row.
#[derive(Debug, Clone, PartialEq)]
pub struct JournalEntry {
    /// Per-journal sequence number (total order over all actors).
    pub seq: u64,
    /// Virtual time of the event, seconds.
    pub t: f64,
    /// Event kind, e.g. `solve`, `resize`, `preempt`, `stage`.
    pub kind: String,
    /// Kind-specific payload.
    pub data: Json,
}

impl JournalEntry {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("seq", self.seq as i64)
            .set("t", self.t)
            .set("kind", self.kind.as_str())
            .set("data", self.data.clone())
    }

    pub fn from_json(j: &Json) -> Result<JournalEntry, String> {
        Ok(JournalEntry {
            seq: get_f64(j, "seq")? as u64,
            t: get_f64(j, "t")?,
            kind: get_str(j, "kind")?,
            data: j.get("data").cloned().ok_or("journal entry missing 'data'")?,
        })
    }
}

/// Thread-safe, seq-stamped event log shared across control-plane
/// actors via `Arc<Journal>`.
pub struct Journal {
    seq: AtomicU64,
    entries: Mutex<Vec<JournalEntry>>,
}

impl fmt::Debug for Journal {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Journal({} entries)", self.len())
    }
}

impl Default for Journal {
    fn default() -> Self {
        Journal::new()
    }
}

impl Journal {
    pub fn new() -> Journal {
        Journal { seq: AtomicU64::new(0), entries: Mutex::new(Vec::new()) }
    }

    /// Append an event at virtual time `t`; returns its seq stamp.  The
    /// stamp is also published to [`crate::util::log`] so interleaved
    /// log lines can be ordered against the journal.
    pub fn record(&self, t: f64, kind: &str, data: Json) -> u64 {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        crate::util::log::note_journal_seq(seq + 1);
        self.entries
            .lock()
            .unwrap()
            .push(JournalEntry { seq, t, kind: kind.to_string(), data });
        seq
    }

    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of all entries (in seq order as recorded).
    pub fn entries(&self) -> Vec<JournalEntry> {
        self.entries.lock().unwrap().clone()
    }

    /// Serialize to JSONL (one entry per line, trailing newline).
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for e in self.entries.lock().unwrap().iter() {
            out.push_str(&e.to_json().to_string());
            out.push('\n');
        }
        out
    }

    /// Parse a JSONL dump back into a journal (blank lines skipped).
    pub fn parse_jsonl(s: &str) -> Result<Journal, String> {
        let j = Journal::new();
        let mut max_seq = 0u64;
        {
            let mut entries = j.entries.lock().unwrap();
            for (ln, line) in s.lines().enumerate() {
                if line.trim().is_empty() {
                    continue;
                }
                let v = Json::parse(line).map_err(|e| format!("line {}: {e}", ln + 1))?;
                let e = JournalEntry::from_json(&v).map_err(|e| format!("line {}: {e}", ln + 1))?;
                max_seq = max_seq.max(e.seq + 1);
                entries.push(e);
            }
        }
        j.seq.store(max_seq, Ordering::Relaxed);
        Ok(j)
    }
}

// ---- config <-> json -------------------------------------------------------

fn get_f64(j: &Json, k: &str) -> Result<f64, String> {
    j.get(k).and_then(Json::as_f64).ok_or_else(|| format!("missing number field '{k}'"))
}

fn get_str(j: &Json, k: &str) -> Result<String, String> {
    j.get(k)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| format!("missing string field '{k}'"))
}

fn get_bool(j: &Json, k: &str) -> Result<bool, String> {
    j.get(k).and_then(Json::as_bool).ok_or_else(|| format!("missing bool field '{k}'"))
}

fn resources_to_json(r: ResourceVec) -> Json {
    Json::obj()
        .set("cpu_cores", r.cpu_cores)
        .set("memory_gb", r.memory_gb)
        .set("accel_slots", r.accel_slots)
}

fn resources_from_json(j: &Json) -> Result<ResourceVec, String> {
    Ok(ResourceVec {
        cpu_cores: get_f64(j, "cpu_cores")?,
        memory_gb: get_f64(j, "memory_gb")?,
        accel_slots: get_f64(j, "accel_slots")?,
    })
}

fn stage_to_json(s: &StageConfig) -> Json {
    Json::obj()
        .set("variant_idx", s.variant_idx)
        .set("variant_key", s.variant_key.as_str())
        .set("batch", s.batch)
        .set("replicas", s.replicas as i64)
        .set("cost", s.cost)
        .set("accuracy", s.accuracy)
        .set("latency", s.latency)
        .set("resources", resources_to_json(s.resources))
}

fn stage_from_json(j: &Json) -> Result<StageConfig, String> {
    Ok(StageConfig {
        variant_idx: get_f64(j, "variant_idx")? as usize,
        variant_key: get_str(j, "variant_key")?,
        batch: get_f64(j, "batch")? as usize,
        replicas: get_f64(j, "replicas")? as u32,
        cost: get_f64(j, "cost")?,
        accuracy: get_f64(j, "accuracy")?,
        latency: get_f64(j, "latency")?,
        resources: resources_from_json(
            j.get("resources").ok_or("stage missing 'resources'")?,
        )?,
    })
}

/// Serialize a [`PipelineConfig`] losslessly (floats round-trip through
/// the shortest-representation printer exactly).
pub fn config_to_json(c: &PipelineConfig) -> Json {
    let stages: Vec<Json> = c.stages.iter().map(stage_to_json).collect();
    Json::obj()
        .set("stages", stages)
        .set("pas", c.pas)
        .set("cost", c.cost)
        .set("batch_sum", c.batch_sum)
        .set("objective", c.objective)
        .set("latency_e2e", c.latency_e2e)
        .set("resources", resources_to_json(c.resources))
}

pub fn config_from_json(j: &Json) -> Result<PipelineConfig, String> {
    let stages = j
        .get("stages")
        .and_then(Json::as_arr)
        .ok_or("config missing 'stages'")?
        .iter()
        .map(stage_from_json)
        .collect::<Result<Vec<_>, _>>()?;
    Ok(PipelineConfig {
        stages,
        pas: get_f64(j, "pas")?,
        cost: get_f64(j, "cost")?,
        batch_sum: get_f64(j, "batch_sum")? as usize,
        objective: get_f64(j, "objective")?,
        latency_e2e: get_f64(j, "latency_e2e")?,
        resources: resources_from_json(j.get("resources").ok_or("config missing 'resources'")?)?,
    })
}

/// Extract the adaptation decisions recorded as `decision` entries —
/// optionally restricted to one fleet member — in journal order, ready
/// to replay via [`crate::simulator::replay`].  `decision_time` is not
/// journaled (it is a wall-clock reading and would break byte-for-byte
/// reproducibility), so it comes back as 0.
pub fn decisions_from_journal(
    journal: &Journal,
    member: Option<u32>,
) -> Result<Vec<Decision>, String> {
    let mut out = Vec::new();
    for e in journal.entries() {
        if e.kind != "decision" {
            continue;
        }
        if let Some(m) = member {
            let em = get_f64(&e.data, "member")? as u32;
            if em != m {
                continue;
            }
        }
        out.push(Decision {
            config: config_from_json(
                e.data.get("config").ok_or("decision entry missing 'config'")?,
            )?,
            lambda_predicted: get_f64(&e.data, "lambda_predicted")?,
            decision_time: 0.0,
            fallback: get_bool(&e.data, "fallback")?,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_roundtrip() {
        let j = Journal::new();
        j.record(1.0, "solve", Json::obj().set("members", 3i64));
        j.record(2.5, "resize", Json::obj().set("target", 12i64).set("axis", 0i64));
        let text = j.to_jsonl();
        let back = Journal::parse_jsonl(&text).unwrap();
        assert_eq!(back.entries(), j.entries());
        assert_eq!(back.to_jsonl(), text);
    }

    #[test]
    fn seq_is_monotone_and_resumes_after_parse() {
        let j = Journal::new();
        assert_eq!(j.record(0.0, "a", Json::Null), 0);
        assert_eq!(j.record(0.0, "b", Json::Null), 1);
        let back = Journal::parse_jsonl(&j.to_jsonl()).unwrap();
        assert_eq!(back.record(1.0, "c", Json::Null), 2);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Journal::parse_jsonl("{nope").is_err());
        assert!(Journal::parse_jsonl("{\"seq\":0}").is_err());
    }

    #[test]
    fn resources_roundtrip() {
        let r = ResourceVec { cpu_cores: 1.5, memory_gb: 4.25, accel_slots: 0.0 };
        let j = resources_to_json(r);
        assert_eq!(resources_from_json(&j).unwrap(), r);
    }
}
