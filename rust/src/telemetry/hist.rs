//! Streaming log-bucketed histograms (HDR-style, fixed 128 buckets).
//!
//! Replaces Vec-accumulation for high-volume series (latency,
//! queue-depth, batch-size, utilization): O(1) record, O(1) memory,
//! mergeable across shards/members by bucket-wise addition.  Exact
//! moments (count/sum/sum-of-squares/min/max) ride alongside the
//! buckets, so `mean`, `min`, `max` and `std` are exact; quantiles are
//! approximate to within one bucket (~±10% relative — the geometric
//! bucket midpoint of a 12.8-buckets-per-decade grid).

use crate::util::stats::Summary;

/// Number of log buckets.
pub const BUCKETS: usize = 128;
/// Lower edge of bucket 0 — smaller values clamp into bucket 0.
const MIN_VALUE: f64 = 1e-6;
/// Decades covered: [1e-6, 1e4) — microseconds to hours when the unit
/// is seconds; also comfortably spans batch sizes and queue depths.
const DECADES: f64 = 10.0;
/// Buckets per decade (12.8 → ~20% relative bucket width).
const PER_DECADE: f64 = BUCKETS as f64 / DECADES;

/// Bucket index for a (non-negative, finite) value.
fn bucket_of(v: f64) -> usize {
    if v <= MIN_VALUE {
        return 0;
    }
    (((v / MIN_VALUE).log10() * PER_DECADE) as usize).min(BUCKETS - 1)
}

/// Lower edge of bucket `i`.
pub fn bucket_lower_edge(i: usize) -> f64 {
    MIN_VALUE * 10f64.powf(i as f64 / PER_DECADE)
}

/// Upper edge of bucket `i` (== lower edge of `i + 1`).
pub fn bucket_upper_edge(i: usize) -> f64 {
    bucket_lower_edge(i + 1)
}

/// Geometric midpoint of bucket `i` — the quantile representative.
fn bucket_mid(i: usize) -> f64 {
    MIN_VALUE * 10f64.powf((i as f64 + 0.5) / PER_DECADE)
}

/// A mergeable streaming histogram.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    counts: [u64; BUCKETS],
    count: u64,
    sum: f64,
    sumsq: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: [0; BUCKETS],
            count: 0,
            sum: 0.0,
            sumsq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Record one observation.  Non-finite values are ignored; negatives
    /// clamp to 0 (bucket 0).
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        let v = v.max(0.0);
        self.counts[bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.sumsq += v * v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Record a whole slice.
    pub fn record_all(&mut self, xs: &[f64]) {
        for &x in xs {
            self.record(x);
        }
    }

    /// Build from a slice.
    pub fn of(xs: &[f64]) -> Histogram {
        let mut h = Histogram::new();
        h.record_all(xs);
        h
    }

    /// Bucket-wise merge: the result is identical to having recorded
    /// both sample streams into one histogram (up to float summation
    /// order in the exact moments).
    pub fn merge(&mut self, other: &Histogram) {
        for (c, o) in self.counts.iter_mut().zip(other.counts.iter()) {
            *c += o;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.sumsq += other.sumsq;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact sum of recorded values.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact mean (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Exact minimum (0.0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact maximum (0.0 when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Raw bucket counts (for exposition formats).
    pub fn bucket_counts(&self) -> &[u64] {
        &self.counts
    }

    /// Approximate percentile, `p` in [0, 100] — nearest-rank over the
    /// buckets, returning the geometric bucket midpoint clamped to the
    /// exact observed [min, max].
    pub fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = (p.clamp(0.0, 100.0) / 100.0) * (self.count - 1) as f64;
        let target = rank.round() as u64 + 1; // 1-indexed rank to reach
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return bucket_mid(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Summary-stats bundle shaped like [`Summary::of`]: `n`, `mean`,
    /// `std`, `min`, `max` are exact; percentiles are bucket-resolution
    /// approximations.
    pub fn summary(&self) -> Summary {
        if self.count == 0 {
            return Summary::of(&[]);
        }
        let n = self.count as f64;
        let mean = self.sum / n;
        let std = if self.count < 2 {
            0.0
        } else {
            (self.sumsq / n - mean * mean).max(0.0).sqrt()
        };
        Summary {
            n: self.count as usize,
            mean,
            std,
            min: self.min,
            p50: self.quantile(50.0),
            p95: self.quantile(95.0),
            p99: self.quantile(99.0),
            max: self.max,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{check, prop_assert, prop_close};
    use crate::util::stats::Summary;

    /// Worst-case multiplicative error of a bucket-midpoint estimate vs
    /// a sample in a neighbouring bucket (edge rounding): 1.5 buckets.
    const BUCKET_ERR: f64 = 1.35;

    #[test]
    fn empty_summary_is_zeroed() {
        let h = Histogram::new();
        assert_eq!(h.summary(), Summary::of(&[]));
        assert_eq!(h.quantile(50.0), 0.0);
        assert_eq!(h.count(), 0);
        assert!(h.is_empty());
    }

    #[test]
    fn exact_moments_match_summary_of() {
        let xs: Vec<f64> = (1..=1000).map(|i| i as f64 * 0.001).collect();
        let h = Histogram::of(&xs);
        let s = h.summary();
        let r = Summary::of(&xs);
        assert_eq!(s.n, r.n);
        assert_eq!(s.min, r.min);
        assert_eq!(s.max, r.max);
        assert!((s.mean - r.mean).abs() < 1e-9, "{} vs {}", s.mean, r.mean);
        assert!((s.std - r.std).abs() < 1e-6, "{} vs {}", s.std, r.std);
    }

    #[test]
    fn quantiles_within_bucket_resolution_on_dense_data() {
        let xs: Vec<f64> = (1..=5000).map(|i| i as f64 * 0.0007).collect();
        let h = Histogram::of(&xs);
        let s = h.summary();
        let r = Summary::of(&xs);
        for (a, b, name) in [(s.p50, r.p50, "p50"), (s.p95, r.p95, "p95"), (s.p99, r.p99, "p99")] {
            assert!(a <= b * BUCKET_ERR && a >= b / BUCKET_ERR, "{name}: {a} vs {b}");
        }
    }

    #[test]
    fn non_finite_ignored_negative_clamped() {
        let mut h = Histogram::new();
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        assert!(h.is_empty());
        h.record(-1.0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.min(), 0.0);
    }

    #[test]
    fn single_value() {
        let h = Histogram::of(&[0.31]);
        let s = h.summary();
        assert_eq!(s.n, 1);
        assert_eq!(s.min, 0.31);
        assert_eq!(s.max, 0.31);
        assert_eq!(s.std, 0.0);
        // clamped to [min, max] the quantile is exact for one sample
        assert_eq!(s.p50, 0.31);
    }

    #[test]
    fn merge_is_concatenation() {
        // hist(a) ⊎ hist(b) must equal hist(a ++ b): identical buckets,
        // identical exact moments (same summation order here), and the
        // merged summary within bucket error of the concatenated-sample
        // Summary::of reference.
        check("hist merge == concat", 150, |g| {
            let a = g.vec_f64(1e-4, 1e3, 128);
            let b = g.vec_f64(1e-4, 1e3, 128);
            let mut ha = Histogram::of(&a);
            let hb = Histogram::of(&b);
            ha.merge(&hb);
            let mut all = a.clone();
            all.extend_from_slice(&b);
            let hc = Histogram::of(&all);
            prop_assert(ha.bucket_counts() == hc.bucket_counts(), "bucket mismatch")?;
            prop_assert(ha.count() == hc.count(), "count mismatch")?;
            prop_close(ha.sum(), hc.sum(), 1e-9 * hc.sum().abs().max(1.0), "sum mismatch")?;
            let s = ha.summary();
            let r = Summary::of(&all);
            prop_assert(s.n == r.n, "n mismatch")?;
            prop_close(s.min, r.min, 0.0, "min mismatch")?;
            prop_close(s.max, r.max, 0.0, "max mismatch")?;
            prop_close(s.mean, r.mean, 1e-9 * r.mean.abs().max(1.0), "mean mismatch")?;
            // nearest-rank order statistics bound the bucketed quantiles
            let mut sorted = all.clone();
            sorted.sort_by(|x, y| x.partial_cmp(y).unwrap());
            for (q, got) in [(50.0, s.p50), (95.0, s.p95), (99.0, s.p99)] {
                let rank = (q / 100.0) * (sorted.len() - 1) as f64;
                let x = sorted[rank.round() as usize];
                prop_assert(
                    got <= x * BUCKET_ERR && got >= x / BUCKET_ERR,
                    &format!("p{q} {got} not within bucket error of rank stat {x}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn edges_are_monotone() {
        for i in 0..BUCKETS {
            assert!(bucket_lower_edge(i) < bucket_upper_edge(i));
            let mid = super::bucket_mid(i);
            assert!(bucket_lower_edge(i) < mid && mid < bucket_upper_edge(i));
        }
    }
}
