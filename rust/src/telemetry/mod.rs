//! Flight recorder: the lock-free telemetry plane.
//!
//! Three coordinated pieces (ISSUE 7):
//!
//! * **Span tracing** ([`Span`], [`Telemetry`]) — sampled requests carry
//!   a trace id and emit one span per stage hop
//!   (arrival → enqueue → queue-wait → batch-form → exec →
//!   forward/done/drop) into per-member lock-free ring buffers
//!   (the [`crate::data_plane::ring`] pattern), drained by
//!   [`Telemetry::take_spans`] and serialized to JSONL.
//! * **Streaming histograms** ([`hist::Histogram`]) — mergeable
//!   log-bucketed series for latency / queue depth / batch size /
//!   utilization, aggregated per member×stage by [`stage_histograms`].
//! * **Decision journal** ([`journal::Journal`]) — seq-stamped
//!   control-plane event log written by the fleet adapter, core,
//!   reconfig and both clocks; replayable via
//!   [`journal::decisions_from_journal`].
//!
//! Determinism: sampling is `trace_id % sample_one_in == 0` (no RNG),
//! spans/journal carry only virtual-clock times, and all recording is
//! observational — a traced DES run is byte-for-byte identical to an
//! untraced one, and two traced runs produce byte-identical JSONL.
//! When `sample_one_in == 0` the plane is fully off: no rings are
//! allocated and the hot path is a branch on an empty Vec.

pub mod export;
pub mod hist;
pub mod journal;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::data_plane::ring::MpscRing;
use crate::util::json::Json;
use hist::Histogram;
use journal::Journal;

/// Telemetry knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TelemetryConfig {
    /// Trace one request in `sample_one_in` (deterministic: request id
    /// modulo).  `0` disables span tracing entirely (no buffers);
    /// `1` traces everything.
    pub sample_one_in: u64,
    /// Capacity of each per-member span ring (rounded up to a power of
    /// two).  On overflow the recorder drains the ring into the sink
    /// under a `try_lock`, or counts a drop if the sink is contended.
    pub span_buffer: usize,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig { sample_one_in: 64, span_buffer: 65_536 }
    }
}

impl TelemetryConfig {
    /// Tracing fully disabled (the zero-cost default for legacy entry
    /// points).
    pub fn off() -> TelemetryConfig {
        TelemetryConfig { sample_one_in: 0, span_buffer: 0 }
    }

    /// Trace every request (tests, waterfalls).
    pub fn full() -> TelemetryConfig {
        TelemetryConfig { sample_one_in: 1, ..Default::default() }
    }
}

/// A stage-hop label on the request's path through the pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Hop {
    /// Request entered the system (span `t` = arrival time).
    Arrival,
    /// Enqueued onto a stage's ingress ring.
    Enqueue,
    /// Waited in a stage queue (`dur` = wait, `value` = queue depth).
    QueueWait,
    /// Batch formation (`value` = batch size).
    BatchForm,
    /// Stage execution (`dur` = service time, `value` = batch size).
    Exec,
    /// Forwarded to the next stage.
    Forward,
    /// Completed the last stage (`dur` = end-to-end latency).
    Done,
    /// Dropped (shed, timeout, or failure).
    Drop,
}

impl Hop {
    pub const ALL: [Hop; 8] = [
        Hop::Arrival,
        Hop::Enqueue,
        Hop::QueueWait,
        Hop::BatchForm,
        Hop::Exec,
        Hop::Forward,
        Hop::Done,
        Hop::Drop,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Hop::Arrival => "arrival",
            Hop::Enqueue => "enqueue",
            Hop::QueueWait => "queue_wait",
            Hop::BatchForm => "batch_form",
            Hop::Exec => "exec",
            Hop::Forward => "forward",
            Hop::Done => "done",
            Hop::Drop => "drop",
        }
    }

    pub fn from_name(s: &str) -> Option<Hop> {
        Hop::ALL.into_iter().find(|h| h.name() == s)
    }
}

/// One recorded hop of one traced request.  `Copy` so the ring moves it
/// without allocation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    /// Trace id (== request id; stable across stages and members).
    pub trace: u64,
    /// Fleet member (0 for single-pipeline runs).
    pub member: u32,
    /// Stage index within the pipeline.
    pub stage: u32,
    pub hop: Hop,
    /// Virtual start time of the hop, seconds.
    pub t: f64,
    /// Duration of the hop, seconds (0 for instantaneous marks).
    pub dur: f64,
    /// Hop-specific magnitude (queue depth, batch size, …).
    pub value: f64,
}

impl Span {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("trace", self.trace as i64)
            .set("member", self.member as i64)
            .set("stage", self.stage as i64)
            .set("hop", self.hop.name())
            .set("t", self.t)
            .set("dur", self.dur)
            .set("value", self.value)
    }

    pub fn from_json(j: &Json) -> Result<Span, String> {
        let num = |k: &str| -> Result<f64, String> {
            j.get(k).and_then(Json::as_f64).ok_or_else(|| format!("span missing '{k}'"))
        };
        let hop_name =
            j.get("hop").and_then(Json::as_str).ok_or("span missing 'hop'")?;
        Ok(Span {
            trace: num("trace")? as u64,
            member: num("member")? as u32,
            stage: num("stage")? as u32,
            hop: Hop::from_name(hop_name).ok_or_else(|| format!("unknown hop '{hop_name}'"))?,
            t: num("t")?,
            dur: num("dur")?,
            value: num("value")?,
        })
    }
}

/// Serialize spans to JSONL (one span per line).
pub fn spans_to_jsonl(spans: &[Span]) -> String {
    let mut out = String::new();
    for s in spans {
        out.push_str(&s.to_json().to_string());
        out.push('\n');
    }
    out
}

/// Parse a span JSONL dump (blank lines skipped).
pub fn spans_from_jsonl(s: &str) -> Result<Vec<Span>, String> {
    let mut out = Vec::new();
    for (ln, line) in s.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(line).map_err(|e| format!("line {}: {e}", ln + 1))?;
        out.push(Span::from_json(&v).map_err(|e| format!("line {}: {e}", ln + 1))?);
    }
    Ok(out)
}

/// The telemetry plane handle: per-member lock-free span rings, an
/// overflow sink, and the shared decision journal.  Cheap to share by
/// reference across workers; all methods take `&self`.
pub struct Telemetry {
    cfg: TelemetryConfig,
    /// One span ring per member (empty when tracing is off).
    shards: Vec<MpscRing<Span>>,
    /// Overflow + drain target: rings spill here when full.
    sink: Mutex<Vec<Span>>,
    /// Spans lost because a full ring met a contended sink.
    dropped: AtomicU64,
    journal: Arc<Journal>,
}

impl std::fmt::Debug for Telemetry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Telemetry(shards={}, cfg={:?})", self.shards.len(), self.cfg)
    }
}

impl Telemetry {
    /// A plane with `members` span shards.
    pub fn new(cfg: TelemetryConfig, members: usize) -> Telemetry {
        let n = if cfg.sample_one_in == 0 { 0 } else { members.max(1) };
        Telemetry {
            cfg,
            shards: (0..n).map(|_| MpscRing::with_capacity(cfg.span_buffer.max(16))).collect(),
            sink: Mutex::new(Vec::new()),
            dropped: AtomicU64::new(0),
            journal: Arc::new(Journal::new()),
        }
    }

    /// Tracing disabled; the journal still works (control-plane events
    /// are rare and never on the hot path).
    pub fn off() -> Telemetry {
        Telemetry::new(TelemetryConfig::off(), 0)
    }

    pub fn config(&self) -> TelemetryConfig {
        self.cfg
    }

    /// Whether span tracing is on at all.
    pub fn enabled(&self) -> bool {
        !self.shards.is_empty()
    }

    /// Deterministic sampling decision for a request/trace id.
    #[inline]
    pub fn sampled(&self, id: u64) -> bool {
        match self.cfg.sample_one_in {
            0 => false,
            1 => true,
            k => id % k == 0,
        }
    }

    /// Shared journal handle for control-plane actors.
    pub fn journal(&self) -> Arc<Journal> {
        Arc::clone(&self.journal)
    }

    /// Record a span (no-op when tracing is off).  Lock-free in the
    /// common case; a full ring is drained into the sink under a
    /// non-blocking `try_lock`, and only a *contended* overflow drops.
    pub fn record(&self, span: Span) {
        if self.shards.is_empty() {
            return;
        }
        let ring = &self.shards[span.member as usize % self.shards.len()];
        if let Err(span) = ring.try_push(span) {
            match self.sink.try_lock() {
                Ok(mut sink) => {
                    while let Some(s) = ring.pop() {
                        sink.push(s);
                    }
                    sink.push(span);
                }
                Err(_) => {
                    self.dropped.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }

    /// Drain every shard (and the overflow sink) into one Vec.  Spans
    /// appear sink-first then shard-by-shard in ring order — stable for
    /// a deterministic producer like the DES.
    pub fn take_spans(&self) -> Vec<Span> {
        let mut sink = self.sink.lock().unwrap();
        for ring in &self.shards {
            while let Some(s) = ring.pop() {
                sink.push(s);
            }
        }
        std::mem::take(&mut *sink)
    }

    /// Spans lost to contended overflow (0 in any deterministic run).
    pub fn dropped_spans(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

/// Per-member×stage histogram bundle aggregated from a span dump.
#[derive(Debug, Clone, Default)]
pub struct StageSeries {
    pub member: u32,
    pub stage: u32,
    /// Queue-wait durations, seconds.
    pub queue_wait: Histogram,
    /// Execution (service) durations, seconds.
    pub exec: Histogram,
    /// Batch sizes at execution.
    pub batch: Histogram,
    /// Queue depth observed at each queue-wait hop.
    pub depth: Histogram,
}

/// Fold spans into per-(member, stage) streaming histograms, sorted by
/// (member, stage).
pub fn stage_histograms(spans: &[Span]) -> Vec<StageSeries> {
    let mut map: BTreeMap<(u32, u32), StageSeries> = BTreeMap::new();
    for s in spans {
        let e = map.entry((s.member, s.stage)).or_insert_with(|| StageSeries {
            member: s.member,
            stage: s.stage,
            ..Default::default()
        });
        match s.hop {
            Hop::QueueWait => {
                e.queue_wait.record(s.dur);
                e.depth.record(s.value);
            }
            Hop::Exec => {
                e.exec.record(s.dur);
                e.batch.record(s.value);
            }
            _ => {}
        }
    }
    map.into_values().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(trace: u64, member: u32, hop: Hop, t: f64, dur: f64) -> Span {
        Span { trace, member, stage: 0, hop, t, dur, value: 1.0 }
    }

    #[test]
    fn off_plane_records_nothing() {
        let tel = Telemetry::off();
        assert!(!tel.enabled());
        assert!(!tel.sampled(0));
        tel.record(span(0, 0, Hop::Arrival, 0.0, 0.0));
        assert!(tel.take_spans().is_empty());
        assert_eq!(tel.dropped_spans(), 0);
    }

    #[test]
    fn sampling_is_deterministic_modulo() {
        let tel = Telemetry::new(TelemetryConfig { sample_one_in: 4, span_buffer: 64 }, 1);
        let picks: Vec<bool> = (0u64..8).map(|i| tel.sampled(i)).collect();
        assert_eq!(picks, vec![true, false, false, false, true, false, false, false]);
        let full = Telemetry::new(TelemetryConfig::full(), 1);
        assert!((0u64..10).all(|i| full.sampled(i)));
    }

    #[test]
    fn record_and_drain_across_shards() {
        let tel = Telemetry::new(TelemetryConfig::full(), 3);
        for m in 0..3u32 {
            for i in 0..5u64 {
                tel.record(span(i, m, Hop::Done, i as f64, 0.1));
            }
        }
        let spans = tel.take_spans();
        assert_eq!(spans.len(), 15);
        assert!(tel.take_spans().is_empty());
    }

    #[test]
    fn overflow_drains_into_sink_without_loss() {
        let tel = Telemetry::new(TelemetryConfig { sample_one_in: 1, span_buffer: 4 }, 1);
        for i in 0..100u64 {
            tel.record(span(i, 0, Hop::Exec, i as f64, 0.01));
        }
        assert_eq!(tel.dropped_spans(), 0);
        let spans = tel.take_spans();
        assert_eq!(spans.len(), 100);
    }

    #[test]
    fn spans_jsonl_roundtrip() {
        let spans = vec![
            Span {
                trace: 7,
                member: 1,
                stage: 2,
                hop: Hop::QueueWait,
                t: 1.5,
                dur: 0.25,
                value: 3.0,
            },
            Span { trace: 8, member: 0, stage: 0, hop: Hop::Done, t: 2.0, dur: 0.5, value: 0.0 },
        ];
        let text = spans_to_jsonl(&spans);
        assert_eq!(spans_from_jsonl(&text).unwrap(), spans);
    }

    #[test]
    fn hop_names_roundtrip() {
        for h in Hop::ALL {
            assert_eq!(Hop::from_name(h.name()), Some(h));
        }
        assert_eq!(Hop::from_name("bogus"), None);
    }

    #[test]
    fn stage_histograms_fold() {
        let spans = vec![
            Span {
                trace: 1,
                member: 0,
                stage: 0,
                hop: Hop::QueueWait,
                t: 0.0,
                dur: 0.1,
                value: 2.0,
            },
            Span { trace: 1, member: 0, stage: 0, hop: Hop::Exec, t: 0.1, dur: 0.3, value: 4.0 },
            Span { trace: 2, member: 1, stage: 1, hop: Hop::Exec, t: 0.2, dur: 0.2, value: 8.0 },
        ];
        let series = stage_histograms(&spans);
        assert_eq!(series.len(), 2);
        assert_eq!((series[0].member, series[0].stage), (0, 0));
        assert_eq!(series[0].queue_wait.count(), 1);
        assert_eq!(series[0].exec.count(), 1);
        assert_eq!(series[0].batch.max(), 4.0);
        assert_eq!((series[1].member, series[1].stage), (1, 1));
        assert_eq!(series[1].batch.max(), 8.0);
    }
}
