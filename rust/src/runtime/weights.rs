//! Deterministic variant-weight generation — bit-exact twin of
//! `python/compile/model.make_params`.
//!
//! Weights never cross the build boundary as data: both sides derive
//! them from `SplitMix64(fnv1a64(key) ^ tensor_index)` so the Rust
//! runtime can feed the AOT graphs the exact tensors the python oracle
//! used when computing the manifest check values.

use crate::util::rng::{fnv1a64, SplitMix64};

/// Shapes of one tower layer's parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerShape {
    pub w: (usize, usize),
    pub b: usize,
}

/// Parameter tensors of a variant tower, flattened per tensor in
/// row-major order, ordered `[W1, b1, W2, b2, ...]`.
#[derive(Debug, Clone)]
pub struct VariantWeights {
    pub key: String,
    pub tensors: Vec<Vec<f32>>,
    pub shapes: Vec<Vec<usize>>,
}

/// Fill `n` f32s in [-0.5, 0.5) from SplitMix64 — python
/// `splitmix64_fill` twin.
pub fn splitmix_fill(seed: u64, n: usize) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|_| rng.next_f32_centered()).collect()
}

/// Generate the weights for a tower of `layers` square layers of width
/// `hidden` (python `make_params` twin: W scaled by 1/sqrt(fan_in),
/// biases by 0.1).
pub fn make_params(key: &str, hidden: usize, layers: usize) -> VariantWeights {
    let base = fnv1a64(key);
    let mut tensors = Vec::with_capacity(2 * layers);
    let mut shapes = Vec::with_capacity(2 * layers);
    for ti in 0..layers {
        let fan_in = hidden;
        let scale = 1.0 / (fan_in as f32).sqrt();
        let mut w = splitmix_fill(base ^ (2 * ti as u64 + 1), hidden * hidden);
        for x in w.iter_mut() {
            *x *= scale;
        }
        tensors.push(w);
        shapes.push(vec![hidden, hidden]);
        let mut b = splitmix_fill(base ^ (2 * ti as u64 + 2), hidden);
        for x in b.iter_mut() {
            *x *= 0.1;
        }
        tensors.push(b);
        shapes.push(vec![hidden]);
    }
    VariantWeights { key: key.to_string(), tensors, shapes }
}

/// The deterministic check input: `ones / sqrt(hidden)` (python
/// `check_input` twin).
pub fn check_input(hidden: usize, batch: usize) -> Vec<f32> {
    vec![1.0 / (hidden as f32).sqrt(); batch * hidden]
}

/// CPU reference forward pass of the tower (f32 accumulation in f64 for
/// stability is NOT used — plain f32 to mirror the XLA numerics).  Used
/// by tests to cross-check the PJRT execution path.
pub fn reference_forward(x: &[f32], batch: usize, hidden: usize, w: &VariantWeights) -> Vec<f32> {
    let layers = w.tensors.len() / 2;
    let mut cur = x.to_vec();
    for li in 0..layers {
        let wt = &w.tensors[2 * li];
        let bt = &w.tensors[2 * li + 1];
        let mut out = vec![0f32; batch * hidden];
        for r in 0..batch {
            for c in 0..hidden {
                let mut acc = 0f32;
                for k in 0..hidden {
                    acc += cur[r * hidden + k] * wt[k * hidden + c];
                }
                acc += bt[c];
                out[r * hidden + c] = if li < layers - 1 { acc.max(0.0) } else { acc };
            }
        }
        cur = out;
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_key() {
        let a = make_params("detect.yolov5n", 32, 3);
        let b = make_params("detect.yolov5n", 32, 3);
        assert_eq!(a.tensors, b.tensors);
        let c = make_params("detect.yolov5s", 32, 3);
        assert_ne!(a.tensors[0], c.tensors[0]);
    }

    #[test]
    fn shapes_and_counts() {
        let w = make_params("x", 64, 3);
        assert_eq!(w.tensors.len(), 6);
        assert_eq!(w.tensors[0].len(), 64 * 64);
        assert_eq!(w.tensors[1].len(), 64);
        assert_eq!(w.shapes[0], vec![64, 64]);
        assert_eq!(w.shapes[5], vec![64]);
    }

    #[test]
    fn weight_scale_bounded() {
        let w = make_params("x", 64, 3);
        let lim = 0.5 / 8.0; // 0.5 * 1/sqrt(64)
        assert!(w.tensors[0].iter().all(|v| v.abs() <= lim + 1e-7));
        assert!(w.tensors[1].iter().all(|v| v.abs() <= 0.05 + 1e-7));
    }

    #[test]
    fn fill_matches_rng_contract() {
        let v = splitmix_fill(1, 4);
        let mut rng = SplitMix64::new(1);
        for x in v {
            assert_eq!(x, rng.next_f32_centered());
        }
    }

    #[test]
    fn reference_forward_identity_shapes() {
        let w = make_params("k", 32, 3);
        let x = check_input(32, 2);
        let y = reference_forward(&x, 2, 32, &w);
        assert_eq!(y.len(), 2 * 32);
        assert!(y.iter().all(|v| v.is_finite()));
        // batch rows identical for identical inputs
        assert_eq!(&y[..32], &y[32..]);
    }
}
