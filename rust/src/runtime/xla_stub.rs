//! Offline stand-in for the `xla` crate (PJRT bindings).
//!
//! The repro gate has no network and no PJRT shared library, so the
//! runtime compiles against this stub: the same type/method surface
//! [`super::engine`] uses, with every entry point failing cleanly at
//! `PjRtClient::cpu()`.  Swapping this module for the real `xla` crate
//! (add the dependency and change one `use` line in `engine.rs`)
//! restores real artifact execution; everything downstream — pool,
//! serving engine, CLI — already degrades gracefully on the error.
#![allow(dead_code)]

/// Stub error (mirrors `xla::Error`'s Debug-only contract).
#[derive(Debug, Clone)]
pub struct Error(pub String);

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(
        "PJRT backend unavailable: built with the offline xla stub (see runtime/xla_stub.rs)"
            .to_string(),
    ))
}

/// Stub of `xla::PjRtClient`.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        unavailable()
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }

    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// Stub of `xla::PjRtBuffer`.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Stub of `xla::Literal`.
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _priv: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_fails_cleanly() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(format!("{e:?}").contains("offline xla stub"));
    }
}
